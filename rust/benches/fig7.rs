//! Bench: regenerate **Fig. 7** — OMD-RT vs SGP vs OPT convergence on
//! Connected-ER(25, 0.2), λ=60, W=3, D=exp(F/C).
//!
//! Expected shape (paper): both converge to OPT; OMD-RT dominates the first
//! ~10 iterations and is essentially at OPT by iteration 50 while SGP is
//! still converging.

use jowr::config::ExperimentConfig;
use jowr::experiments;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let cfg = ExperimentConfig::paper_default();
    let iters = if quick { 30 } else { 200 };
    println!("=== fig7: routing convergence (ER(25,0.2), {iters} iters) ===");
    let (s, opt_cost) = experiments::fig7(&cfg, iters).expect("fig7 scenario");
    let omd = s.get("omd_rt").unwrap();
    let sgp = s.get("sgp").unwrap();
    // paper-shape assertions
    let at10 = 10.min(omd.len() - 1);
    println!(
        "iter 10: OMD {:.4}  SGP {:.4}  |  iter 50: OMD {:.4}  SGP {:.4}  |  OPT {:.4}",
        omd[at10],
        sgp[at10],
        omd[50.min(omd.len() - 1)],
        sgp[50.min(sgp.len() - 1)],
        opt_cost
    );
    assert!(omd[at10] <= sgp[at10] + 1e-9, "OMD must dominate SGP early");
    let omd50 = omd[50.min(omd.len() - 1)];
    let gap = (omd50 - opt_cost) / opt_cost;
    println!("OMD@50 relative gap to OPT: {:.2e}", gap);
    assert!(gap < 0.01, "OMD should nearly reach OPT by iter 50 (gap {gap})");
    println!("fig7 OK");
}
