//! Bench: hot-path microbenchmarks — fused engine vs legacy four-sweep —
//! plus the native-vs-XLA ablation.
//!
//! Covers the per-iteration cost breakdown of OMD-RT on paper-sized
//! instances five ways:
//!
//! * the **reference** sweeps (`flow::node_rates` / `flow::edge_flows` /
//!   `flow::total_cost` / `marginal::compute`, freshly allocated every
//!   call — the pre-engine hot path),
//! * the **engine** fused forward+reverse sweep ([`FlowEngine::prepare`])
//!   at 1, 2, and 4 workers (thread-scaling rows) on the persistent
//!   worker pool, plus the legacy per-sweep `thread::scope` spawn at 4
//!   workers (`engine_fused_prepare_scope_w4`) as the pool's baseline,
//! * the **session-batched SoA** kernels vs the scalar per-session
//!   kernels on a multi-class scenario (12 sessions, blocks of width 4):
//!   `mc{25,40}/engine_fused_prepare_{batched,scalar}_w{1,4}` — batched
//!   must be at least as fast (asserted; results bit-identical) — and,
//!   under `--features simd`, the explicit 4-lane kernels
//!   (`mc{25,40}/engine_fused_prepare_simd_w{1,4}`, asserted to be at
//!   least as fast as batched within noise, bit-identical),
//! * the **incremental dirty-session path** on a 40-node clustered fleet
//!   (20 per-cluster task classes, hardened post-convergence φ):
//!   `clusters40/engine_prepare_dirty_block` re-evaluates a single-class
//!   λ perturbation ≥ 3× faster than `clusters40/engine_prepare_full`
//!   (asserted; the delta state stays bit-identical to a full sweep),
//!   plus the **row-sparse OMD probe loop** on the same fleet
//!   (`clusters40/omd_probe_loop_{dense,sparse}`): a warmed
//!   [`SingleStepOracle`] probe pair through `observe_dirty` with
//!   `sparse_tol` armed must beat the dense `observe` loop ≥ 2×
//!   (asserted), and
//! * full `omd_full_iteration` / `sgp_engine_iteration` solver steps, with
//!   a faithfully reconstructed legacy OMD iteration as the baseline (the
//!   SGP row's "engine" name puts it under the CI bench-regression gate,
//!   pinning the workspace-backed Hessian-bound DPs), and
//! * the **sharded coordination plane** at fleet scale
//!   (`fleet1e4/sharded_round_throughput`): a synthetic 10⁴-node fleet
//!   carrying 10⁵ sessions in the compact ShardBlock lane layout, K=4
//!   shards over the loopback transport at staleness S=1, driven through
//!   the real `ShardPlane::run_round` path — the session-rounds/sec figure
//!   carries a CI-gated 250k floor (asserted in-bench too), and
//! * the **request-level DES replay** (`sim_replay_{heap,calendar,hdr}`):
//!   the two-class paper scenario replayed over an 18000 s horizon
//!   (≥ 10⁶ requests in full mode) on the pinned PR-6 reference engine
//!   vs. the optimized calendar-queue/CSR/slab core (asserted
//!   bitwise-equal and ≥ 2× faster) vs. the streaming-histogram latency
//!   mode; `sim_replay_events_per_sec` carries a CI-gated 600k floor.
//!
//! Emits every measurement plus the speedup ratios as JSON to
//! `BENCH_hotpath.json` (written to the current directory) and asserts the
//! shape invariants above plus the two originals: the fused
//! single-threaded engine beats the legacy four-sweep iteration, and one
//! OMD iteration stays far cheaper than one SGP iteration (the Fig. 9
//! effect at micro scale). Run with `--quick` for the CI smoke
//! configuration.

use jowr::allocation::oracle::SingleStepOracle;
use jowr::model::flow::{self, Phi};
use jowr::model::utility::family;
use jowr::prelude::*;
use jowr::routing::marginal;
use jowr::util::bench::{Bencher, Measurement};
use jowr::util::json::Json;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut b = if quick { Bencher::quick() } else { Bencher::default() };

    for &n in &[25usize, 40] {
        let session = Scenario::paper_default().nodes(n).build().expect("scenario");
        let problem = &session.problem;
        let lam = session.uniform_allocation();
        let phi = Phi::uniform(&problem.net);
        let t = flow::node_rates(&problem.net, &phi, &lam);
        let flows = flow::edge_flows(&problem.net, &phi, &t);

        println!("--- ER({n}) hot path ---");
        // reference sweeps (the pre-engine implementation, kept as the
        // equivalence baseline)
        b.bench(&format!("n{n}/ref_flow_propagation"), || {
            flow::node_rates(&problem.net, &phi, &lam)
        });
        b.bench(&format!("n{n}/ref_edge_flows"), || {
            flow::edge_flows(&problem.net, &phi, &t)
        });
        b.bench(&format!("n{n}/ref_marginal_broadcast"), || {
            marginal::compute(problem, &phi, &flows)
        });
        b.bench(&format!("n{n}/ref_four_sweep"), || {
            let t = flow::node_rates(&problem.net, &phi, &lam);
            let flows = flow::edge_flows(&problem.net, &phi, &t);
            let cost = flow::total_cost(problem, &flows);
            let m = marginal::compute(problem, &phi, &flows);
            (cost, m.dprime.len())
        });

        // engine fused sweeps + thread scaling (per-session parallelism on
        // the persistent pool; results are bit-identical at every worker
        // count)
        let mut cost_w1 = 0.0;
        for &workers in &[1usize, 2, 4] {
            let mut eng = FlowEngine::new().with_workers(workers);
            let c = eng.prepare(problem, &phi, &lam); // warm-up: allocate once
            if workers == 1 {
                cost_w1 = c;
            } else {
                assert_eq!(
                    c.to_bits(),
                    cost_w1.to_bits(),
                    "engine must be bit-identical at {workers} workers"
                );
            }
            b.bench(&format!("n{n}/engine_fused_prepare_w{workers}"), || {
                eng.prepare(problem, &phi, &lam)
            });
        }
        // the retired strategy: per-sweep thread::scope spawn at 4 workers
        // (what `--workers` cost before the persistent pool)
        {
            let mut eng =
                FlowEngine::new().with_workers(4).with_persistent_pool(false);
            let c = eng.prepare(problem, &phi, &lam);
            assert_eq!(c.to_bits(), cost_w1.to_bits(), "scope strategy must agree bitwise");
            b.bench(&format!("n{n}/engine_fused_prepare_scope_w4"), || {
                eng.prepare(problem, &phi, &lam)
            });
        }

        // full solver iterations: engine-backed registry router vs the
        // reconstructed legacy iteration (four sweeps + eq. 22 row update)
        let mut p_buf = phi.clone();
        let mut omd = session.router("omd").expect("registry omd");
        b.bench(&format!("n{n}/omd_full_iteration"), || {
            p_buf.clone_from(&phi);
            omd.step(problem, &lam, &mut p_buf)
        });
        b.bench(&format!("n{n}/omd_legacy_iteration"), || {
            p_buf.clone_from(&phi);
            legacy_omd_iteration(problem, &lam, &mut p_buf, session.cfg.eta_routing)
        });
        // SGP's full iteration (QP rows + the Hessian-bound DPs, now in
        // router-owned workspaces). The row name carries "engine" so the
        // CI bench-regression gate pins the workspace optimization.
        let mut sgp = session.router("sgp").expect("registry sgp");
        b.bench(&format!("n{n}/sgp_engine_iteration"), || {
            p_buf.clone_from(&phi);
            sgp.step(problem, &lam, &mut p_buf)
        });

        // native vs XLA ablation (skipped gracefully without artifacts)
        #[cfg(feature = "xla")]
        match jowr::runtime::XlaRuntime::try_default() {
            Some(mut rt) => {
                match jowr::runtime::routing_step::DenseNet::build(&rt, problem) {
                    Ok(dense) => {
                        // warm compile
                        let mut p = phi.clone();
                        let _ = jowr::runtime::routing_step::routing_step_xla(
                            &mut rt, &dense, problem, &mut p, &lam, 0.5,
                        );
                        b.bench(&format!("n{n}/xla_routing_step"), || {
                            let mut p = phi.clone();
                            jowr::runtime::routing_step::routing_step_xla(
                                &mut rt, &dense, problem, &mut p, &lam, 0.5,
                            )
                            .expect("xla routing step")
                        });
                    }
                    Err(e) => println!("(xla routing_step unavailable: {e})"),
                }
            }
            None => println!("(artifacts/ not built — skipping XLA ablation)"),
        }
        #[cfg(not(feature = "xla"))]
        println!("(built without the xla feature — skipping XLA ablation)");
    }

    // session-batched SoA kernels vs the scalar per-session kernels on a
    // multi-class workload (4 task classes × 3 versions = 12 sessions,
    // version blocks of width 4); results are bit-identical, only the
    // layout differs
    for &n in &[25usize, 40] {
        let session = Scenario::paper_default()
            .nodes(n)
            .seed(7)
            .class("c0", "log", 20.0, &[])
            .class("c1", "log", 20.0, &[1, 5])
            .class("c2", "log", 15.0, &[2, 9])
            .class("c3", "log", 15.0, &[3, 11])
            .build()
            .expect("multi-class scenario");
        let problem = &session.problem;
        assert!(problem.n_sessions() >= 8, "the batched rows need W ≥ 8 sessions");
        let lam = session.uniform_allocation();
        let phi = Phi::uniform(&problem.net);
        println!("--- multi-class ER({n}), {} sessions ---", problem.n_sessions());
        let mut cost_w1 = 0.0;
        for &workers in &[1usize, 4] {
            let mut scalar =
                FlowEngine::new().with_workers(workers).with_batch_mode(BatchMode::Scalar);
            let cs = scalar.prepare(problem, &phi, &lam);
            let mut batched =
                FlowEngine::new().with_workers(workers).with_batch_mode(BatchMode::Batched);
            let cb = batched.prepare(problem, &phi, &lam);
            assert_eq!(cs.to_bits(), cb.to_bits(), "batched must agree bitwise");
            if workers == 1 {
                cost_w1 = cs;
            } else {
                assert_eq!(cs.to_bits(), cost_w1.to_bits(), "worker bit-identity");
            }
            b.bench(&format!("mc{n}/engine_fused_prepare_scalar_w{workers}"), || {
                scalar.prepare(problem, &phi, &lam)
            });
            b.bench(&format!("mc{n}/engine_fused_prepare_batched_w{workers}"), || {
                batched.prepare(problem, &phi, &lam)
            });
            // explicit 4-lane kernels on the padded layout (bit-identical
            // to both scalar and batched; see the reduction-order contract
            // in the engine module docs)
            #[cfg(feature = "simd")]
            {
                let mut simd =
                    FlowEngine::new().with_workers(workers).with_batch_mode(BatchMode::Simd);
                let cv = simd.prepare(problem, &phi, &lam);
                assert_eq!(cv.to_bits(), cs.to_bits(), "simd must agree bitwise");
                b.bench(&format!("mc{n}/engine_fused_prepare_simd_w{workers}"), || {
                    simd.prepare(problem, &phi, &lam)
                });
            }
        }
    }

    // incremental dirty-session path: a 40-node clustered fleet (20
    // clusters × 2 devices, one task class per cluster, both versions
    // hosted in every cluster). After OMD-RT concentrates routing inside
    // the clusters (sub-threshold lanes hardened to exact zeros — the
    // steady-state shape), a single class's λ perturbation touches one
    // cluster's flows: prepare_dirty re-sweeps 2 of 40 sessions and
    // reprices only the affected edges
    {
        let session = clustered_fleet_session();
        let problem = &session.problem;
        let n_sess = problem.n_sessions();
        println!("--- clustered fleet (n=40, {n_sess} sessions) ---");
        let report =
            session.routing_run("omd", 80).expect("clustered omd run").finish();
        let mut phi = report.phi.expect("routing runs expose phi");
        sparsify_phi(&problem.net, &mut phi, 1e-4);
        let lam_a = session.uniform_allocation();
        let mut lam_b = lam_a.clone();
        lam_b[0] = lam_a[0] + 1.0;
        lam_b[1] = lam_a[1] - 1.0;
        let mask = SessionMask::block(n_sess, 0, 2);

        let mut full = FlowEngine::new();
        full.prepare(problem, &phi, &lam_a);
        let mut flip = false;
        b.bench("clusters40/engine_prepare_full", || {
            flip = !flip;
            full.prepare(problem, &phi, if flip { &lam_b } else { &lam_a })
        });
        let mut delta = FlowEngine::new();
        delta.prepare(problem, &phi, &lam_a);
        let mut flip2 = false;
        b.bench("clusters40/engine_prepare_dirty_block", || {
            flip2 = !flip2;
            delta.prepare_dirty(problem, &phi, if flip2 { &lam_b } else { &lam_a }, &mask)
        });
        // sanity (outside the timed loops): the delta state is
        // bit-identical to a fresh full sweep at the same point
        let c_delta = delta.prepare_dirty(problem, &phi, &lam_b, &mask);
        let c_full = FlowEngine::new().prepare(problem, &phi, &lam_b);
        assert_eq!(c_delta.to_bits(), c_full.to_bits(), "dirty path must stay bit-identical");
    }

    // row-sparse OMD probe loop on the same clustered fleet: a warmed
    // single-step oracle alternating a ±probe pair on one class block.
    // The dense row drives plain `observe` (full prepare + full row loop +
    // full post-step sweep); the sparse row drives `observe_dirty` with
    // the class mask and `sparse_tol` armed, so the pre-step sweep covers
    // mask ∪ pending φ rows, converged rows skip their exp-heavy update,
    // and the post-step cost re-sweeps only the touched rows
    {
        let session = clustered_fleet_session();
        let problem = session.problem.clone();
        let n_sess = problem.n_sessions();
        let utils = family("log", n_sess, 60.0).expect("log utility family");
        let mut dense = SingleStepOracle::new(problem.clone(), utils.clone(), 0.5);
        let lam0 = dense.uniform_allocation();
        let (s0, s1, _) = dense.blocks()[0];
        assert!(s1 - s0 >= 2, "the probe pair needs a class block of ≥ 2 sessions");
        let mut lam_up = lam0.clone();
        lam_up[s0] += 0.3;
        lam_up[s0 + 1] -= 0.3;
        let mask = SessionMask::block(n_sess, s0, s1);
        for _ in 0..60 {
            dense.observe(&lam0); // warm: routing concentrates per cluster
        }
        b.bench("clusters40/omd_probe_loop_dense", || {
            dense.observe(&lam_up) + dense.observe(&lam0)
        });
        let mut sparse = SingleStepOracle::new(problem, utils, 0.5);
        sparse.router.sparse_tol = 1e-12;
        for _ in 0..60 {
            sparse.observe(&lam0);
        }
        b.bench("clusters40/omd_probe_loop_sparse", || {
            sparse.observe_dirty(&lam_up, &mask) + sparse.observe_dirty(&lam0, &mask)
        });
    }

    // request-level DES replay: drive the two-class paper scenario through
    // an OMD warm-up, then replay the full horizon three ways:
    //   sim_replay_heap     — the pinned PR-6 reference engine (BinaryHeap
    //                         scheduler, nested routing tables, no slab
    //                         recycling, exact latency vectors)
    //   sim_replay_calendar — the optimized core (calendar queue, CSR
    //                         routes, slab pool), exact latency mode;
    //                         asserted bitwise-equal to the heap row
    //   sim_replay_hdr      — the optimized core with streaming latency
    //                         histograms (O(1) telemetry memory)
    // Full mode replays ≥ 10^6 requests (asserted) and enforces calendar
    // ≥ 2× heap plus the 600k events/s floor (3× the PR-6 gate floor);
    // --quick shortens the horizon for the CI smoke run. The events/sec
    // figures and the ratio land in the speedups table so the
    // bench-regression gate can pin floors under them.
    let sim_events_per_sec;
    let sim_calendar_vs_heap;
    let sim_hdr_events_per_sec;
    {
        let mut session = Scenario::paper_default()
            .nodes(20)
            .seed(42)
            .class("video", "log", 40.0, &[0, 1, 2])
            .class("audio", "sqrt", 20.0, &[])
            .build()
            .expect("sim scenario");
        let horizon_s = if quick { 2_000.0 } else { 18_000.0 };
        session.spec.sim = Some(SimSpec { horizon_s, ..SimSpec::default() });
        let optimized =
            session.routing_run("omd", 30).expect("sim omd warm-up").finish();
        println!("--- request-level replay (two-class ER(20), {horizon_s}s horizon) ---");
        // the optimized (Λ, φ) and arrival streams, exactly as sim_run
        // wires them, for the reference engine's one-shot entry point
        let phi = optimized.final_phi().expect("omd run carries phi");
        let traces: Vec<ArrivalTrace> = session
            .spec
            .classes
            .iter()
            .map(|class| match &class.rate {
                RateSpec::Constant(r) => ArrivalTrace::constant(*r),
                RateSpec::Trace(pts) => ArrivalTrace::from_breakpoints(pts, 1.0),
            })
            .collect();
        let (heap_report, dt_heap) = Bencher::once("sim_replay_heap", || {
            simulate_requests_reference(
                &session.problem,
                phi,
                &optimized.lam,
                traces.clone(),
                SimSpec { horizon_s, ..SimSpec::default() },
                session.cfg.seed,
            )
        });
        let (cal_report, dt_cal) = Bencher::once("sim_replay_calendar", || {
            let run = session.sim_run(1).expect("sim run");
            let (_, report) = run.warm_start_from(&optimized).finish();
            report
        });
        assert_eq!(
            cal_report, heap_report,
            "calendar/CSR/slab hot path must reproduce the reference engine bitwise"
        );
        session.spec.sim =
            Some(SimSpec { horizon_s, latency: LatencyMode::Hdr, ..SimSpec::default() });
        let (hdr_report, dt_hdr) = Bencher::once("sim_replay_hdr", || {
            let run = session.sim_run(1).expect("sim hdr run");
            let (_, report) = run.warm_start_from(&optimized).finish();
            report
        });
        assert_eq!(
            hdr_report.events, cal_report.events,
            "hdr telemetry must not alter the event history"
        );
        assert_eq!(hdr_report.peak_inflight, cal_report.peak_inflight);
        sim_events_per_sec = cal_report.events as f64 / dt_cal.max(1e-12);
        sim_calendar_vs_heap = dt_heap / dt_cal.max(1e-12);
        sim_hdr_events_per_sec = hdr_report.events as f64 / dt_hdr.max(1e-12);
        println!(
            "sim replay: {} arrivals, {} events | heap {dt_heap:.2}s, calendar {dt_cal:.2}s \
             ({:.2}M events/s, {:.2}x vs heap), hdr {dt_hdr:.2}s ({:.2}M events/s), \
             peak in-flight {}",
            cal_report.arrivals,
            cal_report.events,
            sim_events_per_sec / 1e6,
            sim_calendar_vs_heap,
            sim_hdr_events_per_sec / 1e6,
            cal_report.peak_inflight
        );
        // the one-shot rows still enter the results table (single-sample
        // measurements) so the baseline-relative regression gate tracks them
        for (name, dt) in [
            ("sim_replay_heap", dt_heap),
            ("sim_replay_calendar", dt_cal),
            ("sim_replay_hdr", dt_hdr),
        ] {
            b.results.push(Measurement { name: name.to_string(), samples: vec![dt] });
        }
        assert_eq!(
            cal_report.arrivals,
            cal_report.completed + cal_report.dropped + cal_report.in_flight,
            "sim replay must conserve requests"
        );
        if !quick {
            assert!(
                cal_report.arrivals >= 1_000_000,
                "full-mode replay must cover ≥ 10^6 requests (got {})",
                cal_report.arrivals
            );
            assert!(
                sim_calendar_vs_heap >= 2.0,
                "calendar/CSR/slab hot path must be ≥ 2x the reference engine on \
                 the 10^6-request replay (got {sim_calendar_vs_heap:.2}x)"
            );
            assert!(
                sim_events_per_sec >= 600_000.0,
                "replay fell under the 600k events/s floor (3x the PR-6 gate floor): \
                 {sim_events_per_sec:.0}"
            );
        }
    }

    // sharded coordination plane at fleet scale: 2500 clusters × 4 devices
    // = 10⁴ nodes carrying 10⁵ sessions (40 per cluster, 5 lanes each) over
    // ~25k edges, partitioned across K=4 leader shards on the loopback
    // transport with staleness bound S=1. The synthetic fleet is lowered
    // straight into the compact ShardBlock lane layout (a dense Phi at this
    // scale would need ~10⁵ × 10⁵ lane slots) and driven through the *real*
    // `ShardPlane::run_round` path — forward sweeps, delta gossip,
    // staleness sync, pricing, reverse sweeps, mirror updates. The
    // sessions×rounds/sec figure lands in the speedups table so the CI
    // bench-regression gate can pin a floor under it.
    let fleet_throughput;
    {
        use jowr::coordinator::shard::ShardBlock;
        use std::sync::Arc;
        use std::time::Duration;

        const CLUSTERS: usize = 2_500;
        const DEVICES_PER_CLUSTER: usize = 4;
        const EDGES_PER_CLUSTER: usize = 10;
        const SESSIONS_PER_CLUSTER: usize = 40;
        const SESSIONS: usize = CLUSTERS * SESSIONS_PER_CLUSTER;
        const SHARDS: usize = 4;
        let n_nodes = CLUSTERS * DEVICES_PER_CLUSTER;
        assert_eq!(n_nodes, 10_000, "the scale row is a 10^4-node fleet");
        assert_eq!(SESSIONS, 100_000, "the scale row is a 10^5-session fleet");
        let ne = CLUSTERS * EDGES_PER_CLUSTER;
        let per_shard = SESSIONS / SHARDS;
        let blocks: Vec<ShardBlock> = (0..SHARDS)
            .map(|g| {
                let mut block = ShardBlock::default();
                for s in g * per_shard..(g + 1) * per_shard {
                    // sessions stay cluster-local: 4-row DAG, 5 lanes over
                    // the owning cluster's edge pool (session-varied picks)
                    let base = (s / SESSIONS_PER_CLUSTER) * EDGES_PER_CLUSTER;
                    let e = |j: usize| base + (s + 2 * j + 1) % EDGES_PER_CLUSTER;
                    let l0 = block.lane_edge.len();
                    block.lane_edge.extend([e(0), e(1)]);
                    block.lane_dst.extend([1, 2]);
                    block.phi.extend([0.5, 0.5]);
                    let l1 = block.lane_edge.len();
                    block.lane_edge.extend([e(2), e(3)]);
                    block.lane_dst.extend([2, 3]);
                    block.phi.extend([0.5, 0.5]);
                    let l2 = block.lane_edge.len();
                    block.lane_edge.push(e(4));
                    block.lane_dst.push(3);
                    block.phi.push(1.0);
                    let l3 = block.lane_edge.len();
                    block.rows.push(vec![(l0, l1), (l1, l2), (l2, l3), (l3, l3)]);
                    block.sessions.push(s);
                    block.lam.push(0.0);
                    block.src.push(0);
                }
                block
            })
            .collect();
        let mut plane = ShardPlane::new(
            blocks,
            vec![50.0; ne],
            vec![jowr::model::cost::CostKind::Exp; ne],
            1,
            Arc::new(Loopback::new(SHARDS)),
            Duration::from_secs(30),
        )
        .expect("fleet plane");
        assert_eq!(plane.n_sessions(), SESSIONS);
        plane.set_lam(&vec![0.01; SESSIONS]);
        let rounds = if quick { 4 } else { 24 };
        println!(
            "--- sharded fleet (10^4 nodes, 10^5 sessions, K={SHARDS}, S=1, \
             {rounds} rounds) ---"
        );
        let (_, dt) = Bencher::once("fleet1e4/sharded_rounds", || {
            for _ in 0..rounds {
                plane.run_round(0.05).expect("staleness-bounded round");
            }
        });
        fleet_throughput = (SESSIONS * rounds) as f64 / dt.max(1e-12);
        let comm = plane.transport().comm();
        println!(
            "sharded rounds: {rounds} rounds x {SESSIONS} sessions in {dt:.3}s \
             ({:.2}M session-rounds/s, {} gossip msgs, {:.1} MB)",
            fleet_throughput / 1e6,
            comm.messages,
            comm.bytes as f64 / 1e6
        );
        // protocol accounting: one delta per (shard, peer) per round
        assert_eq!(comm.messages, (rounds * SHARDS * (SHARDS - 1)) as u64);
        // the mirror updates kept every row on the simplex
        for block in plane.blocks() {
            for rows in &block.rows {
                for &(l0, l1) in rows {
                    if l1 - l0 < 2 {
                        continue;
                    }
                    let sum: f64 = block.phi[l0..l1].iter().sum();
                    assert!(
                        (sum - 1.0).abs() < 1e-9 && block.phi[l0..l1].iter().all(|p| p.is_finite()),
                        "row left the simplex: sum {sum}"
                    );
                }
            }
        }
        // CI throughput floor (mirrored in ci/check_bench_regression.py)
        assert!(
            fleet_throughput >= 250_000.0,
            "sharded plane fell under the 250k session-rounds/s floor: {fleet_throughput:.0}"
        );
    }

    // summary table
    println!("\n=== hotpath summary ===");
    for m in &b.results {
        println!("{}", m.report());
    }

    // speedup rows: engine vs legacy, per instance size + thread scaling
    let mut speedups: Vec<(String, f64)> = Vec::new();
    for &n in &[25usize, 40] {
        if let (Some(sweep_ref), Some(sweep_eng)) = (
            median(&b, &format!("n{n}/ref_four_sweep")),
            median(&b, &format!("n{n}/engine_fused_prepare_w1")),
        ) {
            speedups.push((format!("n{n}/fused_sweep_vs_reference"), sweep_ref / sweep_eng));
        }
        if let (Some(legacy), Some(engine)) = (
            median(&b, &format!("n{n}/omd_legacy_iteration")),
            median(&b, &format!("n{n}/omd_full_iteration")),
        ) {
            speedups.push((format!("n{n}/omd_engine_vs_legacy"), legacy / engine));
        }
        if let Some(w1) = median(&b, &format!("n{n}/engine_fused_prepare_w1")) {
            for &workers in &[2usize, 4] {
                if let Some(wk) = median(&b, &format!("n{n}/engine_fused_prepare_w{workers}")) {
                    speedups.push((format!("n{n}/thread_scaling_w{workers}"), w1 / wk));
                }
            }
        }
        if let (Some(scope), Some(pool)) = (
            median(&b, &format!("n{n}/engine_fused_prepare_scope_w4")),
            median(&b, &format!("n{n}/engine_fused_prepare_w4")),
        ) {
            speedups.push((format!("n{n}/pool_vs_scope_w4"), scope / pool));
        }
        for &workers in &[1usize, 4] {
            if let (Some(scalar), Some(batched)) = (
                median(&b, &format!("mc{n}/engine_fused_prepare_scalar_w{workers}")),
                median(&b, &format!("mc{n}/engine_fused_prepare_batched_w{workers}")),
            ) {
                speedups
                    .push((format!("mc{n}/batched_vs_scalar_w{workers}"), scalar / batched));
            }
            // absent without --features simd (the row doesn't exist)
            if let (Some(batched), Some(simd)) = (
                median(&b, &format!("mc{n}/engine_fused_prepare_batched_w{workers}")),
                median(&b, &format!("mc{n}/engine_fused_prepare_simd_w{workers}")),
            ) {
                speedups.push((format!("mc{n}/simd_vs_batched_w{workers}"), batched / simd));
            }
        }
    }
    if let (Some(full), Some(delta)) = (
        median(&b, "clusters40/engine_prepare_full"),
        median(&b, "clusters40/engine_prepare_dirty_block"),
    ) {
        speedups.push(("clusters40/dirty_vs_full".to_string(), full / delta));
    }
    if let (Some(dense), Some(sparse)) = (
        median(&b, "clusters40/omd_probe_loop_dense"),
        median(&b, "clusters40/omd_probe_loop_sparse"),
    ) {
        speedups.push(("clusters40/omd_probe_sparse_vs_dense".to_string(), dense / sparse));
    }
    // not a ratio: raw DES throughput on the optimized core, floored by
    // the CI regression gate, plus the calendar-vs-heap speedup and the
    // streaming-histogram throughput for the trajectory
    speedups.push(("sim_replay_events_per_sec".to_string(), sim_events_per_sec));
    speedups.push(("sim_replay_calendar_vs_heap".to_string(), sim_calendar_vs_heap));
    speedups.push(("sim_replay_hdr_events_per_sec".to_string(), sim_hdr_events_per_sec));
    // not a ratio either: raw sharded-plane throughput on the 10⁴-node /
    // 10⁵-session fleet (sessions×rounds per second), floored by the gate
    speedups.push(("fleet1e4/sharded_round_throughput".to_string(), fleet_throughput));
    for (name, x) in &speedups {
        println!("{name:<40} {x:.2}x");
    }

    // JSON dump for the perf trajectory (BENCH_*.json)
    let results = Json::Arr(
        b.results
            .iter()
            .map(|m| {
                Json::obj(vec![
                    ("name", Json::from(m.name.as_str())),
                    ("median_s", Json::from(m.median_s())),
                    ("mad_s", Json::from(m.mad_s())),
                    ("min_s", Json::from(m.min_s())),
                    ("samples", Json::from(m.samples.len())),
                ])
            })
            .collect(),
    );
    let speedup_json =
        Json::Obj(speedups.iter().map(|(k, v)| (k.clone(), Json::from(*v))).collect());
    let doc = Json::obj(vec![
        ("bench", Json::from("hotpath")),
        ("quick", Json::from(quick)),
        ("results", results),
        ("speedups", speedup_json),
    ]);
    match std::fs::write("BENCH_hotpath.json", doc.to_string()) {
        Ok(()) => println!("\nwrote BENCH_hotpath.json"),
        Err(e) => println!("\n(could not write BENCH_hotpath.json: {e})"),
    }

    // shape assertions
    for &n in &[25usize, 40] {
        let engine = median(&b, &format!("n{n}/omd_full_iteration"));
        let legacy = median(&b, &format!("n{n}/omd_legacy_iteration"));
        if let (Some(e), Some(l)) = (engine, legacy) {
            println!("n{n} OMD iteration engine vs legacy: {:.2}x", l / e);
            assert!(
                e < l,
                "fused engine ({e:.3e}s) must beat legacy four-sweep ({l:.3e}s) at n={n}"
            );
        }
    }
    // the persistent pool must be at least as fast as the per-sweep
    // thread::scope spawn it replaced (ROADMAP: spawn per sweep is
    // measurable at n≲25 with workers>1) — checked on the paper-default
    // n=25 topology at 4 workers, with a little slack for runner noise
    if let (Some(pool), Some(scope)) = (
        median(&b, "n25/engine_fused_prepare_w4"),
        median(&b, "n25/engine_fused_prepare_scope_w4"),
    ) {
        println!("n25 persistent pool vs thread::scope at w4: {:.2}x", scope / pool);
        assert!(
            pool <= scope * 1.05,
            "persistent pool ({pool:.3e}s) must not be slower than the per-sweep \
             thread::scope spawn ({scope:.3e}s) at n=25, workers=4"
        );
    }
    // one OMD iteration must stay far cheaper than one SGP iteration
    // (the Fig. 9 effect at micro scale)
    let omd = median(&b, "n40/omd_full_iteration");
    let sgp = median(&b, "n40/sgp_engine_iteration");
    if let (Some(o), Some(s)) = (omd, sgp) {
        println!("n40 per-iteration speedup OMD vs SGP: {:.1}x", s / o);
        assert!(s / o > 3.0, "OMD iteration should be much cheaper than SGP");
    }
    // the session-batched SoA kernels must be at least as fast as the
    // scalar kernels on the multi-class configuration (a little slack for
    // runner noise; the expected win is well above it)
    for &n in &[25usize, 40] {
        for &workers in &[1usize, 4] {
            if let (Some(scalar), Some(batched)) = (
                median(&b, &format!("mc{n}/engine_fused_prepare_scalar_w{workers}")),
                median(&b, &format!("mc{n}/engine_fused_prepare_batched_w{workers}")),
            ) {
                println!("mc{n} batched vs scalar at w{workers}: {:.2}x", scalar / batched);
                assert!(
                    batched <= scalar * 1.05,
                    "batched prepare ({batched:.3e}s) must not be slower than the \
                     scalar prepare ({scalar:.3e}s) at mc{n}, workers={workers}"
                );
            }
            // with --features simd the explicit kernels must be at least
            // as fast as the auto-vectorized batched kernels within noise
            if let (Some(batched), Some(simd)) = (
                median(&b, &format!("mc{n}/engine_fused_prepare_batched_w{workers}")),
                median(&b, &format!("mc{n}/engine_fused_prepare_simd_w{workers}")),
            ) {
                println!("mc{n} simd vs batched at w{workers}: {:.2}x", batched / simd);
                assert!(
                    simd <= batched * 1.05,
                    "simd prepare ({simd:.3e}s) must not be slower than the \
                     batched prepare ({batched:.3e}s) at mc{n}, workers={workers}"
                );
            }
        }
    }
    // a single-block perturbation through the dirty path must beat the
    // full sweep by at least 3x on the clustered fleet (n=40)
    if let (Some(full), Some(delta)) = (
        median(&b, "clusters40/engine_prepare_full"),
        median(&b, "clusters40/engine_prepare_dirty_block"),
    ) {
        println!("clusters40 dirty single-block vs full prepare: {:.2}x", full / delta);
        assert!(
            full / delta >= 3.0,
            "prepare_dirty ({delta:.3e}s) must be ≥ 3x faster than a full \
             prepare ({full:.3e}s) on the clustered fleet"
        );
    }
    // the row-sparse probe loop must beat the dense loop by ≥ 2x on the
    // clustered fleet (the mask touches 2 of 40 sessions; converged rows
    // skip their exp-heavy multiplicative update under sparse_tol)
    if let (Some(dense), Some(sparse)) = (
        median(&b, "clusters40/omd_probe_loop_dense"),
        median(&b, "clusters40/omd_probe_loop_sparse"),
    ) {
        println!("clusters40 sparse probe loop vs dense: {:.2}x", dense / sparse);
        assert!(
            dense / sparse >= 2.0,
            "the row-sparse probe loop ({sparse:.3e}s) must be ≥ 2x faster than \
             the dense observe loop ({dense:.3e}s) on the clustered fleet"
        );
    }
    println!("hotpath OK");
}

/// 20 clusters × 2 devices (n = 40): a bidirectional pair per cluster,
/// light inter-cluster bridges in a ring, both DNN versions pinned inside
/// every cluster, and one task class sourced per cluster — the
/// sharded-fleet shape where workloads localize after convergence, so a
/// one-class perturbation is a genuinely local event (2 of 40 sessions).
fn clustered_fleet_session() -> Session {
    let mut edges = Vec::new();
    for c in 0..20usize {
        let base = c * 2;
        edges.push(EdgeSpec {
            src: base,
            dst: base + 1,
            capacity: 12.0,
            bidirectional: true,
            cost: None,
        });
        edges.push(EdgeSpec {
            src: base,
            dst: ((c + 1) % 20) * 2,
            capacity: 6.0,
            bidirectional: true,
            cost: None,
        });
    }
    let mut nodes = Vec::new();
    for c in 0..20usize {
        for (off, v) in [(0usize, 0usize), (1, 1)] {
            nodes.push(NodeSpec { id: c * 2 + off, compute_capacity: None, version: Some(v) });
        }
    }
    let mut spec = ScenarioSpec::paper_default();
    spec.name = "clustered-fleet".to_string();
    spec.topology = TopologySpec::Explicit { n_nodes: 40, edges };
    spec.n_versions = 2;
    spec.nodes = nodes;
    spec.classes = (0..20usize)
        .map(|c| ClassSpec {
            name: format!("cluster{c}"),
            utility: "log".to_string(),
            rate: RateSpec::Constant(3.0),
            sources: vec![c * 2],
        })
        .collect();
    spec.seed = 5;
    spec.build().expect("clustered fleet scenario")
}

/// Harden a routing state into its steady-state shape: lanes carrying
/// less than `tol` of their row's mass are zeroed and the row
/// renormalized. (Multiplicative OMD updates keep lanes at the 1e-12
/// interior floor forever; zeroing them makes the flow supports of the
/// clustered fleet's classes genuinely disjoint, which is what the
/// dirty-path bench exercises.)
fn sparsify_phi(net: &AugmentedNet, phi: &mut Phi, tol: f64) {
    for w in 0..net.n_sessions() {
        for row in net.csr.rows(w) {
            let lanes = &net.csr.lane_edge[row.start..row.end];
            let mut sum = 0.0;
            for &e in lanes {
                if phi.frac[w][e] < tol {
                    phi.frac[w][e] = 0.0;
                }
                sum += phi.frac[w][e];
            }
            if sum > 0.0 {
                for &e in lanes {
                    phi.frac[w][e] /= sum;
                }
            }
        }
    }
}

fn median(b: &Bencher, name: &str) -> Option<f64> {
    b.results.iter().find(|m| m.name == name).map(|m| m.median_s())
}

/// The pre-engine OMD-RT iteration, reconstructed verbatim: four separate
/// reference sweeps over freshly allocated nested state, then the eq. 22
/// row update over `session_routers`.
fn legacy_omd_iteration(problem: &Problem, lam: &[f64], phi: &mut Phi, eta: f64) -> f64 {
    let net = &problem.net;
    let t = flow::node_rates(net, phi, lam);
    let flows = flow::edge_flows(net, phi, &t);
    let cost_before = flow::total_cost(problem, &flows);
    let m = marginal::compute(problem, phi, &flows);
    let mut row = Vec::new();
    let mut delta = Vec::new();
    for w in 0..net.n_versions() {
        for &i in net.session_routers(w) {
            if t[w][i] <= 0.0 {
                continue;
            }
            let lanes = net.lanes(w, i);
            if lanes.len() < 2 {
                continue;
            }
            row.clear();
            delta.clear();
            for &e in lanes {
                row.push(phi.frac[w][e]);
                delta.push(m.delta(net, w, e));
            }
            jowr::routing::omd::OmdRouter::update_row(&mut row, &delta, eta);
            for (&e, &v) in lanes.iter().zip(&row) {
                phi.frac[w][e] = v;
            }
        }
    }
    cost_before
}
