//! Bench: hot-path microbenchmarks + native-vs-XLA ablation.
//!
//! Covers the per-iteration cost breakdown of OMD-RT (flow propagation,
//! marginal sweep, mirror update) on paper-sized instances, and compares
//! the native rust mirror/routing step against the AOT-compiled XLA
//! artifacts when `artifacts/` is present. Feeds EXPERIMENTS.md §Perf.

use jowr::model::flow::{self, Phi};
use jowr::prelude::*;
use jowr::routing::marginal;
use jowr::util::bench::Bencher;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut b = if quick { Bencher::quick() } else { Bencher::default() };

    for &n in &[25usize, 40] {
        let session = Scenario::paper_default().nodes(n).build().expect("scenario");
        let problem = &session.problem;
        let lam = session.uniform_allocation();
        let phi = Phi::uniform(&problem.net);
        let t = flow::node_rates(&problem.net, &phi, &lam);
        let flows = flow::edge_flows(&problem.net, &phi, &t);

        println!("--- ER({n}) hot path ---");
        b.bench(&format!("n{n}/flow_propagation"), || {
            flow::node_rates(&problem.net, &phi, &lam)
        });
        b.bench(&format!("n{n}/edge_flows"), || {
            flow::edge_flows(&problem.net, &phi, &t)
        });
        b.bench(&format!("n{n}/marginal_broadcast"), || {
            marginal::compute(&problem.net, problem.cost, &phi, &flows)
        });
        b.bench(&format!("n{n}/omd_full_iteration"), || {
            // registry-built router, one streaming iteration
            let mut r = session.router("omd").expect("registry omd");
            let mut p = phi.clone();
            r.step(problem, &lam, &mut p);
            p
        });
        b.bench(&format!("n{n}/sgp_full_iteration"), || {
            let mut r = session.router("sgp").expect("registry sgp");
            let mut p = phi.clone();
            r.step(problem, &lam, &mut p);
            p
        });

        // native vs XLA ablation (skipped gracefully without artifacts)
        #[cfg(feature = "xla")]
        match jowr::runtime::XlaRuntime::try_default() {
            Some(mut rt) => {
                match jowr::runtime::routing_step::DenseNet::build(&rt, problem) {
                    Ok(dense) => {
                        // warm compile
                        let mut p = phi.clone();
                        let _ = jowr::runtime::routing_step::routing_step_xla(
                            &mut rt, &dense, problem, &mut p, &lam, 0.5,
                        );
                        b.bench(&format!("n{n}/xla_routing_step"), || {
                            let mut p = phi.clone();
                            jowr::runtime::routing_step::routing_step_xla(
                                &mut rt, &dense, problem, &mut p, &lam, 0.5,
                            )
                            .expect("xla routing step")
                        });
                    }
                    Err(e) => println!("(xla routing_step unavailable: {e})"),
                }
            }
            None => println!("(artifacts/ not built — skipping XLA ablation)"),
        }
        #[cfg(not(feature = "xla"))]
        println!("(built without the xla feature — skipping XLA ablation)");
    }

    // summary table
    println!("\n=== hotpath summary ===");
    for m in &b.results {
        println!("{}", m.report());
    }
    // shape assertion: one OMD iteration must be far cheaper than one SGP
    // iteration (the Fig. 9 effect at micro scale)
    let omd = b
        .results
        .iter()
        .find(|m| m.name == "n40/omd_full_iteration")
        .map(|m| m.median_s());
    let sgp = b
        .results
        .iter()
        .find(|m| m.name == "n40/sgp_full_iteration")
        .map(|m| m.median_s());
    if let (Some(o), Some(s)) = (omd, sgp) {
        println!("n40 per-iteration speedup OMD vs SGP: {:.1}x", s / o);
        assert!(s / o > 3.0, "OMD iteration should be much cheaper than SGP");
    }
    println!("hotpath OK");
}
