//! Bench: ablations over the design choices DESIGN.md calls out.
//!
//! 1. **Step-size policy** — fixed η vs the backtracking-adaptive η the
//!    repo ships (the practical instantiation of the paper's η ≤ c/L_D).
//! 2. **Decision-space geometry** — OMD (entropic mirror) vs Euclidean GP
//!    at comparable per-iteration budgets (the paper's Remark 2).
//! 3. **Cost family** — convergence across exp / M/M/1 / linear / cubic
//!    link costs (the model's generality claim, §II-D).

use jowr::config::ExperimentConfig;
use jowr::prelude::*;
use jowr::routing::Router;
use jowr::util::rng::Rng;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let iters = if quick { 50 } else { 200 };
    let cfg = ExperimentConfig::paper_default();
    let mut rng = Rng::seed_from(cfg.seed);
    let problem = cfg.build_problem(&mut rng);
    let lam = problem.uniform_allocation();
    let opt = OptRouter::new().solve(&problem, &lam);
    println!("OPT reference cost: {:.4}\n", opt.cost);

    println!("--- ablation 1: step-size policy (final cost after {iters} iters) ---");
    let adaptive = OmdRouter::new(0.5).solve(&problem, &lam, iters);
    println!("{:<28} {:>12.4}  (gap {:.2e})", "adaptive eta=0.5 (ships)", adaptive.cost,
             rel(adaptive.cost, opt.cost));
    for eta in [0.5, 0.1, 0.02] {
        let fixed = OmdRouter::fixed(eta).solve(&problem, &lam, iters);
        println!("{:<28} {:>12.4}  (gap {:.2e})", format!("fixed eta={eta}"), fixed.cost,
                 rel(fixed.cost, opt.cost));
    }
    assert!(
        rel(adaptive.cost, opt.cost) < 0.02,
        "adaptive policy must stay near OPT"
    );

    println!("\n--- ablation 2: geometry (cost after 10 iterations) ---");
    let omd10 = OmdRouter::new(0.5).solve(&problem, &lam, 10);
    println!("{:<28} {:>12.4}", "OMD (entropic mirror)", omd10.cost);
    for eta in [0.01, 0.002, 0.0005] {
        let gp10 = GpRouter::new(eta).solve(&problem, &lam, 10);
        println!("{:<28} {:>12.4}", format!("GP (euclidean, eta={eta})"), gp10.cost);
    }
    // robustness claim: a *single untuned* OMD beats most GP step choices;
    // only a per-instance-tuned GP can be competitive early
    let beaten = [0.01, 0.002, 0.0005]
        .iter()
        .filter(|&&e| GpRouter::new(e).solve(&problem, &lam, 10).cost >= omd10.cost - 1e-9)
        .count();
    assert!(
        beaten >= 2,
        "OMD (untuned) should beat most GP step-size choices early (beat {beaten}/3)"
    );

    println!("\n--- ablation 3: cost families (OMD convergence) ---");
    for kind in [CostKind::Exp, CostKind::Queue, CostKind::Linear, CostKind::Cubic] {
        let mut rng = Rng::seed_from(cfg.seed);
        let mut c2 = cfg.clone();
        c2.cost = kind;
        let p = c2.build_problem(&mut rng);
        let lam = p.uniform_allocation();
        let sol = OmdRouter::new(0.3).solve(&p, &lam, iters);
        println!(
            "{:<28} {:>12.4} -> {:>12.4}  ({} iters)",
            format!("{kind:?}"),
            sol.trajectory[0],
            sol.cost,
            sol.iterations
        );
        assert!(sol.cost <= sol.trajectory[0] + 1e-9, "{kind:?} did not improve");
    }
    println!("\nablation OK");
}

fn rel(a: f64, b: f64) -> f64 {
    (a - b).abs() / b.abs().max(1e-12)
}
