//! Bench: ablations over the design choices DESIGN.md calls out.
//!
//! 1. **Step-size policy** — fixed η vs the backtracking-adaptive η the
//!    repo ships (the practical instantiation of the paper's η ≤ c/L_D).
//! 2. **Decision-space geometry** — OMD (entropic mirror) vs Euclidean GP
//!    at comparable per-iteration budgets (the paper's Remark 2).
//! 3. **Cost family** — convergence across exp / M/M/1 / linear / cubic
//!    link costs (the model's generality claim, §II-D).
//!
//! All solver variants come from the registry (`omd`, `omd-fixed`, `gp`)
//! with per-ablation hyper-parameter overrides.

use jowr::prelude::*;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let iters = if quick { 50 } else { 200 };
    let session = Scenario::paper_default().build().expect("scenario");
    let lam = session.uniform_allocation();
    let opt = OptRouter::new().solve(&session.problem, &lam);
    println!("OPT reference cost: {:.4}\n", opt.cost);

    println!("--- ablation 1: step-size policy (final cost after {iters} iters) ---");
    let adaptive = session.routing_run("omd", iters).unwrap().finish();
    println!(
        "{:<28} {:>12.4}  (gap {:.2e})",
        "adaptive eta=0.5 (ships)",
        adaptive.objective,
        rel(adaptive.objective, opt.cost)
    );
    for eta in [0.5, 0.1, 0.02] {
        let h = Hyper { eta_routing: eta, ..session.hyper() };
        let router = registry::router_with("omd-fixed", &h).expect("registry omd-fixed");
        let fixed = RoutingRun::new(&session.problem, router, lam.clone(), iters).finish();
        println!(
            "{:<28} {:>12.4}  (gap {:.2e})",
            format!("fixed eta={eta}"),
            fixed.objective,
            rel(fixed.objective, opt.cost)
        );
    }
    assert!(
        rel(adaptive.objective, opt.cost) < 0.02,
        "adaptive policy must stay near OPT"
    );

    println!("\n--- ablation 2: geometry (cost after 10 iterations) ---");
    let omd10 = session.routing_run("omd", 10).unwrap().finish();
    println!("{:<28} {:>12.4}", "OMD (entropic mirror)", omd10.objective);
    let gp_cost = |eta: f64| -> f64 {
        let h = Hyper { eta_gp: eta, ..session.hyper() };
        let router = registry::router_with("gp", &h).expect("registry gp");
        RoutingRun::new(&session.problem, router, lam.clone(), 10).finish().objective
    };
    for eta in [0.01, 0.002, 0.0005] {
        println!("{:<28} {:>12.4}", format!("GP (euclidean, eta={eta})"), gp_cost(eta));
    }
    // robustness claim: a *single untuned* OMD beats most GP step choices;
    // only a per-instance-tuned GP can be competitive early
    let beaten = [0.01, 0.002, 0.0005]
        .iter()
        .filter(|&&e| gp_cost(e) >= omd10.objective - 1e-9)
        .count();
    assert!(
        beaten >= 2,
        "OMD (untuned) should beat most GP step-size choices early (beat {beaten}/3)"
    );

    println!("\n--- ablation 3: cost families (OMD convergence) ---");
    for kind in [CostKind::Exp, CostKind::Queue, CostKind::Linear, CostKind::Cubic] {
        let s = Scenario::paper_default().cost(kind).eta_routing(0.3).build().expect("scenario");
        let mut traj = Trajectory::default();
        let sol = s.routing_run("omd", iters).unwrap().observe(&mut traj).finish();
        println!(
            "{:<28} {:>12.4} -> {:>12.4}  ({} iters)",
            format!("{kind:?}"),
            traj.values[0],
            sol.objective,
            sol.iterations
        );
        assert!(sol.objective <= traj.values[0] + 1e-9, "{kind:?} did not improve");
    }
    println!("\nablation OK");
}

fn rel(a: f64, b: f64) -> f64 {
    (a - b).abs() / b.abs().max(1e-12)
}
