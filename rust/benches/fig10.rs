//! Bench: regenerate **Fig. 10** — GS-OMA total network utility under four
//! unknown utility families (linear / sqrt / quadratic / log).
//!
//! Expected shape (paper): every family converges; the log family converges
//! in tens of iterations while linear takes the longest.

use jowr::config::ExperimentConfig;
use jowr::experiments;
use jowr::model::utility::FAMILIES;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut cfg = ExperimentConfig::paper_default();
    if quick {
        cfg.n_nodes = 12;
    }
    let iters = if quick { 15 } else { 60 };
    println!("=== fig10: GS-OMA under 4 unknown utility families ({iters} outer iters) ===");
    let s = experiments::fig10(&cfg, iters).expect("fig10 scenario");
    for fam in FAMILIES {
        let tr = s.get(fam).unwrap();
        let (first, last) = (tr[0], *tr.last().unwrap());
        assert!(
            last >= first - 1e-6,
            "{fam}: utility did not improve ({first} -> {last})"
        );
    }
    println!("fig10 OK");
}
