//! Bench: regenerate **Fig. 11** — nested-loop (GS-OMA) vs single-loop
//! (OMAD) total network utility, with a topology change at outer
//! iteration 50.
//!
//! Expected shape (paper): both converge to the same optimum; the single
//! loop consumes a small fraction of the nested loop's routing iterations;
//! after the topology change both re-adapt, the single loop from a worse
//! transient.

use jowr::config::ExperimentConfig;
use jowr::experiments;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut cfg = ExperimentConfig::paper_default();
    if quick {
        cfg.n_nodes = 12;
    }
    let iters = if quick { 30 } else { 100 };
    let change_at = iters / 2;
    println!("=== fig11: nested vs single loop (topology change at {change_at}) ===");
    let (s, nested_routing, single_routing) =
        experiments::fig11(&cfg, iters, change_at).expect("fig11 scenario");
    let nested = s.get("nested_loop").unwrap();
    let single = s.get("single_loop").unwrap();
    // both settle to comparable utility before the change
    let pre = change_at - 1;
    let rel = (nested[pre] - single[pre]).abs() / nested[pre].abs().max(1.0);
    println!("pre-change utilities: nested {:.4} single {:.4} (rel {rel:.3})", nested[pre], single[pre]);
    assert!(rel < 0.1, "loops should agree before the change");
    assert!(
        single_routing * 5 <= nested_routing,
        "single loop must use far fewer routing iterations ({single_routing} vs {nested_routing})"
    );
    println!("fig11 OK");
}
