//! Bench: regenerate **Figs. 12–15** — OMD-RT vs SGP convergence on the
//! four named topologies (Abilene / Balanced-tree / Fog / GEANT) with
//! Table II parameters.
//!
//! Expected shape (paper): OMD-RT approaches OPT within ~50 iterations on
//! every topology; SGP converges more slowly.

use jowr::config::ExperimentConfig;
use jowr::experiments;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let cfg = ExperimentConfig::paper_default();
    let iters = if quick { 30 } else { 100 };
    println!("=== fig12-15: named topologies ({iters} iters) ===");
    experiments::table2();
    let results = experiments::fig12_15(&cfg, iters).expect("fig12_15 scenario");
    assert_eq!(results.len(), 4);
    for (name, s, opt_cost) in &results {
        let omd = s.get("omd_rt").unwrap();
        let last = *omd.last().unwrap();
        let gap = (last - opt_cost) / opt_cost;
        println!("{name}: OMD final gap to OPT = {gap:.2e}");
        assert!(gap < 0.02, "{name}: OMD should approach OPT (gap {gap})");
    }
    println!("fig12_15 OK");
}
