//! Bench: regenerate **Figs. 8 + 9** — final cost and wall-clock running
//! time vs network size (n ∈ {20,25,30,35,40}, 50 routing iterations).
//!
//! Expected shape (paper): OMD-RT reaches (near-)OPT cost at every size
//! while SGP may lag; OMD-RT's running time is orders of magnitude below
//! SGP's and below OPT's.

use jowr::config::ExperimentConfig;
use jowr::experiments;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let cfg = ExperimentConfig::paper_default();
    let sizes: &[usize] = if quick { &[15, 20] } else { &[20, 25, 30, 35, 40] };
    println!("=== fig8/9: cost + running time vs network size ===");
    let rows = experiments::fig8_9(&cfg, sizes, 50).expect("fig8_9 scenario");
    for r in &rows {
        assert!(r.cost_opt <= r.cost_omd + 1e-6, "OPT must lower-bound OMD at n={}", r.n);
        let gap = (r.cost_omd - r.cost_opt) / r.cost_opt;
        assert!(gap < 0.02, "OMD within 2% of OPT at n={} (gap {gap})", r.n);
        let speedup = r.time_sgp_s / r.time_omd_s;
        println!("n={}: OMD vs SGP wall-clock speedup = {:.1}x", r.n, speedup);
        // shape check: OMD is always cheaper; the magnitude grows with n
        // (the paper's ~3-orders gap is vs a generic-QP SGP implementation;
        // our reimplemented SGP is itself optimized — see DESIGN.md §3)
        assert!(speedup > 1.2, "OMD must be cheaper than SGP at n={}", r.n);
        assert!(
            r.time_omd_s < r.time_opt_s,
            "OMD (distributed) must beat centralized OPT wall-clock at n={}",
            r.n
        );
    }
    println!("fig8_9 OK");
}
