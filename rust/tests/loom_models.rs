//! Loom model checks for the hand-rolled concurrency plane.
//!
//! Compiled and run only under the model checker:
//!
//! ```text
//! cargo add loom@0.7 --dev        # not vendored — offline registry
//! RUSTFLAGS="--cfg loom" cargo test --release --test loom_models
//! ```
//!
//! ## What is modeled (honest scope)
//!
//! Loom explores thread interleavings of *loom* primitives; it cannot
//! instrument `std::sync::mpsc`, which is what `engine::pool::WorkerPool`
//! and `coordinator::transport::Loopback` are built on. These tests
//! therefore model-check the **protocols** — re-expressed 1:1 over a
//! loom-backed bounded mailbox (`Mutex<VecDeque> + Condvar`, the textbook
//! semantics of a bounded channel) — not the std channel internals:
//!
//! * `WorkerPool::run_scoped`: pinned dispatch → caller chunk → completion
//!   barrier → outcome propagation. Checked: the barrier never returns
//!   before every dispatched task ran (task effects are visible after it),
//!   no interleaving deadlocks, and a task failure is *observed after* the
//!   barrier instead of being lost (panic-forwarding, modeled as an `Err`
//!   completion exactly like `pool.rs` forwards payloads).
//! * `Loopback` round protocol at S=0: each shard sends its `FlowDelta`
//!   then blocks on its own mailbox until the peer's round arrived.
//!   Checked: no deadlock even at mailbox capacity 1 (stricter than the
//!   real `shards*4+16` capacity), no lost delta, absolute-value
//!   reconstruction is exact, and per-sender FIFO keeps round numbers in
//!   order across two consecutive rounds.
//!
//! The *real* `WorkerPool`/`Loopback` code paths are exercised under Miri
//! and ThreadSanitizer by the `miri`/`tsan` CI jobs (see
//! `.github/workflows/ci.yml`), and bit-identity across worker counts is
//! pinned by the equivalence suites. State spaces are kept tiny (≤ 3
//! threads, ≤ 2 rounds) so the exhaustive exploration finishes in seconds.

#![cfg(loom)]

use std::collections::VecDeque;

use loom::sync::{Arc, Condvar, Mutex};
use loom::thread;

/// A bounded FIFO mailbox with blocking send (when full) and blocking
/// receive (when empty) — the protocol-level semantics of both the pool's
/// per-thread job channels and the Loopback shard mailboxes.
struct Mailbox<T> {
    q: Mutex<VecDeque<T>>,
    cv: Condvar,
    cap: usize,
}

impl<T> Mailbox<T> {
    fn new(cap: usize) -> Self {
        assert!(cap > 0);
        Mailbox { q: Mutex::new(VecDeque::new()), cv: Condvar::new(), cap }
    }

    fn send(&self, v: T) {
        let mut q = self.q.lock().unwrap();
        while q.len() >= self.cap {
            q = self.cv.wait(q).unwrap();
        }
        q.push_back(v);
        self.cv.notify_all();
    }

    fn recv(&self) -> T {
        let mut q = self.q.lock().unwrap();
        loop {
            if let Some(v) = q.pop_front() {
                self.cv.notify_all();
                return v;
            }
            q = self.cv.wait(q).unwrap();
        }
    }
}

/// Completion outcome, as forwarded by `WorkerPool` (`Err` = caught panic
/// payload).
type Done = Result<(), &'static str>;

/// Two pinned workers + the caller chunk: the barrier must not return
/// until both tasks ran, and their effects must be visible afterwards.
#[test]
fn worker_pool_barrier_sees_every_task_effect() {
    loom::model(|| {
        let done = Arc::new(Mailbox::<Done>::new(2));
        let cells = Arc::new([Mutex::new(0usize), Mutex::new(0usize)]);
        let mut handles = Vec::new();
        for (i, jobs) in [Mailbox::<usize>::new(1), Mailbox::<usize>::new(1)]
            .map(Arc::new)
            .into_iter()
            .enumerate()
        {
            // pinned dispatch: task i goes to worker i's own channel
            let (d, c, j) = (Arc::clone(&done), Arc::clone(&cells), Arc::clone(&jobs));
            handles.push(thread::spawn(move || {
                let task = j.recv();
                *c[task].lock().unwrap() = task + 1; // "run the closure"
                d.send(Ok(()));
            }));
            jobs.send(i);
        }
        // caller chunk runs concurrently, then the completion barrier
        let mut caller_chunk = 41;
        caller_chunk += 1;
        for _ in 0..2 {
            done.recv().unwrap();
        }
        // after the barrier every task effect is visible (this is the
        // property that makes the lifetime erasure in pool.rs sound)
        assert_eq!(*cells[0].lock().unwrap(), 1);
        assert_eq!(*cells[1].lock().unwrap(), 2);
        assert_eq!(caller_chunk, 42);
        for h in handles {
            h.join().unwrap();
        }
    });
}

/// A failing task must be *observed after* the barrier (forwarded, never
/// lost, never unwinding past state that other tasks still borrow).
#[test]
fn worker_pool_failure_is_forwarded_after_the_barrier() {
    loom::model(|| {
        let jobs = Arc::new(Mailbox::<bool>::new(1));
        let done = Arc::new(Mailbox::<Done>::new(1));
        let (j, d) = (Arc::clone(&jobs), Arc::clone(&done));
        let h = thread::spawn(move || {
            let fail = j.recv();
            // pool.rs: catch_unwind(job) → forward the payload as Err
            d.send(if fail { Err("worker boom") } else { Ok(()) });
        });
        jobs.send(true);
        // the barrier drains exactly n completions, then propagates
        let outcome = done.recv();
        assert_eq!(outcome, Err("worker boom"));
        h.join().unwrap();
    });
}

/// One `FlowDelta` of the sharded round protocol.
#[derive(Clone, Copy, Debug, PartialEq)]
struct Delta {
    shard: usize,
    round: u32,
    flow: f64,
}

/// S=0 round: both shards gossip their delta and then block until the
/// peer's delta for the same round arrived. Capacity 1 (tighter than the
/// real plane) must still never deadlock, and no delta may be lost.
#[test]
fn loopback_round_protocol_no_deadlock_no_lost_delta() {
    loom::model(|| {
        let boxes = Arc::new([Mailbox::<Delta>::new(1), Mailbox::<Delta>::new(1)]);
        let mut handles = Vec::new();
        for shard in 0..2usize {
            let b = Arc::clone(&boxes);
            handles.push(thread::spawn(move || {
                let peer = 1 - shard;
                // shard.rs: send own delta, then wait for peer round ≥ r − S
                b[peer].send(Delta { shard, round: 0, flow: (shard + 1) as f64 });
                let got = b[shard].recv();
                assert_eq!(got.shard, peer, "delta from the peer");
                assert_eq!(got.round, 0, "S=0: same-round aggregate");
                // absolute values → exact reconstruction of the peer flow
                assert_eq!(got.flow, (peer + 1) as f64);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    });
}

/// Two consecutive rounds: per-sender FIFO (the property the Loopback
/// channel provides) keeps the peer's rounds in order, so a round-r price
/// never reads a round-(r+1) aggregate at S=0.
#[test]
fn loopback_rounds_stay_ordered_per_sender() {
    loom::model(|| {
        let boxes = Arc::new([Mailbox::<Delta>::new(2), Mailbox::<Delta>::new(2)]);
        let mut handles = Vec::new();
        for shard in 0..2usize {
            let b = Arc::clone(&boxes);
            handles.push(thread::spawn(move || {
                let peer = 1 - shard;
                for round in 0..2u32 {
                    b[peer].send(Delta { shard, round, flow: round as f64 });
                    let got = b[shard].recv();
                    assert_eq!(got.round, round, "FIFO: rounds arrive in order");
                    assert_eq!(got.flow, round as f64);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    });
}
