//! Property-based tests over randomized instances (testkit harness):
//! flow conservation, simplex invariants, monotone descent, convexity,
//! DAG acyclicity — the paper's structural assumptions, fuzzed.

use jowr::graph::augmented::{AugmentedNet, Placement};
use jowr::graph::topologies;
use jowr::model::flow::{self, Phi};
use jowr::model::Problem;
use jowr::prelude::*;
use jowr::routing::omd::OmdRouter;
use jowr::routing::Router;
use jowr::testkit::{forall, Gen};
use jowr::util::rng::Rng;
use jowr::{prop_assert, prop_assert_close};

fn random_problem(g: &mut Gen) -> Problem {
    let n = g.usize_in(5, 14);
    let p = g.f64_in(0.25, 0.6);
    let w = g.usize_in(2, 4);
    let seed = g.rng.next_u64();
    let mut rng = Rng::seed_from(seed);
    let net = topologies::connected_er(n, p, w, &mut rng);
    Problem::new(net, g.f64_in(10.0, 80.0), CostKind::Exp)
}

/// A random feasible φ (not just the uniform initializer).
fn random_phi(g: &mut Gen, net: &AugmentedNet) -> Phi {
    let mut phi = Phi::uniform(net);
    for w in 0..net.n_versions() {
        for i in 0..net.n_nodes() {
            let lanes: Vec<usize> = net.session_out(w, i).collect();
            if lanes.len() < 2 {
                continue;
            }
            let weights = g.simplex(lanes.len());
            for (e, x) in lanes.iter().zip(weights) {
                phi.frac[w][*e] = x;
            }
        }
    }
    phi
}

#[test]
fn prop_flow_conservation_under_random_phi() {
    forall(101, 40, 8, |g| {
        let p = random_problem(g);
        let phi = random_phi(g, &p.net);
        phi.is_feasible(&p.net, 1e-9).map_err(|e| e.to_string())?;
        let lam = p.uniform_allocation();
        let ev = flow::evaluate(&p, &phi, &lam);
        for w in 0..p.n_versions() {
            prop_assert_close!(ev.t[w][p.net.dnode(w)], lam[w], 1e-8);
        }
        // non-negative flows bounded by admitted traffic on real links
        for &f in &ev.flows {
            prop_assert!(f >= -1e-12, "negative flow {f}");
            prop_assert!(f <= p.total_rate + 1e-6, "flow {f} exceeds λ");
        }
        Ok(())
    });
}

#[test]
fn prop_mirror_update_preserves_simplex() {
    forall(202, 60, 10, |g| {
        let d = g.usize_in(2, 10);
        let mut row = g.simplex(d);
        let delta: Vec<f64> = (0..d).map(|_| g.f64_in(-100.0, 1e6)).collect();
        let eta = g.f64_in(0.0, 10.0);
        OmdRouter::update_row(&mut row, &delta, eta);
        let sum: f64 = row.iter().sum();
        prop_assert_close!(sum, 1.0, 1e-9);
        for &x in &row {
            prop_assert!(x >= 0.0, "negative fraction {x}");
            prop_assert!(x.is_finite(), "non-finite fraction");
        }
        Ok(())
    });
}

#[test]
fn prop_session_dags_acyclic_and_reachable() {
    forall(303, 40, 8, |g| {
        let n = g.usize_in(4, 16);
        let pr = g.f64_in(0.2, 0.7);
        let w = g.usize_in(2, 4);
        let seed = g.rng.next_u64();
        let mut rng = Rng::seed_from(seed);
        let graph = topologies::connected_er_graph(n, pr, 10.0, &mut rng);
        let placement = Placement::random(n, w, &mut rng);
        let net = AugmentedNet::build(&graph, &placement, 10.0, &mut rng);
        net.validate().map_err(|e| e)?;
        for sess in 0..w {
            prop_assert!(
                net.graph.topo_order(&net.session_edges[sess]).is_some(),
                "session {sess} DAG has a cycle"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_omd_descends_with_small_step() {
    forall(404, 12, 6, |g| {
        let p = random_problem(g);
        let lam = p.uniform_allocation();
        let mut router = OmdRouter::fixed(0.02);
        let mut phi = Phi::uniform(&p.net);
        let mut prev = f64::INFINITY;
        for _ in 0..15 {
            let cost = router.step(&p, &lam, &mut phi);
            prop_assert!(cost <= prev + 1e-9, "cost increased {prev} -> {cost}");
            prev = cost;
        }
        Ok(())
    });
}

#[test]
fn prop_cost_convex_along_phi_segments() {
    // D(Λ, φ) is convex in φ (Theorem 3): check midpoint convexity along
    // random feasible segments
    forall(505, 25, 8, |g| {
        let p = random_problem(g);
        let lam = p.uniform_allocation();
        let a = random_phi(g, &p.net);
        let b = random_phi(g, &p.net);
        let mut mid = a.clone();
        for w in 0..p.n_versions() {
            for e in 0..p.net.graph.n_edges() {
                mid.frac[w][e] = 0.5 * (a.frac[w][e] + b.frac[w][e]);
            }
        }
        let ca = flow::evaluate(&p, &a, &lam).cost;
        let cb = flow::evaluate(&p, &b, &lam).cost;
        let cm = flow::evaluate(&p, &mid, &lam).cost;
        prop_assert!(
            cm <= 0.5 * (ca + cb) + 1e-6 * (ca + cb),
            "convexity violated: D(mid)={cm} > ({ca}+{cb})/2"
        );
        Ok(())
    });
}

#[test]
fn prop_allocation_perturbation_feasible() {
    forall(606, 60, 8, |g| {
        let w = g.usize_in(2, 6);
        let total = g.f64_in(10.0, 100.0);
        let lam = {
            let s = g.simplex(w);
            s.into_iter().map(|x| x * total).collect::<Vec<f64>>()
        };
        let delta = g.f64_in(0.01, total / w as f64 / 2.0);
        for idx in 0..w {
            for sign in [1.0, -1.0] {
                let v = jowr::allocation::gsoma::perturb(&lam, idx, sign * delta, total);
                let sum: f64 = v.iter().sum();
                prop_assert_close!(sum, total, 1e-7);
                for &x in &v {
                    prop_assert!(x >= -1e-12, "negative allocation {x}");
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_utility_families_satisfy_assumptions() {
    // Assumptions 1-3 on every family instance over random λ ranges
    forall(707, 40, 8, |g| {
        let total = g.f64_in(10.0, 120.0);
        let w = g.usize_in(2, 5);
        for fam in jowr::model::utility::FAMILIES {
            let us = jowr::model::utility::family(fam, w, total).unwrap();
            for u in &us {
                prop_assert!(u.is_valid_on(total), "{fam} invalid on [0,{total}]");
                // monotone + concave via random triples
                let x1 = g.f64_in(0.0, total / 2.0);
                let x2 = x1 + g.f64_in(0.01, total / 2.0);
                prop_assert!(
                    u.value(x2) >= u.value(x1) - 1e-9,
                    "{fam} not increasing on [{x1},{x2}]"
                );
                let mid = u.value(0.5 * (x1 + x2));
                prop_assert!(
                    mid >= 0.5 * (u.value(x1) + u.value(x2)) - 1e-9,
                    "{fam} not concave"
                );
            }
        }
        Ok(())
    });
}
