//! Validation of the request-level discrete-event core (`jowr::sim`):
//! closed-form M/M/1 and M/M/c checks, determinism across worker counts,
//! trace-driven arrivals, and the streaming `SimRun` integration.

use jowr::prelude::*;
use jowr::sim;

/// A minimal scenario whose simulated system is an exact M/M/1 queue: two
/// devices, one version, all traffic admitted at device 0, and φ pinned so
/// every request goes straight onto device 0's computation link (service
/// rate `mu`). The admission link is zero-delay, so end-to-end latency is
/// exactly the station's sojourn time.
fn mm1_session(rate: f64, mu: f64) -> Session {
    let mut spec = ScenarioSpec::paper_default();
    spec.name = "mm1".into();
    spec.topology = TopologySpec::Explicit {
        n_nodes: 2,
        edges: vec![EdgeSpec {
            src: 0,
            dst: 1,
            capacity: 1000.0,
            bidirectional: true,
            cost: None,
        }],
    };
    spec.n_versions = 1;
    spec.classes = vec![ClassSpec {
        name: "mm1".into(),
        utility: "log".into(),
        rate: RateSpec::Constant(rate),
        sources: vec![0],
    }];
    spec.nodes = vec![
        NodeSpec { id: 0, compute_capacity: Some(mu), version: Some(0) },
        NodeSpec { id: 1, compute_capacity: Some(mu), version: Some(0) },
    ];
    spec.build().unwrap()
}

/// φ sending every request at device 0 straight to its computation link.
fn mm1_phi(session: &Session) -> jowr::model::flow::Phi {
    let net = &session.problem.net;
    let mut phi = jowr::model::flow::Phi::uniform(net);
    let dev0 = 1; // augmented id of device 0
    let comp = net
        .graph
        .find_edge(dev0, net.n_real + 1)
        .expect("device 0 computation link");
    for e in 0..net.graph.n_edges() {
        phi.frac[0][e] = 0.0;
    }
    phi.frac[0][comp] = 1.0;
    // admission: S -> device 0 only
    let admit = net.graph.find_edge(0, dev0).expect("admission link");
    phi.frac[0][admit] = 1.0;
    phi
}

/// Erlang-C probability of waiting for an M/M/c queue with offered load
/// `a = λ/μ_server`.
fn erlang_c(c: usize, a: f64) -> f64 {
    let mut sum = 0.0;
    let mut term = 1.0; // a^k / k!
    for k in 0..c {
        if k > 0 {
            term *= a / k as f64;
        }
        sum += term;
    }
    let pc = term * a / c as f64; // a^c / c!
    let rho = a / c as f64;
    let tail = pc / (1.0 - rho);
    tail / (sum + tail)
}

#[test]
fn mm1_matches_closed_form() {
    let (rate, mu) = (30.0, 40.0);
    let session = mm1_session(rate, mu);
    let spec = SimSpec { horizon_s: 4000.0, warmup_s: 100.0, ..SimSpec::default() };
    let report = sim::simulate_requests(
        &session.problem,
        &mm1_phi(&session),
        &[rate],
        vec![ArrivalTrace::constant(rate)],
        spec,
        7,
    );
    assert_eq!(report.dropped, 0);
    assert_eq!(report.in_flight, 0);
    // sojourn time W = 1/(μ−λ), queueing delay Wq = ρ/(μ−λ)
    let w = 1.0 / (mu - rate);
    let wq = (rate / mu) / (mu - rate);
    assert!(
        (report.mean_latency_s - w).abs() < 0.05 * w,
        "mean sojourn {} vs analytic {w}",
        report.mean_latency_s
    );
    let node = &report.nodes[0];
    assert!(
        (node.mean_wait_s - wq).abs() < 0.08 * wq,
        "mean wait {} vs analytic {wq}",
        node.mean_wait_s
    );
    let rho = rate / mu;
    assert!(
        (node.utilization - rho).abs() < 0.05 * rho,
        "utilization {} vs analytic {rho}",
        node.utilization
    );
    // Lq = λ·Wq (Little's law on the waiting line)
    let lq = rate * wq;
    assert!(
        (node.mean_queue_depth - lq).abs() < 0.10 * lq,
        "queue depth {} vs analytic {lq}",
        node.mean_queue_depth
    );
    // M/M/1 sojourn is exponential: p50 = W·ln 2, p99 = W·ln 100
    let p50 = w * 2.0f64.ln();
    assert!(
        (report.p50_latency_s - p50).abs() < 0.08 * p50,
        "p50 {} vs analytic {p50}",
        report.p50_latency_s
    );
}

#[test]
fn mmc_matches_erlang_c() {
    let (rate, mu_total, servers) = (30.0, 40.0, 3usize);
    let session = mm1_session(rate, mu_total);
    let spec = SimSpec {
        horizon_s: 4000.0,
        warmup_s: 100.0,
        servers_per_node: servers,
        ..SimSpec::default()
    };
    let report = sim::simulate_requests(
        &session.problem,
        &mm1_phi(&session),
        &[rate],
        vec![ArrivalTrace::constant(rate)],
        spec,
        11,
    );
    let mu_server = mu_total / servers as f64;
    let a = rate / mu_server;
    let wq = erlang_c(servers, a) / (servers as f64 * mu_server - rate);
    let w = wq + 1.0 / mu_server;
    assert!(
        (report.mean_latency_s - w).abs() < 0.08 * w,
        "M/M/{servers} sojourn {} vs Erlang-C {w}",
        report.mean_latency_s
    );
    let node = &report.nodes[0];
    assert!(
        (node.mean_wait_s - wq).abs() < 0.12 * wq,
        "M/M/{servers} wait {} vs Erlang-C {wq}",
        node.mean_wait_s
    );
    assert!(
        (node.utilization - rate / mu_total).abs() < 0.05 * (rate / mu_total),
        "utilization {}",
        node.utilization
    );
}

#[test]
fn same_seed_same_report_at_any_worker_count() {
    // the full pipeline — OMD optimization at k workers, then replay —
    // must produce bit-identical SimReports for every k: the worker knob
    // only parallelizes the fused sweeps, and the sim itself is
    // single-threaded by construction
    let spec = ScenarioSpec::from_file(std::path::Path::new(
        "../examples/scenarios/two_class_er.json",
    ))
    .unwrap();
    let run = |workers: usize| {
        let mut spec = spec.clone();
        spec.workers = workers;
        spec.sim = Some(SimSpec { horizon_s: 20.0, ..SimSpec::default() });
        let session = spec.build().unwrap();
        let optimized = session.routing_run("omd", 15).unwrap().finish();
        let (_, sim) =
            session.sim_run(4).unwrap().warm_start_from(&optimized).finish();
        sim
    };
    let base = run(1);
    assert!(base.arrivals > 0);
    for workers in [2usize, 4] {
        let other = run(workers);
        assert_eq!(base, other, "SimReport diverged at {workers} workers");
        assert_eq!(
            base.to_json().to_string(),
            other.to_json().to_string(),
            "JSON dump diverged at {workers} workers"
        );
    }
}

#[test]
fn trace_arrivals_track_the_breakpoints() {
    let mut spec = ScenarioSpec::paper_default();
    let TopologySpec::Er { n_nodes, .. } = &mut spec.topology else { unreachable!() };
    *n_nodes = 10;
    spec.horizon = Some(10);
    spec.classes = vec![ClassSpec {
        name: "surge".into(),
        utility: "log".into(),
        rate: RateSpec::Trace(vec![(0, 10.0), (5, 50.0)]),
        sources: vec![],
    }];
    spec.sim = Some(SimSpec { horizon_s: 10.0, trace_window_s: 1.0, ..SimSpec::default() });
    let session = spec.build().unwrap();
    let (_, sim) = session.sim_run(1).unwrap().finish();
    // 5 s at 10/s + 5 s at 50/s = 300 expected arrivals; 5σ band
    let expect = 300.0;
    let sigma = expect.sqrt();
    assert!(
        (sim.arrivals as f64 - expect).abs() < 5.0 * sigma,
        "trace arrivals {} vs expected {expect}",
        sim.arrivals
    );
}

#[test]
fn sim_run_streams_through_the_run_protocol() {
    let spec = ScenarioSpec::from_file(std::path::Path::new(
        "../examples/scenarios/two_class_er.json",
    ))
    .unwrap();
    let session = spec.build().unwrap();
    let optimized = session.routing_run("omd", 10).unwrap().finish();
    let mut traj = Trajectory::default();
    let (report, sim) = session
        .sim_run(5)
        .unwrap()
        .warm_start_from(&optimized)
        .observe(&mut traj)
        .finish();
    assert_eq!(report.algo, "sim");
    assert_eq!(report.iterations, 5);
    assert_eq!(report.stop, StopReason::MaxIters);
    assert_eq!(traj.values.len(), report.iterations + 1);
    assert_eq!(sim.in_flight, 0, "finish() drains the system");
    assert!(sim.arrivals > 0);
    assert!((report.objective - sim.mean_latency_s).abs() < 1e-12);
    // windowing must not change the event history
    let (_, one_shot) =
        session.sim_run(1).unwrap().warm_start_from(&optimized).finish();
    assert_eq!(sim, one_shot, "window count changed the replayed history");
}

#[test]
fn sim_run_driven_by_a_live_allocation_run() {
    let mut spec = ScenarioSpec::from_file(std::path::Path::new(
        "../examples/scenarios/two_class_er.json",
    ))
    .unwrap();
    spec.sim = Some(SimSpec { horizon_s: 12.0, ..SimSpec::default() });
    let session = spec.build().unwrap();
    let driver = session.allocation_run("omad", 100).unwrap();
    let (report, sim) = session.sim_run(4).unwrap().drive(driver).finish();
    assert_eq!(report.iterations, 4);
    assert_eq!(sim.in_flight, 0);
    assert!(sim.arrivals > 0);
    // the driver's allocation reached the simulator: the reported Λ obeys
    // per-class conservation
    let wl = &session.problem.workload;
    for (c, &(a, b)) in wl.class_spans.iter().enumerate() {
        let sum: f64 = report.lam[a..b].iter().sum();
        assert!(
            (sum - wl.class_rates[c]).abs() < 1e-6,
            "class {c}: Λ sums to {sum}, want {}",
            wl.class_rates[c]
        );
    }
}

#[test]
fn lifo_discipline_changes_waits_not_counts() {
    let (rate, mu) = (30.0, 40.0);
    let session = mm1_session(rate, mu);
    let run = |discipline: sim::Discipline| {
        let spec =
            SimSpec { horizon_s: 1000.0, discipline, ..SimSpec::default() };
        sim::simulate_requests(
            &session.problem,
            &mm1_phi(&session),
            &[rate],
            vec![ArrivalTrace::constant(rate)],
            spec,
            3,
        )
    };
    let fifo = run(sim::Discipline::Fifo);
    let lifo = run(sim::Discipline::Lifo);
    // the service order changes, the workload does not: same arrivals and
    // (by work conservation) matching means, but heavier LIFO tails
    assert_eq!(fifo.arrivals, lifo.arrivals);
    assert_eq!(fifo.completed, lifo.completed);
    assert!(
        lifo.p999_latency_s > fifo.p999_latency_s,
        "LIFO p999 {} should exceed FIFO p999 {}",
        lifo.p999_latency_s,
        fifo.p999_latency_s
    );
}

#[test]
fn calendar_queue_pops_the_heap_order_under_stress() {
    // randomized equivalence against a plain BinaryHeap: the calendar
    // queue must pop the identical stable (time, seq) total order through
    // coarse-grid ties (distinct seq on equal times), far-future pushes
    // that land in the overflow heap, bursts that force a bucket-table
    // grow, and drains that force it back down
    use jowr::sim::calendar::{CalendarQueue, Ev, EvKind};
    use std::collections::BinaryHeap;
    let mut rng = jowr::util::rng::Rng::seed_from(0xC0FFEE);
    let mut cal = CalendarQueue::new();
    let mut heap: BinaryHeap<Ev> = BinaryHeap::new();
    let mut seq = 0u64;
    let mut cur = 0.0f64;
    for round in 0..40usize {
        let burst = if round % 10 == 0 { 3000 } else { 50 + rng.below(200) };
        for _ in 0..burst {
            let t = if rng.chance(0.05) {
                // far future: exercises the overflow heap + re-anchor
                cur + 500.0 + 1000.0 * rng.f64()
            } else {
                // coarse grid: exact ties resolved purely by seq
                cur + rng.below(20) as f64 * 0.25
            };
            let ev = Ev { time: t, seq, kind: EvKind::Arrival { class: (seq % 7) as u32 } };
            seq += 1;
            cal.push(ev);
            heap.push(ev);
        }
        let t_end = if rng.chance(0.3) { f64::INFINITY } else { cur + rng.f64() * 8.0 };
        loop {
            let want = heap.peek().copied().filter(|e| e.time <= t_end);
            let got = cal.pop_at_most(t_end);
            match (want, got) {
                (None, None) => break,
                (Some(w), Some(g)) => {
                    assert_eq!(
                        (w.time.to_bits(), w.seq),
                        (g.time.to_bits(), g.seq),
                        "pop order diverged at seq {seq}"
                    );
                    assert_eq!(w.kind, g.kind);
                    heap.pop();
                    cur = g.time;
                }
                (w, g) => panic!("pop divergence: heap {w:?} vs calendar {g:?}"),
            }
        }
        assert_eq!(cal.len(), heap.len(), "length diverged after round {round}");
    }
    // final full drain
    while let Some(w) = heap.pop() {
        let g = cal.pop_at_most(f64::INFINITY).expect("calendar drained early");
        assert_eq!((w.time.to_bits(), w.seq), (g.time.to_bits(), g.seq));
    }
    assert!(cal.is_empty());
}

#[test]
fn optimized_core_matches_the_reference_engine_on_the_config_grid() {
    // the pinned PR-6 reference engine and the calendar/CSR/slab core
    // must produce bitwise-equal reports across drop/block capacities,
    // service disciplines, server counts, and seeds
    let (rate, mu) = (30.0, 40.0);
    let session = mm1_session(rate, mu);
    let phi = mm1_phi(&session);
    for &queue_capacity in &[0usize, 1] {
        for &servers_per_node in &[1usize, 3] {
            for discipline in [sim::Discipline::Fifo, sim::Discipline::Lifo] {
                for seed in [1u64, 9] {
                    let spec = SimSpec {
                        horizon_s: 300.0,
                        queue_capacity,
                        servers_per_node,
                        discipline,
                        ..SimSpec::default()
                    };
                    let fast = sim::simulate_requests(
                        &session.problem,
                        &phi,
                        &[rate],
                        vec![ArrivalTrace::constant(rate)],
                        spec.clone(),
                        seed,
                    );
                    let reference = sim::simulate_requests_reference(
                        &session.problem,
                        &phi,
                        &[rate],
                        vec![ArrivalTrace::constant(rate)],
                        spec,
                        seed,
                    );
                    assert_eq!(
                        fast, reference,
                        "engines diverged at cap={queue_capacity} c={servers_per_node} \
                         {discipline:?} seed={seed}"
                    );
                }
            }
        }
    }
}

#[test]
fn slab_recycling_is_invisible_through_the_omd_pipeline() {
    // slab-recycling bit-identity through the full OMD → replay pipeline:
    // the windowed sim_run (which exercises set_lam/set_phi buffer reuse
    // and slab recycling across a long horizon) must reproduce the
    // reference engine's one-shot replay of the same (Λ, φ, traces, seed)
    // bitwise, at 1 and 4 optimization workers
    let base = ScenarioSpec::from_file(std::path::Path::new(
        "../examples/scenarios/two_class_er.json",
    ))
    .unwrap();
    for &workers in &[1usize, 4] {
        let mut spec = base.clone();
        spec.workers = workers;
        spec.sim = Some(SimSpec { horizon_s: 30.0, ..SimSpec::default() });
        let session = spec.build().unwrap();
        let optimized = session.routing_run("omd", 15).unwrap().finish();
        let (_, piped) =
            session.sim_run(4).unwrap().warm_start_from(&optimized).finish();
        // the reference engine replays the same optimized operating point
        // through its never-recycled request store
        let phi = optimized.final_phi().expect("omd run carries phi");
        let traces: Vec<ArrivalTrace> = session
            .spec
            .classes
            .iter()
            .map(|class| match &class.rate {
                RateSpec::Constant(r) => ArrivalTrace::constant(*r),
                RateSpec::Trace(pts) => ArrivalTrace::from_breakpoints(pts, 1.0),
            })
            .collect();
        let reference = sim::simulate_requests_reference(
            &session.problem,
            phi,
            &optimized.lam,
            traces,
            SimSpec { horizon_s: 30.0, ..SimSpec::default() },
            session.cfg.seed,
        );
        assert_eq!(piped, reference, "slab recycling changed the report at {workers} workers");
        assert!(piped.peak_inflight > 0);
        assert!(piped.peak_inflight <= piped.arrivals);
    }
}

#[test]
fn hdr_latency_mode_keeps_counters_and_bounds_quantiles() {
    // the streaming log-histogram mode must leave the event history (and
    // every counter) untouched, reproduce the mean bitwise on this
    // single-class workload (same sequential summation order), and land
    // every reported quantile within the histogram's relative-error
    // bound of the exact-sample percentiles
    let (rate, mu) = (30.0, 40.0);
    let session = mm1_session(rate, mu);
    let phi = mm1_phi(&session);
    let run = |latency| {
        sim::simulate_requests(
            &session.problem,
            &phi,
            &[rate],
            vec![ArrivalTrace::constant(rate)],
            SimSpec { horizon_s: 2000.0, latency, ..SimSpec::default() },
            13,
        )
    };
    let exact = run(LatencyMode::Exact);
    let hdr = run(LatencyMode::Hdr);
    assert_eq!(exact.arrivals, hdr.arrivals);
    assert_eq!(exact.events, hdr.events);
    assert_eq!(exact.completed, hdr.completed);
    assert_eq!(exact.dropped, hdr.dropped);
    assert_eq!(exact.peak_inflight, hdr.peak_inflight);
    assert_eq!(
        exact.mean_latency_s.to_bits(),
        hdr.mean_latency_s.to_bits(),
        "hdr mean must be the identical sequential sum"
    );
    for (e, h) in exact.classes.iter().zip(&hdr.classes) {
        assert_eq!(e.completed, h.completed);
        assert_eq!(e.mean_latency_s.to_bits(), h.mean_latency_s.to_bits());
    }
    // quantiles: bucket quantization is ≤ 2⁻¹⁰ relative; the looser tail
    // bounds absorb nearest-order-statistic vs interpolated percentiles
    for (e, h, tol, which) in [
        (exact.p50_latency_s, hdr.p50_latency_s, 2e-3, "p50"),
        (exact.p99_latency_s, hdr.p99_latency_s, 5e-3, "p99"),
        (exact.p999_latency_s, hdr.p999_latency_s, 2e-2, "p999"),
    ] {
        assert!(
            (h - e).abs() <= tol * e + 1e-12,
            "{which}: hdr {h} vs exact {e} (tol {tol})"
        );
    }
}

/// The acceptance-scale replay: ≥10⁶ requests through an OMD-optimized
/// two-class scenario. Ignored by default (several seconds); the hotpath
/// bench pins the events/sec floor in CI.
#[test]
#[ignore]
fn million_request_replay() {
    let mut spec = ScenarioSpec::from_file(std::path::Path::new(
        "../examples/scenarios/two_class_er.json",
    ))
    .unwrap();
    // 60 req/s × 18000 s ≈ 1.08M requests
    spec.sim = Some(SimSpec { horizon_s: 18_000.0, ..SimSpec::default() });
    let session = spec.build().unwrap();
    let optimized = session.routing_run("omd", 30).unwrap().finish();
    let t0 = std::time::Instant::now();
    let (_, sim) = session.sim_run(1).unwrap().warm_start_from(&optimized).finish();
    let dt = t0.elapsed().as_secs_f64();
    assert!(sim.arrivals >= 1_000_000, "only {} requests", sim.arrivals);
    assert_eq!(sim.in_flight, 0);
    println!(
        "replayed {} requests / {} events in {dt:.2}s ({:.0} events/s)",
        sim.arrivals,
        sim.events,
        sim.events as f64 / dt
    );
}
