//! Integration: the AOT artifacts round-trip through PJRT with numerics
//! matching the native rust implementation (L1/L2 vs L3 cross-validation).
//!
//! These tests require the `xla` cargo feature plus `make artifacts`; they
//! are skipped (with a loud message) when `artifacts/manifest.json` is
//! absent so `cargo test` still runs on a fresh clone.
#![cfg(feature = "xla")]

use jowr::model::flow::{self, Phi};
use jowr::prelude::*;
use jowr::routing::marginal;
use jowr::routing::omd::OmdRouter;
use jowr::routing::Router;
use jowr::runtime::routing_step::{routing_step_xla, DenseNet};
use jowr::runtime::XlaRuntime;
use jowr::util::rng::Rng;

fn runtime() -> Option<XlaRuntime> {
    match XlaRuntime::try_default() {
        Some(rt) => Some(rt),
        None => {
            eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
            None
        }
    }
}

fn mk_problem(seed: u64, n: usize) -> Problem {
    let mut rng = Rng::seed_from(seed);
    let net = topologies::connected_er(n, 0.3, 3, &mut rng);
    Problem::new(net, 60.0, CostKind::Exp)
}

#[test]
fn mirror_step_xla_matches_native() {
    let Some(mut rt) = runtime() else { return };
    let rows = 24;
    let k = 7;
    let mut rng = Rng::seed_from(3);
    let mut phi = vec![0.0f32; rows * k];
    let mut delta = vec![0.0f32; rows * k];
    let mut mask = vec![0.0f32; rows * k];
    for r in 0..rows {
        let lanes = 2 + (r % (k - 1));
        let mut sum = 0.0;
        for j in 0..lanes {
            mask[r * k + j] = 1.0;
            phi[r * k + j] = rng.uniform(0.05, 1.0) as f32;
            delta[r * k + j] = rng.uniform(0.0, 3.0) as f32;
            sum += phi[r * k + j];
        }
        for j in 0..lanes {
            phi[r * k + j] /= sum;
        }
    }
    let eta = 0.7f32;
    let out =
        jowr::runtime::mirror::mirror_step_xla(&mut rt, &phi, &delta, &mask, eta, rows, k)
            .expect("xla mirror step");
    // native reference row by row
    for r in 0..rows {
        let lanes: Vec<usize> = (0..k).filter(|&j| mask[r * k + j] > 0.0).collect();
        let mut row: Vec<f64> = lanes.iter().map(|&j| phi[r * k + j] as f64).collect();
        let d: Vec<f64> = lanes.iter().map(|&j| delta[r * k + j] as f64).collect();
        OmdRouter::update_row(&mut row, &d, eta as f64);
        for (slot, &j) in lanes.iter().enumerate() {
            let got = out[r * k + j] as f64;
            assert!(
                (got - row[slot]).abs() < 1e-4,
                "row {r} lane {j}: xla {got} vs native {}",
                row[slot]
            );
        }
        // padding lanes stay zero
        for j in 0..k {
            if mask[r * k + j] == 0.0 {
                assert_eq!(out[r * k + j], 0.0);
            }
        }
    }
}

#[test]
fn routing_step_xla_matches_native_iteration() {
    let Some(mut rt) = runtime() else { return };
    let p = mk_problem(11, 10);
    let lam = p.uniform_allocation();
    let dense = DenseNet::build(&rt, &p).expect("dense encode");

    // native one step
    let mut phi_native = Phi::uniform(&p.net);
    let mut router = OmdRouter::fixed(0.2);
    let cost_native = router.step(&p, &lam, &mut phi_native);

    // xla one step
    let mut phi_xla = Phi::uniform(&p.net);
    let step = routing_step_xla(&mut rt, &dense, &p, &mut phi_xla, &lam, 0.2).expect("xla step");

    let rel_cost = (step.cost - cost_native).abs() / cost_native;
    assert!(rel_cost < 1e-4, "cost: xla {} vs native {}", step.cost, cost_native);
    // compare only traffic-carrying rows: for t_i(w) = 0 the paper declares
    // φ "insignificant to the actual flow rates" (§II-C) and the native path
    // skips them while the dense XLA program updates every row
    let t0 = flow::node_rates(&p.net, &Phi::uniform(&p.net), &lam);
    for w in 0..p.n_versions() {
        for (e, edge) in p.net.graph.edges().iter().enumerate() {
            if !p.net.session_edges[w][e] || t0[w][edge.src] <= 1e-12 {
                continue;
            }
            let (a, b) = (phi_xla.frac[w][e], phi_native.frac[w][e]);
            assert!((a - b).abs() < 5e-4, "phi[{w}][{e}]: xla {a} vs native {b}");
        }
    }
    // t / flows parity at the entry point
    let t_native = flow::node_rates(&p.net, &Phi::uniform(&p.net), &lam);
    for w in 0..p.n_versions() {
        for i in 0..p.net.n_nodes() {
            let xla_t = step.t[w * dense.n + i] as f64;
            assert!(
                (xla_t - t_native[w][i]).abs() < 1e-3 * t_native[w][i].max(1.0),
                "t[{w}][{i}]: {xla_t} vs {}",
                t_native[w][i]
            );
        }
    }
}

#[test]
fn routing_step_xla_converges_like_native() {
    let Some(mut rt) = runtime() else { return };
    let p = mk_problem(13, 12);
    let lam = p.uniform_allocation();
    let dense = DenseNet::build(&rt, &p).expect("dense encode");
    let mut phi = Phi::uniform(&p.net);
    let mut costs = Vec::new();
    // fixed small step: monotone descent must hold on the XLA path too
    for _ in 0..40 {
        let step = routing_step_xla(&mut rt, &dense, &p, &mut phi, &lam, 0.05).unwrap();
        costs.push(step.cost);
    }
    for wpair in costs.windows(2) {
        assert!(wpair[1] <= wpair[0] + 1e-2, "xla cost increased: {wpair:?}");
    }
    assert!(costs.last().unwrap() < &costs[0]);
    phi.is_feasible(&p.net, 1e-4).unwrap();
}

#[test]
fn dnn_versions_execute_with_ordered_latency() {
    let Some(mut rt) = runtime() else { return };
    let small = jowr::runtime::dnn::DnnVersion::load(&mut rt, "small", 1).unwrap();
    let large = jowr::runtime::dnn::DnnVersion::load(&mut rt, "large", 1).unwrap();
    let frames = vec![0.5f32; small.frame_dim];
    // warm both
    let _ = small.enhance(&mut rt, &frames).unwrap();
    let _ = large.enhance(&mut rt, &frames).unwrap();
    let mut t_small = 0.0;
    let mut t_large = 0.0;
    for _ in 0..5 {
        let (out_s, dt_s) = small.enhance(&mut rt, &frames).unwrap();
        let (out_l, dt_l) = large.enhance(&mut rt, &frames).unwrap();
        assert_eq!(out_s.len(), small.frame_dim);
        assert!(out_s.iter().all(|x| x.is_finite()));
        assert!(out_l.iter().all(|x| x.is_finite()));
        t_small += dt_s;
        t_large += dt_l;
    }
    assert!(
        t_large > t_small,
        "large ({t_large:.6}s) must be slower than small ({t_small:.6}s)"
    );
    // deterministic outputs for identical inputs
    let (a, _) = small.enhance(&mut rt, &frames).unwrap();
    let (b, _) = small.enhance(&mut rt, &frames).unwrap();
    assert_eq!(a, b);
}

#[test]
fn marginal_cross_check_via_xla_flows() {
    // the XLA step's flow matrix must agree with the native flow algebra
    let Some(mut rt) = runtime() else { return };
    let p = mk_problem(17, 9);
    let lam = p.uniform_allocation();
    let dense = DenseNet::build(&rt, &p).expect("dense");
    let phi = Phi::uniform(&p.net);
    let mut phi_x = phi.clone();
    let step = routing_step_xla(&mut rt, &dense, &p, &mut phi_x, &lam, 0.1).unwrap();
    let t = flow::node_rates(&p.net, &phi, &lam);
    let flows = flow::edge_flows(&p.net, &phi, &t);
    for (e, edge) in p.net.graph.edges().iter().enumerate() {
        let xla_f = step.flows[edge.src * dense.n + edge.dst] as f64;
        assert!(
            (xla_f - flows[e]).abs() < 1e-3 * flows[e].max(1.0),
            "edge {e}: xla {xla_f} vs native {}",
            flows[e]
        );
    }
    // ... and therefore the marginals derived from them agree
    let m = marginal::compute(&p, &phi, &flows);
    assert!(m.dprime.iter().all(|d| d.is_finite()));
}
