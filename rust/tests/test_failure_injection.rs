//! Failure injection + degenerate-input hardening: the system must stay
//! sane (no panics, invariants preserved) under inputs well outside the
//! paper's nominal operating point.

use jowr::allocation::{gsoma::GsOma, omad::Omad, Allocator, AnalyticOracle, SingleStepOracle};
use jowr::coordinator::serving::{AnalyticEngine, ServeParams};
use jowr::model::flow::{self, Phi};
use jowr::model::utility::family;
use jowr::prelude::*;
use jowr::routing::Router;
use jowr::util::rng::Rng;

fn mk_problem(seed: u64, n: usize, rate: f64) -> Problem {
    let mut rng = Rng::seed_from(seed);
    let net = topologies::connected_er(n, 0.3, 3, &mut rng);
    Problem::new(net, rate, CostKind::Exp)
}

#[test]
fn extreme_congestion_converges_finite() {
    // λ = 600 on a C̄ = 10 network: every link far beyond capacity; the exp
    // cost explodes but stays finite, and OMD still descends
    let p = mk_problem(1, 10, 600.0);
    let lam = p.uniform_allocation();
    let initial = FlowEngine::new().evaluate_cost(&p, &Phi::uniform(&p.net), &lam);
    let sol = OmdRouter::new(0.5).solve(&p, &lam, 500);
    assert!(sol.objective.is_finite());
    assert!(sol.objective <= initial);
    sol.phi.unwrap().is_feasible(&p.net, 1e-9).unwrap();
}

#[test]
fn near_zero_rate_is_stable() {
    let p = mk_problem(2, 8, 1e-6);
    let lam = p.uniform_allocation();
    let sol = OmdRouter::new(0.5).solve(&p, &lam, 100);
    assert!(sol.objective.is_finite());
    sol.phi.unwrap().is_feasible(&p.net, 1e-9).unwrap();
}

#[test]
fn all_mass_on_one_version() {
    // degenerate allocation: sessions with λ_w = 0 must not break flows,
    // marginals, or the mirror update
    let p = mk_problem(3, 10, 60.0);
    let lam = vec![60.0, 0.0, 0.0];
    let sol = OmdRouter::new(0.3).solve(&p, &lam, 300);
    let phi = sol.phi.unwrap();
    let ev = flow::evaluate(&p, &phi, &lam);
    assert!((ev.t[0][p.net.dnode(0)] - 60.0).abs() < 1e-9);
    assert_eq!(ev.t[1][p.net.dnode(1)], 0.0);
    assert!(sol.objective.is_finite());
}

#[test]
fn single_device_per_version_minimal_network() {
    // the smallest legal CEC: 3 devices, one per version, ring-connected
    let mut g = jowr::graph::DiGraph::with_nodes(3);
    for (u, v) in [(0, 1), (1, 2), (2, 0), (1, 0), (2, 1), (0, 2)] {
        g.add_edge(u, v, 10.0);
    }
    let placement = jowr::graph::augmented::Placement::new(vec![0, 1, 2], 3);
    let mut rng = Rng::seed_from(4);
    let net = jowr::graph::augmented::AugmentedNet::build(&g, &placement, 10.0, &mut rng);
    let p = Problem::new(net, 30.0, CostKind::Exp);
    let lam = p.uniform_allocation();
    let sol = OmdRouter::new(0.3).solve(&p, &lam, 500);
    let opt = OptRouter::new().solve(&p, &lam);
    assert!((sol.objective - opt.cost).abs() / opt.cost < 1e-2);
}

#[test]
fn repeated_topology_changes_do_not_leak_state() {
    let cfg = jowr::config::ExperimentConfig::paper_default();
    let us = family("log", 3, 60.0).unwrap();
    let mut rng = Rng::seed_from(5);
    let mut problem = {
        let mut c = cfg.clone();
        c.n_nodes = 10;
        c.build_problem(&mut rng).unwrap()
    };
    let mut oracle = SingleStepOracle::new(problem.clone(), us, 0.3);
    let alg = Omad::new(0.5, 0.05);
    let mut lam = vec![20.0, 20.0, 20.0];
    for epoch in 0..5u64 {
        // rewire every epoch
        let mut c = cfg.clone();
        c.n_nodes = 10;
        c.seed = 100 + epoch;
        let mut rng2 = Rng::seed_from(c.seed);
        problem = c.build_problem(&mut rng2).unwrap();
        jowr::allocation::UtilityOracle::on_topology_change(&mut oracle, &problem);
        for _ in 0..10 {
            let (next, _) = alg.outer_step(&mut oracle, &lam);
            lam = next;
            assert!((lam.iter().sum::<f64>() - 60.0).abs() < 1e-6);
            assert!(lam.iter().all(|l| l.is_finite() && *l >= 0.0));
        }
    }
}

#[test]
fn serving_with_saturated_hosts_drops_nothing_but_queues() {
    // inference far slower than arrivals: frames must queue (latency grows)
    // but every admitted frame is eventually served within the window stats
    let p = mk_problem(6, 8, 60.0);
    let phi = Phi::uniform(&p.net);
    let mut eng = AnalyticEngine::new(3, 7);
    eng.device_flops = 2.0e7; // 100x slower devices
    let mut rng = Rng::seed_from(8);
    let params = ServeParams { sim_time: 5.0, ..ServeParams::default_for(3) };
    let lam = p.uniform_allocation();
    let rep = jowr::coordinator::serving::simulate(&p, &phi, &lam, &mut eng, &params, &mut rng);
    assert_eq!(rep.dropped, 0);
    assert!(rep.p99_latency_s > rep.p50_latency_s);
    assert!(rep.utility.is_finite());
}

#[test]
fn gsoma_survives_tiny_delta_and_huge_eta() {
    let p = mk_problem(7, 8, 60.0);
    let us = family("log", 3, 60.0).unwrap();
    let mut oracle = AnalyticOracle::new(p, us);
    // pathological hyper-parameters: must not panic or go non-finite
    let mut alg = GsOma::new(1e-4, 50.0);
    let st = alg.run(&mut oracle, 10);
    assert!(st.lam.iter().all(|l| l.is_finite()));
    assert!((st.lam.iter().sum::<f64>() - 60.0).abs() < 1e-6);
}

#[test]
#[cfg(feature = "xla")]
fn corrupt_manifest_rejected_cleanly() {
    let dir = std::env::temp_dir().join("jowr_corrupt_manifest");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), "{ not json").unwrap();
    let err = jowr::runtime::XlaRuntime::load(&dir);
    assert!(err.is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
#[cfg(feature = "xla")]
fn unknown_artifact_errors_not_panics() {
    if let Some(mut rt) = jowr::runtime::XlaRuntime::try_default() {
        assert!(rt.execute("nonexistent_artifact", &[]).is_err());
    }
}

#[test]
fn unknown_solver_names_error_cleanly() {
    // registry dispatch: bad names are Err, not panic, everywhere
    let session = Scenario::paper_default().nodes(8).build().unwrap();
    assert!(session.router("definitely-not-a-router").is_err());
    assert!(session.allocator("definitely-not-an-allocator").is_err());
    assert!(session.routing_run("nope", 5).is_err());
    assert!(session.allocation_run("nope", 5).is_err());
}
