//! Integration: the distributed actor implementation is *exactly* the
//! centralized algorithm (message passing changes the plumbing, not the
//! math), and the serving pipeline composes with the optimizer — both now
//! streaming through the session stack (`RoutingRun`/`AllocationRun` over
//! `RunCore`), never the legacy state structs.

use jowr::allocation::{omad::Omad, UtilityOracle};
use jowr::coordinator::serving::{AnalyticEngine, MeasuredOracle, ServeParams};
use jowr::prelude::*;
use jowr::util::rng::Rng;

fn mk_problem(seed: u64, n: usize) -> Problem {
    let mut rng = Rng::seed_from(seed);
    let net = topologies::connected_er(n, 0.3, 3, &mut rng);
    Problem::new(net, 60.0, CostKind::Exp)
}

/// Drive a router through the streaming run protocol, recording the
/// trajectory.
fn run(p: &Problem, router: Box<dyn Router>, iters: usize) -> (Vec<f64>, RunReport) {
    let mut traj = Trajectory::default();
    let report = RoutingRun::new(p, router, p.uniform_allocation(), iters)
        .observe(&mut traj)
        .finish();
    (traj.values, report)
}

#[test]
fn distributed_equals_centralized_across_instances() {
    let workers = jowr::testkit::test_workers();
    for seed in [1u64, 9, 23] {
        let p = mk_problem(seed, 9);
        let (dtraj, dreport) =
            run(&p, Box::new(DistributedOmd::new(0.3).with_workers(workers)), 15);
        let (ctraj, _) = run(&p, Box::new(OmdRouter::new(0.3).with_workers(workers)), 15);
        for (i, (a, b)) in dtraj.iter().zip(&ctraj).enumerate() {
            assert!(
                (a - b).abs() <= 1e-9 * b.abs().max(1.0),
                "seed {seed} iter {i}: {a} vs {b}"
            );
        }
        let comm = dreport.comm.expect("distributed run reports comm stats");
        assert!(comm.messages > 0 && comm.bytes > 0);
        assert_eq!(comm.rounds, dreport.iterations);
    }
}

#[test]
fn communication_overhead_is_linear_in_rounds_and_edges() {
    let p = mk_problem(3, 8);
    let (_t, r5) = run(&p, Box::new(DistributedOmd::new(0.2)), 5);
    let (_t, r10) = run(&p, Box::new(DistributedOmd::new(0.2)), 10);
    let (c5, c10) = (r5.comm.unwrap(), r10.comm.unwrap());
    let per_round5 = c5.messages as f64 / 5.0;
    let per_round10 = c10.messages as f64 / 10.0;
    let rel = (per_round5 - per_round10).abs() / per_round10;
    assert!(rel < 0.25, "per-round message cost should be stable: {per_round5} vs {per_round10}");
}

#[test]
fn serving_oracle_drives_allocation_learning() {
    // end-to-end: measured utilities only, no analytic functions anywhere
    let p = mk_problem(5, 10);
    let params = ServeParams { sim_time: 8.0, ..ServeParams::default_for(3) };
    let mut oracle = MeasuredOracle::new(p, params, AnalyticEngine::new(3, 3), 0.3, 17)
        .with_workers(jowr::testkit::test_workers());
    let mut alg = Omad::new(1.5, 0.02);
    let mut lam = vec![20.0, 20.0, 20.0];
    let mut first = None;
    for _ in 0..25 {
        let u = oracle.observe(&lam);
        first.get_or_insert(u);
        let (next, _) = alg.outer_step(&mut oracle, &lam);
        lam = next;
    }
    let last_avg: f64 = (0..5).map(|_| oracle.observe(&lam)).sum::<f64>() / 5.0;
    // learning under measurement noise: average improvement, generous slack
    assert!(
        last_avg > first.unwrap() - 2.0,
        "measured utility regressed: {} -> {last_avg}",
        first.unwrap()
    );
    assert!((lam.iter().sum::<f64>() - 60.0).abs() < 1e-6);
    let rep = oracle.last_report.as_ref().unwrap();
    assert!(rep.throughput_fps > 0.0);
    // the shared-engine telemetry rides along with every observation
    assert!(oracle.last_cost.unwrap() > 0.0);
}

#[test]
fn serving_respects_allocation_mass() {
    // completions track the allocation proportions over a long window
    let p = mk_problem(8, 10);
    let phi = jowr::model::flow::Phi::uniform(&p.net);
    let mut eng = AnalyticEngine::new(3, 4);
    let mut rng = Rng::seed_from(5);
    let params = ServeParams { sim_time: 40.0, ..ServeParams::default_for(3) };
    let lam = [40.0, 15.0, 5.0];
    let rep =
        jowr::coordinator::serving::simulate(&p, &phi, &lam, &mut eng, &params, &mut rng);
    let done: u64 = rep.completed.iter().sum();
    assert!(done > 0);
    let share0 = rep.completed[0] as f64 / done as f64;
    assert!(
        (share0 - 40.0 / 60.0).abs() < 0.08,
        "version-0 share {share0} should be ~2/3 ({:?})",
        rep.completed
    );
}

#[test]
fn measured_serving_streams_through_the_allocation_run() {
    // the CLI `serve` path: MeasuredOracle boxed into a streaming
    // AllocationRun, serving telemetry recovered through the trait
    let p = mk_problem(11, 10);
    let params = ServeParams { sim_time: 4.0, ..ServeParams::default_for(3) };
    let oracle: Box<dyn UtilityOracle> =
        Box::new(MeasuredOracle::new(p, params, AnalyticEngine::new(3, 7), 0.3, 29));
    let mut traj = Trajectory::default();
    let mut run = AllocationRun::new(Box::new(Omad::new(1.5, 0.02)), oracle, 6)
        .observe(&mut traj);
    let report = loop {
        if let std::ops::ControlFlow::Break(r) = run.step() {
            break r;
        }
    };
    assert_eq!(report.iterations, 6);
    let oracle = run.into_oracle();
    // the observer borrow ends with the run; the trajectory has one point
    // per outer iteration plus the final observation
    assert_eq!(traj.values.len(), 7);
    let rep = oracle.last_serve_report().expect("measured oracle exposes serving telemetry");
    assert!(rep.throughput_fps > 0.0);
    assert!(oracle.observations() >= 7);
}
