//! Integration: the distributed actor implementation is *exactly* the
//! centralized algorithm (message passing changes the plumbing, not the
//! math), and the serving pipeline composes with the optimizer.

use jowr::allocation::{omad::Omad, UtilityOracle};
use jowr::coordinator::leader::DistributedOmd;
use jowr::coordinator::serving::{AnalyticEngine, MeasuredOracle, ServeParams};
use jowr::prelude::*;
use jowr::routing::Router;
use jowr::util::rng::Rng;

fn mk_problem(seed: u64, n: usize) -> Problem {
    let mut rng = Rng::seed_from(seed);
    let net = topologies::connected_er(n, 0.3, 3, &mut rng);
    Problem::new(net, 60.0, CostKind::Exp)
}

#[test]
fn distributed_equals_centralized_across_instances() {
    for seed in [1u64, 9, 23] {
        let p = mk_problem(seed, 9);
        let lam = p.uniform_allocation();
        let (d, comm) = DistributedOmd::new(0.3).solve(&p, &lam, 15);
        let c = OmdRouter::new(0.3).solve(&p, &lam, 15);
        for (i, (a, b)) in d.trajectory.iter().zip(&c.trajectory).enumerate() {
            assert!(
                (a - b).abs() < 1e-6 * b.abs().max(1.0),
                "seed {seed} iter {i}: {a} vs {b}"
            );
        }
        assert!(comm.messages > 0 && comm.bytes > 0);
    }
}

#[test]
fn communication_overhead_is_linear_in_rounds_and_edges() {
    let p = mk_problem(3, 8);
    let lam = p.uniform_allocation();
    let (_s, c5) = DistributedOmd::new(0.2).solve(&p, &lam, 5);
    let (_s, c10) = DistributedOmd::new(0.2).solve(&p, &lam, 10);
    let per_round5 = c5.messages as f64 / 5.0;
    let per_round10 = c10.messages as f64 / 10.0;
    let rel = (per_round5 - per_round10).abs() / per_round10;
    assert!(rel < 0.25, "per-round message cost should be stable: {per_round5} vs {per_round10}");
}

#[test]
fn serving_oracle_drives_allocation_learning() {
    // end-to-end: measured utilities only, no analytic functions anywhere
    let p = mk_problem(5, 10);
    let params = ServeParams { sim_time: 8.0, ..ServeParams::default_for(3) };
    let mut oracle = MeasuredOracle::new(p, params, AnalyticEngine::new(3, 3), 0.3, 17);
    let mut alg = Omad::new(1.5, 0.02);
    let mut lam = vec![20.0, 20.0, 20.0];
    let mut first = None;
    for _ in 0..25 {
        let u = oracle.observe(&lam);
        first.get_or_insert(u);
        let (next, _) = alg.outer_step(&mut oracle, &lam);
        lam = next;
    }
    let last_avg: f64 = (0..5).map(|_| oracle.observe(&lam)).sum::<f64>() / 5.0;
    // learning under measurement noise: average improvement, generous slack
    assert!(
        last_avg > first.unwrap() - 2.0,
        "measured utility regressed: {} -> {last_avg}",
        first.unwrap()
    );
    assert!((lam.iter().sum::<f64>() - 60.0).abs() < 1e-6);
    let rep = oracle.last_report.as_ref().unwrap();
    assert!(rep.throughput_fps > 0.0);
}

#[test]
fn serving_respects_allocation_mass() {
    // completions track the allocation proportions over a long window
    let p = mk_problem(8, 10);
    let phi = jowr::model::flow::Phi::uniform(&p.net);
    let mut eng = AnalyticEngine::new(3, 4);
    let mut rng = Rng::seed_from(5);
    let params = ServeParams { sim_time: 40.0, ..ServeParams::default_for(3) };
    let lam = [40.0, 15.0, 5.0];
    let rep =
        jowr::coordinator::serving::simulate(&p, &phi, &lam, &mut eng, &params, &mut rng);
    let done: u64 = rep.completed.iter().sum();
    assert!(done > 0);
    let share0 = rep.completed[0] as f64 / done as f64;
    assert!(
        (share0 - 40.0 / 60.0).abs() < 0.08,
        "version-0 share {share0} should be ~2/3 ({:?})",
        rep.completed
    );
}
