//! End-to-end loopback test of the distributed coordinator: a
//! [`DistributedRun`] driven over `coordinator::net`'s in-process
//! transport must (a) match the centralized `omd` router on the same
//! scenario to 1e-9, (b) account for every fabric message *exactly*, and
//! (c) be bit-identical at any engine worker count.

use std::ops::ControlFlow;

use jowr::graph::augmented::AugmentedNet;
use jowr::prelude::*;
use jowr::testkit::test_workers;

/// Exact per-round fabric message count, derived from the topology:
///
/// * `BeginRound` — one broadcast message per real node,
/// * `Ingress` — one per (session, DAG edge into a real node): S admits λ
///   over its lanes, every real node forwards over its real-dst lanes,
/// * `Marginal` — one per (session, DAG edge into a real node): every
///   real node announces its marginal to each upstream (actor or leader),
/// * `RowsReport` — one per real node.
///
/// Destination lanes (the virtual computation links) carry no messages —
/// `∂D/∂r_{D_w} = 0` is known statically (paper eq. 20).
fn per_round_messages(net: &AugmentedNet) -> u64 {
    let mut m = 2 * net.n_real as u64; // BeginRound + RowsReport
    for w in 0..net.n_versions() {
        for (e, used) in net.session_edges[w].iter().enumerate() {
            let dst = net.graph.edge(e).dst;
            if *used && dst >= 1 && dst <= net.n_real {
                m += 2; // one Ingress + one Marginal over this in-edge
            }
        }
    }
    m
}

fn session_for(workers: usize) -> Session {
    Scenario::paper_default()
        .nodes(10)
        .link_probability(0.3)
        .seed(11)
        .workers(workers)
        .build()
        .unwrap()
}

#[test]
fn loopback_distributed_run_matches_centralized_omd_to_1e9() {
    let session = session_for(test_workers());
    let rounds = 15;
    let mut dtraj = Trajectory::default();
    let dist = session.distributed_run(rounds).unwrap().observe(&mut dtraj).finish();
    let mut ctraj = Trajectory::default();
    let central = session.routing_run("omd", rounds).unwrap().observe(&mut ctraj).finish();

    // the whole trajectory — not just the endpoint — matches the
    // centralized solver (same math over the message fabric)
    assert_eq!(dtraj.values.len(), ctraj.values.len());
    for (i, (a, b)) in dtraj.values.iter().zip(&ctraj.values).enumerate() {
        assert!(
            (a - b).abs() <= 1e-9 * b.abs().max(1.0),
            "iter {i}: distributed {a} vs centralized {b}"
        );
    }
    assert!(
        (dist.objective - central.objective).abs()
            <= 1e-9 * central.objective.abs().max(1.0),
        "final cost: distributed {} vs centralized {}",
        dist.objective,
        central.objective
    );
    // and the final states agree lane by lane
    let (dphi, cphi) = (dist.phi.as_ref().unwrap(), central.phi.as_ref().unwrap());
    for (ra, rb) in dphi.frac.iter().zip(&cphi.frac) {
        for (a, b) in ra.iter().zip(rb) {
            assert!((a - b).abs() <= 1e-9, "phi: {a} vs {b}");
        }
    }
}

#[test]
fn loopback_comm_stats_message_counts_are_exact() {
    let session = session_for(1);
    let rounds = 7;
    let report = session.distributed_run(rounds).unwrap().finish();
    let comm = report.comm.expect("distributed runs report CommStats");
    assert_eq!(comm.rounds, report.iterations);
    let expected = report.iterations as u64 * per_round_messages(&session.problem.net);
    assert_eq!(
        comm.messages, expected,
        "fabric delivered {} messages, topology predicts {} ({} rounds)",
        comm.messages, expected, report.iterations
    );
    assert!(comm.bytes > comm.messages, "every message has a nonzero wire size");
}

#[test]
fn distributed_run_is_bit_identical_across_worker_counts() {
    // the engine worker knob (leader-side cost telemetry feeding the
    // adaptive step size) must not perturb a single bit of the run
    let run_with = |workers: usize| {
        let session = session_for(workers);
        let mut traj = Trajectory::default();
        let report = session.distributed_run(10).unwrap().observe(&mut traj).finish();
        (traj.values, report)
    };
    let (traj1, report1) = run_with(1);
    for workers in [2usize, 4, test_workers()] {
        let (traj, report) = run_with(workers);
        assert_eq!(traj.len(), traj1.len());
        for (i, (a, b)) in traj.iter().zip(&traj1).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "iter {i} at {workers} workers");
        }
        assert_eq!(report.objective.to_bits(), report1.objective.to_bits());
        let (pa, pb) = (report.phi.as_ref().unwrap(), report1.phi.as_ref().unwrap());
        for (ra, rb) in pa.frac.iter().zip(&pb.frac) {
            for (a, b) in ra.iter().zip(rb) {
                assert_eq!(a.to_bits(), b.to_bits(), "phi at {workers} workers");
            }
        }
    }
}

#[test]
fn distributed_run_streams_and_resumes_like_any_run() {
    // step-driven execution with a mid-run pause: the actors stay
    // deployed between steps, and a finished run replays its report
    let session = session_for(1);
    let mut run = session.distributed_run(6).unwrap();
    let mut steps = 0;
    let report = loop {
        match run.step() {
            ControlFlow::Continue(()) => steps += 1,
            ControlFlow::Break(r) => break r,
        }
    };
    assert_eq!(report.iterations, 6);
    assert_eq!(steps, 5); // the 6th step breaks with the report
    // replay without advancing
    if let ControlFlow::Break(again) = run.step() {
        assert_eq!(again.iterations, report.iterations);
        assert_eq!(again.comm.unwrap().messages, report.comm.unwrap().messages);
    } else {
        panic!("finished run must replay its report");
    }
}

#[test]
fn multi_class_distributed_loopback_is_bit_identical_to_centralized() {
    // ROADMAP PR-4 follow-up: the coordinator inherits multi-class
    // scenarios generically (one routed session per (class, version),
    // class-local admission) — pin it end to end. A two-class spec driven
    // through DistributedOmd must reproduce the centralized OMD-RT run
    // bit for bit: with slot-ordered ingress sums every actor replays the
    // engine's accumulation order exactly, and the leader's η adaptation
    // runs off the same fused-engine cost telemetry.
    let build = |workers: usize| {
        Scenario::paper_default()
            .nodes(10)
            .link_probability(0.35)
            .versions(2)
            .seed(23)
            .workers(workers)
            .class("alpha", "log", 30.0, &[])
            .class("beta", "linear", 20.0, &[3, 7])
            .build()
            .unwrap()
    };
    let session = build(test_workers());
    assert_eq!(session.problem.n_sessions(), 4, "two classes × two versions");
    let rounds = 12;
    let mut dtraj = Trajectory::default();
    let dist = session.distributed_run(rounds).unwrap().observe(&mut dtraj).finish();
    let mut ctraj = Trajectory::default();
    let central =
        session.routing_run("omd", rounds).unwrap().observe(&mut ctraj).finish();
    assert_eq!(dtraj.values.len(), ctraj.values.len());
    for (i, (a, b)) in dtraj.values.iter().zip(&ctraj.values).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "iter {i}: distributed {a} vs centralized {b}"
        );
    }
    assert_eq!(dist.objective.to_bits(), central.objective.to_bits());
    let (dphi, cphi) = (dist.phi.as_ref().unwrap(), central.phi.as_ref().unwrap());
    for (w, (ra, rb)) in dphi.frac.iter().zip(&cphi.frac).enumerate() {
        for (e, (a, b)) in ra.iter().zip(rb).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "phi[{w}][{e}]: {a} vs {b}");
        }
    }
    // per-class admission must be respected by the deployed fleet: each
    // session's S-lanes point only into its class's source devices
    let net = &session.problem.net;
    for s in 0..net.n_sessions() {
        for e in net.session_out(s, AugmentedNet::SOURCE) {
            let dst = net.graph.edge(e).dst;
            assert!(
                net.session_admit[s].binary_search(&dst).is_ok(),
                "session {s} admits through non-class device {dst}"
            );
        }
    }
    // and the multi-class distributed path stays bit-identical across
    // engine worker counts
    let reference = dtraj.values;
    for workers in [2usize, 4] {
        let session = build(workers);
        let mut traj = Trajectory::default();
        let _ = session.distributed_run(rounds).unwrap().observe(&mut traj).finish();
        for (i, (a, b)) in traj.values.iter().zip(&reference).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "iter {i} at {workers} workers");
        }
    }
}

#[test]
fn warm_started_distributed_run_continues_descent() {
    // RunReport-based hand-off (the legacy RoutingState interop is gone):
    // a second run warm-started from the first run's report keeps the
    // cost non-increasing in the small-step regime
    let session = session_for(1);
    let problem = &session.problem;
    let lam = session.uniform_allocation();
    let first = RoutingRun::new(
        problem,
        Box::new(DistributedOmd::fixed(0.05)),
        lam.clone(),
        8,
    )
    .finish();
    let second = RoutingRun::new(
        problem,
        Box::new(DistributedOmd::fixed(0.05)),
        lam,
        8,
    )
    .warm_start_from(&first)
    .finish();
    assert!(
        second.objective <= first.objective + 1e-9,
        "warm start regressed: {} -> {}",
        first.objective,
        second.objective
    );
}
