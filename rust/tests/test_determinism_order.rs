//! Determinism pins for the ordering-sensitive paths the audit rules
//! guard (`cargo run -p xtask -- audit`, rules r1/r4/r5), plus std-level
//! stress for the two concurrency primitives whose *protocols* are
//! model-checked in `tests/loom_models.rs`:
//!
//! * repeat-run **bitwise** equality of the distributed leader report —
//!   pins the `BTreeMap` conversions in `coordinator/leader.rs` /
//!   `coordinator/node.rs` (the report-merge loop now iterates in
//!   ascending node order; any drift back to hash-order iteration that
//!   affects results would break these exact-bit comparisons across runs
//!   and against the centralized solver),
//! * repeat-run bitwise equality of every `Suite` cell — pins the
//!   `ProblemCache` conversion in `session/suite.rs` (cells race to warm
//!   a shared cache across worker threads; results must not depend on
//!   who won),
//! * a multi-threaded `Loopback` stress: no lost `FlowDelta`, per-sender
//!   FIFO round ordering, exact message accounting,
//! * a `WorkerPool` stress hammering `run_scoped` with interleaved panic
//!   rounds: panics are forwarded after the completion barrier and the
//!   pool stays usable, with every non-panicking task's effect intact.
//!
//! Comparisons deliberately use `f64::to_bits`, not `==`: the guarantee
//! is bit-identity (same bits in, same bits out), which `==` would
//! weaken around `-0.0` and NaN.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use jowr::coordinator::messages::Msg;
use jowr::engine::pool::WorkerPool;
use jowr::prelude::*;
use jowr::testkit::test_workers;

/// Bitwise equality of two f64 slices, with a labelled assert.
fn assert_bits_eq(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}[{i}]: {x} vs {y} differ in bits");
    }
}

fn assert_reports_bit_identical(a: &RunReport, b: &RunReport, what: &str) {
    assert_eq!(a.algo, b.algo, "{what}: algo");
    assert_eq!(a.objective.to_bits(), b.objective.to_bits(), "{what}: objective bits");
    assert_bits_eq(&a.lam, &b.lam, what);
    assert_eq!(a.iterations, b.iterations, "{what}: iterations");
    assert_eq!(a.routing_iterations, b.routing_iterations, "{what}: routing iterations");
    assert_eq!(a.comm, b.comm, "{what}: comm accounting");
    match (&a.phi, &b.phi) {
        (Some(pa), Some(pb)) => {
            assert_eq!(pa.frac.len(), pb.frac.len(), "{what}: phi session count");
            for (w, (ra, rb)) in pa.frac.iter().zip(&pb.frac).enumerate() {
                assert_bits_eq(ra, rb, &format!("{what}: phi[{w}]"));
            }
        }
        (None, None) => {}
        _ => panic!("{what}: phi presence differs"),
    }
}

fn session_for(workers: usize) -> Session {
    Scenario::paper_default()
        .nodes(10)
        .link_probability(0.3)
        .seed(11)
        .workers(workers)
        .build()
        .unwrap()
}

/// The distributed leader's report merge iterates per-node row reports.
/// Since the conversion to `BTreeMap` that iteration is in ascending node
/// order; two independent runs (fresh sessions, fresh fabrics, fresh
/// engine pools) must produce bit-identical reports.
#[test]
fn distributed_leader_report_is_bitwise_stable_across_runs() {
    let rounds = 12;
    let a = session_for(test_workers()).distributed_run(rounds).unwrap().finish();
    let b = session_for(test_workers()).distributed_run(rounds).unwrap().finish();
    assert_reports_bit_identical(&a, &b, "distributed repeat");
    // and across engine worker counts (the merge must not depend on how
    // node-local work was chunked)
    let c = session_for(1).distributed_run(rounds).unwrap().finish();
    assert_reports_bit_identical(&a, &c, "distributed workers=1 vs pooled");
}

/// Suite cells share a `ProblemCache` (now a `BTreeMap` behind a mutex)
/// and run on a worker pool in nondeterministic completion order; the
/// per-cell reports must not depend on either.
#[test]
fn suite_cells_are_bitwise_stable_across_repeat_runs() {
    let run = || {
        Suite::new()
            .spec("paper", ScenarioSpec::paper_default())
            .router("omd")
            .router("sgp")
            .seeds(&[1, 2])
            .iters(8)
            .workers(test_workers())
            .cache_problems(true)
            .run()
    };
    let a = run();
    let b = run();
    assert_eq!(a.cells.len(), b.cells.len());
    assert!(!a.cells.is_empty(), "suite produced no cells");
    for (ca, cb) in a.cells.iter().zip(&b.cells) {
        assert_eq!(ca.scenario, cb.scenario);
        assert_eq!(ca.solver, cb.solver);
        assert_eq!(ca.seed, cb.seed);
        let what = format!("cell ({}, {}, seed {})", ca.scenario, ca.solver, ca.seed);
        match (&ca.outcome, &cb.outcome) {
            (Ok(ra), Ok(rb)) => assert_reports_bit_identical(&ra.report, &rb.report, &what),
            (Err(ea), Err(eb)) => assert_eq!(ea, eb, "{what}: error text"),
            _ => panic!("{what}: outcome kind differs between runs"),
        }
    }
    // CSV rows agree except the wall-clock column (elapsed_s, column 9)
    for (la, lb) in a.to_csv().lines().zip(b.to_csv().lines()) {
        let strip = |l: &str| {
            let mut f: Vec<String> = l.split(',').map(str::to_string).collect();
            if f.len() > 9 {
                f[9] = String::new();
            }
            f.join(",")
        };
        assert_eq!(strip(la), strip(lb), "csv row differs beyond elapsed_s");
    }
}

/// Two shards hammer a third over the real `Loopback` (bounded std mpsc
/// channels, senders block when full): nothing may be lost, per-sender
/// rounds must arrive in FIFO order, and the transport's communication
/// accounting must be exact.
#[test]
fn loopback_stress_no_lost_deltas_per_sender_fifo() {
    const PER_SENDER: u64 = 64; // well past the bounded mailbox capacity
    let fabric = std::sync::Arc::new(Loopback::new(3));
    let sent_bytes = std::sync::Arc::new(AtomicU64::new(0));
    let mut handles = Vec::new();
    for shard in [1usize, 2usize] {
        let f = std::sync::Arc::clone(&fabric);
        let sb = std::sync::Arc::clone(&sent_bytes);
        handles.push(std::thread::spawn(move || {
            for round in 0..PER_SENDER {
                let msg = Msg::FlowDelta {
                    shard,
                    round,
                    edges: vec![(shard, round as f64), (shard + 7, 0.5)],
                };
                sb.fetch_add(msg.wire_bytes() as u64, Ordering::Relaxed);
                assert!(f.send(shard, 0, msg), "loopback send failed");
            }
        }));
    }
    let mut next_round = [0u64; 3]; // expected next round per sender
    let mut received = 0u64;
    while received < 2 * PER_SENDER {
        let msg = fabric
            .recv(0, Duration::from_secs(10))
            .expect("loopback receive timed out mid-stress");
        match msg {
            Msg::FlowDelta { shard, round, edges } => {
                assert_eq!(round, next_round[shard], "sender {shard}: rounds out of FIFO order");
                next_round[shard] += 1;
                // payload integrity: absolute values arrive untouched
                assert_eq!(edges[0], (shard, round as f64));
                received += 1;
            }
            other => panic!("unexpected message on the fabric: {other:?}"),
        }
    }
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(next_round, [0, PER_SENDER, PER_SENDER], "a sender lost deltas");
    // exact accounting: every send was counted with its wire size
    let comm = fabric.comm();
    assert_eq!(comm.messages, 2 * PER_SENDER);
    assert_eq!(comm.bytes, sent_bytes.load(Ordering::Relaxed));
    assert_eq!(comm.shards[0].msgs, 0, "shard 0 sent nothing");
    assert_eq!(comm.shards[1].msgs, PER_SENDER);
    assert_eq!(comm.shards[2].msgs, PER_SENDER);
}

/// Hammer `run_scoped` across many rounds with interleaved panic rounds:
/// every non-panicking task's effect must land before the barrier
/// returns, a panicking task's payload must resume on the caller *after*
/// the barrier, and the pool must stay fully usable afterwards.
#[test]
fn worker_pool_survives_contention_and_panic_rounds() {
    let pool = WorkerPool::new(3);
    let expect = |round: u64, slot: u64| round.wrapping_mul(0x9e37_79b9) ^ slot;
    for round in 0..80u64 {
        let panic_round = round % 40 == 17; // rounds 17 and 57
        let mut out = vec![0u64; 4];
        {
            let mut slots: Vec<&mut u64> = out.iter_mut().collect();
            let caller_slot = slots.pop().unwrap();
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = slots
                .into_iter()
                .enumerate()
                .map(|(i, slot)| {
                    let boom = panic_round && i == 1;
                    Box::new(move || {
                        *slot = expect(round, i as u64);
                        if boom {
                            panic!("task boom round {round}");
                        }
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            let run = || pool.run_scoped(tasks, || *caller_slot = expect(round, 3));
            if panic_round {
                let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(run));
                assert!(err.is_err(), "round {round}: panic was swallowed");
            } else {
                run();
            }
        }
        // the barrier ran to completion either way: every effect is
        // visible, including the panicking task's pre-panic write
        for (slot, got) in out.iter().enumerate() {
            assert_eq!(*got, expect(round, slot as u64), "round {round} slot {slot}");
        }
    }
    assert_eq!(pool.n_threads(), 3, "pool degraded after panic rounds");
}
