//! Equivalence tests for the explicit SIMD kernels (`--features simd`)
//! and the row-sparse OMD step.
//!
//! * **SIMD ≡ scalar-batched, bitwise.** [`BatchMode::Simd`] must produce
//!   bit-identical engine state (cost, flows, `D'`, per-session rates and
//!   marginals) to [`BatchMode::Batched`] and [`BatchMode::Scalar`] —
//!   across every cost family (plus mixed per-edge families), block
//!   widths 1..=8 (the full remainder range around the 4-lane vectors,
//!   exercising the padded columns), worker counts, and several descent
//!   iterations. Without the feature, `Simd` degrades to the batched
//!   kernels and the same assertions pin that degradation.
//! * **Row-sparse OMD ≡ dense, bitwise** at the default `sparse_tol = 0`:
//!   a probe loop driven through `observe_dirty` (masks from
//!   [`SessionMask::from_diff`], exactly like `allocation::observe_probe`)
//!   must reproduce the dense `observe` loop bit for bit — including
//!   repeated-λ probes (the memo skip), a large-η run (the
//!   [`MAX_EXP_SPAN`] trust-region and row-max-shift branches of
//!   `update_row`), and the engine re-syncs through
//!   `OmdRouter::post_step_cost`.
//! * **`sparse_tol` deviation bound.** With the threshold skip armed at
//!   `1e-12`, each skipped row update moves φ by O(tol) relative, so a
//!   T-step probe loop stays within ~T·tol·κ of the dense trajectory;
//!   asserted at 1e-7 relative — comfortably above the worst-case
//!   accumulation for T ≈ 30, far below any behavioral difference.

use jowr::allocation::oracle::SingleStepOracle;
use jowr::allocation::UtilityOracle;
use jowr::engine::{BatchMode, FlowEngine, SessionMask};
use jowr::graph::augmented::{AugmentedNet, Placement};
use jowr::graph::topologies;
use jowr::model::cost::CostKind;
use jowr::model::flow::Phi;
use jowr::model::utility::family;
use jowr::model::{Problem, Workload};
use jowr::routing::omd::{OmdRouter, MAX_EXP_SPAN, PHI_FLOOR};
use jowr::routing::Router;
use jowr::util::rng::Rng;

/// A heterogeneous multi-class problem: `classes` blocks over 3 versions,
/// so every version's batch block has width `classes` — the knob the SIMD
/// grid turns through the whole remainder range 1..=2·LANES.
fn multi_problem(seed: u64, n: usize, classes: usize, cost: CostKind) -> Problem {
    let mut rng = Rng::seed_from(seed);
    let g = topologies::connected_er_graph(n, 0.3, 10.0, &mut rng);
    let pl = Placement::random(n, 3, &mut rng);
    let mut class_sources: Vec<Vec<usize>> = vec![pl.hosts(0).collect()];
    for c in 1..classes {
        class_sources.push(vec![c % n, (3 * c + 1) % n]);
    }
    let net = AugmentedNet::build_heterogeneous(&g, &pl, 10.0, &[], &class_sources, &mut rng);
    let workload = Workload {
        class_names: (0..classes).map(|c| format!("c{c}")).collect(),
        class_rates: vec![20.0; classes],
        class_spans: (0..classes).map(|c| (3 * c, 3 * (c + 1))).collect(),
    };
    Problem::with_workload(net, cost, workload)
}

/// Assert two prepared engines expose bitwise-identical state.
fn assert_engines_bitwise(tag: &str, problem: &Problem, a: &FlowEngine, b: &FlowEngine) {
    assert_eq!(a.cost().to_bits(), b.cost().to_bits(), "{tag}: cost");
    for (e, (x, y)) in a.flows().iter().zip(b.flows()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{tag}: flows[{e}]");
    }
    for (e, (x, y)) in a.dprime().iter().zip(b.dprime()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{tag}: dprime[{e}]");
    }
    for w in 0..problem.n_sessions() {
        for (i, (x, y)) in a.rates(w).iter().zip(b.rates(w)).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{tag}: t[{w}][{i}]");
        }
        for (i, (x, y)) in a.marginals(w).iter().zip(b.marginals(w)).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{tag}: r[{w}][{i}]");
        }
    }
}

/// Compare Scalar vs Batched vs Simd engines at several descent points of
/// one problem, at the given worker count.
fn check_simd_grid_point(tag: &str, problem: &Problem, workers: usize) {
    let mut scalar = FlowEngine::new().with_workers(workers).with_batch_mode(BatchMode::Scalar);
    let mut batched = FlowEngine::new().with_workers(workers).with_batch_mode(BatchMode::Batched);
    let mut simd = FlowEngine::new().with_workers(workers).with_batch_mode(BatchMode::Simd);
    let mut router = OmdRouter::new(0.5);
    let mut phi = Phi::uniform(&problem.net);
    let lam = problem.uniform_allocation();
    for iter in 0..3 {
        let t = format!("{tag} iter={iter}");
        scalar.prepare(problem, &phi, &lam);
        batched.prepare(problem, &phi, &lam);
        simd.prepare(problem, &phi, &lam);
        if cfg!(feature = "simd") && !problem.net.batch.blocks.is_empty() {
            assert!(simd.ran_simd(), "{t}: Simd mode must run the vector kernels");
        } else {
            assert!(!simd.ran_simd(), "{t}: vector kernels need the simd feature");
        }
        assert_engines_bitwise(&format!("{t} simd-vs-scalar"), problem, &simd, &scalar);
        assert_engines_bitwise(&format!("{t} simd-vs-batched"), problem, &simd, &batched);
        // move to a new operating point (real descent geometry, not noise)
        router.step(problem, &lam, &mut phi);
    }
}

#[test]
fn simd_bit_identical_across_widths_and_families() {
    // width == classes: 1..=8 covers sub-lane blocks, one exact vector,
    // every remainder shape, and two full vectors (all padded under simd)
    for classes in 1..=8usize {
        let cost = match classes % 4 {
            0 => CostKind::Exp,
            1 => CostKind::Queue,
            2 => CostKind::Linear,
            _ => CostKind::Cubic,
        };
        let problem = multi_problem(40 + classes as u64, 14, classes, cost);
        check_simd_grid_point(&format!("w{classes}/{cost:?}/wk1"), &problem, 1);
    }
}

#[test]
fn simd_bit_identical_all_families_multi_worker() {
    let fams = [CostKind::Exp, CostKind::Queue, CostKind::Linear, CostKind::Cubic];
    for (i, cost) in fams.iter().enumerate() {
        let problem = multi_problem(60 + i as u64, 16, 5, *cost);
        for workers in [1usize, 4] {
            check_simd_grid_point(&format!("{cost:?}/wk{workers}"), &problem, workers);
        }
    }
}

#[test]
fn simd_bit_identical_mixed_per_edge_costs() {
    let problem = multi_problem(77, 16, 6, CostKind::Exp);
    let kinds = [CostKind::Exp, CostKind::Queue, CostKind::Linear, CostKind::Cubic];
    let ne = problem.net.graph.n_edges();
    let edge_costs: Vec<CostKind> = (0..ne).map(|e| kinds[e % kinds.len()]).collect();
    let problem = problem.with_edge_cost(Some(edge_costs));
    check_simd_grid_point("mixed/wk1", &problem, 1);
    check_simd_grid_point("mixed/wk4", &problem, 4);
}

#[test]
fn auto_mode_dispatch_matches_feature_and_width() {
    let problem = multi_problem(9, 14, 4, CostKind::Exp);
    let phi = Phi::uniform(&problem.net);
    let lam = problem.uniform_allocation();
    let mut auto = FlowEngine::new();
    auto.prepare(&problem, &phi, &lam);
    assert!(auto.ran_batched(), "auto mode must batch width-4 blocks");
    assert_eq!(
        auto.ran_simd(),
        cfg!(feature = "simd"),
        "auto mode picks the vector kernels exactly when the feature is on"
    );
}

/// Drive a dense oracle (plain `observe`) and a dirty oracle
/// (`observe_dirty` with `from_diff` masks) through the same probe
/// sequence; returns both utility streams.
fn probe_pair(
    problem: &Problem,
    eta: f64,
    sparse_tol: f64,
    probes: &[Vec<f64>],
) -> (Vec<f64>, Vec<f64>) {
    let utils = family("log", problem.n_sessions(), 60.0).expect("log family");
    let mut dense = SingleStepOracle::new(problem.clone(), utils.clone(), eta);
    let mut sparse = SingleStepOracle::new(problem.clone(), utils, eta);
    sparse.router.sparse_tol = sparse_tol;
    let mut u_dense = Vec::new();
    let mut u_sparse = Vec::new();
    let mut prev: Option<Vec<f64>> = None;
    for lam in probes {
        u_dense.push(dense.observe(lam));
        u_sparse.push(match &prev {
            Some(last) => sparse.observe_dirty(lam, &SessionMask::from_diff(last, lam)),
            None => sparse.observe(lam),
        });
        prev = Some(lam.clone());
    }
    (u_dense, u_sparse)
}

/// A probe sequence over one problem's class blocks: rotating ±δ pairs
/// plus deliberate exact repeats (empty diff masks → the memo skip).
fn probe_sequence(problem: &Problem, rounds: usize) -> Vec<Vec<f64>> {
    let lam0 = problem.uniform_allocation();
    let blocks = problem.workload.blocks();
    let mut probes = Vec::new();
    for k in 0..rounds {
        let (s0, s1, _) = blocks[k % blocks.len()];
        if s1 - s0 < 2 {
            probes.push(lam0.clone());
            continue;
        }
        let mut up = lam0.clone();
        up[s0] += 0.4;
        up[s0 + 1] -= 0.4;
        probes.push(up);
        probes.push(lam0.clone());
        if k % 3 == 0 {
            // exact repeat: from_diff yields an empty mask
            probes.push(lam0.clone());
        }
    }
    probes
}

#[test]
fn row_sparse_probe_loop_bit_identical_to_dense() {
    for classes in [1usize, 4] {
        let problem = multi_problem(21 + classes as u64, 14, classes, CostKind::Exp);
        let probes = probe_sequence(&problem, 8);
        let (u_dense, u_sparse) = probe_pair(&problem, 0.5, 0.0, &probes);
        for (k, (a, b)) in u_dense.iter().zip(&u_sparse).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "classes={classes} probe={k}: dirty probe loop must be bit-identical"
            );
        }
    }
}

#[test]
fn row_sparse_bit_identical_under_trust_region_eta() {
    // η = 60 pushes the exp-family exponent spans far past MAX_EXP_SPAN,
    // so every update runs the trust-region-capped, row-max-shifted
    // branch of update_row — the dirty loop must still match bitwise
    let problem = multi_problem(33, 14, 4, CostKind::Exp);
    let probes = probe_sequence(&problem, 6);
    let (u_dense, u_sparse) = probe_pair(&problem, 60.0, 0.0, &probes);
    for (k, (a, b)) in u_dense.iter().zip(&u_sparse).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "probe={k}: large-η dirty loop must match");
    }
}

#[test]
fn sparse_tol_deviation_stays_bounded() {
    let problem = multi_problem(55, 14, 4, CostKind::Exp);
    let probes = probe_sequence(&problem, 10);
    let (u_dense, u_sparse) = probe_pair(&problem, 0.5, 1e-12, &probes);
    for (k, (a, b)) in u_dense.iter().zip(&u_sparse).enumerate() {
        let tol = 1e-7 * a.abs().max(1.0);
        assert!(
            (a - b).abs() <= tol,
            "probe={k}: sparse_tol=1e-12 drifted {:.3e} (> {tol:.3e}) from dense",
            (a - b).abs()
        );
    }
}

#[test]
fn touched_sessions_tracks_changed_rows_only() {
    let problem = multi_problem(13, 14, 4, CostKind::Exp);
    let n = problem.n_sessions();
    let mut router = OmdRouter::new(0.5);
    let mut phi = Phi::uniform(&problem.net);
    let lam = problem.uniform_allocation();
    assert!(router.touched_sessions().is_none(), "no step yet");
    router.step(&problem, &lam, &mut phi);
    let touched = router.touched_sessions().expect("tracked after a step");
    assert_eq!(touched.len(), n);
    assert!(!touched.is_empty(), "the first step from uniform φ must move rows");
    // drive to convergence: once φ is a fixed point, no row changes and
    // the touched set must be empty
    for _ in 0..400 {
        let before = phi.clone();
        router.step(&problem, &lam, &mut phi);
        let same = before
            .frac
            .iter()
            .zip(&phi.frac)
            .all(|(ra, rb)| ra.iter().zip(rb).all(|(x, y)| x.to_bits() == y.to_bits()));
        if same {
            let t = router.touched_sessions().expect("tracked");
            assert!(t.is_empty(), "a bitwise fixed-point step must touch no rows");
            return;
        }
    }
    // not converging to a bitwise fixed point in 400 iters is fine too —
    // the invariant above only binds when it does
}

#[test]
fn update_row_identity_fast_path_fires_on_converged_rows() {
    // equal marginals on a normalized interior row: the update is the
    // identity, and the fast path must keep it *bitwise* untouched
    for row0 in [vec![0.25, 0.25, 0.25, 0.25], vec![0.3, 0.7], vec![1.0]] {
        let mut row = row0.clone();
        let delta = vec![1.7; row.len()];
        OmdRouter::update_row(&mut row, &delta, 0.5);
        for (i, (a, b)) in row0.iter().zip(&row).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "converged row moved at lane {i}");
        }
    }
}

#[test]
fn update_row_identity_fast_path_falls_through_when_guards_fail() {
    // sub-floor live lane: the body must run and restore the interior
    // floor invariant (every live lane 0 or ≥ PHI_FLOOR)
    // (the floored lane lands at PHI_FLOOR / (1 + PHI_FLOOR·…) — one
    // renormalization below the nominal constant, hence the 0.9 slack)
    let mut row = vec![5e-13, 1.0 - 5e-13];
    OmdRouter::update_row(&mut row, &[2.0, 2.0], 0.5);
    assert!(row.iter().all(|&p| p == 0.0 || p >= PHI_FLOOR * 0.9), "floor restored: {row:?}");
    assert!((row.iter().sum::<f64>() - 1.0).abs() <= 1e-12);
    // non-normalized row with equal deltas: the body renormalizes
    let mut row = vec![0.4, 0.7];
    OmdRouter::update_row(&mut row, &[2.0, 2.0], 0.5);
    assert!((row.iter().sum::<f64>() - 1.0).abs() <= 1e-12, "body must renormalize: {row:?}");
    assert!((row[0] - 0.4 / 1.1).abs() <= 1e-15 && (row[1] - 0.7 / 1.1).abs() <= 1e-15);
}

#[test]
fn update_row_trust_region_and_shift_branches() {
    // exponent spread η·(δmax − δmin) = 1000 ≫ MAX_EXP_SPAN: the capped
    // branch must keep the row feasible and prefer the cheap lane without
    // collapsing the rest below the interior floor
    assert!(50.0 * 20.0 > MAX_EXP_SPAN, "this case must engage the trust region");
    let mut row = vec![0.5, 0.3, 0.2];
    OmdRouter::update_row(&mut row, &[0.0, 10.0, 20.0], 50.0);
    assert!((row.iter().sum::<f64>() - 1.0).abs() <= 1e-12, "capped row must stay simplex");
    assert!(row[0] > row[1] && row[1] > row[2], "cheap lanes must gain: {row:?}");
    assert!(row.iter().all(|&p| p >= PHI_FLOOR * 0.9), "every lane stays live: {row:?}");
    // all-negative deltas (z > 0): the row-max shift keeps exp args ≤ 0,
    // so nothing overflows even at |z| ≈ 300
    let mut row = vec![0.5, 0.5];
    OmdRouter::update_row(&mut row, &[-300.0, -100.0], 1.0);
    assert!(row.iter().all(|p| p.is_finite()), "shift must prevent overflow: {row:?}");
    assert!((row.iter().sum::<f64>() - 1.0).abs() <= 1e-12);
    assert!(row[0] > row[1], "the less costly lane must dominate");
}

#[test]
fn post_step_cost_matches_dense_evaluation() {
    let problem = multi_problem(91, 14, 4, CostKind::Exp);
    let n = problem.n_sessions();
    let mut router = OmdRouter::new(0.5);
    let mut phi = Phi::uniform(&problem.net);
    let lam = problem.uniform_allocation();
    for step in 0..6 {
        let mask = SessionMask::none(n);
        if step == 0 {
            router.step(&problem, &lam, &mut phi);
        } else {
            router.step_dirty(&problem, &lam, &mut phi, &mask);
        }
        let c = router.post_step_cost(&problem, &phi, &lam);
        let dense = FlowEngine::new().evaluate_cost(&problem, &phi, &lam);
        assert_eq!(c.to_bits(), dense.to_bits(), "step={step}: post_step_cost must match");
    }
}
