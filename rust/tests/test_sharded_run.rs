//! End-to-end tests of the sharded coordination plane: the `"sharded-omd"`
//! registry router driven through the session API must (a) degenerate to
//! the single-leader loopback plane *bit for bit* at K = 1 (and hence stay
//! within the existing 1e-9 pin of centralized OMD-RT), (b) be a pure
//! function of `(spec, seed, K, S)` — bitwise-deterministic across repeat
//! runs, thread interleavings, and engine worker counts, (c) track the
//! centralized router within tolerance at S = 0, and (d) surface a
//! violated staleness bound as a typed [`SessionError::StalenessExceeded`],
//! never a hang.

use std::sync::Arc;
use std::time::Duration;

use jowr::model::flow::Phi;
use jowr::prelude::*;
use jowr::testkit::{test_shards, test_workers};

fn session_for(shards: usize, staleness: usize, workers: usize) -> Session {
    Scenario::paper_default()
        .nodes(10)
        .link_probability(0.3)
        .seed(17)
        .workers(workers)
        .shards(shards)
        .staleness(staleness)
        .build()
        .unwrap()
}

fn assert_phi_bits_eq(a: &RunReport, b: &RunReport, what: &str) {
    let (pa, pb) = (a.phi.as_ref().unwrap(), b.phi.as_ref().unwrap());
    for (w, (ra, rb)) in pa.frac.iter().zip(&pb.frac).enumerate() {
        for (e, (x, y)) in ra.iter().zip(rb).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: phi[{w}][{e}]: {x} vs {y}");
        }
    }
}

#[test]
fn k1_sharded_run_is_bit_identical_to_the_single_leader_plane() {
    let session = session_for(1, 0, test_workers());
    let rounds = 12;
    let mut straj = Trajectory::default();
    let sharded = session.sharded_run(rounds).unwrap().observe(&mut straj).finish();
    let mut dtraj = Trajectory::default();
    let dist = session.distributed_run(rounds).unwrap().observe(&mut dtraj).finish();

    // K = 1 IS the single-leader plane: every iterate matches bitwise
    assert_eq!(straj.values.len(), dtraj.values.len());
    for (i, (a, b)) in straj.values.iter().zip(&dtraj.values).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "iter {i}: sharded {a} vs single-leader {b}");
    }
    assert_eq!(sharded.objective.to_bits(), dist.objective.to_bits());
    assert_phi_bits_eq(&sharded, &dist, "K=1 vs single leader");

    // ...and therefore inherits the centralized pin (loopback ≡ omd @1e-9)
    let central = session.routing_run("omd", rounds).unwrap().finish();
    assert!(
        (sharded.objective - central.objective).abs()
            <= 1e-9 * central.objective.abs().max(1.0),
        "K=1 sharded {} vs centralized {}",
        sharded.objective,
        central.objective
    );
}

#[test]
fn sharded_runs_are_deterministic_for_fixed_spec_seed_and_staleness() {
    // K ∈ {2, 4} (plus the CI matrix value): repeat runs over the same
    // (spec, seed, S) must agree bit for bit — the staleness protocol is
    // exact-lag, so no thread interleaving can perturb the arithmetic —
    // and the engine worker knob (cost telemetry only) must not matter
    for k in [2usize, 4, test_shards()] {
        for s in [0usize, 2] {
            // 4 versions → 4 single-class sessions, so K=4 deploys a real
            // 4-way partition instead of clamping
            let build = |workers: usize| {
                Scenario::paper_default()
                    .nodes(10)
                    .link_probability(0.3)
                    .versions(4)
                    .seed(29)
                    .workers(workers)
                    .shards(k)
                    .staleness(s)
                    .build()
                    .unwrap()
            };
            let run = |workers: usize| {
                let session = build(workers);
                let mut traj = Trajectory::default();
                let report =
                    session.sharded_run(10).unwrap().observe(&mut traj).finish();
                report
                    .phi
                    .as_ref()
                    .unwrap()
                    .is_feasible(&session.problem.net, 1e-9)
                    .unwrap();
                (traj.values, report)
            };
            let (t1, r1) = run(1);
            let (t2, r2) = run(1);
            assert_eq!(t1.len(), t2.len());
            for (i, (a, b)) in t1.iter().zip(&t2).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "K={k} S={s} iter {i}");
            }
            assert_eq!(r1.objective.to_bits(), r2.objective.to_bits(), "K={k} S={s}");
            assert_phi_bits_eq(&r1, &r2, "repeat run");
            for workers in [4usize, test_workers()] {
                let (tw, rw) = run(workers);
                for (i, (a, b)) in tw.iter().zip(&t1).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(), "K={k} S={s} iter {i} w={workers}");
                }
                assert_eq!(rw.objective.to_bits(), r1.objective.to_bits());
                assert_phi_bits_eq(&rw, &r1, "worker sweep");
            }
            // the run made progress on finite costs
            assert!(t1.iter().all(|c| c.is_finite()), "K={k} S={s}");
            assert!(r1.objective < t1[0], "K={k} S={s}: no descent");
        }
    }
}

#[test]
fn s0_sharded_rounds_track_centralized_omd_within_tolerance() {
    // S = 0 prices every shard against the same-round global flows — the
    // centralized gradient up to summation association — so a fixed-step
    // sharded run tracks the fixed-step centralized router to 1e-9
    let session = session_for(2, 0, test_workers());
    let problem = &session.problem;
    let lam = session.uniform_allocation();
    let rounds = 10;
    let eta = 0.05;
    let mut straj = Trajectory::default();
    let sharded = RoutingRun::new(
        problem,
        Box::new(ShardedOmd::fixed(eta, 2, 0)),
        lam.clone(),
        rounds,
    )
    .observe(&mut straj)
    .finish();
    let mut ctraj = Trajectory::default();
    let central =
        RoutingRun::new(problem, Box::new(OmdRouter::fixed(eta)), lam, rounds)
            .observe(&mut ctraj)
            .finish();
    assert_eq!(straj.values.len(), ctraj.values.len());
    for (i, (a, b)) in straj.values.iter().zip(&ctraj.values).enumerate() {
        assert!(
            (a - b).abs() <= 1e-9 * b.abs().max(1.0),
            "iter {i}: sharded {a} vs centralized {b}"
        );
    }
    assert!(
        (sharded.objective - central.objective).abs()
            <= 1e-9 * central.objective.abs().max(1.0),
        "final: sharded {} vs centralized {}",
        sharded.objective,
        central.objective
    );
    let (sp, cp) = (sharded.phi.as_ref().unwrap(), central.phi.as_ref().unwrap());
    for (ra, rb) in sp.frac.iter().zip(&cp.frac) {
        for (a, b) in ra.iter().zip(rb) {
            assert!((a - b).abs() <= 1e-9, "phi: {a} vs {b}");
        }
    }
}

#[test]
fn violated_staleness_bound_is_a_typed_error_not_a_hang() {
    // a transport that drops every delta: the sync must give up at the
    // timeout and surface the typed fault, leaving φ untouched
    let session = session_for(2, 1, 1);
    let problem = &session.problem;
    let lam = session.uniform_allocation();
    let mut router = ShardedOmd::new(0.2, 2, 1)
        .with_transport(Arc::new(Blackhole::new(2)))
        .with_sync_timeout(Duration::from_millis(50));
    let mut phi = Phi::uniform(&problem.net);
    let before = phi.clone();
    let t0 = std::time::Instant::now();
    let err = router.try_step(problem, &lam, &mut phi).unwrap_err();
    assert!(t0.elapsed() < Duration::from_secs(5), "sync did not give up at the timeout");
    match &err {
        SessionError::StalenessExceeded { shard, round, bound } => {
            assert!(*shard < 2);
            assert_eq!(*round, 0);
            assert_eq!(*bound, 1);
        }
        other => panic!("expected StalenessExceeded, got {other:?}"),
    }
    let msg = String::from(err);
    assert!(msg.contains("staleness"), "{msg}");
    assert_eq!(phi, before, "a failed round must not leak partial φ updates");

    // the infallible Router protocol parks the same fault instead of
    // panicking or hanging: φ still untouched, pre-update cost returned
    let cost = router.step(problem, &lam, &mut phi);
    assert!(cost.is_finite(), "step reports the last evaluated cost");
    assert!(matches!(
        router.fault(),
        Some(SessionError::StalenessExceeded { .. })
    ));
    assert_eq!(phi, before);
}

#[test]
fn multi_class_sharded_runs_use_the_even_split_and_stay_deterministic() {
    // class-major layouts interleave the version blocks, so the partition
    // falls back to the even contiguous split — pin that path end to end
    let build = || {
        Scenario::paper_default()
            .nodes(10)
            .link_probability(0.35)
            .versions(2)
            .seed(23)
            .workers(test_workers())
            .shards(2)
            .staleness(1)
            .class("alpha", "log", 30.0, &[])
            .class("beta", "linear", 20.0, &[3, 7])
            .build()
            .unwrap()
    };
    let session = build();
    assert_eq!(session.problem.n_sessions(), 4, "two classes × two versions");
    let mut t1 = Trajectory::default();
    let r1 = session.sharded_run(8).unwrap().observe(&mut t1).finish();
    let mut t2 = Trajectory::default();
    let r2 = build().sharded_run(8).unwrap().observe(&mut t2).finish();
    for (i, (a, b)) in t1.values.iter().zip(&t2.values).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "iter {i}");
    }
    assert_eq!(r1.objective.to_bits(), r2.objective.to_bits());
    assert!(r1.objective.is_finite());
    assert!(r1.objective < t1.values[0], "no descent on the multi-class fleet");
    r1.phi.as_ref().unwrap().is_feasible(&session.problem.net, 1e-9).unwrap();
}

#[test]
fn sharded_reports_carry_per_shard_comm_stats() {
    let session = session_for(2, 1, 1);
    let report = session.sharded_run(5).unwrap().finish();
    assert_eq!(report.algo, "sharded-omd");
    let n = report.iterations as u64;
    assert!(n >= 2, "need at least two rounds to observe staleness");
    let comm = report.comm.expect("sharded runs report CommStats");
    assert_eq!(comm.rounds, report.iterations);
    assert_eq!(comm.shards.len(), 2, "per-shard breakdown");
    // each shard gossips exactly one delta per peer per round
    assert_eq!(comm.messages, 2 * n);
    assert!(comm.bytes > 0);
    // S = 1: every round past the first prices against lagged peers
    assert_eq!(comm.stale_rounds(), 2 * (n - 1));
}
