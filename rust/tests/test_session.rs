//! Integration: the unified session API.
//!
//! Covers registry lookup (known + unknown names), `Scenario` builder
//! validation, and — the load-bearing guarantee — that streaming
//! `Run::step()`-driven execution reproduces the legacy `Router::solve` /
//! `Allocator::run` loops bit for bit on seeded problems, both cold and
//! warm-started.

use std::ops::ControlFlow;

use jowr::allocation::AnalyticOracle;
use jowr::model::flow::Phi;
use jowr::prelude::*;

fn small_session() -> Session {
    Scenario::paper_default().nodes(12).seed(7).build().unwrap()
}

#[test]
fn registry_lists_all_paper_algorithms() {
    for name in ["omd", "omd-fixed", "sgp", "gp", "opt"] {
        let r = registry::router(name).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(!r.name().is_empty());
        let entry = registry::router_entry(name).unwrap();
        assert!(!entry.description.is_empty());
    }
    for name in ["gsoma", "omad"] {
        let a = registry::allocator(name).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(!a.name().is_empty());
    }
}

#[test]
fn registry_unknown_names_are_errors_with_suggestions() {
    let err = registry::router("omd2").unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("omd2") && msg.contains("sgp"), "{msg}");
    let err = registry::allocator("gs-oma").unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("gs-oma") && msg.contains("gsoma"), "{msg}");
}

#[test]
fn scenario_builder_validates_everything() {
    assert!(Scenario::paper_default().build().is_ok());
    assert!(Scenario::paper_default().topology("nope").build().is_err());
    assert!(Scenario::paper_default().utility("nope").build().is_err());
    assert!(Scenario::paper_default().cost_named("nope").build().is_err());
    assert!(Scenario::paper_default().versions(0).build().is_err());
    assert!(Scenario::paper_default().rate(-1.0).build().is_err());
    assert!(Scenario::paper_default().link_probability(2.0).build().is_err());
    assert!(Scenario::paper_default().eta_routing(-0.5).build().is_err());
    assert!(Scenario::paper_default().delta(40.0).build().is_err());
}

#[test]
fn every_router_runs_by_name_through_the_session() {
    let session = small_session();
    for name in registry::router_names() {
        let report = session
            .routing_run(name, 5)
            .unwrap_or_else(|e| panic!("{name}: {e}"))
            .finish();
        assert!(report.objective.is_finite(), "{name}");
        assert!(report.iterations >= 1 && report.iterations <= 5, "{name}");
        let phi = report.phi.expect("routing runs expose phi");
        phi.is_feasible(&session.problem.net, 1e-9).unwrap_or_else(|e| panic!("{name}: {e}"));
    }
}

#[test]
fn both_allocators_run_by_name_through_the_session() {
    let session = Scenario::paper_default().nodes(8).seed(3).build().unwrap();
    for name in registry::allocator_names() {
        let report = session
            .allocation_run(name, 4)
            .unwrap_or_else(|e| panic!("{name}: {e}"))
            .finish();
        assert!(report.objective.is_finite(), "{name}");
        let total: f64 = report.lam.iter().sum();
        assert!((total - session.cfg.total_rate).abs() < 1e-6, "{name}: {total}");
    }
}

#[test]
fn streaming_routing_run_matches_solver_solve_bit_for_bit() {
    let session = small_session();
    let lam = session.uniform_allocation();

    // solver-internal path: Router::solve from the uniform initializer
    // (returns a RunReport directly — the legacy RoutingState is gone)
    let mut solve_router = OmdRouter::new(session.cfg.eta_routing);
    let solved = solve_router.solve(&session.problem, &lam, 40);

    // session path: streaming run + trajectory observer
    let mut traj = Trajectory::default();
    let report = session.routing_run("omd", 40).unwrap().observe(&mut traj).finish();

    assert_eq!(report.iterations, solved.iterations);
    assert_eq!(report.objective.to_bits(), solved.objective.to_bits());
    assert_eq!(report.stop, solved.stop);
    assert_eq!(traj.values.len(), report.iterations + 1, "per-iter costs + final");
    assert_eq!(traj.values.last().unwrap().to_bits(), solved.objective.to_bits());
    let phi = report.phi.unwrap();
    let solved_phi = solved.phi.unwrap();
    for (ra, rb) in phi.frac.iter().zip(&solved_phi.frac) {
        for (a, b) in ra.iter().zip(rb) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    // and streaming runs are fully deterministic: a second run reproduces
    // the trajectory bit for bit
    let mut traj2 = Trajectory::default();
    session.routing_run("omd", 40).unwrap().observe(&mut traj2).finish();
    assert_eq!(traj.values.len(), traj2.values.len());
    for (i, (a, b)) in traj.values.iter().zip(&traj2.values).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "trajectory diverged at {i}: {a} vs {b}");
    }
}

#[test]
fn warm_started_run_matches_solver_solve_from_bit_for_bit() {
    let session = small_session();
    let lam = session.uniform_allocation();

    // evolve a warm routing state through the session API
    let warm_report = session.routing_run("omd", 15).unwrap().finish();
    let warm = warm_report.final_phi().unwrap().clone();

    // solver continuation: fresh router, warm phi
    let mut phi_solver = warm.clone();
    let mut solve_router = OmdRouter::new(session.cfg.eta_routing);
    let solved = solve_router.solve_from(&session.problem, &lam, &mut phi_solver, 25);

    // streaming continuation: fresh router, same warm phi (via the
    // RunReport-based hand-off)
    let report = session
        .routing_run("omd", 25)
        .unwrap()
        .warm_start_from(&warm_report)
        .finish();

    assert_eq!(report.iterations, solved.iterations);
    assert_eq!(report.objective.to_bits(), solved.objective.to_bits());
}

#[test]
fn streaming_allocation_run_matches_allocator_run_bit_for_bit() {
    let session = Scenario::paper_default().nodes(8).seed(5).build().unwrap();

    // solver-internal path: Allocator::run against a fresh analytic oracle
    // (returns a RunReport directly — the legacy AllocationState is gone)
    let mut oracle = AnalyticOracle::new(session.problem.clone(), session.utilities().unwrap());
    oracle.router_eta = session.cfg.eta_routing;
    let mut alg = GsOma::new(session.cfg.delta, session.cfg.eta_alloc);
    let solved = alg.run(&mut oracle, 8);

    // session path: the oracle/allocator pair is wired by name
    let mut traj = Trajectory::default();
    let report = session.allocation_run("gsoma", 8).unwrap().observe(&mut traj).finish();

    assert_eq!(report.iterations, solved.iterations);
    assert_eq!(report.routing_iterations, solved.routing_iterations);
    assert_eq!(report.objective.to_bits(), solved.objective.to_bits());
    assert_eq!(traj.values.len(), report.iterations + 1);
    assert_eq!(traj.values.last().unwrap().to_bits(), solved.objective.to_bits());
    for (a, b) in report.lam.iter().zip(&solved.lam) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

#[test]
fn step_returns_continue_until_a_stop_rule_fires() {
    let session = small_session();
    let mut run = session.routing_run("omd", 6).unwrap();
    let mut continues = 0;
    let report = loop {
        match run.step() {
            ControlFlow::Continue(()) => continues += 1,
            ControlFlow::Break(report) => break report,
        }
    };
    assert_eq!(continues, report.iterations - 1, "the stopping step is included");
    // stepping a finished run re-reports without advancing
    let again = match run.step() {
        ControlFlow::Break(r) => r,
        ControlFlow::Continue(()) => panic!("finished run must not continue"),
    };
    assert_eq!(again.iterations, report.iterations);
}

#[test]
fn stop_rules_fire_with_the_right_reason() {
    let session = small_session();
    // iteration budget
    let r = session.routing_run("omd", 3).unwrap().finish();
    assert_eq!(r.stop, StopReason::MaxIters);
    assert_eq!(r.iterations, 3);
    // convergence (generous budget, adaptive OMD stalls out)
    let r = session.routing_run("omd", 100_000).unwrap().finish();
    assert_eq!(r.stop, StopReason::Converged);
    assert!(r.iterations < 100_000);
    // wall-clock deadline beats the iteration budget
    let r = session.routing_run("omd", 1_000_000).unwrap().deadline(0.0).finish();
    assert_eq!(r.stop, StopReason::Deadline);
    assert_eq!(r.iterations, 1);
}

#[test]
fn zero_iteration_budget_matches_solver_semantics() {
    let session = small_session();
    let lam = session.uniform_allocation();
    // solve(.., 0): zero iterations, objective = cost at the initializer
    let solved = OmdRouter::new(session.cfg.eta_routing).solve(&session.problem, &lam, 0);
    let mut traj = Trajectory::default();
    let report = session.routing_run("omd", 0).unwrap().observe(&mut traj).finish();
    assert_eq!(report.iterations, 0);
    assert_eq!(report.stop, StopReason::MaxIters);
    assert_eq!(solved.iterations, 0);
    assert_eq!(solved.stop, StopReason::MaxIters);
    assert_eq!(traj.values.len(), 1, "only the final (initial-state) cost");
    assert_eq!(traj.values[0].to_bits(), solved.objective.to_bits());
    assert_eq!(report.objective.to_bits(), solved.objective.to_bits());
}

#[test]
fn opt_through_the_registry_matches_the_direct_solver() {
    let session = Scenario::paper_default().nodes(10).seed(1).build().unwrap();
    let lam = session.uniform_allocation();
    let direct = OptRouter::new().solve(&session.problem, &lam);
    let report = session.routing_run("opt", 3).unwrap().finish();
    let rel = (report.objective - direct.cost).abs() / direct.cost.abs().max(1.0);
    assert!(rel < 1e-6, "registry OPT {} vs direct {}", report.objective, direct.cost);
    // the full solve happens in one step; the second detects the fixed point
    assert!(report.iterations <= 2, "{}", report.iterations);
}

#[test]
fn observers_see_every_step_and_the_finish() {
    struct Counter {
        steps: usize,
        finished: usize,
        last_iter: usize,
    }
    impl Observer for Counter {
        fn on_step(&mut self, info: &StepInfo<'_>) {
            self.steps += 1;
            self.last_iter = info.iter;
            assert!(info.objective.is_finite());
            assert!(info.moved >= 0.0);
        }
        fn on_finish(&mut self, report: &RunReport) {
            self.finished += 1;
            assert_eq!(self.last_iter, report.iterations);
        }
    }
    let session = small_session();
    let mut counter = Counter { steps: 0, finished: 0, last_iter: 0 };
    let report = session.routing_run("sgp", 5).unwrap().observe(&mut counter).finish();
    assert_eq!(counter.steps, report.iterations);
    assert_eq!(counter.finished, 1);
}

#[test]
fn allocation_run_exposes_phi_for_single_loop_oracles() {
    let session = Scenario::paper_default().nodes(8).seed(2).build().unwrap();
    let report = session.allocation_run("omad", 3).unwrap().finish();
    let phi: Phi = report.phi.expect("single-step oracle keeps a persistent phi");
    phi.is_feasible(&session.problem.net, 1e-9).unwrap();
    // the nested-loop oracle re-solves from scratch per observation and
    // keeps no persistent routing state
    let report = session.allocation_run("gsoma", 3).unwrap().finish();
    assert!(report.phi.is_none());
}
