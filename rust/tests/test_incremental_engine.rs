//! Property tests for [`jowr::engine::FlowEngine`]'s incremental
//! dirty-session path: after **any** sequence of λ-block perturbations,
//! φ-row perturbations, sparse masks, and forward-only evaluations, the
//! delta-evaluated engine state (rates, per-session flows, total flows,
//! cost, `D'`, node marginals) must be **bit-identical** to a fresh full
//! `prepare` at the same operating point — in every batch mode, at any
//! worker count, for single- and multi-class problems.

use jowr::engine::{BatchMode, FlowEngine, SessionMask};
use jowr::graph::augmented::{AugmentedNet, Placement};
use jowr::graph::topologies;
use jowr::model::cost::CostKind;
use jowr::model::flow::Phi;
use jowr::model::{Problem, Workload};
use jowr::util::rng::Rng;

/// A heterogeneous multi-class problem (`classes` blocks over 3 versions).
fn multi_problem(seed: u64, n: usize, classes: usize) -> Problem {
    let mut rng = Rng::seed_from(seed);
    let g = topologies::connected_er_graph(n, 0.3, 10.0, &mut rng);
    let pl = Placement::random(n, 3, &mut rng);
    let mut class_sources: Vec<Vec<usize>> = vec![pl.hosts(0).collect()];
    for c in 1..classes {
        class_sources.push(vec![c % n, (3 * c + 1) % n]);
    }
    let net = AugmentedNet::build_heterogeneous(&g, &pl, 10.0, &[], &class_sources, &mut rng);
    let workload = Workload {
        class_names: (0..classes).map(|c| format!("c{c}")).collect(),
        class_rates: vec![20.0; classes],
        class_spans: (0..classes).map(|c| (3 * c, 3 * (c + 1))).collect(),
    };
    Problem::with_workload(net, CostKind::Exp, workload)
}

fn single_problem(seed: u64, n: usize) -> Problem {
    let mut rng = Rng::seed_from(seed);
    let net = topologies::connected_er(n, 0.3, 3, &mut rng);
    Problem::new(net, 60.0, CostKind::Exp)
}

/// Assert the incremental engine's full readable state equals a fresh
/// engine's full `prepare` at the same `(φ, Λ)`, bit for bit.
fn assert_matches_full(tag: &str, problem: &Problem, phi: &Phi, lam: &[f64], eng: &FlowEngine) {
    let mut fresh = FlowEngine::new();
    let cost = fresh.prepare(problem, phi, lam);
    assert_eq!(eng.cost().to_bits(), cost.to_bits(), "{tag}: cost");
    for (e, (a, b)) in eng.flows().iter().zip(fresh.flows()).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{tag}: flows[{e}]");
    }
    for (e, (a, b)) in eng.dprime().iter().zip(fresh.dprime()).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{tag}: dprime[{e}]");
    }
    for w in 0..problem.n_sessions() {
        for (i, (a, b)) in eng.rates(w).iter().zip(fresh.rates(w)).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{tag}: t[{w}][{i}]");
        }
        for (i, (a, b)) in eng.marginals(w).iter().zip(fresh.marginals(w)).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{tag}: r[{w}][{i}]");
        }
    }
}

/// Shift mass between the first two lanes of some multi-lane row of
/// session `s` (keeps φ feasible).
fn perturb_phi_row(problem: &Problem, phi: &mut Phi, s: usize, rng: &mut Rng) {
    let csr = &problem.net.csr;
    let rows: Vec<_> = csr.rows(s).iter().filter(|r| r.len() >= 2).collect();
    if rows.is_empty() {
        return;
    }
    let row = rows[rng.below(rows.len())];
    let (e0, e1) = (csr.lane_edge[row.start], csr.lane_edge[row.start + 1]);
    let shift = rng.uniform(0.0, phi.frac[s][e0]);
    phi.frac[s][e0] -= shift;
    phi.frac[s][e1] += shift;
}

/// Drive one problem through a randomized dirty sequence.
fn run_sequence(problem: &Problem, seed: u64, mode: BatchMode, workers: usize) {
    let n_sess = problem.n_sessions();
    let blocks = problem.workload.blocks();
    let mut rng = Rng::seed_from(seed);
    let mut phi = Phi::uniform(&problem.net);
    let mut lam = problem.uniform_allocation();
    let mut eng = FlowEngine::new().with_batch_mode(mode).with_workers(workers);
    eng.prepare(problem, &phi, &lam);
    for step in 0..16 {
        let tag = format!("mode={mode:?} workers={workers} seed={seed} step={step}");
        let roll = rng.uniform(0.0, 1.0);
        if roll < 0.35 {
            // λ perturbation of one class block
            let (s0, s1, _rate) = blocks[rng.below(blocks.len())];
            let dirty = SessionMask::block(n_sess, s0, s1);
            for l in &mut lam[s0..s1] {
                *l = (*l + rng.uniform(-2.0, 2.0)).max(0.0);
            }
            eng.prepare_dirty(problem, &phi, &lam, &dirty);
            assert_matches_full(&tag, problem, &phi, &lam, &eng);
        } else if roll < 0.6 {
            // φ row perturbation of one session
            let s = rng.below(n_sess);
            let mut dirty = SessionMask::none(n_sess);
            dirty.insert(s);
            perturb_phi_row(problem, &mut phi, s, &mut rng);
            eng.prepare_dirty(problem, &phi, &lam, &dirty);
            assert_matches_full(&tag, problem, &phi, &lam, &eng);
        } else if roll < 0.75 {
            // sparse mask mixing λ and φ changes (possibly empty)
            let mut dirty = SessionMask::none(n_sess);
            for s in 0..n_sess {
                if rng.uniform(0.0, 1.0) < 0.3 {
                    dirty.insert(s);
                    lam[s] = (lam[s] + rng.uniform(-1.0, 1.0)).max(0.0);
                    perturb_phi_row(problem, &mut phi, s, &mut rng);
                }
            }
            eng.prepare_dirty(problem, &phi, &lam, &dirty);
            assert_matches_full(&tag, problem, &phi, &lam, &eng);
        } else if roll < 0.9 {
            // forward-only delta observation (what oracles do), then a
            // dirty prepare straddling the stale-marginal state
            let (s0, s1, _rate) = blocks[rng.below(blocks.len())];
            let dirty = SessionMask::block(n_sess, s0, s1);
            for l in &mut lam[s0..s1] {
                *l = (*l + rng.uniform(-1.0, 1.0)).max(0.0);
            }
            let cost = eng.evaluate_cost_dirty(problem, &phi, &lam, &dirty);
            let full = FlowEngine::new().evaluate_cost(problem, &phi, &lam);
            assert_eq!(cost.to_bits(), full.to_bits(), "{tag}: forward-only cost");
            let dirty2 = SessionMask::none(n_sess);
            eng.prepare_dirty(problem, &phi, &lam, &dirty2);
            assert_matches_full(&tag, problem, &phi, &lam, &eng);
        } else {
            // full-mask call degrades to an ordinary prepare
            let dirty = SessionMask::all(n_sess);
            for l in lam.iter_mut() {
                *l = (*l + rng.uniform(-0.5, 0.5)).max(0.0);
            }
            eng.prepare_dirty(problem, &phi, &lam, &dirty);
            assert_matches_full(&tag, problem, &phi, &lam, &eng);
        }
    }
}

#[test]
fn randomized_dirty_sequences_match_full_sweeps_multi_class() {
    for seed in [1u64, 2, 3] {
        let p = multi_problem(seed, 12, 3);
        run_sequence(&p, seed, BatchMode::Auto, 1);
    }
}

#[test]
fn randomized_dirty_sequences_match_full_sweeps_single_class() {
    for seed in [4u64, 5] {
        let p = single_problem(seed, 12);
        run_sequence(&p, seed, BatchMode::Auto, 1);
    }
}

#[test]
fn dirty_sequences_match_in_every_batch_mode_and_worker_count() {
    let p = multi_problem(6, 12, 2);
    for mode in [BatchMode::Auto, BatchMode::Batched, BatchMode::Scalar] {
        for workers in [1usize, 4, jowr::testkit::test_workers()] {
            run_sequence(&p, 7, mode, workers);
        }
    }
}

#[test]
fn dirty_call_on_cold_engine_falls_back_to_full_sweep() {
    let p = multi_problem(8, 10, 2);
    let phi = Phi::uniform(&p.net);
    let lam = p.uniform_allocation();
    let mut eng = FlowEngine::new();
    // never prepared: the delta entry points must produce full results
    let dirty = SessionMask::block(p.n_sessions(), 0, 3);
    eng.prepare_dirty(&p, &phi, &lam, &dirty);
    assert_matches_full("cold", &p, &phi, &lam, &eng);
    let mut eng2 = FlowEngine::new();
    let c = eng2.evaluate_cost_dirty(&p, &phi, &lam, &dirty);
    let full = FlowEngine::new().evaluate_cost(&p, &phi, &lam);
    assert_eq!(c.to_bits(), full.to_bits());
}

#[test]
fn dirty_path_survives_topology_swap_via_invalidate() {
    // same-shape problem swap requires invalidate(); the next dirty call
    // then falls back to a full sweep on the new problem
    let p1 = multi_problem(9, 10, 2);
    let p2 = multi_problem(10, 10, 2);
    assert_eq!(p1.net.n_nodes(), p2.net.n_nodes());
    assert_eq!(p1.n_sessions(), p2.n_sessions());
    let phi1 = Phi::uniform(&p1.net);
    let phi2 = Phi::uniform(&p2.net);
    let lam = p1.uniform_allocation();
    let mut eng = FlowEngine::new();
    eng.prepare(&p1, &phi1, &lam);
    eng.invalidate();
    let dirty = SessionMask::none(p2.n_sessions());
    eng.prepare_dirty(&p2, &phi2, &lam, &dirty);
    assert_matches_full("swap", &p2, &phi2, &lam, &eng);
}

#[test]
fn single_step_oracle_dirty_observations_bit_identical_to_full() {
    use jowr::allocation::gsoma::perturb_block;
    use jowr::allocation::{SingleStepOracle, UtilityOracle};
    use jowr::model::utility::family;

    let p = multi_problem(11, 10, 2);
    let utilities: Vec<_> = p
        .workload
        .blocks()
        .iter()
        .flat_map(|&(_s0, _s1, rate)| family("log", 3, rate).unwrap())
        .collect();
    let mut full = SingleStepOracle::new(p.clone(), utilities.clone(), 0.4);
    let mut delta = SingleStepOracle::new(p.clone(), utilities, 0.4);
    let blocks = p.workload.blocks();
    let base = p.uniform_allocation();
    // both oracles see the identical probe sequence; one observes fully,
    // the other through per-block dirty masks — values and the persistent
    // routing state must stay bit-identical throughout
    let mut prev: Option<Vec<f64>> = None;
    for round in 0..6 {
        for &(s0, s1, rate) in &blocks {
            for w in s0..s1 {
                let d = if round % 2 == 0 { 0.4 } else { -0.4 };
                let probe = perturb_block(&base, s0, s1, w, d, rate);
                let u_full = full.observe(&probe);
                let u_delta = match &prev {
                    None => delta.observe(&probe),
                    Some(last) => {
                        delta.observe_dirty(&probe, &SessionMask::from_diff(last, &probe))
                    }
                };
                assert_eq!(
                    u_full.to_bits(),
                    u_delta.to_bits(),
                    "round={round} w={w}: dirty observation diverged"
                );
                prev = Some(probe);
            }
        }
    }
    for (ra, rb) in full.phi().frac.iter().zip(&delta.phi().frac) {
        for (a, b) in ra.iter().zip(rb) {
            assert_eq!(a.to_bits(), b.to_bits(), "persistent φ diverged");
        }
    }
}

#[test]
fn omad_with_dirty_plumbing_matches_manual_full_observation_loop() {
    use jowr::allocation::gsoma::perturb_block;
    use jowr::allocation::omad::Omad;
    use jowr::allocation::{Allocator, SingleStepOracle, UtilityOracle};
    use jowr::model::utility::family;

    let p = multi_problem(12, 10, 2);
    let utilities: Vec<_> = p
        .workload
        .blocks()
        .iter()
        .flat_map(|&(_s0, _s1, rate)| family("log", 3, rate).unwrap())
        .collect();
    let alg = Omad::new(0.4, 0.05);
    let blocks = p.workload.blocks();

    // the production path (observe_probe → observe_dirty inside)
    let mut oracle = SingleStepOracle::new(p.clone(), utilities.clone(), 0.4);
    let mut lam = p.uniform_allocation();
    for _ in 0..4 {
        let _ = oracle.observe(&lam);
        let (next, _grad) = alg.outer_step(&mut oracle, &lam);
        lam = next;
    }

    // a manual replica of the pre-PR-5 loop: identical probe sequence,
    // plain full observations
    let mut ref_oracle = SingleStepOracle::new(p.clone(), utilities, 0.4);
    let mut ref_lam = p.uniform_allocation();
    for _ in 0..4 {
        let _ = ref_oracle.observe(&ref_lam);
        let mut grad = vec![0.0; ref_lam.len()];
        for &(s0, s1, rate) in &blocks {
            for w in s0..s1 {
                let up = perturb_block(&ref_lam, s0, s1, w, alg.delta, rate);
                let dn = perturb_block(&ref_lam, s0, s1, w, -alg.delta, rate);
                let u_plus = ref_oracle.observe(&up);
                let u_minus = ref_oracle.observe(&dn);
                grad[w] = (u_plus - u_minus) / (2.0 * alg.delta);
            }
        }
        let mut next = ref_lam.clone();
        for &(s0, s1, rate) in &blocks {
            jowr::allocation::mirror_ascent_update(
                &mut next[s0..s1],
                &grad[s0..s1],
                alg.eta_outer,
                rate,
            );
            let proj = jowr::allocation::project::project_capped_simplex(
                &next[s0..s1],
                rate,
                alg.delta,
                rate - alg.delta,
            );
            next[s0..s1].copy_from_slice(&proj);
        }
        ref_lam = next;
    }
    for (a, b) in lam.iter().zip(&ref_lam) {
        assert_eq!(a.to_bits(), b.to_bits(), "OMAD iterate diverged: {lam:?} vs {ref_lam:?}");
    }
}
