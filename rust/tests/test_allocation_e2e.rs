//! Integration: GS-OMA / OMAD against the ground-truth optimum computed by
//! brute-force grid search over the allocation simplex (the utility
//! functions are known to the *test*, never to the algorithm).

use jowr::allocation::{
    gsoma::GsOma, omad::Omad, Allocator, AnalyticOracle, SingleStepOracle, UtilityOracle,
};
use jowr::model::utility::{family, FAMILIES};
use jowr::prelude::*;
use jowr::routing::Router;
use jowr::util::rng::Rng;

fn mk_problem(seed: u64, n: usize) -> Problem {
    let mut rng = Rng::seed_from(seed);
    let net = topologies::connected_er(n, 0.3, 3, &mut rng);
    Problem::new(net, 60.0, CostKind::Exp)
}

/// Brute-force U(Λ, φ*(Λ)) over a simplex grid (test-side ground truth).
fn grid_optimum(problem: &Problem, fam: &str, step: f64) -> (Vec<f64>, f64) {
    let us = family(fam, 3, problem.total_rate).unwrap();
    let total = problem.total_rate;
    let mut best = (vec![total / 3.0; 3], f64::NEG_INFINITY);
    let mut a = step;
    while a < total - step {
        let mut b = step;
        while a + b < total - step {
            let c = total - a - b;
            let lam = vec![a, b, c];
            let mut router = OmdRouter::new(0.5);
            let sol = router.solve(problem, &lam, 1500);
            let u: f64 =
                lam.iter().zip(&us).map(|(&l, uf)| uf.value(l)).sum::<f64>() - sol.objective;
            if u > best.1 {
                best = (lam, u);
            }
            b += step;
        }
        a += step;
    }
    best
}

#[test]
fn gsoma_reaches_grid_optimum_log() {
    let p = mk_problem(1, 8);
    let (lam_star, u_star) = grid_optimum(&p, "log", 6.0);
    let mut oracle = AnalyticOracle::new(p, family("log", 3, 60.0).unwrap());
    let mut alg = GsOma::new(0.4, 0.06);
    let st = alg.run(&mut oracle, 80);
    let u_final = st.objective;
    assert!(
        u_final >= u_star - 0.05 * u_star.abs().max(1.0),
        "GS-OMA U {} vs grid optimum {} at {:?} (got {:?})",
        u_final,
        u_star,
        lam_star,
        st.lam
    );
}

#[test]
fn omad_reaches_grid_optimum_log() {
    let p = mk_problem(1, 8);
    let (_lam_star, u_star) = grid_optimum(&p, "log", 6.0);
    let mut oracle = SingleStepOracle::new(p, family("log", 3, 60.0).unwrap(), 0.5);
    let mut alg = Omad::new(0.4, 0.06);
    let st = alg.run(&mut oracle, 400);
    let u_final = st.objective;
    assert!(
        u_final >= u_star - 0.05 * u_star.abs().max(1.0),
        "OMAD U {} vs grid optimum {}",
        u_final,
        u_star
    );
}

#[test]
fn every_family_improves_and_respects_constraints() {
    for fam in FAMILIES {
        let p = mk_problem(3, 10);
        let mut probe = AnalyticOracle::new(p.clone(), family(fam, 3, 60.0).unwrap());
        let lam0 = probe.uniform_allocation();
        let first = probe.observe(&lam0);
        let mut oracle = AnalyticOracle::new(p, family(fam, 3, 60.0).unwrap());
        let mut alg = GsOma::new(0.5, 0.05);
        let st = alg.run(&mut oracle, 25);
        let sum: f64 = st.lam.iter().sum();
        assert!((sum - 60.0).abs() < 1e-6, "{fam}: Σλ = {sum}");
        assert!(st.lam.iter().all(|&l| l >= 0.5 - 1e-9), "{fam}: box violated {:?}", st.lam);
        assert!(st.objective >= first - 1e-6, "{fam}: no improvement");
    }
}

#[test]
fn nested_and_single_loop_agree() {
    let p = mk_problem(5, 10);
    let us = family("log", 3, 60.0).unwrap();
    let mut o1 = AnalyticOracle::new(p.clone(), us.clone());
    let st1 = GsOma::new(0.3, 0.06).run(&mut o1, 60);
    let mut o2 = SingleStepOracle::new(p, us, 0.5);
    let st2 = Omad::new(0.3, 0.06).run(&mut o2, 400);
    let (u1, u2) = (st1.objective, st2.objective);
    let rel = (u1 - u2).abs() / u1.abs().max(1.0);
    assert!(rel < 0.03, "nested {u1} vs single {u2}");
    // and single loop is far cheaper in routing iterations
    assert!(o2.routing_iterations() * 5 < o1.routing_iterations());
}

#[test]
fn allocation_shifts_toward_higher_utility_version() {
    // log family gives version 2 the highest marginal utility; with a
    // generous network the optimizer should allocate it the most traffic
    let p = mk_problem(7, 14);
    let mut oracle = AnalyticOracle::new(p, family("log", 3, 60.0).unwrap());
    let mut alg = GsOma::new(0.4, 0.08);
    let st = alg.run(&mut oracle, 60);
    assert!(
        st.lam[2] >= st.lam[0] - 1.0,
        "version 2 should attract at least as much as version 0: {:?}",
        st.lam
    );
}
