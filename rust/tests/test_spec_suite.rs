//! Integration: declarative `ScenarioSpec` scenarios end-to-end through
//! the parallel `Suite` runner — including the committed scenario gallery
//! under `examples/scenarios/` and the acceptance scenario of the spec
//! redesign: a multi-class heterogeneous workload loaded from JSON with
//! ≥2 task classes (distinct utility families) and per-node capacities.

use std::path::Path;

use jowr::model::flow;
use jowr::prelude::*;
use jowr::routing::Router;

fn gallery(name: &str) -> ScenarioSpec {
    let path = Path::new("../examples/scenarios").join(name);
    ScenarioSpec::from_file(&path).unwrap_or_else(|e| panic!("{name}: {e}"))
}

#[test]
fn every_committed_scenario_file_loads_and_builds() {
    for name in ["heterogeneous_star.json", "two_class_er.json", "trace_surge.json"] {
        let spec = gallery(name);
        // full JSON round-trip on the committed files
        let back = ScenarioSpec::from_json(&spec.to_json().to_string())
            .unwrap_or_else(|e| panic!("{name} round-trip: {e}"));
        assert_eq!(back, spec, "{name} round-trip changed the spec");
        let session = spec.build().unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(session.problem.total_rate > 0.0);
    }
}

#[test]
fn multi_class_json_scenario_runs_end_to_end_through_suite() {
    // the acceptance scenario: multi-class + heterogeneous nodes, loaded
    // from JSON (not the builder), run through Suite for routing AND
    // allocation, producing a SuiteReport
    let text = r#"{
        "name": "accept",
        "topology": {"kind": "er", "n_nodes": 12, "p_link": 0.3},
        "n_versions": 2,
        "cap_mean": 10.0,
        "cost": "exp",
        "nodes": [
            {"id": 0, "compute_capacity": 25.0},
            {"id": 3, "compute_capacity": 6.0, "version": 1}
        ],
        "classes": [
            {"name": "video", "utility": "log", "rate": 30.0, "sources": [0, 1]},
            {"name": "audio", "utility": "sqrt", "rate": 14.0, "sources": []}
        ],
        "delta": 0.3,
        "seed": 9
    }"#;
    let spec = ScenarioSpec::from_json(text).unwrap();
    let report = Suite::new()
        .spec("accept", spec)
        .router("omd")
        .router("sgp")
        .allocator("omad")
        .seeds(&[9])
        .iters(12)
        .workers(2)
        .run();
    assert_eq!(report.cells.len(), 3);
    assert_eq!(report.ok_count(), 3, "{:?}", report.cells);
    // routing cells descend and expose a feasible 4-session phi
    for solver in ["omd", "sgp"] {
        let res = report.cell_result("accept", solver).unwrap();
        assert!(res.report.objective.is_finite());
        assert!(
            res.report.objective <= res.trajectory[0] + 1e-9,
            "{solver} did not improve"
        );
        let phi = res.report.phi.as_ref().expect("routing cells expose phi");
        assert_eq!(phi.frac.len(), 4, "2 classes x 2 versions");
    }
    // the allocation cell conserves each class's rate on its own block
    let res = report.cell_result("accept", "omad").unwrap();
    let lam = &res.report.lam;
    assert_eq!(lam.len(), 4);
    assert!((lam[0] + lam[1] - 30.0).abs() < 1e-6, "video block: {lam:?}");
    assert!((lam[2] + lam[3] - 14.0).abs() < 1e-6, "audio block: {lam:?}");
    // CSV + JSON artifacts render
    assert!(report.to_csv().contains("accept"));
    assert!(report.to_json().to_string().contains("trajectory"));
}

#[test]
fn multi_class_flows_match_reference_and_conserve_per_class() {
    // the engine's fused sweeps and the reference flow algebra must agree
    // on multi-class problems exactly like they do on single-class ones
    let session = gallery("two_class_er.json").build().unwrap();
    let p = &session.problem;
    assert_eq!(p.n_sessions(), 6, "2 classes x 3 versions");
    let lam = p.uniform_allocation();
    let phi = jowr::model::flow::Phi::uniform(&p.net);
    let ev = flow::evaluate(p, &phi, &lam);
    let mut eng = FlowEngine::new();
    let cost = eng.prepare(p, &phi, &lam);
    assert!((cost - ev.cost).abs() <= 1e-12 * ev.cost.abs().max(1.0));
    for s in 0..p.n_sessions() {
        // every session delivers its allocation to its version destination
        let d = p.net.dnode(s);
        assert!(
            (ev.t[s][d] - lam[s]).abs() < 1e-9,
            "session {s}: delivered {} vs allocated {}",
            ev.t[s][d],
            lam[s]
        );
        for i in 0..p.net.n_nodes() {
            assert!(
                (eng.node_rate(s, i) - ev.t[s][i]).abs() <= 1e-12,
                "t[{s}][{i}] engine vs reference"
            );
        }
    }
    // and an OMD solve descends with a feasible multi-class phi
    let sol = OmdRouter::new(0.3).solve(p, &lam, 50);
    sol.phi.unwrap().is_feasible(&p.net, 1e-9).unwrap();
    let initial = FlowEngine::new().evaluate_cost(p, &phi, &lam);
    assert!(sol.objective < initial);
}

#[test]
fn trace_scenario_rate_events_fire_in_suite_allocation() {
    let spec = gallery("trace_surge.json");
    // the surge class's trace must compile to two events (t=20, t=40)
    let schedule = spec.events();
    assert_eq!(schedule.fire(20).count(), 1);
    assert_eq!(schedule.fire(40).count(), 1);
    assert_eq!(schedule.fire(0).count(), 0);
    // run the allocation past the first breakpoint: the final Λ sums to
    // the post-event total (steady 20 + surge 35)
    let report =
        Suite::new().spec("surge", spec).allocator("omad").iters(25).workers(1).run();
    assert_eq!(report.ok_count(), 1, "{:?}", report.cells[0].outcome);
    let res = report.cell_result("surge", "omad").unwrap();
    let total: f64 = res.report.lam.iter().sum();
    assert!((total - 55.0).abs() < 1e-6, "Λ sums to {total}, want 55");
}

#[test]
fn per_edge_cost_scenario_prices_links_heterogeneously() {
    let session = gallery("heterogeneous_star.json").build().unwrap();
    let p = &session.problem;
    // the hub-spoke link 0<->1 is queue-priced, the rest exp-priced
    assert_eq!(p.edge_kind(0), CostKind::Queue);
    assert_eq!(p.edge_kind(1), CostKind::Queue);
    assert_eq!(p.edge_kind(2), CostKind::Exp);
    // pinned versions + capacities took effect
    assert_eq!(p.net.placement.version_of[0], 0);
    assert_eq!(p.net.placement.version_of[1], 1);
    assert_eq!(p.net.placement.version_of[2], 2);
    // a routing run on the heterogeneous-cost network descends
    let report = session.routing_run("omd", 30).unwrap().finish();
    assert!(report.objective.is_finite());
}

#[test]
fn suite_seed_grid_is_deterministic_per_seed() {
    let spec = gallery("two_class_er.json");
    let run = |workers: usize| {
        Suite::new()
            .spec("g", spec.clone())
            .router("omd")
            .seeds(&[1, 2])
            .iters(6)
            .workers(workers)
            .run()
    };
    let a = run(1);
    let b = run(2);
    for (ca, cb) in a.cells.iter().zip(&b.cells) {
        let (ra, rb) = (ca.outcome.as_ref().unwrap(), cb.outcome.as_ref().unwrap());
        assert_eq!(ra.report.objective.to_bits(), rb.report.objective.to_bits());
    }
    // different seeds genuinely change the instance
    let r1 = &a.cells[0].outcome.as_ref().unwrap().report;
    let r2 = &a.cells[1].outcome.as_ref().unwrap().report;
    assert_ne!(r1.objective.to_bits(), r2.objective.to_bits());
}
