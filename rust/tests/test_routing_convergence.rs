//! Integration: every routing algorithm reaches the unique optimum
//! (Theorem 3) on every topology family, and the optimality conditions
//! hold at the converged point.

use jowr::model::flow::{self, Phi};
use jowr::prelude::*;
use jowr::routing::marginal;
use jowr::routing::Router;
use jowr::util::rng::Rng;

fn er_problem(seed: u64, n: usize, w: usize) -> Problem {
    let mut rng = Rng::seed_from(seed);
    let net = topologies::connected_er(n, 0.3, w, &mut rng);
    Problem::new(net, 60.0, CostKind::Exp)
}

#[test]
fn omd_sgp_opt_agree_on_er() {
    for seed in [1u64, 2, 3] {
        let p = er_problem(seed, 12, 3);
        let lam = p.uniform_allocation();
        let omd = OmdRouter::new(0.5).solve(&p, &lam, 4000);
        let sgp = SgpRouter::new().solve(&p, &lam, 4000);
        let opt = OptRouter::new().solve(&p, &lam);
        let rel_omd = (omd.objective - opt.cost) / opt.cost;
        let rel_sgp = (sgp.objective - opt.cost) / opt.cost;
        assert!(rel_omd.abs() < 5e-3, "seed {seed}: OMD {} vs OPT {}", omd.objective, opt.cost);
        assert!(rel_sgp.abs() < 5e-3, "seed {seed}: SGP {} vs OPT {}", sgp.objective, opt.cost);
        assert!(omd.objective >= opt.cost - 1e-6, "OPT must lower-bound");
    }
}

#[test]
fn all_named_topologies_converge() {
    for &(name, _n, _e, cbar) in topologies::TABLE2.iter() {
        let mut rng = Rng::seed_from(5);
        let g = topologies::by_name(name, cbar, &mut rng).unwrap();
        let placement =
            jowr::graph::augmented::Placement::random(g.n_nodes(), 3, &mut rng);
        let net = jowr::graph::augmented::AugmentedNet::build(&g, &placement, cbar, &mut rng);
        let p = Problem::new(net, 60.0, CostKind::Exp);
        let lam = p.uniform_allocation();
        let omd = OmdRouter::new(0.5).solve(&p, &lam, 3000);
        let opt = OptRouter::new().solve(&p, &lam);
        let rel = (omd.objective - opt.cost) / opt.cost;
        assert!(rel.abs() < 1e-2, "{name}: OMD {} vs OPT {} (rel {rel})", omd.objective, opt.cost);
        omd.phi.unwrap().is_feasible(&p.net, 1e-9).unwrap();
    }
}

#[test]
fn optimality_conditions_hold_at_convergence() {
    // Theorem 3 eq. (17): on each live row, marginals equal on the support
    // and no unused lane has a strictly smaller marginal.
    let p = er_problem(7, 10, 3);
    let lam = p.uniform_allocation();
    let sol = OmdRouter::new(0.5).solve(&p, &lam, 6000);
    let phi = sol.phi.unwrap();
    let t = flow::node_rates(&p.net, &phi, &lam);
    let flows = flow::edge_flows(&p.net, &phi, &t);
    let m = marginal::compute(&p, &phi, &flows);
    for w in 0..p.n_versions() {
        for &i in p.net.session_routers(w) {
            if t[w][i] < 1e-6 {
                continue;
            }
            let support: Vec<f64> = p
                .net
                .session_out(w, i)
                .filter(|&e| phi.frac[w][e] > 1e-3)
                .map(|e| m.delta(&p.net, w, e))
                .collect();
            if support.len() < 2 {
                continue;
            }
            let hi = support.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let lo = support.iter().cloned().fold(f64::INFINITY, f64::min);
            assert!(
                hi - lo < 0.03 * hi.max(1.0),
                "w={w} i={i}: support marginals not equalized ({support:?})"
            );
            // unused lanes must not be strictly better (within tolerance)
            for e in p.net.session_out(w, i) {
                if phi.frac[w][e] <= 1e-3 {
                    let d = m.delta(&p.net, w, e);
                    assert!(
                        d >= lo - 0.05 * lo.abs().max(1.0),
                        "w={w} i={i}: unused lane {e} has smaller marginal {d} < {lo}"
                    );
                }
            }
        }
    }
}

#[test]
fn cost_families_all_converge() {
    for kind in [CostKind::Exp, CostKind::Queue, CostKind::Linear, CostKind::Cubic] {
        let mut rng = Rng::seed_from(11);
        let net = topologies::connected_er(10, 0.35, 3, &mut rng);
        let p = Problem::new(net, 30.0, kind);
        let lam = p.uniform_allocation();
        let initial = FlowEngine::new().evaluate_cost(&p, &Phi::uniform(&p.net), &lam);
        let sol = OmdRouter::new(0.3).solve(&p, &lam, 2000);
        assert!(sol.objective <= initial + 1e-9, "{kind:?} did not improve");
        let phi = sol.phi.unwrap();
        phi.is_feasible(&p.net, 1e-9).unwrap();
        // conservation regardless of cost family
        let ev = flow::evaluate(&p, &phi, &lam);
        for w in 0..3 {
            assert!((ev.t[w][p.net.dnode(w)] - lam[w]).abs() < 1e-9);
        }
    }
}

#[test]
fn gp_converges_but_slower_than_omd() {
    let p = er_problem(13, 10, 3);
    let lam = p.uniform_allocation();
    let omd = OmdRouter::new(0.5).solve(&p, &lam, 40);
    let gp = GpRouter::new(0.002).solve(&p, &lam, 40);
    assert!(
        omd.objective <= gp.objective + 1e-9,
        "OMD {} vs GP {}",
        omd.objective,
        gp.objective
    );
}

#[test]
fn more_versions_than_three() {
    // W = 4 sessions exercise the generic session machinery
    let p = er_problem(17, 14, 4);
    let lam = p.uniform_allocation();
    let sol = OmdRouter::new(0.5).solve(&p, &lam, 2000);
    let opt = OptRouter::new().solve(&p, &lam);
    let rel = (sol.objective - opt.cost) / opt.cost;
    assert!(rel.abs() < 1e-2, "W=4: OMD {} vs OPT {}", sol.objective, opt.cost);
}
