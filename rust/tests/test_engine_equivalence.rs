//! Property tests pinning [`jowr::engine::FlowEngine`]'s fused sweeps to
//! the reference implementations (`flow::evaluate` + `marginal::compute`).
//!
//! Sweeps topologies (connected-ER, line, star), every [`CostKind`] family,
//! several seeds, and several (Λ, φ) operating points — uniform, skewed,
//! degenerate (a zero-rate session), and mid-descent states evolved by
//! OMD-RT — asserting:
//!
//! * rates `t`, flows `F`, cost, link marginals `D'`, and node marginals
//!   `r` match the reference to 1e-12 (relative), and
//! * engine results are **bit-identical** at 1, 2, and 4 worker threads
//!   (plus the CI matrix's `JOWR_TEST_WORKERS` count) — for the
//!   centralized solvers *and* the distributed message-passing path.

use jowr::engine::FlowEngine;
use jowr::graph::augmented::{AugmentedNet, Placement};
use jowr::graph::topologies;
use jowr::model::cost::CostKind;
use jowr::model::flow::{self, Phi};
use jowr::model::Problem;
use jowr::routing::marginal;
use jowr::routing::omd::OmdRouter;
use jowr::routing::Router;
use jowr::util::rng::Rng;

const COSTS: [CostKind; 4] =
    [CostKind::Exp, CostKind::Queue, CostKind::Linear, CostKind::Cubic];

/// One augmented network per topology family for a given seed.
fn networks(seed: u64) -> Vec<(&'static str, AugmentedNet)> {
    let mut rng = Rng::seed_from(seed);
    let er = topologies::connected_er(12, 0.3, 3, &mut rng);
    let line_graph = topologies::line(9, 10.0, &mut rng);
    let line_pl = Placement::random(9, 3, &mut rng);
    let line = AugmentedNet::build(&line_graph, &line_pl, 10.0, &mut rng);
    let star_graph = topologies::star(9, 10.0, &mut rng);
    let star_pl = Placement::random(9, 3, &mut rng);
    let star = AugmentedNet::build(&star_graph, &star_pl, 10.0, &mut rng);
    vec![("er", er), ("line", line), ("star", star)]
}

/// Allocation variants exercised at every operating point.
fn allocations(total: f64) -> Vec<Vec<f64>> {
    vec![
        vec![total / 3.0; 3],
        vec![total / 2.0, total / 3.0, total / 6.0],
        // degenerate: one session carries everything (zero-rate sweeps)
        vec![total, 0.0, 0.0],
    ]
}

fn assert_close(a: f64, b: f64, what: &str) {
    assert!(
        (a - b).abs() <= 1e-12 * b.abs().max(1.0),
        "{what}: engine {a} vs reference {b}"
    );
}

/// Engine vs reference at one operating point, plus worker bit-identity.
fn check_point(tag: &str, problem: &Problem, phi: &Phi, lam: &[f64]) {
    let net = &problem.net;
    let ev = flow::evaluate(problem, phi, lam);
    let m = marginal::compute(problem, phi, &ev.flows);

    let mut eng = FlowEngine::new();
    let cost = eng.prepare(problem, phi, lam);
    assert_close(cost, ev.cost, &format!("{tag}: cost"));
    for w in 0..net.n_versions() {
        for i in 0..net.n_nodes() {
            assert_close(eng.node_rate(w, i), ev.t[w][i], &format!("{tag}: t[{w}][{i}]"));
            assert_close(eng.node_marginal(w, i), m.r[w][i], &format!("{tag}: r[{w}][{i}]"));
        }
    }
    for e in 0..net.graph.n_edges() {
        assert_close(eng.flows()[e], ev.flows[e], &format!("{tag}: F[{e}]"));
        assert_close(eng.dprime()[e], m.dprime[e], &format!("{tag}: D'[{e}]"));
        assert_close(
            eng.edge_delta(net, 0, e),
            m.delta(net, 0, e),
            &format!("{tag}: delta[{e}]"),
        );
    }

    // bit-identical at 1, 2, and 4 worker threads (and the CI matrix's
    // JOWR_TEST_WORKERS value)
    for workers in [2usize, 4, jowr::testkit::test_workers()] {
        let mut par = FlowEngine::new().with_workers(workers);
        let c = par.prepare(problem, phi, lam);
        assert_eq!(c.to_bits(), cost.to_bits(), "{tag}: cost at {workers} workers");
        for (a, b) in par.flows().iter().zip(eng.flows()) {
            assert_eq!(a.to_bits(), b.to_bits(), "{tag}: flows at {workers} workers");
        }
        for (a, b) in par.dprime().iter().zip(eng.dprime()) {
            assert_eq!(a.to_bits(), b.to_bits(), "{tag}: dprime at {workers} workers");
        }
        for w in 0..net.n_versions() {
            for (a, b) in par.rates(w).iter().zip(eng.rates(w)) {
                assert_eq!(a.to_bits(), b.to_bits(), "{tag}: t at {workers} workers");
            }
            for (a, b) in par.marginals(w).iter().zip(eng.marginals(w)) {
                assert_eq!(a.to_bits(), b.to_bits(), "{tag}: r at {workers} workers");
            }
        }
    }
}

#[test]
fn engine_matches_reference_across_topologies_costs_and_seeds() {
    for seed in [1u64, 5, 11] {
        for (topo, net) in networks(seed) {
            for cost in COSTS {
                let problem = Problem::new(net.clone(), 60.0, cost);
                let phi = Phi::uniform(&problem.net);
                for (k, lam) in allocations(60.0).into_iter().enumerate() {
                    let tag = format!("{topo}/{cost:?}/seed{seed}/lam{k}");
                    check_point(&tag, &problem, &phi, &lam);
                }
            }
        }
    }
}

#[test]
fn engine_matches_reference_mid_descent() {
    // non-uniform φ with near-zero lanes: evolve OMD-RT for a few
    // iterations, re-checking the engine at every visited operating point
    for seed in [2u64, 9] {
        for (topo, net) in networks(seed) {
            let problem = Problem::new(net, 60.0, CostKind::Exp);
            let lam = problem.uniform_allocation();
            let mut phi = Phi::uniform(&problem.net);
            let mut router = OmdRouter::new(0.5);
            for it in 0..8 {
                router.step(&problem, &lam, &mut phi);
                phi.is_feasible(&problem.net, 1e-9).unwrap();
                check_point(&format!("{topo}/seed{seed}/iter{it}"), &problem, &phi, &lam);
            }
        }
    }
}

#[test]
fn engine_backed_router_matches_legacy_four_sweep_step() {
    // the migrated OmdRouter (engine sweeps, CSR rows) must produce the
    // same iterates as the legacy implementation: four reference sweeps +
    // the eq. 22 row update over `session_routers` in node order
    for seed in [3u64, 8] {
        let mut rng = Rng::seed_from(seed);
        let net = topologies::connected_er(12, 0.3, 3, &mut rng);
        let problem = Problem::new(net, 60.0, CostKind::Exp);
        let lam = problem.uniform_allocation();

        let mut phi_engine = Phi::uniform(&problem.net);
        let mut router = OmdRouter::fixed(0.3);

        let mut phi_legacy = phi_engine.clone();
        for it in 0..10 {
            let cost_engine = router.step(&problem, &lam, &mut phi_engine);
            let cost_legacy = legacy_omd_step(&problem, &lam, &mut phi_legacy, 0.3);
            assert_close(cost_engine, cost_legacy, &format!("seed{seed}/iter{it}: cost"));
            for (w, (ra, rb)) in phi_engine.frac.iter().zip(&phi_legacy.frac).enumerate() {
                for (e, (a, b)) in ra.iter().zip(rb).enumerate() {
                    assert_close(*a, *b, &format!("seed{seed}/iter{it}: phi[{w}][{e}]"));
                }
            }
        }
    }
}

/// The pre-engine OMD-RT iteration, verbatim: separate reference sweeps
/// plus the row update over `session_routers` (fixed step, no adaptation).
fn legacy_omd_step(problem: &Problem, lam: &[f64], phi: &mut Phi, eta: f64) -> f64 {
    let net = &problem.net;
    let t = flow::node_rates(net, phi, lam);
    let flows = flow::edge_flows(net, phi, &t);
    let cost_before = flow::total_cost(problem, &flows);
    let m = marginal::compute(problem, phi, &flows);
    for w in 0..net.n_versions() {
        for &i in net.session_routers(w) {
            if t[w][i] <= 0.0 {
                continue;
            }
            let lanes = net.lanes(w, i);
            if lanes.len() < 2 {
                continue;
            }
            let mut row: Vec<f64> = lanes.iter().map(|&e| phi.frac[w][e]).collect();
            let delta: Vec<f64> = lanes.iter().map(|&e| m.delta(net, w, e)).collect();
            OmdRouter::update_row(&mut row, &delta, eta);
            for (&e, &v) in lanes.iter().zip(&row) {
                phi.frac[w][e] = v;
            }
        }
    }
    cost_before
}

#[test]
fn distributed_path_is_bit_identical_across_worker_counts() {
    // the distributed coordinator rides the same engine (leader-side cost
    // telemetry drives the adaptive step size), so its iterates must also
    // be bit-identical at any worker count — per-slot ingress summation
    // makes the message-passing path deterministic
    use jowr::coordinator::leader::DistributedOmd;
    use jowr::session::{RoutingRun, Trajectory};

    let mut rng = Rng::seed_from(6);
    let net = topologies::connected_er(10, 0.3, 3, &mut rng);
    let problem = Problem::new(net, 60.0, CostKind::Exp);
    let lam = problem.uniform_allocation();
    let run_with = |workers: usize| {
        let mut traj = Trajectory::default();
        let report = RoutingRun::new(
            &problem,
            Box::new(DistributedOmd::new(0.5).with_workers(workers)),
            lam.clone(),
            8,
        )
        .observe(&mut traj)
        .finish();
        (traj.values, report.phi.unwrap(), report.objective)
    };
    let (traj1, phi1, cost1) = run_with(1);
    for workers in [2usize, 4, jowr::testkit::test_workers()] {
        let (traj, phi, cost) = run_with(workers);
        assert_eq!(cost.to_bits(), cost1.to_bits(), "final cost at {workers} workers");
        for (i, (a, b)) in traj.iter().zip(&traj1).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "trajectory[{i}] at {workers} workers");
        }
        for (ra, rb) in phi.frac.iter().zip(&phi1.frac) {
            for (a, b) in ra.iter().zip(rb) {
                assert_eq!(a.to_bits(), b.to_bits(), "phi at {workers} workers");
            }
        }
    }
}

#[test]
fn batched_multi_class_engine_matches_reference_and_scalar_bitwise() {
    // heterogeneous multi-class nets route one session per (class,
    // version): the session-batched SoA kernels must match the reference
    // sweeps to 1e-12 and the scalar kernels bit for bit, at every worker
    // count
    use jowr::engine::BatchMode;
    use jowr::model::Workload;

    for seed in [13u64, 21] {
        let mut rng = Rng::seed_from(seed);
        let g = topologies::connected_er_graph(12, 0.3, 10.0, &mut rng);
        let pl = Placement::random(12, 3, &mut rng);
        let class_sources: Vec<Vec<usize>> =
            vec![pl.hosts(0).collect(), vec![2, 5], vec![7]];
        let net =
            AugmentedNet::build_heterogeneous(&g, &pl, 10.0, &[], &class_sources, &mut rng);
        let workload = Workload {
            class_names: vec!["a".into(), "b".into(), "c".into()],
            class_rates: vec![30.0, 20.0, 10.0],
            class_spans: vec![(0, 3), (3, 6), (6, 9)],
        };
        let problem = Problem::with_workload(net, CostKind::Exp, workload);
        let lam = problem.uniform_allocation();
        let mut phi = Phi::uniform(&problem.net);
        let mut router = OmdRouter::fixed(0.3);
        for it in 0..5 {
            let ev = flow::evaluate(&problem, &phi, &lam);
            let m = marginal::compute(&problem, &phi, &ev.flows);
            let mut scalar = FlowEngine::new().with_batch_mode(BatchMode::Scalar);
            let cs = scalar.prepare(&problem, &phi, &lam);
            assert!(
                (cs - ev.cost).abs() <= 1e-12 * ev.cost.abs().max(1.0),
                "seed{seed}/it{it}: scalar cost {cs} vs reference {}",
                ev.cost
            );
            for workers in [1usize, 4, jowr::testkit::test_workers()] {
                let mut batched = FlowEngine::new()
                    .with_batch_mode(BatchMode::Batched)
                    .with_workers(workers);
                let cb = batched.prepare(&problem, &phi, &lam);
                assert_eq!(cb.to_bits(), cs.to_bits(), "seed{seed}/it{it}/w{workers}: cost");
                for (a, b) in batched.flows().iter().zip(scalar.flows()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "seed{seed}/it{it}/w{workers}: F");
                }
                for w in 0..problem.n_sessions() {
                    for (i, (a, b)) in
                        batched.rates(w).iter().zip(scalar.rates(w)).enumerate()
                    {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "seed{seed}/it{it}/w{workers}: t[{w}][{i}]"
                        );
                        assert!(
                            (a - ev.t[w][i]).abs() <= 1e-12,
                            "seed{seed}/it{it}: t[{w}][{i}] vs reference"
                        );
                    }
                    for (i, (a, b)) in
                        batched.marginals(w).iter().zip(scalar.marginals(w)).enumerate()
                    {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "seed{seed}/it{it}/w{workers}: r[{w}][{i}]"
                        );
                        assert!(
                            (a - m.r[w][i]).abs() <= 1e-12,
                            "seed{seed}/it{it}: r[{w}][{i}] vs reference"
                        );
                    }
                }
            }
            // evolve φ off the uniform point through the engine-backed
            // router (Auto mode — batched on this net)
            router.step(&problem, &lam, &mut phi);
            phi.is_feasible(&problem.net, 1e-9).unwrap();
        }
    }
}

#[test]
fn full_solves_agree_between_engine_and_reference_analysis() {
    // a converged engine-backed solve must satisfy the reference-computed
    // stationarity residuals — ties the migrated stack back to eqs. 18–21
    let mut rng = Rng::seed_from(4);
    let net = topologies::connected_er(10, 0.3, 3, &mut rng);
    let problem = Problem::new(net, 60.0, CostKind::Exp);
    let lam = problem.uniform_allocation();
    let sol = OmdRouter::new(0.5).solve(&problem, &lam, 2000);
    let phi = sol.phi.unwrap();
    let ev = flow::evaluate(&problem, &phi, &lam);
    assert_close(sol.objective, ev.cost, "final cost");
    let mut eng = FlowEngine::new().with_workers(4);
    let c = eng.prepare(&problem, &phi, &lam);
    assert_close(c, ev.cost, "engine cost at the solution");
}
