#!/usr/bin/env bash
# Profile-guided-optimization build recipe for the jowr hot path.
#
# Instruments a release build, drives it with the hotpath bench (the
# representative workload: fused sweeps, SIMD kernels, dirty-session
# deltas, row-sparse OMD probe loops), merges the profiles, and rebuilds
# with the profile applied. Requires rustup's llvm-tools (for
# llvm-profdata) next to the stable toolchain:
#
#     rustup component add llvm-tools
#
# Run from the rust/ crate root:
#
#     ci/pgo_build.sh [extra cargo args...]
#
# The optimized binaries land in target/release as usual; re-run the
# bench afterwards to measure the PGO delta:
#
#     cargo bench --bench hotpath --features simd -- --quick
#
# Notes:
# * Results stay bit-identical — PGO only reorders/optimizes codegen; it
#   never changes float semantics (no fast-math is enabled anywhere).
# * The profile directory is scratch state; it is recreated on each run
#   and safe to delete.
set -euo pipefail

cd "$(dirname "$0")/.."

PGO_DIR="${PGO_DIR:-$PWD/target/pgo-profiles}"
rm -rf "$PGO_DIR"
mkdir -p "$PGO_DIR"

# locate llvm-profdata from the active toolchain's llvm-tools component
HOST=$(rustc -vV | sed -n 's/^host: //p')
SYSROOT=$(rustc --print sysroot)
PROFDATA="$SYSROOT/lib/rustlib/$HOST/bin/llvm-profdata"
if [ ! -x "$PROFDATA" ]; then
    echo "error: $PROFDATA not found — run: rustup component add llvm-tools" >&2
    exit 1
fi

echo "=== step 1/3: instrumented build + profiling run (hotpath bench) ==="
RUSTFLAGS="-Cprofile-generate=$PGO_DIR" \
    cargo bench --bench hotpath --features simd "$@" -- --quick

echo "=== step 2/3: merging profiles ==="
"$PROFDATA" merge -o "$PGO_DIR/merged.profdata" "$PGO_DIR"

echo "=== step 3/3: optimized rebuild with the merged profile ==="
RUSTFLAGS="-Cprofile-use=$PGO_DIR/merged.profdata" \
    cargo build --release --features simd "$@"

echo "PGO build complete (profile: $PGO_DIR/merged.profdata)"
