#!/usr/bin/env python3
"""CI bench-regression gate for BENCH_hotpath.json.

Compares the engine rows (bench names containing "engine") of a fresh
``BENCH_hotpath.json`` against the committed baseline and fails (exit 1)
if any row's median regresses by more than ``--tolerance`` (default 20%).
Non-engine rows (the deliberately slow reference sweeps, SGP, the legacy
reconstruction) are reported but never gate.

Bootstrap: the committed baseline starts life as a placeholder with an
empty ``results`` list (this repo has no local Rust toolchain — CI is the
only place the bench runs). While the baseline is empty, the gate passes
and prints instructions: download the ``bench-hotpath`` artifact from the
first green run and commit it as ``rust/ci/BENCH_baseline.json``. Rows
present in only one file are warned about (renames/additions), not failed,
so the gate never blocks intentional bench evolution — refresh the
baseline in the same PR instead.

Usage:
    check_bench_regression.py BASELINE FRESH [--tolerance 0.20] [--filter engine]
"""

from __future__ import annotations

import argparse
import json
import sys


def load_rows(path: str) -> dict[str, float]:
    with open(path, encoding="utf-8") as fh:
        doc = json.load(fh)
    rows = {}
    for row in doc.get("results", []):
        name, median = row.get("name"), row.get("median_s")
        if isinstance(name, str) and isinstance(median, (int, float)) and median > 0:
            rows[name] = float(median)
    return rows


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", help="committed BENCH_baseline.json")
    ap.add_argument("fresh", help="freshly produced BENCH_hotpath.json")
    ap.add_argument("--tolerance", type=float, default=0.20,
                    help="allowed relative slowdown before failing (default 0.20)")
    ap.add_argument("--filter", default="engine",
                    help="substring selecting the gated rows (default 'engine')")
    args = ap.parse_args()

    baseline = load_rows(args.baseline)
    fresh = load_rows(args.fresh)
    if not fresh:
        print(f"error: no usable rows in {args.fresh}", file=sys.stderr)
        return 1
    if not baseline:
        print(f"baseline {args.baseline} is empty (bootstrap mode): gate passes.")
        print("To arm the gate, download this run's 'bench-hotpath' artifact and")
        print("commit it as rust/ci/BENCH_baseline.json.")
        return 0

    gated = sorted(n for n in baseline if args.filter in n)
    regressions, improvements = [], []
    for name in gated:
        if name not in fresh:
            print(f"warn: baseline row '{name}' missing from fresh results "
                  f"(renamed/removed? refresh the baseline)")
            continue
        base, now = baseline[name], fresh[name]
        ratio = now / base
        line = f"{name:<44} {base * 1e6:>10.2f}us -> {now * 1e6:>10.2f}us  ({ratio:5.2f}x)"
        if ratio > 1.0 + args.tolerance:
            regressions.append(line)
        else:
            improvements.append(line)
    for name in sorted(fresh):
        if args.filter in name and name not in baseline:
            print(f"warn: new engine row '{name}' has no baseline yet "
                  f"(commit a refreshed BENCH_baseline.json to gate it)")

    print(f"\nbench gate: {len(gated)} gated rows, tolerance {args.tolerance:.0%}")
    for line in improvements:
        print(f"  ok   {line}")
    for line in regressions:
        print(f"  FAIL {line}")
    if regressions:
        print(f"\n{len(regressions)} engine row(s) regressed more than "
              f"{args.tolerance:.0%} vs the committed baseline.", file=sys.stderr)
        return 1
    print("no engine regressions.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
