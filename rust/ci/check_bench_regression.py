#!/usr/bin/env python3
"""CI bench-regression gate for BENCH_hotpath.json.

Compares the engine rows (bench names containing any ``--filter``
substring, default ``engine,dirty,simd,omd,sim``) of a fresh
``BENCH_hotpath.json`` against the committed baseline and fails (exit 1)
if any row's median regresses by more than ``--tolerance`` (default
20%). Unmatched rows (the deliberately slow ``ref_*`` reference sweeps)
are reported but never gate.

Independently of the baseline, ``--require NAME:FLOOR`` (repeatable)
checks the fresh file's ``speedups`` section: the named ratio must exist
and be at least FLOOR. The defaults pin the structural perf claims —
the session-batched SoA kernels at least match the scalar kernels on the
multi-class configuration, the explicit SIMD kernels at least match the
batched kernels (``mc{25,40}/simd_vs_batched_w{1,4}``; CI runs the bench
with ``--features simd`` so these rows exist), a single-block
``prepare_dirty`` beats a full prepare by ≥ 3× on the clustered fleet,
and the row-sparse OMD probe loop beats the dense observe loop by ≥ 2×
(``clusters40/omd_probe_sparse_vs_dense``) — plus raw-throughput
floors on the request-level DES replay (``sim_replay_events_per_sec``,
events/sec on the calendar-queue/CSR/slab core, floored at 600k = 3x
the PR-6 configuration) with the calendar-vs-heap speedup
(``sim_replay_calendar_vs_heap``) floored alongside it, and on the
sharded coordination plane's 10^4-node / 10^5-session scale row
(``fleet1e4/sharded_round_throughput``,
sessions x rounds per second; the throughputs are not ratios). (The bench binary asserts
the same bounds; the gate re-checks them from the artifact so a stale or
hand-edited JSON cannot slip through.) Pass ``--no-default-requires`` to
drop them (e.g. for older artifacts).

Bootstrap and arming procedure (this repo has no local Rust toolchain —
CI is the only place the bench runs):

1. The committed baseline starts life as a placeholder with an empty
   ``results`` list. While the baseline is empty, the baseline-relative
   gate passes and prints instructions; the ``--require`` floors still
   run — they need only the fresh artifact.
2. After the first green CI run on a bench-affecting change, open that
   run's "print bench artifact" step (or download the ``bench-hotpath``
   artifact), copy the JSON verbatim, and commit it as
   ``rust/ci/BENCH_baseline.json`` — the gate is now armed.
3. When bench rows are renamed, added, or a deliberate perf change
   lands, refresh the baseline the same way **in the same PR**. Rows
   present in only one file are warned about (renames/additions), not
   failed, so the gate never blocks intentional bench evolution.

Usage:
    check_bench_regression.py BASELINE FRESH [--tolerance 0.20]
        [--filter engine,dirty,simd,omd,sim]
        [--require clusters40/dirty_vs_full:3.0]
"""

from __future__ import annotations

import argparse
import json
import sys

# speedup floors every fresh artifact must clear (name, minimum ratio)
DEFAULT_REQUIRES = [
    ("mc25/batched_vs_scalar_w1", 0.95),
    ("mc25/batched_vs_scalar_w4", 0.95),
    ("mc40/batched_vs_scalar_w1", 0.95),
    ("mc40/batched_vs_scalar_w4", 0.95),
    # explicit SIMD kernels vs the batched kernels (rows exist because CI
    # benches with --features simd; 0.95 = "at least as fast within noise")
    ("mc25/simd_vs_batched_w1", 0.95),
    ("mc25/simd_vs_batched_w4", 0.95),
    ("mc40/simd_vs_batched_w1", 0.95),
    ("mc40/simd_vs_batched_w4", 0.95),
    ("clusters40/dirty_vs_full", 3.0),
    # row-sparse OMD probe loop vs the dense observe loop
    ("clusters40/omd_probe_sparse_vs_dense", 2.0),
    # not a ratio: raw DES replay throughput (events/sec) on the optimized
    # calendar-queue/CSR/slab core — 3x the PR-6 floor of 200k
    ("sim_replay_events_per_sec", 600_000.0),
    # calendar/CSR/slab core vs the pinned PR-6 reference engine on the
    # same replay (the bench asserts >= 2.0 on the full 10^6-request
    # config; the quick-mode artifact gets headroom for runner noise)
    ("sim_replay_calendar_vs_heap", 1.2),
    # not a ratio: sharded-plane throughput (sessions x rounds per second)
    # on the synthetic 10^4-node / 10^5-session fleet at K=4, S=1
    ("fleet1e4/sharded_round_throughput", 250_000.0),
]


def load_doc(path: str) -> dict:
    with open(path, encoding="utf-8") as fh:
        return json.load(fh)


def load_rows(doc: dict) -> dict[str, float]:
    rows = {}
    for row in doc.get("results", []):
        name, median = row.get("name"), row.get("median_s")
        if isinstance(name, str) and isinstance(median, (int, float)) and median > 0:
            rows[name] = float(median)
    return rows


def parse_require(text: str) -> tuple[str, float]:
    name, _, floor = text.rpartition(":")
    if not name:
        raise argparse.ArgumentTypeError(f"--require wants NAME:FLOOR, got {text!r}")
    return name, float(floor)


def check_requires(doc: dict, requires: list[tuple[str, float]]) -> list[str]:
    speedups = doc.get("speedups", {})
    failures = []
    for name, floor in requires:
        value = speedups.get(name)
        if not isinstance(value, (int, float)):
            failures.append(f"required speedup '{name}' missing from fresh results")
            continue
        status = "ok  " if value >= floor else "FAIL"
        print(f"  {status} require {name:<38} {value:6.2f}x (floor {floor:.2f}x)")
        if value < floor:
            failures.append(
                f"speedup '{name}' = {value:.2f}x fell below its floor {floor:.2f}x"
            )
    return failures


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", help="committed BENCH_baseline.json")
    ap.add_argument("fresh", help="freshly produced BENCH_hotpath.json")
    ap.add_argument("--tolerance", type=float, default=0.20,
                    help="allowed relative slowdown before failing (default 0.20)")
    ap.add_argument("--filter", default="engine,dirty,simd,omd,sim",
                    help="comma-separated substrings selecting the gated rows "
                         "(default 'engine,dirty,simd,omd,sim')")
    ap.add_argument("--require", type=parse_require, action="append", default=[],
                    metavar="NAME:FLOOR",
                    help="require fresh speedups[NAME] >= FLOOR (repeatable; "
                         "adds to the built-in defaults)")
    ap.add_argument("--no-default-requires", action="store_true",
                    help="skip the built-in speedup floors")
    args = ap.parse_args()

    fresh_doc = load_doc(args.fresh)
    baseline = load_rows(load_doc(args.baseline))
    fresh = load_rows(fresh_doc)
    if not fresh:
        print(f"error: no usable rows in {args.fresh}", file=sys.stderr)
        return 1

    requires = ([] if args.no_default_requires else list(DEFAULT_REQUIRES))
    requires += args.require
    print(f"speedup floors: {len(requires)} required ratio(s)")
    failures = check_requires(fresh_doc, requires)

    filters = [f for f in args.filter.split(",") if f]
    if not baseline:
        print(f"\nbaseline {args.baseline} is empty (bootstrap mode): "
              "baseline gate passes.")
        print("To arm the gate, download this run's 'bench-hotpath' artifact and")
        print("commit it as rust/ci/BENCH_baseline.json.")
    else:
        gated = sorted(n for n in baseline if any(f in n for f in filters))
        regressions, improvements = [], []
        for name in gated:
            if name not in fresh:
                print(f"warn: baseline row '{name}' missing from fresh results "
                      f"(renamed/removed? refresh the baseline)")
                continue
            base, now = baseline[name], fresh[name]
            ratio = now / base
            line = (f"{name:<44} {base * 1e6:>10.2f}us -> "
                    f"{now * 1e6:>10.2f}us  ({ratio:5.2f}x)")
            if ratio > 1.0 + args.tolerance:
                regressions.append(line)
            else:
                improvements.append(line)
        for name in sorted(fresh):
            if any(f in name for f in filters) and name not in baseline:
                print(f"warn: new engine row '{name}' has no baseline yet "
                      f"(commit a refreshed BENCH_baseline.json to gate it)")

        print(f"\nbench gate: {len(gated)} gated rows, tolerance {args.tolerance:.0%}")
        for line in improvements:
            print(f"  ok   {line}")
        for line in regressions:
            print(f"  FAIL {line}")
        if regressions:
            failures.append(f"{len(regressions)} engine row(s) regressed more than "
                            f"{args.tolerance:.0%} vs the committed baseline")

    if failures:
        print("\n" + "\n".join(failures), file=sys.stderr)
        return 1
    print("bench gate: all checks passed.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
