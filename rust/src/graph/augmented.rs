//! Augmented-graph construction (paper §II-C, Fig. 2).
//!
//! The real device network is extended with a virtual source `S` (the
//! controller admitting the total rate λ) and one virtual destination `D_w`
//! per DNN version. Computation cost at device `i` hosting version `w`
//! becomes the communication cost of virtual link `(i, D_w)` (eq. 6).
//!
//! Node layout (shared with the L2 dense encoding in
//! `python/compile/model.py`):
//!
//! ```text
//! 0            = S  (virtual source)
//! 1 ..= n_real = real devices (device d -> node d+1)
//! n_real+1+w   = D_w (virtual destination of session w)
//! ```
//!
//! Each session `w` is additionally restricted to its **session DAG**: edge
//! `(i, j)` is usable iff `hop(j, D_w) < hop(i, D_w)` (strictly closer), and
//! a device hosting version `w` forwards session-`w` traffic only to `D_w`.
//! This realizes Gallager's loop-free routing-variable sets (DESIGN.md §4):
//! flow propagation and the marginal-cost broadcast terminate in ≤ DAG-depth
//! steps, and strong connectivity guarantees every reachable node keeps at
//! least one usable out-edge.

use super::{DiGraph, EdgeId, NodeId};
use crate::util::rng::Rng;

/// Which DNN version each real device hosts (one version per device; a
/// device with capacity for several models is modelled as several virtual
/// devices per the paper §II-A).
#[derive(Clone, Debug)]
pub struct Placement {
    pub version_of: Vec<usize>,
    pub n_versions: usize,
}

impl Placement {
    pub fn new(version_of: Vec<usize>, n_versions: usize) -> Self {
        assert!(version_of.iter().all(|&v| v < n_versions));
        for w in 0..n_versions {
            assert!(
                version_of.contains(&w),
                "version {w} has no hosting device"
            );
        }
        Placement { version_of, n_versions }
    }

    /// Paper's experiment setup: each device uniformly hosts one of the
    /// `n_versions` models, with every version hosted somewhere and version 0
    /// guaranteed at device 0 (the controller's proximate "smallest model"
    /// entry point).
    pub fn random(n_devices: usize, n_versions: usize, rng: &mut Rng) -> Placement {
        assert!(n_devices >= n_versions);
        loop {
            let mut v: Vec<usize> = (0..n_devices).map(|_| rng.below(n_versions)).collect();
            v[0] = 0;
            let all = (0..n_versions).all(|w| v.contains(&w));
            if all {
                return Placement::new(v, n_versions);
            }
        }
    }

    /// Heterogeneous-spec placement: devices with a pinned version keep it,
    /// the rest draw uniformly — except that the versions no pin covers are
    /// assigned (ascending) to the first unpinned devices, so every version
    /// is guaranteed a host. Returns `None` when the pins make coverage
    /// impossible (a pin out of range, or more uncovered versions than
    /// unpinned devices).
    pub fn with_pins(
        n_devices: usize,
        n_versions: usize,
        pins: &[Option<usize>],
        rng: &mut Rng,
    ) -> Option<Placement> {
        assert_eq!(pins.len(), n_devices);
        if pins.iter().flatten().any(|&v| v >= n_versions) {
            return None;
        }
        let mut v: Vec<usize> = pins
            .iter()
            .map(|p| p.unwrap_or_else(|| rng.below(n_versions)))
            .collect();
        let free: Vec<usize> = (0..n_devices).filter(|&d| pins[d].is_none()).collect();
        let must_host: Vec<usize> = (0..n_versions)
            .filter(|&w| !pins.iter().flatten().any(|&p| p == w))
            .collect();
        if must_host.len() > free.len() {
            return None;
        }
        for (&w, &d) in must_host.iter().zip(&free) {
            v[d] = w;
        }
        Some(Placement::new(v, n_versions))
    }

    pub fn hosts(&self, w: usize) -> impl Iterator<Item = usize> + '_ {
        self.version_of
            .iter()
            .enumerate()
            .filter(move |&(_, &v)| v == w)
            .map(|(d, _)| d)
    }
}

/// One routing row of the flat CSR lane index: node `node` owns the
/// contiguous lane range `start..end` into [`FlowCsr::lane_edge`] /
/// [`FlowCsr::lane_dst`].
#[derive(Clone, Copy, Debug)]
pub struct CsrRow {
    pub node: NodeId,
    pub start: usize,
    pub end: usize,
}

impl CsrRow {
    /// Number of usable out-lanes in this row.
    #[inline]
    pub fn len(&self) -> usize {
        self.end - self.start
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }
}

/// Flat CSR-style lane index over every session DAG — the hot-path
/// substrate of [`crate::engine`].
///
/// All usable (session, node, out-edge) lanes live in one flat edge-id
/// array, grouped per session with rows in **forward topological order**,
/// so the per-iteration sweeps are pure index arithmetic: no adjacency
/// re-filtering, no iterator chains, no per-row allocation. `lane_dst`
/// mirrors `lane_edge` with each lane's destination node so the sweeps
/// never chase the edge table.
#[derive(Clone, Debug, Default)]
pub struct FlowCsr {
    /// Flat lane edge ids (session-major, rows in forward topo order).
    pub lane_edge: Vec<EdgeId>,
    /// Destination node of each lane (parallel to `lane_edge`).
    pub lane_dst: Vec<NodeId>,
    /// Flat row table; row `r` owns lanes `rows[r].start..rows[r].end`.
    pub rows: Vec<CsrRow>,
    /// Per-session `(first_row, end_row)` ranges into `rows`.
    pub session_rows: Vec<(usize, usize)>,
    /// Per-session `(first_lane, end_lane)` ranges into `lane_edge`.
    pub session_lane_span: Vec<(usize, usize)>,
    /// Transposed index — sessions whose DAG contains each edge, ascending:
    /// edge `e` owns `edge_session[edge_session_off[e]..edge_session_off[e+1]]`.
    /// This is what lets the engine's incremental path re-reduce a touched
    /// edge's total flow in exactly the full sweep's session order.
    pub edge_session_off: Vec<usize>,
    pub edge_session: Vec<u32>,
}

impl FlowCsr {
    /// Rows of session `w` in forward topological order.
    #[inline]
    pub fn rows(&self, w: usize) -> &[CsrRow] {
        let (a, b) = self.session_rows[w];
        &self.rows[a..b]
    }

    /// Total number of lanes across all sessions.
    #[inline]
    pub fn n_lanes(&self) -> usize {
        self.lane_edge.len()
    }

    /// Sessions whose DAG contains edge `e`, ascending.
    #[inline]
    pub fn sessions_of_edge(&self, e: EdgeId) -> &[u32] {
        &self.edge_session[self.edge_session_off[e]..self.edge_session_off[e + 1]]
    }
}

/// SIMD lane count the batched layout pads to. With the `simd` feature on,
/// every block's workspace width rounds up to a multiple of 4 (the f64x4
/// width of [`crate::engine`]'s vector kernels) so the session-dimension
/// inner loops are whole vectors with no remainder tail. Padding columns
/// carry no session: they start at 0 (workspaces are zero-filled at bind)
/// and stay 0 through the recurrence (`0 · φ` and `x + 0.0` are exact), so
/// logical columns are bit-for-bit unaffected. Without the feature the pad
/// is 1 and the layout is unchanged.
#[cfg(feature = "simd")]
pub const LANE_PAD: usize = 4;
#[cfg(not(feature = "simd"))]
pub const LANE_PAD: usize = 1;

/// One session block of the batched lane index: all sessions serving the
/// same DNN version, swept together over the block's union DAG.
#[derive(Clone, Debug)]
pub struct BatchBlock {
    /// DNN version shared by every session of the block.
    pub version: usize,
    /// Global session ids of the block, ascending (the lane-major columns,
    /// in order).
    pub sessions: Vec<usize>,
    /// Row range of the block into [`BatchCsr::rows`].
    pub rows: (usize, usize),
    /// Union-lane range of the block into [`BatchCsr::lane_edge`].
    pub lanes: (usize, usize),
    /// First slot of the block's lane-major `[lane × session]` region in
    /// the engine's batched workspaces.
    pub slot0: usize,
    /// First column of the block in the node-major `[node × session]`
    /// regions (padded block widths pack to [`BatchCsr::n_cols`] columns
    /// total).
    pub col0: usize,
    /// Workspace stride of the block: [`BatchBlock::width`] rounded up to
    /// [`LANE_PAD`]. Columns `width..padded` are zero-filled padding.
    pub padded: usize,
}

impl BatchBlock {
    /// Number of sessions swept together (the SoA vector width).
    #[inline]
    pub fn width(&self) -> usize {
        self.sessions.len()
    }

    /// Workspace stride (width rounded up to the SIMD lane pad).
    #[inline]
    pub fn padded_width(&self) -> usize {
        self.padded
    }
}

/// Session-batched lane index — the SoA substrate of the engine's batched
/// sweeps.
///
/// Sessions of one DNN version share a destination, hence (up to the
/// virtual source's admission lanes) the same strictly-closer DAG and —
/// after [`AugmentedNet::rebuild_session_dags`] — the same topological row
/// order. Grouping them into a [`BatchBlock`] lets the engine process each
/// CSR row once for the whole block: `φ` is gathered into a lane-major
/// `[lane × session]` workspace and the inner loops become contiguous
/// multiply-accumulates over the session dimension. Lanes a session does
/// not use hold `φ = 0` there, and `x + 0.0` is exact on the engine's
/// non-negative accumulators, so each session sees bit-for-bit its own
/// scalar sweep.
#[derive(Clone, Debug, Default)]
pub struct BatchCsr {
    /// One block per DNN version, in version order.
    pub blocks: Vec<BatchBlock>,
    /// Flat row table (block-major, rows in the shared topo order); lane
    /// ranges are global indices into `lane_edge`.
    pub rows: Vec<CsrRow>,
    /// Union lane edge ids (block-major; within a row, adjacency order —
    /// the same relative order as every member session's scalar lanes).
    pub lane_edge: Vec<EdgeId>,
    /// Destination node of each union lane (parallel to `lane_edge`).
    pub lane_dst: Vec<NodeId>,
    /// Session `s` → `(block index, column within block)`.
    pub session_slot: Vec<(usize, usize)>,
    /// Per scalar-CSR lane `k` (parallel to [`FlowCsr::lane_edge`]): the
    /// global slot of that (session, lane) in the lane-major workspaces —
    /// how the fixed-order flow reduction reads batched per-session flows.
    pub lane_slot: Vec<usize>,
    /// Total lane-major workspace slots (`Σ_b lanes_b × padded_b`).
    pub n_slots: usize,
    /// Total node-major workspace columns (`Σ_b padded_b`); equals
    /// `n_sessions` unless the `simd` feature pads block widths.
    pub n_cols: usize,
}

impl BatchCsr {
    /// Rows of block `b` in the shared forward topological order.
    #[inline]
    pub fn rows(&self, b: usize) -> &[CsrRow] {
        let (a, z) = self.blocks[b].rows;
        &self.rows[a..z]
    }

    /// Widest block (the maximum SoA width; 1 on single-class networks).
    pub fn max_width(&self) -> usize {
        self.blocks.iter().map(BatchBlock::width).max().unwrap_or(0)
    }
}

/// The augmented CEC network: graph, placement, per-session DAG masks.
///
/// A **session** is one routed commodity `S → D_w`. Single-class networks
/// (the paper's setup) have exactly one session per DNN version; the
/// heterogeneous multi-class scenarios of
/// [`crate::session::spec::ScenarioSpec`] route one session per
/// `(task class, version)` pair, class-major, with each class restricted
/// to its own admission (source-device) set. All per-session structures
/// below are indexed by session, not version.
#[derive(Clone, Debug)]
pub struct AugmentedNet {
    pub graph: DiGraph,
    pub placement: Placement,
    pub n_real: usize,
    /// DNN version served by session `s` (identity for single-class nets).
    pub session_version: Vec<usize>,
    /// Admission targets of session `s`: the augmented node ids the virtual
    /// source may forward this session's traffic to (sorted ascending).
    pub session_admit: Vec<Vec<NodeId>>,
    /// `session_edges[w][e]` — edge `e` usable by session `w`.
    pub session_edges: Vec<Vec<bool>>,
    /// Shared topological order per DNN *version* (sources first), valid
    /// for every session serving that version — computed on the union of
    /// their DAG masks. Read per session via
    /// [`AugmentedNet::session_topo`].
    pub version_topo: Vec<Vec<NodeId>>,
    /// Edge ids of virtual links, for cost attribution diagnostics.
    pub virtual_edges: Vec<EdgeId>,
    /// `session_lanes[w][i]` — cached usable out-edges (hot-path: avoids
    /// re-filtering adjacency on every routing iteration).
    pub session_lanes: Vec<Vec<Vec<EdgeId>>>,
    /// Cached router lists per session (nodes with ≥1 usable out-edge,
    /// excluding D_w).
    pub routers: Vec<Vec<NodeId>>,
    /// Edges usable by at least one session (the cost-bearing edge set).
    pub union_edges: Vec<EdgeId>,
    /// Flat CSR lane index (per-session topo-ordered rows) consumed by
    /// [`crate::engine::FlowEngine`]'s fused sweeps.
    pub csr: FlowCsr,
    /// Session-batched lane index (one block per version) consumed by the
    /// engine's lane-major SoA sweeps.
    pub batch: BatchCsr,
}

/// Capacity assigned to S->device admission links (effectively unconstrained:
/// admission is limited by λ, not by the virtual source links).
pub const SOURCE_CAP: f64 = 1e6;

impl AugmentedNet {
    pub const SOURCE: NodeId = 0;

    /// Destination node `D_{version(s)}` of session `s`.
    #[inline]
    pub fn dnode(&self, s: usize) -> NodeId {
        self.n_real + 1 + self.session_version[s]
    }

    /// DNN version served by session `s`.
    #[inline]
    pub fn version_of_session(&self, s: usize) -> usize {
        self.session_version[s]
    }

    /// Forward topological order of session `s`'s DAG (sources first) —
    /// the order shared by every session of the same version (stored once
    /// per version in [`AugmentedNet::version_topo`]).
    #[inline]
    pub fn session_topo(&self, s: usize) -> &[NodeId] {
        &self.version_topo[self.session_version[s]]
    }

    /// Number of DNN versions W (= the number of `D_w` nodes).
    #[inline]
    pub fn n_versions(&self) -> usize {
        self.placement.n_versions
    }

    /// Number of routed sessions (`classes × versions`; equals
    /// [`AugmentedNet::n_versions`] for single-class networks).
    #[inline]
    pub fn n_sessions(&self) -> usize {
        self.session_version.len()
    }

    #[inline]
    pub fn n_nodes(&self) -> usize {
        self.graph.n_nodes()
    }

    /// Real device `d`'s node id in the augmented graph.
    #[inline]
    pub fn device_node(&self, d: usize) -> NodeId {
        d + 1
    }

    /// Build from the real network. `comp_cap_mean` is the mean computing
    /// capacity C_i (drawn per device like link capacities, paper eq. 6).
    /// One session per version, all admitted through the hosts of version 0
    /// (the paper's single-class setup).
    pub fn build(
        real: &DiGraph,
        placement: &Placement,
        comp_cap_mean: f64,
        rng: &mut Rng,
    ) -> AugmentedNet {
        let sources: Vec<usize> = placement.hosts(0).collect();
        Self::build_heterogeneous(real, placement, comp_cap_mean, &[], &[sources], rng)
    }

    /// Heterogeneous multi-class construction (the substrate of
    /// [`crate::session::spec::ScenarioSpec`]).
    ///
    /// * `node_caps[d]` — explicit computing capacity for device `d`
    ///   (`None`/missing = drawn from the `comp_cap_mean` distribution;
    ///   the draw happens for *every* device so the RNG stream — and hence
    ///   every downstream placement — is identical whether or not a device
    ///   pins its capacity).
    /// * `class_sources[c]` — the admission (source-device) set of task
    ///   class `c`. Sessions are class-major: session `c·W + w` routes
    ///   class `c`'s traffic to `D_w`, admitted only through S-links into
    ///   class `c`'s sources. The virtual source gets one admission link
    ///   per device in the ascending union of all class sources.
    ///
    /// With one class whose sources are `hosts(0)` this reduces exactly to
    /// [`AugmentedNet::build`] — same edges, same RNG draws, bit-identical
    /// session DAGs.
    pub fn build_heterogeneous(
        real: &DiGraph,
        placement: &Placement,
        comp_cap_mean: f64,
        node_caps: &[Option<f64>],
        class_sources: &[Vec<usize>],
        rng: &mut Rng,
    ) -> AugmentedNet {
        assert!(!class_sources.is_empty(), "at least one task class required");
        let n_real = real.n_nodes();
        let w_cnt = placement.n_versions;
        let n_total = 1 + n_real + w_cnt;
        let mut g = DiGraph::with_nodes(n_total);

        // real links, shifted by +1
        for e in real.edges() {
            g.add_edge(e.src + 1, e.dst + 1, e.capacity);
        }
        let mut virtual_edges = Vec::new();
        // S -> the union of every class's source devices, ascending (for a
        // single class sourced at hosts(0) this is the paper's "controller
        // directly reaches the devices with the smallest model" layout)
        let mut admit_union: Vec<usize> = class_sources.iter().flatten().copied().collect();
        admit_union.sort_unstable();
        admit_union.dedup();
        for &d in &admit_union {
            assert!(d < n_real, "source device {d} out of range");
            virtual_edges.push(g.add_edge(Self::SOURCE, d + 1, SOURCE_CAP));
        }
        // computation links device -> D_{version(device)}; capacities are
        // drawn for every device (stable RNG stream) and overridden where a
        // node spec pins them
        for (d, &v) in placement.version_of.iter().enumerate() {
            let drawn = rng.uniform(0.2 * comp_cap_mean, 1.8 * comp_cap_mean);
            let cap = node_caps.get(d).copied().flatten().unwrap_or(drawn);
            virtual_edges.push(g.add_edge(d + 1, n_real + 1 + v, cap));
        }

        // sessions: class-major, one per (class, version)
        let mut session_version = Vec::with_capacity(class_sources.len() * w_cnt);
        let mut session_admit = Vec::with_capacity(class_sources.len() * w_cnt);
        for sources in class_sources {
            let mut nodes: Vec<NodeId> = sources.iter().map(|&d| d + 1).collect();
            nodes.sort_unstable();
            nodes.dedup();
            for w in 0..w_cnt {
                session_version.push(w);
                session_admit.push(nodes.clone());
            }
        }

        let mut net = AugmentedNet {
            graph: g,
            placement: placement.clone(),
            n_real,
            session_version,
            session_admit,
            session_edges: Vec::new(),
            version_topo: Vec::new(),
            virtual_edges,
            session_lanes: Vec::new(),
            routers: Vec::new(),
            union_edges: Vec::new(),
            csr: FlowCsr::default(),
            batch: BatchCsr::default(),
        };
        net.rebuild_session_dags();
        net
    }

    /// (Re)compute the per-session DAG masks + topological orders. Called at
    /// construction and after any topology change.
    ///
    /// Sessions serving the same DNN version share **one** topological
    /// order, computed on the union of their DAG masks: every non-source
    /// edge of a version-`w` session strictly decreases the hop distance to
    /// `D_w` and edges out of `S` cannot close a cycle (nothing enters
    /// `S`), so the union is acyclic and its order is valid for each
    /// member DAG. This is what lets [`crate::engine::FlowEngine`] sweep a
    /// whole version block of sessions per CSR row with every session
    /// seeing exactly its own scalar accumulation order (single-class
    /// networks have one session per version, so the union *is* the
    /// session mask and nothing changes).
    pub fn rebuild_session_dags(&mut self) {
        let s_cnt = self.n_sessions();
        let mut session_edges = Vec::with_capacity(s_cnt);
        for w in 0..s_cnt {
            let ver = self.session_version[w];
            let dw = self.dnode(w);
            let dist = self.graph.dist_to(dw);
            // class-local admission rule: S forwards this session only to
            // its class's *nearest* reachable sources. For a single class
            // sourced at every S-neighbor this is exactly the legacy
            // strictly-closer rule (dist(d) < dist(S) ⟺ dist(d) equals the
            // global minimum); with multiple classes the minimum is taken
            // over the class's own sources, so a class farther from D_w
            // than another class still keeps its admission lanes. Edges
            // out of S can never create a loop (nothing enters S).
            let admit_min: Option<u32> =
                self.session_admit[w].iter().filter_map(|&d| dist[d]).min();
            let mut mask = vec![false; self.graph.n_edges()];
            for (eid, e) in self.graph.edges().iter().enumerate() {
                if e.src == Self::SOURCE {
                    let usable = self.session_admit[w].binary_search(&e.dst).is_ok()
                        && dist[e.dst].is_some()
                        && dist[e.dst] == admit_min;
                    mask[eid] = usable;
                    continue;
                }
                let (du, dv) = (dist[e.src], dist[e.dst]);
                let (du, dv) = match (du, dv) {
                    (Some(a), Some(b)) => (a, b),
                    _ => continue,
                };
                if dv >= du {
                    continue; // not strictly closer -> would allow loops
                }
                // a device hosting this session's version only forwards it
                // to that version's destination
                if let Some(d) = self.device_of(e.src) {
                    if self.placement.version_of[d] == ver && e.dst != dw {
                        continue;
                    }
                }
                // session traffic never enters a *different* destination
                if e.dst > self.n_real && e.dst != dw {
                    continue;
                }
                mask[eid] = true;
            }
            session_edges.push(mask);
        }
        // one shared topological order per version, over the union of that
        // version's session masks (identical to the per-session order when
        // each version has exactly one session) — stored once per version,
        // never per session
        let mut version_topo = Vec::with_capacity(self.n_versions());
        for ver in 0..self.n_versions() {
            let mut union = vec![false; self.graph.n_edges()];
            for (s, mask) in session_edges.iter().enumerate() {
                if self.session_version[s] == ver {
                    for (u, &m) in union.iter_mut().zip(mask) {
                        *u |= m;
                    }
                }
            }
            version_topo.push(
                self.graph
                    .topo_order(&union)
                    .expect("per-version union DAG must be acyclic by construction"),
            );
        }
        self.session_edges = session_edges;
        self.version_topo = version_topo;
        // hot-path caches
        self.session_lanes = (0..s_cnt)
            .map(|w| {
                (0..self.graph.n_nodes())
                    .map(|i| {
                        self.graph
                            .out_edges(i)
                            .iter()
                            .copied()
                            .filter(|&e| self.session_edges[w][e])
                            .collect()
                    })
                    .collect()
            })
            .collect();
        self.routers = (0..s_cnt)
            .map(|w| {
                (0..self.graph.n_nodes())
                    .filter(|&i| i != self.dnode(w) && !self.session_lanes[w][i].is_empty())
                    .collect()
            })
            .collect();
        self.union_edges = (0..self.graph.n_edges())
            .filter(|&e| (0..s_cnt).any(|w| self.session_edges[w][e]))
            .collect();
        self.rebuild_csr();
    }

    /// Flatten the per-session lane caches into the CSR index. Row order is
    /// the forward topological order of each session DAG (restricted to
    /// nodes with ≥1 usable out-lane), and the lanes of a row keep the
    /// adjacency-filter order of `session_lanes` — so sweeps over the CSR
    /// visit exactly the same lanes in exactly the same order as the
    /// reference implementations in [`crate::model::flow`] and
    /// [`crate::routing::marginal`].
    fn rebuild_csr(&mut self) {
        let s_cnt = self.n_sessions();
        let mut csr = FlowCsr::default();
        for w in 0..s_cnt {
            let row_first = csr.rows.len();
            let lane_first = csr.lane_edge.len();
            for &i in self.session_topo(w) {
                let lanes = &self.session_lanes[w][i];
                if lanes.is_empty() {
                    continue;
                }
                let start = csr.lane_edge.len();
                for &e in lanes {
                    csr.lane_edge.push(e);
                    csr.lane_dst.push(self.graph.edge(e).dst);
                }
                csr.rows.push(CsrRow { node: i, start, end: csr.lane_edge.len() });
            }
            csr.session_rows.push((row_first, csr.rows.len()));
            csr.session_lane_span.push((lane_first, csr.lane_edge.len()));
        }
        // transposed edge → sessions index (ascending sessions per edge),
        // CSR-packed: the incremental engine path re-reduces a touched
        // edge's flow by walking exactly this list
        let ne = self.graph.n_edges();
        let mut counts = vec![0usize; ne];
        for mask in &self.session_edges {
            for (e, &m) in mask.iter().enumerate() {
                counts[e] += m as usize;
            }
        }
        let mut off = Vec::with_capacity(ne + 1);
        let mut acc = 0usize;
        for &c in &counts {
            off.push(acc);
            acc += c;
        }
        off.push(acc);
        let mut flat = vec![0u32; acc];
        let mut cursor = off.clone();
        for (s, mask) in self.session_edges.iter().enumerate() {
            for (e, &m) in mask.iter().enumerate() {
                if m {
                    flat[cursor[e]] = s as u32;
                    cursor[e] += 1;
                }
            }
        }
        csr.edge_session_off = off;
        csr.edge_session = flat;
        self.csr = csr;
        self.rebuild_batch();
    }

    /// Flatten the per-version session blocks into the batched SoA index.
    /// Each block's rows follow the version's shared topological order and
    /// each row's union lanes keep adjacency order, so every member
    /// session's scalar (row, lane) sequence is a subsequence of the
    /// block's — the invariant behind the batched sweeps' bit-identity.
    fn rebuild_batch(&mut self) {
        let s_cnt = self.n_sessions();
        let ne = self.graph.n_edges();
        let mut batch = BatchCsr {
            session_slot: vec![(0, 0); s_cnt],
            lane_slot: vec![0; self.csr.lane_edge.len()],
            ..BatchCsr::default()
        };
        // scratch: union lane membership + edge -> block-local lane index
        let mut union = vec![false; ne];
        let mut lane_of_edge = vec![usize::MAX; ne];
        let mut col0 = 0usize;
        for ver in 0..self.n_versions() {
            let sessions: Vec<usize> =
                (0..s_cnt).filter(|&s| self.session_version[s] == ver).collect();
            let width = sessions.len();
            if width == 0 {
                continue;
            }
            union.fill(false);
            for &s in &sessions {
                for (e, &m) in self.session_edges[s].iter().enumerate() {
                    union[e] |= m;
                }
            }
            let row_first = batch.rows.len();
            let lane_first = batch.lane_edge.len();
            let slot0 = batch.n_slots;
            // shared topo order: one stored order per version
            for &i in &self.version_topo[ver] {
                let start = batch.lane_edge.len();
                for &e in self.graph.out_edges(i) {
                    if union[e] {
                        lane_of_edge[e] = batch.lane_edge.len() - lane_first;
                        batch.lane_edge.push(e);
                        batch.lane_dst.push(self.graph.edge(e).dst);
                    }
                }
                if batch.lane_edge.len() > start {
                    batch.rows.push(CsrRow { node: i, start, end: batch.lane_edge.len() });
                }
            }
            let n_lanes = batch.lane_edge.len() - lane_first;
            // workspace stride: width rounded up to the SIMD lane pad, so
            // vector kernels see whole f64x4 groups (pad columns stay 0)
            let padded = width.next_multiple_of(LANE_PAD);
            for (col, &s) in sessions.iter().enumerate() {
                batch.session_slot[s] = (batch.blocks.len(), col);
                let (k0, k1) = self.csr.session_lane_span[s];
                for k in k0..k1 {
                    let local = lane_of_edge[self.csr.lane_edge[k]];
                    debug_assert_ne!(local, usize::MAX, "session lane outside block union");
                    batch.lane_slot[k] = slot0 + local * padded + col;
                }
            }
            batch.n_slots += n_lanes * padded;
            batch.blocks.push(BatchBlock {
                version: ver,
                sessions,
                rows: (row_first, batch.rows.len()),
                lanes: (lane_first, batch.lane_edge.len()),
                slot0,
                col0,
                padded,
            });
            col0 += padded;
        }
        batch.n_cols = col0;
        self.batch = batch;
    }

    /// Real device index of augmented node `i` (None for S / D_w).
    #[inline]
    pub fn device_of(&self, i: NodeId) -> Option<usize> {
        if i >= 1 && i <= self.n_real {
            Some(i - 1)
        } else {
            None
        }
    }

    /// Out-edges of node `i` usable by session `w` (cached).
    pub fn session_out(&self, w: usize, i: NodeId) -> impl Iterator<Item = EdgeId> + '_ {
        self.session_lanes[w][i].iter().copied()
    }

    /// Cached usable out-edge slice for (session, node).
    #[inline]
    pub fn lanes(&self, w: usize, i: NodeId) -> &[EdgeId] {
        &self.session_lanes[w][i]
    }

    /// Every (node, usable-out-degree>0) pair for session `w`, excluding D_w
    /// (cached).
    pub fn session_routers(&self, w: usize) -> &[NodeId] {
        &self.routers[w]
    }

    /// Sanity diagnostics used by tests and the CLI `topo` command.
    pub fn validate(&self) -> Result<(), String> {
        for w in 0..self.n_sessions() {
            let dw = self.dnode(w);
            // source must reach the destination inside the session DAG
            if self.session_out(w, Self::SOURCE).next().is_none() {
                return Err(format!("session {w}: source has no usable out-edge"));
            }
            // every node with a usable in-edge must have a usable out-edge
            // (flow can't get stuck), except D_w
            let mask = &self.session_edges[w];
            for i in 0..self.n_nodes() {
                if i == dw {
                    continue;
                }
                let has_in = self.graph.in_edges(i).iter().any(|&e| mask[e]);
                let has_out = self.graph.out_edges(i).iter().any(|&e| mask[e]);
                if has_in && !has_out {
                    return Err(format!("session {w}: node {i} is a dead end"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topologies;

    fn er_net(seed: u64) -> AugmentedNet {
        let mut rng = Rng::seed_from(seed);
        topologies::connected_er(12, 0.3, 3, &mut rng)
    }

    #[test]
    fn layout_and_counts() {
        let net = er_net(3);
        assert_eq!(net.n_nodes(), 12 + 1 + 3);
        assert_eq!(net.dnode(0), 13);
        assert_eq!(net.device_node(0), 1);
        assert_eq!(net.device_of(1), Some(0));
        assert_eq!(net.device_of(0), None);
        assert_eq!(net.device_of(13), None);
    }

    #[test]
    fn placement_random_covers_all_versions() {
        let mut rng = Rng::seed_from(5);
        for _ in 0..20 {
            let p = Placement::random(8, 3, &mut rng);
            for w in 0..3 {
                assert!(p.hosts(w).next().is_some());
            }
            assert_eq!(p.version_of[0], 0);
        }
    }

    #[test]
    #[should_panic(expected = "no hosting device")]
    fn placement_rejects_missing_version() {
        Placement::new(vec![0, 0, 0], 2);
    }

    #[test]
    fn session_dags_valid() {
        for seed in 0..10u64 {
            let net = er_net(seed);
            net.validate().unwrap();
            for w in 0..net.n_versions() {
                // acyclic by construction
                assert!(net.graph.topo_order(&net.session_edges[w]).is_some());
                // hosts of w only point at D_w for session w
                for d in net.placement.hosts(w) {
                    let node = net.device_node(d);
                    for e in net.session_out(w, node) {
                        assert_eq!(net.graph.edge(e).dst, net.dnode(w));
                    }
                }
            }
        }
    }

    #[test]
    fn session_edges_strictly_decrease_distance() {
        let net = er_net(8);
        for w in 0..net.n_versions() {
            let dist = net.graph.dist_to(net.dnode(w));
            for (eid, used) in net.session_edges[w].iter().enumerate() {
                if *used {
                    let e = net.graph.edge(eid);
                    assert!(dist[e.dst].unwrap() < dist[e.src].unwrap());
                }
            }
        }
    }

    #[test]
    fn source_cap_is_unconstraining() {
        let net = er_net(2);
        for &e in &net.virtual_edges {
            let edge = net.graph.edge(e);
            if edge.src == AugmentedNet::SOURCE {
                assert_eq!(edge.capacity, SOURCE_CAP);
            }
        }
    }

    #[test]
    fn csr_mirrors_session_lanes_in_topo_order() {
        for seed in 0..6u64 {
            let net = er_net(seed);
            for w in 0..net.n_versions() {
                let rows = net.csr.rows(w);
                // same node set as the cached router list
                let mut row_nodes: Vec<usize> = rows.iter().map(|r| r.node).collect();
                row_nodes.sort_unstable();
                let mut routers = net.session_routers(w).to_vec();
                routers.sort_unstable();
                assert_eq!(row_nodes, routers, "w={w}");
                // rows follow the session topo order
                let pos: std::collections::BTreeMap<usize, usize> = net
                    .session_topo(w)
                    .iter()
                    .enumerate()
                    .map(|(k, &i)| (i, k))
                    .collect();
                for pair in rows.windows(2) {
                    assert!(pos[&pair[0].node] < pos[&pair[1].node]);
                }
                // each row's lanes equal the cached lane list, in order,
                // with matching destinations
                for row in rows {
                    let lanes = &net.csr.lane_edge[row.start..row.end];
                    assert_eq!(lanes, net.lanes(w, row.node));
                    for k in row.start..row.end {
                        assert_eq!(
                            net.csr.lane_dst[k],
                            net.graph.edge(net.csr.lane_edge[k]).dst
                        );
                    }
                }
                // session lane span covers exactly the session's rows
                let (a, b) = net.csr.session_lane_span[w];
                assert_eq!(a, rows.first().map_or(b, |r| r.start));
                assert_eq!(b, rows.last().map_or(a, |r| r.end));
            }
        }
    }

    #[test]
    fn routers_listed_for_each_session() {
        let net = er_net(4);
        for w in 0..net.n_versions() {
            let routers = net.session_routers(w);
            assert!(routers.contains(&AugmentedNet::SOURCE));
            assert!(!routers.contains(&net.dnode(w)));
        }
    }

    #[test]
    fn single_class_heterogeneous_build_matches_default_build() {
        // the default build() must be the exact single-class reduction of
        // build_heterogeneous(): same edges, same RNG stream, same DAGs
        let mut rng_a = Rng::seed_from(11);
        let g = topologies::connected_er_graph(10, 0.3, 10.0, &mut rng_a);
        let pl = Placement::random(10, 3, &mut rng_a);
        let mut rng_b = rng_a.clone();
        let a = AugmentedNet::build(&g, &pl, 10.0, &mut rng_a);
        let sources: Vec<usize> = pl.hosts(0).collect();
        let b =
            AugmentedNet::build_heterogeneous(&g, &pl, 10.0, &[], &[sources], &mut rng_b);
        assert_eq!(a.graph.n_edges(), b.graph.n_edges());
        for (ea, eb) in a.graph.edges().iter().zip(b.graph.edges()) {
            assert_eq!(ea, eb);
        }
        assert_eq!(a.session_version, b.session_version);
        assert_eq!(a.session_edges, b.session_edges);
        assert_eq!(a.csr.lane_edge, b.csr.lane_edge);
    }

    #[test]
    fn multi_class_sessions_are_class_major_and_admission_restricted() {
        let mut rng = Rng::seed_from(3);
        let g = topologies::connected_er_graph(10, 0.35, 10.0, &mut rng);
        let pl = Placement::random(10, 2, &mut rng);
        let class_a: Vec<usize> = pl.hosts(0).collect();
        let class_b = vec![3usize, 7];
        let net = AugmentedNet::build_heterogeneous(
            &g,
            &pl,
            10.0,
            &[],
            &[class_a.clone(), class_b.clone()],
            &mut rng,
        );
        assert_eq!(net.n_sessions(), 4);
        assert_eq!(net.n_versions(), 2);
        assert_eq!(net.session_version, vec![0, 1, 0, 1]);
        // shared destinations per version across classes
        assert_eq!(net.dnode(0), net.dnode(2));
        assert_eq!(net.dnode(1), net.dnode(3));
        // admission lanes of each session point only into its class sources
        for s in 0..net.n_sessions() {
            let admit = &net.session_admit[s];
            for e in net.session_out(s, AugmentedNet::SOURCE) {
                let dst = net.graph.edge(e).dst;
                assert!(admit.binary_search(&dst).is_ok(), "s={s} dst={dst}");
            }
        }
        // class-b sessions admit exactly through devices 3 and 7
        for s in [2usize, 3] {
            assert_eq!(net.session_admit[s], vec![4usize, 8]);
        }
        net.validate().unwrap();
    }

    /// A two-class heterogeneous net (4 sessions over 2 versions).
    fn two_class_net(seed: u64) -> AugmentedNet {
        let mut rng = Rng::seed_from(seed);
        let g = topologies::connected_er_graph(10, 0.35, 10.0, &mut rng);
        let pl = Placement::random(10, 2, &mut rng);
        let class_a: Vec<usize> = pl.hosts(0).collect();
        let class_b = vec![3usize, 7];
        AugmentedNet::build_heterogeneous(&g, &pl, 10.0, &[], &[class_a, class_b], &mut rng)
    }

    #[test]
    fn same_version_sessions_share_one_topo_order() {
        for seed in 0..6u64 {
            let net = two_class_net(seed);
            // class-major sessions [0,1,2,3] over versions [0,1,0,1]
            assert_eq!(net.session_topo(0), net.session_topo(2));
            assert_eq!(net.session_topo(1), net.session_topo(3));
            assert_eq!(net.version_topo.len(), 2, "one stored order per version");
            // the shared order is a valid topo order of every member DAG
            for s in 0..net.n_sessions() {
                let pos: std::collections::BTreeMap<usize, usize> = net
                    .session_topo(s)
                    .iter()
                    .enumerate()
                    .map(|(k, &i)| (i, k))
                    .collect();
                for (e, used) in net.session_edges[s].iter().enumerate() {
                    if *used {
                        let edge = net.graph.edge(e);
                        assert!(pos[&edge.src] < pos[&edge.dst], "s={s} e={e}");
                    }
                }
            }
        }
    }

    #[test]
    fn edge_session_index_is_exact_and_ascending() {
        let net = two_class_net(1);
        for e in 0..net.graph.n_edges() {
            let listed = net.csr.sessions_of_edge(e);
            let expect: Vec<u32> = (0..net.n_sessions())
                .filter(|&s| net.session_edges[s][e])
                .map(|s| s as u32)
                .collect();
            assert_eq!(listed, expect.as_slice(), "edge {e}");
        }
    }

    #[test]
    fn batch_blocks_group_sessions_by_version() {
        let net = two_class_net(2);
        assert_eq!(net.batch.blocks.len(), 2);
        assert_eq!(net.batch.blocks[0].sessions, vec![0, 2]);
        assert_eq!(net.batch.blocks[1].sessions, vec![1, 3]);
        assert_eq!(net.batch.max_width(), 2);
        // every scalar lane's slot points at its own (edge, session) cell
        for s in 0..net.n_sessions() {
            let (b, col) = net.batch.session_slot[s];
            let blk = &net.batch.blocks[b];
            assert_eq!(blk.sessions[col], s);
            let w = blk.padded_width();
            assert_eq!(w, blk.width().next_multiple_of(LANE_PAD));
            let (k0, k1) = net.csr.session_lane_span[s];
            for k in k0..k1 {
                let slot = net.batch.lane_slot[k];
                let local = (slot - blk.slot0 - col) / w;
                assert_eq!((slot - blk.slot0 - col) % w, 0, "slot aligned to column");
                assert_eq!(
                    net.batch.lane_edge[blk.lanes.0 + local],
                    net.csr.lane_edge[k],
                    "s={s} k={k}"
                );
            }
        }
        // block rows follow the shared topo order and union lanes keep
        // adjacency order (each session's scalar lane order is a
        // subsequence)
        for (b, blk) in net.batch.blocks.iter().enumerate() {
            let order = net.session_topo(blk.sessions[0]);
            let pos: std::collections::BTreeMap<usize, usize> =
                order.iter().enumerate().map(|(k, &i)| (i, k)).collect();
            for pair in net.batch.rows(b).windows(2) {
                assert!(pos[&pair[0].node] < pos[&pair[1].node]);
            }
            for row in net.batch.rows(b) {
                for k in row.start..row.end {
                    assert_eq!(
                        net.batch.lane_dst[k],
                        net.graph.edge(net.batch.lane_edge[k]).dst
                    );
                }
            }
        }
        // slot and column accounting adds up (padded strides)
        let total: usize = net
            .batch
            .blocks
            .iter()
            .map(|b| (b.lanes.1 - b.lanes.0) * b.padded_width())
            .sum();
        assert_eq!(net.batch.n_slots, total);
        let cols: usize = net.batch.blocks.iter().map(BatchBlock::padded_width).sum();
        assert_eq!(net.batch.n_cols, cols);
    }

    #[test]
    fn single_class_batch_mirrors_scalar_csr() {
        let net = er_net(5);
        assert_eq!(net.batch.blocks.len(), net.n_versions());
        assert_eq!(net.batch.max_width(), 1);
        for (b, blk) in net.batch.blocks.iter().enumerate() {
            assert_eq!(blk.sessions, vec![b]);
            let brows = net.batch.rows(b);
            let srows = net.csr.rows(b);
            assert_eq!(brows.len(), srows.len());
            for (br, sr) in brows.iter().zip(srows) {
                assert_eq!(br.node, sr.node);
                assert_eq!(
                    &net.batch.lane_edge[br.start..br.end],
                    &net.csr.lane_edge[sr.start..sr.end]
                );
            }
        }
    }

    #[test]
    fn with_pins_covers_every_version() {
        let mut rng = Rng::seed_from(9);
        let pins = [Some(1), None, None, Some(1), None];
        let p = Placement::with_pins(5, 3, &pins, &mut rng).unwrap();
        for w in 0..3 {
            assert!(p.hosts(w).next().is_some(), "version {w} uncovered");
        }
        assert_eq!(p.version_of[0], 1);
        assert_eq!(p.version_of[3], 1);
        // infeasible: every device pinned to version 0 leaves 1 uncovered
        assert!(Placement::with_pins(2, 2, &[Some(0), Some(0)], &mut rng).is_none());
        // out-of-range pin
        assert!(Placement::with_pins(2, 2, &[Some(5), None], &mut rng).is_none());
    }
}
