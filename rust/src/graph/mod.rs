//! Directed-graph substrate: the CEC network topology layer.

pub mod augmented;
pub mod paths;
pub mod topologies;

/// Node identifier (index into the graph's node table).
pub type NodeId = usize;
/// Edge identifier (index into the graph's edge table).
pub type EdgeId = usize;

/// A directed edge with a fixed capacity `C_ij` (bits/sec in the paper).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Edge {
    pub src: NodeId,
    pub dst: NodeId,
    pub capacity: f64,
}

/// Compact directed graph with O(1) out/in neighbour iteration.
///
/// Nodes are dense indices `0..n`. Edges are stored once; adjacency lists
/// hold edge ids so per-edge state (flows, costs) lives in parallel vectors
/// indexed by [`EdgeId`].
#[derive(Clone, Debug, Default)]
pub struct DiGraph {
    edges: Vec<Edge>,
    out_adj: Vec<Vec<EdgeId>>,
    in_adj: Vec<Vec<EdgeId>>,
}

impl DiGraph {
    pub fn with_nodes(n: usize) -> Self {
        DiGraph {
            edges: Vec::new(),
            out_adj: vec![Vec::new(); n],
            in_adj: vec![Vec::new(); n],
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.out_adj.len()
    }

    pub fn n_edges(&self) -> usize {
        self.edges.len()
    }

    /// Append a node, returning its id.
    pub fn add_node(&mut self) -> NodeId {
        self.out_adj.push(Vec::new());
        self.in_adj.push(Vec::new());
        self.out_adj.len() - 1
    }

    /// Add a directed edge; duplicate (src, dst) pairs are rejected.
    pub fn add_edge(&mut self, src: NodeId, dst: NodeId, capacity: f64) -> EdgeId {
        assert!(src < self.n_nodes() && dst < self.n_nodes(), "edge endpoints out of range");
        assert_ne!(src, dst, "self-loops are not allowed");
        debug_assert!(
            self.find_edge(src, dst).is_none(),
            "duplicate edge ({src},{dst})"
        );
        let id = self.edges.len();
        self.edges.push(Edge { src, dst, capacity });
        self.out_adj[src].push(id);
        self.in_adj[dst].push(id);
        id
    }

    pub fn edge(&self, e: EdgeId) -> &Edge {
        &self.edges[e]
    }

    pub fn edges(&self) -> &[Edge] {
        &self.edges
    }

    pub fn find_edge(&self, src: NodeId, dst: NodeId) -> Option<EdgeId> {
        self.out_adj[src].iter().copied().find(|&e| self.edges[e].dst == dst)
    }

    /// Outgoing edge ids of `i` (the paper's `O(i)`).
    pub fn out_edges(&self, i: NodeId) -> &[EdgeId] {
        &self.out_adj[i]
    }

    /// Incoming edge ids of `i` (the paper's `I(i)`).
    pub fn in_edges(&self, i: NodeId) -> &[EdgeId] {
        &self.in_adj[i]
    }

    pub fn out_neighbors(&self, i: NodeId) -> impl Iterator<Item = NodeId> + '_ {
        self.out_adj[i].iter().map(move |&e| self.edges[e].dst)
    }

    pub fn in_neighbors(&self, i: NodeId) -> impl Iterator<Item = NodeId> + '_ {
        self.in_adj[i].iter().map(move |&e| self.edges[e].src)
    }

    /// BFS hop distances from every node *to* `target` (follows edges
    /// forward; computed by BFS on reversed edges).
    pub fn dist_to(&self, target: NodeId) -> Vec<Option<u32>> {
        let mut dist = vec![None; self.n_nodes()];
        dist[target] = Some(0);
        let mut queue = std::collections::VecDeque::from([target]);
        while let Some(u) = queue.pop_front() {
            let du = dist[u].unwrap();
            for &e in &self.in_adj[u] {
                let v = self.edges[e].src;
                if dist[v].is_none() {
                    dist[v] = Some(du + 1);
                    queue.push_back(v);
                }
            }
        }
        dist
    }

    /// BFS hop distances from `source` to every node.
    pub fn dist_from(&self, source: NodeId) -> Vec<Option<u32>> {
        let mut dist = vec![None; self.n_nodes()];
        dist[source] = Some(0);
        let mut queue = std::collections::VecDeque::from([source]);
        while let Some(u) = queue.pop_front() {
            let du = dist[u].unwrap();
            for &e in &self.out_adj[u] {
                let v = self.edges[e].dst;
                if dist[v].is_none() {
                    dist[v] = Some(du + 1);
                    queue.push_back(v);
                }
            }
        }
        dist
    }

    /// Is the graph strongly connected? (Kosaraju-lite: forward + backward BFS
    /// from node 0 both reach everything.)
    pub fn strongly_connected(&self) -> bool {
        if self.n_nodes() == 0 {
            return true;
        }
        self.dist_from(0).iter().all(Option::is_some)
            && self.dist_to(0).iter().all(Option::is_some)
    }

    /// Kahn topological sort restricted to an edge subset mask; `None` if the
    /// sub-graph has a cycle.
    pub fn topo_order(&self, edge_mask: &[bool]) -> Option<Vec<NodeId>> {
        assert_eq!(edge_mask.len(), self.edges.len());
        let n = self.n_nodes();
        let mut indeg = vec![0usize; n];
        for (e, edge) in self.edges.iter().enumerate() {
            if edge_mask[e] {
                indeg[edge.dst] += 1;
            }
        }
        let mut queue: std::collections::VecDeque<NodeId> =
            (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(n);
        while let Some(u) = queue.pop_front() {
            order.push(u);
            for &e in &self.out_adj[u] {
                if edge_mask[e] {
                    let v = self.edges[e].dst;
                    indeg[v] -= 1;
                    if indeg[v] == 0 {
                        queue.push_back(v);
                    }
                }
            }
        }
        if order.len() == n {
            Some(order)
        } else {
            None
        }
    }

    /// Mean link capacity (diagnostics / Table II verification).
    pub fn mean_capacity(&self) -> f64 {
        if self.edges.is_empty() {
            return 0.0;
        }
        self.edges.iter().map(|e| e.capacity).sum::<f64>() / self.edges.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triangle() -> DiGraph {
        let mut g = DiGraph::with_nodes(3);
        g.add_edge(0, 1, 1.0);
        g.add_edge(1, 2, 2.0);
        g.add_edge(2, 0, 3.0);
        g
    }

    #[test]
    fn construction_and_adjacency() {
        let g = triangle();
        assert_eq!(g.n_nodes(), 3);
        assert_eq!(g.n_edges(), 3);
        assert_eq!(g.out_neighbors(0).collect::<Vec<_>>(), vec![1]);
        assert_eq!(g.in_neighbors(0).collect::<Vec<_>>(), vec![2]);
        assert_eq!(g.edge(g.find_edge(1, 2).unwrap()).capacity, 2.0);
        assert!(g.find_edge(2, 1).is_none());
    }

    #[test]
    fn distances() {
        let g = triangle();
        let d = g.dist_to(2);
        assert_eq!(d[2], Some(0));
        assert_eq!(d[1], Some(1));
        assert_eq!(d[0], Some(2));
        let f = g.dist_from(0);
        assert_eq!(f[2], Some(2));
    }

    #[test]
    fn strong_connectivity() {
        assert!(triangle().strongly_connected());
        let mut g = DiGraph::with_nodes(2);
        g.add_edge(0, 1, 1.0);
        assert!(!g.strongly_connected());
    }

    #[test]
    fn topo_sort_dag_and_cycle() {
        let mut g = DiGraph::with_nodes(3);
        g.add_edge(0, 1, 1.0);
        g.add_edge(1, 2, 1.0);
        let order = g.topo_order(&[true, true]).unwrap();
        assert_eq!(order, vec![0, 1, 2]);
        let t = triangle();
        assert!(t.topo_order(&[true, true, true]).is_none());
        // cycle broken by mask
        assert!(t.topo_order(&[true, true, false]).is_some());
    }

    #[test]
    #[should_panic]
    fn rejects_self_loop() {
        let mut g = DiGraph::with_nodes(1);
        g.add_edge(0, 0, 1.0);
    }

    #[test]
    fn mean_capacity_ok() {
        assert!((triangle().mean_capacity() - 2.0).abs() < 1e-12);
    }
}
