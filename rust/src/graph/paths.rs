//! Path enumeration over session DAGs — the substrate of the centralized
//! OPT baseline (the paper's Fig. 7 "OPT": the operator knows the whole
//! topology, enumerates every S→D_w path, and solves the convex path-flow
//! program).

use super::augmented::AugmentedNet;
use super::{EdgeId, NodeId};

/// One source→destination path as a sequence of edge ids.
#[derive(Clone, Debug, PartialEq)]
pub struct Path {
    pub session: usize,
    pub edges: Vec<EdgeId>,
}

/// Enumerate every path `S -> D_w` inside session `w`'s DAG, up to `cap`
/// paths (DAGs keep this finite; `cap` guards pathological ER draws).
pub fn enumerate_paths(net: &AugmentedNet, w: usize, cap: usize) -> Vec<Path> {
    let mut out = Vec::new();
    let mut stack: Vec<EdgeId> = Vec::new();
    dfs(net, w, AugmentedNet::SOURCE, net.dnode(w), &mut stack, &mut out, cap);
    out
}

fn dfs(
    net: &AugmentedNet,
    w: usize,
    u: NodeId,
    target: NodeId,
    stack: &mut Vec<EdgeId>,
    out: &mut Vec<Path>,
    cap: usize,
) {
    if out.len() >= cap {
        return;
    }
    if u == target {
        out.push(Path { session: w, edges: stack.clone() });
        return;
    }
    for e in net.session_out(w, u) {
        stack.push(e);
        dfs(net, w, net.graph.edge(e).dst, target, stack, out, cap);
        stack.pop();
        if out.len() >= cap {
            return;
        }
    }
}

/// Count paths without materializing them (DP over the DAG topo order).
pub fn count_paths(net: &AugmentedNet, w: usize) -> u64 {
    let n = net.n_nodes();
    let mut count = vec![0u64; n];
    count[net.dnode(w)] = 1;
    // reverse topological order: destinations first
    for &i in net.session_topo(w).iter().rev() {
        if i == net.dnode(w) {
            continue;
        }
        let mut c = 0u64;
        for e in net.session_out(w, i) {
            c = c.saturating_add(count[net.graph.edge(e).dst]);
        }
        count[i] = c;
    }
    count[AugmentedNet::SOURCE]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topologies;
    use crate::util::rng::Rng;

    #[test]
    fn paths_reach_destination_and_match_count() {
        let mut rng = Rng::seed_from(12);
        let net = topologies::connected_er(10, 0.35, 3, &mut rng);
        for w in 0..3 {
            let paths = enumerate_paths(&net, w, 1_000_000);
            assert_eq!(paths.len() as u64, count_paths(&net, w));
            assert!(!paths.is_empty());
            for p in &paths {
                // starts at S, ends at D_w, contiguous
                let first = net.graph.edge(p.edges[0]);
                assert_eq!(first.src, AugmentedNet::SOURCE);
                let last = net.graph.edge(*p.edges.last().unwrap());
                assert_eq!(last.dst, net.dnode(w));
                for win in p.edges.windows(2) {
                    assert_eq!(net.graph.edge(win[0]).dst, net.graph.edge(win[1]).src);
                }
                // all edges belong to the session DAG
                assert!(p.edges.iter().all(|&e| net.session_edges[w][e]));
            }
        }
    }

    #[test]
    fn cap_limits_enumeration() {
        let mut rng = Rng::seed_from(99);
        let net = topologies::connected_er(14, 0.4, 3, &mut rng);
        let some = enumerate_paths(&net, 0, 5);
        assert!(some.len() <= 5);
    }

    #[test]
    fn paths_are_simple() {
        // DAG property: no node repeats within a path
        let mut rng = Rng::seed_from(21);
        let net = topologies::connected_er(9, 0.4, 2, &mut rng);
        for p in enumerate_paths(&net, 1, 10_000) {
            let mut seen = std::collections::BTreeSet::new();
            seen.insert(AugmentedNet::SOURCE);
            for &e in &p.edges {
                assert!(seen.insert(net.graph.edge(e).dst), "node repeated");
            }
        }
    }
}
