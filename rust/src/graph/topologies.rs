//! Topology generators for every network family in the paper's evaluation
//! (Section IV + Appendix F, Table II).
//!
//! All generators produce the *real* device network as a [`DiGraph`] in which
//! each physical link is a pair of directed edges with the same capacity.
//! Capacities are drawn uniformly with mean `cap_mean`, truncated to
//! `[0.2, 1.8] * cap_mean` (the paper draws from `[0, 2C̄]`; we keep the mean
//! but stay away from 0 so the exp link cost remains finite in f32 on the
//! XLA hot path — see DESIGN.md §3).

use super::DiGraph;
use crate::util::rng::Rng;

/// Draw a truncated-uniform capacity with mean `cap_mean`.
fn draw_cap(rng: &mut Rng, cap_mean: f64) -> f64 {
    rng.uniform(0.2 * cap_mean, 1.8 * cap_mean)
}

/// Add an undirected (bidirectional) link with one sampled capacity.
fn add_link(g: &mut DiGraph, rng: &mut Rng, u: usize, v: usize, cap_mean: f64) {
    let c = draw_cap(rng, cap_mean);
    g.add_edge(u, v, c);
    g.add_edge(v, u, c);
}

fn from_pairs(n: usize, pairs: &[(usize, usize)], cap_mean: f64, rng: &mut Rng) -> DiGraph {
    let mut g = DiGraph::with_nodes(n);
    for &(u, v) in pairs {
        add_link(&mut g, rng, u, v, cap_mean);
    }
    debug_assert!(g.strongly_connected(), "named topology must be connected");
    g
}

/// **Connected-ER(n, p)** — connectivity-guaranteed Erdős–Rényi: sample each
/// undirected pair with probability `p`, resample until connected.
/// The paper's default experiment: n=25, p=0.2, C̄=10.
pub fn connected_er_graph(n: usize, p: f64, cap_mean: f64, rng: &mut Rng) -> DiGraph {
    assert!(n >= 2);
    loop {
        let mut g = DiGraph::with_nodes(n);
        for u in 0..n {
            for v in (u + 1)..n {
                if rng.chance(p) {
                    add_link(&mut g, rng, u, v, cap_mean);
                }
            }
        }
        if g.n_edges() > 0 && g.strongly_connected() {
            return g;
        }
    }
}

/// **Abilene** (Fig. 3; Table II: |N|=11, |E|=14, C̄=15) — the Internet2
/// predecessor backbone. Node order: Seattle, Sunnyvale, Denver, LA,
/// Houston, Kansas City, Indianapolis, Atlanta, Chicago, New York,
/// Washington DC.
pub fn abilene(cap_mean: f64, rng: &mut Rng) -> DiGraph {
    const PAIRS: [(usize, usize); 14] = [
        (0, 1), // Seattle - Sunnyvale
        (0, 2), // Seattle - Denver
        (1, 3), // Sunnyvale - LA
        (1, 2), // Sunnyvale - Denver
        (3, 4), // LA - Houston
        (2, 5), // Denver - Kansas City
        (4, 5), // Houston - Kansas City
        (4, 7), // Houston - Atlanta
        (5, 6), // Kansas City - Indianapolis
        (6, 7), // Indianapolis - Atlanta
        (6, 8), // Indianapolis - Chicago
        (8, 9), // Chicago - New York
        (7, 10), // Atlanta - Washington DC
        (9, 10), // New York - Washington DC
    ];
    from_pairs(11, &PAIRS, cap_mean, rng)
}

/// **Balanced-tree** (Fig. 4; Table II: |N|=14, |E|=23, C̄=10) — a complete
/// binary tree over 14 nodes (13 tree links) augmented with 10 deterministic
/// sibling/cousin cross-links to reach Table II's 23 physical links.
pub fn balanced_tree(cap_mean: f64, rng: &mut Rng) -> DiGraph {
    let mut pairs: Vec<(usize, usize)> = Vec::new();
    // complete binary tree, nodes 0..14, children of i: 2i+1, 2i+2
    for i in 0..14usize {
        for c in [2 * i + 1, 2 * i + 2] {
            if c < 14 {
                pairs.push((i, c));
            }
        }
    }
    // cross links: siblings at each level + level-skipping chords
    let cross: [(usize, usize); 10] =
        [(1, 2), (3, 4), (5, 6), (7, 8), (9, 10), (11, 12), (3, 5), (4, 6), (7, 11), (8, 12)];
    pairs.extend_from_slice(&cross);
    assert_eq!(pairs.len(), 23);
    from_pairs(14, &pairs, cap_mean, rng)
}

/// **Fog** (Fig. 5; Table II: |N|=15, |E|=30, C̄=10) — the layered
/// fog-computing sample of Kamran et al. (DECO): 8 leaf edge devices, 4 fog
/// nodes, 2 aggregation nodes, 1 cloud root; leaves dual-homed to fog layer,
/// fog nodes in a ring and dual-homed to aggregation, aggregation to cloud.
pub fn fog(cap_mean: f64, rng: &mut Rng) -> DiGraph {
    // layout: 0..8 leaves, 8..12 fog, 12..14 aggregation, 14 cloud
    let mut pairs: Vec<(usize, usize)> = Vec::new();
    for leaf in 0..8usize {
        let f1 = 8 + leaf / 2;
        let f2 = 8 + (leaf / 2 + 1) % 4;
        pairs.push((leaf, f1));
        pairs.push((leaf, f2));
    }
    for f in 0..4usize {
        pairs.push((8 + f, 8 + (f + 1) % 4)); // fog ring
        pairs.push((8 + f, 12 + f % 2)); // fog -> aggregation
    }
    pairs.push((12, 13));
    pairs.push((12, 14));
    pairs.push((13, 14));
    // cross-tier shortcuts to reach 30 links (all distinct from the above)
    pairs.push((8, 13));
    pairs.push((9, 12));
    pairs.push((0, 10));
    assert_eq!(pairs.len(), 30);
    from_pairs(15, &pairs, cap_mean, rng)
}

/// **GEANT** (Fig. 6; Table II: |N|=22, |E|=33, C̄=10) — pan-European
/// research network; we use the 22-PoP abstraction with 33 physical links
/// (a ring backbone with meshed core chords), matching Table II's
/// cardinalities.
pub fn geant(cap_mean: f64, rng: &mut Rng) -> DiGraph {
    let mut pairs: Vec<(usize, usize)> = Vec::new();
    for i in 0..22usize {
        pairs.push((i, (i + 1) % 22)); // 22-node ring
    }
    // 11 core chords
    let chords: [(usize, usize); 11] = [
        (0, 11),
        (2, 9),
        (4, 13),
        (6, 15),
        (8, 17),
        (10, 19),
        (1, 12),
        (3, 16),
        (5, 18),
        (7, 20),
        (14, 21),
    ];
    pairs.extend_from_slice(&chords);
    assert_eq!(pairs.len(), 33);
    from_pairs(22, &pairs, cap_mean, rng)
}

/// **Line(n)** — a bidirectional chain `0 — 1 — … — n−1`: the deepest
/// session DAGs per node count (worst case for the topological sweeps,
/// used by the engine equivalence property tests).
pub fn line(n: usize, cap_mean: f64, rng: &mut Rng) -> DiGraph {
    assert!(n >= 2);
    let pairs: Vec<(usize, usize)> = (0..n - 1).map(|i| (i, i + 1)).collect();
    from_pairs(n, &pairs, cap_mean, rng)
}

/// **Star(n)** — hub node 0 with `n − 1` bidirectional spokes: the
/// shallowest nontrivial session DAGs (every route is hub-mediated).
pub fn star(n: usize, cap_mean: f64, rng: &mut Rng) -> DiGraph {
    assert!(n >= 3);
    let pairs: Vec<(usize, usize)> = (1..n).map(|v| (0, v)).collect();
    from_pairs(n, &pairs, cap_mean, rng)
}

/// Canonical node count for the named `"line"` / `"star"` lookups.
pub const LINE_STAR_DEFAULT_N: usize = 10;

/// Every name accepted by topology construction: the synthetic `"er"`
/// family (handled by `ExperimentConfig::build_problem`) plus the
/// [`by_name`] lookups. Keep in sync with the `match` in [`by_name`]; the
/// session error messages derive their suggestions from this list.
pub const KNOWN_NAMES: [&str; 8] =
    ["er", "abilene", "tree", "balanced-tree", "fog", "geant", "line", "star"];

/// Named lookup used by the CLI and the fig12–15 bench.
pub fn by_name(name: &str, cap_mean: f64, rng: &mut Rng) -> Option<DiGraph> {
    match name {
        "abilene" => Some(abilene(cap_mean, rng)),
        "tree" | "balanced-tree" => Some(balanced_tree(cap_mean, rng)),
        "fog" => Some(fog(cap_mean, rng)),
        "geant" => Some(geant(cap_mean, rng)),
        "line" => Some(line(LINE_STAR_DEFAULT_N, cap_mean, rng)),
        "star" => Some(star(LINE_STAR_DEFAULT_N, cap_mean, rng)),
        _ => None,
    }
}

/// Table II defaults: (name, |N|, undirected |E|, C̄).
pub const TABLE2: [(&str, usize, usize, f64); 4] = [
    ("abilene", 11, 14, 15.0),
    ("tree", 14, 23, 10.0),
    ("fog", 15, 30, 10.0),
    ("geant", 22, 33, 10.0),
];

/// Convenience: build the paper's default experiment network
/// (Connected-ER(n, p) + random placements) as an [`super::augmented::AugmentedNet`].
pub fn connected_er(
    n: usize,
    p: f64,
    n_versions: usize,
    rng: &mut Rng,
) -> super::augmented::AugmentedNet {
    let g = connected_er_graph(n, p, 10.0, rng);
    let placements = super::augmented::Placement::random(n, n_versions, rng);
    super::augmented::AugmentedNet::build(&g, &placements, 10.0, rng)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_cardinalities() {
        let mut rng = Rng::seed_from(1);
        for &(name, n, e, cbar) in TABLE2.iter() {
            let g = by_name(name, cbar, &mut rng).unwrap();
            assert_eq!(g.n_nodes(), n, "{name} |N|");
            assert_eq!(g.n_edges(), 2 * e, "{name} |E| (directed)");
            assert!(g.strongly_connected(), "{name} connectivity");
            let mc = g.mean_capacity();
            assert!((mc - cbar).abs() < cbar * 0.35, "{name} mean cap {mc} vs {cbar}");
        }
    }

    #[test]
    fn er_connected_and_sized() {
        let mut rng = Rng::seed_from(7);
        for &n in &[10usize, 25, 40] {
            let g = connected_er_graph(n, 0.2, 10.0, &mut rng);
            assert_eq!(g.n_nodes(), n);
            assert!(g.strongly_connected());
            // bidirectional pairing: every edge has its reverse with equal cap
            for e in g.edges() {
                let rid = g.find_edge(e.dst, e.src).expect("reverse edge");
                assert_eq!(g.edge(rid).capacity, e.capacity);
            }
        }
    }

    #[test]
    fn er_deterministic_per_seed() {
        let g1 = connected_er_graph(15, 0.3, 10.0, &mut Rng::seed_from(5));
        let g2 = connected_er_graph(15, 0.3, 10.0, &mut Rng::seed_from(5));
        assert_eq!(g1.n_edges(), g2.n_edges());
        for (a, b) in g1.edges().iter().zip(g2.edges()) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn capacities_truncated_mean_ok() {
        let mut rng = Rng::seed_from(11);
        let g = connected_er_graph(30, 0.3, 10.0, &mut rng);
        for e in g.edges() {
            assert!(e.capacity >= 2.0 && e.capacity <= 18.0);
        }
    }

    #[test]
    fn unknown_name_is_none() {
        let mut rng = Rng::seed_from(1);
        assert!(by_name("nope", 10.0, &mut rng).is_none());
    }

    #[test]
    fn line_and_star_shapes() {
        let mut rng = Rng::seed_from(3);
        let l = line(7, 10.0, &mut rng);
        assert_eq!(l.n_nodes(), 7);
        assert_eq!(l.n_edges(), 2 * 6);
        assert!(l.strongly_connected());
        let s = star(7, 10.0, &mut rng);
        assert_eq!(s.n_nodes(), 7);
        assert_eq!(s.n_edges(), 2 * 6);
        assert!(s.strongly_connected());
        // every spoke touches the hub
        for e in s.edges() {
            assert!(e.src == 0 || e.dst == 0);
        }
        // named lookups resolve
        assert!(by_name("line", 10.0, &mut rng).is_some());
        assert!(by_name("star", 10.0, &mut rng).is_some());
    }
}
