//! # jowr — Joint Optimization of Workload allocation and Routing in CEC
//!
//! A production-grade reproduction of *"Online Optimization of DNN Inference
//! Network Utility in Collaborative Edge Computing"* (Li, Ouyang, Zeng, Liao,
//! Zhou, Chen; 2024).
//!
//! The crate is the Layer-3 **rust coordinator** of a three-layer stack:
//!
//! * **L3 (this crate)** — the CEC control plane: graph/topology substrate,
//!   flow model, marginal-cost broadcast, the paper's OMD-RT routing and
//!   GS-OMA / OMAD allocation algorithms, the SGP / GP / OPT baselines, an
//!   actor-based distributed runtime, and a discrete-event serving simulator.
//! * **L2 (python/compile/model.py)** — a full OMD-RT iteration as a JAX
//!   tensor program plus the served DNN family, AOT-lowered to HLO text.
//! * **L1 (python/compile/kernels)** — Pallas kernels for the mirror-descent
//!   update and link-cost evaluation.
//!
//! Python never runs at request time: [`runtime`] loads the AOT artifacts
//! through the PJRT C API (`xla` crate) and the binary is self-contained.
//!
//! Quickstart (see `examples/quickstart.rs`):
//!
//! ```no_run
//! use jowr::prelude::*;
//! let mut rng = Rng::seed_from(7);
//! let net = topologies::connected_er(25, 0.2, 3, &mut rng);
//! let problem = Problem::new(net, 60.0, CostKind::Exp);
//! let mut omd = OmdRouter::new(0.1);
//! let sol = omd.solve(&problem, &problem.uniform_allocation(), 50);
//! println!("total network cost = {}", sol.cost);
//! ```

pub mod allocation;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod graph;
pub mod metrics;
pub mod model;
pub mod routing;
pub mod runtime;
pub mod testkit;
pub mod util;

/// Convenience re-exports for examples / benches / the CLI.
pub mod prelude {
    pub use crate::allocation::{gsoma::GsOma, omad::Omad, Allocator, UtilityOracle};
    pub use crate::graph::augmented::{AugmentedNet, Placement};
    pub use crate::graph::topologies;
    pub use crate::graph::DiGraph;
    pub use crate::model::cost::CostKind;
    pub use crate::model::utility::{Utility, UtilityKind};
    pub use crate::model::Problem;
    pub use crate::routing::{
        gp::GpRouter, omd::OmdRouter, opt::OptRouter, sgp::SgpRouter, Router, RoutingState,
    };
    pub use crate::util::rng::Rng;
}
