//! # jowr — Joint Optimization of Workload allocation and Routing in CEC
//!
//! A production-grade reproduction of *"Online Optimization of DNN Inference
//! Network Utility in Collaborative Edge Computing"* (Li, Ouyang, Zeng, Liao,
//! Zhou, Chen; 2024).
//!
//! The crate is the Layer-3 **rust coordinator** of a three-layer stack:
//!
//! * **L3 (this crate)** — the CEC control plane: graph/topology substrate,
//!   flow model, marginal-cost broadcast, the paper's OMD-RT routing and
//!   GS-OMA / OMAD allocation algorithms, the SGP / GP / OPT baselines, an
//!   actor-based distributed runtime, and a discrete-event serving simulator.
//!   All per-iteration numerics run on the [`engine::FlowEngine`] — fused
//!   forward/reverse sweeps over a flat CSR lane index, session-parallel
//!   (`--workers`), bit-identical at any worker count.
//! * **L2 (python/compile/model.py)** — a full OMD-RT iteration as a JAX
//!   tensor program plus the served DNN family, AOT-lowered to HLO text.
//! * **L1 (python/compile/kernels)** — Pallas kernels for the mirror-descent
//!   update and link-cost evaluation.
//!
//! Python never runs at request time: the optional [`runtime`] module
//! (behind the `xla` cargo feature, which additionally needs the external
//! `xla` + `anyhow` crates) loads the AOT artifacts through the PJRT C API
//! so the binary is self-contained.
//!
//! ## The session API
//!
//! All entry points go through [`session`]: describe a scenario with the
//! typed [`session::Scenario`] builder, validate it into a
//! [`session::Session`], and instantiate any registered algorithm *by name*
//! from the [`session::registry`]. Execution is streaming and step-driven:
//! a run advances one iteration per `step()`, stop rules decide
//! termination, and observers record trajectories (see
//! `examples/quickstart.rs`):
//!
//! ```no_run
//! use jowr::prelude::*;
//!
//! # fn main() -> Result<(), SessionError> {
//! // the paper's Section-IV scenario, validated up front
//! let session = Scenario::paper_default().utility("log").seed(7).build()?;
//!
//! // any registered router by name: "omd" | "omd-fixed" | "sgp" | "gp" | "opt"
//! let mut traj = Trajectory::default();
//! let report = session.routing_run("omd", 50)?.observe(&mut traj).finish();
//! println!("total network cost {:.4} -> {:.4}", traj.values[0], report.objective);
//!
//! // allocation runs pair the allocator with its matching utility oracle
//! let report = session.allocation_run("omad", 100)?.finish();
//! println!("final allocation Λ = {:?} ({:?})", report.lam, report.stop);
//! # Ok(())
//! # }
//! ```
//!
//! The distributed coordinator (paper Sec. V) is a first-class session
//! citizen: `"distributed-omd"` in the registry, or
//! [`session::Session::distributed_run`] for the typed entry point. One
//! step is one barriered message-passing round over live node actors, and
//! the final [`session::RunReport::comm`] carries the
//! communication-overhead telemetry
//! ([`coordinator::net::CommStats`]). With the deterministic per-slot
//! ingress summation, distributed rounds are bit-identical to centralized
//! OMD-RT iterations at any engine worker count.
//!
//! For fleet scale the plane shards: `"sharded-omd"`
//! ([`coordinator::shard::ShardedOmd`]) partitions sessions across K
//! leader shards connected by a pluggable
//! [`coordinator::transport::Transport`] fabric, gossiping sparse flow
//! deltas under an explicit staleness bound S (a shard proceeds once peer
//! aggregates are ≤ S rounds stale; a violated bound is a typed
//! [`session::SessionError::StalenessExceeded`], never a hang). K=1
//! degenerates to the single-leader plane bit-for-bit. The solver knob
//! surface is unified in [`session::registry::SolverOpts`] — workers,
//! batch mode, η, shards, staleness — applied by the registry and
//! round-tripped through [`session::spec::ScenarioSpec`] JSON.
//!
//! ## Declarative scenarios and suites
//!
//! Scenarios are also first-class *data*: a typed
//! [`session::spec::ScenarioSpec`] describes heterogeneous node
//! capacities, explicit or generated edge lists (with per-edge cost
//! families), and multiple task classes — each with its own source-device
//! set, rate (constant or piecewise trace), and utility family — and
//! round-trips through JSON (`--scenario file.json` on the CLI, committed
//! examples under `examples/scenarios/`). The [`session::suite::Suite`]
//! runner evaluates a `(scenario × solver × seed)` grid in parallel on the
//! engine worker pool and streams the per-cell [`session::RunReport`]s
//! into a [`session::suite::SuiteReport`] (CSV + JSON dumps).
//!
//! ## Request-level simulation
//!
//! The [`sim`] subsystem replays *individual requests* through an
//! optimized `(Λ, φ)` configuration on a deterministic discrete-event
//! core: per-node M/M/c-style compute stations, per-link transmission
//! queues, probabilistic φ-sampled routing, and Poisson / trace-driven
//! arrivals. [`session::Session::sim_run`] is the streaming entry point
//! (windowed, stop-rule/observer-compatible, optionally driven by a live
//! [`session::AllocationRun`] re-optimizing between windows), the CLI
//! exposes it as the `sim` subcommand, and suites grow sim columns via
//! [`session::suite::Suite::sim`]. The roll-up [`sim::SimReport`] carries
//! per-class p50/p99/p999 latency, per-node queue-depth telemetry, and
//! drop rates — the request-granularity view the fluid model cannot see.
//! The hot path runs on a calendar-queue scheduler, flat CSR routing
//! tables, and a slab request pool — each pinned bitwise against the
//! naive reference engine ([`sim::reference`]) — and scales to
//! multi-million-request replays with O(peak in-flight) memory
//! (opt-in streaming latency histograms via [`sim::LatencyMode::Hdr`]).
//!
//! ### Deprecation path
//!
//! Direct construction — `OmdRouter::new(0.1).solve(&problem, &lam, 50)` —
//! still works and remains the right tool *inside* algorithm code, but it
//! is deprecated as an application entry point: it bypasses scenario
//! validation, hard-codes the algorithm choice, and cannot record
//! trajectories. `Router::solve` / `Allocator::run` now return the same
//! unified [`session::RunReport`] as streaming runs (the legacy
//! `RoutingState` / `AllocationState` structs are gone); new code should
//! build a [`session::Scenario`] (or load a
//! [`session::spec::ScenarioSpec`]) and drive a [`session::RoutingRun`] /
//! [`session::AllocationRun`] / [`session::DistributedRun`] — hand-off
//! goes through `RunReport` (`final_phi` for warm starts, `comm` for the
//! fabric telemetry).

pub mod allocation;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod experiments;
pub mod graph;
pub mod metrics;
pub mod model;
pub mod routing;
#[cfg(feature = "xla")]
pub mod runtime;
pub mod session;
pub mod sim;
pub mod testkit;
pub mod util;

/// Convenience re-exports for examples / benches / the CLI.
pub mod prelude {
    pub use crate::allocation::{gsoma::GsOma, omad::Omad, Allocator, UtilityOracle};
    pub use crate::coordinator::leader::DistributedOmd;
    pub use crate::coordinator::net::CommStats;
    pub use crate::coordinator::shard::{ShardPlane, ShardedOmd};
    pub use crate::coordinator::transport::{Blackhole, Loopback, ShardComm, Transport};
    pub use crate::engine::{BatchMode, FlowEngine, SessionMask};
    pub use crate::graph::augmented::{AugmentedNet, Placement};
    pub use crate::graph::topologies;
    pub use crate::graph::DiGraph;
    pub use crate::model::cost::CostKind;
    pub use crate::model::utility::{Utility, UtilityKind};
    pub use crate::model::{Problem, Workload};
    pub use crate::routing::{
        gp::GpRouter, omd::OmdRouter, opt::OptRouter, sgp::SgpRouter, Router,
    };
    pub use crate::session::run::{
        AllocationRun, Deadline, DistributedRun, MaxIters, Observer, Progress, RoutingRun,
        RunReport, SimRun, StepInfo, StopReason, StopRule, Tolerance, ToleranceStrict,
        Trajectory,
    };
    pub use crate::session::spec::{
        ClassSpec, EdgeSpec, NodeSpec, RateSpec, ScenarioSpec, TopologySpec,
    };
    pub use crate::session::suite::{Suite, SuiteCell, SuiteReport};
    pub use crate::session::{registry, Hyper, Scenario, Session, SessionError};
    pub use crate::sim::{
        simulate_requests, simulate_requests_reference, ArrivalTrace, Discipline, LatencyMode,
        SimReport, SimSpec, Simulator,
    };
    pub use crate::util::rng::Rng;
}
