//! `jowr` — CLI launcher for the JOWR system.
//!
//! ```text
//! jowr fig --id 7 [--iters 200] [--seed 42]       regenerate a paper figure
//! jowr fig --id all                               every figure + table
//! jowr topo --name abilene | --all                topology stats (Table II)
//! jowr route [--n 25] [--p 0.2] [--algo omd|sgp|gp|opt] [--iters 50]
//! jowr allocate [--family log] [--algo gsoma|omad] [--iters 60]
//! jowr serve [--sim-time 20] [--iters 40] [--xla] end-to-end serving demo
//! jowr runtime-check                              AOT artifact smoke test
//! jowr config --dump                              print the default config
//! ```

use jowr::allocation::{gsoma::GsOma, omad::Omad, Allocator, AnalyticOracle, SingleStepOracle};
use jowr::config::ExperimentConfig;
use jowr::coordinator::serving::{AnalyticEngine, MeasuredOracle, ServeParams};
use jowr::experiments;
use jowr::graph::topologies;
use jowr::model::utility::family;
use jowr::prelude::*;
use jowr::routing::Router;
use jowr::util::cli::Args;

fn main() {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        usage();
        std::process::exit(2);
    }
    let cmd = argv.remove(0);
    let args = match Args::parse(argv) {
        Ok(a) => a,
        Err(e) => die(&e),
    };
    let result = match cmd.as_str() {
        "fig" => cmd_fig(&args),
        "topo" => cmd_topo(&args),
        "route" => cmd_route(&args),
        "dist" => cmd_dist(&args),
        "allocate" => cmd_allocate(&args),
        "serve" => cmd_serve(&args),
        "runtime-check" => cmd_runtime_check(&args),
        "config" => cmd_config(&args),
        "help" | "--help" | "-h" => {
            usage();
            Ok(())
        }
        other => Err(format!("unknown subcommand '{other}' (try `jowr help`)")),
    };
    if let Err(e) = result.and_then(|_| args.finish()) {
        die(&e);
    }
}

fn die(msg: &str) -> ! {
    eprintln!("error: {msg}");
    std::process::exit(2)
}

fn usage() {
    println!(
        "jowr — online optimization of DNN inference network utility in CEC\n\n\
         subcommands:\n  \
         fig --id 7|8|9|10|11|12|all    regenerate paper figures\n  \
         topo --name <x> | --all        topology stats (Table II)\n  \
         route [--algo omd|sgp|gp|opt]  run one routing solve\n  \
         dist [--rounds 50]             distributed OMD-RT (actors + comm stats)\n  \
         allocate [--algo gsoma|omad]   run one allocation solve\n  \
         serve [--xla]                  end-to-end serving demo\n  \
         runtime-check                  AOT artifact smoke test\n  \
         config --dump                  print default config JSON"
    );
}

fn load_cfg(args: &Args) -> Result<ExperimentConfig, String> {
    let mut cfg = match args.get("config") {
        Some(path) => ExperimentConfig::from_file(std::path::Path::new(path))?,
        None => ExperimentConfig::paper_default(),
    };
    cfg.n_nodes = args.usize_or("n", cfg.n_nodes)?;
    cfg.p_link = args.f64_or("p", cfg.p_link)?;
    cfg.total_rate = args.f64_or("rate", cfg.total_rate)?;
    cfg.seed = args.u64_or("seed", cfg.seed)?;
    if let Some(f) = args.get("family") {
        cfg.utility = f.to_string();
    }
    Ok(cfg)
}

fn cmd_fig(args: &Args) -> Result<(), String> {
    let cfg = load_cfg(args)?;
    let id = args.get_or("id", "all").to_string();
    let iters = args.usize_or("iters", 0)?;
    let run = |which: &str| match which {
        "7" => {
            experiments::fig7(&cfg, if iters > 0 { iters } else { 200 });
        }
        "8" | "9" => {
            experiments::fig8_9(&cfg, &[20, 25, 30, 35, 40], if iters > 0 { iters } else { 50 });
        }
        "10" => {
            experiments::fig10(&cfg, if iters > 0 { iters } else { 60 });
        }
        "11" => {
            experiments::fig11(&cfg, if iters > 0 { iters } else { 100 }, 50);
        }
        "12" | "13" | "14" | "15" => {
            experiments::fig12_15(&cfg, if iters > 0 { iters } else { 100 });
        }
        _ => {}
    };
    match id.as_str() {
        "all" => {
            experiments::table2();
            for f in ["7", "8", "10", "11", "12"] {
                run(f);
            }
        }
        other => run(other),
    }
    Ok(())
}

fn cmd_topo(args: &Args) -> Result<(), String> {
    if args.flag("all") {
        experiments::table2();
        return Ok(());
    }
    let name = args.get("name").ok_or("need --name or --all")?.to_string();
    let mut rng = Rng::seed_from(args.u64_or("seed", 1)?);
    let g = topologies::by_name(&name, 10.0, &mut rng)
        .ok_or_else(|| format!("unknown topology '{name}'"))?;
    println!("{name}: |N|={} |E|={} (directed), C̄={:.2}", g.n_nodes(), g.n_edges(), g.mean_capacity());
    for e in g.edges() {
        println!("  {} -> {}  C={:.2}", e.src, e.dst, e.capacity);
    }
    Ok(())
}

fn cmd_route(args: &Args) -> Result<(), String> {
    let cfg = load_cfg(args)?;
    let iters = args.usize_or("iters", 50)?;
    let algo = args.get_or("algo", "omd").to_string();
    let mut rng = Rng::seed_from(cfg.seed);
    let problem = cfg.build_problem(&mut rng);
    let lam = problem.uniform_allocation();
    println!(
        "routing on {} (n_real={}, λ={}, W={}) with {algo}, {iters} iters",
        cfg.topology, problem.net.n_real, cfg.total_rate, cfg.n_versions
    );
    let sol = match algo.as_str() {
        "omd" => OmdRouter::new(cfg.eta_routing).solve(&problem, &lam, iters),
        "sgp" => SgpRouter::new().solve(&problem, &lam, iters),
        "gp" => GpRouter::new(0.002).solve(&problem, &lam, iters),
        "opt" => {
            let o = OptRouter::new().solve(&problem, &lam);
            println!(
                "OPT cost {:.6} in {} iterations ({:.3}s)",
                o.cost, o.iterations, o.elapsed_s
            );
            return Ok(());
        }
        other => return Err(format!("unknown algo '{other}'")),
    };
    println!(
        "cost {:.6} -> {:.6} in {} iters ({:.4}s)",
        sol.trajectory[0], sol.cost, sol.iterations, sol.elapsed_s
    );
    Ok(())
}

fn cmd_dist(args: &Args) -> Result<(), String> {
    let cfg = load_cfg(args)?;
    let rounds = args.usize_or("rounds", 50)?;
    let mut rng = Rng::seed_from(cfg.seed);
    let problem = cfg.build_problem(&mut rng);
    let lam = problem.uniform_allocation();
    println!(
        "distributed OMD-RT: {} node actors + leader, {rounds} barriered rounds",
        problem.net.n_real
    );
    let dist = jowr::coordinator::leader::DistributedOmd::new(cfg.eta_routing);
    let (sol, comm) = dist.solve(&problem, &lam, rounds);
    println!(
        "cost {:.6} -> {:.6} in {:.3}s",
        sol.trajectory[0], sol.cost, sol.elapsed_s
    );
    println!(
        "communication: {} messages, {} bytes total ({:.1} msgs/round, {:.1} B/round/device)",
        comm.messages,
        comm.bytes,
        comm.messages as f64 / rounds as f64,
        comm.bytes as f64 / rounds as f64 / problem.net.n_real as f64
    );
    // cross-check against the centralized solver
    let central = OmdRouter::new(cfg.eta_routing).solve(&problem, &lam, rounds);
    let rel = (sol.cost - central.cost).abs() / central.cost.abs().max(1.0);
    println!("centralized cross-check: cost {:.6} (rel diff {rel:.2e})", central.cost);
    Ok(())
}

fn cmd_allocate(args: &Args) -> Result<(), String> {
    let cfg = load_cfg(args)?;
    let iters = args.usize_or("iters", 60)?;
    let algo = args.get_or("algo", "gsoma").to_string();
    let mut rng = Rng::seed_from(cfg.seed);
    let problem = cfg.build_problem(&mut rng);
    let utilities = family(&cfg.utility, cfg.n_versions, cfg.total_rate)
        .ok_or_else(|| format!("unknown utility family '{}'", cfg.utility))?;
    let st = match algo.as_str() {
        "gsoma" => {
            let mut o = AnalyticOracle::new(problem, utilities);
            GsOma::new(cfg.delta, cfg.eta_alloc).run(&mut o, iters)
        }
        "omad" => {
            let mut o = SingleStepOracle::new(problem, utilities, cfg.eta_routing);
            Omad::new(cfg.delta, cfg.eta_alloc).run(&mut o, iters)
        }
        other => return Err(format!("unknown algo '{other}'")),
    };
    println!(
        "{algo} ({} utility): U {:.4} -> {:.4} in {} outer iters, {} routing iters ({:.3}s)",
        cfg.utility,
        st.trajectory[0],
        st.trajectory.last().unwrap(),
        st.iterations,
        st.routing_iterations,
        st.elapsed_s
    );
    println!("final Λ = {:?}", st.lam);
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<(), String> {
    let cfg = load_cfg(args)?;
    let iters = args.usize_or("iters", 40)?;
    let sim_time = args.f64_or("sim-time", 10.0)?;
    let use_xla = args.flag("xla");
    let mut rng = Rng::seed_from(cfg.seed);
    let problem = cfg.build_problem(&mut rng);
    let params = ServeParams { sim_time, ..ServeParams::default_for(cfg.n_versions) };
    let mut alg = Omad::new(cfg.delta, 0.03);
    let st = if use_xla {
        let engine = jowr::runtime::dnn::XlaEngine::load_default(cfg.n_versions)
            .map_err(|e| format!("xla engine: {e:#}"))?;
        println!("serving with measured DNN latencies (backend: xla-pjrt)");
        let mut oracle = MeasuredOracle::new(problem, params, engine, cfg.eta_routing, cfg.seed);
        let st = alg.run(&mut oracle, iters);
        if let Some(rep) = &oracle.last_report {
            print_report(rep);
        }
        st
    } else {
        println!("serving with the analytic inference engine (pass --xla for real DNNs)");
        let engine = AnalyticEngine::new(cfg.n_versions, cfg.seed);
        let mut oracle = MeasuredOracle::new(problem, params, engine, cfg.eta_routing, cfg.seed);
        let st = alg.run(&mut oracle, iters);
        if let Some(rep) = &oracle.last_report {
            print_report(rep);
        }
        st
    };
    println!(
        "measured utility {:.4} -> {:.4}; final Λ = {:?}",
        st.trajectory[0],
        st.trajectory.last().unwrap(),
        st.lam
    );
    Ok(())
}

fn print_report(rep: &jowr::coordinator::serving::ServeReport) {
    println!(
        "last window: {:.1} fps, latency p50 {:.2}ms p99 {:.2}ms, completed {:?}, dropped {}",
        rep.throughput_fps,
        rep.p50_latency_s * 1e3,
        rep.p99_latency_s * 1e3,
        rep.completed,
        rep.dropped
    );
}

fn cmd_runtime_check(args: &Args) -> Result<(), String> {
    let _ = args;
    let dir = jowr::runtime::XlaRuntime::default_dir();
    let mut rt = jowr::runtime::XlaRuntime::load(&dir)
        .map_err(|e| format!("load artifacts from {}: {e:#}", dir.display()))?;
    println!("manifest: {} entries", rt.manifest.entries.len());
    // mirror step smoke: move mass to the cheap lane
    let rows = 4;
    let k = 2;
    let phi = vec![0.5f32; rows * k];
    let delta: Vec<f32> = (0..rows * k).map(|i| if i % 2 == 0 { 0.0 } else { 5.0 }).collect();
    let mask = vec![1.0f32; rows * k];
    let out = jowr::runtime::mirror::mirror_step_xla(&mut rt, &phi, &delta, &mask, 1.0, rows, k)
        .map_err(|e| format!("mirror step: {e:#}"))?;
    if !(out[0] > 0.9 && out[1] < 0.1) {
        return Err(format!("mirror step numerics wrong: {out:?}"));
    }
    println!("mirror_step OK ({:?}...)", &out[..2]);
    // dnn smoke
    let v = jowr::runtime::dnn::DnnVersion::load(&mut rt, "small", 1)
        .map_err(|e| format!("dnn load: {e:#}"))?;
    let frames = vec![0.25f32; v.frame_dim];
    let (out, dt) = v.enhance(&mut rt, &frames).map_err(|e| format!("dnn run: {e:#}"))?;
    println!(
        "dnn_small OK: {} outputs, finite={}, {:.3}ms",
        out.len(),
        out.iter().all(|x| x.is_finite()),
        dt * 1e3
    );
    println!("runtime-check OK");
    Ok(())
}

fn cmd_config(args: &Args) -> Result<(), String> {
    if args.flag("dump") {
        println!("{}", ExperimentConfig::paper_default().to_json());
    }
    Ok(())
}
