//! `jowr` — CLI launcher for the JOWR system.
//!
//! ```text
//! jowr fig --id 7 [--iters 200] [--seed 42]       regenerate a paper figure
//! jowr fig --id all                               every figure + table
//! jowr topo --name abilene | --all                topology stats (Table II)
//! jowr route [--n 25] [--p 0.2] [--algo <router>] [--iters 50]
//! jowr dist [--rounds 50] [--workers k]           distributed OMD-RT run
//! jowr allocate [--family log] [--algo <allocator>] [--iters 60]
//! jowr solvers                                    list the solver registry
//! jowr sim [--windows 1] [--router omd]           request-level DES replay
//! jowr serve [--sim-time 20] [--iters 40] [--xla] end-to-end serving demo
//! jowr runtime-check                              AOT artifact smoke test
//! jowr config --dump                              print the default config
//! ```
//!
//! Algorithm dispatch goes through the solver registry
//! (`jowr::session::registry`): an unknown `--algo` is a clean error
//! listing the registered names, never a panic.

use std::ops::ControlFlow;

use jowr::config::ExperimentConfig;
use jowr::coordinator::serving::{AnalyticEngine, MeasuredOracle, ServeParams};
use jowr::experiments;
use jowr::graph::topologies;
use jowr::prelude::*;
use jowr::util::cli::Args;

fn main() {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        usage();
        std::process::exit(2);
    }
    let cmd = argv.remove(0);
    let args = match Args::parse(argv) {
        Ok(a) => a,
        Err(e) => die(&e),
    };
    let result = match cmd.as_str() {
        "fig" => cmd_fig(&args),
        "topo" => cmd_topo(&args),
        "route" => cmd_route(&args),
        "dist" => cmd_dist(&args),
        "allocate" => cmd_allocate(&args),
        "solvers" => cmd_solvers(&args),
        "sim" => cmd_sim(&args),
        "serve" => cmd_serve(&args),
        "suite" => cmd_suite(&args),
        "runtime-check" => cmd_runtime_check(&args),
        "config" => cmd_config(&args),
        "help" | "--help" | "-h" => {
            usage();
            Ok(())
        }
        other => Err(format!("unknown subcommand '{other}' (try `jowr help`)")),
    };
    if let Err(e) = result.and_then(|_| args.finish()) {
        die(&e);
    }
}

fn die(msg: &str) -> ! {
    eprintln!("error: {msg}");
    std::process::exit(2)
}

fn usage() {
    println!(
        "jowr — online optimization of DNN inference network utility in CEC\n\n\
         subcommands:\n  \
         fig --id 7|8|9|10|11|12|all    regenerate paper figures\n  \
         topo --name <x> | --all        topology stats (Table II)\n  \
         route [--algo {routers}]\n                                 run one routing solve\n  \
         dist [--rounds 50]             distributed OMD-RT session run (actors +\n                                 CommStats; also `route --algo distributed-omd`)\n  \
         allocate [--algo {allocators}]\n                                 run one allocation solve\n  \
         suite --scenarios <dir|files>  run a (scenario x solver x seed) grid:\n                                 [--routers a,b] [--allocators x] [--sims omd]\n                                 [--seeds 1,2] [--iters 50] [--out results/suite]\n  \
         solvers                        list the solver registry\n  \
         sim [--router omd] [--iters 50] [--windows 1]\n                                 optimize phi, then replay the request stream\n                                 on the discrete-event core: [--horizon-s 30]\n                                 [--warmup-s 0] [--queue-cap 0] [--servers 1]\n                                 [--discipline fifo|lifo] [--latency exact|hdr]\n                                 [--out report.json]\n  \
         serve [--xla] [--router omd]   end-to-end serving demo\n  \
         runtime-check                  AOT artifact smoke test\n  \
         config --dump                  print default config JSON\n\n\
         common options: --n <nodes> --p <link prob> --rate <λ> --seed <s>\n\
         --scenario <file.json>: load a declarative ScenarioSpec (multi-class\n\
         workloads, per-node capacities, explicit edges, rate traces) —\n\
         see examples/scenarios/\n\
         --workers <k>: engine threads for the per-session flow/marginal\n\
         sweeps (0 = auto; results are bit-identical at any worker count)\n\
         --shards <K> --staleness <S>: partition the coordination plane into\n\
         K leader shards running staleness-S-bounded rounds (used by\n\
         `route --algo sharded-omd`; K=1 is bit-identical to the\n\
         single-leader plane)",
        routers = registry::router_names().join("|"),
        allocators = registry::allocator_names().join("|"),
    );
}

fn load_cfg(args: &Args) -> Result<ExperimentConfig, String> {
    let mut cfg = match args.get("config") {
        Some(path) => ExperimentConfig::from_file(std::path::Path::new(path))?,
        None => ExperimentConfig::paper_default(),
    };
    cfg.n_nodes = args.usize_or("n", cfg.n_nodes)?;
    cfg.p_link = args.f64_or("p", cfg.p_link)?;
    cfg.total_rate = args.f64_or("rate", cfg.total_rate)?;
    cfg.seed = args.u64_or("seed", cfg.seed)?;
    // engine worker threads for the per-session sweeps (0 = auto);
    // results are bit-identical at any value
    cfg.workers = args.usize_or("workers", cfg.workers)?;
    if let Some(f) = args.get("family") {
        cfg.utility = f.to_string();
    }
    Ok(cfg)
}

/// An optional `--key <usize>` argument (consumed so `args.finish()` stays
/// clean), `None` when absent.
fn opt_usize_arg(args: &Args, key: &str) -> Result<Option<usize>, String> {
    match args.get(key) {
        Some(v) => v
            .parse()
            .map(Some)
            .map_err(|_| format!("--{key}: bad integer '{v}'")),
        None => Ok(None),
    }
}

/// Build the validated session for this invocation: either a declarative
/// `--scenario file.json` spec (with seed/workers/shards/staleness
/// overridable from the command line) or the scalar config + overrides.
fn load_session(args: &Args) -> Result<Session, String> {
    let shards = opt_usize_arg(args, "shards")?;
    let staleness = opt_usize_arg(args, "staleness")?;
    if let Some(path) = args.get("scenario") {
        let mut spec = ScenarioSpec::from_file(std::path::Path::new(path))?;
        if let Some(seed) = args.get("seed") {
            spec.seed = seed.parse().map_err(|_| format!("--seed: bad integer '{seed}'"))?;
        }
        if let Some(w) = args.get("workers") {
            spec.workers =
                w.parse().map_err(|_| format!("--workers: bad integer '{w}'"))?;
        }
        if shards.is_some() {
            spec.shards = shards;
        }
        if staleness.is_some() {
            spec.staleness = staleness;
        }
        return Ok(spec.build()?);
    }
    let cfg = load_cfg(args)?;
    let mut scenario = Scenario::from_config(cfg);
    if let Some(k) = shards {
        scenario = scenario.shards(k);
    }
    if let Some(s) = staleness {
        scenario = scenario.staleness(s);
    }
    Ok(scenario.build()?)
}

/// The `suite` subcommand: cross every scenario file with the requested
/// solvers and seeds, run the grid in parallel, print a summary table, and
/// dump CSV + JSON.
fn cmd_suite(args: &Args) -> Result<(), String> {
    let scenarios = args.get("scenarios").ok_or(
        "need --scenarios <dir or comma-separated .json files> (see examples/scenarios/)",
    )?;
    let mut files: Vec<std::path::PathBuf> = Vec::new();
    for part in scenarios.split(',') {
        let path = std::path::Path::new(part);
        if path.is_dir() {
            let mut entries: Vec<_> = std::fs::read_dir(path)
                .map_err(|e| format!("{part}: {e}"))?
                .filter_map(|e| e.ok())
                .map(|e| e.path())
                .filter(|p| p.extension().map(|x| x == "json").unwrap_or(false))
                .collect();
            entries.sort();
            files.extend(entries);
        } else {
            files.push(path.to_path_buf());
        }
    }
    if files.is_empty() {
        return Err(format!("no scenario files found under '{scenarios}'"));
    }
    let mut suite = Suite::new()
        .iters(args.usize_or("iters", 50)?)
        .workers(args.usize_or("workers", 0)?);
    for f in &files {
        suite = suite.scenario_file(f)?;
    }
    let mut any_solver = false;
    if let Some(routers) = args.get("routers") {
        for name in routers.split(',').filter(|s| !s.is_empty()) {
            suite = suite.router(name);
            any_solver = true;
        }
    }
    if let Some(allocators) = args.get("allocators") {
        for name in allocators.split(',').filter(|s| !s.is_empty()) {
            suite = suite.allocator(name);
            any_solver = true;
        }
    }
    if let Some(sims) = args.get("sims") {
        for name in sims.split(',').filter(|s| !s.is_empty()) {
            suite = suite.sim(name);
            any_solver = true;
        }
    }
    if !any_solver {
        suite = suite.router("omd");
    }
    if let Some(seeds) = args.get("seeds") {
        let parsed: Result<Vec<u64>, String> = seeds
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.parse().map_err(|_| format!("--seeds: bad integer '{s}'")))
            .collect();
        suite = suite.seeds(&parsed?);
    }
    println!("suite: {} scenario file(s), {} cell(s)", files.len(), suite.n_cells());
    let report = suite.run();
    println!(
        "{:<24} {:<16} {:>6} {:>14} {:>7} {:>10}",
        "scenario", "solver", "seed", "objective", "iters", "elapsed_s"
    );
    for cell in &report.cells {
        match &cell.outcome {
            Ok(res) => println!(
                "{:<24} {:<16} {:>6} {:>14.6} {:>7} {:>10.4}",
                cell.scenario,
                cell.solver,
                cell.seed,
                res.report.objective,
                res.report.iterations,
                res.report.elapsed_s
            ),
            Err(e) => println!(
                "{:<24} {:<16} {:>6} ERROR: {e}",
                cell.scenario, cell.solver, cell.seed
            ),
        }
    }
    let out = std::path::PathBuf::from(args.get_or("out", "results/suite"));
    report.write(&out).map_err(|e| format!("write {}: {e}", out.display()))?;
    println!(
        "{} ok, {} failed; wrote {}/suite.csv + suite.json",
        report.ok_count(),
        report.err_count(),
        out.display()
    );
    if report.err_count() > 0 {
        return Err(format!("{} suite cell(s) failed", report.err_count()));
    }
    Ok(())
}

fn cmd_fig(args: &Args) -> Result<(), String> {
    let cfg = load_cfg(args)?;
    let id = args.get_or("id", "all").to_string();
    let iters = args.usize_or("iters", 0)?;
    let run = |which: &str| -> Result<(), String> {
        match which {
            "7" => {
                experiments::fig7(&cfg, if iters > 0 { iters } else { 200 })?;
            }
            "8" | "9" => {
                experiments::fig8_9(
                    &cfg,
                    &[20, 25, 30, 35, 40],
                    if iters > 0 { iters } else { 50 },
                )?;
            }
            "10" => {
                experiments::fig10(&cfg, if iters > 0 { iters } else { 60 })?;
            }
            "11" => {
                experiments::fig11(&cfg, if iters > 0 { iters } else { 100 }, 50)?;
            }
            "12" | "13" | "14" | "15" => {
                experiments::fig12_15(&cfg, if iters > 0 { iters } else { 100 })?;
            }
            _ => {}
        }
        Ok(())
    };
    match id.as_str() {
        "all" => {
            experiments::table2();
            for f in ["7", "8", "10", "11", "12"] {
                run(f)?;
            }
        }
        other => run(other)?,
    }
    Ok(())
}

fn cmd_topo(args: &Args) -> Result<(), String> {
    if args.flag("all") {
        experiments::table2();
        return Ok(());
    }
    let name = args.get("name").ok_or("need --name or --all")?.to_string();
    let mut rng = Rng::seed_from(args.u64_or("seed", 1)?);
    let g = topologies::by_name(&name, 10.0, &mut rng)
        .ok_or_else(|| String::from(SessionError::UnknownTopology { name: name.clone() }))?;
    println!(
        "{name}: |N|={} |E|={} (directed), C̄={:.2}",
        g.n_nodes(),
        g.n_edges(),
        g.mean_capacity()
    );
    for e in g.edges() {
        println!("  {} -> {}  C={:.2}", e.src, e.dst, e.capacity);
    }
    Ok(())
}

fn cmd_route(args: &Args) -> Result<(), String> {
    let session = load_session(args)?;
    let iters = args.usize_or("iters", 50)?;
    let algo = args.get_or("algo", "omd").to_string();
    println!(
        "routing on {} (n_real={}, λ={}, W={}) with {algo}, {iters} iters",
        session.cfg.topology,
        session.problem.net.n_real,
        session.cfg.total_rate,
        session.cfg.n_versions
    );
    let mut traj = Trajectory::default();
    let report = session.routing_run(&algo, iters)?.observe(&mut traj).finish();
    // "steps" = streaming iterations: for iterative routers this is the
    // algorithm's iteration count; OPT runs its whole centralized solve
    // inside the first step
    println!(
        "cost {:.6} -> {:.6} in {} steps ({:.4}s, stop: {:?})",
        traj.values[0], report.objective, report.iterations, report.elapsed_s, report.stop
    );
    Ok(())
}

fn cmd_dist(args: &Args) -> Result<(), String> {
    let session = load_session(args)?;
    let rounds = args.usize_or("rounds", 50)?;
    println!(
        "distributed OMD-RT: {} node actors + leader, {rounds} barriered rounds",
        session.problem.net.n_real
    );
    // the distributed coordinator is a session run like any other: one
    // step = one barriered round, CommStats on the final report
    let mut traj = Trajectory::default();
    let report = session.distributed_run(rounds)?.observe(&mut traj).finish();
    println!(
        "cost {:.6} -> {:.6} in {} rounds ({:.3}s, stop: {:?})",
        traj.values[0], report.objective, report.iterations, report.elapsed_s, report.stop
    );
    let comm = report.comm.unwrap_or_default();
    let per_round = comm.rounds.max(1) as f64;
    println!(
        "communication: {} messages, {} bytes total ({:.1} msgs/round, {:.1} B/round/device)",
        comm.messages,
        comm.bytes,
        comm.messages as f64 / per_round,
        comm.bytes as f64 / per_round / session.problem.net.n_real as f64
    );
    // cross-check against the centralized solver from the registry
    let central = session.routing_run("omd", rounds)?.finish();
    let rel = (report.objective - central.objective).abs() / central.objective.abs().max(1.0);
    println!(
        "centralized cross-check: cost {:.6} (rel diff {rel:.2e})",
        central.objective
    );
    Ok(())
}

fn cmd_allocate(args: &Args) -> Result<(), String> {
    let session = load_session(args)?;
    let iters = args.usize_or("iters", 60)?;
    let algo = args.get_or("algo", "gsoma").to_string();
    let mut traj = Trajectory::default();
    let report = session.allocation_run(&algo, iters)?.observe(&mut traj).finish();
    println!(
        "{algo} ({} utility): U {:.4} -> {:.4} in {} outer iters, {} routing iters ({:.3}s)",
        session.cfg.utility,
        traj.values[0],
        traj.values.last().unwrap(),
        report.iterations,
        report.routing_iterations,
        report.elapsed_s
    );
    println!("final Λ = {:?}", report.lam);
    Ok(())
}

fn cmd_solvers(args: &Args) -> Result<(), String> {
    let _ = args;
    println!("routers:");
    for e in registry::ROUTERS.iter() {
        println!("  {:<10} {}", e.name, e.description);
        for (k, v) in e.defaults {
            println!("  {:<10}   default {k} = {v}", "");
        }
    }
    println!("allocators:");
    for e in registry::ALLOCATORS.iter() {
        let loop_kind = if e.single_loop { "single-loop" } else { "nested-loop" };
        println!("  {:<10} {} [{loop_kind}]", e.name, e.description);
        for (k, v) in e.defaults {
            println!("  {:<10}   default {k} = {v}", "");
        }
    }
    Ok(())
}

/// The `sim` subcommand: optimize φ with a registry router, then replay
/// the scenario's request stream through the discrete-event core and print
/// the per-class / per-node roll-up (plus the events/sec replay rate —
/// wall clock is measured here, never inside the deterministic report).
fn cmd_sim(args: &Args) -> Result<(), String> {
    let mut session = load_session(args)?;
    let iters = args.usize_or("iters", 50)?;
    let windows = args.usize_or("windows", 1)?;
    let router = args.get_or("router", "omd").to_string();
    // CLI overrides merge into the scenario's sim block (or the defaults)
    let mut sim_spec = session.spec.sim.clone().unwrap_or_default();
    sim_spec.horizon_s = args.f64_or("horizon-s", sim_spec.horizon_s)?;
    sim_spec.warmup_s = args.f64_or("warmup-s", sim_spec.warmup_s)?;
    sim_spec.queue_capacity = args.usize_or("queue-cap", sim_spec.queue_capacity)?;
    sim_spec.servers_per_node = args.usize_or("servers", sim_spec.servers_per_node)?;
    if let Some(d) = args.get("discipline") {
        sim_spec.discipline = Discipline::parse(d)
            .ok_or_else(|| format!("--discipline: unknown '{d}' (fifo|lifo)"))?;
    }
    if let Some(m) = args.get("latency") {
        sim_spec.latency = LatencyMode::parse(m)
            .ok_or_else(|| format!("--latency: unknown '{m}' (exact|hdr)"))?;
    }
    sim_spec.validate().map_err(|what| format!("sim spec: {what}"))?;
    session.spec.sim = Some(sim_spec.clone());
    println!(
        "sim on {} (n_real={}, λ={}, W={}): {router} warm-up ({iters} iters), \
         horizon {}s x {windows} window(s), seed {}",
        session.cfg.topology,
        session.problem.net.n_real,
        session.cfg.total_rate,
        session.cfg.n_versions,
        sim_spec.horizon_s,
        session.cfg.seed
    );
    let optimized = session.routing_run(&router, iters)?.finish();
    let t0 = jowr::util::clock::Stopwatch::start();
    let (report, sim) = session.sim_run(windows)?.warm_start_from(&optimized).finish();
    let dt = t0.elapsed_secs().max(1e-9);
    println!(
        "replayed {} requests / {} events in {:.3}s ({:.0} events/s, {:.0} reqs/s), \
         peak in-flight {}",
        sim.arrivals,
        sim.events,
        dt,
        sim.events as f64 / dt,
        sim.arrivals as f64 / dt,
        sim.peak_inflight
    );
    println!(
        "overall: completed {} dropped {} ({:.3}% loss), latency mean {:.4}s \
         p50 {:.4}s p99 {:.4}s p999 {:.4}s",
        sim.completed,
        sim.dropped,
        100.0 * sim.dropped as f64 / (sim.arrivals.max(1)) as f64,
        sim.mean_latency_s,
        sim.p50_latency_s,
        sim.p99_latency_s,
        sim.p999_latency_s
    );
    println!(
        "{:<12} {:>10} {:>10} {:>8} {:>10} {:>10} {:>10} {:>10}",
        "class", "arrivals", "completed", "dropped", "mean_s", "p50_s", "p99_s", "p999_s"
    );
    for c in &sim.classes {
        println!(
            "{:<12} {:>10} {:>10} {:>8} {:>10.4} {:>10.4} {:>10.4} {:>10.4}",
            c.name,
            c.arrivals,
            c.completed,
            c.dropped,
            c.mean_latency_s,
            c.p50_latency_s,
            c.p99_latency_s,
            c.p999_latency_s
        );
    }
    println!(
        "{:<8} {:>10} {:>8} {:>8} {:>6} {:>10} {:>9} {:>10}",
        "device", "arrivals", "served", "dropped", "util", "mean_q", "max_q", "wait_s"
    );
    for n in &sim.nodes {
        println!(
            "{:<8} {:>10} {:>8} {:>8} {:>6.3} {:>10.3} {:>9} {:>10.4}",
            n.device,
            n.arrivals,
            n.served,
            n.dropped,
            n.utilization,
            n.mean_queue_depth,
            n.max_queue_depth,
            n.mean_wait_s
        );
    }
    println!("run: {} windows, stop {:?}, wall {:.3}s", report.iterations, report.stop, dt);
    if let Some(out) = args.get("out") {
        let path = std::path::Path::new(out);
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir).map_err(|e| format!("{out}: {e}"))?;
            }
        }
        std::fs::write(path, sim.to_json().to_string())
            .map_err(|e| format!("write {out}: {e}"))?;
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<(), String> {
    let session = load_session(args)?;
    let cfg = &session.cfg;
    let iters = args.usize_or("iters", 40)?;
    let sim_time = args.f64_or("sim-time", 10.0)?;
    let router_name = args.get_or("router", "omd").to_string();
    let use_xla = args.flag("xla");
    let params = ServeParams { sim_time, ..ServeParams::default_for(cfg.n_versions) };
    // the paper's serving setup uses a smaller outer step than the
    // analytic experiments
    let alg = registry::allocator_with(
        args.get_or("algo", "omad"),
        &Hyper { eta_alloc: 0.03, ..session.hyper() },
    )?;
    if use_xla {
        serve_xla(&session, &router_name, params, alg, iters)
    } else {
        println!("serving with the analytic inference engine (pass --xla for real DNNs)");
        let engine = AnalyticEngine::new(cfg.n_versions, cfg.seed);
        let oracle = MeasuredOracle::with_router(
            session.problem.clone(),
            params,
            engine,
            session.router(&router_name)?,
            cfg.seed,
        )
        .with_workers(cfg.workers);
        run_serving(Box::new(oracle), alg, iters)
    }
}

/// Drive a measured-utility allocation run through the streaming session
/// API and print the serving telemetry from the recovered oracle.
fn run_serving(
    oracle: Box<dyn UtilityOracle>,
    alg: Box<dyn Allocator>,
    iters: usize,
) -> Result<(), String> {
    let mut traj = Trajectory::default();
    let mut run = AllocationRun::new(alg, oracle, iters).observe(&mut traj);
    let report = loop {
        if let ControlFlow::Break(report) = run.step() {
            break report;
        }
    };
    let oracle = run.into_oracle();
    if let Some(rep) = oracle.last_serve_report() {
        print_report(rep);
    }
    println!(
        "measured utility {:.4} -> {:.4} in {} outer iters ({:.3}s); final Λ = {:?}",
        traj.values[0],
        traj.values.last().unwrap(),
        report.iterations,
        report.elapsed_s,
        report.lam
    );
    Ok(())
}

#[cfg(feature = "xla")]
fn serve_xla(
    session: &Session,
    router_name: &str,
    params: ServeParams,
    alg: Box<dyn Allocator>,
    iters: usize,
) -> Result<(), String> {
    let cfg = &session.cfg;
    let engine = jowr::runtime::dnn::XlaEngine::load_default(cfg.n_versions)
        .map_err(|e| format!("xla engine: {e:#}"))?;
    println!("serving with measured DNN latencies (backend: xla-pjrt)");
    let oracle = MeasuredOracle::with_router(
        session.problem.clone(),
        params,
        engine,
        session.router(router_name)?,
        cfg.seed,
    )
    .with_workers(cfg.workers);
    run_serving(Box::new(oracle), alg, iters)
}

#[cfg(not(feature = "xla"))]
fn serve_xla(
    _session: &Session,
    _router_name: &str,
    _params: ServeParams,
    _alg: Box<dyn Allocator>,
    _iters: usize,
) -> Result<(), String> {
    Err("this build has no XLA runtime (rebuild with `--features xla` after adding the \
         `xla` and `anyhow` dependencies)"
        .into())
}

fn print_report(rep: &jowr::coordinator::serving::ServeReport) {
    println!(
        "last window: {:.1} fps, latency p50 {:.2}ms p99 {:.2}ms, completed {:?}, dropped {}",
        rep.throughput_fps,
        rep.p50_latency_s * 1e3,
        rep.p99_latency_s * 1e3,
        rep.completed,
        rep.dropped
    );
}

#[cfg(feature = "xla")]
fn cmd_runtime_check(args: &Args) -> Result<(), String> {
    let _ = args;
    let dir = jowr::runtime::XlaRuntime::default_dir();
    let mut rt = jowr::runtime::XlaRuntime::load(&dir)
        .map_err(|e| format!("load artifacts from {}: {e:#}", dir.display()))?;
    println!("manifest: {} entries", rt.manifest.entries.len());
    // mirror step smoke: move mass to the cheap lane
    let rows = 4;
    let k = 2;
    let phi = vec![0.5f32; rows * k];
    let delta: Vec<f32> = (0..rows * k).map(|i| if i % 2 == 0 { 0.0 } else { 5.0 }).collect();
    let mask = vec![1.0f32; rows * k];
    let out = jowr::runtime::mirror::mirror_step_xla(&mut rt, &phi, &delta, &mask, 1.0, rows, k)
        .map_err(|e| format!("mirror step: {e:#}"))?;
    if !(out[0] > 0.9 && out[1] < 0.1) {
        return Err(format!("mirror step numerics wrong: {out:?}"));
    }
    println!("mirror_step OK ({:?}...)", &out[..2]);
    // dnn smoke
    let v = jowr::runtime::dnn::DnnVersion::load(&mut rt, "small", 1)
        .map_err(|e| format!("dnn load: {e:#}"))?;
    let frames = vec![0.25f32; v.frame_dim];
    let (out, dt) = v.enhance(&mut rt, &frames).map_err(|e| format!("dnn run: {e:#}"))?;
    println!(
        "dnn_small OK: {} outputs, finite={}, {:.3}ms",
        out.len(),
        out.iter().all(|x| x.is_finite()),
        dt * 1e3
    );
    println!("runtime-check OK");
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn cmd_runtime_check(args: &Args) -> Result<(), String> {
    let _ = args;
    Err("this build has no XLA runtime (rebuild with `--features xla` after adding the \
         `xla` and `anyhow` dependencies)"
        .into())
}

fn cmd_config(args: &Args) -> Result<(), String> {
    if args.flag("dump") {
        println!("{}", ExperimentConfig::paper_default().to_json());
    }
    Ok(())
}
