//! Minimal property-based testing harness (substitute for the unavailable
//! `proptest`).
//!
//! A [`Gen`] draws structured random inputs from a seeded [`Rng`];
//! [`forall`] runs a predicate over many cases and, on failure, retries the
//! failing seed with progressively simpler sizes ("shrinking-lite") before
//! reporting the minimal reproducer seed. All failures print an exact
//! `seed=` line so any case can be replayed deterministically.

use crate::util::rng::Rng;

/// Engine worker count under test: the `JOWR_TEST_WORKERS` environment
/// variable, defaulting to 1. CI runs the whole suite in a matrix over
/// `{1, 4}` so the engine's bit-identity guarantee is exercised on
/// multi-core runners; tests that construct a
/// [`crate::engine::FlowEngine`] (directly or through
/// `Scenario::workers`) should include this value in their sweep.
pub fn test_workers() -> usize {
    std::env::var("JOWR_TEST_WORKERS")
        .ok()
        .and_then(|s| s.trim().parse().ok())
        .unwrap_or(1)
}

/// Coordination-plane shard count under test: the `JOWR_TEST_SHARDS`
/// environment variable, defaulting to 1. CI runs a matrix leg with 4 so
/// the sharded plane's determinism and K=1 degeneration guarantees are
/// exercised at a non-trivial partition; tests that build a
/// [`crate::coordinator::shard::ShardedOmd`] (directly or through
/// `Scenario::shards`) should include this value in their sweep.
pub fn test_shards() -> usize {
    std::env::var("JOWR_TEST_SHARDS")
        .ok()
        .and_then(|s| s.trim().parse().ok())
        .unwrap_or(1)
        .max(1)
}

/// Size-aware generator context.
pub struct Gen<'a> {
    pub rng: &'a mut Rng,
    /// Complexity budget (shrunk on failure replays).
    pub size: usize,
}

impl<'a> Gen<'a> {
    pub fn new(rng: &'a mut Rng, size: usize) -> Self {
        Gen { rng, size }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    /// A vector of length in [1, size.max(1)] drawn by `f`.
    pub fn vec<T>(&mut self, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let n = self.usize_in(1, self.size.max(1));
        (0..n).map(|_| f(self)).collect()
    }

    /// A probability-simplex vector of dimension `d` (positive, sums to 1).
    pub fn simplex(&mut self, d: usize) -> Vec<f64> {
        let mut v: Vec<f64> = (0..d).map(|_| self.rng.exponential(1.0) + 1e-9).collect();
        let s: f64 = v.iter().sum();
        v.iter_mut().for_each(|x| *x /= s);
        v
    }
}

/// Outcome of a property run.
#[derive(Debug)]
pub struct PropError {
    pub seed: u64,
    pub size: usize,
    pub case: usize,
    pub msg: String,
}

impl std::fmt::Display for PropError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "property failed (case {} seed={} size={}): {}",
            self.case, self.seed, self.size, self.msg
        )
    }
}

/// Run `prop` over `cases` generated inputs. `prop` returns `Err(msg)` to
/// signal failure. On failure the same seed is replayed at smaller sizes to
/// find a simpler reproducer.
pub fn forall<F>(base_seed: u64, cases: usize, size: usize, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::seed_from(seed);
        let mut g = Gen::new(&mut rng, size);
        if let Err(msg) = prop(&mut g) {
            // shrinking-lite: replay with smaller sizes, keep the smallest failure
            let mut best = PropError { seed, size, case, msg };
            let mut s = size / 2;
            while s >= 1 {
                let mut rng2 = Rng::seed_from(seed);
                let mut g2 = Gen::new(&mut rng2, s);
                if let Err(m2) = prop(&mut g2) {
                    best = PropError { seed, size: s, case, msg: m2 };
                }
                s /= 2;
            }
            panic!("{best}");
        }
    }
}

/// Assert with formatted message, returning `Err` for use inside `forall`.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

/// Assert two floats are within `tol`.
#[macro_export]
macro_rules! prop_assert_close {
    ($a:expr, $b:expr, $tol:expr) => {{
        let (a, b) = ($a, $b);
        if (a - b).abs() > $tol {
            return Err(format!(
                "{} = {} != {} = {} (tol {})",
                stringify!($a),
                a,
                stringify!($b),
                b,
                $tol
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        forall(1, 50, 10, |g| {
            let x = g.f64_in(0.0, 1.0);
            prop_assert!((0.0..1.0).contains(&x), "x out of range: {x}");
            Ok(())
        });
    }

    #[test]
    fn simplex_sums_to_one() {
        forall(2, 50, 8, |g| {
            let d = g.usize_in(1, 12);
            let v = g.simplex(d);
            let s: f64 = v.iter().sum();
            prop_assert_close!(s, 1.0, 1e-9);
            prop_assert!(v.iter().all(|&x| x > 0.0), "non-positive entry");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failures() {
        forall(3, 50, 10, |g| {
            let x = g.usize_in(0, 100);
            prop_assert!(x < 95, "x too big: {x}");
            Ok(())
        });
    }

    #[test]
    fn deterministic_replay() {
        let mut log1 = Vec::new();
        forall(99, 5, 4, |g| {
            log1.push(g.usize_in(0, 1000));
            Ok(())
        });
        let mut log2 = Vec::new();
        forall(99, 5, 4, |g| {
            log2.push(g.usize_in(0, 1000));
            Ok(())
        });
        assert_eq!(log1, log2);
    }
}
