//! Flow algebra over the augmented graph (paper §II-C, eqs. 1–4) —
//! **reference implementation**.
//!
//! Given routing variables φ and an allocation Λ, computes per-session node
//! ingress rates `t_i(w)`, total link flows `F_ij`, and the total network
//! cost `Σ D_ij(F_ij, C_ij)`. All sweeps run in session-DAG topological
//! order, so they are exact in one pass (no fixed-point iteration).
//!
//! These free functions are the plain, allocating formulation the paper
//! states directly; the production hot path is the fused, workspace-reusing
//! [`crate::engine::FlowEngine`] forward sweep, which every solver now
//! uses. Keep this module simple: `tests/test_engine_equivalence.rs` pins
//! the engine against it (1e-12) across topologies, cost families, and
//! seeds, so it doubles as the executable specification.

use crate::graph::augmented::AugmentedNet;
use crate::model::Problem;

/// Routing configuration φ: `frac[w][e]` is the fraction of session `w`'s
/// traffic at `src(e)` forwarded over edge `e` (Gallager's routing variables,
/// eq. 2). For every node with usable out-edges the fractions over those
/// edges sum to 1; fractions are 0 on edges outside the session DAG.
#[derive(Clone, Debug, PartialEq)]
pub struct Phi {
    pub frac: Vec<Vec<f64>>,
}

impl Phi {
    /// Paper's initializer: uniform over each node's usable out-edges
    /// (`φ¹_i(w) = 1/|O_w(i)|`).
    pub fn uniform(net: &AugmentedNet) -> Phi {
        let w_cnt = net.n_sessions();
        let mut frac = vec![vec![0.0; net.graph.n_edges()]; w_cnt];
        for (w, row) in frac.iter_mut().enumerate() {
            for i in 0..net.n_nodes() {
                let outs: Vec<usize> = net.session_out(w, i).collect();
                if !outs.is_empty() {
                    let f = 1.0 / outs.len() as f64;
                    for e in outs {
                        row[e] = f;
                    }
                }
            }
        }
        Phi { frac }
    }

    /// Row of fractions for (session, node) as (edge, value) pairs.
    pub fn row<'a>(
        &'a self,
        net: &'a AugmentedNet,
        w: usize,
        i: usize,
    ) -> impl Iterator<Item = (usize, f64)> + 'a {
        net.session_out(w, i).map(move |e| (e, self.frac[w][e]))
    }

    /// Check simplex feasibility (eq. 3) for every routing node.
    pub fn is_feasible(&self, net: &AugmentedNet, tol: f64) -> Result<(), String> {
        for w in 0..net.n_sessions() {
            for e in 0..net.graph.n_edges() {
                let v = self.frac[w][e];
                if !net.session_edges[w][e] {
                    if v != 0.0 {
                        return Err(format!("session {w}: mass {v} on non-DAG edge {e}"));
                    }
                } else if !(-tol..=1.0 + tol).contains(&v) {
                    return Err(format!("session {w}: fraction {v} out of [0,1] on edge {e}"));
                }
            }
            for &i in net.session_routers(w) {
                let s: f64 = self.row(net, w, i).map(|(_, v)| v).sum();
                if (s - 1.0).abs() > tol {
                    return Err(format!("session {w}: node {i} row sums to {s}"));
                }
            }
        }
        Ok(())
    }
}

/// Result of a flow evaluation.
#[derive(Clone, Debug)]
pub struct FlowEval {
    /// `t[w][i]` — session `w`'s total ingress rate at node `i` (eq. 1).
    pub t: Vec<Vec<f64>>,
    /// `flows[e]` — total flow `F_ij` on edge `e` (eq. 4).
    pub flows: Vec<f64>,
    /// Total network cost `Σ_(i,j) D_ij(F_ij, C_ij)` over *used* edges.
    pub cost: f64,
}

/// Per-session ingress rates by forward topological sweep.
pub fn node_rates(net: &AugmentedNet, phi: &Phi, lam: &[f64]) -> Vec<Vec<f64>> {
    let w_cnt = net.n_sessions();
    assert_eq!(lam.len(), w_cnt);
    let mut t = vec![vec![0.0; net.n_nodes()]; w_cnt];
    for w in 0..w_cnt {
        t[w][AugmentedNet::SOURCE] = lam[w];
        for &i in net.session_topo(w) {
            let ti = t[w][i];
            if ti <= 0.0 {
                continue;
            }
            for (e, f) in phi.row(net, w, i) {
                let dst = net.graph.edge(e).dst;
                t[w][dst] += ti * f;
            }
        }
    }
    t
}

/// Total link flows from node rates.
pub fn edge_flows(net: &AugmentedNet, phi: &Phi, t: &[Vec<f64>]) -> Vec<f64> {
    let mut flows = vec![0.0; net.graph.n_edges()];
    for w in 0..net.n_sessions() {
        for i in 0..net.n_nodes() {
            let ti = t[w][i];
            if ti <= 0.0 {
                continue;
            }
            for (e, f) in phi.row(net, w, i) {
                flows[e] += ti * f;
            }
        }
    }
    flows
}

/// Total network cost; only edges carrying any session's DAG are counted
/// (unused physical links cost nothing at F=0 under all families except Exp,
/// where exp(0)=1 — we follow the paper and sum over the *augmented* edge
/// set restricted to session-usable links, a constant set per topology).
/// Each edge is priced with its own cost family
/// ([`Problem::edge_kind`] — the uniform default unless overridden).
pub fn total_cost(problem: &Problem, flows: &[f64]) -> f64 {
    let net = &problem.net;
    let mut sum = 0.0;
    for &e in &net.union_edges {
        sum += problem.edge_kind(e).value(flows[e], net.graph.edge(e).capacity);
    }
    sum
}

/// Full evaluation Λ, φ → (t, F, cost).
pub fn evaluate(problem: &Problem, phi: &Phi, lam: &[f64]) -> FlowEval {
    let net = &problem.net;
    let t = node_rates(net, phi, lam);
    let flows = edge_flows(net, phi, &t);
    let cost = total_cost(problem, &flows);
    FlowEval { t, flows, cost }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topologies;
    use crate::model::cost::CostKind;
    use crate::model::Problem;
    use crate::util::rng::Rng;

    fn problem(seed: u64, n: usize) -> Problem {
        let mut rng = Rng::seed_from(seed);
        let net = topologies::connected_er(n, 0.3, 3, &mut rng);
        Problem::new(net, 60.0, CostKind::Exp)
    }

    #[test]
    fn uniform_phi_feasible() {
        let p = problem(1, 12);
        let phi = Phi::uniform(&p.net);
        phi.is_feasible(&p.net, 1e-9).unwrap();
    }

    #[test]
    fn conservation_all_traffic_reaches_destinations() {
        let p = problem(2, 12);
        let phi = Phi::uniform(&p.net);
        let lam = p.uniform_allocation();
        let ev = evaluate(&p, &phi, &lam);
        for w in 0..p.n_versions() {
            let dw = p.net.dnode(w);
            assert!(
                (ev.t[w][dw] - lam[w]).abs() < 1e-9,
                "session {w}: {} != {}",
                ev.t[w][dw],
                lam[w]
            );
        }
        // flow out of the source equals λ
        let out: f64 = p
            .net
            .graph
            .out_edges(AugmentedNet::SOURCE)
            .iter()
            .map(|&e| ev.flows[e])
            .sum();
        assert!((out - 60.0).abs() < 1e-9);
    }

    #[test]
    fn per_node_conservation() {
        let p = problem(3, 10);
        let phi = Phi::uniform(&p.net);
        let lam = p.uniform_allocation();
        let ev = evaluate(&p, &phi, &lam);
        for w in 0..p.n_versions() {
            for i in 0..p.net.n_nodes() {
                if i == AugmentedNet::SOURCE || i == p.net.dnode(w) {
                    continue;
                }
                let inflow: f64 = p
                    .net
                    .graph
                    .in_edges(i)
                    .iter()
                    .filter(|&&e| p.net.session_edges[w][e])
                    .map(|&e| {
                        let src = p.net.graph.edge(e).src;
                        ev.t[w][src] * phi.frac[w][e]
                    })
                    .sum();
                assert!((inflow - ev.t[w][i]).abs() < 1e-9, "w={w} i={i}");
            }
        }
    }

    #[test]
    fn cost_positive_and_scales_with_rate() {
        let p = problem(4, 10);
        let phi = Phi::uniform(&p.net);
        let c1 = evaluate(&p, &phi, &[10.0, 10.0, 10.0]).cost;
        let c2 = evaluate(&p, &phi, &[20.0, 20.0, 20.0]).cost;
        assert!(c1 > 0.0);
        assert!(c2 > c1);
    }

    #[test]
    fn infeasible_detected() {
        let p = problem(5, 8);
        let mut phi = Phi::uniform(&p.net);
        // corrupt one live row
        let w = 0;
        let i = p.net.session_routers(w)[0];
        let e = p.net.session_out(w, i).next().unwrap();
        phi.frac[w][e] += 0.5;
        assert!(phi.is_feasible(&p.net, 1e-9).is_err());
        // mass outside the DAG
        let mut phi2 = Phi::uniform(&p.net);
        if let Some(bad) = (0..p.net.graph.n_edges()).find(|&e| !p.net.session_edges[0][e]) {
            phi2.frac[0][bad] = 0.1;
            assert!(phi2.is_feasible(&p.net, 1e-9).is_err());
        }
    }

    #[test]
    fn zero_allocation_zero_flow() {
        let p = problem(6, 8);
        let phi = Phi::uniform(&p.net);
        let ev = evaluate(&p, &phi, &[0.0, 0.0, 0.0]);
        assert!(ev.flows.iter().all(|&f| f == 0.0));
    }
}
