//! Link cost families `D_ij(F_ij, C_ij)` (paper §II-D).
//!
//! All families are increasing, continuously differentiable and convex in
//! `F` for fixed `C` — the property Theorems 1/3 rest on. The paper's
//! experiments use the exponential family `exp(F/C)`; the M/M/1 queueing
//! delay `F/(C−F)` and a linear energy model are provided for the cost-model
//! ablation bench.

/// Which convex link-cost family to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CostKind {
    /// `D = exp(F/C)` — the paper's experimental choice (soft capacity).
    Exp,
    /// `D = F / (C - F)` — M/M/1 expected queueing delay (hard capacity,
    /// softened by a clamped barrier like the L1 kernel's `queue_cost_ref`).
    Queue,
    /// `D = a·F` with `a = 1/C` — linear energy/transmission cost.
    Linear,
    /// `D = (F/C)^3` — polynomial congestion cost (ablation).
    Cubic,
}

impl CostKind {
    /// Every name [`CostKind::parse`] accepts; keep in sync with its
    /// `match`. Error messages derive their suggestions from this list.
    pub const NAMES: [&'static str; 5] = ["exp", "queue", "mm1", "linear", "cubic"];

    pub fn parse(s: &str) -> Option<CostKind> {
        match s {
            "exp" => Some(CostKind::Exp),
            "queue" | "mm1" => Some(CostKind::Queue),
            "linear" => Some(CostKind::Linear),
            "cubic" => Some(CostKind::Cubic),
            _ => None,
        }
    }

    /// Cost `D(F, C)`.
    #[inline]
    pub fn value(&self, f: f64, c: f64) -> f64 {
        debug_assert!(f >= -1e-9, "negative flow {f}");
        debug_assert!(c > 0.0, "non-positive capacity {c}");
        match self {
            CostKind::Exp => (f / c).exp(),
            CostKind::Queue => {
                let slack = (c - f).max(1e-3 * c);
                f / slack
            }
            CostKind::Linear => f / c,
            CostKind::Cubic => {
                let r = f / c;
                r * r * r
            }
        }
    }

    /// Marginal cost `∂D/∂F` — the `D'_ij` of eq. (19).
    #[inline]
    pub fn derivative(&self, f: f64, c: f64) -> f64 {
        match self {
            CostKind::Exp => (f / c).exp() / c,
            CostKind::Queue => {
                let slack = (c - f).max(1e-3 * c);
                c / (slack * slack)
            }
            CostKind::Linear => 1.0 / c,
            CostKind::Cubic => 3.0 * (f / c) * (f / c) / c,
        }
    }

    /// Upper bound on `∂²D/∂F²` over `[0, f_max]` — used by the SGP
    /// baseline's diagonal Hessian scaling (Xi & Yeh style).
    pub fn second_derivative_bound(&self, f_max: f64, c: f64) -> f64 {
        match self {
            CostKind::Exp => (f_max / c).exp() / (c * c),
            CostKind::Queue => {
                let slack = (c - f_max).max(1e-3 * c);
                2.0 * c / (slack * slack * slack)
            }
            CostKind::Linear => 0.0,
            CostKind::Cubic => 6.0 * (f_max / c) / (c * c),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const KINDS: [CostKind; 4] =
        [CostKind::Exp, CostKind::Queue, CostKind::Linear, CostKind::Cubic];

    #[test]
    fn parse_roundtrip() {
        assert_eq!(CostKind::parse("exp"), Some(CostKind::Exp));
        assert_eq!(CostKind::parse("mm1"), Some(CostKind::Queue));
        assert_eq!(CostKind::parse("linear"), Some(CostKind::Linear));
        assert_eq!(CostKind::parse("cubic"), Some(CostKind::Cubic));
        assert_eq!(CostKind::parse("x"), None);
    }

    #[test]
    fn increasing_in_flow() {
        for k in KINDS {
            let c = 10.0;
            let mut prev = k.value(0.0, c);
            for i in 1..=20 {
                let f = i as f64 * 0.4;
                let v = k.value(f, c);
                assert!(v >= prev - 1e-12, "{k:?} not increasing at F={f}");
                prev = v;
            }
        }
    }

    #[test]
    fn derivative_matches_finite_difference() {
        for k in KINDS {
            let c = 8.0;
            for &f in &[0.5, 2.0, 5.0] {
                let h = 1e-6;
                let fd = (k.value(f + h, c) - k.value(f - h, c)) / (2.0 * h);
                let d = k.derivative(f, c);
                assert!(
                    (fd - d).abs() <= 1e-4 * d.abs().max(1.0),
                    "{k:?} F={f}: fd={fd} analytic={d}"
                );
            }
        }
    }

    #[test]
    fn convex_along_flow() {
        // midpoint convexity on a grid
        for k in KINDS {
            let c = 10.0;
            for i in 0..15 {
                let a = i as f64 * 0.5;
                let b = a + 3.0;
                let mid = k.value((a + b) / 2.0, c);
                let chord = 0.5 * (k.value(a, c) + k.value(b, c));
                assert!(mid <= chord + 1e-9, "{k:?} not convex at [{a},{b}]");
            }
        }
    }

    #[test]
    fn second_derivative_bound_dominates() {
        for k in KINDS {
            let c = 10.0;
            let f_max = 8.0;
            let bound = k.second_derivative_bound(f_max, c);
            for i in 0..=16 {
                let f = f_max * i as f64 / 16.0;
                let h = 1e-4;
                let dd =
                    (k.derivative(f + h, c) - k.derivative(f - h, c)) / (2.0 * h);
                assert!(dd <= bound * (1.0 + 1e-3) + 1e-9, "{k:?} F={f}: {dd} > {bound}");
            }
        }
    }

    #[test]
    fn queue_cost_finite_past_capacity() {
        let v = CostKind::Queue.value(15.0, 10.0);
        assert!(v.is_finite() && v > 0.0);
    }
}
