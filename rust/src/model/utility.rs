//! Task utility functions `u_w(λ_w)` (paper §II-B, Fig. 10's four families).
//!
//! The optimizer never evaluates these directly: they are hidden behind the
//! [`crate::allocation::UtilityOracle`], which only exposes *observed* total
//! utility values — exactly the paper's "unknown utility function" setting.
//! This module is the ground truth used to *instantiate* oracles and to
//! verify convergence against analytically-known optima in tests.

/// The four families evaluated in Fig. 10. All satisfy Assumptions 1–3
/// (monotone increasing, concave, Lipschitz, bounded on `[0, λ]`) for the
/// parameter ranges used in the experiments.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum UtilityKind {
    /// `u(λ) = a·λ`
    Linear { a: f64 },
    /// `u(λ) = a·√(λ + b) − a·√b` (the paper's shifted square root)
    Sqrt { a: f64, b: f64 },
    /// `u(λ) = −a·λ² + b·λ`, concave increasing on `[0, b/(2a)]`
    Quadratic { a: f64, b: f64 },
    /// `u(λ) = a·log(b·λ + 1)`
    Log { a: f64, b: f64 },
}

/// A single DNN version's utility function.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Utility {
    pub kind: UtilityKind,
}

impl Utility {
    pub fn new(kind: UtilityKind) -> Self {
        Utility { kind }
    }

    /// `u_w(λ_w)`.
    pub fn value(&self, x: f64) -> f64 {
        debug_assert!(x >= -1e-9);
        match self.kind {
            UtilityKind::Linear { a } => a * x,
            UtilityKind::Sqrt { a, b } => a * (x + b).sqrt() - a * b.sqrt(),
            UtilityKind::Quadratic { a, b } => -a * x * x + b * x,
            UtilityKind::Log { a, b } => a * (b * x + 1.0).ln(),
        }
    }

    /// `u'_w(λ_w)` — used only by tests / ground-truth optima, never by the
    /// online algorithms (which learn from observations).
    pub fn derivative(&self, x: f64) -> f64 {
        match self.kind {
            UtilityKind::Linear { a } => a,
            UtilityKind::Sqrt { a, b } => 0.5 * a / (x + b).sqrt(),
            UtilityKind::Quadratic { a, b } => -2.0 * a * x + b,
            UtilityKind::Log { a, b } => a * b / (b * x + 1.0),
        }
    }

    /// Does this instance satisfy Assumption 1 (monotone increasing +
    /// concave) on `[0, lambda]`?
    pub fn is_valid_on(&self, lambda: f64) -> bool {
        match self.kind {
            UtilityKind::Linear { a } => a > 0.0,
            UtilityKind::Sqrt { a, b } => a > 0.0 && b >= 0.0,
            UtilityKind::Quadratic { a, b } => a >= 0.0 && b > 0.0 && b >= 2.0 * a * lambda,
            UtilityKind::Log { a, b } => a > 0.0 && b > 0.0,
        }
    }
}

/// Build one utility per version from a family name, with the per-version
/// parameters `(a_w, b_w)` diversified the way Fig. 10 does (larger models
/// yield higher marginal utility).
pub fn family(name: &str, n_versions: usize, lambda: f64) -> Option<Vec<Utility>> {
    let mk = |w: usize| -> Option<UtilityKind> {
        let i = w as f64 + 1.0;
        match name {
            "linear" => Some(UtilityKind::Linear { a: 1.0 + 0.8 * i }),
            "sqrt" => Some(UtilityKind::Sqrt { a: 6.0 + 2.0 * i, b: 1.0 + i }),
            // keep quadratic concave-increasing on [0, λ]: b ≥ 2aλ
            "quadratic" => {
                let a = 0.01 * i;
                Some(UtilityKind::Quadratic { a, b: 2.0 * a * lambda + 1.5 * i })
            }
            "log" => Some(UtilityKind::Log { a: 8.0 + 4.0 * i, b: 0.5 + 0.3 * i }),
            _ => None,
        }
    };
    (0..n_versions).map(|w| mk(w).map(Utility::new)).collect()
}

pub const FAMILIES: [&str; 4] = ["linear", "sqrt", "quadratic", "log"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn families_valid_and_monotone() {
        let lambda = 60.0;
        for name in FAMILIES {
            let us = family(name, 3, lambda).unwrap();
            assert_eq!(us.len(), 3);
            for u in &us {
                assert!(u.is_valid_on(lambda), "{name} invalid");
                assert!((u.value(0.0)).abs() < 1e-12, "{name} u(0) != 0");
                let mut prev = u.value(0.0);
                for i in 1..=30 {
                    let x = lambda * i as f64 / 30.0;
                    let v = u.value(x);
                    assert!(v >= prev - 1e-9, "{name} not increasing");
                    prev = v;
                }
            }
        }
    }

    #[test]
    fn concavity_midpoint() {
        for name in FAMILIES {
            for u in family(name, 3, 60.0).unwrap() {
                for i in 0..10 {
                    let a = 6.0 * i as f64;
                    let b = a + 6.0;
                    let mid = u.value((a + b) / 2.0);
                    let chord = 0.5 * (u.value(a) + u.value(b));
                    assert!(mid >= chord - 1e-9, "{name} not concave");
                }
            }
        }
    }

    #[test]
    fn derivative_matches_fd() {
        for name in FAMILIES {
            for u in family(name, 3, 60.0).unwrap() {
                for &x in &[1.0, 10.0, 30.0] {
                    let h = 1e-6;
                    let fd = (u.value(x + h) - u.value(x - h)) / (2.0 * h);
                    assert!((fd - u.derivative(x)).abs() < 1e-5 * fd.abs().max(1.0));
                }
            }
        }
    }

    #[test]
    fn unknown_family_none() {
        assert!(family("cosine", 3, 60.0).is_none());
    }
}
