//! Time-variant link capacities (paper §II-A): "in practical scenarios with
//! time-variant link capacity and random noise, our online optimization
//! approach can still work, assuming the link capacity has a constant mean
//! `C_ij` with a zero-mean noise."
//!
//! [`NoisyCostObserver`] perturbs every capacity multiplicatively per
//! observation round (truncated-normal, mean 1), so routers/oracles see
//! noisy costs and marginals while the *true* mean problem stays fixed —
//! the online-robustness experiment the paper gestures at.

use crate::model::Problem;
use crate::util::rng::Rng;

/// Produces per-round noisy instantiations of a mean problem.
#[derive(Clone, Debug)]
pub struct NoisyCostObserver {
    /// The mean problem (ground truth).
    pub mean: Problem,
    /// Relative capacity noise σ (multiplicative, truncated at ±3σ and
    /// floored so capacities stay positive).
    pub sigma: f64,
    rng: Rng,
    pub rounds: usize,
}

impl NoisyCostObserver {
    pub fn new(mean: Problem, sigma: f64, seed: u64) -> Self {
        assert!((0.0..0.33).contains(&sigma), "sigma must keep capacities positive");
        NoisyCostObserver { mean, sigma, rng: Rng::seed_from(seed), rounds: 0 }
    }

    /// Draw one noisy snapshot of the network (capacities jittered around
    /// their means; topology and session structure unchanged).
    pub fn sample(&mut self) -> Problem {
        self.rounds += 1;
        let mut net = self.mean.net.clone();
        let mut g = crate::graph::DiGraph::with_nodes(net.graph.n_nodes());
        for e in net.graph.edges() {
            let z = self.rng.normal().clamp(-3.0, 3.0);
            let factor = (1.0 + self.sigma * z).max(0.1);
            g.add_edge(e.src, e.dst, e.capacity * factor);
        }
        net.graph = g;
        // session DAGs depend only on connectivity, which is unchanged, but
        // rebuild keeps the caches coherent with the new graph object
        net.rebuild_session_dags();
        Problem::with_workload(net, self.mean.cost, self.mean.workload.clone())
            .with_edge_cost(self.mean.edge_cost.clone())
    }

    /// Evaluate φ on the *mean* problem (the ground-truth objective).
    pub fn mean_cost(&self, phi: &crate::model::flow::Phi, lam: &[f64]) -> f64 {
        crate::model::flow::evaluate(&self.mean, phi, lam).cost
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topologies;
    use crate::model::cost::CostKind;
    use crate::model::flow::Phi;
    use crate::routing::omd::OmdRouter;
    use crate::routing::Router;

    fn mk_problem(seed: u64) -> Problem {
        let mut rng = Rng::seed_from(seed);
        let net = topologies::connected_er(10, 0.3, 3, &mut rng);
        Problem::new(net, 60.0, CostKind::Exp)
    }

    #[test]
    fn noise_preserves_structure_and_mean() {
        let p = mk_problem(1);
        let mut obs = NoisyCostObserver::new(p.clone(), 0.1, 7);
        let mut mean_caps = vec![0.0; p.net.graph.n_edges()];
        let rounds = 400;
        for _ in 0..rounds {
            let q = obs.sample();
            assert_eq!(q.net.graph.n_edges(), p.net.graph.n_edges());
            for (e, edge) in q.net.graph.edges().iter().enumerate() {
                mean_caps[e] += edge.capacity / rounds as f64;
            }
        }
        // empirical mean within 5% of the true mean capacity per edge
        for (e, edge) in p.net.graph.edges().iter().enumerate() {
            let rel = (mean_caps[e] - edge.capacity).abs() / edge.capacity;
            assert!(rel < 0.05, "edge {e}: empirical {} vs mean {}", mean_caps[e], edge.capacity);
        }
    }

    #[test]
    fn omd_converges_under_capacity_noise() {
        // each routing iteration sees a different noisy network; the mean
        // cost of the iterate must still approach the mean-problem optimum
        let p = mk_problem(2);
        let lam = p.uniform_allocation();
        let clean = OmdRouter::new(0.3).solve(&p, &lam, 2000);

        let mut obs = NoisyCostObserver::new(p.clone(), 0.1, 13);
        let mut router = OmdRouter::fixed(0.05);
        let mut phi = Phi::uniform(&p.net);
        for _ in 0..2000 {
            let noisy = obs.sample();
            router.step(&noisy, &lam, &mut phi);
        }
        let noisy_final = obs.mean_cost(&phi, &lam);
        let rel = (noisy_final - clean.objective) / clean.objective;
        assert!(
            rel < 0.05,
            "noisy-trained φ mean cost {noisy_final} vs clean optimum {}",
            clean.objective
        );
    }

    #[test]
    #[should_panic(expected = "sigma")]
    fn rejects_excessive_noise() {
        NoisyCostObserver::new(mk_problem(3), 0.5, 1);
    }
}
