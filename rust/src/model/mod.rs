//! Problem model: cost functions, utility functions, flow algebra, and the
//! [`Problem`] bundle handed to routers/allocators.

pub mod cost;
pub mod flow;
pub mod noise;
pub mod utility;

use crate::graph::augmented::AugmentedNet;
use cost::CostKind;

/// The task-class structure of a problem's workload: classes partition the
/// sessions class-major (class `c` owns the contiguous session range
/// `class_spans[c]`), and each class admits its own rate.
///
/// The paper's single-class setup is [`Workload::single`]: one class at the
/// total rate spanning every session. Heterogeneous multi-class scenarios
/// ([`crate::session::spec::ScenarioSpec`]) carry one entry per task class;
/// the allocation layer splits each class's rate across *its own* sessions
/// (per-class simplex blocks) instead of one global simplex.
#[derive(Clone, Debug, PartialEq)]
pub struct Workload {
    /// Human-readable class names (diagnostics and reports).
    pub class_names: Vec<String>,
    /// Admitted task input rate λ_c per class.
    pub class_rates: Vec<f64>,
    /// Session index range `[start, end)` owned by each class.
    pub class_spans: Vec<(usize, usize)>,
}

impl Workload {
    /// The paper's setup: one class at the total rate over all sessions.
    pub fn single(total: f64, n_sessions: usize) -> Workload {
        Workload {
            class_names: vec!["default".to_string()],
            class_rates: vec![total],
            class_spans: vec![(0, n_sessions)],
        }
    }

    pub fn n_classes(&self) -> usize {
        self.class_rates.len()
    }

    /// Total admitted rate λ = Σ_c λ_c.
    pub fn total(&self) -> f64 {
        self.class_rates.iter().sum()
    }

    /// Per-class allocation blocks `(start, end, rate)`.
    pub fn blocks(&self) -> Vec<(usize, usize, f64)> {
        self.class_spans
            .iter()
            .zip(&self.class_rates)
            .map(|(&(a, b), &r)| (a, b, r))
            .collect()
    }

    /// Total number of sessions across all classes.
    pub fn n_sessions(&self) -> usize {
        self.class_spans.last().map_or(0, |&(_, b)| b)
    }

    /// The paper's uniform initializer, per class: `Λ¹_c = (λ_c/W_c)·1`.
    pub fn uniform_allocation(&self) -> Vec<f64> {
        let mut lam = vec![0.0; self.n_sessions()];
        for (&(a, b), &rate) in self.class_spans.iter().zip(&self.class_rates) {
            let share = rate / (b - a) as f64;
            for l in &mut lam[a..b] {
                *l = share;
            }
        }
        lam
    }

    /// Class owning session `s`.
    pub fn class_of_session(&self, s: usize) -> usize {
        self.class_spans
            .iter()
            .position(|&(a, b)| s >= a && s < b)
            .expect("session outside every class span")
    }
}

/// A JOWR problem instance: the augmented network, the admitted workload
/// (total rate λ + per-class structure), and the link cost family — with
/// optional per-edge cost-family overrides for heterogeneous links.
#[derive(Clone, Debug)]
pub struct Problem {
    pub net: AugmentedNet,
    /// Total DNN inference task input rate λ (e.g. 60 fps in the paper).
    pub total_rate: f64,
    /// Default link cost family (every edge without an override).
    pub cost: CostKind,
    /// Task-class structure (single class spanning all sessions by default).
    pub workload: Workload,
    /// Per-edge cost-family overrides, indexed by augmented edge id
    /// (`None` = every edge uses [`Problem::cost`]).
    pub edge_cost: Option<Vec<CostKind>>,
}

impl Problem {
    pub fn new(net: AugmentedNet, total_rate: f64, cost: CostKind) -> Self {
        let workload = Workload::single(total_rate, net.n_sessions());
        Self::with_workload(net, cost, workload)
    }

    /// Multi-class construction: the total rate is the sum of the class
    /// rates and the workload's spans must cover the network's sessions.
    pub fn with_workload(net: AugmentedNet, cost: CostKind, workload: Workload) -> Self {
        let total_rate = workload.total();
        assert!(total_rate > 0.0);
        assert_eq!(
            workload.n_sessions(),
            net.n_sessions(),
            "workload spans must cover every session"
        );
        net.validate().expect("invalid augmented network");
        Problem { net, total_rate, cost, workload, edge_cost: None }
    }

    /// Attach per-edge cost-family overrides (length = augmented edge
    /// count); `None` clears them.
    pub fn with_edge_cost(mut self, edge_cost: Option<Vec<CostKind>>) -> Self {
        if let Some(ec) = &edge_cost {
            assert_eq!(ec.len(), self.net.graph.n_edges(), "one cost kind per edge");
        }
        self.edge_cost = edge_cost;
        self
    }

    /// Cost family of edge `e` (the per-edge override, else the default).
    #[inline]
    pub fn edge_kind(&self, e: usize) -> CostKind {
        match &self.edge_cost {
            Some(kinds) => kinds[e],
            None => self.cost,
        }
    }

    /// Number of DNN versions W.
    #[inline]
    pub fn n_versions(&self) -> usize {
        self.net.n_versions()
    }

    /// Number of routed sessions (allocation coordinates); equals
    /// [`Problem::n_versions`] for single-class problems.
    #[inline]
    pub fn n_sessions(&self) -> usize {
        self.net.n_sessions()
    }

    /// Paper's allocation initializer: per class, `Λ¹ = (λ_c/W_c)·1`.
    pub fn uniform_allocation(&self) -> Vec<f64> {
        self.workload.uniform_allocation()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topologies;
    use crate::util::rng::Rng;

    #[test]
    fn uniform_allocation_sums_to_rate() {
        let mut rng = Rng::seed_from(2);
        let net = topologies::connected_er(10, 0.3, 3, &mut rng);
        let p = Problem::new(net, 60.0, CostKind::Exp);
        let a = p.uniform_allocation();
        assert_eq!(a.len(), 3);
        assert!((a.iter().sum::<f64>() - 60.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn rejects_nonpositive_rate() {
        let mut rng = Rng::seed_from(2);
        let net = topologies::connected_er(10, 0.3, 3, &mut rng);
        Problem::new(net, 0.0, CostKind::Exp);
    }

    #[test]
    fn workload_blocks_and_uniform() {
        let wl = Workload {
            class_names: vec!["a".into(), "b".into()],
            class_rates: vec![40.0, 20.0],
            class_spans: vec![(0, 3), (3, 6)],
        };
        assert_eq!(wl.n_classes(), 2);
        assert_eq!(wl.n_sessions(), 6);
        assert!((wl.total() - 60.0).abs() < 1e-12);
        let lam = wl.uniform_allocation();
        let mut want = vec![40.0 / 3.0; 3];
        want.extend(vec![20.0 / 3.0; 3]);
        assert_eq!(lam, want);
        assert_eq!(wl.blocks(), vec![(0, 3, 40.0), (3, 6, 20.0)]);
        assert_eq!(wl.class_of_session(2), 0);
        assert_eq!(wl.class_of_session(3), 1);
    }

    #[test]
    fn single_workload_matches_legacy_uniform() {
        let wl = Workload::single(60.0, 3);
        assert_eq!(wl.uniform_allocation(), vec![20.0, 20.0, 20.0]);
    }

    #[test]
    fn edge_kind_defaults_and_overrides() {
        let mut rng = Rng::seed_from(4);
        let net = topologies::connected_er(8, 0.3, 2, &mut rng);
        let ne = net.graph.n_edges();
        let p = Problem::new(net, 30.0, CostKind::Exp);
        assert_eq!(p.edge_kind(0), CostKind::Exp);
        let mut kinds = vec![CostKind::Exp; ne];
        kinds[1] = CostKind::Queue;
        let p = p.with_edge_cost(Some(kinds));
        assert_eq!(p.edge_kind(0), CostKind::Exp);
        assert_eq!(p.edge_kind(1), CostKind::Queue);
    }
}
