//! Problem model: cost functions, utility functions, flow algebra, and the
//! [`Problem`] bundle handed to routers/allocators.

pub mod cost;
pub mod flow;
pub mod noise;
pub mod utility;

use crate::graph::augmented::AugmentedNet;
use cost::CostKind;

/// A JOWR problem instance: the augmented network, the total admissible task
/// input rate λ, and the link cost family.
#[derive(Clone, Debug)]
pub struct Problem {
    pub net: AugmentedNet,
    /// Total DNN inference task input rate λ (e.g. 60 fps in the paper).
    pub total_rate: f64,
    pub cost: CostKind,
}

impl Problem {
    pub fn new(net: AugmentedNet, total_rate: f64, cost: CostKind) -> Self {
        assert!(total_rate > 0.0);
        net.validate().expect("invalid augmented network");
        Problem { net, total_rate, cost }
    }

    #[inline]
    pub fn n_versions(&self) -> usize {
        self.net.n_versions()
    }

    /// Paper's allocation initializer: `Λ¹ = (λ/W)·1`.
    pub fn uniform_allocation(&self) -> Vec<f64> {
        vec![self.total_rate / self.n_versions() as f64; self.n_versions()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topologies;
    use crate::util::rng::Rng;

    #[test]
    fn uniform_allocation_sums_to_rate() {
        let mut rng = Rng::seed_from(2);
        let net = topologies::connected_er(10, 0.3, 3, &mut rng);
        let p = Problem::new(net, 60.0, CostKind::Exp);
        let a = p.uniform_allocation();
        assert_eq!(a.len(), 3);
        assert!((a.iter().sum::<f64>() - 60.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn rejects_nonpositive_rate() {
        let mut rng = Rng::seed_from(2);
        let net = topologies::connected_er(10, 0.3, 3, &mut rng);
        Problem::new(net, 0.0, CostKind::Exp);
    }
}
