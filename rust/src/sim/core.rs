//! The deterministic discrete-event core: calendar-queue scheduler,
//! stations, arrival processes, and the per-request routing walk. See the
//! module docs of [`crate::sim`] for the mapping onto the paper's cost
//! model and [`super::reference`] for the pinned naive engine this hot
//! path must match bitwise.
//!
//! ## Hot-path structure
//!
//! Four structural optimizations over the reference, each behaviorally
//! invisible by construction:
//!
//! * **Calendar-queue scheduler** ([`super::calendar::CalendarQueue`]):
//!   O(1)-amortized push/pop popping the identical `(time, seq)` total
//!   order as the reference's `BinaryHeap` (the ordering invariant is
//!   argued in the calendar module docs and pinned by a randomized
//!   pop-order equivalence test).
//! * **CSR routing tables**: one flat lane array (`route_edge` /
//!   `route_phi`) with per-`(session, node)` ranges in `route_off` and
//!   the row sum precomputed in [`Simulator::set_phi`] — same
//!   left-to-right summation order as the reference's per-hop
//!   `row.iter().sum()`, so the inverse-CDF scan consumes the identical
//!   RNG draw and selects the identical lane bitwise. The φ-independent
//!   index is built once at construction; `set_phi` only overwrites the
//!   `φ`/sum values in place — **no allocation after warm-up**.
//! * **Slab request pool**: completed/dropped request slots are recycled
//!   through a freelist, keeping `reqs` at O(peak in-flight) instead of
//!   O(total admitted). Request ids are event payload only — they never
//!   enter an ordering comparison or the RNG — so recycling cannot
//!   perturb the event stream (the *slab-id non-ordering contract*).
//!   [`super::SimReport::peak_inflight`] reports the pool's high-water
//!   mark.
//! * **Streaming latency telemetry** ([`super::LatencyMode::Hdr`]):
//!   opt-in per-class log-histograms ([`super::hist::LogHist`]) replace
//!   the unbounded latency vectors with O(1) memory and ≤ 0.1% relative
//!   quantile error. Exact sampling stays the default and the
//!   bit-identity reference.

use crate::graph::augmented::AugmentedNet;
use crate::model::flow::Phi;
use crate::model::Problem;
use crate::util::rng::Rng;

use super::calendar::{CalendarQueue, Ev, EvKind};
use super::hist::LogHist;
use super::report::{latency_summary, ClassStats, NodeStats, SimReport};
use super::{ArrivalTrace, Discipline, LatencyMode, SimSpec};
use std::collections::VecDeque;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum StationKind {
    /// `S → source device` virtual link: zero-delay pass-through.
    Admission,
    /// Real network edge: single exponential server at the link capacity.
    Comm,
    /// `device → D_w` computation link: `c` exponential servers sharing
    /// the device's compute capacity (the M/M/c analogue).
    Compute { device: usize },
}

/// One queueing station per augmented-graph edge.
#[derive(Clone, Debug)]
struct Station {
    kind: StationKind,
    servers: usize,
    /// Per-server exponential service rate.
    rate: f64,
    busy: usize,
    /// Waiting line: `(request, enqueue time)`.
    queue: VecDeque<(u32, f64)>,
    arrivals: u64,
    served: u64,
    dropped: u64,
    /// Σ service durations started (utilization numerator).
    busy_time: f64,
    /// Σ waiting time of served requests.
    wait_sum: f64,
    /// ∫ queue-depth dt up to `last_change`.
    queue_area: f64,
    last_change: f64,
    max_depth: usize,
}

#[derive(Clone, Copy, Debug)]
struct Req {
    w: u32,
    t0: f64,
}

/// Post-warm-up latency accounting — exact samples (the default and
/// bit-identity reference) or the streaming histogram.
#[derive(Clone, Debug)]
enum LatAccum {
    Exact(Vec<f64>),
    Hdr(LogHist),
}

impl LatAccum {
    fn new(mode: LatencyMode) -> LatAccum {
        match mode {
            LatencyMode::Exact => LatAccum::Exact(Vec::new()),
            LatencyMode::Hdr => LatAccum::Hdr(LogHist::new()),
        }
    }

    #[inline]
    fn record(&mut self, lat: f64) {
        match self {
            LatAccum::Exact(v) => v.push(lat),
            LatAccum::Hdr(h) => h.record(lat),
        }
    }

    fn measured(&self) -> u64 {
        match self {
            LatAccum::Exact(v) => v.len() as u64,
            LatAccum::Hdr(h) => h.count(),
        }
    }

    /// `(mean, p50, p99, p999)` over the recorded completions.
    fn summary(&self) -> (f64, f64, f64, f64) {
        match self {
            LatAccum::Exact(v) => latency_summary(v),
            LatAccum::Hdr(h) => h.summary(),
        }
    }
}

#[derive(Clone, Debug)]
struct ClassAccum {
    arrivals: u64,
    completed: u64,
    dropped: u64,
    /// End-to-end latencies of post-warm-up admissions.
    lat: LatAccum,
}

/// Per-window deltas returned by [`Simulator::run_until`] — the streaming
/// objective consumed by `SimRun`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WindowStats {
    pub completed: u64,
    pub dropped: u64,
    /// Mean end-to-end latency of this window's completions (0 if none).
    pub mean_latency_s: f64,
}

/// The discrete-event engine. A run is a pure function of
/// `(problem, φ, Λ, SimSpec, seed)`: one calendar queue popping the stable
/// `(time, seq)` order, one RNG consumed in event order, no wall-clock or
/// thread dependence. Pinned bitwise (exact latency mode) against
/// [`super::reference::simulate_requests_reference`].
pub struct Simulator<'p> {
    problem: &'p Problem,
    spec: SimSpec,
    traces: Vec<ArrivalTrace>,
    lam: Vec<f64>,
    /// Σ Λ over each class's session block (admission split normalizer).
    class_lam_sum: Vec<f64>,
    /// CSR routing tables: row `w * n_nodes + i` spans
    /// `route_off[row]..route_off[row+1]` of the flat lane arrays.
    route_off: Vec<u32>,
    route_edge: Vec<u32>,
    route_phi: Vec<f64>,
    /// Left-to-right Σ φ per row, precomputed in [`Simulator::set_phi`].
    row_sum: Vec<f64>,
    stations: Vec<Station>,
    /// Computation-link edge of each real device (per-node telemetry).
    comp_edge: Vec<usize>,
    cal: CalendarQueue,
    seq: u64,
    clock: f64,
    rng: Rng,
    /// Slab request pool: slots recycled through `free`.
    reqs: Vec<Req>,
    free: Vec<u32>,
    inflight: u64,
    peak_inflight: u64,
    events: u64,
    admitted: u64,
    completed: u64,
    dropped: u64,
    classes: Vec<ClassAccum>,
    win_completed: u64,
    win_dropped: u64,
    win_lat_sum: f64,
}

impl<'p> Simulator<'p> {
    /// Build a simulator over `problem` with uniform routing (override via
    /// [`Simulator::set_phi`]). `traces` gives each task class's arrival
    /// process in sim time; `lam` the per-session allocation splitting
    /// each class's admissions across versions.
    pub fn new(
        problem: &'p Problem,
        spec: SimSpec,
        traces: Vec<ArrivalTrace>,
        lam: Vec<f64>,
        seed: u64,
    ) -> Simulator<'p> {
        spec.validate().expect("invalid SimSpec");
        let n_classes = problem.workload.n_classes();
        assert_eq!(traces.len(), n_classes, "one arrival trace per class");
        assert_eq!(lam.len(), problem.n_sessions(), "Λ must cover every session");
        let net = &problem.net;
        let n_real = net.n_real;
        let mut stations = Vec::with_capacity(net.graph.n_edges());
        let mut comp_edge = vec![usize::MAX; n_real];
        for (eid, e) in net.graph.edges().iter().enumerate() {
            let kind = if e.src == AugmentedNet::SOURCE {
                StationKind::Admission
            } else if e.dst > n_real {
                StationKind::Compute { device: e.src - 1 }
            } else {
                StationKind::Comm
            };
            let (servers, rate) = match kind {
                StationKind::Admission => (1, 1.0), // pass-through, never serves
                StationKind::Compute { device } => {
                    comp_edge[device] = eid;
                    let c = spec.servers_per_node;
                    (c, e.capacity / c as f64)
                }
                StationKind::Comm => (1, e.capacity),
            };
            stations.push(Station {
                kind,
                servers,
                rate,
                busy: 0,
                queue: VecDeque::new(),
                arrivals: 0,
                served: 0,
                dropped: 0,
                busy_time: 0.0,
                wait_sum: 0.0,
                queue_area: 0.0,
                last_change: 0.0,
                max_depth: 0,
            });
        }
        // φ-independent CSR index over the routing lanes, built once —
        // set_phi only refreshes the φ values and row sums in place.
        let n_nodes = net.n_nodes();
        let mut route_off = Vec::with_capacity(net.n_sessions() * n_nodes + 1);
        route_off.push(0u32);
        let mut route_edge: Vec<u32> = Vec::new();
        for w in 0..net.n_sessions() {
            for i in 0..n_nodes {
                route_edge.extend(net.lanes(w, i).iter().map(|&e| e as u32));
                route_off.push(route_edge.len() as u32);
            }
        }
        let route_phi = vec![0.0; route_edge.len()];
        let row_sum = vec![0.0; net.n_sessions() * n_nodes];
        let latency = spec.latency;
        let mut sim = Simulator {
            problem,
            spec,
            traces,
            lam,
            class_lam_sum: Vec::new(),
            route_off,
            route_edge,
            route_phi,
            row_sum,
            stations,
            comp_edge,
            cal: CalendarQueue::new(),
            seq: 0,
            clock: 0.0,
            rng: Rng::seed_from(seed),
            reqs: Vec::new(),
            free: Vec::new(),
            inflight: 0,
            peak_inflight: 0,
            events: 0,
            admitted: 0,
            completed: 0,
            dropped: 0,
            classes: (0..n_classes)
                .map(|_| ClassAccum {
                    arrivals: 0,
                    completed: 0,
                    dropped: 0,
                    lat: LatAccum::new(latency),
                })
                .collect(),
            win_completed: 0,
            win_dropped: 0,
            win_lat_sum: 0.0,
        };
        sim.refresh_class_sums();
        sim.rebuild_route(&Phi::uniform(net));
        // prime one pending arrival per class
        for c in 0..n_classes {
            let t = sim.next_arrival(c, 0.0);
            if t < sim.spec.horizon_s {
                sim.schedule(t, EvKind::Arrival { class: c as u32 });
            }
        }
        sim
    }

    /// Swap in a new routing configuration (e.g. the next window's φ from
    /// a live `AllocationRun`). In-flight requests are unaffected; future
    /// routing decisions sample the new split ratios. Allocation-free:
    /// only the CSR φ values and row sums are overwritten.
    pub fn set_phi(&mut self, phi: &Phi) {
        self.rebuild_route(phi);
    }

    /// Swap in a new allocation (splits each class's future admissions).
    pub fn set_lam(&mut self, lam: &[f64]) {
        assert_eq!(lam.len(), self.problem.n_sessions());
        self.lam.copy_from_slice(lam);
        self.refresh_class_sums();
    }

    pub fn events(&self) -> u64 {
        self.events
    }

    /// The allocation currently splitting class admissions.
    pub fn lam(&self) -> &[f64] {
        &self.lam
    }

    pub fn clock(&self) -> f64 {
        self.clock
    }

    pub fn spec(&self) -> &SimSpec {
        &self.spec
    }

    /// High-water mark of concurrently in-flight requests — the slab
    /// pool's resident size (the reference derives the same number from
    /// its counters, so the field is bit-comparable).
    pub fn peak_inflight(&self) -> u64 {
        self.peak_inflight
    }

    #[inline]
    fn schedule(&mut self, time: f64, kind: EvKind) {
        let seq = self.seq;
        self.seq += 1;
        self.cal.push(Ev { time, seq, kind });
    }

    fn refresh_class_sums(&mut self) {
        self.class_lam_sum = self
            .problem
            .workload
            .class_spans
            .iter()
            .map(|&(s0, s1)| self.lam[s0..s1].iter().sum())
            .collect();
    }

    /// Refresh the CSR φ values and row sums in place. The sum runs
    /// left-to-right over the same lane order as the reference's per-hop
    /// `row.iter().sum()`, so [`Simulator::route_from`]'s inverse-CDF
    /// scan sees bitwise-identical numbers.
    fn rebuild_route(&mut self, phi: &Phi) {
        let net = &self.problem.net;
        let n_nodes = net.n_nodes();
        for w in 0..net.n_sessions() {
            let frac = &phi.frac[w];
            for i in 0..n_nodes {
                let row = w * n_nodes + i;
                let (a, b) = (self.route_off[row] as usize, self.route_off[row + 1] as usize);
                for k in a..b {
                    self.route_phi[k] = frac[self.route_edge[k] as usize];
                }
                self.row_sum[row] = self.route_phi[a..b].iter().sum();
            }
        }
    }

    /// Next event time of class `c`'s piecewise-constant Poisson stream
    /// after `from`. Exact across rate breakpoints: a draw that would
    /// cross a segment boundary is restarted *from* the boundary at the
    /// new rate (memorylessness), no thinning involved.
    fn next_arrival(&mut self, c: usize, from: f64) -> f64 {
        let mut t = from;
        loop {
            let (rate, end) = self.traces[c].segment_at(t);
            if rate <= 0.0 {
                if end.is_finite() {
                    t = end;
                    continue;
                }
                return f64::INFINITY;
            }
            let dt = self.rng.exponential(rate);
            if t + dt < end {
                return t + dt;
            }
            t = end;
        }
    }

    /// Process every event up to and including `t_end`, returning the
    /// window's completion/drop deltas. Passing `f64::INFINITY` drains
    /// the system (arrivals are only ever scheduled below the horizon).
    pub fn run_until(&mut self, t_end: f64) -> WindowStats {
        self.win_completed = 0;
        self.win_dropped = 0;
        self.win_lat_sum = 0.0;
        while let Some(ev) = self.cal.pop_at_most(t_end) {
            self.clock = ev.time;
            self.events += 1;
            match ev.kind {
                EvKind::Arrival { class } => self.on_arrival(class as usize),
                EvKind::Depart { edge, req } => self.on_depart(edge as usize, req),
            }
        }
        if t_end.is_finite() && t_end > self.clock {
            self.clock = t_end;
        }
        WindowStats {
            completed: self.win_completed,
            dropped: self.win_dropped,
            mean_latency_s: if self.win_completed > 0 {
                self.win_lat_sum / self.win_completed as f64
            } else {
                0.0
            },
        }
    }

    /// Run the arrival horizon, drain the system, report.
    pub fn run_to_end(&mut self) -> SimReport {
        let h = self.spec.horizon_s;
        self.run_until(h);
        self.run_until(f64::INFINITY);
        self.report()
    }

    /// Claim a slab slot for a newly admitted request, recycling a freed
    /// one when available. Ids are event payload only — never ordering
    /// inputs — so recycling is behaviorally invisible.
    #[inline]
    fn alloc_req(&mut self, w: u32, t0: f64) -> u32 {
        self.inflight += 1;
        if self.inflight > self.peak_inflight {
            self.peak_inflight = self.inflight;
        }
        match self.free.pop() {
            Some(id) => {
                self.reqs[id as usize] = Req { w, t0 };
                id
            }
            None => {
                let id = self.reqs.len() as u32;
                self.reqs.push(Req { w, t0 });
                id
            }
        }
    }

    /// Return a finished (completed or dropped) request's slot to the
    /// freelist. Callers guarantee no pending event references `id`.
    #[inline]
    fn free_req(&mut self, id: u32) {
        self.inflight -= 1;
        self.free.push(id);
    }

    fn on_arrival(&mut self, c: usize) {
        let t = self.clock;
        // schedule the class's next admission first (fixed RNG order)
        let nt = self.next_arrival(c, t);
        if nt < self.spec.horizon_s {
            self.schedule(nt, EvKind::Arrival { class: c as u32 });
        }
        // thin the class arrival onto a session ∝ Λ
        let (s0, s1) = self.problem.workload.class_spans[c];
        let total = self.class_lam_sum[c];
        let w = if total > 0.0 {
            let mut x = self.rng.f64() * total;
            let mut chosen = s0;
            for s in s0..s1 {
                let f = self.lam[s];
                if x < f {
                    chosen = s;
                    break;
                }
                x -= f;
                chosen = s;
            }
            chosen
        } else {
            s0
        };
        let req = self.alloc_req(w as u32, t);
        self.admitted += 1;
        self.classes[c].arrivals += 1;
        self.route_from(AugmentedNet::SOURCE, req);
    }

    /// Walk the request from `node` until it hits a delaying station or
    /// its destination. Admission links are zero-delay, so the walk only
    /// loops across those; comm/compute stations terminate it.
    fn route_from(&mut self, mut node: usize, req: u32) {
        let w = self.reqs[req as usize].w as usize;
        let dnode = self.problem.net.dnode(w);
        let n_nodes = self.problem.net.n_nodes();
        loop {
            if node == dnode {
                self.complete(req);
                return;
            }
            let row = w * n_nodes + node;
            let (a, b) = (self.route_off[row] as usize, self.route_off[row + 1] as usize);
            if a == b {
                // unreachable on validated nets; account rather than hang
                self.drop_req(req, None);
                return;
            }
            let sum = self.row_sum[row];
            let mut x = self.rng.f64() * sum.max(1e-300);
            let mut chosen = self.route_edge[a];
            for k in a..b {
                let f = self.route_phi[k];
                if x < f {
                    chosen = self.route_edge[k];
                    break;
                }
                x -= f;
                chosen = self.route_edge[k];
            }
            let e = chosen as usize;
            if self.stations[e].kind == StationKind::Admission {
                node = self.problem.net.graph.edge(e).dst;
                continue;
            }
            self.enqueue(e, req);
            return;
        }
    }

    fn enqueue(&mut self, e: usize, req: u32) {
        let t = self.clock;
        let cap = self.spec.queue_capacity;
        let st = &mut self.stations[e];
        st.arrivals += 1;
        if st.busy < st.servers {
            st.busy += 1;
            let service = self.rng.exponential(st.rate);
            st.busy_time += service;
            self.schedule(t + service, EvKind::Depart { edge: e as u32, req });
        } else if cap > 0 && st.queue.len() >= cap {
            st.dropped += 1;
            self.drop_req(req, Some(e));
        } else {
            let depth = st.queue.len();
            st.queue_area += depth as f64 * (t - st.last_change);
            st.last_change = t;
            st.queue.push_back((req, t));
            st.max_depth = st.max_depth.max(st.queue.len());
        }
    }

    fn on_depart(&mut self, e: usize, req: u32) {
        let t = self.clock;
        self.stations[e].served += 1;
        let dst = self.problem.net.graph.edge(e).dst;
        self.route_from(dst, req);
        // backfill the freed server from the waiting line
        let disc = self.spec.discipline;
        let st = &mut self.stations[e];
        let next = match disc {
            Discipline::Fifo => st.queue.pop_front(),
            Discipline::Lifo => st.queue.pop_back(),
        };
        match next {
            Some((nreq, at)) => {
                st.queue_area += (st.queue.len() + 1) as f64 * (t - st.last_change);
                st.last_change = t;
                st.wait_sum += t - at;
                let service = self.rng.exponential(st.rate);
                st.busy_time += service;
                self.schedule(t + service, EvKind::Depart { edge: e as u32, req: nreq });
            }
            None => st.busy -= 1,
        }
    }

    fn complete(&mut self, req: u32) {
        let r = self.reqs[req as usize];
        let c = self.problem.workload.class_of_session(r.w as usize);
        let lat = self.clock - r.t0;
        self.completed += 1;
        self.classes[c].completed += 1;
        if r.t0 >= self.spec.warmup_s {
            self.classes[c].lat.record(lat);
        }
        self.win_completed += 1;
        self.win_lat_sum += lat;
        self.free_req(req);
    }

    fn drop_req(&mut self, req: u32, _station: Option<usize>) {
        let r = self.reqs[req as usize];
        let c = self.problem.workload.class_of_session(r.w as usize);
        self.dropped += 1;
        self.classes[c].dropped += 1;
        self.win_dropped += 1;
        self.free_req(req);
    }

    /// Snapshot the accumulated history into a [`SimReport`]. No
    /// wall-clock enters the report — same-seed runs are bit-comparable.
    pub fn report(&self) -> SimReport {
        let span = self.clock.max(1e-12);
        // global roll-up over classes: concatenate (exact) or merge (hdr)
        let (mean, p50, p99, p999) = match self.spec.latency {
            LatencyMode::Exact => {
                let mut all: Vec<f64> = Vec::new();
                for cl in &self.classes {
                    if let LatAccum::Exact(v) = &cl.lat {
                        all.extend_from_slice(v);
                    }
                }
                latency_summary(&all)
            }
            LatencyMode::Hdr => {
                let mut all = LogHist::new();
                for cl in &self.classes {
                    if let LatAccum::Hdr(h) = &cl.lat {
                        all.merge(h);
                    }
                }
                all.summary()
            }
        };
        let classes = self
            .classes
            .iter()
            .enumerate()
            .map(|(c, cl)| {
                let (m, q50, q99, q999) = cl.lat.summary();
                ClassStats {
                    name: self.problem.workload.class_names[c].clone(),
                    arrivals: cl.arrivals,
                    completed: cl.completed,
                    dropped: cl.dropped,
                    measured: cl.lat.measured(),
                    mean_latency_s: m,
                    p50_latency_s: q50,
                    p99_latency_s: q99,
                    p999_latency_s: q999,
                }
            })
            .collect();
        let nodes = self
            .comp_edge
            .iter()
            .enumerate()
            .filter(|&(_, &e)| e != usize::MAX)
            .map(|(d, &e)| {
                let st = &self.stations[e];
                let tail = st.queue.len() as f64 * (self.clock - st.last_change);
                NodeStats {
                    device: d,
                    arrivals: st.arrivals,
                    served: st.served,
                    dropped: st.dropped,
                    utilization: st.busy_time / (span * st.servers as f64),
                    mean_queue_depth: (st.queue_area + tail) / span,
                    max_queue_depth: st.max_depth,
                    mean_wait_s: st.wait_sum / st.served.max(1) as f64,
                }
            })
            .collect();
        SimReport {
            horizon_s: self.spec.horizon_s,
            warmup_s: self.spec.warmup_s,
            end_s: self.clock,
            events: self.events,
            arrivals: self.admitted,
            completed: self.completed,
            dropped: self.dropped,
            in_flight: self.admitted - self.completed - self.dropped,
            peak_inflight: self.peak_inflight,
            mean_latency_s: mean,
            p50_latency_s: p50,
            p99_latency_s: p99,
            p999_latency_s: p999,
            classes,
            nodes,
        }
    }
}

/// One-shot replay: run `(φ, Λ)` over the full horizon, drain, report.
pub fn simulate_requests(
    problem: &Problem,
    phi: &Phi,
    lam: &[f64],
    traces: Vec<ArrivalTrace>,
    spec: SimSpec,
    seed: u64,
) -> SimReport {
    let mut sim = Simulator::new(problem, spec, traces, lam.to_vec(), seed);
    sim.set_phi(phi);
    sim.run_to_end()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topologies;
    use crate::sim::reference::simulate_requests_reference;

    fn small_problem(seed: u64) -> Problem {
        let mut rng = Rng::seed_from(seed);
        let net = topologies::connected_er(8, 0.35, 2, &mut rng);
        Problem::new(net, 20.0, crate::model::cost::CostKind::Queue)
    }

    fn constant_traces(problem: &Problem) -> Vec<ArrivalTrace> {
        problem
            .workload
            .class_rates
            .iter()
            .map(|&r| ArrivalTrace::constant(r))
            .collect()
    }

    #[test]
    fn conservation_and_counts() {
        let problem = small_problem(7);
        let lam = problem.uniform_allocation();
        let spec = SimSpec { horizon_s: 50.0, ..SimSpec::default() };
        let traces = constant_traces(&problem);
        let report =
            simulate_requests(&problem, &Phi::uniform(&problem.net), &lam, traces, spec, 1);
        assert!(report.arrivals > 0);
        assert_eq!(report.in_flight, 0, "drained run leaves nothing in flight");
        assert_eq!(report.arrivals, report.completed + report.dropped);
        assert_eq!(
            report.arrivals,
            report.classes.iter().map(|c| c.arrivals).sum::<u64>()
        );
        assert!(report.events >= report.arrivals);
        assert!(report.peak_inflight > 0);
        assert!(report.peak_inflight <= report.arrivals);
        assert!(report.mean_latency_s > 0.0);
        assert!(report.p50_latency_s <= report.p99_latency_s);
        assert!(report.p99_latency_s <= report.p999_latency_s);
    }

    #[test]
    fn same_seed_bit_identical_reports() {
        let problem = small_problem(3);
        let lam = problem.uniform_allocation();
        let spec = SimSpec { horizon_s: 30.0, ..SimSpec::default() };
        let a = simulate_requests(
            &problem,
            &Phi::uniform(&problem.net),
            &lam,
            constant_traces(&problem),
            spec.clone(),
            9,
        );
        let b = simulate_requests(
            &problem,
            &Phi::uniform(&problem.net),
            &lam,
            constant_traces(&problem),
            spec,
            9,
        );
        assert_eq!(a, b);
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    }

    #[test]
    fn matches_the_reference_engine_bitwise() {
        for seed in [1u64, 5, 23] {
            let problem = small_problem(seed);
            let lam = problem.uniform_allocation();
            let spec = SimSpec { horizon_s: 25.0, ..SimSpec::default() };
            let phi = Phi::uniform(&problem.net);
            let fast = simulate_requests(
                &problem,
                &phi,
                &lam,
                constant_traces(&problem),
                spec.clone(),
                seed,
            );
            let slow = simulate_requests_reference(
                &problem,
                &phi,
                &lam,
                constant_traces(&problem),
                spec,
                seed,
            );
            assert_eq!(fast, slow, "optimized core diverged from the reference (seed {seed})");
        }
    }

    #[test]
    fn windowed_run_matches_one_shot() {
        let problem = small_problem(5);
        let lam = problem.uniform_allocation();
        let spec = SimSpec { horizon_s: 40.0, ..SimSpec::default() };
        let one = simulate_requests(
            &problem,
            &Phi::uniform(&problem.net),
            &lam,
            constant_traces(&problem),
            spec.clone(),
            4,
        );
        let mut sim =
            Simulator::new(&problem, spec, constant_traces(&problem), lam.clone(), 4);
        sim.set_phi(&Phi::uniform(&problem.net));
        for k in 1..=8 {
            sim.run_until(40.0 * k as f64 / 8.0);
        }
        sim.run_until(f64::INFINITY);
        assert_eq!(sim.report(), one, "window boundaries must not change history");
    }

    #[test]
    fn bounded_queue_drops() {
        let problem = small_problem(11);
        let lam = problem.uniform_allocation();
        // saturate: arrival rate far above every capacity, one waiting slot
        let traces = vec![ArrivalTrace::constant(500.0); problem.workload.n_classes()];
        let spec = SimSpec { horizon_s: 10.0, queue_capacity: 1, ..SimSpec::default() };
        let report =
            simulate_requests(&problem, &Phi::uniform(&problem.net), &lam, traces, spec, 2);
        assert!(report.dropped > 0, "overload with capacity 1 must drop");
        assert_eq!(report.arrivals, report.completed + report.dropped);
        let node_drops: u64 = report.nodes.iter().map(|n| n.dropped).sum();
        assert!(node_drops <= report.dropped, "node drops are a subset");
    }

    #[test]
    fn slab_stays_bounded_by_peak_inflight() {
        let problem = small_problem(13);
        let lam = problem.uniform_allocation();
        let spec = SimSpec { horizon_s: 60.0, ..SimSpec::default() };
        let mut sim =
            Simulator::new(&problem, spec, constant_traces(&problem), lam.clone(), 17);
        sim.set_phi(&Phi::uniform(&problem.net));
        let report = sim.run_to_end();
        assert!(report.arrivals > 1000, "want a non-trivial run");
        assert_eq!(sim.reqs.len() as u64, report.peak_inflight, "slab high-water = peak");
        assert!(
            report.peak_inflight < report.arrivals / 2,
            "recycling must keep the pool well below total admissions \
             (peak {} vs arrivals {})",
            report.peak_inflight,
            report.arrivals
        );
    }

    #[test]
    fn hdr_mode_tracks_exact_mode() {
        let problem = small_problem(19);
        let lam = problem.uniform_allocation();
        let phi = Phi::uniform(&problem.net);
        let exact_spec = SimSpec { horizon_s: 80.0, ..SimSpec::default() };
        let hdr_spec = SimSpec { latency: LatencyMode::Hdr, ..exact_spec.clone() };
        let exact = simulate_requests(
            &problem,
            &phi,
            &lam,
            constant_traces(&problem),
            exact_spec,
            31,
        );
        let hdr =
            simulate_requests(&problem, &phi, &lam, constant_traces(&problem), hdr_spec, 31);
        // identical event history: every counter matches bitwise
        assert_eq!(hdr.arrivals, exact.arrivals);
        assert_eq!(hdr.completed, exact.completed);
        assert_eq!(hdr.events, exact.events);
        assert_eq!(hdr.peak_inflight, exact.peak_inflight);
        assert_eq!(hdr.end_s.to_bits(), exact.end_s.to_bits());
        // per-class means share the same sequential sum: bitwise equal
        for (h, e) in hdr.classes.iter().zip(exact.classes.iter()) {
            assert_eq!(h.measured, e.measured);
            assert_eq!(h.mean_latency_s.to_bits(), e.mean_latency_s.to_bits());
        }
        // quantiles agree to the histogram's resolution
        for (h, e) in [(hdr.p50_latency_s, exact.p50_latency_s), (hdr.p99_latency_s, exact.p99_latency_s)]
        {
            if e > 0.0 {
                assert!((h - e).abs() / e < 5e-3, "hdr {h} vs exact {e}");
            }
        }
    }
}
