//! **`sim`** — request-level discrete-event simulation of a served
//! configuration.
//!
//! The optimizers in [`crate::routing`] / [`crate::allocation`] work on the
//! paper's *fluid* flow model (eqs. 1–4): session rates `t_i(w)` (eq. 1)
//! split by φ, link flows `F_ij` (eq. 2), and a congestion cost
//! `D_ij(F_ij, C_ij)` per link (eqs. 3–4). The fluid optimum says nothing
//! about request-granularity effects — burstiness, queue backlogs,
//! head-of-line blocking, tail latency, loss under bounded buffers. This
//! module replays *individual requests* through an optimized `(Λ, φ)`
//! configuration and measures exactly those effects.
//!
//! ## Mapping the cost model to queueing stations
//!
//! Every edge of the augmented graph becomes a service station:
//!
//! * **communication links** (real network edges) — a single-server FIFO
//!   queue with exponential service at rate `C_ij` (the link capacity, in
//!   the same request/s units as the admitted rates). Its steady-state
//!   mean number-in-system is `F/(C−F)` — *exactly* the
//!   [`crate::model::cost::CostKind::Queue`] family of eq. 3, so for the
//!   `queue` cost the fluid objective Σ `D_ij` is the fluid prediction of
//!   the summed mean queue lengths this simulator measures (Little's law;
//!   the `exp`/`linear`/`cubic` families are monotone congestion proxies
//!   and correspond qualitatively);
//! * **computation links** (device `d` → its version's destination
//!   `D_w`) — an M/M/c-style station: [`SimSpec::servers_per_node`]
//!   servers, each with exponential service at rate `C_d / c` so the
//!   station's total capacity equals the fluid compute capacity drawn (or
//!   pinned via `NodeSpec::compute_capacity`) for the device. Finishing
//!   service on a computation link *is* the DNN inference — the request
//!   completes when it reaches `D_w`;
//! * **admission links** (`S` → source devices) — pass-through with zero
//!   delay (their fluid capacity is the non-binding `SOURCE_CAP`).
//!
//! Per-request routing samples the next hop from the optimized φ split
//! ratios — the probabilistic interpretation of the fluid split — and
//! arrivals are Poisson per task class ([`ArrivalTrace`]: constant rates
//! or piecewise-constant traces compiled from `RateSpec::Trace`
//! breakpoints), thinned onto sessions proportionally to Λ.
//!
//! ## Determinism
//!
//! The event core pops a **total order** keyed on `(time, seq)` — the
//! `seq` tie-break makes event order total, and a single seeded
//! [`crate::util::rng::Rng`] is consumed in event order, so a run is a
//! pure function of `(problem, φ, Λ, SimSpec, seed)`. The engine worker
//! count never enters the simulation: the same seed produces a
//! bit-identical [`SimReport`] at any `--workers` value (asserted by
//! `rust/tests/test_sim.rs`).
//!
//! The scheduler is a [`calendar::CalendarQueue`] — time-bucketed with
//! lazy resize and a heap fallback for far-future events. Its **ordering
//! invariant**: bucket assignment is a monotone function of time, each
//! bucket stays sorted, and pushes never predate the last pop, so the
//! calendar pops the *identical* `(time, seq)` sequence a
//! `BinaryHeap<Ev>` would (randomized equivalence test in
//! `rust/tests/test_sim.rs`). Request ids come from a slab pool that
//! recycles completed/dropped slots through a freelist — the **slab-id
//! non-ordering contract**: ids are event payload only, never compared,
//! never fed to the RNG, so recycling cannot change any simulated
//! outcome while keeping memory at O(peak in-flight)
//! ([`SimReport::peak_inflight`]).
//!
//! The PR-6 engine (binary heap, nested routing tables, no recycling) is
//! kept verbatim in [`reference`] and every optimization is pinned
//! bitwise against it in exact latency mode.
//!
//! ## Latency telemetry modes
//!
//! [`SimSpec::latency`] picks how post-warm-up completions are recorded:
//! [`LatencyMode::Exact`] (default) keeps every sample and computes
//! interpolated percentiles — the bit-identity reference;
//! [`LatencyMode::Hdr`] streams samples into a fixed-resolution
//! log-histogram ([`hist::LogHist`]) with ≤ 0.1% relative bucket width
//! and O(1) memory — the right choice for multi-million-request replays.
//! Hdr counters and per-class means stay bitwise-equal to exact mode
//! (same event history, same sequential sum); quantiles are approximate
//! within the documented bound.
//!
//! ## Validation
//!
//! `rust/tests/test_sim.rs` pins the core against closed forms: a
//! single-station scenario must reproduce the M/M/1 mean sojourn
//! `1/(μ−λ)` and mean wait `ρ/(μ−λ)`, and a multi-server station the
//! Erlang-C M/M/c wait, within seeded-CI tolerances.
//! `python/tests/test_sim_des.py` mirrors the same semantics in Python
//! against the same formulas.

pub mod calendar;
pub mod core;
pub mod hist;
pub mod reference;
pub mod report;

pub use self::core::{simulate_requests, Simulator, WindowStats};
pub use reference::simulate_requests_reference;
pub use report::{ClassStats, NodeStats, SimReport};

use crate::util::json::Json;

/// Queueing discipline of a station's waiting line.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Discipline {
    /// First-in-first-out (the default).
    Fifo,
    /// Last-in-first-out (stack service; fattens the tail).
    Lifo,
}

impl Discipline {
    pub fn parse(name: &str) -> Option<Discipline> {
        match name {
            "fifo" => Some(Discipline::Fifo),
            "lifo" => Some(Discipline::Lifo),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Discipline::Fifo => "fifo",
            Discipline::Lifo => "lifo",
        }
    }
}

/// How post-warm-up completion latencies are recorded.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LatencyMode {
    /// Keep every sample; interpolated percentiles at report time. The
    /// default and the bit-identity reference — O(completions) memory.
    Exact,
    /// Stream samples into a fixed-resolution log-histogram
    /// ([`hist::LogHist`]): O(1) memory, ≤ 0.1% relative bucket width.
    /// Counters and per-class means stay bitwise-equal to exact mode;
    /// quantiles are approximate within the bound.
    Hdr,
}

impl LatencyMode {
    pub fn parse(name: &str) -> Option<LatencyMode> {
        match name {
            "exact" => Some(LatencyMode::Exact),
            "hdr" => Some(LatencyMode::Hdr),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            LatencyMode::Exact => "exact",
            LatencyMode::Hdr => "hdr",
        }
    }
}

/// The scenario-level simulation knobs (the `"sim"` object of a scenario
/// file; every field optional there, falling back to these defaults).
#[derive(Clone, Debug, PartialEq)]
pub struct SimSpec {
    /// Simulated horizon in seconds: arrivals are admitted on
    /// `[0, horizon_s)`, then the system drains.
    pub horizon_s: f64,
    /// Requests admitted before this time are excluded from the latency
    /// percentiles (queue warm-up transient).
    pub warmup_s: f64,
    /// Bounded station buffers: maximum *waiting* requests per station
    /// (`0` = unbounded). Overflow drops the request (counted per class
    /// and per node).
    pub queue_capacity: usize,
    /// Servers per computation station (`c` of the M/M/c analogy); each
    /// serves at `capacity / c` so total station capacity matches the
    /// fluid model.
    pub servers_per_node: usize,
    /// Waiting-line discipline of every station.
    pub discipline: Discipline,
    /// Sim-seconds per outer-iteration unit when compiling
    /// `RateSpec::Trace` breakpoints into arrival-rate changes.
    pub trace_window_s: f64,
    /// Latency recording mode ([`LatencyMode::Exact`] by default).
    pub latency: LatencyMode,
}

impl Default for SimSpec {
    fn default() -> Self {
        SimSpec {
            horizon_s: 30.0,
            warmup_s: 0.0,
            queue_capacity: 0,
            servers_per_node: 1,
            discipline: Discipline::Fifo,
            trace_window_s: 1.0,
            latency: LatencyMode::Exact,
        }
    }
}

impl SimSpec {
    /// Structural validation (mirrors `ScenarioSpec::validate` style).
    pub fn validate(&self) -> Result<(), String> {
        if !(self.horizon_s > 0.0) {
            return Err(format!("sim horizon_s must be > 0 (got {})", self.horizon_s));
        }
        if !(self.warmup_s >= 0.0 && self.warmup_s < self.horizon_s) {
            return Err(format!(
                "sim warmup_s must be in [0, horizon_s) (got {} vs horizon {})",
                self.warmup_s, self.horizon_s
            ));
        }
        if self.servers_per_node == 0 {
            return Err("sim servers_per_node must be >= 1".to_string());
        }
        if !(self.trace_window_s > 0.0) {
            return Err(format!(
                "sim trace_window_s must be > 0 (got {})",
                self.trace_window_s
            ));
        }
        Ok(())
    }

    /// Parse the `"sim"` object of a scenario file. Missing fields fall
    /// back to the defaults; present-but-mistyped fields are hard errors
    /// and unknown fields are warned about, matching the spec layer.
    pub fn from_json(j: &Json) -> Result<SimSpec, String> {
        let obj = j.as_obj().ok_or_else(|| format!("bad sim '{j}' (want an object)"))?;
        const KNOWN: [&str; 7] = [
            "horizon_s",
            "warmup_s",
            "queue_capacity",
            "servers_per_node",
            "discipline",
            "trace_window_s",
            "latency",
        ];
        for key in obj.keys() {
            if !KNOWN.contains(&key.as_str()) {
                crate::log_warn!("sim spec: ignoring unknown field '{key}'");
            }
        }
        let mut spec = SimSpec::default();
        if let Some(x) = opt_f64(j, "horizon_s")? {
            spec.horizon_s = x;
        }
        if let Some(x) = opt_f64(j, "warmup_s")? {
            spec.warmup_s = x;
        }
        if let Some(x) = opt_usize(j, "queue_capacity")? {
            spec.queue_capacity = x;
        }
        if let Some(x) = opt_usize(j, "servers_per_node")? {
            spec.servers_per_node = x;
        }
        if !matches!(j.get("discipline"), Json::Null) {
            let d = j.get("discipline");
            spec.discipline = d
                .as_str()
                .and_then(Discipline::parse)
                .ok_or_else(|| format!("bad sim discipline '{d}' (fifo | lifo)"))?;
        }
        if let Some(x) = opt_f64(j, "trace_window_s")? {
            spec.trace_window_s = x;
        }
        if !matches!(j.get("latency"), Json::Null) {
            let m = j.get("latency");
            spec.latency = m
                .as_str()
                .and_then(LatencyMode::parse)
                .ok_or_else(|| format!("bad sim latency '{m}' (exact | hdr)"))?;
        }
        Ok(spec)
    }

    /// Serialize (the inverse of [`SimSpec::from_json`]).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("horizon_s", Json::from(self.horizon_s)),
            ("warmup_s", Json::from(self.warmup_s)),
            ("queue_capacity", Json::from(self.queue_capacity)),
            ("servers_per_node", Json::from(self.servers_per_node)),
            ("discipline", Json::from(self.discipline.name())),
            ("trace_window_s", Json::from(self.trace_window_s)),
            ("latency", Json::from(self.latency.name())),
        ])
    }
}

fn opt_f64(j: &Json, key: &str) -> Result<Option<f64>, String> {
    match j.get(key) {
        Json::Null => Ok(None),
        v => v
            .as_f64()
            .map(Some)
            .ok_or_else(|| format!("bad sim {key} '{v}' (want a number)")),
    }
}

fn opt_usize(j: &Json, key: &str) -> Result<Option<usize>, String> {
    match j.get(key) {
        Json::Null => Ok(None),
        v => match v.as_f64() {
            Some(x) if x >= 0.0 && x.fract() == 0.0 => Ok(Some(x as usize)),
            _ => Err(format!("bad sim {key} '{v}' (want a non-negative integer)")),
        },
    }
}

/// A task class's arrival rate over *sim time*: piecewise-constant
/// `(start_s, rate)` segments, first segment starting at 0. The exact
/// piecewise-Poisson generator lives in [`Simulator`]: an exponential
/// inter-arrival draw that crosses a segment boundary is restarted *from*
/// the boundary at the new rate (exact by memorylessness, no thinning).
#[derive(Clone, Debug, PartialEq)]
pub struct ArrivalTrace {
    /// `(start_s, rate)` segments, strictly increasing in `start_s`.
    pub points: Vec<(f64, f64)>,
}

impl ArrivalTrace {
    /// A constant-rate Poisson stream.
    pub fn constant(rate: f64) -> ArrivalTrace {
        ArrivalTrace { points: vec![(0.0, rate)] }
    }

    /// Compile outer-iteration breakpoints (`RateSpec::Trace` shape) into
    /// sim time at `window_s` sim-seconds per iteration.
    pub fn from_breakpoints(points: &[(usize, f64)], window_s: f64) -> ArrivalTrace {
        ArrivalTrace {
            points: points.iter().map(|&(t, r)| (t as f64 * window_s, r)).collect(),
        }
    }

    /// The rate in effect at time `t` and the end of its segment
    /// (`f64::INFINITY` for the last segment).
    pub fn segment_at(&self, t: f64) -> (f64, f64) {
        let mut rate = 0.0;
        let mut end = f64::INFINITY;
        for (k, &(t0, r)) in self.points.iter().enumerate() {
            if t0 <= t {
                rate = r;
                end = self.points.get(k + 1).map(|&(t1, _)| t1).unwrap_or(f64::INFINITY);
            } else {
                break;
            }
        }
        (rate, end)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_roundtrips_and_validates() {
        let spec = SimSpec {
            horizon_s: 12.5,
            warmup_s: 2.0,
            queue_capacity: 64,
            servers_per_node: 3,
            discipline: Discipline::Lifo,
            trace_window_s: 0.25,
            latency: LatencyMode::Hdr,
        };
        spec.validate().unwrap();
        let back = SimSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back, spec);
        // defaults fill missing fields
        let partial = SimSpec::from_json(&Json::parse(r#"{"horizon_s": 5}"#).unwrap()).unwrap();
        assert_eq!(partial.horizon_s, 5.0);
        assert_eq!(partial.discipline, Discipline::Fifo);
        assert_eq!(partial.latency, LatencyMode::Exact);
    }

    #[test]
    fn spec_rejects_bad_fields() {
        for text in [
            r#"{"horizon_s": "long"}"#,
            r#"{"queue_capacity": 2.5}"#,
            r#"{"discipline": "random"}"#,
            r#"{"latency": "sampled"}"#,
            r#"7"#,
        ] {
            assert!(SimSpec::from_json(&Json::parse(text).unwrap()).is_err(), "{text}");
        }
        assert!(SimSpec { horizon_s: 0.0, ..SimSpec::default() }.validate().is_err());
        assert!(SimSpec { warmup_s: 31.0, ..SimSpec::default() }.validate().is_err());
        assert!(SimSpec { servers_per_node: 0, ..SimSpec::default() }.validate().is_err());
        assert!(SimSpec { trace_window_s: 0.0, ..SimSpec::default() }.validate().is_err());
    }

    #[test]
    fn trace_segments() {
        let tr = ArrivalTrace::from_breakpoints(&[(0, 10.0), (5, 20.0), (9, 15.0)], 2.0);
        assert_eq!(tr.segment_at(0.0), (10.0, 10.0));
        assert_eq!(tr.segment_at(9.99), (10.0, 10.0));
        assert_eq!(tr.segment_at(10.0), (20.0, 18.0));
        assert_eq!(tr.segment_at(50.0), (15.0, f64::INFINITY));
        assert_eq!(ArrivalTrace::constant(7.0).segment_at(3.0), (7.0, f64::INFINITY));
    }
}
