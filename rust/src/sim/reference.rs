//! The PR-6 discrete-event engine, kept as the **pinned bit-identity
//! reference** for the optimized hot path in [`super::core`] — the same
//! playbook as `routing::reference` (plain sweeps vs. the batched engine)
//! and the scalar SIMD references: the naive structures stay in-tree,
//! exercised by tests and the `sim_replay_heap` bench row, and every
//! structural optimization must reproduce this engine's `SimReport`
//! *bitwise* in exact latency mode.
//!
//! Differences from the optimized core — all behaviorally invisible:
//!
//! * `BinaryHeap<Ev>` scheduler instead of the calendar queue (identical
//!   `(time, seq)` pop order by [`Ev`]'s `Ord`);
//! * nested `Vec<Vec<Vec<(edge, φ)>>>` routing table with the row sum
//!   recomputed on every hop (same left-to-right order as the CSR
//!   tables' precomputed sums, so the inverse-CDF scan consumes the
//!   identical RNG draw and picks the identical lane);
//! * `reqs` grows monotonically — no slab recycling — so its length is
//!   O(total admitted) rather than O(peak in-flight);
//! * exact `Vec<f64>` latency logs only (the reference for the default
//!   [`super::LatencyMode::Exact`]; the streaming histogram mode is an
//!   explicitly approximate opt-in with no reference path).
//!
//! `peak_inflight` is derived from the same admitted/completed/dropped
//! counters the optimized core's slab occupancy tracks, so the field is
//! bit-comparable too.

use std::collections::{BinaryHeap, VecDeque};

use crate::graph::augmented::AugmentedNet;
use crate::model::flow::Phi;
use crate::model::Problem;
use crate::util::rng::Rng;

use super::calendar::{Ev, EvKind};
use super::report::{latency_summary, ClassStats, NodeStats, SimReport};
use super::{ArrivalTrace, Discipline, SimSpec};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum StationKind {
    Admission,
    Comm,
    Compute { device: usize },
}

#[derive(Clone, Debug)]
struct Station {
    kind: StationKind,
    servers: usize,
    rate: f64,
    busy: usize,
    queue: VecDeque<(u32, f64)>,
    arrivals: u64,
    served: u64,
    dropped: u64,
    busy_time: f64,
    wait_sum: f64,
    queue_area: f64,
    last_change: f64,
    max_depth: usize,
}

#[derive(Clone, Copy, Debug)]
struct Req {
    w: u32,
    t0: f64,
}

#[derive(Clone, Debug, Default)]
struct ClassAccum {
    arrivals: u64,
    completed: u64,
    dropped: u64,
    lat: Vec<f64>,
}

/// The reference engine. Private mirror of the PR-6 `Simulator`; drive it
/// through [`simulate_requests_reference`].
struct ReferenceSimulator<'p> {
    problem: &'p Problem,
    spec: SimSpec,
    traces: Vec<ArrivalTrace>,
    lam: Vec<f64>,
    class_lam_sum: Vec<f64>,
    route: Vec<Vec<Vec<(u32, f64)>>>,
    stations: Vec<Station>,
    comp_edge: Vec<usize>,
    heap: BinaryHeap<Ev>,
    seq: u64,
    clock: f64,
    rng: Rng,
    reqs: Vec<Req>,
    events: u64,
    admitted: u64,
    completed: u64,
    dropped: u64,
    peak_inflight: u64,
    classes: Vec<ClassAccum>,
}

impl<'p> ReferenceSimulator<'p> {
    fn new(
        problem: &'p Problem,
        spec: SimSpec,
        traces: Vec<ArrivalTrace>,
        lam: Vec<f64>,
        seed: u64,
    ) -> ReferenceSimulator<'p> {
        spec.validate().expect("invalid SimSpec");
        let n_classes = problem.workload.n_classes();
        assert_eq!(traces.len(), n_classes, "one arrival trace per class");
        assert_eq!(lam.len(), problem.n_sessions(), "Λ must cover every session");
        let net = &problem.net;
        let n_real = net.n_real;
        let mut stations = Vec::with_capacity(net.graph.n_edges());
        let mut comp_edge = vec![usize::MAX; n_real];
        for (eid, e) in net.graph.edges().iter().enumerate() {
            let kind = if e.src == AugmentedNet::SOURCE {
                StationKind::Admission
            } else if e.dst > n_real {
                StationKind::Compute { device: e.src - 1 }
            } else {
                StationKind::Comm
            };
            let (servers, rate) = match kind {
                StationKind::Admission => (1, 1.0),
                StationKind::Compute { device } => {
                    comp_edge[device] = eid;
                    let c = spec.servers_per_node;
                    (c, e.capacity / c as f64)
                }
                StationKind::Comm => (1, e.capacity),
            };
            stations.push(Station {
                kind,
                servers,
                rate,
                busy: 0,
                queue: VecDeque::new(),
                arrivals: 0,
                served: 0,
                dropped: 0,
                busy_time: 0.0,
                wait_sum: 0.0,
                queue_area: 0.0,
                last_change: 0.0,
                max_depth: 0,
            });
        }
        let mut sim = ReferenceSimulator {
            problem,
            spec,
            traces,
            lam,
            class_lam_sum: Vec::new(),
            route: Vec::new(),
            stations,
            comp_edge,
            heap: BinaryHeap::new(),
            seq: 0,
            clock: 0.0,
            rng: Rng::seed_from(seed),
            reqs: Vec::new(),
            events: 0,
            admitted: 0,
            completed: 0,
            dropped: 0,
            peak_inflight: 0,
            classes: vec![ClassAccum::default(); n_classes],
        };
        sim.refresh_class_sums();
        sim.rebuild_route(&Phi::uniform(net));
        for c in 0..n_classes {
            let t = sim.next_arrival(c, 0.0);
            if t < sim.spec.horizon_s {
                let seq = sim.seq;
                sim.seq += 1;
                sim.heap.push(Ev { time: t, seq, kind: EvKind::Arrival { class: c as u32 } });
            }
        }
        sim
    }

    fn set_phi(&mut self, phi: &Phi) {
        self.rebuild_route(phi);
    }

    fn refresh_class_sums(&mut self) {
        self.class_lam_sum = self
            .problem
            .workload
            .class_spans
            .iter()
            .map(|&(s0, s1)| self.lam[s0..s1].iter().sum())
            .collect();
    }

    fn rebuild_route(&mut self, phi: &Phi) {
        let net = &self.problem.net;
        self.route = (0..net.n_sessions())
            .map(|w| {
                (0..net.n_nodes())
                    .map(|i| {
                        net.lanes(w, i)
                            .iter()
                            .map(|&e| (e as u32, phi.frac[w][e]))
                            .collect()
                    })
                    .collect()
            })
            .collect();
    }

    fn next_arrival(&mut self, c: usize, from: f64) -> f64 {
        let mut t = from;
        loop {
            let (rate, end) = self.traces[c].segment_at(t);
            if rate <= 0.0 {
                if end.is_finite() {
                    t = end;
                    continue;
                }
                return f64::INFINITY;
            }
            let dt = self.rng.exponential(rate);
            if t + dt < end {
                return t + dt;
            }
            t = end;
        }
    }

    fn run_until(&mut self, t_end: f64) {
        while let Some(top) = self.heap.peek() {
            if top.time > t_end {
                break;
            }
            let ev = self.heap.pop().expect("peeked event");
            self.clock = ev.time;
            self.events += 1;
            match ev.kind {
                EvKind::Arrival { class } => self.on_arrival(class as usize),
                EvKind::Depart { edge, req } => self.on_depart(edge as usize, req),
            }
        }
        if t_end.is_finite() && t_end > self.clock {
            self.clock = t_end;
        }
    }

    fn on_arrival(&mut self, c: usize) {
        let t = self.clock;
        let nt = self.next_arrival(c, t);
        if nt < self.spec.horizon_s {
            let seq = self.seq;
            self.seq += 1;
            self.heap.push(Ev { time: nt, seq, kind: EvKind::Arrival { class: c as u32 } });
        }
        let (s0, s1) = self.problem.workload.class_spans[c];
        let total = self.class_lam_sum[c];
        let w = if total > 0.0 {
            let mut x = self.rng.f64() * total;
            let mut chosen = s0;
            for s in s0..s1 {
                let f = self.lam[s];
                if x < f {
                    chosen = s;
                    break;
                }
                x -= f;
                chosen = s;
            }
            chosen
        } else {
            s0
        };
        let req = self.reqs.len() as u32;
        self.reqs.push(Req { w: w as u32, t0: t });
        self.admitted += 1;
        let inflight = self.admitted - self.completed - self.dropped;
        if inflight > self.peak_inflight {
            self.peak_inflight = inflight;
        }
        self.classes[c].arrivals += 1;
        self.route_from(AugmentedNet::SOURCE, req);
    }

    fn route_from(&mut self, mut node: usize, req: u32) {
        let w = self.reqs[req as usize].w as usize;
        let dnode = self.problem.net.dnode(w);
        loop {
            if node == dnode {
                self.complete(req);
                return;
            }
            let row = &self.route[w][node];
            if row.is_empty() {
                self.drop_req(req);
                return;
            }
            let sum: f64 = row.iter().map(|&(_, f)| f).sum();
            let mut x = self.rng.f64() * sum.max(1e-300);
            let mut chosen = row[0].0;
            for &(e, f) in row {
                if x < f {
                    chosen = e;
                    break;
                }
                x -= f;
                chosen = e;
            }
            let e = chosen as usize;
            if self.stations[e].kind == StationKind::Admission {
                node = self.problem.net.graph.edge(e).dst;
                continue;
            }
            self.enqueue(e, req);
            return;
        }
    }

    fn enqueue(&mut self, e: usize, req: u32) {
        let t = self.clock;
        let cap = self.spec.queue_capacity;
        let st = &mut self.stations[e];
        st.arrivals += 1;
        if st.busy < st.servers {
            st.busy += 1;
            let service = self.rng.exponential(st.rate);
            st.busy_time += service;
            let seq = self.seq;
            self.seq += 1;
            self.heap.push(Ev {
                time: t + service,
                seq,
                kind: EvKind::Depart { edge: e as u32, req },
            });
        } else if cap > 0 && st.queue.len() >= cap {
            st.dropped += 1;
            self.drop_req(req);
        } else {
            let depth = st.queue.len();
            st.queue_area += depth as f64 * (t - st.last_change);
            st.last_change = t;
            st.queue.push_back((req, t));
            st.max_depth = st.max_depth.max(st.queue.len());
        }
    }

    fn on_depart(&mut self, e: usize, req: u32) {
        let t = self.clock;
        self.stations[e].served += 1;
        let dst = self.problem.net.graph.edge(e).dst;
        self.route_from(dst, req);
        let disc = self.spec.discipline;
        let st = &mut self.stations[e];
        let next = match disc {
            Discipline::Fifo => st.queue.pop_front(),
            Discipline::Lifo => st.queue.pop_back(),
        };
        match next {
            Some((nreq, at)) => {
                st.queue_area += (st.queue.len() + 1) as f64 * (t - st.last_change);
                st.last_change = t;
                st.wait_sum += t - at;
                let service = self.rng.exponential(st.rate);
                st.busy_time += service;
                let seq = self.seq;
                self.seq += 1;
                self.heap.push(Ev {
                    time: t + service,
                    seq,
                    kind: EvKind::Depart { edge: e as u32, req: nreq },
                });
            }
            None => st.busy -= 1,
        }
    }

    fn complete(&mut self, req: u32) {
        let r = self.reqs[req as usize];
        let c = self.problem.workload.class_of_session(r.w as usize);
        let lat = self.clock - r.t0;
        self.completed += 1;
        self.classes[c].completed += 1;
        if r.t0 >= self.spec.warmup_s {
            self.classes[c].lat.push(lat);
        }
    }

    fn drop_req(&mut self, req: u32) {
        let r = self.reqs[req as usize];
        let c = self.problem.workload.class_of_session(r.w as usize);
        self.dropped += 1;
        self.classes[c].dropped += 1;
    }

    fn report(&self) -> SimReport {
        let span = self.clock.max(1e-12);
        let mut all: Vec<f64> = Vec::new();
        for cl in &self.classes {
            all.extend_from_slice(&cl.lat);
        }
        let (mean, p50, p99, p999) = latency_summary(&all);
        let classes = self
            .classes
            .iter()
            .enumerate()
            .map(|(c, cl)| {
                let (m, q50, q99, q999) = latency_summary(&cl.lat);
                ClassStats {
                    name: self.problem.workload.class_names[c].clone(),
                    arrivals: cl.arrivals,
                    completed: cl.completed,
                    dropped: cl.dropped,
                    measured: cl.lat.len() as u64,
                    mean_latency_s: m,
                    p50_latency_s: q50,
                    p99_latency_s: q99,
                    p999_latency_s: q999,
                }
            })
            .collect();
        let nodes = self
            .comp_edge
            .iter()
            .enumerate()
            .filter(|&(_, &e)| e != usize::MAX)
            .map(|(d, &e)| {
                let st = &self.stations[e];
                let tail = st.queue.len() as f64 * (self.clock - st.last_change);
                NodeStats {
                    device: d,
                    arrivals: st.arrivals,
                    served: st.served,
                    dropped: st.dropped,
                    utilization: st.busy_time / (span * st.servers as f64),
                    mean_queue_depth: (st.queue_area + tail) / span,
                    max_queue_depth: st.max_depth,
                    mean_wait_s: st.wait_sum / st.served.max(1) as f64,
                }
            })
            .collect();
        SimReport {
            horizon_s: self.spec.horizon_s,
            warmup_s: self.spec.warmup_s,
            end_s: self.clock,
            events: self.events,
            arrivals: self.admitted,
            completed: self.completed,
            dropped: self.dropped,
            in_flight: self.admitted - self.completed - self.dropped,
            peak_inflight: self.peak_inflight,
            mean_latency_s: mean,
            p50_latency_s: p50,
            p99_latency_s: p99,
            p999_latency_s: p999,
            classes,
            nodes,
        }
    }
}

/// One-shot replay on the reference engine: run `(φ, Λ)` over the full
/// horizon, drain, report. Exact latency mode only — the streaming
/// histogram is an optimized-core opt-in with no reference semantics
/// (`spec.latency` is ignored here).
pub fn simulate_requests_reference(
    problem: &Problem,
    phi: &Phi,
    lam: &[f64],
    traces: Vec<ArrivalTrace>,
    spec: SimSpec,
    seed: u64,
) -> SimReport {
    let mut sim = ReferenceSimulator::new(problem, spec, traces, lam.to_vec(), seed);
    sim.set_phi(phi);
    let h = sim.spec.horizon_s;
    sim.run_until(h);
    sim.run_until(f64::INFINITY);
    sim.report()
}
