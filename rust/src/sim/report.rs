//! Roll-up of per-request accounting into a [`SimReport`].
//!
//! The report is a pure function of the simulated history: it carries no
//! wall-clock fields, so same-seed runs can be compared bit-for-bit (the
//! CLI measures and prints elapsed time separately).

use crate::util::json::Json;
use crate::util::stats;

/// Per-class end-to-end latency and loss accounting. Latency percentiles
/// are computed over requests *admitted after the warm-up cutoff*; the
/// raw counters cover the whole run.
#[derive(Clone, Debug, PartialEq)]
pub struct ClassStats {
    pub name: String,
    /// Requests admitted (arrived at the source).
    pub arrivals: u64,
    /// Requests that reached their session's destination.
    pub completed: u64,
    /// Requests lost to a full station buffer.
    pub dropped: u64,
    /// Post-warm-up completions the percentiles are computed over.
    pub measured: u64,
    pub mean_latency_s: f64,
    pub p50_latency_s: f64,
    pub p99_latency_s: f64,
    pub p999_latency_s: f64,
}

/// Per-device queue-depth telemetry of the node's *computation* station
/// (the M/M/c analogue of its compute capacity).
#[derive(Clone, Debug, PartialEq)]
pub struct NodeStats {
    /// Real-device index (matches `NodeSpec::id`).
    pub device: usize,
    pub arrivals: u64,
    pub served: u64,
    pub dropped: u64,
    /// Fraction of server-time spent busy over the observed span.
    pub utilization: f64,
    /// Time-averaged waiting-line length (∫ depth dt / span).
    pub mean_queue_depth: f64,
    pub max_queue_depth: usize,
    /// Mean time served requests spent waiting in line (excludes service).
    pub mean_wait_s: f64,
}

/// The full simulation outcome. Deterministic for a fixed
/// `(problem, φ, Λ, SimSpec, seed)` — see the module docs of
/// [`crate::sim`].
#[derive(Clone, Debug, PartialEq)]
pub struct SimReport {
    /// Arrival horizon the run was configured with.
    pub horizon_s: f64,
    /// Warm-up cutoff excluded from the latency percentiles.
    pub warmup_s: f64,
    /// Sim time when the report was taken (≥ horizon after draining).
    pub end_s: f64,
    /// Discrete events processed (arrivals + service completions).
    pub events: u64,
    pub arrivals: u64,
    pub completed: u64,
    pub dropped: u64,
    /// Admitted but neither completed nor dropped yet (0 after a drain).
    pub in_flight: u64,
    /// High-water mark of concurrently in-flight requests — the resident
    /// size of the core's slab request pool (memory is O(this), not
    /// O(arrivals)).
    pub peak_inflight: u64,
    pub mean_latency_s: f64,
    pub p50_latency_s: f64,
    pub p99_latency_s: f64,
    pub p999_latency_s: f64,
    pub classes: Vec<ClassStats>,
    pub nodes: Vec<NodeStats>,
}

impl SimReport {
    pub fn to_json(&self) -> Json {
        let classes = self
            .classes
            .iter()
            .map(|c| {
                Json::obj(vec![
                    ("name", Json::from(c.name.as_str())),
                    ("arrivals", Json::from_u64(c.arrivals)),
                    ("completed", Json::from_u64(c.completed)),
                    ("dropped", Json::from_u64(c.dropped)),
                    ("measured", Json::from_u64(c.measured)),
                    ("mean_latency_s", Json::from(c.mean_latency_s)),
                    ("p50_latency_s", Json::from(c.p50_latency_s)),
                    ("p99_latency_s", Json::from(c.p99_latency_s)),
                    ("p999_latency_s", Json::from(c.p999_latency_s)),
                ])
            })
            .collect();
        let nodes = self
            .nodes
            .iter()
            .map(|n| {
                Json::obj(vec![
                    ("device", Json::from(n.device)),
                    ("arrivals", Json::from_u64(n.arrivals)),
                    ("served", Json::from_u64(n.served)),
                    ("dropped", Json::from_u64(n.dropped)),
                    ("utilization", Json::from(n.utilization)),
                    ("mean_queue_depth", Json::from(n.mean_queue_depth)),
                    ("max_queue_depth", Json::from(n.max_queue_depth)),
                    ("mean_wait_s", Json::from(n.mean_wait_s)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("horizon_s", Json::from(self.horizon_s)),
            ("warmup_s", Json::from(self.warmup_s)),
            ("end_s", Json::from(self.end_s)),
            ("events", Json::from_u64(self.events)),
            ("arrivals", Json::from_u64(self.arrivals)),
            ("completed", Json::from_u64(self.completed)),
            ("dropped", Json::from_u64(self.dropped)),
            ("in_flight", Json::from_u64(self.in_flight)),
            ("peak_inflight", Json::from_u64(self.peak_inflight)),
            ("mean_latency_s", Json::from(self.mean_latency_s)),
            ("p50_latency_s", Json::from(self.p50_latency_s)),
            ("p99_latency_s", Json::from(self.p99_latency_s)),
            ("p999_latency_s", Json::from(self.p999_latency_s)),
            ("classes", Json::Arr(classes)),
            ("nodes", Json::Arr(nodes)),
        ])
    }
}

/// Latency summary helper shared by the class and global roll-ups.
pub(crate) fn latency_summary(samples: &[f64]) -> (f64, f64, f64, f64) {
    (
        stats::mean(samples),
        stats::percentile(samples, 50.0),
        stats::percentile(samples, 99.0),
        stats::percentile(samples, 99.9),
    )
}
