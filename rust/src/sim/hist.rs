//! Fixed-resolution streaming log-histogram for latency telemetry — the
//! O(1)-memory backing of [`super::LatencyMode::Hdr`].
//!
//! The default (and bit-identity reference) latency mode records every
//! post-warm-up completion into a `Vec<f64>` and sorts at report time —
//! O(total requests) memory, which fights the slab pool's O(in-flight)
//! guarantee on multi-million-request replays. [`LogHist`] replaces the
//! vector with a fixed array of buckets that subdivide each power-of-two
//! latency range ("binade") into [`SUB_BUCKETS`] equal-bit-pattern slices:
//! the bucket of a sample is just its f64 bit pattern shifted right by
//! [`SHIFT`] (IEEE-754 doubles sort like their bit patterns for positive
//! values, so the map is monotone and the bucket edges are exact doubles).
//!
//! * **Resolution.** Each binade splits into 1024 buckets, so a bucket's
//!   relative width is `2^-10 ≈ 0.098% < 0.1%`; reporting the bucket
//!   midpoint bounds the relative quantile error by half of that
//!   (pinned against the exact-mode percentiles in
//!   `rust/tests/test_sim.rs` and `python/tests/test_sim_des.py`).
//! * **Range.** `[2^-30, 2^17)` seconds (≈ 1 ns … 36 h), clamped at both
//!   ends — 48128 `u64` counters ≈ 376 KiB per class, independent of the
//!   request count.
//! * **Determinism.** Bucketing is pure bit arithmetic and the running
//!   `sum` accumulates in completion order, so the histogram — like every
//!   sim artifact — is a pure function of `(problem, φ, Λ, spec, seed)`.
//!   The per-class mean is the *same sequential sum* the exact mode
//!   computes, hence bitwise-equal to it.

/// Mantissa bits kept per bucket index: 52 − 10 → 1024 buckets per binade.
const SHIFT: u32 = 42;
/// Buckets per power-of-two range.
pub const SUB_BUCKETS: u64 = 1u64 << (52 - SHIFT);
/// Smallest distinguishable latency (lower values clamp into bucket 0).
pub const MIN_LATENCY_S: f64 = 9.313225746154785e-10; // 2^-30
/// Upper bound of the top bucket (higher values clamp into it).
pub const MAX_LATENCY_S: f64 = 131072.0; // 2^17
/// Bit pattern of [`MIN_LATENCY_S`] pre-shifted — the index offset.
const BASE: u64 = ((1023 - 30) as u64) << (52 - SHIFT as u64);
/// Total buckets: 47 binades × 1024.
const N_BUCKETS: usize = (47 * SUB_BUCKETS) as usize;

/// Deterministic HDR-style latency histogram: O(1) memory, ≤ 0.1%
/// relative bucket width, exact streaming mean. See the module docs.
#[derive(Clone, Debug, PartialEq)]
pub struct LogHist {
    counts: Vec<u64>,
    count: u64,
    /// Σ samples in record order (bitwise-matches the exact-mode sum).
    sum: f64,
}

impl Default for LogHist {
    fn default() -> Self {
        LogHist::new()
    }
}

impl LogHist {
    pub fn new() -> LogHist {
        LogHist { counts: vec![0; N_BUCKETS], count: 0, sum: 0.0 }
    }

    /// Bucket index of a latency sample (clamped to the histogram range).
    #[inline]
    fn index_of(x: f64) -> usize {
        if !(x >= MIN_LATENCY_S) {
            // negative / NaN / subnormal-small: bottom bucket
            return 0;
        }
        if x >= MAX_LATENCY_S {
            return N_BUCKETS - 1;
        }
        ((x.to_bits() >> SHIFT) - BASE) as usize
    }

    /// Record one sample. The raw (unclamped) value enters the mean.
    #[inline]
    pub fn record(&mut self, x: f64) {
        self.counts[Self::index_of(x)] += 1;
        self.count += 1;
        self.sum += x;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Mean of everything recorded — the same left-to-right sum the
    /// exact mode's `stats::mean` computes, so bitwise-equal to it.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Midpoint of bucket `i` — the representative value a quantile
    /// landing in the bucket reports.
    fn bucket_mid(i: usize) -> f64 {
        let lo = f64::from_bits((BASE + i as u64) << SHIFT);
        let hi = f64::from_bits((BASE + i as u64 + 1) << SHIFT);
        0.5 * (lo + hi)
    }

    /// The `q`-th percentile (q in [0, 100]) as the bucket midpoint of
    /// the nearest order statistic — within half a bucket width
    /// (≤ ~0.05% relative) of the exact-mode interpolated percentile.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((q / 100.0) * (self.count - 1) as f64).round() as u64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            cum += c;
            if cum > rank {
                return Self::bucket_mid(i);
            }
        }
        Self::bucket_mid(N_BUCKETS - 1)
    }

    /// Fold another histogram in (the global roll-up over classes).
    pub fn merge(&mut self, other: &LogHist) {
        for (a, &b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
    }

    /// `(mean, p50, p99, p999)` — the shape of `report::latency_summary`.
    pub fn summary(&self) -> (f64, f64, f64, f64) {
        (self.mean(), self.quantile(50.0), self.quantile(99.0), self.quantile(99.9))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::stats;

    #[test]
    fn constants_are_consistent() {
        assert_eq!(MIN_LATENCY_S, (2.0f64).powi(-30));
        assert_eq!(MAX_LATENCY_S, (2.0f64).powi(17));
        assert_eq!(LogHist::index_of(MIN_LATENCY_S), 0);
        assert_eq!(LogHist::index_of(MAX_LATENCY_S), N_BUCKETS - 1);
        // the map is monotone across a binade boundary
        assert!(LogHist::index_of(0.9999) < LogHist::index_of(1.0));
        assert!(LogHist::index_of(1.0) < LogHist::index_of(1.001));
    }

    #[test]
    fn empty_matches_exact_mode_zeros() {
        let h = LogHist::new();
        assert_eq!(h.summary(), (0.0, 0.0, 0.0, 0.0));
    }

    #[test]
    fn mean_is_bitwise_exact() {
        let mut rng = Rng::seed_from(5);
        let mut h = LogHist::new();
        let mut xs = Vec::new();
        for _ in 0..10_000 {
            let x = rng.exponential(3.0);
            h.record(x);
            xs.push(x);
        }
        assert_eq!(h.mean().to_bits(), stats::mean(&xs).to_bits());
        assert_eq!(h.count(), xs.len() as u64);
    }

    #[test]
    fn quantiles_within_relative_bound() {
        let mut rng = Rng::seed_from(11);
        let mut h = LogHist::new();
        let mut xs = Vec::new();
        for _ in 0..200_000 {
            let x = rng.exponential(0.7);
            h.record(x);
            xs.push(x);
        }
        for q in [50.0, 90.0, 99.0, 99.9] {
            let exact = stats::percentile(&xs, q);
            let approx = h.quantile(q);
            let rel = (approx - exact).abs() / exact;
            assert!(rel < 2e-3, "p{q}: exact {exact} vs hist {approx} (rel {rel})");
        }
    }

    #[test]
    fn clamps_out_of_range_samples() {
        let mut h = LogHist::new();
        h.record(1e-30); // below range
        h.record(1e9); // above range
        assert_eq!(h.count(), 2);
        assert!(h.quantile(0.0) < 1e-8);
        assert!(h.quantile(100.0) > 1e5);
    }

    #[test]
    fn merge_equals_recording_into_one() {
        let mut rng = Rng::seed_from(2);
        let (mut a, mut b, mut whole) = (LogHist::new(), LogHist::new(), LogHist::new());
        for k in 0..5_000 {
            let x = rng.exponential(1.3);
            if k % 2 == 0 {
                a.record(x);
            } else {
                b.record(x);
            }
        }
        // rebuild the interleaved stream for the sum comparison
        let mut rng = Rng::seed_from(2);
        for _ in 0..5_000 {
            whole.record(rng.exponential(1.3));
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert_eq!(a.quantile(50.0), whole.quantile(50.0));
        assert_eq!(a.quantile(99.0), whole.quantile(99.0));
        // sums differ only by association order; counts per bucket agree
        assert!((a.mean() - whole.mean()).abs() < 1e-12);
    }
}
