//! Calendar-queue event scheduler — the O(1)-amortized replacement for
//! the binary heap of the PR-6 core, popping the **identical** stable
//! `(time, seq)` total order.
//!
//! ## Ordering invariant
//!
//! The queue's contract with the simulator is the classic calendar-queue
//! precondition plus the repo's determinism discipline:
//!
//! 1. **Total order.** Events are popped in ascending `(time, seq)` —
//!    exactly the order a `BinaryHeap<Ev>` over [`Ev`]'s `Ord` produces.
//!    `seq` is the simulator's monotone push counter, so ties in `time`
//!    resolve by insertion order and the pop sequence is a pure function
//!    of the push sequence (pinned by the randomized pop-order
//!    equivalence test in `rust/tests/test_sim.rs` and the Python mirror
//!    in `python/tests/test_sim_des.py`).
//! 2. **Monotone pushes.** A push never predates the last popped event
//!    (`time >= floor_time`, debug-asserted). Discrete-event simulation
//!    guarantees this by construction: every event is scheduled at or
//!    after the current clock. The invariant is what lets the pop scan
//!    start at the clock's bucket without ever revisiting earlier ones.
//!
//! ## Why the order is preserved *by construction*
//!
//! Bucket assignment is `floor((time - cal_start) * inv_width)` — a
//! monotone non-decreasing function of `time` (multiplication by a
//! positive constant and `floor` are both monotone), so bucket-major
//! iteration visits events in time order, equal times always share a
//! bucket (same index), and each bucket is kept sorted by `(time, seq)`.
//! Events whose index falls past the last bucket overflow into a plain
//! binary heap (the far-future fallback); the same monotone index
//! function partitions them, so every bucketed event precedes every
//! overflowed one and equal times never straddle the boundary. Lazy
//! resize re-anchors the calendar at the current floor with a bucket
//! width recomputed from the live event span — a pure function of queue
//! contents, so resize points are seed-reproducible too.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Heap/calendar entry: min-first on `(time, seq)`. The monotone `seq`
/// tie-break makes the event order total, hence seed-reproducible.
#[derive(Clone, Copy, Debug)]
pub struct Ev {
    pub time: f64,
    pub seq: u64,
    pub kind: EvKind,
}

/// What happens when the event fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvKind {
    /// Next admission of the class's Poisson stream.
    Arrival { class: u32 },
    /// A server of station `edge` finishes serving request `req`.
    Depart { edge: u32, req: u32 },
}

impl PartialEq for Ev {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ev {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed: BinaryHeap is a max-heap, we want earliest-first
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Initial bucket count (power of two; the queue resizes itself).
const MIN_BUCKETS: usize = 16;

/// A calendar queue over [`Ev`] popping ascending `(time, seq)` — see the
/// module docs for the ordering argument. `push` is O(1) amortized
/// (binary-search insert into a ~2-event bucket), `pop_at_most` is O(1)
/// amortized (the scan from the clock's bucket to the next event's bucket
/// advances monotonically, so each bucket is crossed once per calendar
/// span).
#[derive(Clone, Debug)]
pub struct CalendarQueue {
    /// Each bucket sorted by `(time, seq)` **descending** so the bucket
    /// minimum pops from the back in O(1).
    buckets: Vec<Vec<Ev>>,
    /// Start time of bucket 0.
    cal_start: f64,
    /// Bucket time width and its reciprocal (index = `(t-start)*inv`).
    width: f64,
    inv_width: f64,
    /// Far-future fallback: events whose index falls past the last bucket.
    overflow: BinaryHeap<Ev>,
    /// Events currently stored (buckets + overflow).
    len: usize,
    /// Time of the last popped event (pushes never predate it).
    floor_time: f64,
    /// Scratch for rebuilds (kept so steady-state resizes do not allocate
    /// fresh vectors every time).
    scratch: Vec<Ev>,
}

impl Default for CalendarQueue {
    fn default() -> Self {
        CalendarQueue::new()
    }
}

impl CalendarQueue {
    pub fn new() -> CalendarQueue {
        CalendarQueue {
            buckets: vec![Vec::new(); MIN_BUCKETS],
            cal_start: 0.0,
            width: 1.0,
            inv_width: 1.0,
            overflow: BinaryHeap::new(),
            len: 0,
            floor_time: 0.0,
            scratch: Vec::new(),
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Bucket index of `time` under the current calendar anchor — the
    /// monotone map the ordering argument rests on. May exceed the bucket
    /// count (the caller overflows those into the heap).
    #[inline]
    fn index_of(&self, time: f64) -> usize {
        // times are finite and >= cal_start (monotone-push invariant)
        ((time - self.cal_start) * self.inv_width) as usize
    }

    /// Schedule an event. `ev.time` must be finite and not precede the
    /// last popped event (the monotone-push contract of the module docs).
    pub fn push(&mut self, ev: Ev) {
        debug_assert!(ev.time.is_finite(), "calendar events carry finite times");
        debug_assert!(
            ev.time >= self.floor_time,
            "push at {} predates the last pop at {}",
            ev.time,
            self.floor_time
        );
        let idx = self.index_of(ev.time);
        if idx >= self.buckets.len() {
            self.overflow.push(ev);
        } else {
            insert_sorted(&mut self.buckets[idx], ev);
        }
        self.len += 1;
        if self.len > 2 * self.buckets.len() {
            let target = self.buckets.len() * 2;
            self.rebuild(target);
        }
    }

    /// Pop the earliest event if its time is `<= t_end`; `None` when the
    /// queue is empty or the minimum lies beyond `t_end` (the event stays
    /// queued). `pop_at_most(f64::INFINITY)` is an unconditional pop.
    pub fn pop_at_most(&mut self, t_end: f64) -> Option<Ev> {
        if self.len == 0 {
            return None;
        }
        // The global minimum is the first event in bucket-major order
        // (see module docs); scan from the floor's bucket — everything
        // earlier is provably empty by the monotone-push invariant.
        let start = self.index_of(self.floor_time).min(self.buckets.len() - 1);
        for b in start..self.buckets.len() {
            if let Some(&ev) = self.buckets[b].last() {
                if ev.time > t_end {
                    return None;
                }
                self.buckets[b].pop();
                self.len -= 1;
                self.floor_time = ev.time;
                if self.len < self.buckets.len() / 8 && self.buckets.len() > MIN_BUCKETS {
                    let target = self.buckets.len() / 2;
                    self.rebuild(target);
                }
                return Some(ev);
            }
        }
        // Buckets drained but overflow still holds events: re-anchor the
        // calendar at the overflow minimum and retry (at least that event
        // lands in bucket 0, so the recursion terminates immediately).
        debug_assert!(!self.overflow.is_empty());
        let t_min = self.overflow.peek().expect("len > 0").time;
        if t_min > t_end {
            return None;
        }
        self.reanchor(t_min);
        self.pop_at_most(t_end)
    }

    /// Re-anchor the calendar window at `t` (keeping size and width) and
    /// migrate every overflow event that now fits into the buckets.
    fn reanchor(&mut self, t: f64) {
        self.cal_start = t;
        while let Some(&ev) = self.overflow.peek() {
            let idx = self.index_of(ev.time);
            if idx >= self.buckets.len() {
                break;
            }
            let ev = self.overflow.pop().expect("peeked event");
            insert_sorted(&mut self.buckets[idx], ev);
        }
    }

    /// Lazy resize: re-bucket everything into `n_buckets` (power of two,
    /// floored at [`MIN_BUCKETS`]) with the width recomputed from the
    /// live event span — a pure function of the queue contents, so
    /// resize behavior is deterministic.
    fn rebuild(&mut self, n_buckets: usize) {
        let n_buckets = n_buckets.max(MIN_BUCKETS);
        self.scratch.clear();
        for b in &mut self.buckets {
            self.scratch.append(b);
        }
        while let Some(ev) = self.overflow.pop() {
            self.scratch.push(ev);
        }
        if self.buckets.len() < n_buckets {
            self.buckets.resize(n_buckets, Vec::new());
        } else {
            self.buckets.truncate(n_buckets);
        }
        // aim for ~2 events per bucket over the live span; degenerate
        // spans (all ties, single event) keep the old width
        let mut max_t = self.floor_time;
        for ev in &self.scratch {
            max_t = max_t.max(ev.time);
        }
        let span = max_t - self.floor_time;
        if self.scratch.len() >= 2 && span > 0.0 {
            self.width = span * 2.0 / self.scratch.len() as f64;
            self.inv_width = 1.0 / self.width;
        }
        self.cal_start = self.floor_time;
        self.len = 0;
        // re-push without the resize checks (len is already final-sized)
        let mut scratch = std::mem::take(&mut self.scratch);
        for ev in scratch.drain(..) {
            let idx = self.index_of(ev.time);
            if idx >= self.buckets.len() {
                self.overflow.push(ev);
            } else {
                insert_sorted(&mut self.buckets[idx], ev);
            }
            self.len += 1;
        }
        self.scratch = scratch;
    }
}

/// Insert into a bucket kept sorted by `(time, seq)` descending (the
/// bucket minimum lives at the back). Buckets hold ~2 events in steady
/// state, so the binary search + shift is effectively O(1).
#[inline]
fn insert_sorted(bucket: &mut Vec<Ev>, ev: Ev) {
    let pos = bucket
        .binary_search_by(|probe| {
            // descending (time, seq): larger entries sort first
            ev.time
                .total_cmp(&probe.time)
                .then_with(|| ev.seq.cmp(&probe.seq))
                .reverse()
        })
        .unwrap_or_else(|p| p);
    bucket.insert(pos, ev);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(time: f64, seq: u64) -> Ev {
        Ev { time, seq, kind: EvKind::Arrival { class: 0 } }
    }

    #[test]
    fn pops_in_time_then_seq_order() {
        let mut q = CalendarQueue::new();
        q.push(ev(2.0, 0));
        q.push(ev(1.0, 1));
        q.push(ev(1.0, 2));
        q.push(ev(3.0, 3));
        q.push(ev(1.0, 4));
        let order: Vec<(f64, u64)> = std::iter::from_fn(|| q.pop_at_most(f64::INFINITY))
            .map(|e| (e.time, e.seq))
            .collect();
        assert_eq!(order, vec![(1.0, 1), (1.0, 2), (1.0, 4), (2.0, 0), (3.0, 3)]);
        assert!(q.is_empty());
    }

    #[test]
    fn pop_at_most_leaves_later_events() {
        let mut q = CalendarQueue::new();
        q.push(ev(5.0, 0));
        q.push(ev(1.0, 1));
        assert_eq!(q.pop_at_most(2.0).map(|e| e.seq), Some(1));
        assert_eq!(q.pop_at_most(2.0), None);
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop_at_most(5.0).map(|e| e.seq), Some(0));
        assert_eq!(q.pop_at_most(f64::INFINITY), None);
    }

    #[test]
    fn far_future_overflow_and_reanchor() {
        let mut q = CalendarQueue::new();
        // default window is 16 buckets x width 1.0 = [0, 16): 1e6 overflows
        q.push(ev(1_000_000.0, 0));
        q.push(ev(0.5, 1));
        q.push(ev(1_000_000.0, 2));
        assert_eq!(q.pop_at_most(f64::INFINITY).map(|e| e.seq), Some(1));
        // the overflow minimum is beyond t_end: nothing pops, nothing lost
        assert_eq!(q.pop_at_most(10.0), None);
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop_at_most(f64::INFINITY).map(|e| e.seq), Some(0));
        assert_eq!(q.pop_at_most(f64::INFINITY).map(|e| e.seq), Some(2));
        assert!(q.is_empty());
    }

    #[test]
    fn grow_and_shrink_keep_the_order() {
        let mut q = CalendarQueue::new();
        let mut reference: Vec<(f64, u64)> = Vec::new();
        // dense burst on a coarse grid (many exact ties) forces growth
        for seq in 0..500u64 {
            let t = (seq % 13) as f64 * 0.25;
            q.push(ev(t, seq));
            reference.push((t, seq));
        }
        assert!(q.buckets.len() > MIN_BUCKETS, "500 events must trigger growth");
        reference.sort_by(|a, b| a.0.total_cmp(&b.0).then_with(|| a.1.cmp(&b.1)));
        // drain most of it (forcing shrink) and compare the order
        for want in &reference {
            let got = q.pop_at_most(f64::INFINITY).expect("event");
            assert_eq!((got.time, got.seq), *want);
        }
        assert!(q.is_empty());
        assert_eq!(q.buckets.len(), MIN_BUCKETS, "drain must shrink back");
    }
}
