//! Figure/table harnesses: one function per experiment in the paper's
//! evaluation (Section IV + Appendix F). Each regenerates the figure's data
//! (CSV under `results/`), prints an ASCII rendition, and returns the raw
//! series for the bench targets and tests.
//!
//! All harnesses run on the [`crate::session`] API: scenarios lower into
//! declarative [`crate::session::spec::ScenarioSpec`]s, solver grids run
//! through the parallel [`Suite`] runner (per-cell trajectories come back
//! on the [`crate::session::suite::SuiteReport`]), and solvers come from
//! the registry by name — no harness constructs algorithms or dispatches
//! on algorithm names by hand. The OPT reference lines keep the exact
//! centralized path-flow solve.

pub mod asciiplot;

use crate::allocation::{Allocator, UtilityOracle};
use crate::config::ExperimentConfig;
use crate::coordinator::events::{EventSchedule, NetworkEvent};
use crate::graph::topologies;
use crate::metrics::SeriesSet;
use crate::model::Problem;
use crate::routing::{omd::OmdRouter, opt::OptRouter, Router};
use crate::session::{registry, Scenario, SessionError, Suite};

/// Where CSVs land (`results/figN.csv`).
pub fn results_dir() -> std::path::PathBuf {
    std::env::var("JOWR_RESULTS").map(Into::into).unwrap_or_else(|_| "results".into())
}

fn save(set: &SeriesSet, name: &str) {
    let path = results_dir().join(name);
    if let Err(e) = set.write_csv(&path) {
        crate::log_warn!("could not write {}: {e}", path.display());
    } else {
        println!("  wrote {}", path.display());
    }
}

/// **Fig. 7** — OMD-RT vs SGP convergence on Connected-ER(25, 0.2) with the
/// centralized OPT line. Returns (series, opt_cost).
pub fn fig7(cfg: &ExperimentConfig, iters: usize) -> Result<(SeriesSet, f64), SessionError> {
    let session = Scenario::from_config(cfg.clone()).build()?;
    let lam = session.uniform_allocation();

    // both solvers as one suite grid (each cell rebuilds the identical
    // seeded scenario, so the series match the single-session runs bit
    // for bit)
    let results = Suite::new()
        .spec("fig7", session.spec.clone())
        .router("omd")
        .router("sgp")
        .iters(iters)
        .workers(0)
        .run();
    let omd = results.cell_result("fig7", "omd")?.trajectory.clone();
    let sgp = results.cell_result("fig7", "sgp")?.trajectory.clone();
    // the OPT reference line keeps the exact path-flow objective
    let opt = OptRouter::new().solve(&session.problem, &lam);

    let mut s = SeriesSet::new();
    s.set("omd_rt", pad_to(&omd, iters + 1));
    s.set("sgp", pad_to(&sgp, iters + 1));
    s.set("opt", vec![opt.cost; iters + 1]);
    save(&s, "fig7.csv");
    println!(
        "{}",
        asciiplot::plot(
            "Fig.7 total network cost vs routing iteration",
            &[
                ("OMD-RT", s.get("omd_rt").unwrap()),
                ("SGP", s.get("sgp").unwrap()),
                ("OPT", s.get("opt").unwrap()),
            ],
            64,
            18,
        )
    );
    Ok((s, opt.cost))
}

/// Extend a (possibly early-converged) trajectory to `len` by holding the
/// final value — matches how the paper plots flat converged tails.
fn pad_to(tr: &[f64], len: usize) -> Vec<f64> {
    let mut v = tr.to_vec();
    let last = *v.last().unwrap_or(&0.0);
    while v.len() < len {
        v.push(last);
    }
    v
}

/// One row of the Fig. 8/9 sweep.
#[derive(Clone, Debug)]
pub struct SizeRow {
    pub n: usize,
    pub cost_omd: f64,
    pub cost_sgp: f64,
    pub cost_opt: f64,
    pub time_omd_s: f64,
    pub time_sgp_s: f64,
    pub time_opt_s: f64,
}

/// **Figs. 8 + 9** — final cost and wall-clock vs network size
/// (n ∈ {20,25,30,35,40}, 50 routing iterations each, per the paper).
pub fn fig8_9(
    cfg: &ExperimentConfig,
    sizes: &[usize],
    iters: usize,
) -> Result<Vec<SizeRow>, SessionError> {
    // the whole size sweep is one suite grid: |sizes| specs × {omd, sgp},
    // cells running in parallel (per-cell sessions are rebuilt from the
    // seeded specs, so results equal the sequential harness)
    let mut suite = Suite::new().router("omd").router("sgp").iters(iters).workers(0);
    for &n in sizes {
        let spec = Scenario::from_config(cfg.clone())
            .nodes(n)
            .seed(cfg.seed + n as u64)
            .into_spec()?;
        suite = suite.spec(&format!("n{n}"), spec);
    }
    let results = suite.run();

    let mut rows = Vec::new();
    println!(
        "{:>4} {:>12} {:>12} {:>12} {:>12} {:>12} {:>12}",
        "n", "cost(OMD)", "cost(SGP)", "cost(OPT)", "t(OMD)s", "t(SGP)s", "t(OPT)s"
    );
    for &n in sizes {
        let name = format!("n{n}");
        let omd = &results.cell_result(&name, "omd")?.report;
        let sgp = &results.cell_result(&name, "sgp")?.report;
        // OPT keeps the exact centralized path-flow solve
        let session = Scenario::from_config(cfg.clone())
            .nodes(n)
            .seed(cfg.seed + n as u64)
            .build()?;
        let lam = session.uniform_allocation();
        let opt = OptRouter::new().solve(&session.problem, &lam);
        let row = SizeRow {
            n,
            cost_omd: omd.objective,
            cost_sgp: sgp.objective,
            cost_opt: opt.cost,
            time_omd_s: omd.elapsed_s,
            time_sgp_s: sgp.elapsed_s,
            time_opt_s: opt.elapsed_s,
        };
        println!(
            "{:>4} {:>12.4} {:>12.4} {:>12.4} {:>12.6} {:>12.6} {:>12.6}",
            row.n,
            row.cost_omd,
            row.cost_sgp,
            row.cost_opt,
            row.time_omd_s,
            row.time_sgp_s,
            row.time_opt_s
        );
        rows.push(row);
    }
    let mut s = SeriesSet::new();
    s.set("n", rows.iter().map(|r| r.n as f64).collect());
    s.set("cost_omd", rows.iter().map(|r| r.cost_omd).collect());
    s.set("cost_sgp", rows.iter().map(|r| r.cost_sgp).collect());
    s.set("cost_opt", rows.iter().map(|r| r.cost_opt).collect());
    s.set("time_omd_s", rows.iter().map(|r| r.time_omd_s).collect());
    s.set("time_sgp_s", rows.iter().map(|r| r.time_sgp_s).collect());
    s.set("time_opt_s", rows.iter().map(|r| r.time_opt_s).collect());
    save(&s, "fig8_9.csv");
    Ok(rows)
}

/// **Fig. 10** — GS-OMA (nested loop) under the four unknown utility
/// families. Returns the per-family utility trajectories.
pub fn fig10(cfg: &ExperimentConfig, outer_iters: usize) -> Result<SeriesSet, SessionError> {
    // one spec per utility family, all four GS-OMA cells in parallel
    let mut suite = Suite::new().allocator("gsoma").iters(outer_iters).workers(0);
    for fam in crate::model::utility::FAMILIES {
        let spec = Scenario::from_config(cfg.clone()).utility(fam).into_spec()?;
        suite = suite.spec(fam, spec);
    }
    let results = suite.run();
    let mut s = SeriesSet::new();
    for fam in crate::model::utility::FAMILIES {
        let cell = results.cell_result(fam, "gsoma")?;
        s.set(fam, pad_to(&cell.trajectory, outer_iters + 1));
        println!(
            "  {fam:<10} U: {:.4} -> {:.4}  ({} outer iters, {} routing iters)",
            cell.trajectory[0],
            cell.trajectory.last().unwrap(),
            cell.report.iterations,
            cell.report.routing_iterations
        );
    }
    save(&s, "fig10.csv");
    let names: Vec<(&str, &[f64])> = crate::model::utility::FAMILIES
        .iter()
        .map(|f| (*f, s.get(f).unwrap()))
        .collect();
    println!(
        "{}",
        asciiplot::plot("Fig.10 total network utility (4 utility families)", &names, 64, 18)
    );
    Ok(s)
}

/// **Fig. 11** — nested vs single loop with a topology change at
/// `change_at`. Returns (series, nested routing iters, single routing iters).
pub fn fig11(
    cfg: &ExperimentConfig,
    outer_iters: usize,
    change_at: usize,
) -> Result<(SeriesSet, usize, usize), SessionError> {
    let schedule =
        EventSchedule::new().at(change_at, NetworkEvent::Rewire { seed: cfg.seed + 1000 });

    // identical harness for both loops: the registry picks the algorithm,
    // the session pairs it with its matching oracle
    let run = |algo: &str| -> Result<(Vec<f64>, usize), SessionError> {
        let session = Scenario::from_config(cfg.clone()).build()?;
        let allocator: Box<dyn Allocator> = registry::allocator_with(algo, &session.hyper())?;
        let mut oracle: Box<dyn UtilityOracle> = session.oracle_for(algo)?;
        let mut problem = session.problem.clone();
        let total = cfg.total_rate;
        let w = cfg.n_versions;
        let mut lam = vec![total / w as f64; w];
        let mut traj = Vec::with_capacity(outer_iters);
        for t in 0..outer_iters {
            for ev in schedule.fire(t) {
                problem = EventSchedule::apply(cfg, &problem, ev)?;
                oracle.on_topology_change(&problem);
            }
            traj.push(oracle.observe(&lam));
            let (next, _) = allocator.outer_step(oracle.as_mut(), &lam);
            lam = next;
        }
        Ok((traj, oracle.routing_iterations()))
    };

    let (nested, nested_routing) = run("gsoma")?;
    let (single, single_routing) = run("omad")?;
    let mut s = SeriesSet::new();
    s.set("nested_loop", nested);
    s.set("single_loop", single);
    save(&s, "fig11.csv");
    println!(
        "{}",
        asciiplot::plot(
            &format!("Fig.11 nested vs single loop (topology change at t={change_at})"),
            &[
                ("nested", s.get("nested_loop").unwrap()),
                ("single", s.get("single_loop").unwrap()),
            ],
            64,
            18,
        )
    );
    println!(
        "  routing iterations: nested {nested_routing} vs single {single_routing} ({}x fewer)",
        nested_routing / single_routing.max(1)
    );
    Ok((s, nested_routing, single_routing))
}

/// **Figs. 12–15** — OMD-RT vs SGP on the four named topologies with
/// Table II parameters. Returns per-topology series.
pub fn fig12_15(
    cfg: &ExperimentConfig,
    iters: usize,
) -> Result<Vec<(String, SeriesSet, f64)>, SessionError> {
    // all four named topologies × {omd, sgp} as one parallel suite grid
    let mut suite = Suite::new().router("omd").router("sgp").iters(iters).workers(0);
    for &(name, _n, _e, cbar) in topologies::TABLE2.iter() {
        let spec =
            Scenario::from_config(cfg.clone()).topology(name).capacity(cbar).into_spec()?;
        suite = suite.spec(name, spec);
    }
    let results = suite.run();

    let mut out = Vec::new();
    for &(name, _n, _e, cbar) in topologies::TABLE2.iter() {
        let omd = results.cell_result(name, "omd")?.trajectory.clone();
        let sgp = results.cell_result(name, "sgp")?.trajectory.clone();
        let session = Scenario::from_config(cfg.clone()).topology(name).capacity(cbar).build()?;
        let lam = session.uniform_allocation();
        let opt = OptRouter::new().solve(&session.problem, &lam);
        let mut s = SeriesSet::new();
        s.set("omd_rt", pad_to(&omd, iters + 1));
        s.set("sgp", pad_to(&sgp, iters + 1));
        s.set("opt", vec![opt.cost; iters + 1]);
        save(&s, &format!("fig12_15_{name}.csv"));
        println!(
            "{}",
            asciiplot::plot(
                &format!("Figs.12-15 {name}: cost vs iteration"),
                &[
                    ("OMD-RT", s.get("omd_rt").unwrap()),
                    ("SGP", s.get("sgp").unwrap()),
                    ("OPT", s.get("opt").unwrap()),
                ],
                64,
                14,
            )
        );
        out.push((name.to_string(), s, opt.cost));
    }
    Ok(out)
}

/// **Table II** — verify and print the named-topology parameters.
pub fn table2() -> Vec<(String, usize, usize, f64)> {
    let mut rows = Vec::new();
    println!("{:<16} {:>5} {:>5} {:>8}", "Topology", "|N|", "|E|", "C̄");
    for &(name, n, e, cbar) in topologies::TABLE2.iter() {
        let mut rng = crate::util::rng::Rng::seed_from(1);
        let g = topologies::by_name(name, cbar, &mut rng).unwrap();
        assert_eq!(g.n_nodes(), n, "{name} |N| mismatch");
        assert_eq!(g.n_edges(), 2 * e, "{name} |E| mismatch");
        println!("{name:<16} {n:>5} {e:>5} {cbar:>8.1}");
        rows.push((name.to_string(), n, e, cbar));
    }
    rows
}

/// Check a problem's OMD solution satisfies Theorem 3 stationarity within
/// `tol` (used by harness self-checks).
pub fn check_stationarity(problem: &Problem, iters: usize, tol: f64) -> bool {
    let lam = problem.uniform_allocation();
    let sol = OmdRouter::new(0.5).solve(problem, &lam, iters);
    let phi = sol.phi.expect("routing solve exposes phi");
    let t = crate::model::flow::node_rates(&problem.net, &phi, &lam);
    let flows = crate::model::flow::edge_flows(&problem.net, &phi, &t);
    let m = crate::routing::marginal::compute(problem, &phi, &flows);
    for w in 0..problem.n_versions() {
        for &i in problem.net.session_routers(w) {
            if t[w][i] < 1e-6 {
                continue;
            }
            let vals: Vec<f64> = problem
                .net
                .session_out(w, i)
                .filter(|&e| phi.frac[w][e] > 1e-4)
                .map(|e| m.delta(&problem.net, w, e))
                .collect();
            if vals.len() < 2 {
                continue;
            }
            let hi = vals.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let lo = vals.iter().cloned().fold(f64::INFINITY, f64::min);
            if hi - lo > tol * hi.max(1.0) {
                return false;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn quiet_cfg() -> ExperimentConfig {
        let mut c = ExperimentConfig::paper_default();
        c.n_nodes = 10;
        c
    }

    #[test]
    fn fig7_shape() {
        let (s, opt_cost) = fig7(&quiet_cfg(), 15).unwrap();
        let omd = s.get("omd_rt").unwrap();
        assert_eq!(omd.len(), 16);
        assert!(omd.last().unwrap() >= &opt_cost || (omd.last().unwrap() - opt_cost).abs() < 1e-3);
        // OMD descends
        assert!(omd.last().unwrap() < &omd[0]);
    }

    #[test]
    fn fig8_9_rows() {
        let rows = fig8_9(&quiet_cfg(), &[8, 10], 10).unwrap();
        assert_eq!(rows.len(), 2);
        for r in rows {
            assert!(r.cost_opt <= r.cost_omd + 1e-6);
            assert!(r.time_omd_s > 0.0 && r.time_sgp_s > 0.0);
        }
    }

    #[test]
    fn table2_matches() {
        let rows = table2();
        assert_eq!(rows.len(), 4);
    }

    #[test]
    fn harnesses_propagate_bad_configs() {
        let mut c = quiet_cfg();
        c.topology = "nope".into();
        assert!(fig7(&c, 3).is_err());
        assert!(fig10(&c, 2).is_err());
    }

    #[test]
    fn stationarity_check_works() {
        let cfg = quiet_cfg();
        let mut rng = Rng::seed_from(cfg.seed);
        let p = cfg.build_problem(&mut rng).unwrap();
        assert!(check_stationarity(&p, 3000, 0.02));
    }
}
