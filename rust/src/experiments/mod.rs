//! Figure/table harnesses: one function per experiment in the paper's
//! evaluation (Section IV + Appendix F). Each regenerates the figure's data
//! (CSV under `results/`), prints an ASCII rendition, and returns the raw
//! series for the bench targets and tests.

pub mod asciiplot;

use crate::allocation::{
    gsoma::GsOma, omad::Omad, Allocator, AnalyticOracle, SingleStepOracle, UtilityOracle,
};
use crate::config::ExperimentConfig;
use crate::coordinator::events::{EventSchedule, NetworkEvent};
use crate::graph::topologies;
use crate::metrics::SeriesSet;
use crate::model::utility::family;
use crate::model::Problem;
use crate::routing::{omd::OmdRouter, opt::OptRouter, sgp::SgpRouter, Router};
use crate::util::rng::Rng;

/// Where CSVs land (`results/figN.csv`).
pub fn results_dir() -> std::path::PathBuf {
    std::env::var("JOWR_RESULTS").map(Into::into).unwrap_or_else(|_| "results".into())
}

fn save(set: &SeriesSet, name: &str) {
    let path = results_dir().join(name);
    if let Err(e) = set.write_csv(&path) {
        crate::log_warn!("could not write {}: {e}", path.display());
    } else {
        println!("  wrote {}", path.display());
    }
}

/// **Fig. 7** — OMD-RT vs SGP convergence on Connected-ER(25, 0.2) with the
/// centralized OPT line. Returns (series, opt_cost).
pub fn fig7(cfg: &ExperimentConfig, iters: usize) -> (SeriesSet, f64) {
    let mut rng = Rng::seed_from(cfg.seed);
    let problem = cfg.build_problem(&mut rng);
    let lam = problem.uniform_allocation();

    let omd = OmdRouter::new(cfg.eta_routing).solve(&problem, &lam, iters);
    let sgp = SgpRouter::new().solve(&problem, &lam, iters);
    let opt = OptRouter::new().solve(&problem, &lam);

    let mut s = SeriesSet::new();
    s.set("omd_rt", pad_to(&omd.trajectory, iters + 1));
    s.set("sgp", pad_to(&sgp.trajectory, iters + 1));
    s.set("opt", vec![opt.cost; iters + 1]);
    save(&s, "fig7.csv");
    println!(
        "{}",
        asciiplot::plot(
            "Fig.7 total network cost vs routing iteration",
            &[
                ("OMD-RT", s.get("omd_rt").unwrap()),
                ("SGP", s.get("sgp").unwrap()),
                ("OPT", s.get("opt").unwrap()),
            ],
            64,
            18,
        )
    );
    (s, opt.cost)
}

/// Extend a (possibly early-converged) trajectory to `len` by holding the
/// final value — matches how the paper plots flat converged tails.
fn pad_to(tr: &[f64], len: usize) -> Vec<f64> {
    let mut v = tr.to_vec();
    let last = *v.last().unwrap_or(&0.0);
    while v.len() < len {
        v.push(last);
    }
    v
}

/// One row of the Fig. 8/9 sweep.
#[derive(Clone, Debug)]
pub struct SizeRow {
    pub n: usize,
    pub cost_omd: f64,
    pub cost_sgp: f64,
    pub cost_opt: f64,
    pub time_omd_s: f64,
    pub time_sgp_s: f64,
    pub time_opt_s: f64,
}

/// **Figs. 8 + 9** — final cost and wall-clock vs network size
/// (n ∈ {20,25,30,35,40}, 50 routing iterations each, per the paper).
pub fn fig8_9(cfg: &ExperimentConfig, sizes: &[usize], iters: usize) -> Vec<SizeRow> {
    let mut rows = Vec::new();
    println!(
        "{:>4} {:>12} {:>12} {:>12} {:>12} {:>12} {:>12}",
        "n", "cost(OMD)", "cost(SGP)", "cost(OPT)", "t(OMD)s", "t(SGP)s", "t(OPT)s"
    );
    for &n in sizes {
        let mut c = cfg.clone();
        c.n_nodes = n;
        let mut rng = Rng::seed_from(cfg.seed + n as u64);
        let problem = c.build_problem(&mut rng);
        let lam = problem.uniform_allocation();
        let omd = OmdRouter::new(cfg.eta_routing).solve(&problem, &lam, iters);
        let sgp = SgpRouter::new().solve(&problem, &lam, iters);
        let opt = OptRouter::new().solve(&problem, &lam);
        let row = SizeRow {
            n,
            cost_omd: omd.cost,
            cost_sgp: sgp.cost,
            cost_opt: opt.cost,
            time_omd_s: omd.elapsed_s,
            time_sgp_s: sgp.elapsed_s,
            time_opt_s: opt.elapsed_s,
        };
        println!(
            "{:>4} {:>12.4} {:>12.4} {:>12.4} {:>12.6} {:>12.6} {:>12.6}",
            row.n,
            row.cost_omd,
            row.cost_sgp,
            row.cost_opt,
            row.time_omd_s,
            row.time_sgp_s,
            row.time_opt_s
        );
        rows.push(row);
    }
    let mut s = SeriesSet::new();
    s.set("n", rows.iter().map(|r| r.n as f64).collect());
    s.set("cost_omd", rows.iter().map(|r| r.cost_omd).collect());
    s.set("cost_sgp", rows.iter().map(|r| r.cost_sgp).collect());
    s.set("cost_opt", rows.iter().map(|r| r.cost_opt).collect());
    s.set("time_omd_s", rows.iter().map(|r| r.time_omd_s).collect());
    s.set("time_sgp_s", rows.iter().map(|r| r.time_sgp_s).collect());
    s.set("time_opt_s", rows.iter().map(|r| r.time_opt_s).collect());
    save(&s, "fig8_9.csv");
    rows
}

/// **Fig. 10** — GS-OMA (nested loop) under the four unknown utility
/// families. Returns the per-family utility trajectories.
pub fn fig10(cfg: &ExperimentConfig, outer_iters: usize) -> SeriesSet {
    let mut s = SeriesSet::new();
    for fam in crate::model::utility::FAMILIES {
        let mut rng = Rng::seed_from(cfg.seed);
        let problem = cfg.build_problem(&mut rng);
        let utilities = family(fam, cfg.n_versions, cfg.total_rate).unwrap();
        let mut oracle = AnalyticOracle::new(problem, utilities);
        let mut alg = GsOma::new(cfg.delta, cfg.eta_alloc);
        let st = alg.run(&mut oracle, outer_iters);
        s.set(fam, pad_to(&st.trajectory, outer_iters + 1));
        println!(
            "  {fam:<10} U: {:.4} -> {:.4}  ({} outer iters, {} routing iters)",
            st.trajectory[0],
            st.trajectory.last().unwrap(),
            st.iterations,
            st.routing_iterations
        );
    }
    save(&s, "fig10.csv");
    let names: Vec<(&str, &[f64])> = crate::model::utility::FAMILIES
        .iter()
        .map(|f| (*f, s.get(f).unwrap()))
        .collect();
    println!(
        "{}",
        asciiplot::plot("Fig.10 total network utility (4 utility families)", &names, 64, 18)
    );
    s
}

/// **Fig. 11** — nested vs single loop with a topology change at
/// `change_at`. Returns (series, nested routing iters, single routing iters).
pub fn fig11(
    cfg: &ExperimentConfig,
    outer_iters: usize,
    change_at: usize,
) -> (SeriesSet, usize, usize) {
    let utilities = family(&cfg.utility, cfg.n_versions, cfg.total_rate).unwrap();
    let schedule =
        EventSchedule::new().at(change_at, NetworkEvent::Rewire { seed: cfg.seed + 1000 });

    let run = |single: bool| -> (Vec<f64>, usize) {
        let mut rng = Rng::seed_from(cfg.seed);
        let mut problem = cfg.build_problem(&mut rng);
        let total = cfg.total_rate;
        let w = cfg.n_versions;
        let mut lam = vec![total / w as f64; w];
        let mut traj = Vec::with_capacity(outer_iters);
        if single {
            let mut oracle = SingleStepOracle::new(problem.clone(), utilities.clone(), cfg.eta_routing);
            let alg = Omad::new(cfg.delta, cfg.eta_alloc);
            for t in 0..outer_iters {
                for ev in schedule.fire(t) {
                    problem = EventSchedule::apply(cfg, &problem, ev);
                    oracle.on_topology_change(&problem);
                }
                traj.push(crate::allocation::UtilityOracle::observe(&mut oracle, &lam));
                let (next, _) = alg.outer_step(&mut oracle, &lam);
                lam = next;
            }
            (traj, crate::allocation::UtilityOracle::routing_iterations(&oracle))
        } else {
            let mut oracle = AnalyticOracle::new(problem.clone(), utilities.clone());
            let alg = GsOma::new(cfg.delta, cfg.eta_alloc);
            for t in 0..outer_iters {
                for ev in schedule.fire(t) {
                    problem = EventSchedule::apply(cfg, &problem, ev);
                    oracle.on_topology_change(&problem);
                }
                traj.push(crate::allocation::UtilityOracle::observe(&mut oracle, &lam));
                let (next, _) = alg.outer_step(&mut oracle, &lam);
                lam = next;
            }
            (traj, crate::allocation::UtilityOracle::routing_iterations(&oracle))
        }
    };

    let (nested, nested_routing) = run(false);
    let (single, single_routing) = run(true);
    let mut s = SeriesSet::new();
    s.set("nested_loop", nested);
    s.set("single_loop", single);
    save(&s, "fig11.csv");
    println!(
        "{}",
        asciiplot::plot(
            &format!("Fig.11 nested vs single loop (topology change at t={change_at})"),
            &[
                ("nested", s.get("nested_loop").unwrap()),
                ("single", s.get("single_loop").unwrap()),
            ],
            64,
            18,
        )
    );
    println!(
        "  routing iterations: nested {nested_routing} vs single {single_routing} ({}x fewer)",
        nested_routing / single_routing.max(1)
    );
    (s, nested_routing, single_routing)
}

/// **Figs. 12–15** — OMD-RT vs SGP on the four named topologies with
/// Table II parameters. Returns per-topology series.
pub fn fig12_15(cfg: &ExperimentConfig, iters: usize) -> Vec<(String, SeriesSet, f64)> {
    let mut out = Vec::new();
    for &(name, _n, _e, cbar) in topologies::TABLE2.iter() {
        let mut c = cfg.clone();
        c.topology = name.to_string();
        c.cap_mean = cbar;
        let mut rng = Rng::seed_from(cfg.seed);
        let problem = c.build_problem(&mut rng);
        let lam = problem.uniform_allocation();
        let omd = OmdRouter::new(cfg.eta_routing).solve(&problem, &lam, iters);
        let sgp = SgpRouter::new().solve(&problem, &lam, iters);
        let opt = OptRouter::new().solve(&problem, &lam);
        let mut s = SeriesSet::new();
        s.set("omd_rt", pad_to(&omd.trajectory, iters + 1));
        s.set("sgp", pad_to(&sgp.trajectory, iters + 1));
        s.set("opt", vec![opt.cost; iters + 1]);
        save(&s, &format!("fig12_15_{name}.csv"));
        println!(
            "{}",
            asciiplot::plot(
                &format!("Figs.12-15 {name}: cost vs iteration"),
                &[
                    ("OMD-RT", s.get("omd_rt").unwrap()),
                    ("SGP", s.get("sgp").unwrap()),
                    ("OPT", s.get("opt").unwrap()),
                ],
                64,
                14,
            )
        );
        out.push((name.to_string(), s, opt.cost));
    }
    out
}

/// **Table II** — verify and print the named-topology parameters.
pub fn table2() -> Vec<(String, usize, usize, f64)> {
    let mut rows = Vec::new();
    println!("{:<16} {:>5} {:>5} {:>8}", "Topology", "|N|", "|E|", "C̄");
    for &(name, n, e, cbar) in topologies::TABLE2.iter() {
        let mut rng = Rng::seed_from(1);
        let g = topologies::by_name(name, cbar, &mut rng).unwrap();
        assert_eq!(g.n_nodes(), n, "{name} |N| mismatch");
        assert_eq!(g.n_edges(), 2 * e, "{name} |E| mismatch");
        println!("{name:<16} {n:>5} {e:>5} {cbar:>8.1}");
        rows.push((name.to_string(), n, e, cbar));
    }
    rows
}

/// Check a problem's OMD solution satisfies Theorem 3 stationarity within
/// `tol` (used by harness self-checks).
pub fn check_stationarity(problem: &Problem, iters: usize, tol: f64) -> bool {
    let lam = problem.uniform_allocation();
    let sol = OmdRouter::new(0.5).solve(problem, &lam, iters);
    let t = crate::model::flow::node_rates(&problem.net, &sol.phi, &lam);
    let flows = crate::model::flow::edge_flows(&problem.net, &sol.phi, &t);
    let m = crate::routing::marginal::compute(&problem.net, problem.cost, &sol.phi, &flows);
    for w in 0..problem.n_versions() {
        for &i in problem.net.session_routers(w) {
            if t[w][i] < 1e-6 {
                continue;
            }
            let vals: Vec<f64> = problem
                .net
                .session_out(w, i)
                .filter(|&e| sol.phi.frac[w][e] > 1e-4)
                .map(|e| m.delta(&problem.net, w, e))
                .collect();
            if vals.len() < 2 {
                continue;
            }
            let hi = vals.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let lo = vals.iter().cloned().fold(f64::INFINITY, f64::min);
            if hi - lo > tol * hi.max(1.0) {
                return false;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quiet_cfg() -> ExperimentConfig {
        let mut c = ExperimentConfig::paper_default();
        c.n_nodes = 10;
        c
    }

    #[test]
    fn fig7_shape() {
        let (s, opt_cost) = fig7(&quiet_cfg(), 15);
        let omd = s.get("omd_rt").unwrap();
        assert_eq!(omd.len(), 16);
        assert!(omd.last().unwrap() >= &opt_cost || (omd.last().unwrap() - opt_cost).abs() < 1e-3);
        // OMD descends
        assert!(omd.last().unwrap() < &omd[0]);
    }

    #[test]
    fn fig8_9_rows() {
        let rows = fig8_9(&quiet_cfg(), &[8, 10], 10);
        assert_eq!(rows.len(), 2);
        for r in rows {
            assert!(r.cost_opt <= r.cost_omd + 1e-6);
            assert!(r.time_omd_s > 0.0 && r.time_sgp_s > 0.0);
        }
    }

    #[test]
    fn table2_matches() {
        let rows = table2();
        assert_eq!(rows.len(), 4);
    }

    #[test]
    fn stationarity_check_works() {
        let cfg = quiet_cfg();
        let mut rng = Rng::seed_from(cfg.seed);
        let p = cfg.build_problem(&mut rng);
        assert!(check_stationarity(&p, 3000, 0.02));
    }
}
