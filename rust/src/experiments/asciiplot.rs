//! Terminal line plots for the figure harnesses (no plotting deps offline).

/// Render multiple named series as an ASCII chart.
pub fn plot(title: &str, series: &[(&str, &[f64])], width: usize, height: usize) -> String {
    let mut out = String::new();
    out.push_str(&format!("── {title} ──\n"));
    let all: Vec<f64> = series
        .iter()
        .flat_map(|(_, s)| s.iter().copied())
        .filter(|v| v.is_finite())
        .collect();
    if all.is_empty() {
        out.push_str("(no data)\n");
        return out;
    }
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for &v in &all {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    if (hi - lo).abs() < 1e-15 {
        hi = lo + 1.0;
    }
    let max_len = series.iter().map(|(_, s)| s.len()).max().unwrap_or(1).max(2);
    let marks = ['*', '+', 'o', 'x', '#', '@'];
    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, s)) in series.iter().enumerate() {
        let mark = marks[si % marks.len()];
        for (i, &v) in s.iter().enumerate() {
            if !v.is_finite() {
                continue;
            }
            let x = (i * (width - 1)) / (max_len - 1).max(1);
            let yf = (v - lo) / (hi - lo);
            let y = height - 1 - ((yf * (height - 1) as f64).round() as usize).min(height - 1);
            grid[y][x] = mark;
        }
    }
    for (r, row) in grid.iter().enumerate() {
        let label = if r == 0 {
            format!("{hi:>12.4}")
        } else if r == height - 1 {
            format!("{lo:>12.4}")
        } else {
            " ".repeat(12)
        };
        out.push_str(&format!("{label} │{}\n", row.iter().collect::<String>()));
    }
    out.push_str(&format!("{} └{}\n", " ".repeat(12), "─".repeat(width)));
    let legend: Vec<String> = series
        .iter()
        .enumerate()
        .map(|(i, (n, _))| format!("{} {}", marks[i % marks.len()], n))
        .collect();
    out.push_str(&format!("{} {}\n", " ".repeat(13), legend.join("   ")));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_series() {
        let a = [10.0, 8.0, 6.0, 5.0, 4.5];
        let b = [10.0, 9.5, 9.0, 8.8, 8.7];
        let s = plot("conv", &[("omd", &a), ("sgp", &b)], 40, 10);
        assert!(s.contains("omd"));
        assert!(s.contains('*'));
        assert!(s.contains('+'));
        assert!(s.lines().count() > 10);
    }

    #[test]
    fn empty_is_safe() {
        let s = plot("none", &[("x", &[])], 10, 5);
        assert!(s.contains("no data"));
    }

    #[test]
    fn constant_series_safe() {
        let a = [3.0, 3.0, 3.0];
        let s = plot("const", &[("c", &a)], 20, 6);
        assert!(s.contains('*'));
    }
}
