//! Marginal-cost computation (paper eqs. 18–21, Gallager's recursion) —
//! **reference implementation**. The production hot path is the fused
//! [`crate::engine::FlowEngine`] reverse sweep, pinned against this module
//! by `tests/test_engine_equivalence.rs`.
//!
//! `δφ_ij(w) = D'_ij + ∂D/∂r_j(w)` where the downstream marginal
//! `∂D/∂r_j(w)` is computed by the **broadcast protocol**: destinations
//! announce 0, every node combines its out-edges' marginals weighted by its
//! own routing fractions and forwards the result upstream. Here the
//! recursion runs in reverse session-DAG topological order (the distributed
//! message-passing twin lives in [`crate::coordinator`] and must agree with
//! this module exactly — a cross-checked invariant in the integration
//! tests).

use crate::graph::augmented::AugmentedNet;
use crate::model::flow::Phi;
use crate::model::Problem;

/// Marginal costs at a given operating point (Λ, φ).
#[derive(Clone, Debug)]
pub struct Marginals {
    /// `dprime[e]` — link marginal `∂D_ij/∂F_ij`.
    pub dprime: Vec<f64>,
    /// `r[w][i]` — node marginal `∂D/∂r_i(w)` (eq. 20–21).
    pub r: Vec<Vec<f64>>,
}

impl Marginals {
    /// Routing-variable marginal `δφ_ij(w)` for edge `e` (eq. 19).
    #[inline]
    pub fn delta(&self, net: &AugmentedNet, w: usize, e: usize) -> f64 {
        self.dprime[e] + self.r[w][net.graph.edge(e).dst]
    }

    /// Full gradient `∂D/∂φ_ij(w) = t_i(w) · δφ_ij(w)` (eq. 18).
    #[inline]
    pub fn grad(&self, net: &AugmentedNet, w: usize, e: usize, t_i: f64) -> f64 {
        t_i * self.delta(net, w, e)
    }
}

/// Compute all marginals by one reverse sweep per session. Each edge's
/// `D'` uses its own cost family ([`Problem::edge_kind`]).
pub fn compute(problem: &Problem, phi: &Phi, flows: &[f64]) -> Marginals {
    let net = &problem.net;
    let ne = net.graph.n_edges();
    let mut dprime = vec![0.0; ne];
    for &e in &net.union_edges {
        dprime[e] = problem.edge_kind(e).derivative(flows[e], net.graph.edge(e).capacity);
    }

    let mut r = vec![vec![0.0; net.n_nodes()]; net.n_sessions()];
    for w in 0..net.n_sessions() {
        // reverse topological order: D_w first (r = 0 there by eq. 20)
        for &i in net.session_topo(w).iter().rev() {
            if i == net.dnode(w) {
                continue;
            }
            let mut acc = 0.0;
            for (e, f) in phi.row(net, w, i) {
                if f > 0.0 {
                    acc += f * (dprime[e] + r[w][net.graph.edge(e).dst]);
                }
            }
            r[w][i] = acc;
        }
    }
    Marginals { dprime, r }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topologies;
    use crate::model::cost::CostKind;
    use crate::model::flow::{self, Phi};
    use crate::model::Problem;
    use crate::util::rng::Rng;

    fn setup(seed: u64) -> (Problem, Phi, Vec<f64>, flow::FlowEval) {
        let mut rng = Rng::seed_from(seed);
        let net = topologies::connected_er(10, 0.35, 3, &mut rng);
        let p = Problem::new(net, 30.0, CostKind::Exp);
        let phi = Phi::uniform(&p.net);
        let lam = p.uniform_allocation();
        let ev = flow::evaluate(&p, &phi, &lam);
        (p, phi, lam, ev)
    }

    #[test]
    fn destination_marginal_is_zero() {
        let (p, phi, _lam, ev) = setup(1);
        let m = compute(&p, &phi, &ev.flows);
        for w in 0..p.n_versions() {
            assert_eq!(m.r[w][p.net.dnode(w)], 0.0);
        }
    }

    #[test]
    fn recursion_consistency() {
        // r_i(w) must equal Σ_j φ_ij (D'_ij + r_j(w)) at every node (eq. 21)
        let (p, phi, _lam, ev) = setup(2);
        let m = compute(&p, &phi, &ev.flows);
        for w in 0..p.n_versions() {
            for i in 0..p.net.n_nodes() {
                if i == p.net.dnode(w) {
                    continue;
                }
                let expect: f64 = phi
                    .row(&p.net, w, i)
                    .map(|(e, f)| f * (m.dprime[e] + m.r[w][p.net.graph.edge(e).dst]))
                    .sum();
                assert!((m.r[w][i] - expect).abs() < 1e-12, "w={w} i={i}");
            }
        }
    }

    #[test]
    fn gradient_matches_finite_difference() {
        // ∂D/∂φ_ij(w) ≈ (D(φ+h·e_ij·renorm) − D(φ)) / h on an *unnormalized*
        // perturbation: perturb φ_ij by +h and φ_ik (another lane) by −h;
        // directional derivative should equal t_i(δ_ij − δ_ik).
        let (p, phi, lam, ev) = setup(3);
        let m = compute(&p, &phi, &ev.flows);
        let t = flow::node_rates(&p.net, &phi, &lam);
        for w in 0..p.n_versions() {
            for &i in p.net.session_routers(w) {
                let lanes: Vec<usize> = p.net.session_out(w, i).collect();
                if lanes.len() < 2 || t[w][i] < 1e-9 {
                    continue;
                }
                let (e1, e2) = (lanes[0], lanes[1]);
                let h = 1e-7;
                let mut phi2 = phi.clone();
                phi2.frac[w][e1] += h;
                phi2.frac[w][e2] -= h;
                let ev2 = flow::evaluate(&p, &phi2, &lam);
                let fd = (ev2.cost - ev.cost) / h;
                let analytic = t[w][i] * (m.delta(&p.net, w, e1) - m.delta(&p.net, w, e2));
                assert!(
                    (fd - analytic).abs() < 1e-3 * analytic.abs().max(1.0),
                    "w={w} i={i}: fd={fd} analytic={analytic}"
                );
                return; // one verified row per run is enough here
            }
        }
    }

    #[test]
    fn marginals_positive_on_live_edges() {
        let (p, phi, _lam, ev) = setup(4);
        let m = compute(&p, &phi, &ev.flows);
        for w in 0..p.n_versions() {
            for (e, used) in p.net.session_edges[w].iter().enumerate() {
                if *used {
                    assert!(m.delta(&p.net, w, e) > 0.0);
                }
            }
        }
    }
}
