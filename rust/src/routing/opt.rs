//! **OPT** — centralized optimal routing (the Fig. 7 reference line).
//!
//! The operator is assumed to know the whole topology: it enumerates every
//! `S → D_w` path (finite on the session DAGs), then solves the convex
//! path-flow program
//!
//! ```text
//! min_{x ≥ 0}  Σ_e D_e(F_e(x), C_e)    s.t.  Σ_{p ∈ w} x_p = λ_w  ∀w
//! ```
//!
//! with high-precision entropic mirror descent over each session's path
//! simplex (run to stationarity; tolerances far below anything the
//! distributed algorithms reach). The result serves as ground truth for
//! Theorems 3/4 convergence checks and the "OPT" line in Figs. 7–8.

use crate::engine::{BatchMode, FlowEngine};
use crate::graph::paths::{enumerate_paths, Path};
use crate::model::flow::Phi;
use crate::model::Problem;

/// Centralized solution.
#[derive(Clone, Debug)]
pub struct OptSolution {
    pub cost: f64,
    /// Per-session per-path flows.
    pub path_flows: Vec<Vec<f64>>,
    pub paths: Vec<Vec<Path>>,
    pub iterations: usize,
    pub elapsed_s: f64,
}

#[derive(Clone, Debug)]
pub struct OptRouter {
    /// Path enumeration cap per session (guards pathological instances).
    pub max_paths: usize,
    /// Mirror-descent iterations.
    pub max_iters: usize,
    /// Stationarity tolerance on the max marginal spread.
    pub tol: f64,
    /// Streaming adapter memo: the `(Λ, φ*)` of the last full solve. A
    /// `Router::step` whose inputs still match is a cheap evaluation; any
    /// change to Λ or an externally reset φ (e.g. a topology change)
    /// triggers a fresh solve.
    streaming_cache: Option<(Vec<f64>, Phi)>,
    engine: FlowEngine,
}

impl Default for OptRouter {
    fn default() -> Self {
        OptRouter {
            max_paths: 500_000,
            max_iters: 20_000,
            tol: 1e-9,
            streaming_cache: None,
            engine: FlowEngine::new(),
        }
    }
}

impl OptRouter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Worker threads for the engine's per-session sweeps (`0` = auto).
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.engine.set_workers(workers);
        self
    }

    /// Solve the path-flow program for allocation `lam`.
    pub fn solve(&self, problem: &Problem, lam: &[f64]) -> OptSolution {
        let t0 = crate::util::clock::Stopwatch::start();
        let net = &problem.net;
        let w_cnt = net.n_sessions();
        assert_eq!(lam.len(), w_cnt);

        let paths: Vec<Vec<Path>> = (0..w_cnt)
            .map(|w| {
                let p = enumerate_paths(net, w, self.max_paths);
                assert!(
                    p.len() < self.max_paths,
                    "path enumeration cap hit for session {w}"
                );
                p
            })
            .collect();

        // x[w][p]: start uniform on each session's path simplex
        let mut x: Vec<Vec<f64>> = paths
            .iter()
            .zip(lam)
            .map(|(ps, &l)| vec![l / ps.len() as f64; ps.len()])
            .collect();

        let ne = net.graph.n_edges();
        let mut flows = vec![0.0; ne];
        let mut iterations = 0;
        let mut eta = 0.2;
        let mut last_cost = f64::INFINITY;
        for it in 0..self.max_iters {
            iterations = it + 1;
            // edge flows from path flows
            flows.iter_mut().for_each(|f| *f = 0.0);
            for (ps, xs) in paths.iter().zip(&x) {
                for (p, &xp) in ps.iter().zip(xs) {
                    if xp > 0.0 {
                        for &e in &p.edges {
                            flows[e] += xp;
                        }
                    }
                }
            }
            let cost = crate::model::flow::total_cost(problem, &flows);
            // per-edge marginals -> per-path marginals
            let dprime: Vec<f64> = net
                .graph
                .edges()
                .iter()
                .enumerate()
                .map(|(e, edge)| {
                    if (0..w_cnt).any(|w| net.session_edges[w][e]) {
                        problem.edge_kind(e).derivative(flows[e], edge.capacity)
                    } else {
                        0.0
                    }
                })
                .collect();

            let mut spread_max = 0.0f64;
            for (w, (ps, xs)) in paths.iter().zip(&mut x).enumerate() {
                if lam[w] <= 0.0 {
                    continue;
                }
                let marg: Vec<f64> = ps
                    .iter()
                    .map(|p| p.edges.iter().map(|&e| dprime[e]).sum::<f64>())
                    .collect();
                // stationarity: marginal spread over the support
                let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
                for (m, &xp) in marg.iter().zip(xs.iter()) {
                    if xp > 1e-9 * lam[w] {
                        lo = lo.min(*m);
                        hi = hi.max(*m);
                    }
                }
                spread_max = spread_max.max((hi - lo) / hi.abs().max(1.0));
                // entropic mirror step on the scaled simplex, with the same
                // exponent-span trust region + interior floor as OMD-RT
                // (multiplicative updates must never zero a path for good)
                let mmin = marg.iter().cloned().fold(f64::INFINITY, f64::min);
                let mmax = marg.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let span = eta * (mmax - mmin);
                let escale = if span > crate::routing::omd::MAX_EXP_SPAN {
                    crate::routing::omd::MAX_EXP_SPAN / span
                } else {
                    1.0
                };
                let mut sum = 0.0;
                for (xp, m) in xs.iter_mut().zip(&marg) {
                    *xp *= (-eta * (m - mmin) * escale).exp();
                    sum += *xp;
                }
                if sum > 0.0 {
                    let scale = lam[w] / sum;
                    let floor = crate::routing::omd::PHI_FLOOR * lam[w];
                    let mut sum2 = 0.0;
                    for xp in xs.iter_mut() {
                        *xp = (*xp * scale).max(floor);
                        sum2 += *xp;
                    }
                    let rescale = lam[w] / sum2;
                    xs.iter_mut().for_each(|xp| *xp *= rescale);
                }
            }
            if spread_max < self.tol {
                break;
            }
            // simple adaptive step: back off if cost went up
            if cost > last_cost + 1e-12 {
                eta *= 0.7;
            }
            last_cost = cost;
        }

        // final evaluation
        flows.iter_mut().for_each(|f| *f = 0.0);
        for (ps, xs) in paths.iter().zip(&x) {
            for (p, &xp) in ps.iter().zip(xs) {
                for &e in &p.edges {
                    flows[e] += xp;
                }
            }
        }
        let cost = crate::model::flow::total_cost(problem, &flows);
        OptSolution {
            cost,
            path_flows: x,
            paths,
            iterations,
            elapsed_s: t0.elapsed_secs(),
        }
    }

    /// Convert the path-flow solution back to node-based routing variables φ
    /// (for cross-validation with the distributed algorithms).
    pub fn to_phi(&self, problem: &Problem, sol: &OptSolution) -> Phi {
        let net = &problem.net;
        let ne = net.graph.n_edges();
        let w_cnt = net.n_sessions();
        let mut per_edge = vec![vec![0.0; ne]; w_cnt];
        for (w, (ps, xs)) in sol.paths.iter().zip(&sol.path_flows).enumerate() {
            for (p, &xp) in ps.iter().zip(xs) {
                for &e in &p.edges {
                    per_edge[w][e] += xp;
                }
            }
        }
        let mut phi = Phi::uniform(net);
        for w in 0..w_cnt {
            for i in 0..net.n_nodes() {
                let lanes: Vec<usize> = net.session_out(w, i).collect();
                if lanes.is_empty() {
                    continue;
                }
                let out: f64 = lanes.iter().map(|&e| per_edge[w][e]).sum();
                if out > 1e-12 {
                    for &e in &lanes {
                        phi.frac[w][e] = per_edge[w][e] / out;
                    }
                }
            }
        }
        phi
    }
}

/// Registry adapter: a [`crate::routing::Router::step`] performs the full
/// centralized solve and installs the resulting φ*; while Λ and φ stay
/// unchanged, subsequent steps are cheap evaluations that leave φ fixed,
/// so `step`-driven runs converge at the next iteration (φ stops moving)
/// without re-running the solve. A changed Λ (e.g. an allocator's ±δ
/// probes) or an externally reset φ (topology change) re-solves. The
/// returned value is — per the `Router` contract — the cost *before* the
/// update.
impl crate::routing::Router for OptRouter {
    fn name(&self) -> &'static str {
        "OPT"
    }

    fn set_workers(&mut self, workers: usize) {
        self.engine.set_workers(workers);
    }

    fn set_batch_mode(&mut self, mode: BatchMode) {
        self.engine.set_batch_mode(mode);
    }

    fn step(&mut self, problem: &Problem, lam: &[f64], phi: &mut Phi) -> f64 {
        let cost_before = self.engine.evaluate_cost(problem, phi, lam);
        let cached = self
            .streaming_cache
            .as_ref()
            .is_some_and(|(l, p)| l.as_slice() == lam && p == &*phi);
        if !cached {
            let sol = self.solve(problem, lam);
            *phi = self.to_phi(problem, &sol);
            self.streaming_cache = Some((lam.to_vec(), phi.clone()));
        }
        cost_before
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topologies;
    use crate::model::cost::CostKind;
    use crate::model::flow;
    use crate::routing::omd::OmdRouter;
    use crate::routing::Router;
    use crate::util::rng::Rng;

    fn problem(seed: u64, n: usize) -> Problem {
        let mut rng = Rng::seed_from(seed);
        let net = topologies::connected_er(n, 0.3, 3, &mut rng);
        Problem::new(net, 60.0, CostKind::Exp)
    }

    #[test]
    fn opt_is_a_lower_bound_and_omd_reaches_it() {
        let p = problem(1, 10);
        let lam = p.uniform_allocation();
        let opt = OptRouter::new().solve(&p, &lam);
        let omd = OmdRouter::new(0.5).solve(&p, &lam, 5000);
        assert!(
            opt.cost <= omd.objective + 1e-6,
            "OPT {} must lower-bound OMD {}",
            opt.cost,
            omd.objective
        );
        let rel = (omd.objective - opt.cost) / opt.cost;
        assert!(rel < 5e-3, "OMD {} should match OPT {} (rel {rel})", omd.objective, opt.cost);
    }

    #[test]
    fn path_flows_conserve_allocation() {
        let p = problem(2, 8);
        let lam = p.uniform_allocation();
        let sol = OptRouter::new().solve(&p, &lam);
        for (w, xs) in sol.path_flows.iter().enumerate() {
            let s: f64 = xs.iter().sum();
            assert!((s - lam[w]).abs() < 1e-6, "session {w}: {s} vs {}", lam[w]);
            assert!(xs.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn to_phi_reproduces_cost() {
        let p = problem(3, 8);
        let lam = p.uniform_allocation();
        let router = OptRouter::new();
        let sol = router.solve(&p, &lam);
        let phi = router.to_phi(&p, &sol);
        phi.is_feasible(&p.net, 1e-6).unwrap();
        let ev = flow::evaluate(&p, &phi, &lam);
        let rel = (ev.cost - sol.cost).abs() / sol.cost;
        assert!(rel < 1e-6, "phi cost {} vs path cost {}", ev.cost, sol.cost);
    }
}
