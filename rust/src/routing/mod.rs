//! Routing algorithms for the optimal-routing problem `P2` (paper §III-B).
//!
//! * [`omd::OmdRouter`] — **OMD-RT** (Algorithm 2), the paper's contribution.
//! * [`sgp::SgpRouter`] — scaled gradient projection baseline ([13], Xi&Yeh).
//! * [`gp::GpRouter`] — vanilla Gallager gradient projection (ablation).
//! * [`opt::OptRouter`] — centralized path-flow solve (the "OPT" line).
//!
//! Prefer constructing routers by name through
//! [`crate::session::registry`] and driving them with
//! [`crate::session::RoutingRun`]; direct `OmdRouter::new(η).solve(...)`
//! construction remains supported for algorithm-internal code and
//! fine-grained control, but new entry points should go through the
//! session API (see the deprecation note in the crate docs).
//!
//! All per-iteration numerics (rates, flows, cost, marginals) go through
//! [`crate::engine::FlowEngine`]'s fused sweeps; the free functions in
//! [`crate::model::flow`] and [`marginal`] remain as the plain reference
//! implementations the engine is pinned against.

pub mod gp;
pub mod marginal;
pub mod omd;
pub mod opt;
pub mod sgp;

use crate::coordinator::net::CommStats;
use crate::engine::{BatchMode, FlowEngine, SessionMask};
use crate::model::flow::Phi;
use crate::model::Problem;
use crate::session::registry::SolverOpts;
use crate::session::run::{RunReport, StopReason};

/// A distributed routing algorithm: iterates routing variables φ toward the
/// minimizer of the total network cost for a fixed allocation Λ.
pub trait Router {
    fn name(&self) -> &'static str;

    /// Perform **one** routing iteration in place, returning the total cost
    /// evaluated *before* the update (matching the paper's per-iteration
    /// convergence plots).
    fn step(&mut self, problem: &Problem, lam: &[f64], phi: &mut Phi) -> f64;

    /// Like [`Router::step`], with the caller's promise that only the
    /// sessions in `dirty` changed their `λ` entry or `φ` rows since this
    /// router's **previous** evaluation of the same problem. Routers with
    /// a delta-capable engine override this so the pre-update evaluation
    /// ([`FlowEngine::prepare_dirty`]) re-runs the forward recurrence only
    /// for the dirty sessions (and, when the engine's marginals are still
    /// in sync, re-broadcasts only from repriced lanes) — results are
    /// bit-identical to [`Router::step`] either way. Default: a full step.
    fn step_dirty(
        &mut self,
        problem: &Problem,
        lam: &[f64],
        phi: &mut Phi,
        _dirty: &SessionMask,
    ) -> f64 {
        self.step(problem, lam, phi)
    }

    /// The `φ` rows this router's **last** step actually changed
    /// (bitwise), as a [`SessionMask`] — `None` when the router does not
    /// track them (default) or before any step. Oracles use this to keep
    /// their *post-step* telemetry sweeps O(touched) (see
    /// `coordinator::serving::MeasuredOracle`); a `None` simply means
    /// "assume everything moved".
    fn touched_sessions(&self) -> Option<&SessionMask> {
        None
    }

    /// Set the [`FlowEngine`] worker count for this router's per-iteration
    /// sweeps (`0` = auto-detect). Results are bit-identical at any value.
    /// Default: no-op for routers without an engine.
    fn set_workers(&mut self, _workers: usize) {}

    /// Select the engine sweep kernels (scalar vs session-batched; see
    /// [`BatchMode`]). Results are bit-identical in every mode — this knob
    /// exists for the hotpath bench and the equivalence tests. Default:
    /// no-op for routers without an engine.
    fn set_batch_mode(&mut self, _mode: BatchMode) {}

    /// Communication accounting, for routers that run over a message
    /// fabric (the distributed coordinator). `None` for in-process
    /// routers; surfaced as [`crate::session::RunReport::comm`].
    fn comm_stats(&self) -> Option<CommStats> {
        None
    }

    /// Apply a unified [`SolverOpts`] bundle to an existing router — the
    /// one-call replacement for the `set_workers` + `set_batch_mode` pair.
    /// Construction-time knobs (η, shards, staleness) are consumed by
    /// [`crate::session::registry::router_opts`] instead; this method
    /// covers everything reconfigurable after the fact.
    fn configure(&mut self, opts: &SolverOpts) {
        self.set_workers(opts.workers);
        self.set_batch_mode(opts.batch_mode);
    }

    /// Iterate up to `max_iters`, stopping early when φ stops changing
    /// (`Line 6` of Algorithm 2: `φ^{k+1} == φ^k`). Returns the unified
    /// [`RunReport`] (the legacy `RoutingState` is gone): `objective` is
    /// the final cost, `phi` is always `Some`. Trajectories are a
    /// streaming-run concern — attach a
    /// [`crate::session::Trajectory`] to a
    /// [`crate::session::RoutingRun`] when you need one.
    fn solve(&mut self, problem: &Problem, lam: &[f64], max_iters: usize) -> RunReport {
        let mut phi = Phi::uniform(&problem.net);
        self.solve_from(problem, lam, &mut phi, max_iters)
    }

    /// Like [`Router::solve`] but warm-started from (and updating) `phi`.
    fn solve_from(
        &mut self,
        problem: &Problem,
        lam: &[f64],
        phi: &mut Phi,
        max_iters: usize,
    ) -> RunReport {
        let t0 = crate::util::clock::Stopwatch::start();
        let mut iterations = 0;
        let mut stop = StopReason::MaxIters;
        for _ in 0..max_iters {
            let prev = phi.clone();
            let _cost_before = self.step(problem, lam, phi);
            iterations += 1;
            if phi_close(&prev, phi, CONVERGENCE_TOL) {
                stop = StopReason::Converged;
                break;
            }
        }
        // engine-based final evaluation — the same fused sweep the session
        // API's `RoutingRun` report uses, so both paths stay bit-identical
        let final_cost = FlowEngine::new().evaluate_cost(problem, phi, lam);
        RunReport {
            algo: self.name().to_string(),
            objective: final_cost,
            lam: lam.to_vec(),
            phi: Some(phi.clone()),
            iterations,
            routing_iterations: iterations,
            comm: self.comm_stats(),
            stop,
            elapsed_s: t0.elapsed_secs(),
        }
    }
}

/// Stopping tolerance on `‖φ^{k+1} − φ^k‖_∞` (the paper's exact-equality
/// stop, relaxed to floating point).
pub const CONVERGENCE_TOL: f64 = 1e-10;

/// Max-norm closeness of two routing configurations.
pub fn phi_close(a: &Phi, b: &Phi, tol: f64) -> bool {
    a.frac
        .iter()
        .zip(&b.frac)
        .all(|(ra, rb)| ra.iter().zip(rb).all(|(x, y)| (x - y).abs() <= tol))
}

/// Euclidean projection onto the probability simplex `{x ≥ 0, Σx = 1}`
/// (Held–Wolfe–Crowder; O(d log d)). Shared by the GP and SGP baselines.
pub fn project_simplex(y: &[f64]) -> Vec<f64> {
    let d = y.len();
    assert!(d > 0);
    let mut u: Vec<f64> = y.to_vec();
    u.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let mut css = 0.0;
    let mut rho = 0;
    let mut theta = 0.0;
    for (i, &ui) in u.iter().enumerate() {
        css += ui;
        let th = (css - 1.0) / (i + 1) as f64;
        if ui - th > 0.0 {
            rho = i + 1;
            theta = th;
        }
    }
    debug_assert!(rho > 0);
    y.iter().map(|&x| (x - theta).max(0.0)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simplex_projection_identity_on_feasible() {
        let x = vec![0.2, 0.3, 0.5];
        let p = project_simplex(&x);
        for (a, b) in x.iter().zip(&p) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn simplex_projection_feasible_output() {
        let cases = [vec![5.0, -3.0, 0.1], vec![0.0, 0.0], vec![-1.0, -2.0, -3.0, 10.0]];
        for y in cases {
            let p = project_simplex(&y);
            assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9, "{p:?}");
            assert!(p.iter().all(|&v| v >= 0.0));
        }
    }

    #[test]
    fn simplex_projection_is_nearest_point() {
        // brute-force check on a 2-simplex grid
        let y = vec![0.9, 0.4, -0.2];
        let p = project_simplex(&y);
        let dist =
            |x: &[f64]| x.iter().zip(&y).map(|(a, b)| (a - b) * (a - b)).sum::<f64>();
        let dp = dist(&p);
        let mut best = f64::INFINITY;
        let g = 60;
        for i in 0..=g {
            for j in 0..=(g - i) {
                let x = [i as f64 / g as f64, j as f64 / g as f64, (g - i - j) as f64 / g as f64];
                best = best.min(dist(&x));
            }
        }
        assert!(dp <= best + 1e-3, "projection {dp} vs grid best {best}");
    }
}
