//! Vanilla gradient projection routing (Gallager 1977) — ablation baseline.
//!
//! Each (session, node) row takes a plain Euclidean gradient step on the
//! full gradient `t_i(w)·δφ_ij(w)` followed by projection onto the simplex.
//! Included to demonstrate the paper's Remark 2/4 point: mirror descent
//! (OMD-RT) fits the simplex geometry and converges far faster than the
//! canonical gradient projection at the same step size.

use super::{project_simplex, Router};
use crate::engine::{BatchMode, FlowEngine};
use crate::model::flow::Phi;
use crate::model::Problem;

#[derive(Clone, Debug)]
pub struct GpRouter {
    /// Euclidean step size.
    pub eta: f64,
    engine: FlowEngine,
}

impl GpRouter {
    pub fn new(eta: f64) -> Self {
        GpRouter { eta, engine: FlowEngine::new() }
    }

    /// Worker threads for the engine's per-session sweeps (`0` = auto).
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.engine.set_workers(workers);
        self
    }
}

impl Router for GpRouter {
    fn name(&self) -> &'static str {
        "GP"
    }

    fn set_workers(&mut self, workers: usize) {
        self.engine.set_workers(workers);
    }

    fn set_batch_mode(&mut self, mode: BatchMode) {
        self.engine.set_batch_mode(mode);
    }

    fn step(&mut self, problem: &Problem, lam: &[f64], phi: &mut Phi) -> f64 {
        let net = &problem.net;
        let cost_before = self.engine.prepare(problem, phi, lam);

        let csr = &net.csr;
        for w in 0..net.n_sessions() {
            let frac = &mut phi.frac[w];
            for r in csr.rows(w) {
                let ti = self.engine.node_rate(w, r.node);
                if ti <= 0.0 || r.len() < 2 {
                    continue;
                }
                let y: Vec<f64> = (r.start..r.end)
                    .map(|k| {
                        // same association as the legacy `η·(t_i·δφ)` gradient
                        frac[csr.lane_edge[k]]
                            - self.eta * (ti * self.engine.lane_delta(csr, w, k))
                    })
                    .collect();
                let proj = project_simplex(&y);
                for (k, &v) in (r.start..r.end).zip(&proj) {
                    frac[csr.lane_edge[k]] = v;
                }
            }
        }
        cost_before
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topologies;
    use crate::model::cost::CostKind;
    use crate::util::rng::Rng;

    fn problem(seed: u64) -> Problem {
        let mut rng = Rng::seed_from(seed);
        let net = topologies::connected_er(10, 0.3, 3, &mut rng);
        Problem::new(net, 60.0, CostKind::Exp)
    }

    #[test]
    fn descends_and_stays_feasible() {
        let p = problem(1);
        let lam = p.uniform_allocation();
        // initial cost = uniform-φ evaluation (what trajectory[0] used to be)
        let initial =
            FlowEngine::new().evaluate_cost(&p, &Phi::uniform(&p.net), &lam);
        let mut r = GpRouter::new(0.002);
        let sol = r.solve(&p, &lam, 80);
        assert!(sol.objective < initial);
        sol.phi.unwrap().is_feasible(&p.net, 1e-9).unwrap();
    }

    #[test]
    fn omd_beats_gp_early() {
        // the paper's geometry argument: at comparable effective step sizes,
        // OMD makes much faster early progress than Euclidean GP
        let p = problem(2);
        let lam = p.uniform_allocation();
        let gp = GpRouter::new(0.002).solve(&p, &lam, 10);
        let omd = super::super::omd::OmdRouter::new(0.1).solve(&p, &lam, 10);
        assert!(
            omd.objective <= gp.objective + 1e-9,
            "OMD {} should beat GP {} after 10 iters",
            omd.objective,
            gp.objective
        );
    }
}
