//! Vanilla gradient projection routing (Gallager 1977) — ablation baseline.
//!
//! Each (session, node) row takes a plain Euclidean gradient step on the
//! full gradient `t_i(w)·δφ_ij(w)` followed by projection onto the simplex.
//! Included to demonstrate the paper's Remark 2/4 point: mirror descent
//! (OMD-RT) fits the simplex geometry and converges far faster than the
//! canonical gradient projection at the same step size.

use super::{marginal, project_simplex, Router};
use crate::model::flow::{self, Phi};
use crate::model::Problem;

#[derive(Clone, Debug)]
pub struct GpRouter {
    /// Euclidean step size.
    pub eta: f64,
}

impl GpRouter {
    pub fn new(eta: f64) -> Self {
        GpRouter { eta }
    }
}

impl Router for GpRouter {
    fn name(&self) -> &'static str {
        "GP"
    }

    fn step(&mut self, problem: &Problem, lam: &[f64], phi: &mut Phi) -> f64 {
        let net = &problem.net;
        let t = flow::node_rates(net, phi, lam);
        let flows = flow::edge_flows(net, phi, &t);
        let cost_before = flow::total_cost(net, problem.cost, &flows);
        let m = marginal::compute(net, problem.cost, phi, &flows);

        for w in 0..net.n_versions() {
            for &i in net.session_routers(w) {
                if t[w][i] <= 0.0 {
                    continue;
                }
                let lanes: Vec<usize> = net.session_out(w, i).collect();
                if lanes.len() < 2 {
                    continue;
                }
                let y: Vec<f64> = lanes
                    .iter()
                    .map(|&e| phi.frac[w][e] - self.eta * m.grad(net, w, e, t[w][i]))
                    .collect();
                let proj = project_simplex(&y);
                for (&e, &v) in lanes.iter().zip(&proj) {
                    phi.frac[w][e] = v;
                }
            }
        }
        cost_before
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topologies;
    use crate::model::cost::CostKind;
    use crate::util::rng::Rng;

    fn problem(seed: u64) -> Problem {
        let mut rng = Rng::seed_from(seed);
        let net = topologies::connected_er(10, 0.3, 3, &mut rng);
        Problem::new(net, 60.0, CostKind::Exp)
    }

    #[test]
    fn descends_and_stays_feasible() {
        let p = problem(1);
        let lam = p.uniform_allocation();
        let mut r = GpRouter::new(0.002);
        let sol = r.solve(&p, &lam, 80);
        assert!(sol.cost < sol.trajectory[0]);
        sol.phi.is_feasible(&p.net, 1e-9).unwrap();
    }

    #[test]
    fn omd_beats_gp_early() {
        // the paper's geometry argument: at comparable effective step sizes,
        // OMD makes much faster early progress than Euclidean GP
        let p = problem(2);
        let lam = p.uniform_allocation();
        let gp = GpRouter::new(0.002).solve(&p, &lam, 10);
        let omd = super::super::omd::OmdRouter::new(0.1).solve(&p, &lam, 10);
        assert!(
            omd.cost <= gp.cost + 1e-9,
            "OMD {} should beat GP {} after 10 iters",
            omd.cost,
            gp.cost
        );
    }
}
