//! **SGP** — scaled gradient projection baseline (Xi & Yeh, [13]; the
//! "state of the art" the paper compares OMD-RT against in Figs. 7–9,
//! 12–15).
//!
//! Per (session, node) row, SGP solves the scaled projection subproblem
//!
//! ```text
//! φ^{k+1}_i(w) = argmin_{φ ∈ Δ}  ⟨∇_i(w), φ − φ^k⟩ + ½ (φ − φ^k)ᵀ M (φ − φ^k)
//! ```
//!
//! where `M = M_i^k(w)` is the diagonal Hessian upper bound of [13]:
//! `M_jj = t_i(w) · h_j · D̄''`, with `h_j` the maximum remaining hop count
//! from next-hop `j` to `D_w` (extra *system information* SGP needs — the
//! paper's footnote 4) and `D̄''` the per-iteration bound on the link cost's
//! second derivative along the downstream sub-DAG.
//!
//! The subproblem is a QP over the simplex; faithful to the comparison's
//! spirit ("SGP needs to solve a complex convex problem while OMD-RT just
//! needs a softmax"), it is solved by an iterative scaled projected-gradient
//! inner loop run to 1e-10, not a closed form. Computing `M` additionally
//! costs a DP over the session DAG per iteration. Both are counted in the
//! Fig. 9 runtime comparison.
//!
//! The Hessian-bound ingredients — the `h_j` max-hop DP, the per-edge
//! second-derivative bounds, and the downstream `D̄''` maxima — live in
//! **router-owned workspaces** sized once per topology and reused across
//! iterations (the same zero-allocation discipline as the
//! [`FlowEngine`]'s sweeps): the downstream bound is a reverse-topological
//! DP (`down[j] = max over out-lanes of max(D̄''_e, down[dst(e)])`) that
//! replaces the per-lane BFS of earlier revisions with identical results
//! (`max` is exact — no rounding, so the values are bit-identical).

use super::{project_simplex, Router};
use crate::engine::{BatchMode, FlowEngine};
use crate::model::flow::Phi;
use crate::model::Problem;

#[derive(Clone, Debug)]
pub struct SgpRouter {
    /// Global scaling multiplier on M (≥1 keeps the Hessian bound valid;
    /// larger is more conservative = smaller steps).
    pub scale: f64,
    /// Inner QP solver tolerance.
    pub qp_tol: f64,
    /// Inner QP solver iteration cap.
    pub qp_max_iters: usize,
    engine: FlowEngine,
    /// Per-edge second-derivative bounds at the current operating point
    /// (workspace; refilled every iteration, sized once per topology).
    ddmax: Vec<f64>,
    /// Per-node max remaining hops `h_j` of the current session (workspace).
    hops: Vec<f64>,
    /// Per-node downstream `D̄''` maxima of the current session (workspace).
    down_dd: Vec<f64>,
}

impl Default for SgpRouter {
    fn default() -> Self {
        SgpRouter {
            scale: 1.0,
            qp_tol: 1e-10,
            qp_max_iters: 400,
            engine: FlowEngine::new(),
            ddmax: Vec::new(),
            hops: Vec::new(),
            down_dd: Vec::new(),
        }
    }
}

impl SgpRouter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Worker threads for the engine's per-session sweeps (`0` = auto).
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.engine.set_workers(workers);
        self
    }

    /// Solve `argmin ⟨g, x−x0⟩ + ½ (x−x0)ᵀ diag(m) (x−x0)` over the simplex
    /// by projected gradient with step `1/max(m)`, to `qp_tol`.
    fn solve_row_qp(&self, x0: &[f64], g: &[f64], m: &[f64]) -> Vec<f64> {
        let mmax = m.iter().cloned().fold(0.0f64, f64::max).max(1e-12);
        let step = 1.0 / mmax;
        let mut x = x0.to_vec();
        for _ in 0..self.qp_max_iters {
            let grad: Vec<f64> = x
                .iter()
                .zip(x0)
                .zip(g.iter().zip(m))
                .map(|((&xi, &x0i), (&gi, &mi))| gi + mi * (xi - x0i))
                .collect();
            let y: Vec<f64> = x.iter().zip(&grad).map(|(&xi, &gi)| xi - step * gi).collect();
            let nx = project_simplex(&y);
            let delta: f64 = nx.iter().zip(&x).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
            x = nx;
            if delta < self.qp_tol {
                break;
            }
        }
        x
    }
}

impl Router for SgpRouter {
    fn name(&self) -> &'static str {
        "SGP"
    }

    fn set_workers(&mut self, workers: usize) {
        self.engine.set_workers(workers);
    }

    fn set_batch_mode(&mut self, mode: BatchMode) {
        self.engine.set_batch_mode(mode);
    }

    fn step(&mut self, problem: &Problem, lam: &[f64], phi: &mut Phi) -> f64 {
        let net = &problem.net;
        let cost_before = self.engine.prepare(problem, phi, lam);

        // Hessian-bound ingredients ([13]'s extra system information):
        // per-edge second-derivative bounds at the current operating point
        // plus the max-hop and downstream-D̄'' DPs per session — all into
        // router-owned workspaces (zero allocations after the first call
        // on a topology).
        let total: f64 = lam.iter().sum();
        self.ddmax.resize(net.graph.n_edges(), 0.0);
        for (e, edge) in net.graph.edges().iter().enumerate() {
            self.ddmax[e] = problem
                .edge_kind(e)
                .second_derivative_bound(flows_cap(total, edge.capacity), edge.capacity);
        }
        self.hops.resize(net.n_nodes(), 0.0);
        self.down_dd.resize(net.n_nodes(), 0.0);

        let csr = &net.csr;
        for w in 0..net.n_sessions() {
            // reverse-topological DPs: max remaining hops h_j and the
            // downstream second-derivative maxima (the per-lane bound is
            // then max(D̄''_e, down_dd[dst(e)]) — identical to a BFS over
            // the downstream sub-DAG, since `max` is exact)
            self.hops.fill(0.0);
            self.down_dd.fill(0.0);
            let dw = net.dnode(w);
            for &i in net.session_topo(w).iter().rev() {
                if i == dw {
                    continue;
                }
                let mut best_h = 0.0f64;
                let mut best_dd = 0.0f64;
                for e in net.session_out(w, i) {
                    let dst = net.graph.edge(e).dst;
                    best_h = best_h.max(1.0 + self.hops[dst]);
                    best_dd = best_dd.max(self.ddmax[e].max(self.down_dd[dst]));
                }
                self.hops[i] = best_h;
                self.down_dd[i] = best_dd;
            }
            for r in csr.rows(w) {
                let ti = self.engine.node_rate(w, r.node);
                if ti <= 0.0 || r.len() < 2 {
                    continue;
                }
                let lanes = &csr.lane_edge[r.start..r.end];
                let x0: Vec<f64> = lanes.iter().map(|&e| phi.frac[w][e]).collect();
                let g: Vec<f64> = (r.start..r.end)
                    .map(|k| ti * self.engine.lane_delta(csr, w, k))
                    .collect();
                // diagonal scaling M_jj = scale · t_i · h_j · D̄''_(downstream max)
                let mm: Vec<f64> = (r.start..r.end)
                    .map(|k| {
                        let j = csr.lane_dst[k];
                        let e = csr.lane_edge[k];
                        let dd = self.ddmax[e].max(self.down_dd[j]);
                        (self.scale * ti * ti * (self.hops[j] + 1.0) * dd).max(1e-9)
                    })
                    .collect();
                let x = self.solve_row_qp(&x0, &g, &mm);
                for (&e, &v) in lanes.iter().zip(&x) {
                    phi.frac[w][e] = v;
                }
            }
        }
        cost_before
    }
}

/// Flow level at which to evaluate the Hessian bound: total admitted rate
/// capped by the link's capacity region of interest.
#[inline]
fn flows_cap(total: f64, cap: f64) -> f64 {
    total.min(3.0 * cap)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topologies;
    use crate::model::cost::CostKind;
    use crate::routing::omd::OmdRouter;
    use crate::util::rng::Rng;

    fn problem(seed: u64) -> Problem {
        let mut rng = Rng::seed_from(seed);
        let net = topologies::connected_er(10, 0.3, 3, &mut rng);
        Problem::new(net, 60.0, CostKind::Exp)
    }

    #[test]
    fn descends_and_stays_feasible() {
        let p = problem(1);
        let mut traj = crate::session::Trajectory::default();
        let report = crate::session::RoutingRun::new(
            &p,
            Box::new(SgpRouter::new()),
            p.uniform_allocation(),
            50,
        )
        .observe(&mut traj)
        .finish();
        assert!(report.objective < traj.values[0], "{:?}", &traj.values[..5]);
        report.phi.unwrap().is_feasible(&p.net, 1e-7).unwrap();
        for w in traj.values.windows(2) {
            assert!(w[1] <= w[0] + 1e-6, "SGP cost increased {} -> {}", w[0], w[1]);
        }
    }

    #[test]
    fn converges_to_same_cost_as_omd() {
        // Both must reach the unique optimum (Theorem 3) — Fig. 7's plateau.
        let p = problem(2);
        let lam = p.uniform_allocation();
        let omd = OmdRouter::new(0.5).solve(&p, &lam, 4000);
        let sgp = SgpRouter::new().solve(&p, &lam, 4000);
        let rel = (omd.objective - sgp.objective).abs() / omd.objective;
        assert!(rel < 5e-3, "OMD {} vs SGP {}", omd.objective, sgp.objective);
    }

    #[test]
    fn row_qp_solves_projection() {
        // with g = 0, the QP returns x0 (already feasible)
        let r = SgpRouter::new();
        let x0 = [0.25, 0.75];
        let x = r.solve_row_qp(&x0, &[0.0, 0.0], &[1.0, 1.0]);
        assert!((x[0] - 0.25).abs() < 1e-8 && (x[1] - 0.75).abs() < 1e-8);
        // strong gradient on lane 1 pushes mass to lane 0
        let x = r.solve_row_qp(&x0, &[0.0, 10.0], &[1.0, 1.0]);
        assert!(x[0] > 0.99);
    }

    #[test]
    fn omd_cheaper_per_iteration() {
        // per-iteration wall clock: OMD should be at least 5x cheaper even
        // on this small instance (the Fig. 9 effect; full measurement in
        // benches/fig8_9).
        let p = problem(3);
        let lam = p.uniform_allocation();
        let t0 = std::time::Instant::now();
        let _ = OmdRouter::new(0.5).solve(&p, &lam, 30);
        let omd_t = t0.elapsed();
        let t1 = std::time::Instant::now();
        let _ = SgpRouter::new().solve(&p, &lam, 30);
        let sgp_t = t1.elapsed();
        assert!(
            sgp_t > omd_t * 2,
            "SGP {:?} should be much slower than OMD {:?}",
            sgp_t,
            omd_t
        );
    }
}
