//! **OMD-RT** — the paper's optimal distributed routing algorithm
//! (Algorithm 2): online mirror descent with the exponentiated-gradient
//! update (eq. 22) on each node's out-neighbour simplex.
//!
//! Per iteration, per (session, node) row:
//!
//! ```text
//! φ_ij ← φ_ij · exp(−η · δφ_ij) / Σ_j φ_ij · exp(−η · δφ_ij)
//! ```
//!
//! The update is a softmax — no projection, no QP — which is the source of
//! the paper's ~3-orders-of-magnitude per-iteration runtime advantage over
//! SGP (Fig. 9). The same update can be executed on the XLA hot path via
//! the AOT-compiled L1 Pallas kernel (see [`crate::runtime::mirror`]); this
//! module is the native implementation and the numerical ground truth.

use super::Router;
use crate::engine::{BatchMode, FlowEngine, SessionMask};
use crate::model::flow::Phi;
use crate::model::Problem;

/// Numerical-stability shift: exponents are shifted by the row max before
/// exponentiation (mirrors the L1 kernel's `_MASK_PENALTY` scheme).
const EXP_SHIFT_MIN_SUM: f64 = 1e-300;

/// Per-row trust region: the exponent *span* of one update is capped at
/// this value, bounding the multiplicative change of any lane to `e^±SPAN`
/// per iteration. Without it, the exp cost family's enormous early
/// marginals (`exp(F/C)/C` can exceed e³⁰ on a congested virtual link)
/// drive lanes to exactly 0 in one step — and multiplicative updates can
/// never resurrect a zero lane, freezing OMD at a non-optimal point. This
/// is the practical instantiation of the paper's `η_k ≤ c/L_D` condition
/// (the local gradient scale *is* the Lipschitz constant): the step
/// direction is preserved, only its magnitude is clamped. The L1 Pallas
/// kernel applies the identical rule (see `mirror_step.py`).
pub const MAX_EXP_SPAN: f64 = 40.0;

/// Interior floor: after each update every live lane keeps at least this
/// fraction of the row's mass. Mirror descent's convergence theory assumes
/// iterates stay in the simplex *interior* (the Bregman divergence to the
/// optimum must stay finite); numerically, a lane that underflows to ~0 can
/// take arbitrarily many iterations to revive, and the `φ^{k+1} == φ^k`
/// stop then fires at a non-optimal fixed point. A 1e-12 floor is far below
/// any cost-relevant flow yet keeps every lane one good gradient away from
/// revival. Identical constant in the L1 kernel.
pub const PHI_FLOOR: f64 = 1e-12;

#[derive(Clone, Debug)]
pub struct OmdRouter {
    /// Base mirror-descent step size η (paper: constant `η_k ≤ c/L_D`).
    pub eta: f64,
    /// Backtracking adaptation (default on): `L_D` is unknown in practice,
    /// so the `η_k ≤ c/L_D` condition is enforced by feedback — halve η
    /// whenever the observed total cost *increased* since the previous
    /// iteration, creep back up (×1.05, capped at the base η) while it
    /// decreases. The cost signal is already available at every node scale
    /// (the leader aggregates it alongside the marginal broadcast).
    pub adaptive: bool,
    eta_cur: f64,
    last_cost: Option<f64>,
    k: usize,
    engine: FlowEngine,
    scratch_row: Vec<f64>,
    scratch_delta: Vec<f64>,
}

impl OmdRouter {
    pub fn new(eta: f64) -> Self {
        OmdRouter {
            eta,
            adaptive: true,
            eta_cur: eta,
            last_cost: None,
            k: 0,
            engine: FlowEngine::new(),
            scratch_row: Vec::new(),
            scratch_delta: Vec::new(),
        }
    }

    /// Fixed-step variant (theory experiments; requires η ≤ c/L_D).
    pub fn fixed(eta: f64) -> Self {
        OmdRouter { adaptive: false, ..Self::new(eta) }
    }

    /// Worker threads for the engine's per-session sweeps (`0` = auto).
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.engine.set_workers(workers);
        self
    }

    /// The engine evaluating this router's iterations (e.g. to share it
    /// for a post-step cost evaluation without a second workspace set).
    pub fn engine_mut(&mut self) -> &mut FlowEngine {
        &mut self.engine
    }

    /// The η the *next* update will use.
    pub fn current_eta(&self) -> f64 {
        self.eta_cur
    }

    /// Shared backtracking rule (also used verbatim by the distributed
    /// leader so both implementations stay in lockstep).
    pub fn adapt_eta(eta_cur: f64, eta_base: f64, last_cost: Option<f64>, cost: f64) -> f64 {
        match last_cost {
            Some(lc) if cost > lc * (1.0 + 1e-12) => (eta_cur * 0.5).max(1e-9),
            Some(_) => (eta_cur * 1.05).min(eta_base),
            None => eta_cur,
        }
    }

    /// The eq. (22) update for one row, in place. Exposed for reuse by the
    /// coordinator actors (each node runs exactly this on its own state).
    pub fn update_row(phi_row: &mut [f64], delta: &[f64], eta: f64) {
        debug_assert_eq!(phi_row.len(), delta.len());
        let (mut zmax, mut zmin) = (f64::NEG_INFINITY, f64::INFINITY);
        for (&d, &p) in delta.iter().zip(phi_row.iter()) {
            if p > 0.0 {
                let z = -eta * d;
                zmax = zmax.max(z);
                zmin = zmin.min(z);
            }
        }
        if !zmax.is_finite() {
            return; // empty row
        }
        let span = zmax - zmin;
        let scale = if span > MAX_EXP_SPAN { MAX_EXP_SPAN / span } else { 1.0 };
        let mut sum = 0.0;
        for (p, &d) in phi_row.iter_mut().zip(delta) {
            *p *= ((-eta * d - zmax) * scale).exp();
            sum += *p;
        }
        if sum > EXP_SHIFT_MIN_SUM {
            for p in phi_row.iter_mut() {
                *p /= sum;
            }
            // interior floor + renormalize (see PHI_FLOOR)
            let mut sum2 = 0.0;
            for p in phi_row.iter_mut() {
                if *p > 0.0 && *p < PHI_FLOOR {
                    *p = PHI_FLOOR;
                }
                sum2 += *p;
            }
            for p in phi_row.iter_mut() {
                *p /= sum2;
            }
        }
    }
}

impl OmdRouter {
    /// The shared iteration body: evaluate (fully or via the engine's
    /// dirty delta path — bit-identical), adapt η, and run the eq. 22 row
    /// updates.
    fn step_impl(
        &mut self,
        problem: &Problem,
        lam: &[f64],
        phi: &mut Phi,
        dirty: Option<&SessionMask>,
    ) -> f64 {
        let net = &problem.net;
        // fused forward + reverse sweep: t, F, cost, D', r in two passes
        // (the delta path re-sweeps only the dirty sessions)
        let cost_before = match dirty {
            Some(mask) => self.engine.prepare_dirty(problem, phi, lam, mask),
            None => self.engine.prepare(problem, phi, lam),
        };

        if self.adaptive {
            self.eta_cur = Self::adapt_eta(self.eta_cur, self.eta, self.last_cost, cost_before);
        }
        self.last_cost = Some(cost_before);
        let eta = self.eta_cur;
        self.k += 1;
        // scratch buffers live on self: zero allocations in the hot loop
        let mut row = std::mem::take(&mut self.scratch_row);
        let mut delta = std::mem::take(&mut self.scratch_delta);
        let csr = &net.csr;
        for w in 0..net.n_sessions() {
            let frac = &mut phi.frac[w];
            for r in csr.rows(w) {
                if r.len() < 2 {
                    continue; // single lane is pinned at 1
                }
                // Algorithm 2 line 5: only nodes with t_i(w) > 0 update.
                if self.engine.node_rate(w, r.node) <= 0.0 {
                    continue;
                }
                row.clear();
                delta.clear();
                for k in r.start..r.end {
                    row.push(frac[csr.lane_edge[k]]);
                    delta.push(self.engine.lane_delta(csr, w, k));
                }
                Self::update_row(&mut row, &delta, eta);
                for (k, &v) in (r.start..r.end).zip(&row) {
                    frac[csr.lane_edge[k]] = v;
                }
            }
        }
        self.scratch_row = row;
        self.scratch_delta = delta;
        cost_before
    }
}

impl Router for OmdRouter {
    fn name(&self) -> &'static str {
        "OMD-RT"
    }

    fn set_workers(&mut self, workers: usize) {
        self.engine.set_workers(workers);
    }

    fn set_batch_mode(&mut self, mode: BatchMode) {
        self.engine.set_batch_mode(mode);
    }

    fn step(&mut self, problem: &Problem, lam: &[f64], phi: &mut Phi) -> f64 {
        self.step_impl(problem, lam, phi, None)
    }

    /// One iteration whose pre-update evaluation re-sweeps only the dirty
    /// sessions — the single-step oracle's path for GS-OMA/OMAD probes
    /// that change one class block's `λ` between observations.
    /// Bit-identical to [`Router::step`].
    fn step_dirty(
        &mut self,
        problem: &Problem,
        lam: &[f64],
        phi: &mut Phi,
        dirty: &SessionMask,
    ) -> f64 {
        self.step_impl(problem, lam, phi, Some(dirty))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topologies;
    use crate::model::cost::CostKind;
    use crate::model::flow;
    use crate::routing::marginal;
    use crate::util::rng::Rng;

    fn problem(seed: u64, n: usize) -> Problem {
        let mut rng = Rng::seed_from(seed);
        let net = topologies::connected_er(n, 0.3, 3, &mut rng);
        Problem::new(net, 60.0, CostKind::Exp)
    }

    #[test]
    fn update_row_moves_to_cheap_lane() {
        let mut row = vec![0.5, 0.5];
        OmdRouter::update_row(&mut row, &[0.0, 10.0], 1.0);
        assert!(row[0] > 0.99);
        assert!((row.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn update_row_zero_eta_identity() {
        let mut row = vec![0.3, 0.7];
        OmdRouter::update_row(&mut row, &[5.0, 1.0], 0.0);
        assert!((row[0] - 0.3).abs() < 1e-12 && (row[1] - 0.7).abs() < 1e-12);
    }

    #[test]
    fn monotone_descent() {
        // Theorem 4's eq. (67): cost never increases for small enough η —
        // the per-iteration series comes from a streaming run's Trajectory
        // (solve() reports only the final objective now)
        let p = problem(1, 12);
        let mut traj = crate::session::Trajectory::default();
        let report = crate::session::RoutingRun::new(
            &p,
            Box::new(OmdRouter::new(0.05)),
            p.uniform_allocation(),
            60,
        )
        .observe(&mut traj)
        .finish();
        for w in traj.values.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "cost increased: {} -> {}", w[0], w[1]);
        }
        assert!(report.objective < traj.values[0]);
    }

    #[test]
    fn feasibility_preserved() {
        let p = problem(2, 10);
        let lam = p.uniform_allocation();
        let mut router = OmdRouter::new(0.3);
        let sol = router.solve(&p, &lam, 100);
        sol.phi.unwrap().is_feasible(&p.net, 1e-9).unwrap();
    }

    #[test]
    fn stationarity_at_convergence() {
        // Theorem 3 / eq. (17): on the support, marginals equalize.
        let p = problem(3, 8);
        let lam = p.uniform_allocation();
        let mut router = OmdRouter::new(0.5);
        let sol = router.solve(&p, &lam, 3000);
        let phi = sol.phi.unwrap();
        let t = flow::node_rates(&p.net, &phi, &lam);
        let flows = flow::edge_flows(&p.net, &phi, &t);
        let m = marginal::compute(&p, &phi, &flows);
        for w in 0..p.n_versions() {
            for &i in p.net.session_routers(w) {
                if t[w][i] < 1e-6 {
                    continue;
                }
                let vals: Vec<f64> = p
                    .net
                    .session_out(w, i)
                    .filter(|&e| phi.frac[w][e] > 1e-4)
                    .map(|e| m.delta(&p.net, w, e))
                    .collect();
                if vals.len() < 2 {
                    continue;
                }
                let spread = vals.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
                    - vals.iter().cloned().fold(f64::INFINITY, f64::min);
                let scale = vals.iter().cloned().fold(f64::NEG_INFINITY, f64::max).max(1.0);
                assert!(spread < 0.02 * scale, "w={w} i={i} spread={spread} vals={vals:?}");
            }
        }
    }

    #[test]
    fn solve_converges_and_stops_early() {
        let p = problem(4, 10);
        let lam = p.uniform_allocation();
        let mut router = OmdRouter::new(0.5);
        let sol = router.solve(&p, &lam, 100_000);
        assert!(sol.iterations < 100_000, "did not converge early");
        assert_eq!(sol.stop, crate::session::StopReason::Converged);
    }

    #[test]
    fn warm_start_resumes() {
        let p = problem(5, 10);
        let lam = p.uniform_allocation();
        let mut r1 = OmdRouter::new(0.3);
        let mut phi = Phi::uniform(&p.net);
        let a = r1.solve_from(&p, &lam, &mut phi, 10);
        let b = r1.solve_from(&p, &lam, &mut phi, 10);
        assert!(b.objective <= a.objective + 1e-9);
    }
}
