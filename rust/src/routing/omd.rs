//! **OMD-RT** — the paper's optimal distributed routing algorithm
//! (Algorithm 2): online mirror descent with the exponentiated-gradient
//! update (eq. 22) on each node's out-neighbour simplex.
//!
//! Per iteration, per (session, node) row:
//!
//! ```text
//! φ_ij ← φ_ij · exp(−η · δφ_ij) / Σ_j φ_ij · exp(−η · δφ_ij)
//! ```
//!
//! The update is a softmax — no projection, no QP — which is the source of
//! the paper's ~3-orders-of-magnitude per-iteration runtime advantage over
//! SGP (Fig. 9). The same update can be executed on the XLA hot path via
//! the AOT-compiled L1 Pallas kernel (see [`crate::runtime::mirror`]); this
//! module is the native implementation and the numerical ground truth.
//!
//! ## Row-sparse updates
//!
//! [`Router::step`]/[`Router::step_dirty`] are **row-sparse**: the scatter
//! back into `φ` is write-compare (only bitwise-changed lanes are stored),
//! the set of sessions whose rows actually moved is emitted as a
//! [`SessionMask`] ([`Router::touched_sessions`]), and three
//! exactness-preserving skips cut per-iteration work once descent settles:
//!
//! * **identity fast path** — [`OmdRouter::update_row`] returns untouched
//!   when every live multiplier rounds to exactly 1.0 and the row is
//!   already normalized above the interior floor (bit-exact by
//!   construction, see the guard chain there);
//! * **memo skip** — a session whose previous update changed nothing is
//!   skipped outright when η is unchanged and the engine attests
//!   ([`FlowEngine::session_delta_clean`]) that every input of its update
//!   is bitwise unchanged (exact by induction: unchanged inputs ⇒ the
//!   recomputation would reproduce the unchanged rows bit for bit);
//! * **threshold skip** (opt-in, [`OmdRouter::sparse_tol`] `> 0`) — a row
//!   whose η-scaled live-lane marginal span is below the tolerance is
//!   left in place, bounding the per-step deviation from the dense step
//!   by O(tol) per row. Default **off**: with `sparse_tol == 0` the
//!   router is *bit-identical* to the dense step.
//!
//! The touched set also closes the incremental loop around the engine:
//! the pre-update [`FlowEngine::prepare_dirty`] unions the caller's dirty
//! mask with the rows the router itself changed since its engine's last
//! sweep, and [`OmdRouter::post_step_cost`] re-syncs the engine O(touched)
//! after the update — so a warmed GS-OMA/OMAD probe loop runs
//! O(touched ∪ probe block) end to end (benched by the
//! `clusters40/omd_probe_loop_{dense,sparse}` rows in
//! `benches/hotpath.rs`).

use super::Router;
use crate::engine::{BatchMode, FlowEngine, SessionMask};
use crate::model::flow::Phi;
use crate::model::Problem;

/// Numerical-stability shift: exponents are shifted by the row max before
/// exponentiation (mirrors the L1 kernel's `_MASK_PENALTY` scheme).
const EXP_SHIFT_MIN_SUM: f64 = 1e-300;

/// Per-row trust region: the exponent *span* of one update is capped at
/// this value, bounding the multiplicative change of any lane to `e^±SPAN`
/// per iteration. Without it, the exp cost family's enormous early
/// marginals (`exp(F/C)/C` can exceed e³⁰ on a congested virtual link)
/// drive lanes to exactly 0 in one step — and multiplicative updates can
/// never resurrect a zero lane, freezing OMD at a non-optimal point. This
/// is the practical instantiation of the paper's `η_k ≤ c/L_D` condition
/// (the local gradient scale *is* the Lipschitz constant): the step
/// direction is preserved, only its magnitude is clamped. The L1 Pallas
/// kernel applies the identical rule (see `mirror_step.py`).
pub const MAX_EXP_SPAN: f64 = 40.0;

/// Interior floor: after each update every live lane keeps at least this
/// fraction of the row's mass. Mirror descent's convergence theory assumes
/// iterates stay in the simplex *interior* (the Bregman divergence to the
/// optimum must stay finite); numerically, a lane that underflows to ~0 can
/// take arbitrarily many iterations to revive, and the `φ^{k+1} == φ^k`
/// stop then fires at a non-optimal fixed point. A 1e-12 floor is far below
/// any cost-relevant flow yet keeps every lane one good gradient away from
/// revival. Identical constant in the L1 kernel.
pub const PHI_FLOOR: f64 = 1e-12;

/// Converged-row identity fast path threshold (see
/// [`OmdRouter::update_row`]): an exponent span this far below one ulp at
/// 1.0 (2⁻⁵³ ≈ 1.1e-16) makes every row-max-shifted multiplier round to
/// exactly 1.0 under any faithful `exp`.
const EXP_IDENTITY_SPAN: f64 = 1e-17;

#[derive(Clone, Debug)]
pub struct OmdRouter {
    /// Base mirror-descent step size η (paper: constant `η_k ≤ c/L_D`).
    pub eta: f64,
    /// Backtracking adaptation (default on): `L_D` is unknown in practice,
    /// so the `η_k ≤ c/L_D` condition is enforced by feedback — halve η
    /// whenever the observed total cost *increased* since the previous
    /// iteration, creep back up (×1.05, capped at the base η) while it
    /// decreases. The cost signal is already available at every node scale
    /// (the leader aggregates it alongside the marginal broadcast).
    pub adaptive: bool,
    /// Opt-in threshold skip for the row-sparse step (see the module
    /// docs): a row is left untouched when the η-scaled marginal span
    /// over its live lanes is below this tolerance, bounding the per-step
    /// deviation from the dense update by O(`sparse_tol`) per row.
    /// Default `0.0` — **off**, every result bit-identical to the dense
    /// step. The probe-loop bench arms it at `1e-12`.
    pub sparse_tol: f64,
    eta_cur: f64,
    last_cost: Option<f64>,
    /// η of the previous step (bitwise), for the memo skip's "same step
    /// size" precondition.
    prev_eta: Option<f64>,
    k: usize,
    engine: FlowEngine,
    /// `row_fixed[w]`: the last computed update of session `w` left every
    /// one of its rows bitwise unchanged (the memo-skip attestation on
    /// the router side; the engine side is `session_delta_clean`).
    row_fixed: Vec<bool>,
    /// Sessions whose rows the last step changed (bitwise) — surfaced as
    /// [`Router::touched_sessions`] and consumed by
    /// [`OmdRouter::post_step_cost`].
    last_touched: Option<SessionMask>,
    /// Rows this router changed *after* its engine's last sweep. The next
    /// dirty step unions these into the engine mask, so callers only ever
    /// promise what *they* changed; cleared whenever `post_step_cost`
    /// re-syncs the engine at the post-update `φ`.
    pending_phi: Option<SessionMask>,
    scratch_row: Vec<f64>,
    scratch_delta: Vec<f64>,
}

impl OmdRouter {
    pub fn new(eta: f64) -> Self {
        OmdRouter {
            eta,
            adaptive: true,
            sparse_tol: 0.0,
            eta_cur: eta,
            last_cost: None,
            prev_eta: None,
            k: 0,
            engine: FlowEngine::new(),
            row_fixed: Vec::new(),
            last_touched: None,
            pending_phi: None,
            scratch_row: Vec::new(),
            scratch_delta: Vec::new(),
        }
    }

    /// Fixed-step variant (theory experiments; requires η ≤ c/L_D).
    pub fn fixed(eta: f64) -> Self {
        OmdRouter { adaptive: false, ..Self::new(eta) }
    }

    /// Worker threads for the engine's per-session sweeps (`0` = auto).
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.engine.set_workers(workers);
        self
    }

    /// The engine evaluating this router's iterations (e.g. to share it
    /// for a post-step cost evaluation without a second workspace set).
    pub fn engine_mut(&mut self) -> &mut FlowEngine {
        &mut self.engine
    }

    /// The η the *next* update will use.
    pub fn current_eta(&self) -> f64 {
        self.eta_cur
    }

    /// Shared backtracking rule (also used verbatim by the distributed
    /// leader so both implementations stay in lockstep).
    pub fn adapt_eta(eta_cur: f64, eta_base: f64, last_cost: Option<f64>, cost: f64) -> f64 {
        match last_cost {
            Some(lc) if cost > lc * (1.0 + 1e-12) => (eta_cur * 0.5).max(1e-9),
            Some(_) => (eta_cur * 1.05).min(eta_base),
            None => eta_cur,
        }
    }

    /// The eq. (22) update for one row, in place. Exposed for reuse by the
    /// coordinator actors (each node runs exactly this on its own state).
    pub fn update_row(phi_row: &mut [f64], delta: &[f64], eta: f64) {
        debug_assert_eq!(phi_row.len(), delta.len());
        let (mut zmax, mut zmin) = (f64::NEG_INFINITY, f64::INFINITY);
        for (&d, &p) in delta.iter().zip(phi_row.iter()) {
            if p > 0.0 {
                let z = -eta * d;
                zmax = zmax.max(z);
                zmin = zmin.min(z);
            }
        }
        if !zmax.is_finite() {
            return; // empty row
        }
        let span = zmax - zmin;
        // Converged-row identity fast path: when the support's exponents
        // agree to within ≪ one ulp at 1.0, every row-max-shifted
        // multiplier `exp((z − zmax)·scale)` rounds to exactly 1.0 — the
        // guard verifies that on the extreme argument rather than assume
        // it (glibc's exp is correctly rounded; any monotone faithful exp
        // then agrees on the interior arguments, which sit closer to 0).
        // The full body would multiply every support lane by 1.0, keep
        // zero lanes at zero, and divide twice by the bitwise lane-order
        // sum; if that sum is exactly 1.0 and no lane sits below the
        // interior floor, the body is the identity — return without
        // touching the row so converged rows stay bitwise fixed and the
        // row-sparse step can prove them unchanged. Falls through (and
        // stays exact) whenever any guard fails.
        if span <= EXP_IDENTITY_SPAN
            && (zmin - zmax).exp() == 1.0
            && phi_row.iter().sum::<f64>() == 1.0
            && phi_row.iter().all(|&p| p == 0.0 || p >= PHI_FLOOR)
        {
            return;
        }
        let scale = if span > MAX_EXP_SPAN { MAX_EXP_SPAN / span } else { 1.0 };
        let mut sum = 0.0;
        for (p, &d) in phi_row.iter_mut().zip(delta) {
            *p *= ((-eta * d - zmax) * scale).exp();
            sum += *p;
        }
        if sum > EXP_SHIFT_MIN_SUM {
            for p in phi_row.iter_mut() {
                *p /= sum;
            }
            // interior floor + renormalize (see PHI_FLOOR)
            let mut sum2 = 0.0;
            for p in phi_row.iter_mut() {
                if *p > 0.0 && *p < PHI_FLOOR {
                    *p = PHI_FLOOR;
                }
                sum2 += *p;
            }
            for p in phi_row.iter_mut() {
                *p /= sum2;
            }
        }
    }

    /// Opt-in threshold skip (see [`OmdRouter::sparse_tol`]): `true` when
    /// the η-scaled marginal span over the row's *live* lanes is below
    /// `tol` — the eq. 22 multipliers then agree to within `tol`
    /// relatively, so the normalized update would move the row by O(tol)
    /// — and no floored lane is about to revive (a revival needs its
    /// exponent to top every live lane's, and must never be skipped:
    /// multiplicative revival is exactly what [`PHI_FLOOR`] keeps
    /// possible).
    fn row_update_below_tol(row: &[f64], delta: &[f64], eta: f64, tol: f64) -> bool {
        /// Lanes carrying at most this are "floored": their mass moves
        /// the row by less than any meaningful tolerance, but their
        /// exponents still gate the revival check below.
        const LIVE_EPS: f64 = 1e-9;
        let (mut zlo, mut zhi) = (f64::INFINITY, f64::NEG_INFINITY);
        let mut zall = f64::NEG_INFINITY;
        for (&p, &d) in row.iter().zip(delta) {
            if p > 0.0 {
                let z = -eta * d;
                if z > zall {
                    zall = z;
                }
                if p > LIVE_EPS {
                    if z < zlo {
                        zlo = z;
                    }
                    if z > zhi {
                        zhi = z;
                    }
                }
            }
        }
        zhi.is_finite() && zhi - zlo <= tol && zall <= zhi
    }

    /// Post-update cost at `(Λ, φ)` reusing this router's engine. When
    /// the last step's row updates touched only a few sessions, re-sweep
    /// O(touched) through [`FlowEngine::prepare_dirty`] — which also
    /// re-syncs the marginals, keeping the *next* step's reverse work
    /// incremental — and fall back to the dense forward sweep when the
    /// touched set is large (≥ half the sessions: the dirty re-reduce and
    /// re-broadcast overhead then beats its savings) or untracked.
    /// Bit-identical to `engine_mut().evaluate_cost(..)` at the same
    /// state either way.
    pub fn post_step_cost(&mut self, problem: &Problem, phi: &Phi, lam: &[f64]) -> f64 {
        let n = problem.net.n_sessions();
        let cost = match self.last_touched.take() {
            Some(mask) if mask.len() == n && !mask.is_all() && 2 * mask.count() < n => {
                let c = self.engine.prepare_dirty(problem, phi, lam, &mask);
                self.last_touched = Some(mask);
                c
            }
            other => {
                self.last_touched = other;
                self.engine.evaluate_cost(problem, phi, lam)
            }
        };
        // the engine is now synced at the post-update φ: nothing pending
        self.pending_phi = None;
        cost
    }
}

impl OmdRouter {
    /// The shared iteration body: evaluate (fully or via the engine's
    /// dirty delta path — bit-identical), adapt η, and run the eq. 22 row
    /// updates.
    fn step_impl(
        &mut self,
        problem: &Problem,
        lam: &[f64],
        phi: &mut Phi,
        dirty: Option<&SessionMask>,
    ) -> f64 {
        let net = &problem.net;
        let n_sess = net.n_sessions();
        // fused forward + reverse sweep: t, F, cost, D', r in two passes.
        // The delta path re-sweeps the caller's dirty sessions *unioned
        // with the rows this router itself changed since its engine's
        // last sweep* (pending_phi) — callers only ever promise what
        // *they* changed; the router's own row updates are its to track.
        let cost_before = match dirty {
            Some(mask) => match self.pending_phi.take() {
                Some(mut pending) if pending.len() == mask.len() => {
                    pending.union_with(mask);
                    self.engine.prepare_dirty(problem, phi, lam, &pending)
                }
                // a pending set of the wrong shape means the problem
                // changed under us — the engine's own shape check will
                // force the full sweep, but don't trust the mask either
                Some(_) => self.engine.prepare(problem, phi, lam),
                // no pending rows: post_step_cost already re-synced the
                // engine at the current φ (or this router never stepped,
                // in which case prepare_dirty full-sweeps on its own)
                None => self.engine.prepare_dirty(problem, phi, lam, mask),
            },
            None => {
                self.pending_phi = None;
                self.engine.prepare(problem, phi, lam)
            }
        };

        if self.adaptive {
            self.eta_cur = Self::adapt_eta(self.eta_cur, self.eta, self.last_cost, cost_before);
        }
        self.last_cost = Some(cost_before);
        let eta = self.eta_cur;
        let eta_same = self.prev_eta.is_some_and(|e| e.to_bits() == eta.to_bits());
        self.prev_eta = Some(eta);
        self.k += 1;
        if self.row_fixed.len() != n_sess {
            self.row_fixed = vec![false; n_sess];
        }
        let mut touched = SessionMask::none(n_sess);
        // scratch buffers live on self: zero allocations in the hot loop
        let mut row = std::mem::take(&mut self.scratch_row);
        let mut delta = std::mem::take(&mut self.scratch_delta);
        let csr = &net.csr;
        for w in 0..n_sess {
            // memo skip (exact): the last computed update left every row
            // of w unchanged, η is bitwise the same, and the engine
            // attests that every input of w's update (t(w), D' on its
            // lanes, ∂D/∂r(w)) is bitwise unchanged — recomputing would
            // reproduce the unchanged rows bit for bit.
            if eta_same && self.row_fixed[w] && self.engine.session_delta_clean(w) {
                continue;
            }
            let frac = &mut phi.frac[w];
            let mut changed = false;
            for r in csr.rows(w) {
                if r.len() < 2 {
                    continue; // single lane is pinned at 1
                }
                // Algorithm 2 line 5: only nodes with t_i(w) > 0 update.
                if self.engine.node_rate(w, r.node) <= 0.0 {
                    continue;
                }
                row.clear();
                delta.clear();
                for k in r.start..r.end {
                    row.push(frac[csr.lane_edge[k]]);
                    delta.push(self.engine.lane_delta(csr, w, k));
                }
                if self.sparse_tol > 0.0
                    && Self::row_update_below_tol(&row, &delta, eta, self.sparse_tol)
                {
                    continue;
                }
                Self::update_row(&mut row, &delta, eta);
                // write-compare scatter: store only bitwise-changed lanes
                // and remember whether anything in this session moved
                for (k, &v) in (r.start..r.end).zip(&row) {
                    let dst = &mut frac[csr.lane_edge[k]];
                    if dst.to_bits() != v.to_bits() {
                        *dst = v;
                        changed = true;
                    }
                }
            }
            self.row_fixed[w] = !changed;
            if changed {
                touched.insert(w);
            }
        }
        self.scratch_row = row;
        self.scratch_delta = delta;
        // new memo-skip epoch: the attestations are relative to the
        // engine state this row loop just read
        self.engine.reset_delta_clean();
        self.pending_phi = Some(touched.clone());
        self.last_touched = Some(touched);
        cost_before
    }
}

impl Router for OmdRouter {
    fn name(&self) -> &'static str {
        "OMD-RT"
    }

    fn set_workers(&mut self, workers: usize) {
        self.engine.set_workers(workers);
    }

    fn set_batch_mode(&mut self, mode: BatchMode) {
        self.engine.set_batch_mode(mode);
    }

    fn step(&mut self, problem: &Problem, lam: &[f64], phi: &mut Phi) -> f64 {
        self.step_impl(problem, lam, phi, None)
    }

    /// One iteration whose pre-update evaluation re-sweeps only the dirty
    /// sessions — the single-step oracle's path for GS-OMA/OMAD probes
    /// that change one class block's `λ` between observations.
    /// Bit-identical to [`Router::step`].
    fn step_dirty(
        &mut self,
        problem: &Problem,
        lam: &[f64],
        phi: &mut Phi,
        dirty: &SessionMask,
    ) -> f64 {
        self.step_impl(problem, lam, phi, Some(dirty))
    }

    fn touched_sessions(&self) -> Option<&SessionMask> {
        self.last_touched.as_ref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topologies;
    use crate::model::cost::CostKind;
    use crate::model::flow;
    use crate::routing::marginal;
    use crate::util::rng::Rng;

    fn problem(seed: u64, n: usize) -> Problem {
        let mut rng = Rng::seed_from(seed);
        let net = topologies::connected_er(n, 0.3, 3, &mut rng);
        Problem::new(net, 60.0, CostKind::Exp)
    }

    #[test]
    fn update_row_moves_to_cheap_lane() {
        let mut row = vec![0.5, 0.5];
        OmdRouter::update_row(&mut row, &[0.0, 10.0], 1.0);
        assert!(row[0] > 0.99);
        assert!((row.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn update_row_zero_eta_identity() {
        let mut row = vec![0.3, 0.7];
        OmdRouter::update_row(&mut row, &[5.0, 1.0], 0.0);
        assert!((row[0] - 0.3).abs() < 1e-12 && (row[1] - 0.7).abs() < 1e-12);
    }

    #[test]
    fn monotone_descent() {
        // Theorem 4's eq. (67): cost never increases for small enough η —
        // the per-iteration series comes from a streaming run's Trajectory
        // (solve() reports only the final objective now)
        let p = problem(1, 12);
        let mut traj = crate::session::Trajectory::default();
        let report = crate::session::RoutingRun::new(
            &p,
            Box::new(OmdRouter::new(0.05)),
            p.uniform_allocation(),
            60,
        )
        .observe(&mut traj)
        .finish();
        for w in traj.values.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "cost increased: {} -> {}", w[0], w[1]);
        }
        assert!(report.objective < traj.values[0]);
    }

    #[test]
    fn feasibility_preserved() {
        let p = problem(2, 10);
        let lam = p.uniform_allocation();
        let mut router = OmdRouter::new(0.3);
        let sol = router.solve(&p, &lam, 100);
        sol.phi.unwrap().is_feasible(&p.net, 1e-9).unwrap();
    }

    #[test]
    fn stationarity_at_convergence() {
        // Theorem 3 / eq. (17): on the support, marginals equalize.
        let p = problem(3, 8);
        let lam = p.uniform_allocation();
        let mut router = OmdRouter::new(0.5);
        let sol = router.solve(&p, &lam, 3000);
        let phi = sol.phi.unwrap();
        let t = flow::node_rates(&p.net, &phi, &lam);
        let flows = flow::edge_flows(&p.net, &phi, &t);
        let m = marginal::compute(&p, &phi, &flows);
        for w in 0..p.n_versions() {
            for &i in p.net.session_routers(w) {
                if t[w][i] < 1e-6 {
                    continue;
                }
                let vals: Vec<f64> = p
                    .net
                    .session_out(w, i)
                    .filter(|&e| phi.frac[w][e] > 1e-4)
                    .map(|e| m.delta(&p.net, w, e))
                    .collect();
                if vals.len() < 2 {
                    continue;
                }
                let spread = vals.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
                    - vals.iter().cloned().fold(f64::INFINITY, f64::min);
                let scale = vals.iter().cloned().fold(f64::NEG_INFINITY, f64::max).max(1.0);
                assert!(spread < 0.02 * scale, "w={w} i={i} spread={spread} vals={vals:?}");
            }
        }
    }

    #[test]
    fn solve_converges_and_stops_early() {
        let p = problem(4, 10);
        let lam = p.uniform_allocation();
        let mut router = OmdRouter::new(0.5);
        let sol = router.solve(&p, &lam, 100_000);
        assert!(sol.iterations < 100_000, "did not converge early");
        assert_eq!(sol.stop, crate::session::StopReason::Converged);
    }

    #[test]
    fn warm_start_resumes() {
        let p = problem(5, 10);
        let lam = p.uniform_allocation();
        let mut r1 = OmdRouter::new(0.3);
        let mut phi = Phi::uniform(&p.net);
        let a = r1.solve_from(&p, &lam, &mut phi, 10);
        let b = r1.solve_from(&p, &lam, &mut phi, 10);
        assert!(b.objective <= a.objective + 1e-9);
    }
}
