//! Unified session API: typed scenario construction, the solver registry,
//! and streaming step-driven runs.
//!
//! The paper's contribution is a *cross-layer* optimizer — allocation
//! (GS-OMA / OMAD) and routing (OMD-RT / SGP / GP / OPT) composed over one
//! flow model. This module is the single front door to that machinery:
//!
//! 1. **[`Scenario`]** — a builder describing an experiment (topology,
//!    rates, cost/utility families, hyper-parameters, seed). Validation is
//!    fallible end-to-end: [`Scenario::build`] returns `Result` instead of
//!    panicking deep inside problem construction.
//! 2. **[`Session`]** — a validated scenario with its [`Problem`] instance
//!    built. Owns oracle selection and solver instantiation by name via
//!    the [`registry`].
//! 3. **[`RoutingRun`] / [`AllocationRun`]** — resumable streaming
//!    execution: `step()` advances one iteration, [`run::StopRule`]s decide
//!    termination, [`run::Observer`]s record trajectories and telemetry,
//!    and the result is a unified [`RunReport`].
//!
//! ```no_run
//! use jowr::prelude::*;
//!
//! # fn main() -> Result<(), SessionError> {
//! let session = Scenario::paper_default()
//!     .topology("er")
//!     .utility("log")
//!     .seed(7)
//!     .build()?;
//! let mut traj = Trajectory::default();
//! let report = session.routing_run("omd", 50)?.observe(&mut traj).finish();
//! println!("cost {:.4} -> {:.4} ({:?})", traj.values[0], report.objective, report.stop);
//! # Ok(())
//! # }
//! ```

pub mod error;
pub mod registry;
pub mod run;

pub use error::SessionError;
pub use registry::Hyper;
pub use run::{
    AllocationRun, DistributedRun, RoutingRun, RunReport, StepInfo, StopReason, Trajectory,
};

use crate::allocation::{AnalyticOracle, SingleStepOracle, UtilityOracle};
use crate::allocation::Allocator;
use crate::config::ExperimentConfig;
use crate::model::cost::CostKind;
use crate::model::utility::{family, Utility};
use crate::model::Problem;
use crate::routing::Router;
use crate::util::rng::Rng;

/// Builder for a JOWR experiment scenario. Setters are chainable; nothing
/// is validated until [`Scenario::build`].
#[derive(Clone, Debug)]
pub struct Scenario {
    cfg: ExperimentConfig,
    cost_name: Option<String>,
}

impl Scenario {
    /// The paper's Section-IV defaults: Connected-ER(25, 0.2), λ=60, W=3,
    /// C̄=10, `D_ij = exp(F/C)`, log utilities, seed 42.
    pub fn paper_default() -> Self {
        Scenario { cfg: ExperimentConfig::paper_default(), cost_name: None }
    }

    /// Start from an existing config (e.g. loaded from a JSON file).
    pub fn from_config(cfg: ExperimentConfig) -> Self {
        Scenario { cfg, cost_name: None }
    }

    /// Topology generator: `"er"` or a named topology
    /// (`"abilene"`, `"tree"`, `"fog"`, `"geant"`).
    pub fn topology(mut self, name: &str) -> Self {
        self.cfg.topology = name.to_string();
        self
    }

    /// ER node count (ignored for named topologies).
    pub fn nodes(mut self, n: usize) -> Self {
        self.cfg.n_nodes = n;
        self
    }

    /// ER link probability.
    pub fn link_probability(mut self, p: f64) -> Self {
        self.cfg.p_link = p;
        self
    }

    /// Mean link capacity C̄.
    pub fn capacity(mut self, cap_mean: f64) -> Self {
        self.cfg.cap_mean = cap_mean;
        self
    }

    /// Number of DNN versions W.
    pub fn versions(mut self, w: usize) -> Self {
        self.cfg.n_versions = w;
        self
    }

    /// Total task input rate λ.
    pub fn rate(mut self, total: f64) -> Self {
        self.cfg.total_rate = total;
        self
    }

    /// Link cost family (typed).
    pub fn cost(mut self, kind: CostKind) -> Self {
        self.cfg.cost = kind;
        self.cost_name = None;
        self
    }

    /// Link cost family by name (`"exp"`, `"queue"`, `"linear"`,
    /// `"cubic"`); validated at [`Scenario::build`].
    pub fn cost_named(mut self, name: &str) -> Self {
        self.cost_name = Some(name.to_string());
        self
    }

    /// Utility family by name (`"linear"`, `"sqrt"`, `"quadratic"`,
    /// `"log"`); validated at [`Scenario::build`].
    pub fn utility(mut self, name: &str) -> Self {
        self.cfg.utility = name.to_string();
        self
    }

    /// OMD-RT step size η.
    pub fn eta_routing(mut self, eta: f64) -> Self {
        self.cfg.eta_routing = eta;
        self
    }

    /// Allocation step size.
    pub fn eta_alloc(mut self, eta: f64) -> Self {
        self.cfg.eta_alloc = eta;
        self
    }

    /// Gradient-sampling disturbance δ.
    pub fn delta(mut self, delta: f64) -> Self {
        self.cfg.delta = delta;
        self
    }

    /// RNG seed for topology generation and placements.
    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    /// Engine worker threads for the per-session flow/marginal sweeps
    /// (`0` = auto-detect, `1` = single-threaded default). Solver results
    /// are bit-identical at any worker count.
    pub fn workers(mut self, workers: usize) -> Self {
        self.cfg.workers = workers;
        self
    }

    /// Validate every field and build the problem instance.
    pub fn build(mut self) -> Result<Session, SessionError> {
        if let Some(name) = &self.cost_name {
            self.cfg.cost = CostKind::parse(name)
                .ok_or_else(|| SessionError::UnknownCost { name: name.clone() })?;
        }
        let cfg = self.cfg;
        if cfg.n_versions == 0 {
            return Err(invalid("n_versions must be >= 1"));
        }
        if !(cfg.total_rate > 0.0) {
            return Err(invalid(&format!("total_rate must be > 0 (got {})", cfg.total_rate)));
        }
        if !(cfg.cap_mean > 0.0) {
            return Err(invalid(&format!("cap_mean must be > 0 (got {})", cfg.cap_mean)));
        }
        if cfg.topology == "er" {
            if cfg.n_nodes < 2 {
                return Err(invalid(&format!("ER topology needs >= 2 nodes (got {})", cfg.n_nodes)));
            }
            if !(cfg.p_link > 0.0 && cfg.p_link <= 1.0) {
                return Err(invalid(&format!("p_link must be in (0, 1] (got {})", cfg.p_link)));
            }
        }
        if !(cfg.eta_routing > 0.0) {
            return Err(invalid(&format!("eta_routing must be > 0 (got {})", cfg.eta_routing)));
        }
        if !(cfg.eta_alloc > 0.0) {
            return Err(invalid(&format!("eta_alloc must be > 0 (got {})", cfg.eta_alloc)));
        }
        // the allocation projection onto [δ, λ−δ]^W requires W·δ ≤ λ
        if !(cfg.delta > 0.0 && cfg.n_versions as f64 * cfg.delta <= cfg.total_rate) {
            return Err(invalid(&format!(
                "delta must satisfy 0 < n_versions*delta <= total_rate (delta {}, W {}, rate {})",
                cfg.delta, cfg.n_versions, cfg.total_rate
            )));
        }
        // utility families are consumed lazily by allocation runs, but an
        // unknown name should fail loudly here, not mid-experiment
        family(&cfg.utility, cfg.n_versions, cfg.total_rate)
            .ok_or_else(|| SessionError::UnknownUtility { name: cfg.utility.clone() })?;
        let mut rng = Rng::seed_from(cfg.seed);
        let problem = cfg.build_problem(&mut rng)?;
        Ok(Session { cfg, problem })
    }
}

fn invalid(what: &str) -> SessionError {
    SessionError::InvalidScenario { what: what.to_string() }
}

/// A validated scenario with its problem instance built: the factory for
/// solvers, oracles, and streaming runs.
#[derive(Clone, Debug)]
pub struct Session {
    pub cfg: ExperimentConfig,
    pub problem: Problem,
}

impl Session {
    /// Hyper-parameters derived from this session's config.
    pub fn hyper(&self) -> Hyper {
        Hyper::from_config(&self.cfg)
    }

    /// The paper's allocation initializer `Λ¹ = (λ/W)·1`.
    pub fn uniform_allocation(&self) -> Vec<f64> {
        self.problem.uniform_allocation()
    }

    /// The (hidden) ground-truth utility functions for this scenario.
    pub fn utilities(&self) -> Result<Vec<Utility>, SessionError> {
        family(&self.cfg.utility, self.cfg.n_versions, self.cfg.total_rate)
            .ok_or_else(|| SessionError::UnknownUtility { name: self.cfg.utility.clone() })
    }

    /// Instantiate a router by registry name with this session's
    /// hyper-parameters.
    pub fn router(&self, name: &str) -> Result<Box<dyn Router>, SessionError> {
        registry::router_with(name, &self.hyper())
    }

    /// Instantiate an allocator by registry name with this session's
    /// hyper-parameters.
    pub fn allocator(&self, name: &str) -> Result<Box<dyn Allocator>, SessionError> {
        registry::allocator_with(name, &self.hyper())
    }

    /// The utility oracle matching an allocator: single-loop algorithms get
    /// the persistent single-step oracle (`K = 1` routing per observation),
    /// nested-loop algorithms the run-to-convergence oracle.
    pub fn oracle_for(&self, allocator: &str) -> Result<Box<dyn UtilityOracle>, SessionError> {
        let entry = registry::allocator_entry(allocator)
            .ok_or_else(|| SessionError::UnknownAllocator { name: allocator.to_string() })?;
        let utilities = self.utilities()?;
        if entry.single_loop {
            let mut oracle =
                SingleStepOracle::new(self.problem.clone(), utilities, self.cfg.eta_routing);
            // the persistent routing state advances on the shared engine;
            // thread the session's worker knob through
            oracle.router.set_workers(self.cfg.workers);
            Ok(Box::new(oracle))
        } else {
            let mut oracle = AnalyticOracle::new(self.problem.clone(), utilities);
            oracle.router_eta = self.cfg.eta_routing;
            oracle.workers = self.cfg.workers;
            Ok(Box::new(oracle))
        }
    }

    /// A streaming routing run of `algo` on the uniform allocation, with
    /// the legacy convergence tolerance and an iteration budget. The
    /// session's `workers` knob is threaded into the run's final-report
    /// engine and the router's per-iteration sweeps.
    pub fn routing_run(
        &self,
        algo: &str,
        max_iters: usize,
    ) -> Result<RoutingRun<'_>, SessionError> {
        Ok(RoutingRun::new(
            &self.problem,
            self.router(algo)?,
            self.uniform_allocation(),
            max_iters,
        )
        .engine_workers(self.cfg.workers))
    }

    /// A streaming distributed routing run (paper Sec. V): the
    /// `"distributed-omd"` registry solver — one step = one barriered
    /// round over live node actors — driven through the same `RunCore`
    /// protocol as every centralized run. The final
    /// [`RunReport::comm`] carries the
    /// [`crate::coordinator::net::CommStats`] telemetry.
    pub fn distributed_run(&self, rounds: usize) -> Result<DistributedRun<'_>, SessionError> {
        self.routing_run("distributed-omd", rounds)
    }

    /// A streaming allocation run of `algo` with its matching oracle, from
    /// the uniform initializer.
    pub fn allocation_run<'o>(
        &self,
        algo: &str,
        max_outer: usize,
    ) -> Result<AllocationRun<'o>, SessionError> {
        // full feasibility of the projection box [δ, λ−δ]^W: the lower
        // bound needs W·δ ≤ λ (checked at build), the upper needs
        // λ ≤ W·(λ−δ) — which rules out W = 1 for any δ > 0
        let (w, total, delta) = (self.cfg.n_versions as f64, self.cfg.total_rate, self.cfg.delta);
        if total > w * (total - delta) {
            let what = format!(
                "allocation domain is infeasible: delta {delta}, W {w}, rate {total} \
                 violate rate <= W*(rate - delta); reduce delta or add versions"
            );
            return Err(SessionError::InvalidScenario { what });
        }
        Ok(AllocationRun::new(self.allocator(algo)?, self.oracle_for(algo)?, max_outer))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_default_builds() {
        let s = Scenario::paper_default().build().unwrap();
        assert_eq!(s.problem.net.n_real, 25);
        assert_eq!(s.cfg.n_versions, 3);
    }

    #[test]
    fn unknown_names_fail_at_build() {
        assert!(matches!(
            Scenario::paper_default().topology("moebius").build(),
            Err(SessionError::UnknownTopology { .. })
        ));
        assert!(matches!(
            Scenario::paper_default().utility("cosine").build(),
            Err(SessionError::UnknownUtility { .. })
        ));
        assert!(matches!(
            Scenario::paper_default().cost_named("tanh").build(),
            Err(SessionError::UnknownCost { .. })
        ));
    }

    #[test]
    fn invalid_parameters_fail_at_build() {
        assert!(Scenario::paper_default().versions(0).build().is_err());
        assert!(Scenario::paper_default().rate(0.0).build().is_err());
        assert!(Scenario::paper_default().rate(f64::NAN).build().is_err());
        assert!(Scenario::paper_default().link_probability(0.0).build().is_err());
        assert!(Scenario::paper_default().link_probability(1.5).build().is_err());
        assert!(Scenario::paper_default().nodes(1).build().is_err());
        assert!(Scenario::paper_default().eta_routing(0.0).build().is_err());
        assert!(Scenario::paper_default().delta(1e9).build().is_err());
    }

    #[test]
    fn allocation_feasibility_is_enforced() {
        // W·δ > λ fails at build (the projection's lower-bound condition)
        assert!(Scenario::paper_default().delta(25.0).build().is_err());
        // routing-only W=1 sessions build, but allocation runs are
        // rejected (λ ≤ W·(λ−δ) cannot hold for W=1, δ>0)
        let s = Scenario::paper_default().versions(1).build().unwrap();
        assert!(s.routing_run("omd", 3).is_ok());
        assert!(s.allocation_run("omad", 3).is_err());
    }

    #[test]
    fn cost_named_is_applied() {
        let s = Scenario::paper_default().cost_named("queue").build().unwrap();
        assert_eq!(s.cfg.cost, CostKind::Queue);
    }

    #[test]
    fn named_topology_builds() {
        let s = Scenario::paper_default().topology("abilene").capacity(15.0).build().unwrap();
        assert_eq!(s.problem.net.n_real, 11);
    }

    #[test]
    fn session_construction_is_seed_deterministic() {
        let a = Scenario::paper_default().seed(9).build().unwrap();
        let b = Scenario::paper_default().seed(9).build().unwrap();
        assert_eq!(a.problem.net.graph.n_edges(), b.problem.net.graph.n_edges());
    }
}
