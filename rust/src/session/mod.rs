//! Unified session API: typed scenario construction, the solver registry,
//! and streaming step-driven runs.
//!
//! The paper's contribution is a *cross-layer* optimizer — allocation
//! (GS-OMA / OMAD) and routing (OMD-RT / SGP / GP / OPT) composed over one
//! flow model. This module is the single front door to that machinery:
//!
//! 1. **[`spec::ScenarioSpec`]** — the declarative scenario: heterogeneous
//!    node capacities, explicit or generated edge lists (with per-edge
//!    cost families), and a list of task classes — each with its own
//!    source set, rate (constant or trace), and utility family. Specs
//!    round-trip through JSON (`--scenario file.json`).
//! 2. **[`Scenario`]** — the ergonomic builder (scalar knobs + class/node
//!    sugar) that lowers into a spec. Validation is fallible end-to-end:
//!    [`Scenario::build`] returns `Result` instead of panicking deep
//!    inside problem construction.
//! 3. **[`Session`]** — a validated spec with its [`Problem`] instance
//!    built. Owns oracle selection and solver instantiation by name via
//!    the [`registry`].
//! 4. **[`RoutingRun`] / [`AllocationRun`]** — resumable streaming
//!    execution: `step()` advances one iteration, [`run::StopRule`]s decide
//!    termination, [`run::Observer`]s record trajectories and telemetry,
//!    and the result is a unified [`RunReport`].
//! 5. **[`suite::Suite`]** — a `(scenario × solver × seed)` grid executed
//!    in parallel on the engine worker pool, streaming `RunReport`s into a
//!    [`suite::SuiteReport`].
//!
//! ```no_run
//! use jowr::prelude::*;
//!
//! # fn main() -> Result<(), SessionError> {
//! let session = Scenario::paper_default()
//!     .topology("er")
//!     .utility("log")
//!     .seed(7)
//!     .build()?;
//! let mut traj = Trajectory::default();
//! let report = session.routing_run("omd", 50)?.observe(&mut traj).finish();
//! println!("cost {:.4} -> {:.4} ({:?})", traj.values[0], report.objective, report.stop);
//! # Ok(())
//! # }
//! ```

pub mod error;
pub mod registry;
pub mod run;
pub mod spec;
pub mod suite;

pub use error::SessionError;
pub use registry::Hyper;
pub use run::{
    AllocationRun, DistributedRun, RoutingRun, RunReport, SimRun, StepInfo, StopReason,
    Trajectory,
};
pub use spec::ScenarioSpec;
pub use suite::{Suite, SuiteReport};

use crate::allocation::Allocator;
use crate::allocation::{AnalyticOracle, SingleStepOracle, UtilityOracle};
use crate::config::ExperimentConfig;
use crate::coordinator::events::EventSchedule;
use crate::model::cost::CostKind;
use crate::model::utility::{family, Utility};
use crate::model::Problem;
use crate::routing::Router;
use crate::sim::{ArrivalTrace, Simulator};
use spec::{ClassSpec, NodeSpec, RateSpec};

/// Builder for a JOWR experiment scenario: the paper's scalar knobs plus
/// sugar for heterogeneous nodes and multi-class workloads. Setters are
/// chainable; nothing is validated until [`Scenario::build`], which lowers
/// the builder into a [`ScenarioSpec`] (see [`Scenario::into_spec`]).
#[derive(Clone, Debug)]
pub struct Scenario {
    cfg: ExperimentConfig,
    cost_name: Option<String>,
    classes: Vec<ClassSpec>,
    nodes: Vec<NodeSpec>,
    horizon: Option<usize>,
    shards: Option<usize>,
    staleness: Option<usize>,
}

impl Scenario {
    /// The paper's Section-IV defaults: Connected-ER(25, 0.2), λ=60, W=3,
    /// C̄=10, `D_ij = exp(F/C)`, log utilities, seed 42.
    pub fn paper_default() -> Self {
        Self::from_config(ExperimentConfig::paper_default())
    }

    /// Start from an existing config (e.g. loaded from a JSON file). The
    /// lowering into the spec is lossless: every config field lands in the
    /// spec (unknown *file* fields are warned about by
    /// `ExperimentConfig::from_json` itself).
    pub fn from_config(cfg: ExperimentConfig) -> Self {
        Scenario {
            cfg,
            cost_name: None,
            classes: Vec::new(),
            nodes: Vec::new(),
            horizon: None,
            shards: None,
            staleness: None,
        }
    }

    /// Topology generator: `"er"` or a named topology
    /// (`"abilene"`, `"tree"`, `"fog"`, `"geant"`, `"line"`, `"star"`).
    pub fn topology(mut self, name: &str) -> Self {
        self.cfg.topology = name.to_string();
        self
    }

    /// ER node count (ignored for named topologies).
    pub fn nodes(mut self, n: usize) -> Self {
        self.cfg.n_nodes = n;
        self
    }

    /// ER link probability.
    pub fn link_probability(mut self, p: f64) -> Self {
        self.cfg.p_link = p;
        self
    }

    /// Mean link capacity C̄.
    pub fn capacity(mut self, cap_mean: f64) -> Self {
        self.cfg.cap_mean = cap_mean;
        self
    }

    /// Number of DNN versions W.
    pub fn versions(mut self, w: usize) -> Self {
        self.cfg.n_versions = w;
        self
    }

    /// Total task input rate λ (of the default class; adding explicit
    /// classes via [`Scenario::class`] supersedes it).
    pub fn rate(mut self, total: f64) -> Self {
        self.cfg.total_rate = total;
        self
    }

    /// Link cost family (typed).
    pub fn cost(mut self, kind: CostKind) -> Self {
        self.cfg.cost = kind;
        self.cost_name = None;
        self
    }

    /// Link cost family by name (`"exp"`, `"queue"`, `"linear"`,
    /// `"cubic"`); validated at [`Scenario::build`].
    pub fn cost_named(mut self, name: &str) -> Self {
        self.cost_name = Some(name.to_string());
        self
    }

    /// Utility family by name (`"linear"`, `"sqrt"`, `"quadratic"`,
    /// `"log"`) for the default class; validated at [`Scenario::build`].
    pub fn utility(mut self, name: &str) -> Self {
        self.cfg.utility = name.to_string();
        self
    }

    /// Add a task class with a constant rate (multi-class sugar): its own
    /// utility family and source-device set (empty sources = the hosts of
    /// version 0). The first call replaces the implicit default class.
    pub fn class(mut self, name: &str, utility: &str, rate: f64, sources: &[usize]) -> Self {
        self.classes.push(ClassSpec {
            name: name.to_string(),
            utility: utility.to_string(),
            rate: RateSpec::Constant(rate),
            sources: sources.to_vec(),
        });
        self
    }

    /// Add a task class with a piecewise-constant rate trace
    /// (`[(outer_iteration, rate), ...]`, first point at iteration 0);
    /// requires a [`Scenario::horizon`].
    pub fn class_trace(
        mut self,
        name: &str,
        utility: &str,
        trace: &[(usize, f64)],
        sources: &[usize],
    ) -> Self {
        self.classes.push(ClassSpec {
            name: name.to_string(),
            utility: utility.to_string(),
            rate: RateSpec::Trace(trace.to_vec()),
            sources: sources.to_vec(),
        });
        self
    }

    /// Pin device `id`'s computing capacity (heterogeneous-node sugar).
    pub fn node_compute(mut self, id: usize, capacity: f64) -> Self {
        self.node_mut(id).compute_capacity = Some(capacity);
        self
    }

    /// Pin the DNN version device `id` hosts.
    pub fn pin_version(mut self, id: usize, version: usize) -> Self {
        self.node_mut(id).version = Some(version);
        self
    }

    fn node_mut(&mut self, id: usize) -> &mut NodeSpec {
        if let Some(k) = self.nodes.iter().position(|n| n.id == id) {
            &mut self.nodes[k]
        } else {
            self.nodes.push(NodeSpec { id, compute_capacity: None, version: None });
            self.nodes.last_mut().unwrap()
        }
    }

    /// Outer-iteration horizon (required when any class uses a rate trace).
    pub fn horizon(mut self, h: usize) -> Self {
        self.horizon = Some(h);
        self
    }

    /// OMD-RT step size η.
    pub fn eta_routing(mut self, eta: f64) -> Self {
        self.cfg.eta_routing = eta;
        self
    }

    /// Allocation step size.
    pub fn eta_alloc(mut self, eta: f64) -> Self {
        self.cfg.eta_alloc = eta;
        self
    }

    /// Gradient-sampling disturbance δ.
    pub fn delta(mut self, delta: f64) -> Self {
        self.cfg.delta = delta;
        self
    }

    /// RNG seed for topology generation and placements.
    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    /// Engine worker threads for the per-session flow/marginal sweeps
    /// (`0` = auto-detect, `1` = single-threaded default). Solver results
    /// are bit-identical at any worker count.
    pub fn workers(mut self, workers: usize) -> Self {
        self.cfg.workers = workers;
        self
    }

    /// Leader shards for the sharded coordination plane (`"sharded-omd"`;
    /// `1` = the single-leader degenerate case).
    pub fn shards(mut self, k: usize) -> Self {
        self.shards = Some(k);
        self
    }

    /// Staleness bound S for sharded rounds: a shard proceeds once peer
    /// flow aggregates are at most S rounds stale.
    pub fn staleness(mut self, s: usize) -> Self {
        self.staleness = Some(s);
        self
    }

    /// Lower the builder into the declarative [`ScenarioSpec`] it
    /// describes (without building the problem). Builder sugar and spec
    /// construction are interchangeable: `builder.build()` ≡
    /// `builder.into_spec()?.build()`.
    pub fn into_spec(self) -> Result<ScenarioSpec, SessionError> {
        let mut cfg = self.cfg;
        if let Some(name) = &self.cost_name {
            cfg.cost = CostKind::parse(name)
                .ok_or_else(|| SessionError::UnknownCost { name: name.clone() })?;
        }
        if !(cfg.total_rate > 0.0) && self.classes.is_empty() {
            return Err(SessionError::InvalidScenario {
                what: format!("total_rate must be > 0 (got {})", cfg.total_rate),
            });
        }
        let mut spec = ScenarioSpec::from_config(&cfg);
        if !self.classes.is_empty() {
            spec.classes = self.classes;
        }
        spec.nodes = self.nodes;
        spec.horizon = self.horizon;
        spec.shards = self.shards;
        spec.staleness = self.staleness;
        Ok(spec)
    }

    /// Validate every field and build the problem instance.
    pub fn build(self) -> Result<Session, SessionError> {
        self.into_spec()?.build()
    }
}

/// A validated scenario with its problem instance built: the factory for
/// solvers, oracles, and streaming runs.
#[derive(Clone, Debug)]
pub struct Session {
    /// Scalar compatibility view of the spec (total rate = sum of class
    /// rates, utility = the first class's family).
    pub cfg: ExperimentConfig,
    /// The declarative scenario this session was built from.
    pub spec: ScenarioSpec,
    pub problem: Problem,
}

impl Session {
    /// Hyper-parameters derived from this session's config, with the
    /// spec's shard/staleness knobs lifted in.
    pub fn hyper(&self) -> Hyper {
        let mut h = Hyper::from_config(&self.cfg);
        if let Some(k) = self.spec.shards {
            h.shards = k;
        }
        if let Some(s) = self.spec.staleness {
            h.staleness = s;
        }
        h
    }

    /// The unified [`registry::SolverOpts`] view of this session's solver
    /// configuration (workers + shards + staleness; batch mode and η stay
    /// at their defaults — the per-solver η comes from [`Session::hyper`]).
    pub fn solver_opts(&self) -> registry::SolverOpts {
        registry::SolverOpts::from_hyper(&self.hyper())
    }

    /// The paper's allocation initializer — per class, `Λ¹ = (λ_c/W_c)·1`.
    pub fn uniform_allocation(&self) -> Vec<f64> {
        self.problem.uniform_allocation()
    }

    /// The rate-trace breakpoints of this scenario compiled to scheduled
    /// [`crate::coordinator::events::NetworkEvent::ClassRate`] events
    /// (empty when every class rate is constant).
    pub fn events(&self) -> EventSchedule {
        self.spec.events()
    }

    /// The (hidden) ground-truth utility functions for this scenario, one
    /// per session: class-major, each class's family instantiated at that
    /// class's rate.
    pub fn utilities(&self) -> Result<Vec<Utility>, SessionError> {
        let w_cnt = self.spec.n_versions;
        let mut out = Vec::with_capacity(self.problem.n_sessions());
        for (class, &rate) in self.spec.classes.iter().zip(&self.problem.workload.class_rates)
        {
            let us = family(&class.utility, w_cnt, rate).ok_or_else(|| {
                SessionError::UnknownUtility { name: class.utility.clone() }
            })?;
            out.extend(us);
        }
        Ok(out)
    }

    /// Instantiate a router by registry name with this session's
    /// hyper-parameters.
    pub fn router(&self, name: &str) -> Result<Box<dyn Router>, SessionError> {
        registry::router_with(name, &self.hyper())
    }

    /// Instantiate an allocator by registry name with this session's
    /// hyper-parameters.
    pub fn allocator(&self, name: &str) -> Result<Box<dyn Allocator>, SessionError> {
        registry::allocator_with(name, &self.hyper())
    }

    /// The utility oracle matching an allocator: single-loop algorithms get
    /// the persistent single-step oracle (`K = 1` routing per observation),
    /// nested-loop algorithms the run-to-convergence oracle.
    pub fn oracle_for(&self, allocator: &str) -> Result<Box<dyn UtilityOracle>, SessionError> {
        let entry = registry::allocator_entry(allocator)
            .ok_or_else(|| SessionError::UnknownAllocator { name: allocator.to_string() })?;
        let utilities = self.utilities()?;
        if entry.single_loop {
            let mut oracle =
                SingleStepOracle::new(self.problem.clone(), utilities, self.cfg.eta_routing);
            // the persistent routing state advances on the shared engine;
            // thread the session's worker knob through
            oracle.router.set_workers(self.cfg.workers);
            Ok(Box::new(oracle))
        } else {
            let mut oracle = AnalyticOracle::new(self.problem.clone(), utilities);
            oracle.router_eta = self.cfg.eta_routing;
            oracle.workers = self.cfg.workers;
            Ok(Box::new(oracle))
        }
    }

    /// A streaming routing run of `algo` on the uniform allocation, with
    /// the legacy convergence tolerance and an iteration budget. The
    /// session's `workers` knob is threaded into the run's final-report
    /// engine and the router's per-iteration sweeps.
    pub fn routing_run(
        &self,
        algo: &str,
        max_iters: usize,
    ) -> Result<RoutingRun<'_>, SessionError> {
        Ok(RoutingRun::new(
            &self.problem,
            self.router(algo)?,
            self.uniform_allocation(),
            max_iters,
        )
        .engine_workers(self.cfg.workers))
    }

    /// A streaming distributed routing run (paper Sec. V): the
    /// `"distributed-omd"` registry solver — one step = one barriered
    /// round over live node actors — driven through the same `RunCore`
    /// protocol as every centralized run. The final
    /// [`RunReport::comm`] carries the
    /// [`crate::coordinator::net::CommStats`] telemetry.
    pub fn distributed_run(&self, rounds: usize) -> Result<DistributedRun<'_>, SessionError> {
        self.routing_run("distributed-omd", rounds)
    }

    /// A streaming **sharded** distributed run: the `"sharded-omd"`
    /// registry solver — K leader shards, staleness-bounded rounds, λ-sync
    /// delta gossip — configured from the spec's `shards`/`staleness`
    /// knobs. K = 1 (the default) degenerates to
    /// [`Session::distributed_run`] bit for bit.
    pub fn sharded_run(&self, rounds: usize) -> Result<DistributedRun<'_>, SessionError> {
        self.routing_run("sharded-omd", rounds)
    }

    /// A streaming allocation run of `algo` with its matching oracle, from
    /// the uniform initializer.
    pub fn allocation_run<'o>(
        &self,
        algo: &str,
        max_outer: usize,
    ) -> Result<AllocationRun<'o>, SessionError> {
        // full feasibility of each class's projection box [δ, λ_c−δ]^W:
        // the lower bound needs W·δ ≤ λ_c (checked at build), the upper
        // needs λ_c ≤ W·(λ_c−δ) — which rules out W = 1 for any δ > 0
        let delta = self.cfg.delta;
        for (c, &(s0, s1)) in self.problem.workload.class_spans.iter().enumerate() {
            let name = &self.problem.workload.class_names[c];
            let w = (s1 - s0) as f64;
            let rate = self.problem.workload.class_rates[c];
            if rate > w * (rate - delta) {
                let what = format!(
                    "allocation domain of class '{name}' is infeasible: delta {delta}, \
                     W {w}, rate {rate} violate rate <= W*(rate - delta); reduce delta \
                     or add versions"
                );
                return Err(SessionError::InvalidScenario { what });
            }
        }
        Ok(AllocationRun::new(self.allocator(algo)?, self.oracle_for(algo)?, max_outer))
    }

    /// A streaming request-level simulation run over `windows` equal
    /// sim-time windows of the scenario's arrival horizon (the `sim` block
    /// of the spec, or [`crate::sim::SimSpec::default`] when absent).
    /// Starts from the uniform `(Λ, φ)`; feed an optimized configuration
    /// with [`SimRun::warm_start_from`], or attach a live
    /// [`AllocationRun`] via [`SimRun::drive`]. Each class's arrival
    /// process comes from its [`RateSpec`]: constant rates become
    /// homogeneous Poisson streams, rate traces piecewise-constant ones
    /// (breakpoint iterations scaled by `trace_window_s`). The simulation
    /// seeds from the scenario seed — same scenario, same report,
    /// bit-for-bit, at any engine worker count.
    pub fn sim_run(&self, windows: usize) -> Result<SimRun<'_>, SessionError> {
        let spec = self.spec.sim.clone().unwrap_or_default();
        let traces = self
            .spec
            .classes
            .iter()
            .map(|class| match &class.rate {
                RateSpec::Constant(r) => ArrivalTrace::constant(*r),
                RateSpec::Trace(pts) => {
                    ArrivalTrace::from_breakpoints(pts, spec.trace_window_s)
                }
            })
            .collect();
        let sim = Simulator::new(
            &self.problem,
            spec,
            traces,
            self.uniform_allocation(),
            self.cfg.seed,
        );
        Ok(SimRun::new(sim, windows))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_default_builds() {
        let s = Scenario::paper_default().build().unwrap();
        assert_eq!(s.problem.net.n_real, 25);
        assert_eq!(s.cfg.n_versions, 3);
        assert_eq!(s.spec.classes.len(), 1);
    }

    #[test]
    fn unknown_names_fail_at_build() {
        assert!(matches!(
            Scenario::paper_default().topology("moebius").build(),
            Err(SessionError::UnknownTopology { .. })
        ));
        assert!(matches!(
            Scenario::paper_default().utility("cosine").build(),
            Err(SessionError::UnknownUtility { .. })
        ));
        assert!(matches!(
            Scenario::paper_default().cost_named("tanh").build(),
            Err(SessionError::UnknownCost { .. })
        ));
    }

    #[test]
    fn invalid_parameters_fail_at_build() {
        assert!(Scenario::paper_default().versions(0).build().is_err());
        assert!(Scenario::paper_default().rate(0.0).build().is_err());
        assert!(Scenario::paper_default().rate(f64::NAN).build().is_err());
        assert!(Scenario::paper_default().link_probability(0.0).build().is_err());
        assert!(Scenario::paper_default().link_probability(1.5).build().is_err());
        assert!(Scenario::paper_default().nodes(1).build().is_err());
        assert!(Scenario::paper_default().eta_routing(0.0).build().is_err());
        assert!(Scenario::paper_default().delta(1e9).build().is_err());
    }

    #[test]
    fn allocation_feasibility_is_enforced() {
        // W·δ > λ fails at build (the projection's lower-bound condition)
        assert!(Scenario::paper_default().delta(25.0).build().is_err());
        // routing-only W=1 sessions build, but allocation runs are
        // rejected (λ ≤ W·(λ−δ) cannot hold for W=1, δ>0)
        let s = Scenario::paper_default().versions(1).build().unwrap();
        assert!(s.routing_run("omd", 3).is_ok());
        assert!(s.allocation_run("omad", 3).is_err());
    }

    #[test]
    fn cost_named_is_applied() {
        let s = Scenario::paper_default().cost_named("queue").build().unwrap();
        assert_eq!(s.cfg.cost, CostKind::Queue);
        assert_eq!(s.spec.cost, CostKind::Queue);
    }

    #[test]
    fn named_topology_builds() {
        let s = Scenario::paper_default().topology("abilene").capacity(15.0).build().unwrap();
        assert_eq!(s.problem.net.n_real, 11);
    }

    #[test]
    fn session_construction_is_seed_deterministic() {
        let a = Scenario::paper_default().seed(9).build().unwrap();
        let b = Scenario::paper_default().seed(9).build().unwrap();
        assert_eq!(a.problem.net.graph.n_edges(), b.problem.net.graph.n_edges());
    }

    #[test]
    fn builder_sugar_equals_spec_construction() {
        // the same scenario described via builder sugar and via a
        // hand-built spec must produce identical problems
        let by_builder = Scenario::paper_default()
            .versions(2)
            .delta(0.2)
            .class("video", "log", 40.0, &[0, 1])
            .class("audio", "sqrt", 20.0, &[])
            .node_compute(2, 50.0)
            .seed(5)
            .build()
            .unwrap();
        let mut spec = ScenarioSpec::paper_default();
        spec.n_versions = 2;
        spec.delta = 0.2;
        spec.seed = 5;
        spec.classes = vec![
            spec::ClassSpec {
                name: "video".into(),
                utility: "log".into(),
                rate: spec::RateSpec::Constant(40.0),
                sources: vec![0, 1],
            },
            spec::ClassSpec {
                name: "audio".into(),
                utility: "sqrt".into(),
                rate: spec::RateSpec::Constant(20.0),
                sources: vec![],
            },
        ];
        spec.nodes =
            vec![spec::NodeSpec { id: 2, compute_capacity: Some(50.0), version: None }];
        let by_spec = spec.build().unwrap();
        assert_eq!(by_builder.spec, by_spec.spec);
        assert_eq!(
            by_builder.problem.net.csr.lane_edge,
            by_spec.problem.net.csr.lane_edge
        );
        for (a, b) in by_builder
            .problem
            .net
            .graph
            .edges()
            .iter()
            .zip(by_spec.problem.net.graph.edges())
        {
            assert_eq!(a, b);
        }
        assert_eq!(by_builder.problem.workload, by_spec.problem.workload);
    }

    #[test]
    fn shard_knobs_flow_from_builder_to_hyper() {
        let s = Scenario::paper_default().shards(3).staleness(2).seed(4).build().unwrap();
        assert_eq!(s.spec.shards, Some(3));
        assert_eq!(s.spec.staleness, Some(2));
        let h = s.hyper();
        assert_eq!(h.shards, 3);
        assert_eq!(h.staleness, 2);
        let opts = s.solver_opts();
        assert_eq!(opts.shards, 3);
        assert_eq!(opts.staleness, 2);
        // knobs survive the spec's JSON round trip
        let back = ScenarioSpec::from_json(&s.spec.to_json().to_string()).unwrap();
        assert_eq!(back.shards, Some(3));
        assert_eq!(back.staleness, Some(2));
        // and default sessions leave them unset (digest stability)
        let d = Scenario::paper_default().build().unwrap();
        assert_eq!(d.spec.shards, None);
        assert_eq!(d.hyper().shards, 1);
    }

    #[test]
    fn sharded_run_streams_like_any_other() {
        let s = Scenario::paper_default()
            .nodes(10)
            .link_probability(0.3)
            .shards(2)
            .staleness(1)
            .seed(8)
            .build()
            .unwrap();
        let report = s.sharded_run(6).unwrap().finish();
        assert_eq!(report.algo, "sharded-omd");
        assert!(report.objective.is_finite());
        let comm = report.comm.expect("sharded runs report comm stats");
        assert_eq!(comm.shards.len(), 2);
        assert!(comm.messages > 0);
    }

    #[test]
    fn multi_class_session_runs_and_allocates() {
        let s = Scenario::paper_default()
            .versions(2)
            .delta(0.2)
            .class("video", "log", 40.0, &[])
            .class("audio", "sqrt", 20.0, &[])
            .seed(3)
            .build()
            .unwrap();
        assert_eq!(s.problem.n_sessions(), 4);
        let us = s.utilities().unwrap();
        assert_eq!(us.len(), 4);
        let report = s.routing_run("omd", 10).unwrap().finish();
        assert!(report.objective.is_finite());
        let report = s.allocation_run("omad", 3).unwrap().finish();
        // per-class conservation
        let wl = &s.problem.workload;
        for (c, &(a, b)) in wl.class_spans.iter().enumerate() {
            let sum: f64 = report.lam[a..b].iter().sum();
            assert!(
                (sum - wl.class_rates[c]).abs() < 1e-6,
                "class {c}: {sum} vs {}",
                wl.class_rates[c]
            );
        }
    }
}
