//! Error type for scenario construction and solver lookup.
//!
//! Every fallible step of a session — topology lookup, utility-family
//! lookup, solver-registry lookup, scenario validation — reports through
//! [`SessionError`], so callers (the CLI, harnesses, library users) get a
//! clean `Result` end-to-end instead of a `panic!` deep inside problem
//! construction.

use std::fmt;

/// What went wrong while building a [`crate::session::Scenario`] or looking
/// up a solver in the [`crate::session::registry`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SessionError {
    /// No router registered under this name.
    UnknownRouter { name: String },
    /// No allocator registered under this name.
    UnknownAllocator { name: String },
    /// No topology generator known under this name.
    UnknownTopology { name: String },
    /// No utility family known under this name.
    UnknownUtility { name: String },
    /// No link-cost family known under this name.
    UnknownCost { name: String },
    /// A task class names a source device that does not exist in the
    /// topology.
    UnknownSourceNode { class: String, node: usize },
    /// A node spec pins a DNN version that cannot be satisfied (out of
    /// range, or the pins leave a version with no hosting device).
    UnsupportedVersion { what: String },
    /// A task class's source set cannot reach a version's destination: the
    /// virtual source ends up with no usable admission lane in that
    /// session's DAG.
    DisconnectedSource { class: String, version: usize },
    /// A class's rate trace is malformed or inconsistent with the
    /// scenario horizon.
    InvalidTrace { class: String, what: String },
    /// A scenario parameter is out of its valid range.
    InvalidScenario { what: String },
    /// A shard of the sharded coordination plane could not obtain peer
    /// aggregates fresh enough for the staleness bound S within the sync
    /// timeout (a partitioned or straggling peer). Surfaced as a typed
    /// error — sharded rounds never hang on a missing peer.
    StalenessExceeded { shard: usize, round: usize, bound: usize },
}

impl fmt::Display for SessionError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SessionError::UnknownRouter { name } => write!(
                f,
                "unknown router '{name}' (known: {})",
                crate::session::registry::router_names().join(", ")
            ),
            SessionError::UnknownAllocator { name } => write!(
                f,
                "unknown allocator '{name}' (known: {})",
                crate::session::registry::allocator_names().join(", ")
            ),
            SessionError::UnknownTopology { name } => write!(
                f,
                "unknown topology '{name}' (known: {})",
                crate::graph::topologies::KNOWN_NAMES.join(", ")
            ),
            SessionError::UnknownUtility { name } => write!(
                f,
                "unknown utility family '{name}' (known: {})",
                crate::model::utility::FAMILIES.join(", ")
            ),
            SessionError::UnknownCost { name } => write!(
                f,
                "unknown cost family '{name}' (known: {})",
                crate::model::cost::CostKind::NAMES.join(", ")
            ),
            SessionError::UnknownSourceNode { class, node } => write!(
                f,
                "task class '{class}' lists source device {node}, which does not exist \
                 in the topology"
            ),
            SessionError::UnsupportedVersion { what } => {
                write!(f, "unsupported version placement: {what}")
            }
            SessionError::DisconnectedSource { class, version } => write!(
                f,
                "task class '{class}' cannot reach version {version}'s destination: \
                 the source has no usable admission lane in that session's DAG"
            ),
            SessionError::InvalidTrace { class, what } => {
                write!(f, "invalid rate trace for class '{class}': {what}")
            }
            SessionError::InvalidScenario { what } => write!(f, "invalid scenario: {what}"),
            SessionError::StalenessExceeded { shard, round, bound } => write!(
                f,
                "shard {shard} exceeded the staleness bound S={bound} at round {round}: \
                 peer flow aggregates did not arrive within the sync timeout"
            ),
        }
    }
}

impl std::error::Error for SessionError {}

/// Lets `?` propagate a [`SessionError`] inside the CLI's string-error
/// plumbing.
impl From<SessionError> for String {
    fn from(e: SessionError) -> String {
        e.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn messages_name_the_offender_and_the_alternatives() {
        let e = SessionError::UnknownRouter { name: "nope".into() };
        let msg = e.to_string();
        assert!(msg.contains("nope"), "{msg}");
        assert!(msg.contains("omd"), "{msg}");
        let e = SessionError::UnknownAllocator { name: "bad".into() };
        let msg = e.to_string();
        assert!(msg.contains("bad") && msg.contains("gsoma"), "{msg}");
    }

    #[test]
    fn staleness_error_names_the_shard_and_bound() {
        let e = SessionError::StalenessExceeded { shard: 3, round: 17, bound: 2 };
        let msg = e.to_string();
        assert!(msg.contains("shard 3") && msg.contains("S=2") && msg.contains("17"), "{msg}");
    }

    #[test]
    fn converts_into_cli_string_errors() {
        let s: String = SessionError::UnknownTopology { name: "x".into() }.into();
        assert!(s.contains('x'));
    }
}
