//! Streaming, step-driven execution: one iteration at a time, with
//! pluggable stop rules and observer callbacks.
//!
//! A run is a resumable handle — [`RoutingRun`] / [`AllocationRun`] — whose
//! [`step`](RoutingRun::step) advances the underlying algorithm by exactly
//! one iteration and returns [`ControlFlow::Continue`] until a
//! [`StopRule`] fires, at which point it returns
//! [`ControlFlow::Break`] with the unified [`RunReport`]. Trajectories and
//! metrics are recorded by [`Observer`]s (e.g. [`Trajectory`]) instead of
//! being baked into each algorithm, so telemetry composes without touching
//! solver code.
//!
//! All run kinds share one [`RunCore`]: the run-loop *protocol* — stop
//! rules, observer fan-out, report caching, the zero-budget edge case —
//! lives in exactly one place, parameterized over the per-iteration
//! advance (a routing step vs. an allocation outer step). The distributed
//! coordinator streams through the same core: a [`DistributedRun`] is a
//! routing run whose router performs one barriered message-passing round
//! per step, with its [`crate::coordinator::net::CommStats`] surfaced on
//! [`RunReport::comm`]. Final-report objectives are evaluated by the fused
//! [`crate::engine::FlowEngine`] sweep — worker count threaded from
//! `Scenario::workers` via [`RoutingRun::engine_workers`] — the same code
//! path the legacy `Router::solve` epilogue uses.
//!
//! Driven to completion with the default rules, a run reproduces the legacy
//! `Router::solve` / `Allocator::run` loops *bit for bit* (same oracle call
//! order, same floating-point operations) — verified by
//! `tests/test_session.rs`.

use std::ops::ControlFlow;
use crate::util::clock::Stopwatch;

use crate::allocation::{Allocator, UtilityOracle};
use crate::coordinator::net::CommStats;
use crate::engine::FlowEngine;
use crate::model::flow::Phi;
use crate::model::Problem;
use crate::routing::{Router, CONVERGENCE_TOL};
use crate::sim::{SimReport, Simulator};

/// Why a run stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    /// The iterate stopped moving (`‖x^{k+1} − x^k‖_∞ ≤ tol`).
    Converged,
    /// The iteration budget was exhausted.
    MaxIters,
    /// The wall-clock deadline passed.
    Deadline,
}

/// Unified final report of a routing or allocation run (the successor of
/// the legacy `RoutingState` / `AllocationState` pair; trajectories live in
/// observers, not here).
#[derive(Clone, Debug)]
pub struct RunReport {
    /// Algorithm name as reported by the solver.
    pub algo: String,
    /// Final objective: total network cost for routing runs, observed total
    /// network utility for allocation runs.
    pub objective: f64,
    /// Final allocation Λ (the fixed input allocation for routing runs).
    pub lam: Vec<f64>,
    /// Final routing state, when the run exposes one.
    pub phi: Option<Phi>,
    /// Iterations performed.
    pub iterations: usize,
    /// Total routing iterations consumed (equals `iterations` for routing
    /// runs; counts oracle-internal routing work for allocation runs).
    pub routing_iterations: usize,
    /// Communication accounting, when the solver ran over a message fabric
    /// (the distributed coordinator); `None` for in-process solvers.
    pub comm: Option<CommStats>,
    pub stop: StopReason,
    pub elapsed_s: f64,
}

impl RunReport {
    /// The final routing state, for hand-off into a warm-started follow-up
    /// run (the successor of the legacy `RoutingState.phi` interop).
    pub fn final_phi(&self) -> Option<&Phi> {
        self.phi.as_ref()
    }
}

/// Per-iteration snapshot handed to stop rules and observers.
#[derive(Clone, Copy, Debug)]
pub struct StepInfo<'a> {
    /// 1-based count of completed iterations.
    pub iter: usize,
    /// Objective observed at this iteration (cost *before* the update for
    /// routing, utility *at the iterate* for allocation — matching the
    /// paper's per-iteration convergence plots).
    pub objective: f64,
    /// `‖x^{k+1} − x^k‖_∞` for this iteration's update.
    pub moved: f64,
    /// Wall-clock seconds since the run started.
    pub elapsed_s: f64,
    /// Current allocation Λ.
    pub lam: &'a [f64],
}

/// Decides when a run is finished. Rules are checked in registration order
/// after every iteration; the first to fire wins.
pub trait StopRule {
    fn check(&mut self, info: &StepInfo<'_>) -> Option<StopReason>;
}

/// Stop after a fixed number of iterations.
#[derive(Clone, Copy, Debug)]
pub struct MaxIters(pub usize);

impl StopRule for MaxIters {
    fn check(&mut self, info: &StepInfo<'_>) -> Option<StopReason> {
        (info.iter >= self.0).then_some(StopReason::MaxIters)
    }
}

/// Stop when the iterate stops moving: `‖x^{k+1} − x^k‖_∞ ≤ tol` (the
/// paper's exact-equality stop, relaxed to floating point; inclusive,
/// matching the legacy `phi_close` check of `Router::solve`).
#[derive(Clone, Copy, Debug)]
pub struct Tolerance(pub f64);

impl StopRule for Tolerance {
    fn check(&mut self, info: &StepInfo<'_>) -> Option<StopReason> {
        (info.moved <= self.0).then_some(StopReason::Converged)
    }
}

/// Strict variant: stop when `‖x^{k+1} − x^k‖_∞ < tol` — the boundary
/// behavior of the legacy `Allocator::run` loop.
#[derive(Clone, Copy, Debug)]
pub struct ToleranceStrict(pub f64);

impl StopRule for ToleranceStrict {
    fn check(&mut self, info: &StepInfo<'_>) -> Option<StopReason> {
        (info.moved < self.0).then_some(StopReason::Converged)
    }
}

/// Stop once the run has consumed a wall-clock budget (seconds).
#[derive(Clone, Copy, Debug)]
pub struct Deadline(pub f64);

impl StopRule for Deadline {
    fn check(&mut self, info: &StepInfo<'_>) -> Option<StopReason> {
        (info.elapsed_s >= self.0).then_some(StopReason::Deadline)
    }
}

/// Telemetry callback invoked after every iteration and once at the end.
pub trait Observer {
    fn on_step(&mut self, info: &StepInfo<'_>);
    fn on_finish(&mut self, _report: &RunReport) {}
}

/// Records the objective at every iteration plus the final objective —
/// exactly the legacy `trajectory` field of `RoutingState` /
/// `AllocationState`.
#[derive(Clone, Debug, Default)]
pub struct Trajectory {
    pub values: Vec<f64>,
}

impl Observer for Trajectory {
    fn on_step(&mut self, info: &StepInfo<'_>) {
        self.values.push(info.objective);
    }

    fn on_finish(&mut self, report: &RunReport) {
        self.values.push(report.objective);
    }
}

/// Prints a progress line every `every` iterations (CLI telemetry).
#[derive(Clone, Copy, Debug)]
pub struct Progress {
    pub every: usize,
}

impl Observer for Progress {
    fn on_step(&mut self, info: &StepInfo<'_>) {
        if self.every > 0 && info.iter % self.every == 0 {
            println!(
                "  iter {:>5}  objective {:>14.6}  moved {:.2e}  ({:.3}s)",
                info.iter, info.objective, info.moved, info.elapsed_s
            );
        }
    }
}

/// Max-norm distance between two routing configurations.
fn phi_moved(a: &Phi, b: &Phi) -> f64 {
    a.frac
        .iter()
        .zip(&b.frac)
        .flat_map(|(ra, rb)| ra.iter().zip(rb))
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f64, f64::max)
}

/// Max-norm distance between two allocations.
fn lam_moved(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f64, f64::max)
}

/// The run-loop protocol shared by [`RoutingRun`] and [`AllocationRun`]:
/// stop rules, observers, the iteration/clock bookkeeping, and final-report
/// caching. The per-iteration *advance* is the only thing the two run
/// kinds implement themselves.
struct RunCore<'a> {
    stop_rules: Vec<Box<dyn StopRule + 'a>>,
    observers: Vec<&'a mut dyn Observer>,
    t0: Stopwatch,
    iter: usize,
    finished: Option<RunReport>,
}

impl<'a> RunCore<'a> {
    fn new(stop_rules: Vec<Box<dyn StopRule + 'a>>) -> Self {
        RunCore {
            stop_rules,
            observers: Vec::new(),
            t0: Stopwatch::start(),
            iter: 0,
            finished: None,
        }
    }

    /// Re-report a finished run without advancing it.
    fn replay_finished(&self) -> Option<ControlFlow<RunReport>> {
        self.finished.as_ref().map(|r| ControlFlow::Break(r.clone()))
    }

    fn elapsed_s(&self) -> f64 {
        self.t0.elapsed_secs()
    }

    /// Step epilogue: count the iteration, fan out to observers, and check
    /// the stop rules in registration order.
    fn record_step(&mut self, objective: f64, moved: f64, lam: &[f64]) -> Option<StopReason> {
        self.iter += 1;
        let info = StepInfo {
            iter: self.iter,
            objective,
            moved,
            elapsed_s: self.elapsed_s(),
            lam,
        };
        for obs in self.observers.iter_mut() {
            obs.on_step(&info);
        }
        self.stop_rules.iter_mut().find_map(|r| r.check(&info))
    }

    /// Assemble, cache, and broadcast the final report. `routing_iters`
    /// defaults to the iteration count (routing runs).
    #[allow(clippy::too_many_arguments)]
    fn finish(
        &mut self,
        algo: &str,
        objective: f64,
        lam: Vec<f64>,
        phi: Option<Phi>,
        routing_iters: Option<usize>,
        comm: Option<CommStats>,
        stop: StopReason,
    ) -> RunReport {
        let report = RunReport {
            algo: algo.to_string(),
            objective,
            lam,
            phi,
            iterations: self.iter,
            routing_iterations: routing_iters.unwrap_or(self.iter),
            comm,
            stop,
            elapsed_s: self.elapsed_s(),
        };
        for obs in self.observers.iter_mut() {
            obs.on_finish(&report);
        }
        self.finished = Some(report.clone());
        report
    }
}

/// A streaming distributed routing run: a [`RoutingRun`] whose router is
/// the message-passing [`crate::coordinator::leader::DistributedOmd`]
/// (one step = one barriered round over live node actors). It reuses
/// `RunCore` — stop rules, observers, report caching — verbatim; the
/// distributed-specific telemetry arrives through
/// [`RunReport::comm`]. Construct via
/// [`crate::session::Session::distributed_run`].
pub type DistributedRun<'a> = RoutingRun<'a>;

/// A resumable routing run: minimizes `D(Λ, φ)` one iteration per
/// [`step`](RoutingRun::step) for a fixed allocation Λ.
pub struct RoutingRun<'a> {
    problem: &'a Problem,
    router: Box<dyn Router>,
    lam: Vec<f64>,
    phi: Phi,
    max_iters: usize,
    engine: FlowEngine,
    core: RunCore<'a>,
}

impl<'a> RoutingRun<'a> {
    /// A run from the paper's uniform initializer `φ¹`, stopping on
    /// convergence ([`Tolerance`] at the legacy `CONVERGENCE_TOL`) or after
    /// `max_iters` iterations — the exact semantics of the legacy
    /// `Router::solve`.
    pub fn new(
        problem: &'a Problem,
        router: Box<dyn Router>,
        lam: Vec<f64>,
        max_iters: usize,
    ) -> Self {
        RoutingRun {
            problem,
            router,
            lam,
            phi: Phi::uniform(&problem.net),
            max_iters,
            engine: FlowEngine::new(),
            core: RunCore::new(vec![
                Box::new(Tolerance(CONVERGENCE_TOL)),
                Box::new(MaxIters(max_iters)),
            ]),
        }
    }

    /// Start from (and take ownership of) an existing routing state instead
    /// of the uniform initializer.
    pub fn warm_start(mut self, phi: Phi) -> Self {
        self.phi = phi;
        self
    }

    /// Warm-start from a previous run's final state (the `RunReport`-based
    /// hand-off that replaces the legacy `RoutingState` interop). No-op if
    /// the report carries no routing state.
    pub fn warm_start_from(self, report: &RunReport) -> Self {
        match report.final_phi() {
            Some(phi) => self.warm_start(phi.clone()),
            None => self,
        }
    }

    /// Worker threads for this run's final-report [`FlowEngine`]
    /// evaluation *and* the router's per-iteration sweeps (`0` = auto).
    /// Threaded automatically from `Scenario::workers` by
    /// [`crate::session::Session::routing_run`].
    pub fn engine_workers(mut self, workers: usize) -> Self {
        self.engine.set_workers(workers);
        self.router.set_workers(workers);
        self
    }

    /// Add a stop rule (checked after the defaults).
    pub fn stop_when(mut self, rule: impl StopRule + 'a) -> Self {
        self.core.stop_rules.push(Box::new(rule));
        self
    }

    /// Add a wall-clock budget in seconds.
    pub fn deadline(self, seconds: f64) -> Self {
        self.stop_when(Deadline(seconds))
    }

    /// Attach an observer.
    pub fn observe(mut self, obs: &'a mut dyn Observer) -> Self {
        self.core.observers.push(obs);
        self
    }

    /// Current routing state.
    pub fn phi(&self) -> &Phi {
        &self.phi
    }

    /// Advance by one routing iteration. Returns
    /// [`ControlFlow::Break`] with the final report once a stop rule fires;
    /// further calls return the same report without advancing.
    pub fn step(&mut self) -> ControlFlow<RunReport> {
        if let Some(done) = self.core.replay_finished() {
            return done;
        }
        // legacy `solve(.., 0)` performs zero iterations; honor a zero
        // budget before doing any work
        if self.max_iters == 0 {
            return ControlFlow::Break(self.make_report(StopReason::MaxIters));
        }
        let prev = self.phi.clone();
        let cost_before = self.router.step(self.problem, &self.lam, &mut self.phi);
        let moved = phi_moved(&prev, &self.phi);
        match self.core.record_step(cost_before, moved, &self.lam) {
            None => ControlFlow::Continue(()),
            Some(stop) => ControlFlow::Break(self.make_report(stop)),
        }
    }

    fn make_report(&mut self, stop: StopReason) -> RunReport {
        let final_cost = self.engine.evaluate_cost(self.problem, &self.phi, &self.lam);
        self.core.finish(
            self.router.name(),
            final_cost,
            self.lam.clone(),
            Some(self.phi.clone()),
            None,
            self.router.comm_stats(),
            stop,
        )
    }

    /// Drive the run to completion.
    pub fn finish(mut self) -> RunReport {
        loop {
            if let ControlFlow::Break(report) = self.step() {
                return report;
            }
        }
    }
}

/// A resumable allocation run: maximizes the observed total network utility
/// one outer iteration per [`step`](AllocationRun::step), querying the
/// oracle exactly like the legacy `Allocator::run` loop.
pub struct AllocationRun<'a> {
    allocator: Box<dyn Allocator>,
    oracle: Box<dyn UtilityOracle>,
    lam: Vec<f64>,
    max_outer: usize,
    core: RunCore<'a>,
}

impl<'a> AllocationRun<'a> {
    /// A run from the paper's uniform initializer `Λ¹ = (λ/W)·1`, stopping
    /// when Λ stops moving (the allocator's own tolerance) or after
    /// `max_outer` outer iterations — the exact semantics of the legacy
    /// `Allocator::run`.
    pub fn new(
        allocator: Box<dyn Allocator>,
        oracle: Box<dyn UtilityOracle>,
        max_outer: usize,
    ) -> Self {
        let lam = oracle.uniform_allocation();
        let tol = allocator.stop_tol();
        AllocationRun {
            allocator,
            oracle,
            lam,
            max_outer,
            // strict (<) matches the legacy Allocator::run boundary
            core: RunCore::new(vec![
                Box::new(ToleranceStrict(tol)),
                Box::new(MaxIters(max_outer)),
            ]),
        }
    }

    /// Start from an existing allocation instead of the uniform initializer.
    pub fn warm_start(mut self, lam: Vec<f64>) -> Self {
        self.lam = lam;
        self
    }

    /// Add a stop rule (checked after the defaults).
    pub fn stop_when(mut self, rule: impl StopRule + 'a) -> Self {
        self.core.stop_rules.push(Box::new(rule));
        self
    }

    /// Add a wall-clock budget in seconds.
    pub fn deadline(self, seconds: f64) -> Self {
        self.stop_when(Deadline(seconds))
    }

    /// Attach an observer.
    pub fn observe(mut self, obs: &'a mut dyn Observer) -> Self {
        self.core.observers.push(obs);
        self
    }

    /// Current allocation Λ.
    pub fn lam(&self) -> &[f64] {
        &self.lam
    }

    /// The oracle driving this run (e.g. to inject topology changes via
    /// [`UtilityOracle::on_topology_change`]).
    pub fn oracle_mut(&mut self) -> &mut dyn UtilityOracle {
        self.oracle.as_mut()
    }

    /// Advance by one outer iteration (one utility observation at the
    /// iterate plus one gradient-sampling update).
    pub fn step(&mut self) -> ControlFlow<RunReport> {
        if let Some(done) = self.core.replay_finished() {
            return done;
        }
        // legacy `run(.., 0)` performs zero outer iterations (one final
        // observation only); honor a zero budget before doing any work
        if self.max_outer == 0 {
            return ControlFlow::Break(self.make_report(StopReason::MaxIters));
        }
        let u_at_iterate = self.oracle.observe(&self.lam);
        let (next, _grad) = self.allocator.outer_step(self.oracle.as_mut(), &self.lam);
        let moved = lam_moved(&next, &self.lam);
        self.lam = next;
        match self.core.record_step(u_at_iterate, moved, &self.lam) {
            None => ControlFlow::Continue(()),
            Some(stop) => ControlFlow::Break(self.make_report(stop)),
        }
    }

    fn make_report(&mut self, stop: StopReason) -> RunReport {
        let final_u = self.oracle.observe(&self.lam);
        self.core.finish(
            self.allocator.name(),
            final_u,
            self.lam.clone(),
            self.oracle.current_phi().cloned(),
            Some(self.oracle.routing_iterations()),
            None,
            stop,
        )
    }

    /// Drive the run to completion.
    pub fn finish(mut self) -> RunReport {
        loop {
            if let ControlFlow::Break(report) = self.step() {
                return report;
            }
        }
    }

    /// Tear the run down and recover its oracle, e.g. to read
    /// oracle-specific telemetry (the serving oracle's last
    /// [`crate::coordinator::serving::ServeReport`]) after the final
    /// report.
    pub fn into_oracle(self) -> Box<dyn UtilityOracle> {
        self.oracle
    }
}

/// A resumable request-level simulation run: one [`step`](SimRun::step)
/// replays one sim-time *window* of the arrival horizon through the
/// discrete-event [`Simulator`], reporting the window's mean end-to-end
/// latency as the streaming objective. Construct via
/// [`crate::session::Session::sim_run`]; feed an optimized routing state
/// with [`SimRun::warm_start`] / [`SimRun::warm_start_from`], or attach a
/// live [`AllocationRun`] with [`SimRun::drive`] to re-optimize `(Λ, φ)`
/// between windows (one outer allocation step per window, its current
/// iterate swapped into the simulator before the window replays).
///
/// The run speaks the same `RunCore` protocol as every other run —
/// [`StopRule`]s, [`Observer`]s, replayable final report. `moved` is
/// reported as `+∞` (requests don't form an iterate), so
/// [`Tolerance`]-style rules stay inert; the default stop is
/// [`MaxIters`] at the window count. The final [`RunReport`] carries the
/// drained-system mean latency as `objective`; the full [`SimReport`]
/// comes back from [`SimRun::finish`] or [`SimRun::sim_report`].
pub struct SimRun<'a> {
    sim: Simulator<'a>,
    window_s: f64,
    driver: Option<AllocationRun<'a>>,
    final_sim: Option<SimReport>,
    core: RunCore<'a>,
}

impl<'a> SimRun<'a> {
    /// A run splitting the simulator's arrival horizon into `windows`
    /// equal sim-time windows (clamped to ≥ 1), stopping after the last.
    pub fn new(sim: Simulator<'a>, windows: usize) -> Self {
        let windows = windows.max(1);
        let window_s = sim.spec().horizon_s / windows as f64;
        SimRun {
            sim,
            window_s,
            driver: None,
            final_sim: None,
            core: RunCore::new(vec![Box::new(MaxIters(windows))]),
        }
    }

    /// Replay against an optimized routing state instead of the uniform φ.
    pub fn warm_start(mut self, phi: &Phi) -> Self {
        self.sim.set_phi(phi);
        self
    }

    /// Replay against a previous run's final `(Λ, φ)` — the standard
    /// optimize-then-simulate hand-off. φ is a no-op if the report carries
    /// no routing state; Λ is always adopted.
    pub fn warm_start_from(mut self, report: &RunReport) -> Self {
        self.sim.set_lam(&report.lam);
        match report.final_phi() {
            Some(phi) => self.warm_start(phi),
            None => self,
        }
    }

    /// Attach a live allocation run: before each window replays, the
    /// driver advances one outer step and its current `(Λ, φ)` iterate is
    /// swapped into the simulator — the online closed loop of paper Sec. V
    /// at request granularity.
    pub fn drive(mut self, driver: AllocationRun<'a>) -> Self {
        self.driver = Some(driver);
        self
    }

    /// Add a stop rule (checked after the default window budget).
    pub fn stop_when(mut self, rule: impl StopRule + 'a) -> Self {
        self.core.stop_rules.push(Box::new(rule));
        self
    }

    /// Add a wall-clock budget in seconds.
    pub fn deadline(self, seconds: f64) -> Self {
        self.stop_when(Deadline(seconds))
    }

    /// Attach an observer.
    pub fn observe(mut self, obs: &'a mut dyn Observer) -> Self {
        self.core.observers.push(obs);
        self
    }

    /// Discrete events processed so far.
    pub fn events(&self) -> u64 {
        self.sim.events()
    }

    /// Snapshot the simulation roll-up at the current sim time (the final
    /// drained report after the run breaks).
    pub fn sim_report(&self) -> SimReport {
        self.sim.report()
    }

    /// Advance by one sim-time window. Returns [`ControlFlow::Break`] with
    /// the final report once a stop rule fires (the system is drained past
    /// the horizon first); further calls return the same report.
    pub fn step(&mut self) -> ControlFlow<RunReport> {
        if let Some(done) = self.core.replay_finished() {
            return done;
        }
        if let Some(driver) = self.driver.as_mut() {
            let _ = driver.step();
            // borrow the iterate in place (disjoint fields): the per-window
            // swap allocates nothing — set_phi refreshes the simulator's
            // CSR tables in place and set_lam copies into its buffer
            if let Some(phi) = driver.oracle_mut().current_phi() {
                self.sim.set_phi(phi);
            }
            self.sim.set_lam(driver.lam());
        }
        let horizon = self.sim.spec().horizon_s;
        let target = (((self.core.iter + 1) as f64) * self.window_s).min(horizon);
        let window = self.sim.run_until(target);
        // requests are not an iterate: +∞ keeps Tolerance rules inert
        match self.core.record_step(window.mean_latency_s, f64::INFINITY, self.sim.lam()) {
            None => ControlFlow::Continue(()),
            Some(stop) => ControlFlow::Break(self.make_report(stop)),
        }
    }

    fn make_report(&mut self, stop: StopReason) -> RunReport {
        self.sim.run_until(f64::INFINITY); // drain in-flight requests
        let sr = self.sim.report();
        let report = self.core.finish(
            "sim",
            sr.mean_latency_s,
            self.sim.lam().to_vec(),
            None,
            None,
            None,
            stop,
        );
        self.final_sim = Some(sr);
        report
    }

    /// Drive the run to completion, returning the unified report plus the
    /// full drained [`SimReport`].
    pub fn finish(mut self) -> (RunReport, SimReport) {
        let report = loop {
            if let ControlFlow::Break(r) = self.step() {
                break r;
            }
        };
        let sim = self.final_sim.take().unwrap_or_else(|| self.sim.report());
        (report, sim)
    }
}
