//! **`ScenarioSpec`** — the declarative scenario layer.
//!
//! A spec is a *typed description* of a JOWR experiment that goes beyond
//! the scalar knobs of [`crate::config::ExperimentConfig`]:
//!
//! * **heterogeneous nodes** — per-device compute capacities and optional
//!   pinned DNN versions ([`NodeSpec`]);
//! * **explicit or generated edge lists** — Connected-ER, any named
//!   topology, or a hand-written edge list with per-edge capacities and
//!   per-edge link-cost families ([`TopologySpec`], [`EdgeSpec`]);
//! * **multiple task classes** — each with its own source-device set,
//!   admitted rate (constant or a piecewise-constant trace over outer
//!   iterations), and utility family ([`ClassSpec`], [`RateSpec`]).
//!
//! Specs round-trip through JSON ([`ScenarioSpec::to_json`] /
//! [`ScenarioSpec::from_json`] / [`ScenarioSpec::from_file`]) — this is
//! what the CLI's `--scenario file.json` and the committed gallery under
//! `examples/scenarios/` load — and validate into a
//! [`crate::session::Session`] via [`ScenarioSpec::build`], reporting
//! precise [`SessionError`] variants (unknown source node, unsupported
//! version pin, disconnected source, trace/horizon mismatch) instead of
//! panicking mid-construction.
//!
//! The ergonomic [`crate::session::Scenario`] builder is sugar that lowers
//! into a spec; a single-class spec built from the paper's scalar knobs
//! produces a bit-identical problem to the pre-spec construction path.

use std::path::Path;

use super::error::SessionError;
use super::Session;
use crate::config::ExperimentConfig;
use crate::coordinator::events::{EventSchedule, NetworkEvent};
use crate::graph::augmented::{AugmentedNet, Placement};
use crate::graph::{topologies, DiGraph};
use crate::model::cost::CostKind;
use crate::model::utility;
use crate::model::{Problem, Workload};
use crate::sim::SimSpec;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// How the real device network is constructed.
#[derive(Clone, Debug, PartialEq)]
pub enum TopologySpec {
    /// Connectivity-guaranteed Erdős–Rényi (the paper's default family).
    Er { n_nodes: usize, p_link: f64 },
    /// A named generator from [`topologies::by_name`]
    /// (`abilene`, `tree`, `fog`, `geant`, `line`, `star`).
    Named { name: String },
    /// An explicit edge list (each entry optionally bidirectional, with
    /// its own capacity and cost family).
    Explicit { n_nodes: usize, edges: Vec<EdgeSpec> },
}

/// One explicit link of a [`TopologySpec::Explicit`] topology.
#[derive(Clone, Debug, PartialEq)]
pub struct EdgeSpec {
    pub src: usize,
    pub dst: usize,
    pub capacity: f64,
    /// `true` (default) adds the reverse edge with the same capacity/cost.
    pub bidirectional: bool,
    /// Per-edge cost family (`None` = the scenario default).
    pub cost: Option<CostKind>,
}

/// Per-device overrides: explicit compute capacity and/or a pinned hosted
/// version. Devices without an entry draw capacity from the `cap_mean`
/// distribution and a uniform-random version, exactly like the paper.
#[derive(Clone, Debug, PartialEq)]
pub struct NodeSpec {
    /// Real device index (0-based).
    pub id: usize,
    /// Computing capacity of the device's virtual computation link
    /// (`None` = drawn from the capacity distribution).
    pub compute_capacity: Option<f64>,
    /// Pinned hosted DNN version (`None` = drawn uniformly).
    pub version: Option<usize>,
}

/// A task class's admitted rate: constant, or a piecewise-constant trace
/// `[(outer_iteration, rate), ...]` starting at iteration 0. Breakpoints
/// beyond 0 compile into [`NetworkEvent::ClassRate`] events
/// (see [`ScenarioSpec::events`]).
#[derive(Clone, Debug, PartialEq)]
pub enum RateSpec {
    Constant(f64),
    Trace(Vec<(usize, f64)>),
}

impl RateSpec {
    /// The rate in effect at outer iteration `t`.
    pub fn at(&self, t: usize) -> f64 {
        match self {
            RateSpec::Constant(r) => *r,
            RateSpec::Trace(points) => points
                .iter()
                .take_while(|&&(t0, _)| t0 <= t)
                .last()
                .map(|&(_, r)| r)
                .unwrap_or(0.0),
        }
    }

    /// The rate at iteration 0 (what the built [`Problem`] starts with).
    pub fn initial(&self) -> f64 {
        self.at(0)
    }

    /// The smallest rate the trace ever admits (feasibility checks).
    pub fn min_rate(&self) -> f64 {
        match self {
            RateSpec::Constant(r) => *r,
            RateSpec::Trace(points) => {
                points.iter().map(|&(_, r)| r).fold(f64::INFINITY, f64::min)
            }
        }
    }
}

/// One task class: a named workload stream with its own sources, rate, and
/// (hidden) utility family.
#[derive(Clone, Debug, PartialEq)]
pub struct ClassSpec {
    pub name: String,
    /// Utility family name (`linear`, `sqrt`, `quadratic`, `log`).
    pub utility: String,
    pub rate: RateSpec,
    /// Source device ids traffic of this class is admitted through
    /// (empty = the hosts of version 0, the paper's layout).
    pub sources: Vec<usize>,
}

/// The full declarative scenario. See the [module docs](self).
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioSpec {
    pub name: String,
    pub topology: TopologySpec,
    /// Number of DNN versions W.
    pub n_versions: usize,
    /// Mean capacity C̄ for drawn link/compute capacities.
    pub cap_mean: f64,
    /// Default link cost family (per-edge overrides via [`EdgeSpec`]).
    pub cost: CostKind,
    /// Sparse per-device overrides.
    pub nodes: Vec<NodeSpec>,
    /// Task classes (at least one).
    pub classes: Vec<ClassSpec>,
    /// Outer-iteration horizon; required when any class rate is a trace.
    pub horizon: Option<usize>,
    /// Request-level simulation knobs (`None` = [`SimSpec::default`] when
    /// a sim run is requested; the field is omitted from the canonical
    /// JSON when absent, so sim-less specs keep their digests).
    pub sim: Option<SimSpec>,
    pub eta_routing: f64,
    pub eta_alloc: f64,
    pub delta: f64,
    pub seed: u64,
    pub workers: usize,
    /// Leader shards for the sharded coordination plane (`"sharded-omd"`;
    /// `None` = the single-leader default, omitted from canonical JSON so
    /// existing spec digests are stable).
    pub shards: Option<usize>,
    /// Staleness bound S for sharded rounds (`None` = the default S = 1;
    /// omitted from canonical JSON when absent).
    pub staleness: Option<usize>,
}

impl ScenarioSpec {
    /// The paper's Section-IV default as a single-class spec.
    pub fn paper_default() -> Self {
        Self::from_config(&ExperimentConfig::paper_default())
    }

    /// Lossless lowering of the scalar-knob config: every field of the
    /// config maps onto the spec (one class named `default`, sourced at
    /// the hosts of version 0, at the total rate with the config's
    /// utility family).
    pub fn from_config(cfg: &ExperimentConfig) -> Self {
        let topology = if cfg.topology == "er" {
            TopologySpec::Er { n_nodes: cfg.n_nodes, p_link: cfg.p_link }
        } else {
            TopologySpec::Named { name: cfg.topology.clone() }
        };
        ScenarioSpec {
            name: "scenario".to_string(),
            topology,
            n_versions: cfg.n_versions,
            cap_mean: cfg.cap_mean,
            cost: cfg.cost,
            nodes: Vec::new(),
            classes: vec![ClassSpec {
                name: "default".to_string(),
                utility: cfg.utility.clone(),
                rate: RateSpec::Constant(cfg.total_rate),
                sources: Vec::new(),
            }],
            horizon: None,
            sim: None,
            eta_routing: cfg.eta_routing,
            eta_alloc: cfg.eta_alloc,
            delta: cfg.delta,
            seed: cfg.seed,
            workers: cfg.workers,
            shards: None,
            staleness: None,
        }
    }

    /// Best-effort scalar view (the compatibility `Session::cfg`):
    /// `total_rate` is the sum of initial class rates, `utility` the first
    /// class's family.
    pub fn to_config(&self) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::paper_default();
        match &self.topology {
            TopologySpec::Er { n_nodes, p_link } => {
                cfg.topology = "er".to_string();
                cfg.n_nodes = *n_nodes;
                cfg.p_link = *p_link;
            }
            TopologySpec::Named { name } => {
                cfg.topology = name.clone();
            }
            TopologySpec::Explicit { n_nodes, .. } => {
                cfg.topology = "explicit".to_string();
                cfg.n_nodes = *n_nodes;
            }
        }
        cfg.n_versions = self.n_versions;
        cfg.cap_mean = self.cap_mean;
        cfg.cost = self.cost;
        cfg.total_rate = self.classes.iter().map(|c| c.rate.initial()).sum();
        cfg.utility =
            self.classes.first().map(|c| c.utility.clone()).unwrap_or_else(|| "log".into());
        cfg.eta_routing = self.eta_routing;
        cfg.eta_alloc = self.eta_alloc;
        cfg.delta = self.delta;
        cfg.seed = self.seed;
        cfg.workers = self.workers;
        cfg
    }

    /// The rate-trace breakpoints compiled to scheduled
    /// [`NetworkEvent::ClassRate`] events (empty for all-constant rates).
    pub fn events(&self) -> EventSchedule {
        let mut schedule = EventSchedule::new();
        for (c, class) in self.classes.iter().enumerate() {
            if let RateSpec::Trace(points) = &class.rate {
                for &(t, rate) in points {
                    if t > 0 {
                        schedule = schedule.at(t, NetworkEvent::ClassRate { class: c, rate });
                    }
                }
            }
        }
        schedule
    }

    /// Structural validation that needs no RNG or graph construction.
    /// [`ScenarioSpec::build`] calls this first, then adds the
    /// graph-dependent checks (source-node existence, version coverage,
    /// per-session connectivity).
    pub fn validate(&self) -> Result<(), SessionError> {
        if self.n_versions == 0 {
            return Err(invalid("n_versions must be >= 1"));
        }
        if !(self.cap_mean > 0.0) {
            return Err(invalid(&format!("cap_mean must be > 0 (got {})", self.cap_mean)));
        }
        if !(self.eta_routing > 0.0) {
            return Err(invalid(&format!(
                "eta_routing must be > 0 (got {})",
                self.eta_routing
            )));
        }
        if !(self.eta_alloc > 0.0) {
            return Err(invalid(&format!("eta_alloc must be > 0 (got {})", self.eta_alloc)));
        }
        match &self.topology {
            TopologySpec::Er { n_nodes, p_link } => {
                if *n_nodes < 2 {
                    return Err(invalid(&format!(
                        "ER topology needs >= 2 nodes (got {n_nodes})"
                    )));
                }
                if !(*p_link > 0.0 && *p_link <= 1.0) {
                    return Err(invalid(&format!(
                        "p_link must be in (0, 1] (got {p_link})"
                    )));
                }
            }
            TopologySpec::Named { name } => {
                if name == "er" || !topologies::KNOWN_NAMES.contains(&name.as_str()) {
                    return Err(SessionError::UnknownTopology { name: name.clone() });
                }
            }
            TopologySpec::Explicit { n_nodes, edges } => {
                if *n_nodes < 2 {
                    return Err(invalid(&format!(
                        "explicit topology needs >= 2 nodes (got {n_nodes})"
                    )));
                }
                if edges.is_empty() {
                    return Err(invalid("explicit topology has no edges"));
                }
                for (k, e) in edges.iter().enumerate() {
                    if e.src >= *n_nodes || e.dst >= *n_nodes {
                        return Err(invalid(&format!(
                            "edge {k} ({} -> {}) is out of range for {n_nodes} nodes",
                            e.src, e.dst
                        )));
                    }
                    if e.src == e.dst {
                        return Err(invalid(&format!("edge {k} is a self-loop ({})", e.src)));
                    }
                    if !(e.capacity > 0.0) {
                        return Err(invalid(&format!(
                            "edge {k} capacity must be > 0 (got {})",
                            e.capacity
                        )));
                    }
                }
                // duplicate directed pairs would trip the graph's
                // debug assertions much later; reject them here
                let mut pairs: Vec<(usize, usize)> = Vec::new();
                for e in edges {
                    pairs.push((e.src, e.dst));
                    if e.bidirectional {
                        pairs.push((e.dst, e.src));
                    }
                }
                pairs.sort_unstable();
                if pairs.windows(2).any(|w| w[0] == w[1]) {
                    return Err(invalid("explicit topology has duplicate directed edges"));
                }
            }
        }
        // node overrides
        let n_declared = match &self.topology {
            TopologySpec::Er { n_nodes, .. } | TopologySpec::Explicit { n_nodes, .. } => {
                Some(*n_nodes)
            }
            TopologySpec::Named { .. } => None, // node count known at build
        };
        let mut ids: Vec<usize> = self.nodes.iter().map(|n| n.id).collect();
        ids.sort_unstable();
        if ids.windows(2).any(|w| w[0] == w[1]) {
            return Err(invalid("duplicate node-spec ids"));
        }
        for node in &self.nodes {
            if let Some(n) = n_declared {
                if node.id >= n {
                    return Err(invalid(&format!(
                        "node spec id {} out of range for {n} nodes",
                        node.id
                    )));
                }
            }
            if let Some(cap) = node.compute_capacity {
                if !(cap > 0.0) {
                    return Err(invalid(&format!(
                        "node {} compute_capacity must be > 0 (got {cap})",
                        node.id
                    )));
                }
            }
            if let Some(v) = node.version {
                if v >= self.n_versions {
                    return Err(SessionError::UnsupportedVersion {
                        what: format!(
                            "node {} pins version {v}, but the scenario has only {} versions",
                            node.id, self.n_versions
                        ),
                    });
                }
            }
        }
        // classes
        if self.classes.is_empty() {
            return Err(invalid("at least one task class is required"));
        }
        let mut names: Vec<&str> = self.classes.iter().map(|c| c.name.as_str()).collect();
        names.sort_unstable();
        if names.windows(2).any(|w| w[0] == w[1]) {
            return Err(invalid("duplicate task class names"));
        }
        for class in &self.classes {
            if class.name.is_empty() {
                return Err(invalid("task class names must be non-empty"));
            }
            // utility families are consumed lazily, but an unknown name
            // should fail loudly here, not mid-experiment
            utility::family(&class.utility, self.n_versions, class.rate.initial().max(1.0))
                .ok_or_else(|| SessionError::UnknownUtility {
                    name: class.utility.clone(),
                })?;
            match &class.rate {
                RateSpec::Constant(r) => {
                    if !(*r > 0.0) {
                        return Err(invalid(&format!(
                            "class '{}' rate must be > 0 (got {r})",
                            class.name
                        )));
                    }
                }
                RateSpec::Trace(points) => {
                    let err = |what: &str| SessionError::InvalidTrace {
                        class: class.name.clone(),
                        what: what.to_string(),
                    };
                    if points.is_empty() {
                        return Err(err("trace has no points"));
                    }
                    if points[0].0 != 0 {
                        return Err(err("trace must start at iteration 0"));
                    }
                    if points.windows(2).any(|w| w[1].0 <= w[0].0) {
                        return Err(err("trace iterations must be strictly increasing"));
                    }
                    if points.iter().any(|&(_, r)| !(r > 0.0)) {
                        return Err(err("every trace rate must be > 0"));
                    }
                    match self.horizon {
                        None => {
                            return Err(err(
                                "rate traces need a scenario horizon (set `horizon`)",
                            ))
                        }
                        Some(h) => {
                            if let Some(&(t, _)) =
                                points.iter().find(|&&(t, _)| t >= h && t != 0)
                            {
                                return Err(err(&format!(
                                    "trace breakpoint at iteration {t} is outside the \
                                     horizon {h}"
                                )));
                            }
                        }
                    }
                }
            }
            // the allocation projection onto [δ, λ_c−δ]^W needs W·δ ≤ λ_c
            // at every rate the trace admits
            let min_rate = class.rate.min_rate();
            if !(self.delta > 0.0 && self.n_versions as f64 * self.delta <= min_rate) {
                return Err(invalid(&format!(
                    "class '{}': delta must satisfy 0 < n_versions*delta <= rate \
                     (delta {}, W {}, min rate {min_rate})",
                    class.name, self.delta, self.n_versions
                )));
            }
        }
        if let Some(sim) = &self.sim {
            sim.validate().map_err(|what| invalid(&what))?;
        }
        Ok(())
    }

    /// Validate the spec and build the [`Session`]: real graph, placement
    /// (respecting version pins), heterogeneous augmented network, and the
    /// multi-class [`Problem`]. A single-class spec lowered from scalar
    /// knobs builds a bit-identical problem to the legacy
    /// `ExperimentConfig::build_problem` path.
    pub fn build(self) -> Result<Session, SessionError> {
        self.validate()?;
        let mut rng = Rng::seed_from(self.seed);
        let real = match &self.topology {
            TopologySpec::Er { n_nodes, p_link } => {
                topologies::connected_er_graph(*n_nodes, *p_link, self.cap_mean, &mut rng)
            }
            TopologySpec::Named { name } => topologies::by_name(name, self.cap_mean, &mut rng)
                .ok_or_else(|| SessionError::UnknownTopology { name: name.clone() })?,
            TopologySpec::Explicit { n_nodes, edges } => {
                let mut g = DiGraph::with_nodes(*n_nodes);
                for e in edges {
                    g.add_edge(e.src, e.dst, e.capacity);
                    if e.bidirectional {
                        g.add_edge(e.dst, e.src, e.capacity);
                    }
                }
                if !g.strongly_connected() {
                    return Err(invalid(
                        "explicit topology must be strongly connected (every device \
                         must reach and be reachable from every other)",
                    ));
                }
                g
            }
        };
        let n_real = real.n_nodes();
        if n_real < self.n_versions {
            return Err(invalid(&format!(
                "{n_real} devices cannot host {} versions (need one device per version)",
                self.n_versions
            )));
        }
        for node in &self.nodes {
            if node.id >= n_real {
                return Err(invalid(&format!(
                    "node spec id {} out of range for {n_real} nodes",
                    node.id
                )));
            }
        }

        // placement: the no-pins path consumes the RNG exactly like the
        // legacy Placement::random (bit-identical default scenarios)
        let has_pins = self.nodes.iter().any(|n| n.version.is_some());
        let placement = if has_pins {
            let mut pins: Vec<Option<usize>> = vec![None; n_real];
            for node in &self.nodes {
                pins[node.id] = node.version;
            }
            Placement::with_pins(n_real, self.n_versions, &pins, &mut rng).ok_or_else(
                || SessionError::UnsupportedVersion {
                    what: format!(
                        "the version pins leave no hosting device for some of the {} \
                         versions",
                        self.n_versions
                    ),
                },
            )?
        } else {
            Placement::random(n_real, self.n_versions, &mut rng)
        };

        let mut node_caps: Vec<Option<f64>> = vec![None; n_real];
        for node in &self.nodes {
            node_caps[node.id] = node.compute_capacity;
        }

        // resolve class source sets (empty = hosts of version 0)
        let mut class_sources: Vec<Vec<usize>> = Vec::with_capacity(self.classes.len());
        for class in &self.classes {
            if class.sources.is_empty() {
                class_sources.push(placement.hosts(0).collect());
            } else {
                for &d in &class.sources {
                    if d >= n_real {
                        return Err(SessionError::UnknownSourceNode {
                            class: class.name.clone(),
                            node: d,
                        });
                    }
                }
                class_sources.push(class.sources.clone());
            }
        }

        let net = AugmentedNet::build_heterogeneous(
            &real,
            &placement,
            self.cap_mean,
            &node_caps,
            &class_sources,
            &mut rng,
        );
        // per-session admission connectivity: every class must be able to
        // reach every version's destination through its own sources
        for s in 0..net.n_sessions() {
            if net.lanes(s, AugmentedNet::SOURCE).is_empty() {
                let class = s / self.n_versions;
                return Err(SessionError::DisconnectedSource {
                    class: self.classes[class].name.clone(),
                    version: net.version_of_session(s),
                });
            }
        }
        if let Err(what) = net.validate() {
            return Err(SessionError::InvalidScenario { what });
        }

        let workload = Workload {
            class_names: self.classes.iter().map(|c| c.name.clone()).collect(),
            class_rates: self.classes.iter().map(|c| c.rate.initial()).collect(),
            class_spans: (0..self.classes.len())
                .map(|c| (c * self.n_versions, (c + 1) * self.n_versions))
                .collect(),
        };

        // per-edge cost overrides (explicit topologies only; real edges are
        // inserted first and in spec order, so edge ids line up)
        let edge_cost = match &self.topology {
            TopologySpec::Explicit { edges, .. }
                if edges.iter().any(|e| e.cost.is_some()) =>
            {
                let mut kinds = vec![self.cost; net.graph.n_edges()];
                let mut k = 0;
                for e in edges {
                    kinds[k] = e.cost.unwrap_or(self.cost);
                    k += 1;
                    if e.bidirectional {
                        kinds[k] = e.cost.unwrap_or(self.cost);
                        k += 1;
                    }
                }
                Some(kinds)
            }
            _ => None,
        };

        let problem =
            Problem::with_workload(net, self.cost, workload).with_edge_cost(edge_cost);
        Ok(Session { cfg: self.to_config(), problem, spec: self })
    }

    /// A stable content digest of the spec (FNV-1a over its canonical
    /// JSON, which round-trips every field including the seed). Two specs
    /// with equal digests build bit-identical [`Problem`]s — the key of
    /// [`crate::session::Suite`]'s problem/CSR cache.
    pub fn digest(&self) -> u64 {
        let mut h = crate::util::hash::Fnv64::new();
        h.write(self.to_json().to_string().as_bytes());
        h.finish()
    }

    /// Assemble a [`Session`] around a problem instance built earlier from
    /// a spec with the **same digest** (see [`ScenarioSpec::digest`]) —
    /// the cache-hit path of [`crate::session::Suite`]. Skips the graph
    /// generation, placement draw, and session-DAG/CSR rebuild; the
    /// resulting session is bit-identical to [`ScenarioSpec::build`]'s
    /// because problem construction is a pure function of the canonical
    /// spec JSON.
    pub fn build_with_problem(self, problem: Problem) -> Session {
        debug_assert_eq!(problem.n_sessions(), self.classes.len() * self.n_versions);
        Session { cfg: self.to_config(), problem, spec: self }
    }

    /// Parse a spec from JSON text. Missing top-level keys fall back to
    /// the paper defaults; unknown keys are warned about (never silently
    /// dropped).
    pub fn from_json(text: &str) -> Result<Self, String> {
        let j = Json::parse(text).map_err(|e| e.to_string())?;
        let obj = j.as_obj().ok_or("scenario file must be a JSON object")?;
        const KNOWN: [&str; 16] = [
            "name",
            "topology",
            "n_versions",
            "cap_mean",
            "cost",
            "nodes",
            "classes",
            "horizon",
            "sim",
            "eta_routing",
            "eta_alloc",
            "delta",
            "seed",
            "workers",
            "shards",
            "staleness",
        ];
        for key in obj.keys() {
            if !KNOWN.contains(&key.as_str()) {
                crate::log_warn!("scenario spec: ignoring unknown field '{key}'");
            }
        }
        // present-but-wrongly-typed fields are hard errors, never silent
        // fallbacks to the paper defaults
        let mut spec = ScenarioSpec::paper_default();
        if !matches!(j.get("name"), Json::Null) {
            spec.name = j
                .get("name")
                .as_str()
                .ok_or_else(|| format!("bad name '{}' (want a string)", j.get("name")))?
                .to_string();
        }
        if !matches!(j.get("topology"), Json::Null) {
            spec.topology = parse_topology(j.get("topology"))?;
        }
        if let Some(x) = opt_usize(&j, "n_versions")? {
            spec.n_versions = x;
        }
        if let Some(x) = opt_f64(&j, "cap_mean")? {
            spec.cap_mean = x;
        }
        if !matches!(j.get("cost"), Json::Null) {
            let c = j.get("cost");
            spec.cost = c
                .as_str()
                .and_then(CostKind::parse)
                .ok_or_else(|| format!("bad cost '{c}'"))?;
        }
        if !matches!(j.get("nodes"), Json::Null) {
            let nodes = j
                .get("nodes")
                .as_arr()
                .ok_or_else(|| format!("bad nodes '{}' (want an array)", j.get("nodes")))?;
            spec.nodes = nodes.iter().map(parse_node).collect::<Result<_, _>>()?;
        }
        if !matches!(j.get("classes"), Json::Null) {
            let classes = j
                .get("classes")
                .as_arr()
                .ok_or_else(|| format!("bad classes '{}' (want an array)", j.get("classes")))?;
            spec.classes = classes.iter().map(parse_class).collect::<Result<_, _>>()?;
        }
        if let Some(h) = opt_usize(&j, "horizon")? {
            spec.horizon = Some(h);
        }
        if !matches!(j.get("sim"), Json::Null) {
            spec.sim = Some(SimSpec::from_json(j.get("sim"))?);
        }
        if let Some(x) = opt_f64(&j, "eta_routing")? {
            spec.eta_routing = x;
        }
        if let Some(x) = opt_f64(&j, "eta_alloc")? {
            spec.eta_alloc = x;
        }
        if let Some(x) = opt_f64(&j, "delta")? {
            spec.delta = x;
        }
        if let Some(x) = opt_usize(&j, "workers")? {
            spec.workers = x;
        }
        if let Some(x) = opt_usize(&j, "shards")? {
            spec.shards = Some(x);
        }
        if let Some(x) = opt_usize(&j, "staleness")? {
            spec.staleness = Some(x);
        }
        if !matches!(j.get("seed"), Json::Null) {
            spec.seed = j
                .get("seed")
                .as_u64()
                .ok_or_else(|| format!("bad seed '{}' (not a u64)", j.get("seed")))?;
        }
        Ok(spec)
    }

    /// Load a spec from a JSON file.
    pub fn from_file(path: &Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("{}: {e}", path.display()))?;
        Self::from_json(&text).map_err(|e| format!("{}: {e}", path.display()))
    }

    /// Serialize the spec (the inverse of [`ScenarioSpec::from_json`]:
    /// every field round-trips).
    pub fn to_json(&self) -> Json {
        let topology = match &self.topology {
            TopologySpec::Er { n_nodes, p_link } => Json::obj(vec![
                ("kind", Json::from("er")),
                ("n_nodes", Json::from(*n_nodes)),
                ("p_link", Json::from(*p_link)),
            ]),
            TopologySpec::Named { name } => Json::obj(vec![
                ("kind", Json::from("named")),
                ("name", Json::from(name.as_str())),
            ]),
            TopologySpec::Explicit { n_nodes, edges } => Json::obj(vec![
                ("kind", Json::from("explicit")),
                ("n_nodes", Json::from(*n_nodes)),
                (
                    "edges",
                    Json::Arr(
                        edges
                            .iter()
                            .map(|e| {
                                let mut fields = vec![
                                    ("src", Json::from(e.src)),
                                    ("dst", Json::from(e.dst)),
                                    ("capacity", Json::from(e.capacity)),
                                    ("bidirectional", Json::from(e.bidirectional)),
                                ];
                                if let Some(c) = e.cost {
                                    fields.push(("cost", Json::from(cost_name(c))));
                                }
                                Json::obj(fields)
                            })
                            .collect(),
                    ),
                ),
            ]),
        };
        let nodes = Json::Arr(
            self.nodes
                .iter()
                .map(|n| {
                    let mut fields = vec![("id", Json::from(n.id))];
                    if let Some(c) = n.compute_capacity {
                        fields.push(("compute_capacity", Json::from(c)));
                    }
                    if let Some(v) = n.version {
                        fields.push(("version", Json::from(v)));
                    }
                    Json::obj(fields)
                })
                .collect(),
        );
        let classes = Json::Arr(
            self.classes
                .iter()
                .map(|c| {
                    let rate = match &c.rate {
                        RateSpec::Constant(r) => Json::from(*r),
                        RateSpec::Trace(points) => Json::obj(vec![(
                            "trace",
                            Json::Arr(
                                points
                                    .iter()
                                    .map(|&(t, r)| {
                                        Json::Arr(vec![Json::from(t), Json::from(r)])
                                    })
                                    .collect(),
                            ),
                        )]),
                    };
                    Json::obj(vec![
                        ("name", Json::from(c.name.as_str())),
                        ("utility", Json::from(c.utility.as_str())),
                        ("rate", rate),
                        (
                            "sources",
                            Json::Arr(c.sources.iter().map(|&d| Json::from(d)).collect()),
                        ),
                    ])
                })
                .collect(),
        );
        let mut fields = vec![
            ("name", Json::from(self.name.as_str())),
            ("topology", topology),
            ("n_versions", Json::from(self.n_versions)),
            ("cap_mean", Json::from(self.cap_mean)),
            ("cost", Json::from(cost_name(self.cost))),
            ("nodes", nodes),
            ("classes", classes),
            ("eta_routing", Json::from(self.eta_routing)),
            ("eta_alloc", Json::from(self.eta_alloc)),
            ("delta", Json::from(self.delta)),
            ("workers", Json::from(self.workers)),
            ("seed", Json::from_u64(self.seed)),
        ];
        if let Some(h) = self.horizon {
            fields.push(("horizon", Json::from(h)));
        }
        if let Some(sim) = &self.sim {
            fields.push(("sim", sim.to_json()));
        }
        if let Some(k) = self.shards {
            fields.push(("shards", Json::from(k)));
        }
        if let Some(s) = self.staleness {
            fields.push(("staleness", Json::from(s)));
        }
        Json::obj(fields)
    }
}

fn invalid(what: &str) -> SessionError {
    SessionError::InvalidScenario { what: what.to_string() }
}

/// Typed optional field: `Ok(None)` when absent, an error (never a silent
/// default) when present with the wrong type.
fn opt_f64(j: &Json, key: &str) -> Result<Option<f64>, String> {
    match j.get(key) {
        Json::Null => Ok(None),
        v => v
            .as_f64()
            .map(Some)
            .ok_or_else(|| format!("bad {key} '{v}' (want a number)")),
    }
}

/// Typed optional field: exact non-negative integers only (`2.5` is an
/// error, not a truncation).
fn opt_usize(j: &Json, key: &str) -> Result<Option<usize>, String> {
    match j.get(key) {
        Json::Null => Ok(None),
        v => match v.as_f64() {
            Some(x) if x >= 0.0 && x.fract() == 0.0 => Ok(Some(x as usize)),
            _ => Err(format!("bad {key} '{v}' (want a non-negative integer)")),
        },
    }
}

fn cost_name(kind: CostKind) -> &'static str {
    match kind {
        CostKind::Exp => "exp",
        CostKind::Queue => "queue",
        CostKind::Linear => "linear",
        CostKind::Cubic => "cubic",
    }
}

fn parse_topology(j: &Json) -> Result<TopologySpec, String> {
    let kind = j.get("kind").as_str().ok_or("topology needs a 'kind' field")?;
    match kind {
        "er" => Ok(TopologySpec::Er {
            n_nodes: j.get("n_nodes").as_usize().ok_or("er topology needs n_nodes")?,
            p_link: j.get("p_link").as_f64().ok_or("er topology needs p_link")?,
        }),
        "named" => Ok(TopologySpec::Named {
            name: j
                .get("name")
                .as_str()
                .ok_or("named topology needs a 'name' field")?
                .to_string(),
        }),
        "explicit" => {
            let edges = j
                .get("edges")
                .as_arr()
                .ok_or("explicit topology needs an 'edges' array")?
                .iter()
                .map(parse_edge)
                .collect::<Result<_, _>>()?;
            Ok(TopologySpec::Explicit {
                n_nodes: j.get("n_nodes").as_usize().ok_or("explicit topology needs n_nodes")?,
                edges,
            })
        }
        other => Err(format!("unknown topology kind '{other}' (er | named | explicit)")),
    }
}

fn parse_edge(j: &Json) -> Result<EdgeSpec, String> {
    let cost = match j.get("cost") {
        Json::Null => None,
        c => Some(
            c.as_str()
                .and_then(CostKind::parse)
                .ok_or_else(|| format!("bad edge cost '{c}'"))?,
        ),
    };
    let bidirectional = match j.get("bidirectional") {
        Json::Null => true,
        v => v
            .as_bool()
            .ok_or_else(|| format!("bad bidirectional '{v}' (want a bool)"))?,
    };
    Ok(EdgeSpec {
        src: opt_usize(j, "src")?.ok_or("edge needs src")?,
        dst: opt_usize(j, "dst")?.ok_or("edge needs dst")?,
        capacity: opt_f64(j, "capacity")?.ok_or("edge needs capacity")?,
        bidirectional,
        cost,
    })
}

fn parse_node(j: &Json) -> Result<NodeSpec, String> {
    Ok(NodeSpec {
        id: opt_usize(j, "id")?.ok_or("node spec needs id")?,
        compute_capacity: opt_f64(j, "compute_capacity")?,
        version: opt_usize(j, "version")?,
    })
}

fn parse_class(j: &Json) -> Result<ClassSpec, String> {
    let rate = match j.get("rate") {
        Json::Num(r) => RateSpec::Constant(*r),
        obj @ Json::Obj(_) => {
            let points = obj
                .get("trace")
                .as_arr()
                .ok_or("class rate object needs a 'trace' array")?
                .iter()
                .map(|p| {
                    let pair = p.as_arr().filter(|a| a.len() == 2).ok_or_else(|| {
                        format!("trace points are [iteration, rate] pairs (got {p})")
                    })?;
                    let t = match pair[0].as_f64() {
                        Some(x) if x >= 0.0 && x.fract() == 0.0 => x as usize,
                        _ => return Err(format!("bad trace iteration '{}'", pair[0])),
                    };
                    let r = pair[1]
                        .as_f64()
                        .ok_or_else(|| format!("bad trace rate '{}'", pair[1]))?;
                    Ok::<(usize, f64), String>((t, r))
                })
                .collect::<Result<Vec<_>, _>>()?;
            RateSpec::Trace(points)
        }
        other => return Err(format!("bad class rate '{other}' (number or {{\"trace\": ..}})")),
    };
    let sources = match j.get("sources") {
        Json::Null => Vec::new(),
        arr => arr
            .as_arr()
            .ok_or("class sources must be an array of device ids")?
            .iter()
            .map(|v| match v.as_f64() {
                Some(x) if x >= 0.0 && x.fract() == 0.0 => Ok(x as usize),
                _ => Err(format!("bad source device '{v}'")),
            })
            .collect::<Result<_, _>>()?,
    };
    let utility = match j.get("utility") {
        Json::Null => "log".to_string(),
        v => v
            .as_str()
            .ok_or_else(|| format!("bad class utility '{v}' (want a string)"))?
            .to_string(),
    };
    Ok(ClassSpec {
        name: j.get("name").as_str().ok_or("class needs a name")?.to_string(),
        utility,
        rate,
        sources,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_class_spec() -> ScenarioSpec {
        let mut spec = ScenarioSpec::paper_default();
        spec.name = "two-class".into();
        spec.n_versions = 2;
        spec.classes = vec![
            ClassSpec {
                name: "video".into(),
                utility: "log".into(),
                rate: RateSpec::Constant(40.0),
                sources: vec![0, 1],
            },
            ClassSpec {
                name: "audio".into(),
                utility: "sqrt".into(),
                rate: RateSpec::Constant(20.0),
                sources: Vec::new(),
            },
        ];
        spec
    }

    #[test]
    fn paper_default_builds_bit_identical_to_config_path() {
        let cfg = ExperimentConfig::paper_default();
        let mut rng = Rng::seed_from(cfg.seed);
        let legacy = cfg.build_problem(&mut rng).unwrap();
        let session = ScenarioSpec::paper_default().build().unwrap();
        assert_eq!(session.problem.net.graph.n_edges(), legacy.net.graph.n_edges());
        for (a, b) in session.problem.net.graph.edges().iter().zip(legacy.net.graph.edges()) {
            assert_eq!(a, b);
        }
        assert_eq!(
            session.problem.net.placement.version_of,
            legacy.net.placement.version_of
        );
        assert_eq!(session.problem.net.csr.lane_edge, legacy.net.csr.lane_edge);
        assert_eq!(session.problem.total_rate, legacy.total_rate);
    }

    #[test]
    fn two_class_spec_builds_class_major_sessions() {
        let session = two_class_spec().build().unwrap();
        let p = &session.problem;
        assert_eq!(p.n_sessions(), 4);
        assert_eq!(p.n_versions(), 2);
        assert_eq!(p.workload.n_classes(), 2);
        assert!((p.total_rate - 60.0).abs() < 1e-12);
        assert_eq!(p.workload.class_spans, vec![(0, 2), (2, 4)]);
        let lam = p.uniform_allocation();
        assert_eq!(lam, vec![20.0, 20.0, 10.0, 10.0]);
    }

    #[test]
    fn json_roundtrip_every_field() {
        let mut spec = two_class_spec();
        spec.topology = TopologySpec::Explicit {
            n_nodes: 3,
            edges: vec![
                EdgeSpec {
                    src: 0,
                    dst: 1,
                    capacity: 12.0,
                    bidirectional: true,
                    cost: Some(CostKind::Queue),
                },
                EdgeSpec { src: 1, dst: 2, capacity: 8.0, bidirectional: true, cost: None },
                EdgeSpec { src: 2, dst: 0, capacity: 6.5, bidirectional: true, cost: None },
            ],
        };
        spec.nodes = vec![
            NodeSpec { id: 0, compute_capacity: Some(25.0), version: Some(0) },
            NodeSpec { id: 2, compute_capacity: None, version: Some(1) },
        ];
        spec.classes[1].rate = RateSpec::Trace(vec![(0, 20.0), (40, 35.0)]);
        spec.horizon = Some(100);
        spec.sim = Some(crate::sim::SimSpec {
            horizon_s: 45.0,
            warmup_s: 5.0,
            queue_capacity: 128,
            servers_per_node: 2,
            discipline: crate::sim::Discipline::Lifo,
            trace_window_s: 0.5,
            latency: crate::sim::LatencyMode::Hdr,
        });
        spec.seed = u64::MAX; // exercises the string-seed path
        spec.workers = 4;
        spec.shards = Some(4);
        spec.staleness = Some(2);
        spec.cost = CostKind::Cubic;
        let text = spec.to_json().to_string();
        let back = ScenarioSpec::from_json(&text).unwrap();
        assert_eq!(back, spec, "round-trip mismatch; json was {text}");
    }

    #[test]
    fn named_and_er_topologies_roundtrip() {
        for topo in [
            TopologySpec::Er { n_nodes: 14, p_link: 0.25 },
            TopologySpec::Named { name: "star".into() },
        ] {
            let mut spec = ScenarioSpec::paper_default();
            spec.topology = topo.clone();
            let back = ScenarioSpec::from_json(&spec.to_json().to_string()).unwrap();
            assert_eq!(back.topology, topo);
        }
    }

    #[test]
    fn partial_json_uses_defaults() {
        let spec = ScenarioSpec::from_json(r#"{"n_versions": 4}"#).unwrap();
        assert_eq!(spec.n_versions, 4);
        assert_eq!(spec.classes.len(), 1);
        assert_eq!(spec.classes[0].rate, RateSpec::Constant(60.0));
        assert!(matches!(spec.topology, TopologySpec::Er { n_nodes: 25, .. }));
    }

    #[test]
    fn wrongly_typed_known_fields_are_errors_not_defaults() {
        // a present-but-mistyped field must never silently fall back
        assert!(ScenarioSpec::from_json(r#"{"cap_mean": "12.0"}"#).is_err());
        assert!(ScenarioSpec::from_json(r#"{"n_versions": 2.5}"#).is_err());
        assert!(ScenarioSpec::from_json(r#"{"n_versions": -1}"#).is_err());
        assert!(ScenarioSpec::from_json(r#"{"nodes": 3}"#).is_err());
        assert!(ScenarioSpec::from_json(r#"{"classes": "video"}"#).is_err());
        assert!(ScenarioSpec::from_json(r#"{"horizon": "soon"}"#).is_err());
        assert!(ScenarioSpec::from_json(r#"{"shards": 2.5}"#).is_err());
        assert!(ScenarioSpec::from_json(r#"{"staleness": -1}"#).is_err());
        assert!(ScenarioSpec::from_json(r#"{"name": 7}"#).is_err());
        assert!(ScenarioSpec::from_json(r#"{"sim": 3}"#).is_err());
        assert!(ScenarioSpec::from_json(r#"{"sim": {"horizon_s": "long"}}"#).is_err());
        assert!(ScenarioSpec::from_json(r#"{"sim": {"queue_capacity": 2.5}}"#).is_err());
        assert!(ScenarioSpec::from_json(r#"{"sim": {"discipline": "random"}}"#).is_err());
        assert!(ScenarioSpec::from_json(
            r#"{"classes": [{"name": "a", "utility": "log", "rate": 10.0,
                 "sources": [1.5]}]}"#
        )
        .is_err());
        assert!(ScenarioSpec::from_json(
            r#"{"nodes": [{"id": 0, "version": 1.5}]}"#
        )
        .is_err());
    }

    #[test]
    fn validation_unknown_source_node() {
        let mut spec = two_class_spec();
        spec.classes[0].sources = vec![999];
        assert!(matches!(
            spec.build(),
            Err(SessionError::UnknownSourceNode { node: 999, .. })
        ));
    }

    #[test]
    fn validation_unsupported_version() {
        let mut spec = ScenarioSpec::paper_default();
        spec.nodes = vec![NodeSpec { id: 0, compute_capacity: None, version: Some(7) }];
        assert!(matches!(spec.build(), Err(SessionError::UnsupportedVersion { .. })));
        // pins that leave a version uncovered on a tiny network
        let mut spec = ScenarioSpec::paper_default();
        spec.topology = TopologySpec::Explicit {
            n_nodes: 2,
            edges: vec![EdgeSpec {
                src: 0,
                dst: 1,
                capacity: 10.0,
                bidirectional: true,
                cost: None,
            }],
        };
        spec.n_versions = 2;
        spec.delta = 0.1;
        spec.nodes = vec![
            NodeSpec { id: 0, compute_capacity: None, version: Some(0) },
            NodeSpec { id: 1, compute_capacity: None, version: Some(0) },
        ];
        assert!(matches!(spec.build(), Err(SessionError::UnsupportedVersion { .. })));
    }

    #[test]
    fn validation_trace_errors() {
        let mut spec = two_class_spec();
        // no horizon
        spec.classes[0].rate = RateSpec::Trace(vec![(0, 30.0), (10, 40.0)]);
        assert!(matches!(spec.clone().build(), Err(SessionError::InvalidTrace { .. })));
        // breakpoint outside the horizon
        spec.horizon = Some(5);
        assert!(matches!(spec.clone().build(), Err(SessionError::InvalidTrace { .. })));
        // not starting at 0
        spec.horizon = Some(50);
        spec.classes[0].rate = RateSpec::Trace(vec![(3, 30.0)]);
        assert!(matches!(spec.clone().build(), Err(SessionError::InvalidTrace { .. })));
        // non-increasing iterations
        spec.classes[0].rate = RateSpec::Trace(vec![(0, 30.0), (10, 40.0), (10, 45.0)]);
        assert!(matches!(spec.clone().build(), Err(SessionError::InvalidTrace { .. })));
        // a valid trace builds and compiles to events
        spec.classes[0].rate = RateSpec::Trace(vec![(0, 30.0), (10, 40.0)]);
        let session = spec.clone().build().unwrap();
        assert_eq!(session.problem.workload.class_rates[0], 30.0);
        let schedule = spec.events();
        assert_eq!(schedule.fire(10).count(), 1);
        assert_eq!(schedule.fire(0).count(), 0);
    }

    #[test]
    fn validation_misc_errors() {
        let mut spec = ScenarioSpec::paper_default();
        spec.classes.clear();
        assert!(spec.build().is_err());

        let mut spec = ScenarioSpec::paper_default();
        spec.classes[0].utility = "cosine".into();
        assert!(matches!(spec.build(), Err(SessionError::UnknownUtility { .. })));

        let mut spec = ScenarioSpec::paper_default();
        spec.topology = TopologySpec::Named { name: "moebius".into() };
        assert!(matches!(spec.build(), Err(SessionError::UnknownTopology { .. })));

        // disconnected explicit topology
        let mut spec = ScenarioSpec::paper_default();
        spec.n_versions = 2;
        spec.delta = 0.1;
        spec.topology = TopologySpec::Explicit {
            n_nodes: 4,
            edges: vec![
                EdgeSpec { src: 0, dst: 1, capacity: 5.0, bidirectional: true, cost: None },
                EdgeSpec { src: 2, dst: 3, capacity: 5.0, bidirectional: true, cost: None },
            ],
        };
        assert!(spec.build().is_err());
    }

    #[test]
    fn rate_spec_evaluation() {
        let trace = RateSpec::Trace(vec![(0, 10.0), (5, 20.0), (9, 15.0)]);
        assert_eq!(trace.at(0), 10.0);
        assert_eq!(trace.at(4), 10.0);
        assert_eq!(trace.at(5), 20.0);
        assert_eq!(trace.at(100), 15.0);
        assert_eq!(trace.initial(), 10.0);
        assert_eq!(trace.min_rate(), 10.0);
        assert_eq!(RateSpec::Constant(7.0).at(42), 7.0);
    }

    #[test]
    fn per_edge_costs_land_in_the_problem() {
        let mut spec = ScenarioSpec::paper_default();
        spec.n_versions = 2;
        spec.delta = 0.1;
        spec.topology = TopologySpec::Explicit {
            n_nodes: 3,
            edges: vec![
                EdgeSpec {
                    src: 0,
                    dst: 1,
                    capacity: 10.0,
                    bidirectional: true,
                    cost: Some(CostKind::Queue),
                },
                EdgeSpec { src: 1, dst: 2, capacity: 10.0, bidirectional: true, cost: None },
                EdgeSpec { src: 2, dst: 0, capacity: 10.0, bidirectional: true, cost: None },
            ],
        };
        let session = spec.build().unwrap();
        let p = &session.problem;
        assert!(p.edge_cost.is_some());
        // explicit real edges come first, in spec order (fwd then reverse)
        assert_eq!(p.edge_kind(0), CostKind::Queue);
        assert_eq!(p.edge_kind(1), CostKind::Queue);
        assert_eq!(p.edge_kind(2), CostKind::Exp);
        // virtual edges use the scenario default
        assert_eq!(p.edge_kind(p.net.graph.n_edges() - 1), CostKind::Exp);
    }

    #[test]
    fn heterogeneous_node_caps_are_applied() {
        let mut spec = ScenarioSpec::paper_default();
        spec.nodes = vec![NodeSpec { id: 3, compute_capacity: Some(123.0), version: None }];
        let session = spec.build().unwrap();
        let net = &session.problem.net;
        // device 3's computation link has exactly the pinned capacity
        let v = net.placement.version_of[3];
        let e = net
            .graph
            .find_edge(net.device_node(3), net.n_real + 1 + v)
            .expect("computation link");
        assert_eq!(net.graph.edge(e).capacity, 123.0);
    }

    #[test]
    fn config_lowering_is_lossless() {
        let mut cfg = ExperimentConfig::paper_default();
        cfg.n_nodes = 18;
        cfg.p_link = 0.4;
        cfg.cap_mean = 12.0;
        cfg.n_versions = 4;
        cfg.total_rate = 80.0;
        cfg.cost = CostKind::Queue;
        cfg.utility = "sqrt".into();
        cfg.eta_routing = 0.25;
        cfg.eta_alloc = 0.01;
        cfg.delta = 0.2;
        cfg.seed = 99;
        cfg.workers = 3;
        let spec = ScenarioSpec::from_config(&cfg);
        let back = spec.to_config();
        assert_eq!(back.topology, cfg.topology);
        assert_eq!(back.n_nodes, cfg.n_nodes);
        assert_eq!(back.p_link, cfg.p_link);
        assert_eq!(back.cap_mean, cfg.cap_mean);
        assert_eq!(back.n_versions, cfg.n_versions);
        assert_eq!(back.total_rate, cfg.total_rate);
        assert_eq!(back.cost, cfg.cost);
        assert_eq!(back.utility, cfg.utility);
        assert_eq!(back.eta_routing, cfg.eta_routing);
        assert_eq!(back.eta_alloc, cfg.eta_alloc);
        assert_eq!(back.delta, cfg.delta);
        assert_eq!(back.seed, cfg.seed);
        assert_eq!(back.workers, cfg.workers);
    }
}
