//! **`Suite`** — evaluate a `(scenario × solver × seed)` grid in parallel.
//!
//! A suite crosses a set of [`ScenarioSpec`]s (inline or loaded from
//! files) with routing and/or allocation solvers (by registry name) and
//! seeds. Cells execute on the same persistent
//! [`crate::engine::pool::WorkerPool`] the flow engine uses — each cell
//! builds its own [`crate::session::Session`] and streams a run to
//! completion, so results are deterministic and independent of scheduling
//! — and the per-cell [`RunReport`]s (plus trajectories) collect into a
//! [`SuiteReport`] with CSV + JSON dumps.
//!
//! Allocation cells honor scenario rate traces: the spec's
//! [`ScenarioSpec::events`] schedule is applied between outer iterations,
//! exactly like the Fig. 11 harness applies topology changes.
//!
//! ```no_run
//! use jowr::prelude::*;
//!
//! let report = Suite::new()
//!     .spec("paper", ScenarioSpec::paper_default())
//!     .router("omd")
//!     .router("sgp")
//!     .seeds(&[1, 2, 3])
//!     .iters(50)
//!     .workers(0) // auto
//!     .run();
//! println!("{}", report.to_csv());
//! ```

use std::collections::BTreeMap;
use std::ops::ControlFlow;
use std::path::Path;
use std::sync::Mutex;

use super::run::{RunReport, Trajectory};
use super::spec::ScenarioSpec;
use super::SessionError;
use crate::coordinator::events::EventSchedule;
use crate::engine::pool::WorkerPool;
use crate::model::Problem;
use crate::util::json::Json;

/// Spec-digest-keyed problem cache shared by a suite's cells: cells whose
/// specs are identical (same canonical JSON, seed included) reuse one
/// built [`Problem`] — graph generation, capacity draws, session-DAG and
/// CSR construction happen once per unique topology instead of once per
/// `(solver × seed)` cell. Problem construction is a pure function of the
/// canonical spec, so cached cells are bit-identical to rebuilt ones
/// (asserted by the suite tests).
type ProblemCache = Mutex<BTreeMap<u64, Problem>>;

/// Which half of the solver registry a suite entry addresses — or the
/// request-level simulator replaying a router's optimized configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SolverKind {
    Router,
    Allocator,
    /// Optimize φ with the named router, then replay the scenario's
    /// request stream through [`crate::sim`]; the cell objective is the
    /// drained mean end-to-end latency and [`CellResult::sim`] carries the
    /// full [`crate::sim::SimReport`] as JSON.
    Sim,
}

/// Sim-time windows a suite's sim cells stream through (the window count
/// only shapes the trajectory — event history is window-invariant).
const SIM_WINDOWS: usize = 8;

/// One solver of the grid: a registry name plus its kind.
#[derive(Clone, Debug)]
pub struct SolverRef {
    pub kind: SolverKind,
    pub name: String,
}

/// The grid: specs × solvers × seeds. Build with the chainable setters,
/// execute with [`Suite::run`].
#[derive(Clone, Debug)]
pub struct Suite {
    specs: Vec<(String, ScenarioSpec)>,
    solvers: Vec<SolverRef>,
    seeds: Vec<u64>,
    iters: usize,
    workers: usize,
    problem_cache: bool,
}

impl Default for Suite {
    /// Identical to [`Suite::new`] (50 iterations, sequential cells) — a
    /// derived all-zero default would silently build zero-iteration cells.
    fn default() -> Self {
        Self::new()
    }
}

/// A successful cell: the unified report plus the per-iteration objective
/// trajectory (and, for sim cells, the full simulation roll-up).
#[derive(Clone, Debug)]
pub struct CellResult {
    pub report: RunReport,
    pub trajectory: Vec<f64>,
    /// The [`crate::sim::SimReport`] of a [`SolverKind::Sim`] cell
    /// (per-class percentiles, node telemetry, drops); `None` otherwise.
    pub sim: Option<Json>,
}

/// One evaluated grid cell.
#[derive(Clone, Debug)]
pub struct SuiteCell {
    pub scenario: String,
    pub solver: String,
    pub kind: SolverKind,
    /// The seed the cell actually ran with (the grid seed, or the spec's
    /// own seed when the suite declares none).
    pub seed: u64,
    /// The run outcome; build/validation/solver-lookup failures land here
    /// as messages instead of aborting the rest of the grid.
    pub outcome: Result<CellResult, String>,
}

/// Every cell of an executed suite, in grid order (scenario-major, then
/// solver, then seed).
#[derive(Clone, Debug)]
pub struct SuiteReport {
    pub cells: Vec<SuiteCell>,
}

impl Suite {
    pub fn new() -> Self {
        Suite {
            specs: Vec::new(),
            solvers: Vec::new(),
            seeds: Vec::new(),
            iters: 50,
            workers: 1,
            problem_cache: true,
        }
    }

    /// Add an inline scenario under a display name.
    pub fn spec(mut self, name: &str, spec: ScenarioSpec) -> Self {
        self.specs.push((name.to_string(), spec));
        self
    }

    /// Load a scenario file (`*.json`); the display name is the file stem.
    pub fn scenario_file(self, path: &Path) -> Result<Self, String> {
        let spec = ScenarioSpec::from_file(path)?;
        let name = path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| path.display().to_string());
        Ok(self.spec(&name, spec))
    }

    /// Add a routing solver by registry name.
    pub fn router(mut self, name: &str) -> Self {
        self.solvers.push(SolverRef { kind: SolverKind::Router, name: name.to_string() });
        self
    }

    /// Add an allocation solver by registry name.
    pub fn allocator(mut self, name: &str) -> Self {
        self.solvers.push(SolverRef { kind: SolverKind::Allocator, name: name.to_string() });
        self
    }

    /// Add a request-level simulation column: optimize φ with the named
    /// router (the cell's iteration budget), then replay the scenario's
    /// request stream against the optimized `(Λ, φ)` on the
    /// discrete-event core.
    pub fn sim(mut self, router: &str) -> Self {
        self.solvers.push(SolverRef { kind: SolverKind::Sim, name: router.to_string() });
        self
    }

    /// Seeds to cross the grid with. Empty (the default) = one cell per
    /// (spec, solver) at the spec's own seed.
    pub fn seeds(mut self, seeds: &[u64]) -> Self {
        self.seeds = seeds.to_vec();
        self
    }

    /// Iteration budget per cell (routing iterations / allocation outer
    /// iterations). When a scenario declares a horizon, allocation cells
    /// run `min(iters, horizon)` so traces stay inside their domain.
    pub fn iters(mut self, iters: usize) -> Self {
        self.iters = iters;
        self
    }

    /// Cells executed concurrently (`0` = auto-detect, `1` = sequential).
    /// Cell results are independent of the worker count.
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    /// Share one built problem instance among cells with identical specs
    /// (default on). Cells crossing several solvers over one `(spec,
    /// seed)` then skip the repeated graph/placement/CSR construction;
    /// results are bit-identical either way.
    pub fn cache_problems(mut self, on: bool) -> Self {
        self.problem_cache = on;
        self
    }

    /// Total number of grid cells.
    pub fn n_cells(&self) -> usize {
        self.specs.len() * self.solvers.len() * self.seeds.len().max(1)
    }

    /// Execute every cell (in parallel when `workers > 1`) and collect the
    /// report. Never panics on a bad cell: failures are carried in
    /// [`SuiteCell::outcome`].
    pub fn run(&self) -> SuiteReport {
        let mut grid: Vec<(usize, usize, Option<u64>)> = Vec::with_capacity(self.n_cells());
        for spec_idx in 0..self.specs.len() {
            for solver_idx in 0..self.solvers.len() {
                if self.seeds.is_empty() {
                    grid.push((spec_idx, solver_idx, None));
                } else {
                    for &seed in &self.seeds {
                        grid.push((spec_idx, solver_idx, Some(seed)));
                    }
                }
            }
        }
        let mut results: Vec<Option<SuiteCell>> = (0..grid.len()).map(|_| None).collect();
        let workers = self.effective_workers(grid.len());
        let cache: ProblemCache = Mutex::new(BTreeMap::new());
        let cache = &cache;
        if workers <= 1 || grid.len() <= 1 {
            for (slot, desc) in results.iter_mut().zip(&grid) {
                *slot = Some(self.run_cell(*desc, cache));
            }
        } else {
            // same dispatch shape as the engine's per-session sweeps:
            // chunk 0 on the caller thread, chunk i on pool thread i−1
            let pool = WorkerPool::new(workers - 1);
            let chunk = grid.len().div_ceil(workers);
            let mut result_chunks = results.chunks_mut(chunk);
            let mut grid_chunks = grid.chunks(chunk);
            let own_results = result_chunks.next().expect("at least one chunk");
            let own_grid = grid_chunks.next().expect("at least one chunk");
            let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            for (slots, descs) in result_chunks.zip(grid_chunks) {
                tasks.push(Box::new(move || {
                    for (slot, desc) in slots.iter_mut().zip(descs) {
                        *slot = Some(self.run_cell(*desc, cache));
                    }
                }));
            }
            pool.run_scoped(tasks, move || {
                for (slot, desc) in own_results.iter_mut().zip(own_grid) {
                    *slot = Some(self.run_cell(*desc, cache));
                }
            });
        }
        SuiteReport { cells: results.into_iter().map(|c| c.expect("cell ran")).collect() }
    }

    fn effective_workers(&self, n_cells: usize) -> usize {
        let requested = if self.workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            self.workers
        };
        requested.clamp(1, n_cells.max(1))
    }

    fn run_cell(
        &self,
        (spec_idx, solver_idx, seed): (usize, usize, Option<u64>),
        cache: &ProblemCache,
    ) -> SuiteCell {
        let (spec_name, base_spec) = &self.specs[spec_idx];
        let solver = &self.solvers[solver_idx];
        let mut spec = base_spec.clone();
        if let Some(s) = seed {
            spec.seed = s;
        }
        let seed_used = spec.seed;
        let outcome = self.execute(spec, solver, cache).map_err(|e| e.to_string());
        SuiteCell {
            scenario: spec_name.clone(),
            solver: solver.name.clone(),
            kind: solver.kind,
            seed: seed_used,
            outcome,
        }
    }

    /// Build the cell's session — through the spec-digest problem cache
    /// when enabled (the seed is part of the canonical JSON, so distinct
    /// seeds never collide; concurrent misses on one digest build the same
    /// deterministic problem and insert equal values).
    fn build_session(
        &self,
        spec: ScenarioSpec,
        cache: &ProblemCache,
    ) -> Result<super::Session, SessionError> {
        if !self.problem_cache {
            return spec.build();
        }
        let digest = spec.digest();
        let hit = cache.lock().expect("suite cache lock").get(&digest).cloned();
        match hit {
            Some(problem) => Ok(spec.build_with_problem(problem)),
            None => {
                let session = spec.build()?;
                cache
                    .lock()
                    .expect("suite cache lock")
                    .insert(digest, session.problem.clone());
                Ok(session)
            }
        }
    }

    fn execute(
        &self,
        spec: ScenarioSpec,
        solver: &SolverRef,
        cache: &ProblemCache,
    ) -> Result<CellResult, SessionError> {
        let session = self.build_session(spec, cache)?;
        let mut traj = Trajectory::default();
        let mut sim_json = None;
        let report = match solver.kind {
            SolverKind::Router => session
                .routing_run(&solver.name, self.iters)?
                .observe(&mut traj)
                .finish(),
            SolverKind::Sim => {
                let optimized = session.routing_run(&solver.name, self.iters)?.finish();
                let (report, sim) = session
                    .sim_run(SIM_WINDOWS)?
                    .warm_start_from(&optimized)
                    .observe(&mut traj)
                    .finish();
                sim_json = Some(sim.to_json());
                report
            }
            SolverKind::Allocator => {
                let iters = match session.spec.horizon {
                    Some(h) => self.iters.min(h),
                    None => self.iters,
                };
                let schedule = session.events();
                let mut run =
                    session.allocation_run(&solver.name, iters)?.observe(&mut traj);
                if schedule.is_empty() {
                    run.finish()
                } else {
                    // rate traces fire between outer iterations, exactly
                    // like the Fig. 11 topology-change harness — but as
                    // *workload* changes: the oracle keeps its persistent
                    // routing state across a pure rate breakpoint
                    let mut problem = session.problem.clone();
                    let mut t = 0usize;
                    loop {
                        for ev in schedule.fire(t) {
                            problem = EventSchedule::apply(&session.cfg, &problem, ev)?;
                            run.oracle_mut().on_workload_change(&problem);
                        }
                        match run.step() {
                            ControlFlow::Continue(()) => t += 1,
                            ControlFlow::Break(report) => break report,
                        }
                    }
                }
            }
        };
        Ok(CellResult { report, trajectory: traj.values, sim: sim_json })
    }
}

impl SuiteReport {
    /// Number of successful cells.
    pub fn ok_count(&self) -> usize {
        self.cells.iter().filter(|c| c.outcome.is_ok()).count()
    }

    /// Number of failed cells.
    pub fn err_count(&self) -> usize {
        self.cells.len() - self.ok_count()
    }

    /// Look a cell up by its grid coordinates.
    pub fn get(&self, scenario: &str, solver: &str, seed: u64) -> Option<&SuiteCell> {
        self.cells
            .iter()
            .find(|c| c.scenario == scenario && c.solver == solver && c.seed == seed)
    }

    /// The trajectory of a cell (empty for failed cells) — the harnesses'
    /// accessor for figure series.
    pub fn trajectory(&self, scenario: &str, solver: &str) -> Option<&[f64]> {
        self.cells
            .iter()
            .find(|c| c.scenario == scenario && c.solver == solver)
            .and_then(|c| c.outcome.as_ref().ok())
            .map(|r| r.trajectory.as_slice())
    }

    /// The first matching cell's result, with the cell's failure message
    /// surfaced as a [`SessionError`] (for `?`-style harness plumbing).
    pub fn cell_result(
        &self,
        scenario: &str,
        solver: &str,
    ) -> Result<&CellResult, SessionError> {
        let cell = self
            .cells
            .iter()
            .find(|c| c.scenario == scenario && c.solver == solver)
            .ok_or_else(|| SessionError::InvalidScenario {
                what: format!("suite has no cell ({scenario}, {solver})"),
            })?;
        cell.outcome.as_ref().map_err(|e| SessionError::InvalidScenario {
            what: format!("suite cell ({scenario}, {solver}) failed: {e}"),
        })
    }

    /// One CSV row per cell:
    /// `scenario,solver,kind,seed,status,objective,iterations,routing_iterations,stop,elapsed_s,comm_msgs,comm_bytes,comm_stale,error`.
    /// The comm columns are empty for cells whose solver reports no
    /// [`crate::coordinator::net::CommStats`] (in-process routers).
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "scenario,solver,kind,seed,status,objective,iterations,routing_iterations,\
             stop,elapsed_s,comm_msgs,comm_bytes,comm_stale,error\n",
        );
        for c in &self.cells {
            let kind = match c.kind {
                SolverKind::Router => "router",
                SolverKind::Allocator => "allocator",
                SolverKind::Sim => "sim",
            };
            match &c.outcome {
                Ok(res) => {
                    let r = &res.report;
                    let comm = match &r.comm {
                        Some(cs) => {
                            format!("{},{},{}", cs.messages, cs.bytes, cs.stale_rounds())
                        }
                        None => ",,".to_string(),
                    };
                    out.push_str(&format!(
                        "{},{},{kind},{},ok,{},{},{},{:?},{},{comm},\n",
                        c.scenario,
                        c.solver,
                        c.seed,
                        r.objective,
                        r.iterations,
                        r.routing_iterations,
                        r.stop,
                        r.elapsed_s
                    ));
                }
                Err(e) => {
                    let msg = e.replace(',', ";").replace('\n', " ");
                    out.push_str(&format!(
                        "{},{},{kind},{},error,,,,,,,,,{msg}\n",
                        c.scenario, c.solver, c.seed
                    ));
                }
            }
        }
        out
    }

    /// Full JSON dump (reports + trajectories).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![(
            "cells",
            Json::Arr(
                self.cells
                    .iter()
                    .map(|c| {
                        let kind = match c.kind {
                            SolverKind::Router => "router",
                            SolverKind::Allocator => "allocator",
                            SolverKind::Sim => "sim",
                        };
                        let mut fields = vec![
                            ("scenario", Json::from(c.scenario.as_str())),
                            ("solver", Json::from(c.solver.as_str())),
                            ("kind", Json::from(kind)),
                            ("seed", Json::from_u64(c.seed)),
                        ];
                        match &c.outcome {
                            Ok(res) => {
                                let r = &res.report;
                                fields.push(("status", Json::from("ok")));
                                let mut rep = vec![
                                    ("algo", Json::from(r.algo.as_str())),
                                    ("objective", Json::from(r.objective)),
                                    ("iterations", Json::from(r.iterations)),
                                    ("routing_iterations", Json::from(r.routing_iterations)),
                                    ("stop", Json::from(format!("{:?}", r.stop).as_str())),
                                    ("elapsed_s", Json::from(r.elapsed_s)),
                                    ("lam", Json::from(r.lam.clone())),
                                ];
                                if let Some(cs) = &r.comm {
                                    rep.push((
                                        "comm",
                                        Json::obj(vec![
                                            ("messages", Json::from_u64(cs.messages)),
                                            ("bytes", Json::from_u64(cs.bytes)),
                                            ("rounds", Json::from(cs.rounds)),
                                            (
                                                "stale_rounds",
                                                Json::from_u64(cs.stale_rounds()),
                                            ),
                                            (
                                                "shards",
                                                Json::Arr(
                                                    cs.shards
                                                        .iter()
                                                        .map(|s| {
                                                            Json::obj(vec![
                                                                (
                                                                    "msgs",
                                                                    Json::from_u64(s.msgs),
                                                                ),
                                                                (
                                                                    "bytes",
                                                                    Json::from_u64(s.bytes),
                                                                ),
                                                                (
                                                                    "stale_rounds",
                                                                    Json::from_u64(
                                                                        s.stale_rounds,
                                                                    ),
                                                                ),
                                                            ])
                                                        })
                                                        .collect(),
                                                ),
                                            ),
                                        ]),
                                    ));
                                }
                                fields.push(("report", Json::obj(rep)));
                                fields.push((
                                    "trajectory",
                                    Json::from(res.trajectory.clone()),
                                ));
                                if let Some(sim) = &res.sim {
                                    fields.push(("sim", sim.clone()));
                                }
                            }
                            Err(e) => {
                                fields.push(("status", Json::from("error")));
                                fields.push(("error", Json::from(e.as_str())));
                            }
                        }
                        Json::obj(fields)
                    })
                    .collect(),
            ),
        )])
    }

    /// Write `suite.csv` + `suite.json` under `dir`.
    pub fn write(&self, dir: &Path) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join("suite.csv"), self.to_csv())?;
        std::fs::write(dir.join("suite.json"), self.to_json().to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::session::spec::{ClassSpec, RateSpec};

    fn small_spec() -> ScenarioSpec {
        let mut spec = ScenarioSpec::paper_default();
        let TopologySpec::Er { n_nodes, .. } = &mut spec.topology else { unreachable!() };
        *n_nodes = 10;
        spec
    }
    use crate::session::spec::TopologySpec;

    #[test]
    fn grid_runs_all_cells_in_order() {
        let report = Suite::new()
            .spec("a", small_spec())
            .router("omd")
            .router("sgp")
            .seeds(&[1, 2])
            .iters(5)
            .run();
        assert_eq!(report.cells.len(), 4);
        assert_eq!(report.ok_count(), 4);
        let order: Vec<(String, u64)> = report
            .cells
            .iter()
            .map(|c| (c.solver.clone(), c.seed))
            .collect();
        assert_eq!(
            order,
            vec![
                ("omd".to_string(), 1),
                ("omd".to_string(), 2),
                ("sgp".to_string(), 1),
                ("sgp".to_string(), 2)
            ]
        );
        let cell = report.get("a", "omd", 1).unwrap();
        let res = cell.outcome.as_ref().unwrap();
        assert!(res.report.objective.is_finite());
        assert_eq!(res.trajectory.len(), res.report.iterations + 1);
    }

    #[test]
    fn parallel_execution_matches_sequential() {
        let build = || {
            Suite::new()
                .spec("a", small_spec())
                .router("omd")
                .seeds(&[1, 2, 3, 4])
                .iters(4)
        };
        let seq = build().workers(1).run();
        let par = build().workers(4).run();
        assert_eq!(seq.cells.len(), par.cells.len());
        for (a, b) in seq.cells.iter().zip(&par.cells) {
            assert_eq!(a.scenario, b.scenario);
            assert_eq!(a.seed, b.seed);
            let (ra, rb) = (a.outcome.as_ref().unwrap(), b.outcome.as_ref().unwrap());
            assert_eq!(
                ra.report.objective.to_bits(),
                rb.report.objective.to_bits(),
                "parallel suite must be deterministic"
            );
        }
    }

    #[test]
    fn unknown_solver_is_a_cell_error_not_a_panic() {
        let report = Suite::new().spec("a", small_spec()).router("nope").iters(2).run();
        assert_eq!(report.err_count(), 1);
        let msg = report.cells[0].outcome.as_ref().unwrap_err();
        assert!(msg.contains("nope"), "{msg}");
        // and the CSV still renders
        let csv = report.to_csv();
        assert!(csv.contains("error"));
    }

    #[test]
    fn empty_seeds_use_the_spec_seed() {
        let mut spec = small_spec();
        spec.seed = 777;
        let report = Suite::new().spec("a", spec).router("omd").iters(2).run();
        assert_eq!(report.cells.len(), 1);
        assert_eq!(report.cells[0].seed, 777);
    }

    #[test]
    fn allocation_cells_run_with_traces() {
        let mut spec = small_spec();
        spec.n_versions = 2;
        spec.delta = 0.2;
        spec.horizon = Some(6);
        spec.classes = vec![ClassSpec {
            name: "surge".into(),
            utility: "log".into(),
            rate: RateSpec::Trace(vec![(0, 30.0), (3, 45.0)]),
            sources: Vec::new(),
        }];
        let report = Suite::new().spec("surge", spec).allocator("omad").iters(6).run();
        assert_eq!(report.ok_count(), 1, "{:?}", report.cells[0].outcome);
        let res = report.cells[0].outcome.as_ref().unwrap();
        // after the t=3 rate event the allocation tracks the new total
        let total: f64 = res.report.lam.iter().sum();
        assert!((total - 45.0).abs() < 1e-6, "Λ sums to {total}, want 45");
    }

    #[test]
    fn problem_cache_hits_are_bit_identical_to_rebuilt_cells() {
        // several solvers × seeds over one spec: with the cache on, every
        // cell after the first (spec, seed) build reuses the cached
        // problem — results must be bit-identical to cache-off rebuilds
        let build = || {
            Suite::new()
                .spec("a", small_spec())
                .router("omd")
                .router("gp")
                .allocator("omad")
                .seeds(&[1, 2])
                .iters(4)
        };
        let cached = build().cache_problems(true).run();
        let rebuilt = build().cache_problems(false).run();
        assert_eq!(cached.cells.len(), 6);
        assert_eq!(cached.ok_count(), rebuilt.ok_count());
        for (a, b) in cached.cells.iter().zip(&rebuilt.cells) {
            assert_eq!(a.scenario, b.scenario);
            assert_eq!(a.solver, b.solver);
            assert_eq!(a.seed, b.seed);
            let (ra, rb) = (a.outcome.as_ref().unwrap(), b.outcome.as_ref().unwrap());
            assert_eq!(
                ra.report.objective.to_bits(),
                rb.report.objective.to_bits(),
                "cached cell ({}, {}) diverged from rebuilt",
                a.solver,
                a.seed
            );
            assert_eq!(ra.trajectory.len(), rb.trajectory.len());
            for (x, y) in ra.trajectory.iter().zip(&rb.trajectory) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        // and the parallel path shares the cache safely
        let par = build().cache_problems(true).workers(4).run();
        for (a, b) in par.cells.iter().zip(&cached.cells) {
            let (ra, rb) = (a.outcome.as_ref().unwrap(), b.outcome.as_ref().unwrap());
            assert_eq!(ra.report.objective.to_bits(), rb.report.objective.to_bits());
        }
    }

    #[test]
    fn sim_cells_replay_and_dump_reports() {
        let mut spec = small_spec();
        spec.sim = Some(crate::sim::SimSpec { horizon_s: 15.0, ..Default::default() });
        let report = Suite::new().spec("a", spec).sim("omd").router("omd").iters(5).run();
        assert_eq!(report.ok_count(), 2, "{:?}", report.cells[0].outcome);
        let cell = report.cells.iter().find(|c| c.kind == SolverKind::Sim).unwrap();
        let res = cell.outcome.as_ref().unwrap();
        assert!(res.sim.is_some(), "sim cells carry the SimReport");
        let sim = res.sim.as_ref().unwrap();
        assert!(sim.get("arrivals").as_u64().unwrap() > 0);
        assert_eq!(res.trajectory.len(), res.report.iterations + 1);
        assert_eq!(res.report.algo, "sim");
        // the CSV and JSON render the sim kind
        assert!(report.to_csv().contains(",sim,"));
        let json = report.to_json().to_string();
        let parsed = crate::util::json::Json::parse(&json).unwrap();
        let cells = parsed.get("cells").as_arr().unwrap();
        assert!(cells.iter().any(|c| !matches!(c.get("sim"), Json::Null)));
        // router cells stay sim-free
        let router_cell =
            report.cells.iter().find(|c| c.kind == SolverKind::Router).unwrap();
        assert!(router_cell.outcome.as_ref().unwrap().sim.is_none());
    }

    #[test]
    fn spec_digest_separates_seeds_and_contents() {
        let a = small_spec();
        let mut b = small_spec();
        assert_eq!(a.digest(), b.digest(), "identical specs share a digest");
        b.seed = a.seed + 1;
        assert_ne!(a.digest(), b.digest(), "the seed is part of the digest");
        let mut c = small_spec();
        c.n_versions += 1;
        assert_ne!(a.digest(), c.digest());
    }

    #[test]
    fn csv_and_json_render() {
        let report =
            Suite::new().spec("a", small_spec()).router("omd").iters(3).run();
        let csv = report.to_csv();
        assert!(csv.lines().count() >= 2);
        assert!(csv.starts_with("scenario,solver"));
        let json = report.to_json().to_string();
        let parsed = crate::util::json::Json::parse(&json).unwrap();
        assert_eq!(parsed.get("cells").as_arr().unwrap().len(), 1);
    }

    #[test]
    fn comm_columns_render_for_distributed_cells() {
        let mut spec = small_spec();
        spec.shards = Some(2);
        spec.staleness = Some(1);
        let report = Suite::new()
            .spec("a", spec)
            .router("sharded-omd")
            .router("omd")
            .iters(3)
            .run();
        assert_eq!(report.ok_count(), 2, "{:?}", report.cells[0].outcome);
        let csv = report.to_csv();
        let header = csv.lines().next().unwrap();
        assert!(
            header.ends_with("comm_msgs,comm_bytes,comm_stale,error"),
            "{header}"
        );
        // every row (ok or error) carries the same column count as the header
        let n_cols = header.split(',').count();
        for line in csv.lines().skip(1) {
            assert_eq!(line.split(',').count(), n_cols, "{line}");
        }
        let json = report.to_json().to_string();
        let parsed = crate::util::json::Json::parse(&json).unwrap();
        let cells = parsed.get("cells").as_arr().unwrap();
        let sharded = cells
            .iter()
            .find(|c| c.get("solver").as_str() == Some("sharded-omd"))
            .unwrap();
        let comm = sharded.get("report").get("comm");
        assert!(comm.get("messages").as_u64().unwrap() > 0);
        assert_eq!(comm.get("shards").as_arr().unwrap().len(), 2);
        // in-process routers stay comm-free in both dumps
        let plain = cells
            .iter()
            .find(|c| c.get("solver").as_str() == Some("omd"))
            .unwrap();
        assert!(matches!(plain.get("report").get("comm"), Json::Null));
    }
}
