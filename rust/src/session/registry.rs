//! Solver registry: every routing and allocation algorithm in the crate,
//! addressable by name, with human-readable descriptions and default
//! hyper-parameters.
//!
//! The registry replaces the ad-hoc string-`match` dispatch that every entry
//! point (CLI, figure harnesses, benches, examples) used to re-implement.
//! New algorithms — e.g. congestion-aware routing variants or learned
//! path-selection policies — plug in by adding one [`RouterEntry`] /
//! [`AllocatorEntry`] here and become reachable from *every* entry point at
//! once.

use super::error::SessionError;
use crate::allocation::{gsoma::GsOma, omad::Omad, Allocator};
use crate::config::ExperimentConfig;
use crate::coordinator::leader::DistributedOmd;
use crate::coordinator::shard::ShardedOmd;
use crate::engine::BatchMode;
use crate::routing::{gp::GpRouter, omd::OmdRouter, opt::OptRouter, sgp::SgpRouter, Router};

/// Paper Section-IV default hyper-parameters — the single source of truth
/// shared by [`Hyper::default`] and the registry entries' `defaults`
/// metadata.
pub const DEFAULT_ETA_ROUTING: f64 = 0.5;
pub const DEFAULT_ETA_GP: f64 = 0.002;
pub const DEFAULT_ETA_ALLOC: f64 = 0.05;
pub const DEFAULT_DELTA: f64 = 0.5;

/// Hyper-parameters handed to solver constructors. The paper's Section-IV
/// defaults; [`Hyper::from_config`] lifts an [`ExperimentConfig`].
#[derive(Clone, Copy, Debug)]
pub struct Hyper {
    /// OMD-RT mirror-descent step size η.
    pub eta_routing: f64,
    /// Euclidean step size for the GP ablation baseline (a different scale
    /// from η: GP lacks the entropic geometry, see the paper's Remark 2).
    pub eta_gp: f64,
    /// Allocation (mirror-ascent) step size.
    pub eta_alloc: f64,
    /// Gradient-sampling disturbance δ.
    pub delta: f64,
    /// [`crate::engine::FlowEngine`] worker threads for the per-session
    /// sweeps (`0` = auto-detect). Bit-identical results at any value.
    pub workers: usize,
    /// Leader shards for the sharded coordination plane (`"sharded-omd"`;
    /// `1` = the single-leader degenerate case, ignored by other solvers).
    pub shards: usize,
    /// Staleness bound S for sharded rounds (peer aggregates may lag up to
    /// S rounds; ignored by non-sharded solvers).
    pub staleness: usize,
}

impl Default for Hyper {
    fn default() -> Self {
        Hyper {
            eta_routing: DEFAULT_ETA_ROUTING,
            eta_gp: DEFAULT_ETA_GP,
            eta_alloc: DEFAULT_ETA_ALLOC,
            delta: DEFAULT_DELTA,
            workers: 1,
            shards: 1,
            staleness: 1,
        }
    }
}

impl Hyper {
    pub fn from_config(cfg: &ExperimentConfig) -> Self {
        Hyper {
            eta_routing: cfg.eta_routing,
            eta_alloc: cfg.eta_alloc,
            delta: cfg.delta,
            workers: cfg.workers,
            ..Hyper::default()
        }
    }
}

/// The unified solver-configuration surface: one struct for every knob
/// that used to be scattered across `Router::set_workers`,
/// `Router::set_batch_mode`, `DistributedOmd::with_workers`, and the
/// per-router η constructor arguments. Applied uniformly by
/// [`router_opts`]/[`allocator_opts`] (and by
/// [`crate::routing::Router::configure`] on an existing solver), and
/// round-tripped through [`super::spec::ScenarioSpec`] JSON via the
/// `workers`/`shards`/`staleness` fields.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SolverOpts {
    /// Engine worker threads (`0` = auto-detect).
    pub workers: usize,
    /// Flow-engine sweep kernel selection.
    pub batch_mode: BatchMode,
    /// Step-size override: replaces the solver's primary η
    /// (`eta_routing` for routers, `eta_alloc` for allocators) when set.
    pub eta: Option<f64>,
    /// Leader shards for the sharded plane (`1` = single leader).
    pub shards: usize,
    /// Staleness bound S for sharded rounds.
    pub staleness: usize,
}

impl Default for SolverOpts {
    fn default() -> Self {
        SolverOpts {
            workers: 1,
            batch_mode: BatchMode::Auto,
            eta: None,
            shards: 1,
            staleness: 1,
        }
    }
}

impl SolverOpts {
    /// Lift the solver-relevant knobs out of a [`Hyper`] bundle.
    pub fn from_hyper(h: &Hyper) -> Self {
        SolverOpts {
            workers: h.workers,
            shards: h.shards,
            staleness: h.staleness,
            ..SolverOpts::default()
        }
    }

    /// Lower into a [`Hyper`] bundle for the registry constructors: the η
    /// override (when set) replaces both step sizes, since a solver only
    /// ever reads its own.
    pub fn hyper(&self) -> Hyper {
        let mut h = Hyper {
            workers: self.workers,
            shards: self.shards,
            staleness: self.staleness,
            ..Hyper::default()
        };
        if let Some(eta) = self.eta {
            h.eta_routing = eta;
            h.eta_alloc = eta;
        }
        h
    }
}

/// One registered routing algorithm.
pub struct RouterEntry {
    pub name: &'static str,
    pub description: &'static str,
    /// `(hyper-parameter, default)` pairs the constructor consumes.
    pub defaults: &'static [(&'static str, f64)],
    make: fn(&Hyper) -> Box<dyn Router>,
}

impl RouterEntry {
    pub fn instantiate(&self, h: &Hyper) -> Box<dyn Router> {
        (self.make)(h)
    }
}

/// One registered allocation algorithm.
pub struct AllocatorEntry {
    pub name: &'static str,
    pub description: &'static str,
    pub defaults: &'static [(&'static str, f64)],
    /// Single-loop algorithms advance a persistent routing state one
    /// iteration per observation and pair with the single-step oracle;
    /// nested-loop algorithms pair with the run-to-convergence oracle.
    pub single_loop: bool,
    make: fn(&Hyper) -> Box<dyn Allocator>,
}

impl AllocatorEntry {
    pub fn instantiate(&self, h: &Hyper) -> Box<dyn Allocator> {
        (self.make)(h)
    }
}

// Constructors take the solver's own hyper-parameters only; the shared
// execution knobs (workers, batch mode) are applied uniformly by
// `router_with` after construction.
fn make_omd(h: &Hyper) -> Box<dyn Router> {
    Box::new(OmdRouter::new(h.eta_routing))
}

fn make_omd_fixed(h: &Hyper) -> Box<dyn Router> {
    Box::new(OmdRouter::fixed(h.eta_routing))
}

fn make_sgp(_h: &Hyper) -> Box<dyn Router> {
    Box::new(SgpRouter::new())
}

fn make_gp(h: &Hyper) -> Box<dyn Router> {
    Box::new(GpRouter::new(h.eta_gp))
}

fn make_opt(_h: &Hyper) -> Box<dyn Router> {
    Box::new(OptRouter::new())
}

fn make_distributed_omd(h: &Hyper) -> Box<dyn Router> {
    Box::new(DistributedOmd::new(h.eta_routing))
}

fn make_sharded_omd(h: &Hyper) -> Box<dyn Router> {
    Box::new(ShardedOmd::new(h.eta_routing, h.shards, h.staleness))
}

fn make_gsoma(h: &Hyper) -> Box<dyn Allocator> {
    Box::new(GsOma::new(h.delta, h.eta_alloc))
}

fn make_omad(h: &Hyper) -> Box<dyn Allocator> {
    Box::new(Omad::new(h.delta, h.eta_alloc))
}

/// Every registered router, in presentation order.
pub static ROUTERS: [RouterEntry; 7] = [
    RouterEntry {
        name: "omd",
        description: "OMD-RT (Algorithm 2): entropic mirror descent with backtracking step size",
        defaults: &[("eta_routing", DEFAULT_ETA_ROUTING)],
        make: make_omd,
    },
    RouterEntry {
        name: "omd-fixed",
        description: "OMD-RT with a fixed step size (theory experiments; requires eta <= c/L_D)",
        defaults: &[("eta_routing", DEFAULT_ETA_ROUTING)],
        make: make_omd_fixed,
    },
    RouterEntry {
        name: "sgp",
        description: "Scaled gradient projection baseline (Xi & Yeh [13])",
        defaults: &[],
        make: make_sgp,
    },
    RouterEntry {
        name: "gp",
        description: "Vanilla Gallager gradient projection (geometry ablation)",
        defaults: &[("eta_gp", DEFAULT_ETA_GP)],
        make: make_gp,
    },
    RouterEntry {
        name: "opt",
        description: "Centralized path-flow solve (the OPT reference line)",
        defaults: &[],
        make: make_opt,
    },
    RouterEntry {
        name: "distributed-omd",
        description: "OMD-RT over message-passing node actors (paper Sec. V; \
                      one step = one barriered round, CommStats on the report)",
        defaults: &[("eta_routing", DEFAULT_ETA_ROUTING)],
        make: make_distributed_omd,
    },
    RouterEntry {
        name: "sharded-omd",
        description: "OMD-RT over K leader shards with staleness-bounded rounds and \
                      lambda-sync delta gossip (K=1 degenerates to distributed-omd)",
        defaults: &[("eta_routing", DEFAULT_ETA_ROUTING), ("shards", 1.0), ("staleness", 1.0)],
        make: make_sharded_omd,
    },
];

/// Every registered allocator, in presentation order.
pub static ALLOCATORS: [AllocatorEntry; 2] = [
    AllocatorEntry {
        name: "gsoma",
        description: "GS-OMA (Algorithm 1): nested loop, routing run to convergence per sample",
        defaults: &[("delta", DEFAULT_DELTA), ("eta_alloc", DEFAULT_ETA_ALLOC)],
        single_loop: false,
        make: make_gsoma,
    },
    AllocatorEntry {
        name: "omad",
        description: "OMAD (Algorithm 3): single loop, one routing iteration per observation",
        defaults: &[("delta", DEFAULT_DELTA), ("eta_alloc", DEFAULT_ETA_ALLOC)],
        single_loop: true,
        make: make_omad,
    },
];

/// Registry entry for a router name, if registered.
pub fn router_entry(name: &str) -> Option<&'static RouterEntry> {
    ROUTERS.iter().find(|e| e.name == name)
}

/// Registry entry for an allocator name, if registered.
pub fn allocator_entry(name: &str) -> Option<&'static AllocatorEntry> {
    ALLOCATORS.iter().find(|e| e.name == name)
}

/// All registered router names.
pub fn router_names() -> Vec<&'static str> {
    ROUTERS.iter().map(|e| e.name).collect()
}

/// All registered allocator names.
pub fn allocator_names() -> Vec<&'static str> {
    ALLOCATORS.iter().map(|e| e.name).collect()
}

/// Instantiate a router by name with the paper-default hyper-parameters.
pub fn router(name: &str) -> Result<Box<dyn Router>, SessionError> {
    router_with(name, &Hyper::default())
}

/// Instantiate a router by name with explicit hyper-parameters. The shared
/// execution knobs (`workers`) apply uniformly here — individual `make`
/// functions only consume the solver's own hyper-parameters.
pub fn router_with(name: &str, h: &Hyper) -> Result<Box<dyn Router>, SessionError> {
    router_entry(name)
        .map(|e| {
            let mut r = e.instantiate(h);
            r.set_workers(h.workers);
            r
        })
        .ok_or_else(|| SessionError::UnknownRouter { name: name.to_string() })
}

/// Instantiate a router from a unified [`SolverOpts`] bundle — the
/// preferred entry point; [`router_with`] remains for callers that carry a
/// full [`Hyper`]. Applies `workers` *and* `batch_mode` (and, for
/// `"sharded-omd"`, `shards`/`staleness`) uniformly.
pub fn router_opts(name: &str, opts: &SolverOpts) -> Result<Box<dyn Router>, SessionError> {
    let mut r = router_with(name, &opts.hyper())?;
    r.configure(opts);
    Ok(r)
}

/// Instantiate an allocator by name with the paper-default hyper-parameters.
pub fn allocator(name: &str) -> Result<Box<dyn Allocator>, SessionError> {
    allocator_with(name, &Hyper::default())
}

/// Instantiate an allocator by name with explicit hyper-parameters.
pub fn allocator_with(name: &str, h: &Hyper) -> Result<Box<dyn Allocator>, SessionError> {
    allocator_entry(name)
        .map(|e| e.instantiate(h))
        .ok_or_else(|| SessionError::UnknownAllocator { name: name.to_string() })
}

/// Instantiate an allocator from a unified [`SolverOpts`] bundle (the η
/// override maps onto `eta_alloc`).
pub fn allocator_opts(name: &str, opts: &SolverOpts) -> Result<Box<dyn Allocator>, SessionError> {
    allocator_with(name, &opts.hyper())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_instantiates_and_reports_its_name() {
        let h = Hyper::default();
        for e in ROUTERS.iter() {
            let r = e.instantiate(&h);
            assert!(!r.name().is_empty(), "{}", e.name);
            assert!(!e.description.is_empty());
        }
        for e in ALLOCATORS.iter() {
            let a = e.instantiate(&h);
            assert!(!a.name().is_empty(), "{}", e.name);
        }
    }

    #[test]
    fn names_are_unique() {
        let mut names = router_names();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), ROUTERS.len());
        let mut names = allocator_names();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), ALLOCATORS.len());
    }

    #[test]
    fn unknown_names_are_clean_errors() {
        assert!(matches!(router("nope"), Err(SessionError::UnknownRouter { .. })));
        assert!(matches!(allocator("nope"), Err(SessionError::UnknownAllocator { .. })));
    }

    #[test]
    fn solver_opts_round_trip_through_hyper() {
        let opts = SolverOpts { workers: 3, shards: 4, staleness: 2, ..SolverOpts::default() };
        let h = opts.hyper();
        assert_eq!(h.workers, 3);
        assert_eq!(h.shards, 4);
        assert_eq!(h.staleness, 2);
        assert_eq!(h.eta_routing, DEFAULT_ETA_ROUTING, "no override: defaults stand");
        assert_eq!(SolverOpts::from_hyper(&h), opts);
        let h = SolverOpts { eta: Some(0.125), ..SolverOpts::default() }.hyper();
        assert_eq!(h.eta_routing, 0.125);
        assert_eq!(h.eta_alloc, 0.125);
    }

    #[test]
    fn opts_entry_points_instantiate_every_solver() {
        let opts = SolverOpts { workers: 2, shards: 2, staleness: 0, ..SolverOpts::default() };
        for e in ROUTERS.iter() {
            let r = router_opts(e.name, &opts).unwrap();
            assert_eq!(r.name(), e.name);
        }
        for e in ALLOCATORS.iter() {
            let a = allocator_opts(e.name, &opts).unwrap();
            assert!(!a.name().is_empty());
        }
        assert!(matches!(
            router_opts("nope", &opts),
            Err(SessionError::UnknownRouter { .. })
        ));
    }

    #[test]
    fn sharded_entry_carries_its_knobs() {
        let h = Hyper { shards: 3, staleness: 2, ..Hyper::default() };
        let r = router_with("sharded-omd", &h).unwrap();
        assert_eq!(r.name(), "sharded-omd");
    }

    #[test]
    fn hyper_lifts_config() {
        let mut cfg = ExperimentConfig::paper_default();
        cfg.eta_routing = 0.25;
        cfg.delta = 0.1;
        let h = Hyper::from_config(&cfg);
        assert_eq!(h.eta_routing, 0.25);
        assert_eq!(h.delta, 0.1);
        assert_eq!(h.eta_gp, 0.002);
        assert_eq!(h.workers, cfg.workers);
    }
}
