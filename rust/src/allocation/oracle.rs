//! The unknown-utility boundary (paper §II-B).
//!
//! Allocation algorithms *never* see the utility functions `u_w`: they can
//! only submit an allocation Λ and observe the resulting total network
//! utility `U(Λ, φ(Λ)) = Σ u_w(λ_w) − Σ D_ij`. This module provides the two
//! oracle instantiations used by Algorithms 1 and 3 plus the bookkeeping
//! (observation counts, routing-iteration counts) the evaluation reports.
//! A third, *measured* oracle — utility observed from the discrete-event
//! serving simulator with real DNN latencies — lives in
//! [`crate::coordinator::serving`].

use crate::coordinator::serving::ServeReport;
use crate::engine::SessionMask;
use crate::model::flow::Phi;
use crate::model::utility::Utility;
use crate::model::Problem;
use crate::routing::omd::OmdRouter;
use crate::routing::Router;

/// An opaque evaluator of the total network utility at a given allocation.
pub trait UtilityOracle {
    /// Observe `U(Λ, φ(Λ))`. How φ(Λ) is produced is oracle-specific
    /// (converged routing for Algorithm 1, one routing step for Algorithm 3,
    /// measured serving for the end-to-end driver).
    fn observe(&mut self, lam: &[f64]) -> f64;

    /// Like [`UtilityOracle::observe`], with the caller's promise that
    /// only the sessions in `dirty` changed their `λ` entry since the
    /// **previous** observation (GS-OMA/OMAD probes perturb one class
    /// block at a time — see
    /// [`crate::allocation::observe_probe`]). Stateful oracles with a
    /// delta-capable engine override this to cut the pre-update forward
    /// evaluation inside their routing step to the dirty block (the
    /// post-step cost and the marginal broadcast still span every session,
    /// since the mirror update touches all `φ` rows); the observed value
    /// is bit-identical to [`UtilityOracle::observe`] either way.
    /// Default: a full observation.
    fn observe_dirty(&mut self, lam: &[f64], _dirty: &SessionMask) -> f64 {
        self.observe(lam)
    }

    /// Total admissible rate λ.
    fn total_rate(&self) -> f64;

    /// Number of allocation coordinates — one per routed session (equals
    /// the version count W for single-class problems, `classes × W` for
    /// heterogeneous multi-class workloads).
    fn n_versions(&self) -> usize;

    /// Per-task-class blocks `(start, end, rate)` of the allocation
    /// vector: allocators perturb, mirror-update, and project each block
    /// on its own scaled simplex. Default: one block covering every
    /// coordinate at the total rate (the paper's single-class setting).
    fn blocks(&self) -> Vec<(usize, usize, f64)> {
        vec![(0, self.n_versions(), self.total_rate())]
    }

    /// The paper's uniform initializer — per class, `Λ¹ = (λ_c/W_c)·1`.
    fn uniform_allocation(&self) -> Vec<f64> {
        let w = self.n_versions();
        vec![self.total_rate() / w as f64; w]
    }

    /// Cumulative routing iterations consumed (the convergence-cost metric
    /// of Fig. 11's nested vs single loop comparison).
    fn routing_iterations(&self) -> usize;

    /// Number of `observe` calls so far.
    fn observations(&self) -> usize;

    /// Notify the oracle that the network topology changed (Fig. 11's
    /// perturbation at outer iteration 50). Default: no-op.
    fn on_topology_change(&mut self, _problem: &Problem) {}

    /// Notify the oracle that only the admitted *workload* changed (a
    /// [`crate::coordinator::events::NetworkEvent::ClassRate`] trace
    /// breakpoint): same topology and session structure, new rates.
    /// Stateful oracles override this to keep their persistent routing
    /// state — re-initializing φ for a pure rate change would throw away
    /// converged routing for no reason. Default: treat it like a topology
    /// change.
    fn on_workload_change(&mut self, problem: &Problem) {
        self.on_topology_change(problem);
    }

    /// The oracle's persistent routing state, when it keeps one (single-step
    /// and measured oracles do; the run-to-convergence oracle does not).
    fn current_phi(&self) -> Option<&Phi> {
        None
    }

    /// The last serving-simulator window report, for oracles whose
    /// observations are *measured* (see
    /// [`crate::coordinator::serving::MeasuredOracle`]); `None` for
    /// analytic oracles.
    fn last_serve_report(&self) -> Option<&ServeReport> {
        None
    }
}

/// Assumption 4's oracle 𝔒 for the **nested loop**: every observation runs
/// OMD-RT from the uniform initializer to convergence, so the observed value
/// is `U(Λ, φ*(Λ))`.
pub struct AnalyticOracle {
    pub problem: Problem,
    utilities: Vec<Utility>,
    pub router_eta: f64,
    pub max_routing_iters: usize,
    /// Engine worker threads for the per-observation routing solves
    /// (`0` = auto); threaded from `Scenario::workers` by the session.
    pub workers: usize,
    routing_iters: usize,
    observations: usize,
}

impl AnalyticOracle {
    pub fn new(problem: Problem, utilities: Vec<Utility>) -> Self {
        assert_eq!(utilities.len(), problem.n_sessions());
        AnalyticOracle {
            problem,
            utilities,
            router_eta: 0.5,
            max_routing_iters: 2_000,
            workers: 1,
            routing_iters: 0,
            observations: 0,
        }
    }

    /// Ground truth Σ u_w(λ_w) (tests only; never exposed to allocators).
    pub fn true_task_utility(&self, lam: &[f64]) -> f64 {
        lam.iter().zip(&self.utilities).map(|(&l, u)| u.value(l)).sum()
    }

    /// Ground-truth utility derivative (tests only).
    pub fn true_utility_derivative(&self, w: usize, x: f64) -> f64 {
        self.utilities[w].derivative(x)
    }
}

impl UtilityOracle for AnalyticOracle {
    fn observe(&mut self, lam: &[f64]) -> f64 {
        self.observations += 1;
        let mut router = OmdRouter::new(self.router_eta).with_workers(self.workers);
        let sol = router.solve(&self.problem, lam, self.max_routing_iters);
        self.routing_iters += sol.iterations;
        self.true_task_utility(lam) - sol.objective
    }

    fn total_rate(&self) -> f64 {
        self.problem.total_rate
    }

    fn n_versions(&self) -> usize {
        self.problem.n_sessions()
    }

    fn blocks(&self) -> Vec<(usize, usize, f64)> {
        self.problem.workload.blocks()
    }

    fn uniform_allocation(&self) -> Vec<f64> {
        self.problem.uniform_allocation()
    }

    fn routing_iterations(&self) -> usize {
        self.routing_iters
    }

    fn observations(&self) -> usize {
        self.observations
    }

    fn on_topology_change(&mut self, problem: &Problem) {
        self.problem = problem.clone();
    }
}

/// Algorithm 3's oracle for the **single loop**: a persistent routing state
/// is advanced by exactly **one** OMD-RT iteration per observation
/// (`invoke Algorithm 2 with K = 1`), so routing and allocation converge
/// together.
pub struct SingleStepOracle {
    pub problem: Problem,
    utilities: Vec<Utility>,
    pub router: OmdRouter,
    phi: Phi,
    /// The last observed Λ (bitwise), for the debug-mode check of the
    /// [`UtilityOracle::observe_dirty`] contract.
    last_lam: Option<Vec<f64>>,
    routing_iters: usize,
    observations: usize,
}

impl SingleStepOracle {
    pub fn new(problem: Problem, utilities: Vec<Utility>, eta: f64) -> Self {
        assert_eq!(utilities.len(), problem.n_sessions());
        let phi = Phi::uniform(&problem.net);
        SingleStepOracle {
            problem,
            utilities,
            router: OmdRouter::new(eta),
            phi,
            last_lam: None,
            routing_iters: 0,
            observations: 0,
        }
    }

    pub fn true_task_utility(&self, lam: &[f64]) -> f64 {
        lam.iter().zip(&self.utilities).map(|(&l, u)| u.value(l)).sum()
    }

    /// Current (not necessarily converged) routing state.
    pub fn phi(&self) -> &Phi {
        &self.phi
    }

    /// The observation body shared by the full and dirty entry points:
    /// one mirror-descent routing iteration on the persistent state, then
    /// one fused sweep for the post-step cost — reusing the router's
    /// engine workspaces (no second workspace set). With a dirty mask,
    /// the pre-update evaluation inside the routing step re-sweeps only
    /// the masked (plus router-touched) sessions, and the post-step cost
    /// goes through [`OmdRouter::post_step_cost`], which re-syncs the
    /// engine O(touched rows) — so a warmed probe loop is incremental end
    /// to end (bit-identical either way).
    fn observe_impl(&mut self, lam: &[f64], dirty: Option<&SessionMask>) -> f64 {
        self.observations += 1;
        self.routing_iters += 1;
        match dirty {
            Some(mask) => {
                // debug check of the caller's promise: every λ entry that
                // changed since the previous observation is in the mask
                #[cfg(debug_assertions)]
                if let Some(last) = &self.last_lam {
                    if last.len() == lam.len() {
                        for (s, (a, b)) in last.iter().zip(lam).enumerate() {
                            debug_assert!(
                                a.to_bits() == b.to_bits() || mask.contains(s),
                                "observe_dirty: λ[{s}] changed outside the dirty mask"
                            );
                        }
                    }
                }
                self.router.step_dirty(&self.problem, lam, &mut self.phi, mask);
            }
            None => {
                self.router.step(&self.problem, lam, &mut self.phi);
            }
        }
        let cost = match dirty {
            Some(_) => self.router.post_step_cost(&self.problem, &self.phi, lam),
            None => self.router.engine_mut().evaluate_cost(&self.problem, &self.phi, lam),
        };
        match &mut self.last_lam {
            Some(buf) if buf.len() == lam.len() => buf.copy_from_slice(lam),
            slot => *slot = Some(lam.to_vec()),
        }
        self.true_task_utility(lam) - cost
    }
}

impl UtilityOracle for SingleStepOracle {
    fn observe(&mut self, lam: &[f64]) -> f64 {
        self.observe_impl(lam, None)
    }

    fn observe_dirty(&mut self, lam: &[f64], dirty: &SessionMask) -> f64 {
        self.observe_impl(lam, Some(dirty))
    }

    fn total_rate(&self) -> f64 {
        self.problem.total_rate
    }

    fn n_versions(&self) -> usize {
        self.problem.n_sessions()
    }

    fn blocks(&self) -> Vec<(usize, usize, f64)> {
        self.problem.workload.blocks()
    }

    fn uniform_allocation(&self) -> Vec<f64> {
        self.problem.uniform_allocation()
    }

    fn routing_iterations(&self) -> usize {
        self.routing_iters
    }

    fn observations(&self) -> usize {
        self.observations
    }

    fn on_topology_change(&mut self, problem: &Problem) {
        self.problem = problem.clone();
        // routing state re-initialized on the new topology (the Fig. 11
        // "worse initial point" effect for the single loop); the engine's
        // incremental state belongs to the old problem — drop it so the
        // next (possibly dirty) observation starts from a full sweep
        self.phi = Phi::uniform(&self.problem.net);
        self.router.engine_mut().invalidate();
        self.last_lam = None;
    }

    fn on_workload_change(&mut self, problem: &Problem) {
        // same topology, new class rates: the persistent routing state
        // stays valid (φ is per-(session, edge); rates enter through Λ) —
        // but the incremental engine state is conservatively dropped so a
        // dirty observation straddling the breakpoint re-sweeps fully
        self.problem = problem.clone();
        self.router.engine_mut().invalidate();
        self.last_lam = None;
    }

    fn current_phi(&self) -> Option<&Phi> {
        Some(&self.phi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topologies;
    use crate::model::cost::CostKind;
    use crate::model::utility::family;
    use crate::util::rng::Rng;

    fn mk_problem(seed: u64) -> Problem {
        let mut rng = Rng::seed_from(seed);
        let net = topologies::connected_er(10, 0.3, 3, &mut rng);
        Problem::new(net, 60.0, CostKind::Exp)
    }

    #[test]
    fn analytic_oracle_counts_and_values() {
        let p = mk_problem(1);
        let us = family("log", 3, 60.0).unwrap();
        let mut o = AnalyticOracle::new(p, us);
        let u1 = o.observe(&[20.0, 20.0, 20.0]);
        assert_eq!(o.observations(), 1);
        assert!(o.routing_iterations() > 0);
        assert!(u1.is_finite());
        // deterministic: same Λ -> same value
        let u2 = o.observe(&[20.0, 20.0, 20.0]);
        assert!((u1 - u2).abs() < 1e-9);
    }

    #[test]
    fn single_step_oracle_improves_over_calls() {
        // repeated observation at the same Λ keeps improving routing, so the
        // observed utility is non-decreasing
        let p = mk_problem(2);
        let us = family("log", 3, 60.0).unwrap();
        // small-step regime: Theorem 4's monotone descent applies
        let mut o = SingleStepOracle::new(p, us, 0.05);
        let lam = [20.0, 20.0, 20.0];
        let mut prev = o.observe(&lam);
        for _ in 0..30 {
            let u = o.observe(&lam);
            assert!(u >= prev - 1e-9, "utility decreased {prev} -> {u}");
            prev = u;
        }
        assert_eq!(o.routing_iterations(), 31);
    }

    #[test]
    fn single_step_approaches_analytic() {
        let p = mk_problem(3);
        let us = family("log", 3, 60.0).unwrap();
        let lam = [25.0, 20.0, 15.0];
        let mut exact = AnalyticOracle::new(p.clone(), us.clone());
        let target = exact.observe(&lam);
        let mut ss = SingleStepOracle::new(p, us, 0.5);
        let mut last = f64::NEG_INFINITY;
        for _ in 0..800 {
            last = ss.observe(&lam);
        }
        assert!(
            (last - target).abs() < 1e-3 * target.abs().max(1.0),
            "single-step {last} vs analytic {target}"
        );
    }

    #[test]
    fn workload_change_keeps_single_step_phi_warm() {
        // a ClassRate trace breakpoint must not throw away the persistent
        // routing state — only real topology changes reset φ
        let p = mk_problem(6);
        let us = family("log", 3, 60.0).unwrap();
        let mut o = SingleStepOracle::new(p.clone(), us, 0.5);
        let lam = [20.0, 20.0, 20.0];
        for _ in 0..40 {
            o.observe(&lam);
        }
        let warm = o.phi().clone();
        let mut wl = p.workload.clone();
        wl.class_rates[0] = 45.0;
        let p2 = Problem::with_workload(p.net.clone(), p.cost, wl);
        o.on_workload_change(&p2);
        for (ra, rb) in o.phi().frac.iter().zip(&warm.frac) {
            for (a, b) in ra.iter().zip(rb) {
                assert_eq!(a.to_bits(), b.to_bits(), "phi must survive a rate change");
            }
        }
        assert!((o.total_rate() - 45.0).abs() < 1e-12, "new rate installed");
    }

    #[test]
    fn topology_change_resets_single_step_phi() {
        let p = mk_problem(4);
        let us = family("log", 3, 60.0).unwrap();
        let mut o = SingleStepOracle::new(p, us.clone(), 0.5);
        let lam = [20.0, 20.0, 20.0];
        for _ in 0..50 {
            o.observe(&lam);
        }
        let settled = o.observe(&lam);
        let p2 = mk_problem(5);
        o.on_topology_change(&p2);
        let after = o.observe(&lam);
        // fresh uniform routing on a different topology is (almost surely)
        // worse than the settled value was relative to its own optimum;
        // at minimum the state must be valid and finite
        assert!(after.is_finite());
        assert!(o.phi().is_feasible(&o.problem.net, 1e-9).is_ok());
        let _ = settled;
    }
}
