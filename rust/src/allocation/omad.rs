//! **OMAD** — Algorithm 3: the online mirror ascent–descent single loop.
//!
//! Identical outer structure to GS-OMA, but every oracle observation runs
//! exactly **one** routing iteration on a *persistent* routing state
//! (`invoke Algorithm 2 with K = 1`), so allocation and routing converge
//! together: O(1/t) overall (Theorem 5) at a fraction of the nested loop's
//! total routing iterations, and with fast re-adaptation when the topology
//! changes (Fig. 11).

use super::gsoma::perturb_block;
use super::project::project_capped_simplex;
use super::{mirror_ascent_update, observe_probe, Allocator, UtilityOracle};

#[derive(Clone, Debug)]
pub struct Omad {
    /// Gradient-sampling disturbance δ.
    pub delta: f64,
    /// Outer (allocation) step size η_o.
    pub eta_outer: f64,
    /// Stop tolerance on `‖Λ^{t+1} − Λ^t‖_∞`.
    pub stop_tol: f64,
}

impl Omad {
    pub fn new(delta: f64, eta_outer: f64) -> Self {
        Omad { delta, eta_outer, stop_tol: 1e-10 }
    }
}

impl Allocator for Omad {
    fn name(&self) -> &'static str {
        "OMAD"
    }

    /// One single-loop iteration against the (stateful) oracle, per task
    /// class on its own scaled simplex.
    fn outer_step(&self, oracle: &mut dyn UtilityOracle, lam: &[f64]) -> (Vec<f64>, Vec<f64>) {
        let blocks = oracle.blocks();
        let mut grad = vec![0.0; lam.len()];
        // consecutive probes differ only inside one class block: the diff
        // mask lets the single-step oracle's routing step delta-evaluate
        // (O(block) instead of O(W·E); values bit-identical). The OMD
        // router's row-sparse updates extend that to the post-step cost —
        // a warmed probe loop re-sweeps only the rows that actually moved
        let mut prev: Option<Vec<f64>> = None;
        for &(s0, s1, rate) in &blocks {
            for w in s0..s1 {
                let up = perturb_block(lam, s0, s1, w, self.delta, rate);
                let dn = perturb_block(lam, s0, s1, w, -self.delta, rate);
                // each observation advances the shared routing state by one
                // mirror-descent iteration (K = 1)
                let u_plus = observe_probe(oracle, &up, &mut prev);
                let u_minus = observe_probe(oracle, &dn, &mut prev);
                grad[w] = (u_plus - u_minus) / (2.0 * self.delta);
            }
        }
        let mut next = lam.to_vec();
        for &(s0, s1, rate) in &blocks {
            mirror_ascent_update(&mut next[s0..s1], &grad[s0..s1], self.eta_outer, rate);
            let proj =
                project_capped_simplex(&next[s0..s1], rate, self.delta, rate - self.delta);
            next[s0..s1].copy_from_slice(&proj);
        }
        (next, grad)
    }

    fn stop_tol(&self) -> f64 {
        self.stop_tol
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allocation::gsoma::GsOma;
    use crate::allocation::{AnalyticOracle, SingleStepOracle};
    use crate::graph::topologies;
    use crate::model::cost::CostKind;
    use crate::model::utility::family;
    use crate::model::Problem;
    use crate::util::rng::Rng;

    fn mk_problem(seed: u64) -> Problem {
        let mut rng = Rng::seed_from(seed);
        let net = topologies::connected_er(10, 0.3, 3, &mut rng);
        Problem::new(net, 60.0, CostKind::Exp)
    }

    #[test]
    fn single_loop_improves_utility() {
        let p = mk_problem(1);
        // pre-run probe at the uniform initializer (a fresh single-step
        // oracle's first observation — what trajectory[0] used to record)
        let mut probe =
            SingleStepOracle::new(p.clone(), family("log", 3, 60.0).unwrap(), 0.5);
        let lam0 = probe.uniform_allocation();
        let first = probe.observe(&lam0);

        let mut o = SingleStepOracle::new(p, family("log", 3, 60.0).unwrap(), 0.5);
        let mut alg = Omad::new(0.5, 0.05);
        let st = alg.run(&mut o, 120);
        let last = st.objective;
        assert!(last > first, "{first} -> {last}");
        assert!((st.lam.iter().sum::<f64>() - 60.0).abs() < 1e-6);
    }

    #[test]
    fn single_loop_matches_nested_loop_optimum() {
        // Fig. 11: both loops converge to the same (Λ*, φ*(Λ*))
        let p = mk_problem(2);
        let us = family("log", 3, 60.0).unwrap();

        let mut o_nested = AnalyticOracle::new(p.clone(), us.clone());
        let mut nested = GsOma::new(0.3, 0.06);
        let st_nested = nested.run(&mut o_nested, 60);

        let mut o_single = SingleStepOracle::new(p, us, 0.5);
        let mut single = Omad::new(0.3, 0.06);
        let st_single = single.run(&mut o_single, 300);

        let u_nested = st_nested.objective;
        let u_single = st_single.objective;
        let rel = (u_nested - u_single).abs() / u_nested.abs().max(1.0);
        assert!(rel < 0.02, "nested {u_nested} vs single {u_single}");
    }

    #[test]
    fn single_loop_uses_far_fewer_routing_iterations() {
        // the Fig. 11 headline: OMAD's total routing work is a small
        // fraction of GS-OMA's
        let p = mk_problem(3);
        let us = family("log", 3, 60.0).unwrap();

        let mut o_nested = AnalyticOracle::new(p.clone(), us.clone());
        let st_nested = GsOma::new(0.3, 0.06).run(&mut o_nested, 30);

        let mut o_single = SingleStepOracle::new(p, us, 0.5);
        let st_single = Omad::new(0.3, 0.06).run(&mut o_single, 30);

        assert!(
            st_single.routing_iterations * 10 <= st_nested.routing_iterations,
            "single {} vs nested {}",
            st_single.routing_iterations,
            st_nested.routing_iterations
        );
    }

    #[test]
    fn adapts_after_topology_change() {
        let p = mk_problem(4);
        let us = family("log", 3, 60.0).unwrap();
        let mut o = SingleStepOracle::new(p, us, 0.5);
        let alg = Omad::new(0.4, 0.05);
        let total = o.total_rate();
        let mut lam = vec![total / 3.0; 3];
        for _ in 0..80 {
            let (n, _) = alg.outer_step(&mut o, &lam);
            lam = n;
        }
        let settled = o.observe(&lam);
        // swap in a new topology and keep iterating
        let p2 = mk_problem(5);
        o.on_topology_change(&p2);
        let dip = o.observe(&lam);
        for _ in 0..120 {
            let (n, _) = alg.outer_step(&mut o, &lam);
            lam = n;
        }
        let recovered = o.observe(&lam);
        assert!(recovered.is_finite() && settled.is_finite());
        // after adaptation the utility on the new topology is at least the
        // immediate post-change value
        assert!(recovered >= dip - 1e-6, "no recovery: {dip} -> {recovered}");
    }
}
