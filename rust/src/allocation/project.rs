//! Euclidean projection onto the capped simplex
//! `{ x : Σ x_w = total,  lo ≤ x_w ≤ hi }` — the paper's projection step
//! `P_{[δ, λ−δ]^W}` (Algorithm 1 line 9), which keeps every perturbed
//! allocation `Λ ± δ e_w` inside the domain `[0, λ]^W`.
//!
//! The KKT solution is `x_w(ν) = clamp(y_w − ν, lo, hi)` with the scalar
//! dual ν chosen so the sum constraint holds; `Σ x(ν)` is non-increasing in
//! ν, so ν is found by bisection to machine precision.

/// Project `y` onto `{Σ = total, lo ≤ x ≤ hi}` (requires feasibility:
/// `d·lo ≤ total ≤ d·hi`).
pub fn project_capped_simplex(y: &[f64], total: f64, lo: f64, hi: f64) -> Vec<f64> {
    let d = y.len();
    assert!(d > 0);
    assert!(lo <= hi);
    assert!(
        d as f64 * lo <= total + 1e-9 && total <= d as f64 * hi + 1e-9,
        "infeasible box-simplex: d={d} lo={lo} hi={hi} total={total}"
    );
    let eval = |nu: f64| -> f64 { y.iter().map(|&v| (v - nu).clamp(lo, hi)).sum() };
    // bracket ν
    let ymin = y.iter().cloned().fold(f64::INFINITY, f64::min);
    let ymax = y.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let mut a = ymin - hi - 1.0; // sum = d*hi ≥ total
    let mut b = ymax - lo + 1.0; // sum = d*lo ≤ total
    for _ in 0..200 {
        let mid = 0.5 * (a + b);
        if eval(mid) >= total {
            a = mid;
        } else {
            b = mid;
        }
        if b - a < 1e-14 * (1.0 + ymax.abs()) {
            break;
        }
    }
    let nu = 0.5 * (a + b);
    let mut x: Vec<f64> = y.iter().map(|&v| (v - nu).clamp(lo, hi)).collect();
    // exact-sum cleanup: distribute the residual over non-saturated entries
    let resid = total - x.iter().sum::<f64>();
    if resid.abs() > 1e-12 {
        let free: Vec<usize> = (0..d)
            .filter(|&i| x[i] > lo + 1e-12 && x[i] < hi - 1e-12)
            .collect();
        if !free.is_empty() {
            let share = resid / free.len() as f64;
            for i in free {
                x[i] = (x[i] + share).clamp(lo, hi);
            }
        }
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;
    use crate::util::rng::Rng;

    fn check_feasible(x: &[f64], total: f64, lo: f64, hi: f64) {
        assert!((x.iter().sum::<f64>() - total).abs() < 1e-8, "sum {:?}", x);
        for &v in x {
            assert!(v >= lo - 1e-9 && v <= hi + 1e-9, "bounds {v}");
        }
    }

    #[test]
    fn identity_on_feasible_points() {
        let y = vec![10.0, 20.0, 30.0];
        let x = project_capped_simplex(&y, 60.0, 1.0, 59.0);
        for (a, b) in x.iter().zip(&y) {
            assert!((a - b).abs() < 1e-8);
        }
    }

    #[test]
    fn clamps_and_redistributes() {
        // one coordinate wants everything; caps force spread
        let y = vec![100.0, 0.0, 0.0];
        let x = project_capped_simplex(&y, 60.0, 1.0, 58.0);
        check_feasible(&x, 60.0, 1.0, 58.0);
        assert!((x[0] - 58.0).abs() < 1e-8);
        assert!((x[1] - 1.0).abs() < 1e-8);
    }

    #[test]
    fn property_feasibility_and_optimality() {
        testkit::forall(7, 100, 8, |g| {
            let d = g.usize_in(2, 8);
            let total = g.f64_in(5.0, 100.0);
            let lo = g.f64_in(0.0, total / d as f64 * 0.9);
            let hi = g.f64_in(total / d as f64 * 1.1, total);
            let y: Vec<f64> = (0..d).map(|_| g.f64_in(-50.0, 150.0)).collect();
            let x = project_capped_simplex(&y, total, lo, hi);
            let sum: f64 = x.iter().sum();
            crate::prop_assert_close!(sum, total, 1e-7);
            for &v in &x {
                crate::prop_assert!(
                    v >= lo - 1e-8 && v <= hi + 1e-8,
                    "bound violated: {v} not in [{lo},{hi}]"
                );
            }
            // optimality via random feasible comparisons
            let mut rng = Rng::seed_from(g.rng.next_u64());
            let dist = |a: &[f64]| -> f64 {
                a.iter().zip(&y).map(|(p, q)| (p - q) * (p - q)).sum()
            };
            let dx = dist(&x);
            for _ in 0..20 {
                let mut z: Vec<f64> =
                    (0..d).map(|_| rng.uniform(lo, hi)).collect();
                // rescale into the box-simplex via the projection itself
                z = project_capped_simplex(&z, total, lo, hi);
                crate::prop_assert!(
                    dx <= dist(&z) + 1e-6,
                    "not the nearest point: {dx} > {}",
                    dist(&z)
                );
            }
            Ok(())
        });
    }

    #[test]
    fn tight_box_forces_uniform() {
        let y = vec![5.0, 1.0, 0.0];
        let x = project_capped_simplex(&y, 6.0, 2.0, 2.0);
        check_feasible(&x, 6.0, 2.0, 2.0);
    }

    #[test]
    #[should_panic(expected = "infeasible")]
    fn rejects_infeasible_box() {
        project_capped_simplex(&[1.0, 1.0], 10.0, 0.0, 1.0);
    }
}
