//! Workload allocation layer (paper §III-A / §III-C).
//!
//! * [`gsoma::GsOma`] — Algorithm 1: nested-loop gradient sampling + online
//!   mirror ascent, with the routing oracle run to convergence per sample.
//! * [`omad::Omad`] — Algorithm 3: single-loop variant, one routing
//!   iteration per allocation step.
//! * [`project`] — Euclidean projection onto `[δ, λ−δ]^W ∩ {Σ = λ}`.
//! * [`oracle`] — the *unknown utility* boundary: allocators only ever see
//!   observed `U(Λ)` values, never the utility functions.

pub mod gsoma;
pub mod omad;
pub mod oracle;
pub mod project;

pub use oracle::{AnalyticOracle, SingleStepOracle, UtilityOracle};

use crate::engine::SessionMask;
use crate::session::run::{RunReport, StopReason};

/// A workload allocation algorithm operating against an opaque utility
/// oracle (the only window onto the unknown utility functions).
///
/// Implementors provide the per-iteration [`Allocator::outer_step`]; the
/// iteration loop itself (shared by GS-OMA and OMAD, and by the streaming
/// [`crate::session::AllocationRun`]) is the provided [`Allocator::run`].
pub trait Allocator {
    fn name(&self) -> &'static str;

    /// One outer iteration: estimate the utility gradient by sampling the
    /// oracle, update + project Λ — per task class, on each class's own
    /// scaled simplex (single-class problems have exactly one block, the
    /// paper's setting). Returns `(next Λ, gradient estimate)`.
    fn outer_step(&self, oracle: &mut dyn UtilityOracle, lam: &[f64]) -> (Vec<f64>, Vec<f64>);

    /// Stop when `‖Λ^{t+1} − Λ^t‖_∞` falls below this (the paper's
    /// exact-equality stop, relaxed to floating point).
    fn stop_tol(&self) -> f64;

    /// Run up to `max_outer` outer iterations from the paper's uniform
    /// initializer (per class, `Λ¹ = (λ_c/W_c)·1`). Returns the unified
    /// [`RunReport`] (the legacy `AllocationState` is gone): `objective`
    /// is the utility observed at the final iterate, `phi` is the oracle's
    /// persistent routing state when it keeps one. The observation
    /// sequence is identical to a streaming
    /// [`crate::session::AllocationRun`] driven to completion — attach a
    /// [`crate::session::Trajectory`] there when you need the
    /// per-iteration series.
    fn run(&mut self, oracle: &mut dyn UtilityOracle, max_outer: usize) -> RunReport {
        let t0 = crate::util::clock::Stopwatch::start();
        let mut lam = oracle.uniform_allocation();
        let mut iterations = 0;
        let mut stop = StopReason::MaxIters;
        for _ in 0..max_outer {
            iterations += 1;
            // utility observed at the iterate itself (the Fig. 10/11
            // trajectory point; stateful oracles advance here)
            let _u = oracle.observe(&lam);
            let (next, _grad) = self.outer_step(&mut *oracle, &lam);
            let moved = next
                .iter()
                .zip(&lam)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f64, f64::max);
            lam = next;
            if moved < self.stop_tol() {
                stop = StopReason::Converged;
                break;
            }
        }
        let final_u = oracle.observe(&lam);
        RunReport {
            algo: self.name().to_string(),
            objective: final_u,
            phi: oracle.current_phi().cloned(),
            lam,
            iterations,
            routing_iterations: oracle.routing_iterations(),
            comm: None,
            stop,
            elapsed_s: t0.elapsed_secs(),
        }
    }
}

/// Observe one gradient-sampling probe, threading the exact dirty-session
/// mask to the oracle when the previous probe of this outer step is known.
///
/// GS-OMA and OMAD perturb `Λ` one class block at a time, so between
/// consecutive probes only that block's coordinates change — the oracle
/// (and through it the engine's
/// [`crate::engine::FlowEngine::prepare_dirty`]) can then re-sweep
/// O(block) instead of O(W·E). Only the allocator knows both consecutive
/// probes, so the mask is computed here as the bitwise diff; the *first*
/// probe of an outer step has no known predecessor at the oracle (callers
/// may interleave their own observations) and stays a full observation.
/// Observed values are bit-identical to plain
/// [`UtilityOracle::observe`] calls.
pub fn observe_probe(
    oracle: &mut dyn UtilityOracle,
    probe: &[f64],
    prev: &mut Option<Vec<f64>>,
) -> f64 {
    let u = match prev {
        Some(last) => oracle.observe_dirty(probe, &SessionMask::from_diff(last, probe)),
        None => oracle.observe(probe),
    };
    match prev {
        Some(buf) if buf.len() == probe.len() => buf.copy_from_slice(probe),
        slot => *slot = Some(probe.to_vec()),
    }
    u
}

/// Online mirror ascent update on the λ-scaled simplex (paper eq. 10).
pub fn mirror_ascent_update(lam: &mut [f64], grad: &[f64], eta: f64, total: f64) {
    debug_assert_eq!(lam.len(), grad.len());
    // stabilize: shift by max exponent
    let zmax = grad
        .iter()
        .map(|g| eta * g)
        .fold(f64::NEG_INFINITY, f64::max);
    let mut sum = 0.0;
    for (l, g) in lam.iter_mut().zip(grad) {
        *l *= (eta * g - zmax).exp();
        sum += *l;
    }
    if sum > 0.0 {
        let scale = total / sum;
        lam.iter_mut().for_each(|l| *l *= scale);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mirror_ascent_preserves_total_and_prefers_high_gradient() {
        let mut lam = vec![20.0, 20.0, 20.0];
        mirror_ascent_update(&mut lam, &[1.0, 0.0, -1.0], 0.5, 60.0);
        assert!((lam.iter().sum::<f64>() - 60.0).abs() < 1e-9);
        assert!(lam[0] > lam[1] && lam[1] > lam[2]);
    }

    #[test]
    fn mirror_ascent_zero_grad_identity() {
        let mut lam = vec![10.0, 30.0, 20.0];
        mirror_ascent_update(&mut lam, &[0.0, 0.0, 0.0], 1.0, 60.0);
        assert!((lam[0] - 10.0).abs() < 1e-9);
        assert!((lam[1] - 30.0).abs() < 1e-9);
    }
}
