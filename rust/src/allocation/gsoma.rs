//! **GS-OMA** — Algorithm 1: gradient sampling + online mirror ascent for
//! optimal workload allocation under unknown utility functions.
//!
//! Per outer iteration `t`, for every session `w`, the oracle is queried at
//! the two-point perturbations `Λ^t ± δ·e_w` and the central difference
//! `(U⁺ − U⁻)/(2δ)` estimates `∂U/∂λ_w` (gradient sampling, Assumption 5).
//! The estimate feeds the mirror-ascent update (eq. 10) on the λ-scaled
//! simplex, followed by the projection onto `[δ, λ−δ]^W` (line 9) that keeps
//! all future perturbations inside the domain. The loop stops when Λ stops
//! moving (line 10).

use super::project::project_capped_simplex;
use super::{mirror_ascent_update, observe_probe, Allocator, UtilityOracle};

#[derive(Clone, Debug)]
pub struct GsOma {
    /// Gradient-sampling disturbance δ.
    pub delta: f64,
    /// Mirror-ascent step size η_t (constant, paper sets η_t ≤ 1/L_U).
    pub eta: f64,
    /// Stop when `‖Λ^{t+1} − Λ^t‖_∞ < stop_tol` (the paper's exact-equality
    /// stop, relaxed to floating point).
    pub stop_tol: f64,
}

impl GsOma {
    pub fn new(delta: f64, eta: f64) -> Self {
        GsOma { delta, eta, stop_tol: 1e-9 }
    }
}

/// Shift coordinate `w` by `d` inside the class block `[s0, s1)`,
/// compensating uniformly on the block's other coordinates so the probe
/// stays on the class's Σ=rate simplex; coordinates outside the block are
/// untouched. With one block spanning the whole vector this is exactly the
/// single-class perturbation of the paper.
pub fn perturb_block(
    lam: &[f64],
    s0: usize,
    s1: usize,
    w: usize,
    d: f64,
    rate: f64,
) -> Vec<f64> {
    debug_assert!(s0 <= w && w < s1);
    let mut v = lam.to_vec();
    v[w] = (v[w] + d).clamp(0.0, rate);
    let others: f64 = rate - v[w];
    let cur: f64 = (s0..s1).filter(|&i| i != w).map(|i| v[i]).sum();
    if cur > 0.0 {
        let scale = others / cur;
        for i in s0..s1 {
            if i != w {
                v[i] *= scale;
            }
        }
    } else if s1 - s0 > 1 {
        // degenerate input (all class mass on w): spread the remainder evenly
        let share = others / (s1 - s0 - 1) as f64;
        for i in s0..s1 {
            if i != w {
                v[i] = share;
            }
        }
    }
    v
}

/// Single-block convenience: shift coordinate `w` by `d` on the global
/// Σ=total simplex (the paper's single-class probe).
pub fn perturb(lam: &[f64], w: usize, d: f64, total: f64) -> Vec<f64> {
    perturb_block(lam, 0, lam.len(), w, d, total)
}

impl Allocator for GsOma {
    fn name(&self) -> &'static str {
        "GS-OMA"
    }

    /// One outer iteration: sample 2·|sessions| observations, estimate the
    /// gradient, then update + project *per task class* on its own scaled
    /// simplex. Returns (new Λ, gradient estimate).
    fn outer_step(&self, oracle: &mut dyn UtilityOracle, lam: &[f64]) -> (Vec<f64>, Vec<f64>) {
        let blocks = oracle.blocks();
        let mut grad = vec![0.0; lam.len()];
        // consecutive probes differ only inside one class block; the diff
        // mask lets stateful oracles delta-evaluate (bit-identical values).
        // With the row-sparse OMD router this makes the whole warmed probe
        // loop O(touched): the pre-step sweep covers the mask ∪ pending φ
        // rows and the post-step cost covers the touched rows only
        let mut prev: Option<Vec<f64>> = None;
        for &(s0, s1, rate) in &blocks {
            for w in s0..s1 {
                // Λ±(t): perturb coordinate w, renormalizing the rest of
                // its class so the probe stays on the class simplex (the
                // flow model requires exact conservation; the ±δ probes
                // shift mass to/from the class's other versions).
                let up = perturb_block(lam, s0, s1, w, self.delta, rate);
                let dn = perturb_block(lam, s0, s1, w, -self.delta, rate);
                let u_plus = observe_probe(oracle, &up, &mut prev);
                let u_minus = observe_probe(oracle, &dn, &mut prev);
                grad[w] = (u_plus - u_minus) / (2.0 * self.delta);
            }
        }
        let mut next = lam.to_vec();
        for &(s0, s1, rate) in &blocks {
            mirror_ascent_update(&mut next[s0..s1], &grad[s0..s1], self.eta, rate);
            let proj =
                project_capped_simplex(&next[s0..s1], rate, self.delta, rate - self.delta);
            next[s0..s1].copy_from_slice(&proj);
        }
        (next, grad)
    }

    fn stop_tol(&self) -> f64 {
        self.stop_tol
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allocation::AnalyticOracle;
    use crate::graph::topologies;
    use crate::model::cost::CostKind;
    use crate::model::utility::family;
    use crate::model::Problem;
    use crate::util::rng::Rng;

    fn oracle(seed: u64, fam: &str) -> AnalyticOracle {
        let mut rng = Rng::seed_from(seed);
        let net = topologies::connected_er(10, 0.3, 3, &mut rng);
        let p = Problem::new(net, 60.0, CostKind::Exp);
        AnalyticOracle::new(p, family(fam, 3, 60.0).unwrap())
    }

    #[test]
    fn perturb_stays_on_simplex() {
        let lam = vec![10.0, 20.0, 30.0];
        for w in 0..3 {
            for d in [0.5, -0.5] {
                let v = perturb(&lam, w, d, 60.0);
                assert!((v.iter().sum::<f64>() - 60.0).abs() < 1e-9, "{v:?}");
                assert!(v.iter().all(|&x| x >= 0.0));
                assert!((v[w] - (lam[w] + d)).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn utility_increases_monotonically_ish() {
        // utility at the uniform initializer (what trajectory[0] used to
        // record; the analytic oracle is deterministic, so a fresh probe
        // sees the same value)
        let mut probe = oracle(1, "log");
        let lam0 = probe.uniform_allocation();
        let first = probe.observe(&lam0);

        let mut o = oracle(1, "log");
        let mut alg = GsOma::new(0.5, 0.05);
        let st = alg.run(&mut o, 40);
        // overall improvement (small non-monotonic wiggle from sampling is OK)
        let last = st.objective;
        assert!(last > first, "no improvement: {first} -> {last}");
        assert!((st.lam.iter().sum::<f64>() - 60.0).abs() < 1e-6);
        assert!(st.lam.iter().all(|&l| l >= 0.5 - 1e-9));
    }

    #[test]
    fn gradient_estimate_consistent_across_delta() {
        // Assumption 5: as δ shrinks, the two-point estimate converges to a
        // stable (sub)gradient of U — estimates at δ and δ/2 must agree
        let lam = vec![20.0, 20.0, 20.0];
        let grad_at = |delta: f64| {
            let mut o = oracle(2, "log");
            GsOma::new(delta, 0.05).outer_step(&mut o, &lam).1
        };
        let g1 = grad_at(0.5);
        let g2 = grad_at(0.25);
        for (a, b) in g1.iter().zip(&g2) {
            assert!((a - b).abs() < 0.15 * a.abs().max(1.0), "{g1:?} vs {g2:?}");
        }
        // and the *ranking* given by the estimate must be self-consistent
        let g3 = grad_at(0.5);
        assert_eq!(
            g1.iter().map(|x| format!("{x:.9}")).collect::<Vec<_>>(),
            g3.iter().map(|x| format!("{x:.9}")).collect::<Vec<_>>(),
            "oracle observations must be deterministic"
        );
    }

    #[test]
    fn converges_near_kkt_for_log_family() {
        // Theorem 1: at Λ*, ∂U/∂λ_w equalized. Verify the *utility-side*
        // gradient spread shrinks (the routing cost side is shared).
        let mut o = oracle(3, "log");
        let mut alg = GsOma::new(0.3, 0.08);
        let st = alg.run(&mut o, 60);
        let (_n, grad) = alg.outer_step(&mut o, &st.lam);
        let spread = grad.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
            - grad.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(spread < 0.6, "KKT spread too large: {grad:?}");
    }

    #[test]
    fn all_four_families_improve() {
        for fam in crate::model::utility::FAMILIES {
            let mut probe = oracle(4, fam);
            let lam0 = probe.uniform_allocation();
            let first = probe.observe(&lam0);
            let mut o = oracle(4, fam);
            let mut alg = GsOma::new(0.5, 0.04);
            let st = alg.run(&mut o, 25);
            let last = st.objective;
            assert!(last >= first - 1e-6, "{fam}: {first} -> {last}");
        }
    }
}
