//! Experiment configuration (JSON files + CLI overrides).
//!
//! One [`ExperimentConfig`] describes a JOWR instance: topology, sizes,
//! rates, cost family, utility family, algorithm hyper-parameters, seed.
//! Every figure harness in [`crate::experiments`] starts from
//! [`ExperimentConfig::paper_default`] (the Section-IV setup) and overrides
//! the handful of fields that figure sweeps.

use std::path::Path;

use crate::graph::augmented::{AugmentedNet, Placement};
use crate::graph::topologies;
use crate::model::cost::CostKind;
use crate::model::Problem;
use crate::session::SessionError;
use crate::util::json::Json;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// "er" or a named topology ("abilene", "tree", "fog", "geant").
    pub topology: String,
    /// ER node count (ignored for named topologies).
    pub n_nodes: usize,
    /// ER link probability.
    pub p_link: f64,
    /// Mean link capacity C̄.
    pub cap_mean: f64,
    /// Number of DNN versions W.
    pub n_versions: usize,
    /// Total task input rate λ.
    pub total_rate: f64,
    pub cost: CostKind,
    /// Utility family name for allocation experiments.
    pub utility: String,
    /// OMD-RT step size.
    pub eta_routing: f64,
    /// Allocation step size.
    pub eta_alloc: f64,
    /// Gradient-sampling disturbance δ.
    pub delta: f64,
    pub seed: u64,
    /// Engine worker threads for the per-session flow/marginal sweeps
    /// (`0` = auto-detect, `1` = single-threaded), served by the engine's
    /// persistent worker pool. Results are bit-identical at any value —
    /// for centralized, distributed, and serving runs alike; this only
    /// trades wall-clock for cores.
    pub workers: usize,
}

impl ExperimentConfig {
    /// The paper's Section-IV default: Connected-ER(25, 0.2), λ=60, W=3,
    /// C̄=10, `D_ij = exp(F/C)`.
    pub fn paper_default() -> Self {
        ExperimentConfig {
            topology: "er".into(),
            n_nodes: 25,
            p_link: 0.2,
            cap_mean: 10.0,
            n_versions: 3,
            total_rate: 60.0,
            cost: CostKind::Exp,
            utility: "log".into(),
            eta_routing: 0.5,
            eta_alloc: 0.05,
            delta: 0.5,
            seed: 42,
            workers: 1,
        }
    }

    /// Build the problem instance (network + rate + cost) for this config.
    /// Fails cleanly on an unknown topology name instead of panicking; use
    /// [`crate::session::Scenario`] for full up-front validation.
    pub fn build_problem(&self, rng: &mut Rng) -> Result<Problem, SessionError> {
        let real = match self.topology.as_str() {
            "er" => topologies::connected_er_graph(self.n_nodes, self.p_link, self.cap_mean, rng),
            name => topologies::by_name(name, self.cap_mean, rng)
                .ok_or_else(|| SessionError::UnknownTopology { name: name.to_string() })?,
        };
        let placement = Placement::random(real.n_nodes(), self.n_versions, rng);
        let net = AugmentedNet::build(&real, &placement, self.cap_mean, rng);
        Ok(Problem::new(net, self.total_rate, self.cost))
    }

    /// Parse from JSON text; missing keys fall back to `paper_default`.
    /// Unrecognized keys are warned about instead of silently dropped —
    /// spec-only fields (classes, nodes, explicit edges, traces) need the
    /// full [`crate::session::spec::ScenarioSpec`] loader (`--scenario`).
    pub fn from_json(text: &str) -> Result<Self, String> {
        let j = Json::parse(text).map_err(|e| e.to_string())?;
        if let Some(obj) = j.as_obj() {
            const KNOWN: [&str; 13] = [
                "topology",
                "n_nodes",
                "p_link",
                "cap_mean",
                "n_versions",
                "total_rate",
                "cost",
                "utility",
                "eta_routing",
                "eta_alloc",
                "delta",
                "workers",
                "seed",
            ];
            for key in obj.keys() {
                if !KNOWN.contains(&key.as_str()) {
                    crate::log_warn!(
                        "config: ignoring unknown field '{key}' (declarative fields like \
                         classes/nodes/edges need a ScenarioSpec file via --scenario)"
                    );
                }
            }
        }
        let mut c = Self::paper_default();
        if let Some(s) = j.get("topology").as_str() {
            c.topology = s.to_string();
        }
        if let Some(x) = j.get("n_nodes").as_usize() {
            c.n_nodes = x;
        }
        if let Some(x) = j.get("p_link").as_f64() {
            c.p_link = x;
        }
        if let Some(x) = j.get("cap_mean").as_f64() {
            c.cap_mean = x;
        }
        if let Some(x) = j.get("n_versions").as_usize() {
            c.n_versions = x;
        }
        if let Some(x) = j.get("total_rate").as_f64() {
            c.total_rate = x;
        }
        if let Some(s) = j.get("cost").as_str() {
            c.cost = CostKind::parse(s).ok_or_else(|| format!("bad cost '{s}'"))?;
        }
        if let Some(s) = j.get("utility").as_str() {
            c.utility = s.to_string();
        }
        if let Some(x) = j.get("eta_routing").as_f64() {
            c.eta_routing = x;
        }
        if let Some(x) = j.get("eta_alloc").as_f64() {
            c.eta_alloc = x;
        }
        if let Some(x) = j.get("delta").as_f64() {
            c.delta = x;
        }
        if let Some(x) = j.get("workers").as_usize() {
            c.workers = x;
        }
        if !matches!(j.get("seed"), Json::Null) {
            c.seed = j
                .get("seed")
                .as_u64()
                .ok_or_else(|| format!("bad seed '{}' (not a u64)", j.get("seed")))?;
        }
        Ok(c)
    }

    pub fn from_file(path: &Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        Self::from_json(&text)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("topology", Json::from(self.topology.as_str())),
            ("n_nodes", Json::from(self.n_nodes)),
            ("p_link", Json::from(self.p_link)),
            ("cap_mean", Json::from(self.cap_mean)),
            ("n_versions", Json::from(self.n_versions)),
            ("total_rate", Json::from(self.total_rate)),
            (
                "cost",
                Json::from(match self.cost {
                    CostKind::Exp => "exp",
                    CostKind::Queue => "queue",
                    CostKind::Linear => "linear",
                    CostKind::Cubic => "cubic",
                }),
            ),
            ("utility", Json::from(self.utility.as_str())),
            ("eta_routing", Json::from(self.eta_routing)),
            ("eta_alloc", Json::from(self.eta_alloc)),
            ("delta", Json::from(self.delta)),
            ("workers", Json::from(self.workers)),
            // u64-safe: seeds beyond 2^53 are not representable as JSON
            // numbers and round-trip as decimal strings
            ("seed", Json::from_u64(self.seed)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_builds() {
        let c = ExperimentConfig::paper_default();
        let mut rng = Rng::seed_from(c.seed);
        let p = c.build_problem(&mut rng).unwrap();
        assert_eq!(p.n_versions(), 3);
        assert_eq!(p.total_rate, 60.0);
        assert_eq!(p.net.n_real, 25);
    }

    #[test]
    fn unknown_topology_is_a_clean_error() {
        let mut c = ExperimentConfig::paper_default();
        c.topology = "hypercube".into();
        let mut rng = Rng::seed_from(1);
        let err = c.build_problem(&mut rng).unwrap_err();
        assert!(err.to_string().contains("hypercube"), "{err}");
    }

    #[test]
    fn json_roundtrip() {
        let mut c = ExperimentConfig::paper_default();
        c.workers = 4;
        let text = c.to_json().to_string();
        let c2 = ExperimentConfig::from_json(&text).unwrap();
        assert_eq!(c2.n_nodes, c.n_nodes);
        assert_eq!(c2.cost, c.cost);
        assert_eq!(c2.utility, c.utility);
        assert_eq!(c2.seed, c.seed);
        assert_eq!(c2.workers, 4);
    }

    #[test]
    fn partial_json_uses_defaults() {
        let c = ExperimentConfig::from_json(r#"{"n_nodes": 40, "cost": "queue"}"#).unwrap();
        assert_eq!(c.n_nodes, 40);
        assert_eq!(c.cost, CostKind::Queue);
        assert_eq!(c.total_rate, 60.0);
    }

    #[test]
    fn named_topology_builds() {
        let mut c = ExperimentConfig::paper_default();
        c.topology = "abilene".into();
        c.cap_mean = 15.0;
        let mut rng = Rng::seed_from(1);
        let p = c.build_problem(&mut rng).unwrap();
        assert_eq!(p.net.n_real, 11);
    }

    #[test]
    fn bad_cost_rejected() {
        assert!(ExperimentConfig::from_json(r#"{"cost": "nope"}"#).is_err());
    }

    #[test]
    fn large_seed_roundtrips_losslessly() {
        // seeds >= 2^53 used to be corrupted by the f64 JSON path
        for seed in [u64::MAX, (1u64 << 53) + 1, 2u64.pow(60) + 12345, 42] {
            let mut c = ExperimentConfig::paper_default();
            c.seed = seed;
            let text = c.to_json().to_string();
            let c2 = ExperimentConfig::from_json(&text).unwrap();
            assert_eq!(c2.seed, seed, "json was: {text}");
        }
    }

    #[test]
    fn numeric_and_string_seeds_both_parse() {
        let c = ExperimentConfig::from_json(r#"{"seed": 7}"#).unwrap();
        assert_eq!(c.seed, 7);
        let c = ExperimentConfig::from_json(r#"{"seed": "18446744073709551615"}"#).unwrap();
        assert_eq!(c.seed, u64::MAX);
        assert!(ExperimentConfig::from_json(r#"{"seed": -3}"#).is_err());
        assert!(ExperimentConfig::from_json(r#"{"seed": 1.5}"#).is_err());
    }
}
