//! Wire protocol between node actors (paper §III-B "marginal cost
//! broadcast" + control-plane messages).
//!
//! The broadcast protocol (footnote 6): the last node of each path to `D_w`
//! starts by announcing `∂D/∂r = 0`; every node that has received the
//! marginals of **all** its session out-neighbours combines them with its
//! local `D'_ij` (eq. 21) and announces its own marginal upstream. On a
//! session DAG this terminates in depth(DAG) rounds.

/// Node-to-node and leader-to-node messages.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// Downstream node `from` (augmented node id) announces
    /// `∂D/∂r_from(w) = value` to an upstream neighbour.
    Marginal { w: usize, from: usize, value: f64 },
    /// Leader round kick-off + the mirror step size for this round.
    BeginRound { round: u64, eta: f64 },
    /// One upstream neighbour's session-`w` flow contribution over one
    /// in-edge (exactly one per (session, in-edge) per round). `from` is
    /// the sender's augmented node id — receivers bucket contributions per
    /// upstream slot and sum them in the session DAG's topological order,
    /// so the accumulated `t_i(w)` is independent of message arrival order
    /// and bit-identical to the centralized engine sweep.
    Ingress { w: usize, from: usize, rate: f64 },
    /// Node reports its updated rows to the leader:
    /// (session, edge, fraction) triples.
    RowsReport { from: usize, rows: Vec<(usize, usize, f64)> },
    /// Shard-to-shard λ-sync gossip (the sharded plane's data plane):
    /// shard `shard`'s round-`round` per-edge flow aggregate `A_k[e]`, as a
    /// sparse delta — only the entries that changed bitwise since the
    /// shard's previous round, carrying their new absolute value. Peers
    /// reconstruct `A_k` by overlaying the entries onto their stored copy,
    /// so reconstruction is exact and order-independent (one delta per
    /// peer per round).
    FlowDelta { shard: usize, round: u64, edges: Vec<(usize, f64)> },
    /// Orderly shutdown.
    Shutdown,
}

impl Msg {
    /// Approximate wire size in bytes (for the communication-overhead
    /// accounting; marginals piggyback on task messages per footnote 6).
    pub fn wire_bytes(&self) -> usize {
        match self {
            // value (8) + session tag (4) + sender id (4) — the sender id
            // is billed for Marginal and Ingress alike
            Msg::Marginal { .. } => 8 + 2 * 4,
            Msg::BeginRound { .. } => 16,
            // rate (8) + session tag (4) + sender id (4)
            Msg::Ingress { .. } => 8 + 2 * 4,
            Msg::RowsReport { rows, .. } => 8 + rows.len() * 20,
            // round (8) + shard id (4) + per entry: edge id (4) + value (8)
            Msg::FlowDelta { edges, .. } => 8 + 4 + edges.len() * 12,
            Msg::Shutdown => 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_bytes_scale_with_payload() {
        let small = Msg::RowsReport { from: 0, rows: vec![(0, 0, 0.5)] };
        let big = Msg::RowsReport { from: 0, rows: vec![(0, 0, 0.5); 10] };
        assert!(big.wire_bytes() > small.wire_bytes());
        assert!(Msg::Shutdown.wire_bytes() >= 1);
        let lean = Msg::FlowDelta { shard: 0, round: 3, edges: vec![(1, 0.5)] };
        let fat = Msg::FlowDelta { shard: 0, round: 3, edges: vec![(1, 0.5); 7] };
        assert!(fat.wire_bytes() > lean.wire_bytes());
    }
}
