//! Discrete-event serving simulator: the *measured-utility* path.
//!
//! This is the end-to-end story of the paper made concrete: frames arrive
//! at the controller as a Poisson stream, are admitted to version-`w`
//! sessions according to Λ, hop through the network along φ (FIFO links,
//! transmission time = size/С), and are finally served by the hosting
//! device's DNN — whose inference latency comes from an
//! [`InferenceEngine`] (either the analytic FLOPs model or the real
//! AOT-compiled DNN executed through PJRT, see [`crate::runtime::dnn`]).
//!
//! The resulting **measured utility** (quality-weighted goodput minus a
//! latency penalty) instantiates the unknown `u_w`: the online learner
//! (GS-OMA/OMAD) optimizes it from observations alone.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::super::allocation::UtilityOracle;
use crate::engine::{FlowEngine, SessionMask};
use crate::graph::augmented::AugmentedNet;
use crate::model::flow::Phi;
use crate::model::Problem;
use crate::routing::omd::OmdRouter;
use crate::routing::Router;
use crate::util::rng::Rng;

/// Provides per-frame inference latency for a DNN version.
pub trait InferenceEngine {
    fn infer_latency(&mut self, version: usize) -> f64;

    /// Latency of serving `batch` frames together (dynamic batching).
    /// Default: no batching benefit. Real engines override this (the XLA
    /// engine dispatches to the AOT `dnn_*_b8` artifact).
    fn infer_batch_latency(&mut self, version: usize, batch: usize) -> f64 {
        (0..batch).map(|_| self.infer_latency(version)).sum()
    }

    /// Human-readable backend name (for reports).
    fn backend(&self) -> &'static str;
}

/// Analytic engine: latency = FLOPs / device_flops, with multiplicative
/// jitter. Default FLOPs match the AOT DNN family (small/medium/large).
pub struct AnalyticEngine {
    pub flops: Vec<f64>,
    pub device_flops: f64,
    pub jitter: f64,
    rng: Rng,
}

impl AnalyticEngine {
    pub fn new(n_versions: usize, seed: u64) -> Self {
        // FLOPs of the L2 DNN family (see python/compile/model.py):
        // small ~0.56 MFLOP, medium ~3.7 MFLOP, large ~14.7 MFLOP per frame
        let base = [0.56e6, 3.7e6, 14.7e6];
        let flops = (0..n_versions).map(|w| base[w.min(2)] * (1.0 + w as f64 * 0.1)).collect();
        AnalyticEngine { flops, device_flops: 2.0e9, jitter: 0.1, rng: Rng::seed_from(seed) }
    }
}

impl InferenceEngine for AnalyticEngine {
    fn infer_latency(&mut self, version: usize) -> f64 {
        let base = self.flops[version] / self.device_flops;
        base * (1.0 + self.jitter * self.rng.normal().abs())
    }

    fn infer_batch_latency(&mut self, version: usize, batch: usize) -> f64 {
        // batching amortizes fixed overhead: marginal frame costs 70%
        let base = self.flops[version] / self.device_flops;
        let eff = base * (1.0 + 0.7 * (batch.max(1) as f64 - 1.0));
        eff * (1.0 + self.jitter * self.rng.normal().abs())
    }

    fn backend(&self) -> &'static str {
        "analytic"
    }
}

/// Simulation parameters.
#[derive(Clone, Debug)]
pub struct ServeParams {
    /// Simulated horizon per observation window (seconds).
    pub sim_time: f64,
    /// Frame size in capacity units (link tx time = size / C).
    pub frame_size: f64,
    /// Per-version quality score (the "revenue" of serving one frame with
    /// version w; higher versions are worth more).
    pub quality: Vec<f64>,
    /// Utility penalty per second of mean end-to-end latency.
    pub latency_penalty: f64,
    /// Dynamic batching: max frames a host serves in one DNN invocation.
    pub max_batch: usize,
}

impl ServeParams {
    pub fn default_for(n_versions: usize) -> Self {
        ServeParams {
            sim_time: 30.0,
            frame_size: 0.05,
            quality: (0..n_versions).map(|w| 1.0 + 1.5 * w as f64).collect(),
            latency_penalty: 40.0,
            max_batch: 8,
        }
    }
}

/// Outcome of one simulated window.
#[derive(Clone, Debug, Default)]
pub struct ServeReport {
    pub completed: Vec<u64>,
    pub dropped: u64,
    pub mean_latency_s: f64,
    pub p50_latency_s: f64,
    pub p99_latency_s: f64,
    pub throughput_fps: f64,
    pub utility: f64,
}

#[derive(Clone, Debug)]
enum EvKind {
    /// A frame arrives at `node` (session `w`, admitted at `t0`).
    AtNode { frame: usize, node: usize },
    /// A batch finished DNN service at its host.
    ServedBatch { node: usize, frames: Vec<usize> },
}

#[derive(Clone, Debug)]
struct Ev {
    time: f64,
    seq: u64,
    kind: EvKind,
}

impl PartialEq for Ev {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ev {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap via reverse on time, tie-break by seq for determinism
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

struct FrameState {
    w: usize,
    admitted_at: f64,
}

/// Run one serving window: Poisson arrivals at total rate λ split by Λ,
/// hop-by-hop forwarding sampled from φ, FIFO links, FIFO DNN servers.
pub fn simulate(
    problem: &Problem,
    phi: &Phi,
    lam: &[f64],
    engine: &mut dyn InferenceEngine,
    params: &ServeParams,
    rng: &mut Rng,
) -> ServeReport {
    let net = &problem.net;
    let w_cnt = net.n_sessions();
    let total: f64 = lam.iter().sum();
    let mut queue: BinaryHeap<Ev> = BinaryHeap::new();
    let mut seq = 0u64;
    let push = |q: &mut BinaryHeap<Ev>, time: f64, kind: EvKind, seq: &mut u64| {
        *seq += 1;
        q.push(Ev { time, seq: *seq, kind });
    };

    // schedule Poisson arrivals over the window
    let mut frames: Vec<FrameState> = Vec::new();
    let mut t = 0.0;
    if total > 0.0 {
        loop {
            t += rng.exponential(total);
            if t >= params.sim_time {
                break;
            }
            // session by allocation share
            let mut x = rng.f64() * total;
            let mut w = 0;
            for (i, &l) in lam.iter().enumerate() {
                if x < l {
                    w = i;
                    break;
                }
                x -= l;
                w = i;
            }
            let frame = frames.len();
            frames.push(FrameState { w, admitted_at: t });
            push(&mut queue, t, EvKind::AtNode { frame, node: AugmentedNet::SOURCE }, &mut seq);
        }
    }

    let mut link_free = vec![0.0f64; net.graph.n_edges()];
    let mut host_busy = vec![false; net.n_nodes()];
    let mut host_queue: Vec<std::collections::VecDeque<usize>> =
        vec![std::collections::VecDeque::new(); net.n_nodes()];
    let mut latencies: Vec<f64> = Vec::new();
    let mut completed = vec![0u64; w_cnt];
    let mut dropped = 0u64;

    while let Some(ev) = queue.pop() {
        match ev.kind {
            EvKind::AtNode { frame, node } => {
                let w = frames[frame].w;
                if node == net.dnode(w) {
                    // reached the virtual destination: already served
                    continue;
                }
                // host of version w about to forward over its computation
                // link: service happens at the host
                let lanes: Vec<(usize, f64)> = phi.row(net, w, node).collect();
                if lanes.is_empty() {
                    dropped += 1;
                    continue;
                }
                // sample next hop by φ
                let sum: f64 = lanes.iter().map(|(_, f)| f).sum();
                let mut x = rng.f64() * sum.max(1e-300);
                let mut chosen = lanes[0].0;
                for &(e, f) in &lanes {
                    if x < f {
                        chosen = e;
                        break;
                    }
                    x -= f;
                    chosen = e;
                }
                let edge = net.graph.edge(chosen);
                if edge.dst == net.dnode(w) {
                    // computation link: the host's DNN server with dynamic
                    // batching — an idle server starts immediately, a busy
                    // one queues the frame for the next batch
                    if host_busy[node] {
                        host_queue[node].push_back(frame);
                    } else {
                        host_busy[node] = true;
                        let service =
                            engine.infer_batch_latency(net.version_of_session(w), 1);
                        push(
                            &mut queue,
                            ev.time + service,
                            EvKind::ServedBatch { node, frames: vec![frame] },
                            &mut seq,
                        );
                    }
                } else {
                    // communication link: FIFO transmission
                    let tx = params.frame_size / edge.capacity;
                    let start = link_free[chosen].max(ev.time);
                    link_free[chosen] = start + tx;
                    push(
                        &mut queue,
                        start + tx,
                        EvKind::AtNode { frame, node: edge.dst },
                        &mut seq,
                    );
                }
            }
            EvKind::ServedBatch { node, frames: batch } => {
                for &frame in &batch {
                    let st = &frames[frame];
                    completed[st.w] += 1;
                    latencies.push(ev.time - st.admitted_at);
                }
                // pull the next batch off the host's queue
                if host_queue[node].is_empty() {
                    host_busy[node] = false;
                } else {
                    let take = params.max_batch.min(host_queue[node].len()).max(1);
                    let next: Vec<usize> =
                        (0..take).filter_map(|_| host_queue[node].pop_front()).collect();
                    let w = frames[next[0]].w;
                    let service =
                        engine.infer_batch_latency(net.version_of_session(w), next.len());
                    push(
                        &mut queue,
                        ev.time + service,
                        EvKind::ServedBatch { node, frames: next },
                        &mut seq,
                    );
                }
            }
        }
    }

    let mean_latency = crate::util::stats::mean(&latencies);
    let done: u64 = completed.iter().sum();
    let throughput = done as f64 / params.sim_time;
    // quality is a per-*version* score: sessions of different task classes
    // served by the same version earn the same per-frame value
    let goodput_value: f64 = completed
        .iter()
        .enumerate()
        .map(|(w, &c)| {
            params.quality[net.version_of_session(w)] * c as f64 / params.sim_time
        })
        .sum();
    let utility = goodput_value - params.latency_penalty * mean_latency;
    ServeReport {
        completed,
        dropped,
        mean_latency_s: mean_latency,
        p50_latency_s: crate::util::stats::percentile(&latencies, 50.0),
        p99_latency_s: crate::util::stats::percentile(&latencies, 99.0),
        throughput_fps: throughput,
        utility,
    }
}

/// A [`UtilityOracle`] whose observations are *measured* from the serving
/// simulator (the end-to-end driver's oracle). Routing advances one OMD
/// iteration per observation (single-loop style) and rides the shared
/// fused [`FlowEngine`] sweep: the `--workers` knob threads through
/// [`MeasuredOracle::with_workers`] into both the router's per-iteration
/// sweeps and the oracle's own analytic-cost telemetry
/// ([`MeasuredOracle::last_cost`]).
pub struct MeasuredOracle<E: InferenceEngine> {
    pub problem: Problem,
    pub params: ServeParams,
    pub engine: E,
    router: Box<dyn Router>,
    /// Shared flow evaluator for the analytic-cost telemetry at the served
    /// routing state (workspaces reused across observations).
    flow_engine: FlowEngine,
    phi: Phi,
    rng: Rng,
    /// The last observed Λ (bitwise), for the debug-mode check of the
    /// [`UtilityOracle::observe_dirty`] contract.
    last_lam: Option<Vec<f64>>,
    routing_iters: usize,
    observations: usize,
    /// Last serving report (for end-to-end latency/throughput logging).
    pub last_report: Option<ServeReport>,
    /// Analytic network cost `D(Λ, φ)` at the last served routing state —
    /// the model-predicted congestion next to the *measured* utility.
    pub last_cost: Option<f64>,
}

impl<E: InferenceEngine> MeasuredOracle<E> {
    /// Default wiring: OMD-RT with step size `eta` (the paper's serving
    /// setup).
    pub fn new(problem: Problem, params: ServeParams, engine: E, eta: f64, seed: u64) -> Self {
        Self::with_router(problem, params, engine, Box::new(OmdRouter::new(eta)), seed)
    }

    /// Serve with any registered routing algorithm (see
    /// [`crate::session::registry`]): the serving loop advances it one
    /// iteration per observation, whatever its update rule.
    pub fn with_router(
        problem: Problem,
        params: ServeParams,
        engine: E,
        router: Box<dyn Router>,
        seed: u64,
    ) -> Self {
        let phi = Phi::uniform(&problem.net);
        MeasuredOracle {
            problem,
            params,
            engine,
            router,
            flow_engine: FlowEngine::new(),
            phi,
            rng: Rng::seed_from(seed),
            last_lam: None,
            routing_iters: 0,
            observations: 0,
            last_report: None,
            last_cost: None,
        }
    }

    /// Engine worker threads for the per-observation sweeps (`0` = auto):
    /// applied to the router's iteration engine *and* the oracle's shared
    /// cost evaluator. Results are bit-identical at any value.
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.flow_engine.set_workers(workers);
        self.router.set_workers(workers);
        self
    }

    pub fn phi(&self) -> &Phi {
        &self.phi
    }

    /// The observation body shared by the full and dirty entry points:
    /// one routing iteration on the served state, the analytic-cost
    /// telemetry sweep, then one simulated serving window. With a dirty
    /// mask, the pre-update evaluation inside the routing step re-sweeps
    /// only the masked sessions, and the telemetry sweep re-runs only the
    /// masked ∪ router-touched rows ([`Router::touched_sessions`]) —
    /// bit-identical either way. The serving window itself always replays
    /// every session — requests don't know which λ entries moved.
    fn observe_impl(&mut self, lam: &[f64], dirty: Option<&SessionMask>) -> f64 {
        self.observations += 1;
        self.routing_iters += 1;
        match dirty {
            Some(mask) => {
                // debug check of the caller's promise: every λ entry that
                // changed since the previous observation is in the mask
                #[cfg(debug_assertions)]
                if let Some(last) = &self.last_lam {
                    if last.len() == lam.len() {
                        for (s, (a, b)) in last.iter().zip(lam).enumerate() {
                            debug_assert!(
                                a.to_bits() == b.to_bits() || mask.contains(s),
                                "observe_dirty: λ[{s}] changed outside the dirty mask"
                            );
                        }
                    }
                }
                self.router.step_dirty(&self.problem, lam, &mut self.phi, mask);
            }
            None => {
                self.router.step(&self.problem, lam, &mut self.phi);
            }
        }
        // one fused forward sweep at the post-step state: the analytic
        // congestion the flow model predicts for the window we simulate.
        // On the dirty path, everything that moved since this telemetry
        // engine's previous sweep is the caller's λ-mask plus the φ rows
        // the router just rewrote — their union is a sound dirty set.
        self.last_cost = Some(match dirty {
            Some(mask) => {
                let n = self.problem.net.n_sessions();
                match self.router.touched_sessions() {
                    Some(touched) if mask.len() == n && touched.len() == n => {
                        let mut eff = mask.clone();
                        eff.union_with(touched);
                        self.flow_engine.evaluate_cost_dirty(&self.problem, &self.phi, lam, &eff)
                    }
                    _ => self.flow_engine.evaluate_cost(&self.problem, &self.phi, lam),
                }
            }
            None => self.flow_engine.evaluate_cost(&self.problem, &self.phi, lam),
        });
        match &mut self.last_lam {
            Some(buf) if buf.len() == lam.len() => buf.copy_from_slice(lam),
            slot => *slot = Some(lam.to_vec()),
        }
        let report = simulate(
            &self.problem,
            &self.phi,
            lam,
            &mut self.engine,
            &self.params,
            &mut self.rng,
        );
        let u = report.utility;
        self.last_report = Some(report);
        u
    }
}

impl<E: InferenceEngine> UtilityOracle for MeasuredOracle<E> {
    fn observe(&mut self, lam: &[f64]) -> f64 {
        self.observe_impl(lam, None)
    }

    fn observe_dirty(&mut self, lam: &[f64], dirty: &SessionMask) -> f64 {
        self.observe_impl(lam, Some(dirty))
    }

    fn total_rate(&self) -> f64 {
        self.problem.total_rate
    }

    fn n_versions(&self) -> usize {
        self.problem.n_sessions()
    }

    fn blocks(&self) -> Vec<(usize, usize, f64)> {
        self.problem.workload.blocks()
    }

    fn uniform_allocation(&self) -> Vec<f64> {
        self.problem.uniform_allocation()
    }

    fn routing_iterations(&self) -> usize {
        self.routing_iters
    }

    fn observations(&self) -> usize {
        self.observations
    }

    fn on_topology_change(&mut self, problem: &Problem) {
        self.problem = problem.clone();
        self.phi = Phi::uniform(&self.problem.net);
        // the λ layout may have changed; drop the dirty-contract baseline
        // and the telemetry engine's delta state with it
        self.last_lam = None;
        self.flow_engine.invalidate();
    }

    fn on_workload_change(&mut self, problem: &Problem) {
        // a pure rate change keeps the served routing state warm, but the
        // telemetry engine's cached per-session flows are stale
        self.problem = problem.clone();
        self.last_lam = None;
        self.flow_engine.invalidate();
    }

    fn current_phi(&self) -> Option<&Phi> {
        Some(&self.phi)
    }

    fn last_serve_report(&self) -> Option<&ServeReport> {
        self.last_report.as_ref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topologies;
    use crate::model::cost::CostKind;
    use crate::util::rng::Rng;

    fn mk_problem(seed: u64) -> Problem {
        let mut rng = Rng::seed_from(seed);
        let net = topologies::connected_er(10, 0.3, 3, &mut rng);
        Problem::new(net, 60.0, CostKind::Exp)
    }

    #[test]
    fn all_frames_accounted() {
        let p = mk_problem(1);
        let phi = Phi::uniform(&p.net);
        let lam = p.uniform_allocation();
        let mut eng = AnalyticEngine::new(3, 7);
        let mut rng = Rng::seed_from(9);
        let params = ServeParams { sim_time: 5.0, ..ServeParams::default_for(3) };
        let rep = simulate(&p, &phi, &lam, &mut eng, &params, &mut rng);
        let done: u64 = rep.completed.iter().sum();
        assert!(done > 0, "nothing served");
        assert_eq!(rep.dropped, 0, "frames dropped on a valid topology");
        // Poisson(λ·T) sanity: within 5 sigma
        let expect: f64 = 60.0 * 5.0;
        let sigma = expect.sqrt();
        assert!(
            (done as f64 - expect).abs() < 5.0 * sigma,
            "completed {done} vs expected {expect}"
        );
        assert!(rep.mean_latency_s > 0.0);
        assert!(rep.p99_latency_s >= rep.p50_latency_s);
    }

    #[test]
    fn allocation_shifts_completions() {
        let p = mk_problem(2);
        let phi = Phi::uniform(&p.net);
        let mut eng = AnalyticEngine::new(3, 7);
        let mut rng = Rng::seed_from(11);
        let params = ServeParams { sim_time: 10.0, ..ServeParams::default_for(3) };
        let rep = simulate(&p, &phi, &[50.0, 5.0, 5.0], &mut eng, &params, &mut rng);
        assert!(
            rep.completed[0] > rep.completed[1] + rep.completed[2],
            "{:?}",
            rep.completed
        );
    }

    #[test]
    fn measured_oracle_runs_and_counts() {
        let p = mk_problem(3);
        let params = ServeParams { sim_time: 3.0, ..ServeParams::default_for(3) };
        let mut o = MeasuredOracle::new(p, params, AnalyticEngine::new(3, 5), 0.3, 13);
        let lam = [20.0, 20.0, 20.0];
        let u = o.observe(&lam);
        assert!(u.is_finite());
        assert_eq!(o.observations(), 1);
        assert_eq!(o.routing_iterations(), 1);
        assert!(o.last_report.is_some());
        assert!(o.last_serve_report().is_some());
        // shared-engine telemetry: the analytic cost at the served state
        assert!(o.last_cost.unwrap().is_finite() && o.last_cost.unwrap() > 0.0);
    }

    #[test]
    fn measured_oracle_is_bit_identical_across_engine_workers() {
        // the worker knob only parallelizes the fused sweeps — the served
        // routing state, the analytic cost, and the measured utility must
        // be bit-identical at any worker count
        let params = ServeParams { sim_time: 2.0, ..ServeParams::default_for(3) };
        let lam = [20.0, 25.0, 15.0];
        let run = |workers: usize| {
            let p = mk_problem(6);
            let mut o =
                MeasuredOracle::new(p, params.clone(), AnalyticEngine::new(3, 5), 0.3, 13)
                    .with_workers(workers);
            let us: Vec<f64> = (0..5).map(|_| o.observe(&lam)).collect();
            (us, o.phi().clone(), o.last_cost.unwrap())
        };
        let (u1, phi1, c1) = run(1);
        for workers in [2usize, 4] {
            let (u, phi, c) = run(workers);
            for (a, b) in u.iter().zip(&u1) {
                assert_eq!(a.to_bits(), b.to_bits(), "utility at {workers} workers");
            }
            assert_eq!(c.to_bits(), c1.to_bits(), "cost at {workers} workers");
            for (ra, rb) in phi.frac.iter().zip(&phi1.frac) {
                for (a, b) in ra.iter().zip(rb) {
                    assert_eq!(a.to_bits(), b.to_bits(), "phi at {workers} workers");
                }
            }
        }
    }

    #[test]
    fn dirty_observations_are_bit_identical_to_full() {
        // window-level dirty masks: feeding the exact λ-diff mask through
        // observe_dirty must reproduce the full observe sequence bit for
        // bit — the mask only prunes the routing step's pre-update sweep
        let params = ServeParams { sim_time: 2.0, ..ServeParams::default_for(3) };
        let lams = [[20.0, 25.0, 15.0], [22.0, 25.0, 13.0], [22.0, 20.0, 18.0]];
        let run = |dirty: bool| {
            let p = mk_problem(8);
            let mut o =
                MeasuredOracle::new(p, params.clone(), AnalyticEngine::new(3, 5), 0.3, 17);
            let mut prev: Option<Vec<f64>> = None;
            let us: Vec<f64> = lams
                .iter()
                .map(|lam| {
                    let u = match (&prev, dirty) {
                        (Some(last), true) => {
                            let mask = SessionMask::from_diff(last, lam);
                            o.observe_dirty(lam, &mask)
                        }
                        _ => o.observe(lam),
                    };
                    prev = Some(lam.to_vec());
                    u
                })
                .collect();
            (us, o.phi().clone(), o.last_cost.unwrap())
        };
        let (u_full, phi_full, c_full) = run(false);
        let (u_dirty, phi_dirty, c_dirty) = run(true);
        for (a, b) in u_full.iter().zip(&u_dirty) {
            assert_eq!(a.to_bits(), b.to_bits(), "dirty observation diverged");
        }
        assert_eq!(c_full.to_bits(), c_dirty.to_bits());
        for (ra, rb) in phi_full.frac.iter().zip(&phi_dirty.frac) {
            for (a, b) in ra.iter().zip(rb) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn deterministic_given_seeds() {
        let p = mk_problem(4);
        let phi = Phi::uniform(&p.net);
        let lam = p.uniform_allocation();
        let params = ServeParams { sim_time: 3.0, ..ServeParams::default_for(3) };
        let run = || {
            let mut eng = AnalyticEngine::new(3, 7);
            let mut rng = Rng::seed_from(21);
            simulate(&p, &phi, &lam, &mut eng, &params, &mut rng)
        };
        let (a, b) = (run(), run());
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.mean_latency_s, b.mean_latency_s);
    }
}

#[cfg(test)]
mod batching_tests {
    use super::*;
    use crate::graph::topologies;
    use crate::model::cost::CostKind;
    use crate::util::rng::Rng;

    fn mk_problem(seed: u64) -> Problem {
        let mut rng = Rng::seed_from(seed);
        let net = topologies::connected_er(8, 0.35, 3, &mut rng);
        Problem::new(net, 60.0, CostKind::Exp)
    }

    #[test]
    fn dynamic_batching_raises_saturated_throughput() {
        // slow hosts saturate; batching amortizes per-invocation overhead so
        // the batched run completes strictly more frames
        let p = mk_problem(1);
        let phi = Phi::uniform(&p.net);
        let lam = p.uniform_allocation();
        let run = |max_batch: usize| {
            let mut eng = AnalyticEngine::new(3, 7);
            eng.device_flops = 1.0e8; // saturated servers
            let mut rng = Rng::seed_from(5);
            let params = ServeParams {
                sim_time: 20.0,
                max_batch,
                ..ServeParams::default_for(3)
            };
            simulate(&p, &phi, &lam, &mut eng, &params, &mut rng)
        };
        let unbatched = run(1);
        let batched = run(8);
        // the DES drains every admitted frame in both runs; the batching
        // win shows up as queueing delay (and hence measured utility)
        assert_eq!(
            batched.completed.iter().sum::<u64>(),
            unbatched.completed.iter().sum::<u64>()
        );
        assert!(
            batched.mean_latency_s < 0.8 * unbatched.mean_latency_s,
            "batching should cut queueing delay: {} vs {}",
            batched.mean_latency_s,
            unbatched.mean_latency_s
        );
        assert!(batched.utility > unbatched.utility);
    }

    #[test]
    fn batch_latency_default_is_linear() {
        struct Fixed;
        impl InferenceEngine for Fixed {
            fn infer_latency(&mut self, _v: usize) -> f64 {
                0.01
            }
            fn backend(&self) -> &'static str {
                "fixed"
            }
        }
        let mut f = Fixed;
        assert!((f.infer_batch_latency(0, 5) - 0.05).abs() < 1e-12);
    }

    #[test]
    fn analytic_batching_is_sublinear() {
        let mut eng = AnalyticEngine::new(3, 3);
        eng.jitter = 0.0;
        let one = eng.infer_batch_latency(2, 1);
        let eight = eng.infer_batch_latency(2, 8);
        assert!(eight < 8.0 * one, "batching must amortize: {eight} vs {}", 8.0 * one);
        assert!(eight > one);
    }
}
