//! Message fabric: per-node inboxes over std mpsc channels, with global
//! delivered-message / byte accounting (the communication-overhead metric
//! the paper reports qualitatively in §III-B footnote 4/6).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use super::messages::Msg;

// The accounting type grew a per-shard breakdown and lives with the
// [`super::transport::Transport`] trait now; re-exported here so the
// long-standing `coordinator::net::CommStats` path keeps working.
pub use super::transport::CommStats;

/// Shared counters for fabric traffic.
#[derive(Debug, Default)]
pub struct Counters {
    pub messages: AtomicU64,
    pub bytes: AtomicU64,
}

impl Counters {
    pub fn snapshot(&self) -> (u64, u64) {
        (self.messages.load(Ordering::Relaxed), self.bytes.load(Ordering::Relaxed))
    }
}

/// Addressed sender set. Address 0..n are node actors; the leader has its
/// own inbox at [`Fabric::LEADER`].
#[derive(Clone)]
pub struct Fabric {
    senders: Vec<Sender<Msg>>,
    leader: Sender<Msg>,
    pub counters: Arc<Counters>,
}

impl Fabric {
    /// Build a fabric for `n` node actors (+ the leader). Returns the fabric
    /// plus each actor's receiver and the leader's receiver.
    pub fn new(n: usize) -> (Fabric, Vec<Receiver<Msg>>, Receiver<Msg>) {
        let mut senders = Vec::with_capacity(n);
        let mut receivers = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel();
            senders.push(tx);
            receivers.push(rx);
        }
        let (ltx, lrx) = channel();
        let fabric = Fabric { senders, leader: ltx, counters: Arc::new(Counters::default()) };
        (fabric, receivers, lrx)
    }

    pub fn n_nodes(&self) -> usize {
        self.senders.len()
    }

    /// Send to node actor `to` (counted).
    pub fn send(&self, to: usize, msg: Msg) {
        self.counters.messages.fetch_add(1, Ordering::Relaxed);
        self.counters.bytes.fetch_add(msg.wire_bytes() as u64, Ordering::Relaxed);
        // a closed inbox during shutdown is not an error
        let _ = self.senders[to].send(msg);
    }

    /// Send to the leader (counted).
    pub fn send_leader(&self, msg: Msg) {
        self.counters.messages.fetch_add(1, Ordering::Relaxed);
        self.counters.bytes.fetch_add(msg.wire_bytes() as u64, Ordering::Relaxed);
        let _ = self.leader.send(msg);
    }

    /// Broadcast to every node actor.
    pub fn broadcast(&self, msg: Msg) {
        for i in 0..self.senders.len() {
            self.send(i, msg.clone());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counting_and_delivery() {
        let (fabric, rxs, lrx) = Fabric::new(2);
        fabric.send(0, Msg::BeginRound { round: 1, eta: 0.5 });
        fabric.send(1, Msg::Shutdown);
        fabric.send_leader(Msg::RowsReport { from: 1, rows: vec![(0, 0, 1.0)] });
        assert_eq!(rxs[0].try_recv().unwrap(), Msg::BeginRound { round: 1, eta: 0.5 });
        assert_eq!(rxs[1].try_recv().unwrap(), Msg::Shutdown);
        assert!(matches!(lrx.try_recv().unwrap(), Msg::RowsReport { from: 1, .. }));
        let (msgs, bytes) = fabric.counters.snapshot();
        assert_eq!(msgs, 3);
        assert!(bytes > 0);
    }

    #[test]
    fn broadcast_reaches_all() {
        let (fabric, rxs, _lrx) = Fabric::new(3);
        fabric.broadcast(Msg::Ingress { w: 0, from: 0, rate: 0.5 });
        for rx in &rxs {
            assert_eq!(rx.try_recv().unwrap(), Msg::Ingress { w: 0, from: 0, rate: 0.5 });
        }
    }

    #[test]
    fn send_to_dropped_inbox_is_ok() {
        let (fabric, rxs, _lrx) = Fabric::new(1);
        drop(rxs);
        fabric.send(0, Msg::Shutdown); // must not panic
    }
}
