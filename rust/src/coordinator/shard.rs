//! Sharded coordination plane: OMD-RT rounds partitioned across K leader
//! shards with staleness-bounded λ-sync gossip.
//!
//! The single-leader plane ([`super::leader::DistributedOmd`]) sweeps every
//! session per barriered round — correct and bit-identical to the
//! centralized router, but one leader cannot reach 10⁴-node fleets. This
//! module shards the plane:
//!
//! * **Partition** — sessions are split into K contiguous ranges, snapped
//!   to the [`crate::graph::augmented::BatchCsr`] version-block boundaries
//!   when those tile the session space (single-class layouts), falling
//!   back to an even contiguous split otherwise
//!   ([`partition_sessions`]).
//! * **Rounds** — each shard runs the full OMD-RT round over *its own*
//!   sessions: eq. 1/4 forward sweep → per-edge flow aggregate `A_k[e]` →
//!   eq. 21 pricing on the synced total `F[e] = Σ_k A_k[e]` → eq. 20–21
//!   reverse marginal sweep → eq. 22 mirror updates (the shared
//!   [`OmdRouter::update_row`] kernel).
//! * **Gossip** — instead of a full broadcast, shards exchange
//!   [`Msg::FlowDelta`] messages over a [`Transport`]: the bitwise-changed
//!   entries of `A_k` only. Reconstruction at the peers is exact.
//! * **Staleness bound S** — a shard at round `r` prices against peer
//!   aggregates from round `max(0, r − S)` *exactly* (deterministic lag,
//!   not "most recent available"), so a run is a pure function of
//!   `(problem, φ⁰, Λ, K, S)`. The paper's OMD regret analysis (and the
//!   asynchronous congestion-routing follow-ups, arXiv 2205.07178)
//!   tolerates bounded gradient delay, which is precisely what S encodes.
//!   A peer that cannot satisfy the bound within the sync timeout surfaces
//!   as [`SessionError::StalenessExceeded`] — never a hang.
//!
//! `K = 1` degenerates to the current single-leader plane:
//! [`ShardedOmd`] delegates to an inner [`DistributedOmd`], so the
//! existing loopback bit-identity pin (distributed ≡ centralized OMD-RT)
//! carries over structurally.
//!
//! The round kernel operates on the compact lane-level [`ShardBlock`]
//! layout (no dense per-session edge rows), so the same code path drives
//! both real [`Problem`]s and the 10⁴-node / 10⁵-session synthetic fleet
//! of the `fleet1e4/sharded_round_throughput` hotpath bench.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Duration;

use super::leader::DistributedOmd;
use super::messages::Msg;
use super::transport::{CommStats, Loopback, Transport};
use crate::engine::{FlowEngine, SessionMask};
use crate::graph::augmented::AugmentedNet;
use crate::model::cost::CostKind;
use crate::model::flow::Phi;
use crate::model::Problem;
use crate::routing::omd::OmdRouter;
use crate::routing::Router;
use crate::session::error::SessionError;

/// One shard's compact, lane-level view of its owned sessions. Node ids
/// are session-local *topo positions*: row `j` of a session is its `j`-th
/// node in forward topological order, and [`ShardBlock::lane_dst`] points
/// at the destination's topo position within the same session. This keeps
/// a shard's footprint O(Σ lanes) instead of O(sessions × edges), which is
/// what makes 10⁵-session fleets representable at all.
#[derive(Clone, Debug, Default)]
pub struct ShardBlock {
    /// Global ids of the owned sessions (ascending).
    pub sessions: Vec<usize>,
    /// Arrival rate λ_w per owned session (refreshed every round).
    pub lam: Vec<f64>,
    /// Topo position of the virtual source per owned session.
    pub src: Vec<usize>,
    /// Per session: lane span `(start, end)` per topo position, into the
    /// flat lane arrays.
    pub rows: Vec<Vec<(usize, usize)>>,
    /// Flat lanes (session-major, rows in topo order): global edge id.
    pub lane_edge: Vec<usize>,
    /// Topo position of each lane's head node within its session.
    pub lane_dst: Vec<usize>,
    /// Routing fraction per lane — the shard-owned slice of φ.
    pub phi: Vec<f64>,
}

impl ShardBlock {
    /// Total lanes across the owned sessions.
    pub fn n_lanes(&self) -> usize {
        self.lane_edge.len()
    }
}

/// One peer's reconstructed flow aggregate plus the retained history the
/// staleness bound needs (rounds `r − S ..= r`).
#[derive(Clone, Debug, Default)]
struct PeerAgg {
    /// Running aggregate after overlaying every delta received so far.
    latest: Vec<f64>,
    /// Retained versions, ascending by round.
    ring: VecDeque<(u64, Vec<f64>)>,
}

impl PeerAgg {
    fn apply(&mut self, round: u64, edges: &[(usize, f64)], keep: usize) {
        for &(e, v) in edges {
            self.latest[e] = v;
        }
        self.ring.push_back((round, self.latest.clone()));
        while self.ring.len() > keep {
            self.ring.pop_front();
        }
    }

    fn version(&self, round: u64) -> Option<&[f64]> {
        self.ring
            .iter()
            .find(|&&(r, _)| r == round)
            .map(|(_, agg)| agg.as_slice())
    }
}

/// Per-shard gossip state (publish history + peer reconstructions).
#[derive(Clone, Debug, Default)]
struct Gossip {
    /// The aggregate this shard published last round (delta baseline).
    own_prev: Vec<f64>,
    /// One [`PeerAgg`] per shard index (the own slot stays empty).
    peers: Vec<PeerAgg>,
}

/// The sharded round driver: K [`ShardBlock`]s, the shared edge tables,
/// and the gossip state, stepped one staleness-bounded round at a time
/// over a [`Transport`]. Used by [`ShardedOmd`] for real problems and
/// driven directly by the scale bench on synthetic fleets.
pub struct ShardPlane {
    blocks: Vec<ShardBlock>,
    edge_cap: Vec<f64>,
    edge_kind: Vec<CostKind>,
    staleness: usize,
    transport: Arc<dyn Transport>,
    sync_timeout: Duration,
    round: u64,
    gossip: Vec<Gossip>,
}

impl ShardPlane {
    /// Build a plane over pre-lowered blocks. `edge_cap` / `edge_kind` are
    /// the global per-edge capacity and cost-family tables; `transport`
    /// must connect exactly `blocks.len()` shards.
    pub fn new(
        blocks: Vec<ShardBlock>,
        edge_cap: Vec<f64>,
        edge_kind: Vec<CostKind>,
        staleness: usize,
        transport: Arc<dyn Transport>,
        sync_timeout: Duration,
    ) -> Result<ShardPlane, SessionError> {
        if transport.shards() != blocks.len() {
            return Err(SessionError::InvalidScenario {
                what: format!(
                    "transport connects {} shards but the plane has {} blocks",
                    transport.shards(),
                    blocks.len()
                ),
            });
        }
        let ne = edge_cap.len();
        let k = blocks.len();
        let gossip = (0..k)
            .map(|_| Gossip {
                own_prev: vec![0.0; ne],
                peers: (0..k).map(|_| PeerAgg { latest: vec![0.0; ne], ring: VecDeque::new() }).collect(),
            })
            .collect();
        Ok(ShardPlane {
            blocks,
            edge_cap,
            edge_kind,
            staleness,
            transport,
            sync_timeout,
            round: 0,
            gossip,
        })
    }

    pub fn n_shards(&self) -> usize {
        self.blocks.len()
    }

    pub fn n_sessions(&self) -> usize {
        self.blocks.iter().map(|b| b.sessions.len()).sum()
    }

    /// Rounds completed so far.
    pub fn rounds(&self) -> u64 {
        self.round
    }

    pub fn blocks(&self) -> &[ShardBlock] {
        &self.blocks
    }

    pub fn transport(&self) -> &Arc<dyn Transport> {
        &self.transport
    }

    /// Refresh the per-session arrival rates from a global Λ vector.
    pub fn set_lam(&mut self, lam: &[f64]) {
        for block in &mut self.blocks {
            for (slot, &w) in block.sessions.iter().enumerate() {
                block.lam[slot] = lam[w];
            }
        }
    }

    /// One staleness-bounded round across every shard (scoped threads; a
    /// shard that cannot sync within the timeout aborts the round with a
    /// typed error). Deterministic for a fixed `(blocks, Λ, K, S)` at any
    /// thread interleaving: each shard's arithmetic depends only on the
    /// per-peer round-tagged aggregates, never on arrival order.
    pub fn run_round(&mut self, eta: f64) -> Result<(), SessionError> {
        let round = self.round;
        let staleness = self.staleness;
        let timeout = self.sync_timeout;
        let k = self.blocks.len();
        let (caps, kinds) = (&self.edge_cap, &self.edge_kind);
        let transport = &self.transport;
        let results: Vec<Result<(), SessionError>> = std::thread::scope(|scope| {
            let handles: Vec<_> = self
                .blocks
                .iter_mut()
                .zip(self.gossip.iter_mut())
                .enumerate()
                .map(|(shard, (block, gossip))| {
                    let t = Arc::clone(transport);
                    scope.spawn(move || {
                        shard_round(
                            shard, k, block, gossip, caps, kinds, round, staleness, eta,
                            t.as_ref(), timeout,
                        )
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("shard thread panicked")).collect()
        });
        for r in results {
            r?;
        }
        self.round += 1;
        Ok(())
    }
}

/// One shard's half of a round: forward sweep → gossip → staleness-bounded
/// sync → pricing → reverse sweep → mirror updates.
#[allow(clippy::too_many_arguments)]
fn shard_round(
    shard: usize,
    k: usize,
    block: &mut ShardBlock,
    gossip: &mut Gossip,
    caps: &[f64],
    kinds: &[CostKind],
    round: u64,
    staleness: usize,
    eta: f64,
    transport: &dyn Transport,
    timeout: Duration,
) -> Result<(), SessionError> {
    let ne = caps.len();
    // --- eq. 1/4 forward sweeps: per-session node rates t_i(w) and the
    //     shard's per-edge flow aggregate A_k[e], summed in ascending
    //     session order (deterministic association)
    let mut t_flat: Vec<f64> = Vec::new();
    let mut t_off: Vec<usize> = Vec::with_capacity(block.sessions.len() + 1);
    let mut own = vec![0.0f64; ne];
    t_off.push(0);
    for (s, rows) in block.rows.iter().enumerate() {
        let base = t_flat.len();
        t_flat.resize(base + rows.len(), 0.0);
        t_flat[base + block.src[s]] = block.lam[s];
        for (j, &(l0, l1)) in rows.iter().enumerate() {
            let ti = t_flat[base + j];
            if ti <= 0.0 {
                continue;
            }
            for l in l0..l1 {
                let f = ti * block.phi[l];
                own[block.lane_edge[l]] += f;
                t_flat[base + block.lane_dst[l]] += f;
            }
        }
        t_off.push(t_flat.len());
    }
    // --- gossip the λ-sync delta: only the bitwise-changed aggregate
    //     entries, with their new absolute value (exact reconstruction)
    let edges: Vec<(usize, f64)> = own
        .iter()
        .zip(&gossip.own_prev)
        .enumerate()
        .filter(|(_, (a, b))| a.to_bits() != b.to_bits())
        .map(|(e, (&a, _))| (e, a))
        .collect();
    for p in 0..k {
        if p != shard {
            transport.send(shard, p, Msg::FlowDelta { shard, round, edges: edges.clone() });
        }
    }
    gossip.own_prev.copy_from_slice(&own);
    // --- staleness-bounded sync: in lockstep every peer publishes exactly
    //     one delta per round, so drain K−1 messages (they advance the
    //     per-peer reconstructions), then read each peer at round
    //     `max(0, r − S)` — the exact-lag version the bound prescribes
    let stale_err = || SessionError::StalenessExceeded {
        shard,
        round: round as usize,
        bound: staleness,
    };
    let mut pending = k - 1;
    while pending > 0 {
        let msg = transport.recv(shard, timeout).ok_or_else(stale_err)?;
        match msg {
            Msg::FlowDelta { shard: from, round: r, edges } => {
                gossip.peers[from].apply(r, &edges, staleness + 1);
                pending -= 1;
            }
            other => panic!("unexpected message at shard {shard}: {other:?}"),
        }
    }
    let needed = round.saturating_sub(staleness as u64);
    if needed < round {
        transport.note_stale_round(shard);
    }
    // --- synced total flows F[e] = Σ_k A_k[e] in ascending shard order
    //     (own aggregate fresh, peers ≤ S rounds stale)
    let mut flows = vec![0.0f64; ne];
    for p in 0..k {
        let agg: &[f64] =
            if p == shard { &own } else { gossip.peers[p].version(needed).ok_or_else(stale_err)? };
        for (f, a) in flows.iter_mut().zip(agg) {
            *f += a;
        }
    }
    // --- eq. 21 pricing at the synced flows
    let dprime: Vec<f64> =
        (0..ne).map(|e| kinds[e].derivative(flows[e], caps[e])).collect();
    // --- eq. 20–21 reverse marginal sweeps + eq. 22 mirror updates
    let mut r_buf: Vec<f64> = Vec::new();
    let mut delta_buf: Vec<f64> = Vec::new();
    for (s, rows) in block.rows.iter().enumerate() {
        let base = t_off[s];
        r_buf.clear();
        r_buf.resize(rows.len(), 0.0);
        for j in (0..rows.len()).rev() {
            let (l0, l1) = rows[j];
            let mut acc = 0.0;
            for l in l0..l1 {
                let f = block.phi[l];
                if f > 0.0 {
                    acc += f * (dprime[block.lane_edge[l]] + r_buf[block.lane_dst[l]]);
                }
            }
            // destinations have no lanes and stay at r = 0 (eq. 20)
            r_buf[j] = acc;
        }
        for (j, &(l0, l1)) in rows.iter().enumerate() {
            if l1 - l0 < 2 || t_flat[base + j] <= 0.0 {
                continue;
            }
            delta_buf.clear();
            delta_buf.extend(
                (l0..l1).map(|l| dprime[block.lane_edge[l]] + r_buf[block.lane_dst[l]]),
            );
            OmdRouter::update_row(&mut block.phi[l0..l1], &delta_buf, eta);
        }
    }
    Ok(())
}

/// Partition sessions into `k` contiguous ranges. When the
/// [`crate::graph::augmented::BatchCsr`] version blocks tile the session
/// space as contiguous runs (single-class layouts), shard cuts snap to
/// block boundaries so each shard owns whole version blocks; otherwise
/// (multi-class class-major layouts, where block session ids interleave)
/// the split is even. `k` is clamped to the session count, so tiny
/// problems may deploy fewer effective shards than requested.
pub fn partition_sessions(net: &AugmentedNet, k: usize) -> Vec<(usize, usize)> {
    let n = net.n_sessions();
    let k = k.max(1).min(n.max(1));
    // block end boundaries, if the blocks tile 0..n contiguously
    let mut cuts: Vec<usize> = Vec::new();
    let mut tiled = true;
    let mut next = 0usize;
    for b in &net.batch.blocks {
        if b.sessions.is_empty() {
            continue;
        }
        if b.sessions[0] != next || b.sessions.windows(2).any(|w| w[1] != w[0] + 1) {
            tiled = false;
            break;
        }
        next = b.sessions.last().unwrap() + 1;
        cuts.push(next);
    }
    tiled = tiled && next == n && cuts.len() >= k;
    let mut ranges = Vec::with_capacity(k);
    let mut start = 0usize;
    if tiled {
        let b = cuts.len();
        let mut ci = 0usize;
        for g in 0..k {
            let end = if g == k - 1 {
                n
            } else {
                // close the shard at the first boundary reaching its
                // proportional share, leaving one block per remaining shard
                let target = (g + 1) * n / k;
                let max_ci = b - (k - 1 - g);
                let mut j = ci;
                while j + 1 < max_ci && cuts[j] < target {
                    j += 1;
                }
                ci = j + 1;
                cuts[j]
            };
            ranges.push((start, end));
            start = end;
        }
    } else {
        let (base, rem) = (n / k, n % k);
        for g in 0..k {
            let len = base + usize::from(g < rem);
            ranges.push((start, start + len));
            start += len;
        }
    }
    ranges
}

/// Lower a contiguous session range of a [`Problem`] into the compact
/// [`ShardBlock`] layout, seeding lane φ from `phi`.
pub fn lower_block(problem: &Problem, phi: &Phi, s0: usize, s1: usize) -> ShardBlock {
    let net = &problem.net;
    let mut block = ShardBlock::default();
    let mut pos = vec![usize::MAX; net.n_nodes()];
    for w in s0..s1 {
        let topo = net.session_topo(w);
        for (j, &i) in topo.iter().enumerate() {
            pos[i] = j;
        }
        let mut rows = Vec::with_capacity(topo.len());
        for &i in topo {
            let l0 = block.lane_edge.len();
            for e in net.session_out(w, i) {
                block.lane_edge.push(e);
                block.lane_dst.push(pos[net.graph.edge(e).dst]);
                block.phi.push(phi.frac[w][e]);
            }
            rows.push((l0, block.lane_edge.len()));
        }
        block.sessions.push(w);
        block.lam.push(0.0);
        block.src.push(pos[AugmentedNet::SOURCE]);
        block.rows.push(rows);
    }
    block
}

/// A deployed plane plus what it was built for (redeploy detection, same
/// contract as the single-leader fleet).
struct PlaneDeployment {
    plane: ShardPlane,
    digest: u64,
    /// The routing state the blocks currently hold (synced after every
    /// successful round); a caller handing in a different φ forces a
    /// redeploy, exactly like the single-leader fleet.
    phi: Phi,
}

/// Sharded OMD-RT behind the standard [`Router`] protocol: registry name
/// `"sharded-omd"`. `K = 1` delegates to the single-leader
/// [`DistributedOmd`] (bit-identical to centralized OMD-RT by the existing
/// loopback pin); `K ≥ 2` runs staleness-bounded rounds on a
/// [`ShardPlane`]. One [`Router::step`] is one plane round; the adaptive
/// η schedule is the same backtracking rule every OMD variant shares.
pub struct ShardedOmd {
    /// Base mirror-descent step size η.
    pub eta: f64,
    /// Backtracking η adaptation (default on).
    pub adaptive: bool,
    shards: usize,
    staleness: usize,
    eta_cur: f64,
    last_cost: Option<f64>,
    /// Leader-side cost telemetry (drives the adaptive η rule).
    engine: FlowEngine,
    rounds: usize,
    /// The K = 1 degenerate case: the current single-leader plane.
    inner: Option<DistributedOmd>,
    deployment: Option<PlaneDeployment>,
    transport_override: Option<Arc<dyn Transport>>,
    sync_timeout: Duration,
    fault: Option<SessionError>,
    touched: Option<SessionMask>,
    comm_base: CommStats,
}

impl ShardedOmd {
    pub fn new(eta: f64, shards: usize, staleness: usize) -> Self {
        let shards = shards.max(1);
        ShardedOmd {
            eta,
            adaptive: true,
            shards,
            staleness,
            eta_cur: eta,
            last_cost: None,
            engine: FlowEngine::new(),
            rounds: 0,
            inner: (shards == 1).then(|| DistributedOmd::new(eta)),
            deployment: None,
            transport_override: None,
            sync_timeout: Duration::from_secs(5),
            fault: None,
            touched: None,
            comm_base: CommStats::default(),
        }
    }

    /// Fixed-step variant (theory experiments).
    pub fn fixed(eta: f64, shards: usize, staleness: usize) -> Self {
        let mut router = Self::new(eta, shards, staleness);
        router.adaptive = false;
        router.inner = (router.shards == 1).then(|| DistributedOmd::fixed(eta));
        router
    }

    /// Swap the transport (e.g. a [`super::transport::Blackhole`] for
    /// fault-injection tests, or a socket transport later). The transport
    /// must connect exactly the effective shard count.
    pub fn with_transport(mut self, transport: Arc<dyn Transport>) -> Self {
        self.transport_override = Some(transport);
        self
    }

    /// How long a shard waits for a peer delta before declaring the
    /// staleness bound violated (default 5 s).
    pub fn with_sync_timeout(mut self, timeout: Duration) -> Self {
        self.sync_timeout = timeout;
        self
    }

    /// The staleness fault of the most recent [`Router::step`], if any
    /// (the infallible `step` stores it; [`ShardedOmd::try_step`] returns
    /// it directly).
    pub fn fault(&self) -> Option<&SessionError> {
        self.fault.as_ref()
    }

    pub fn take_fault(&mut self) -> Option<SessionError> {
        self.fault.take()
    }

    pub fn shard_count(&self) -> usize {
        self.shards
    }

    pub fn staleness_bound(&self) -> usize {
        self.staleness
    }

    fn ensure_deployed(&mut self, problem: &Problem, phi: &Phi) -> Result<(), SessionError> {
        let digest = DistributedOmd::fleet_digest(problem);
        let in_sync = self
            .deployment
            .as_ref()
            .is_some_and(|d| d.digest == digest && d.phi == *phi);
        if in_sync {
            return Ok(());
        }
        self.teardown();
        // a redeploy is a fresh run: restart the backtracking schedule
        self.eta_cur = self.eta;
        self.last_cost = None;
        let ranges = partition_sessions(&problem.net, self.shards);
        let blocks: Vec<ShardBlock> =
            ranges.iter().map(|&(s0, s1)| lower_block(problem, phi, s0, s1)).collect();
        let net = &problem.net;
        let ne = net.graph.n_edges();
        let edge_cap: Vec<f64> = (0..ne).map(|e| net.graph.edge(e).capacity).collect();
        let edge_kind: Vec<CostKind> = (0..ne).map(|e| problem.edge_kind(e)).collect();
        let transport = match &self.transport_override {
            Some(t) => Arc::clone(t),
            None => Arc::new(Loopback::new(blocks.len())) as Arc<dyn Transport>,
        };
        let plane = ShardPlane::new(
            blocks,
            edge_cap,
            edge_kind,
            self.staleness,
            transport,
            self.sync_timeout,
        )?;
        self.deployment = Some(PlaneDeployment { plane, digest, phi: phi.clone() });
        Ok(())
    }

    /// Fold the live transport counters into the carried-over base and
    /// drop the plane (the next step redeploys).
    fn teardown(&mut self) {
        if let Some(dep) = self.deployment.take() {
            self.comm_base.absorb(&dep.plane.transport().comm());
        }
    }

    /// One sharded round, with staleness faults surfaced as a typed error
    /// instead of being parked on [`ShardedOmd::fault`]. On error φ is
    /// untouched and the plane is torn down (the next step redeploys
    /// cleanly).
    pub fn try_step(
        &mut self,
        problem: &Problem,
        lam: &[f64],
        phi: &mut Phi,
    ) -> Result<f64, SessionError> {
        if let Some(inner) = self.inner.as_mut() {
            // K = 1: the single-leader plane, bit for bit
            return Ok(inner.step(problem, lam, phi));
        }
        self.ensure_deployed(problem, phi)?;
        let cost_before = self.engine.evaluate_cost(problem, phi, lam);
        if self.adaptive {
            self.eta_cur =
                OmdRouter::adapt_eta(self.eta_cur, self.eta, self.last_cost, cost_before);
        }
        self.last_cost = Some(cost_before);
        let dep = self.deployment.as_mut().expect("deployed above");
        dep.plane.set_lam(lam);
        if let Err(e) = dep.plane.run_round(self.eta_cur) {
            // a failed round may have updated some shards' rows; drop the
            // plane so the next step rebuilds from the caller's clean φ
            self.teardown();
            return Err(e);
        }
        // scatter the shard-owned lanes back into the dense φ
        for block in dep.plane.blocks() {
            for (slot, &w) in block.sessions.iter().enumerate() {
                let row = &mut phi.frac[w];
                for &(l0, l1) in &block.rows[slot] {
                    for l in l0..l1 {
                        row[block.lane_edge[l]] = block.phi[l];
                    }
                }
            }
        }
        dep.phi.clone_from(phi);
        self.rounds += 1;
        self.touched = Some(SessionMask::all(problem.net.n_sessions()));
        Ok(cost_before)
    }
}

impl Router for ShardedOmd {
    fn name(&self) -> &'static str {
        "sharded-omd"
    }

    /// One sharded round. A staleness fault is stored on
    /// [`ShardedOmd::fault`] (φ untouched, previous cost returned) so the
    /// infallible `Router` protocol keeps streaming; use
    /// [`ShardedOmd::try_step`] for the typed result.
    fn step(&mut self, problem: &Problem, lam: &[f64], phi: &mut Phi) -> f64 {
        match self.try_step(problem, lam, phi) {
            Ok(cost) => {
                self.fault = None;
                cost
            }
            Err(e) => {
                self.fault = Some(e);
                self.last_cost.unwrap_or(f64::INFINITY)
            }
        }
    }

    fn touched_sessions(&self) -> Option<&SessionMask> {
        if let Some(inner) = self.inner.as_ref() {
            return inner.touched_sessions();
        }
        self.touched.as_ref()
    }

    fn set_workers(&mut self, workers: usize) {
        self.engine.set_workers(workers);
        if let Some(inner) = self.inner.as_mut() {
            inner.set_workers(workers);
        }
    }

    fn set_batch_mode(&mut self, mode: crate::engine::BatchMode) {
        self.engine.set_batch_mode(mode);
        if let Some(inner) = self.inner.as_mut() {
            inner.set_batch_mode(mode);
        }
    }

    fn comm_stats(&self) -> Option<CommStats> {
        if let Some(inner) = self.inner.as_ref() {
            return inner.comm_stats();
        }
        let mut comm = self.comm_base.clone();
        if let Some(dep) = self.deployment.as_ref() {
            comm.absorb(&dep.plane.transport().comm());
        }
        comm.rounds = self.rounds;
        Some(comm)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topologies;
    use crate::util::rng::Rng;

    fn problem(seed: u64, n: usize) -> Problem {
        let mut rng = Rng::seed_from(seed);
        let net = topologies::connected_er(n, 0.35, 3, &mut rng);
        Problem::new(net, 60.0, CostKind::Exp)
    }

    #[test]
    fn partition_snaps_to_version_blocks_when_tiled() {
        let p = problem(1, 9);
        let n = p.net.n_sessions();
        for k in 1..=n.min(4) {
            let ranges = partition_sessions(&p.net, k);
            assert_eq!(ranges.len(), k);
            assert_eq!(ranges[0].0, 0);
            assert_eq!(ranges[k - 1].1, n);
            for w in ranges.windows(2) {
                assert_eq!(w[0].1, w[1].0, "ranges must tile");
                assert!(w[0].0 < w[0].1, "ranges must be non-empty");
            }
        }
        // single-class: one session per version, so blocks are singleton
        // runs and every cut lands on a block boundary by construction
        let blocks = &p.net.batch.blocks;
        if blocks.iter().all(|b| !b.sessions.is_empty()) {
            let boundaries: Vec<usize> =
                blocks.iter().map(|b| b.sessions.last().unwrap() + 1).collect();
            for &(_, end) in &partition_sessions(&p.net, 3) {
                assert!(end == n || boundaries.contains(&end), "cut {end} off-boundary");
            }
        }
    }

    #[test]
    fn partition_clamps_to_session_count() {
        let p = problem(2, 6);
        let n = p.net.n_sessions();
        let ranges = partition_sessions(&p.net, n + 5);
        assert_eq!(ranges.len(), n);
        assert!(ranges.iter().all(|&(a, b)| b == a + 1));
    }

    #[test]
    fn lowered_blocks_round_trip_phi() {
        let p = problem(3, 8);
        let phi = Phi::uniform(&p.net);
        let n = p.net.n_sessions();
        let block = lower_block(&p, &phi, 0, n);
        assert_eq!(block.sessions.len(), n);
        // every lane's φ matches the dense row it was gathered from
        for (slot, &w) in block.sessions.iter().enumerate() {
            for &(l0, l1) in &block.rows[slot] {
                for l in l0..l1 {
                    assert_eq!(
                        block.phi[l].to_bits(),
                        phi.frac[w][block.lane_edge[l]].to_bits()
                    );
                }
            }
        }
    }

    #[test]
    fn k1_delegates_to_the_single_leader_plane() {
        let p = problem(4, 7);
        let lam = p.uniform_allocation();
        let mut sharded = ShardedOmd::new(0.3, 1, 2);
        let mut single = DistributedOmd::new(0.3);
        let mut phi_a = Phi::uniform(&p.net);
        let mut phi_b = Phi::uniform(&p.net);
        for _ in 0..6 {
            let a = sharded.step(&p, &lam, &mut phi_a);
            let b = single.step(&p, &lam, &mut phi_b);
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(phi_a, phi_b);
        assert_eq!(sharded.name(), "sharded-omd");
    }

    #[test]
    fn sharded_rounds_are_deterministic_and_descend() {
        let p = problem(5, 10);
        let lam = p.uniform_allocation();
        for (k, s) in [(2, 0), (2, 1), (3, 2)] {
            let run = |_: usize| {
                let mut router = ShardedOmd::fixed(0.05, k, s);
                let mut phi = Phi::uniform(&p.net);
                let mut traj = Vec::new();
                for _ in 0..10 {
                    traj.push(router.try_step(&p, &lam, &mut phi).unwrap());
                }
                (traj, phi)
            };
            let (t1, phi1) = run(0);
            let (t2, phi2) = run(1);
            for (a, b) in t1.iter().zip(&t2) {
                assert_eq!(a.to_bits(), b.to_bits(), "K={k} S={s}");
            }
            assert_eq!(phi1, phi2, "K={k} S={s}");
            if s == 0 {
                // S = 0 prices every shard against the same-round flows —
                // exactly the centralized gradient, so the small-step
                // monotone-descent guarantee carries over; lagged rounds
                // (S > 0) only promise bounded-delay convergence
                for w in t1.windows(2) {
                    assert!(w[1] <= w[0] + 1e-9, "K={k}: {} -> {}", w[0], w[1]);
                }
            }
            assert!(t1.iter().all(|c| c.is_finite()), "K={k} S={s}");
            assert!(
                t1.last().unwrap() < t1.first().unwrap(),
                "K={k} S={s}: no net progress over 10 rounds"
            );
            phi1.is_feasible(&p.net, 1e-9).unwrap();
        }
    }

    #[test]
    fn comm_stats_carry_per_shard_breakdown() {
        let p = problem(6, 8);
        let lam = p.uniform_allocation();
        let mut router = ShardedOmd::new(0.2, 2, 1);
        let mut phi = Phi::uniform(&p.net);
        for _ in 0..4 {
            router.try_step(&p, &lam, &mut phi).unwrap();
        }
        let comm = router.comm_stats().unwrap();
        assert_eq!(comm.rounds, 4);
        assert_eq!(comm.shards.len(), 2);
        // each shard gossips one delta per peer per round
        assert_eq!(comm.messages, 2 * 4);
        assert!(comm.bytes > 0);
        // S = 1: every round past the first prices against lagged peers
        assert_eq!(comm.stale_rounds(), 2 * 3);
    }
}
