//! One actor per edge device: holds only *local* state (its own routing
//! rows, its out-link capacities), learns `t_i(w)` from upstream ingress
//! messages, computes its link marginals locally, participates in the
//! marginal-cost broadcast, and applies the eq.-(22) mirror update to its
//! own rows — exactly the distributed node-based scheme of Algorithm 2.
//!
//! The actor's arithmetic must agree with [`crate::routing::omd`] to the
//! last bit; the integration tests cross-check distributed vs centralized
//! trajectories.

use std::sync::mpsc::Receiver;

use super::messages::Msg;
use super::net::Fabric;
use crate::model::cost::CostKind;
use crate::routing::omd::OmdRouter;

/// Where an out-edge leads, from the actor's perspective.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Peer {
    /// A real device actor (actor index).
    Actor(usize),
    /// The virtual destination `D_w` (marginal is 0, no messages needed).
    Destination,
    /// The virtual source / leader (marginals are reported to the leader).
    Leader,
}

/// One out-edge of this node inside one session's DAG.
#[derive(Clone, Debug)]
pub struct OutLane {
    pub edge_id: usize,
    pub dst: Peer,
    pub capacity: f64,
}

/// Static per-epoch description of one node's view of the network.
#[derive(Clone, Debug)]
pub struct NodeSpec {
    /// Actor index (= augmented node id − 1).
    pub actor: usize,
    /// Augmented-graph node id (for message attribution).
    pub node_id: usize,
    pub n_sessions: usize,
    pub cost: CostKind,
    /// `lanes[w]` — session w's usable out-edges.
    pub lanes: Vec<Vec<OutLane>>,
    /// `in_peers[w]` — upstream peers (for the marginal broadcast).
    pub in_peers: Vec<Vec<Peer>>,
    /// Initial routing fractions per session (parallel to `lanes`).
    pub phi0: Vec<Vec<f64>>,
}

impl NodeSpec {
    fn expected_ingress(&self, w: usize) -> usize {
        self.in_peers[w].len()
    }

    fn expected_marginals(&self, w: usize) -> usize {
        self.lanes[w].iter().filter(|l| matches!(l.dst, Peer::Actor(_))).count()
    }
}

/// Per-round mutable state.
struct RoundState {
    eta: f64,
    /// accumulated ingress per session + received count
    t: Vec<f64>,
    t_seen: Vec<usize>,
    /// downstream marginals per (session, edge slot); None until received
    r_down: Vec<Vec<Option<f64>>>,
    /// link marginals D' per (session, edge slot); computed once flows known
    dprime: Vec<Vec<f64>>,
    flows_done: bool,
    sent_ingress: Vec<bool>,
    sent_marginal: Vec<bool>,
    reported: bool,
}

impl RoundState {
    fn new(spec: &NodeSpec, eta: f64) -> Self {
        let w = spec.n_sessions;
        RoundState {
            eta,
            t: vec![0.0; w],
            t_seen: vec![0; w],
            r_down: (0..w)
                .map(|i| {
                    spec.lanes[i]
                        .iter()
                        .map(|l| match l.dst {
                            Peer::Actor(_) => None,
                            // destination / leader lanes have r = 0 (eq. 20)
                            _ => Some(0.0),
                        })
                        .collect()
                })
                .collect(),
            dprime: (0..w).map(|i| vec![0.0; spec.lanes[i].len()]).collect(),
            flows_done: false,
            sent_ingress: vec![false; w],
            sent_marginal: vec![false; w],
            reported: false,
        }
    }
}

/// The node actor. `run` consumes the inbox until `Shutdown`.
pub struct NodeActor {
    pub spec: NodeSpec,
    /// Current routing fractions (persist across rounds — warm state).
    pub phi: Vec<Vec<f64>>,
}

impl NodeActor {
    pub fn new(spec: NodeSpec) -> Self {
        let phi = spec.phi0.clone();
        NodeActor { spec, phi }
    }

    pub fn run(mut self, inbox: Receiver<Msg>, fabric: Fabric) {
        let mut round: Option<RoundState> = None;
        let mut pending: Vec<Msg> = Vec::new();
        while let Ok(msg) = inbox.recv() {
            match msg {
                Msg::Shutdown => break,
                Msg::BeginRound { eta, .. } => {
                    let mut st = RoundState::new(&self.spec, eta);
                    // replay any messages that raced ahead of BeginRound
                    for m in pending.drain(..) {
                        self.handle(&mut st, m, &fabric);
                    }
                    self.progress(&mut st, &fabric);
                    round = Some(st);
                }
                m => match round {
                    Some(ref mut st) if !st.reported => {
                        self.handle(st, m, &fabric);
                        self.progress(st, &fabric);
                    }
                    // between rounds: buffer until the next BeginRound
                    _ => pending.push(m),
                },
            }
            if let Some(ref st) = round {
                if st.reported {
                    round = None;
                }
            }
        }
    }

    fn handle(&mut self, st: &mut RoundState, msg: Msg, _fabric: &Fabric) {
        match msg {
            Msg::Ingress { w, rate } => {
                st.t[w] += rate;
                st.t_seen[w] += 1;
            }
            Msg::Marginal { w, from, value } => {
                // locate the lane pointing at `from`
                for (slot, lane) in self.spec.lanes[w].iter().enumerate() {
                    if let Peer::Actor(a) = lane.dst {
                        if a + 1 == from {
                            st.r_down[w][slot] = Some(value);
                        }
                    }
                }
            }
            _ => {}
        }
    }

    /// Drive the per-round state machine as far as possible.
    fn progress(&mut self, st: &mut RoundState, fabric: &Fabric) {
        let spec = &self.spec;
        let w_cnt = spec.n_sessions;

        // 1. forward ingress downstream as soon as a session's own ingress
        //    is complete
        for w in 0..w_cnt {
            if !st.sent_ingress[w] && st.t_seen[w] == spec.expected_ingress(w) {
                st.sent_ingress[w] = true;
                for (slot, lane) in spec.lanes[w].iter().enumerate() {
                    if let Peer::Actor(a) = lane.dst {
                        fabric.send(a, Msg::Ingress { w, rate: st.t[w] * self.phi[w][slot] });
                    }
                }
            }
        }

        // 2. once *all* sessions' ingress arrived, link flows (and hence the
        //    local marginals D'_ij) are known
        if !st.flows_done && (0..w_cnt).all(|w| st.sent_ingress[w]) {
            st.flows_done = true;
            // F_e sums every session's contribution on the shared physical
            // edge; sessions may share an edge id
            let mut flow_of: std::collections::HashMap<usize, f64> =
                std::collections::HashMap::new();
            for w in 0..w_cnt {
                for (slot, lane) in spec.lanes[w].iter().enumerate() {
                    *flow_of.entry(lane.edge_id).or_insert(0.0) +=
                        st.t[w] * self.phi[w][slot];
                }
            }
            for w in 0..w_cnt {
                for (slot, lane) in spec.lanes[w].iter().enumerate() {
                    let f = flow_of[&lane.edge_id];
                    st.dprime[w][slot] = spec.cost.derivative(f, lane.capacity);
                }
            }
        }

        if !st.flows_done {
            return;
        }

        // 3. marginal broadcast: session done when every downstream marginal
        //    arrived
        for w in 0..w_cnt {
            if st.sent_marginal[w] {
                continue;
            }
            let got = st.r_down[w].iter().filter(|r| r.is_some()).count()
                - (spec.lanes[w].len() - spec.expected_marginals(w));
            if got < spec.expected_marginals(w) {
                continue;
            }
            // r_i(w) = Σ φ (D' + r_down)   (eq. 21)
            let r_i: f64 = spec.lanes[w]
                .iter()
                .enumerate()
                .map(|(slot, _)| {
                    self.phi[w][slot] * (st.dprime[w][slot] + st.r_down[w][slot].unwrap())
                })
                .sum();
            st.sent_marginal[w] = true;
            for peer in &spec.in_peers[w] {
                match peer {
                    Peer::Actor(a) => fabric.send(
                        *a,
                        Msg::Marginal { w, from: spec.node_id, value: r_i },
                    ),
                    Peer::Leader => fabric.send_leader(Msg::Marginal {
                        w,
                        from: spec.node_id,
                        value: r_i,
                    }),
                    Peer::Destination => {}
                }
            }
        }

        // 4. when every session's marginals are settled, apply the mirror
        //    update (Algorithm 2 lines 4–5) and report
        if !st.reported && (0..w_cnt).all(|w| st.sent_marginal[w]) {
            st.reported = true;
            for w in 0..w_cnt {
                // paper: only nodes with t_i(w) > 0 and a real choice update
                if st.t[w] > 0.0 && spec.lanes[w].len() >= 2 {
                    let delta: Vec<f64> = spec.lanes[w]
                        .iter()
                        .enumerate()
                        .map(|(slot, _)| st.dprime[w][slot] + st.r_down[w][slot].unwrap())
                        .collect();
                    OmdRouter::update_row(&mut self.phi[w], &delta, st.eta);
                }
            }
            let mut rows: Vec<(usize, usize, f64)> = Vec::new();
            for w in 0..w_cnt {
                for (slot, lane) in spec.lanes[w].iter().enumerate() {
                    rows.push((w, lane.edge_id, self.phi[w][slot]));
                }
            }
            fabric.send_leader(Msg::RowsReport { from: spec.node_id, rows });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_expected_counts() {
        let spec = NodeSpec {
            actor: 0,
            node_id: 1,
            n_sessions: 2,
            cost: CostKind::Exp,
            lanes: vec![
                vec![
                    OutLane { edge_id: 0, dst: Peer::Actor(1), capacity: 10.0 },
                    OutLane { edge_id: 1, dst: Peer::Destination, capacity: 5.0 },
                ],
                vec![OutLane { edge_id: 2, dst: Peer::Actor(2), capacity: 10.0 }],
            ],
            in_peers: vec![vec![Peer::Leader], vec![Peer::Leader, Peer::Actor(3)]],
            phi0: vec![vec![0.5, 0.5], vec![1.0]],
        };
        assert_eq!(spec.expected_ingress(0), 1);
        assert_eq!(spec.expected_ingress(1), 2);
        assert_eq!(spec.expected_marginals(0), 1);
        assert_eq!(spec.expected_marginals(1), 1);
    }
}
