//! One actor per edge device: holds only *local* state (its own routing
//! rows, its out-link capacities), learns `t_i(w)` from upstream ingress
//! messages, computes its link marginals locally, participates in the
//! marginal-cost broadcast, and applies the eq.-(22) mirror update to its
//! own rows — exactly the distributed node-based scheme of Algorithm 2.
//!
//! The actor's arithmetic must agree with [`crate::routing::omd`] **to the
//! last bit**: ingress contributions are bucketed per upstream slot and
//! summed in the session DAG's topological order (the same order the fused
//! [`crate::engine::FlowEngine`] forward sweep accumulates them), so the
//! result is independent of message arrival order. The integration tests
//! cross-check distributed vs centralized trajectories and assert
//! bit-identity across engine worker counts.

use std::sync::mpsc::Receiver;

use super::messages::Msg;
use super::net::Fabric;
use crate::model::cost::CostKind;
use crate::routing::omd::OmdRouter;

/// Where an out-edge leads, from the actor's perspective.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Peer {
    /// A real device actor (actor index).
    Actor(usize),
    /// The virtual destination `D_w` (marginal is 0, no messages needed).
    Destination,
    /// The virtual source / leader (marginals are reported to the leader).
    Leader,
}

/// One out-edge of this node inside one session's DAG.
#[derive(Clone, Debug)]
pub struct OutLane {
    pub edge_id: usize,
    pub dst: Peer,
    pub capacity: f64,
    /// Link cost family of this edge (per-edge heterogeneous costs deploy
    /// as per-lane state — the actor never needs the global cost table).
    pub cost: CostKind,
}

/// One upstream neighbour inside one session's DAG. The leader sorts each
/// node's upstream list in the session's forward topological order (S
/// first), so the deferred ingress summation reproduces the engine's
/// accumulation order bit for bit.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Upstream {
    /// Augmented-graph node id of the sender (`0` = S / the leader).
    pub node: usize,
    pub peer: Peer,
}

/// Static per-epoch description of one node's view of the network.
#[derive(Clone, Debug)]
pub struct NodeSpec {
    /// Actor index (= augmented node id − 1).
    pub actor: usize,
    /// Augmented-graph node id (for message attribution).
    pub node_id: usize,
    pub n_sessions: usize,
    /// `lanes[w]` — session w's usable out-edges.
    pub lanes: Vec<Vec<OutLane>>,
    /// `in_peers[w]` — upstream neighbours in session-topo order (for the
    /// deterministic ingress sum and the marginal broadcast).
    pub in_peers: Vec<Vec<Upstream>>,
    /// Initial routing fractions per session (parallel to `lanes`).
    pub phi0: Vec<Vec<f64>>,
}

impl NodeSpec {
    fn expected_ingress(&self, w: usize) -> usize {
        self.in_peers[w].len()
    }

    fn expected_marginals(&self, w: usize) -> usize {
        self.lanes[w].iter().filter(|l| matches!(l.dst, Peer::Actor(_))).count()
    }
}

/// Per-round mutable state.
struct RoundState {
    eta: f64,
    /// per-(session, upstream-slot) ingress contributions; summed in slot
    /// (= session-topo) order once complete
    t_parts: Vec<Vec<Option<f64>>>,
    /// accumulated ingress per session (valid once `sent_ingress[w]`)
    t: Vec<f64>,
    /// downstream marginals per (session, edge slot); None until received
    r_down: Vec<Vec<Option<f64>>>,
    /// link marginals D' per (session, edge slot); computed once flows known
    dprime: Vec<Vec<f64>>,
    flows_done: bool,
    sent_ingress: Vec<bool>,
    sent_marginal: Vec<bool>,
    reported: bool,
}

impl RoundState {
    fn new(spec: &NodeSpec, eta: f64) -> Self {
        let w = spec.n_sessions;
        RoundState {
            eta,
            t_parts: (0..w).map(|i| vec![None; spec.in_peers[i].len()]).collect(),
            t: vec![0.0; w],
            r_down: (0..w)
                .map(|i| {
                    spec.lanes[i]
                        .iter()
                        .map(|l| match l.dst {
                            Peer::Actor(_) => None,
                            // destination / leader lanes have r = 0 (eq. 20)
                            _ => Some(0.0),
                        })
                        .collect()
                })
                .collect(),
            dprime: (0..w).map(|i| vec![0.0; spec.lanes[i].len()]).collect(),
            flows_done: false,
            sent_ingress: vec![false; w],
            sent_marginal: vec![false; w],
            reported: false,
        }
    }
}

/// The node actor. `run` consumes the inbox until `Shutdown`.
pub struct NodeActor {
    pub spec: NodeSpec,
    /// Current routing fractions (persist across rounds — warm state).
    pub phi: Vec<Vec<f64>>,
}

impl NodeActor {
    pub fn new(spec: NodeSpec) -> Self {
        let phi = spec.phi0.clone();
        NodeActor { spec, phi }
    }

    pub fn run(mut self, inbox: Receiver<Msg>, fabric: Fabric) {
        let mut round: Option<RoundState> = None;
        let mut pending: Vec<Msg> = Vec::new();
        while let Ok(msg) = inbox.recv() {
            match msg {
                Msg::Shutdown => break,
                Msg::BeginRound { eta, .. } => {
                    let mut st = RoundState::new(&self.spec, eta);
                    // replay any messages that raced ahead of BeginRound
                    for m in pending.drain(..) {
                        self.handle(&mut st, m, &fabric);
                    }
                    self.progress(&mut st, &fabric);
                    round = Some(st);
                }
                m => match round {
                    Some(ref mut st) if !st.reported => {
                        self.handle(st, m, &fabric);
                        self.progress(st, &fabric);
                    }
                    // between rounds: buffer until the next BeginRound
                    _ => pending.push(m),
                },
            }
            if let Some(ref st) = round {
                if st.reported {
                    round = None;
                }
            }
        }
    }

    fn handle(&mut self, st: &mut RoundState, msg: Msg, _fabric: &Fabric) {
        match msg {
            Msg::Ingress { w, from, rate } => {
                // bucket by upstream slot; the sum happens in slot order
                // once every contribution arrived (arrival-order agnostic).
                // Parallel edges from the same upstream fill its slots in
                // arrival order (one message per in-edge per round).
                let slot = self.spec.in_peers[w]
                    .iter()
                    .enumerate()
                    .position(|(s, u)| u.node == from && st.t_parts[w][s].is_none())
                    .expect("ingress from an unknown upstream");
                st.t_parts[w][slot] = Some(rate);
            }
            Msg::Marginal { w, from, value } => {
                // locate the lane pointing at `from`
                for (slot, lane) in self.spec.lanes[w].iter().enumerate() {
                    if let Peer::Actor(a) = lane.dst {
                        if a + 1 == from {
                            st.r_down[w][slot] = Some(value);
                        }
                    }
                }
            }
            _ => {}
        }
    }

    /// Drive the per-round state machine as far as possible.
    fn progress(&mut self, st: &mut RoundState, fabric: &Fabric) {
        let spec = &self.spec;
        let w_cnt = spec.n_sessions;

        // 1. once a session's ingress is complete, sum it in slot
        //    (session-topo) order — the engine's accumulation order — and
        //    forward downstream
        for w in 0..w_cnt {
            if !st.sent_ingress[w] && st.t_parts[w].iter().all(Option::is_some) {
                let mut t = 0.0;
                for part in &st.t_parts[w] {
                    t += part.unwrap();
                }
                st.t[w] = t;
                st.sent_ingress[w] = true;
                for (slot, lane) in spec.lanes[w].iter().enumerate() {
                    if let Peer::Actor(a) = lane.dst {
                        fabric.send(
                            a,
                            Msg::Ingress {
                                w,
                                from: spec.node_id,
                                rate: st.t[w] * self.phi[w][slot],
                            },
                        );
                    }
                }
            }
        }

        // 2. once *all* sessions' ingress arrived, link flows (and hence the
        //    local marginals D'_ij) are known
        if !st.flows_done && (0..w_cnt).all(|w| st.sent_ingress[w]) {
            st.flows_done = true;
            // F_e sums every session's contribution on the shared physical
            // edge, in ascending session order (the engine's fixed-order
            // cross-session reduction); sessions may share an edge id
            let mut flow_of: std::collections::BTreeMap<usize, f64> =
                std::collections::BTreeMap::new();
            for w in 0..w_cnt {
                for (slot, lane) in spec.lanes[w].iter().enumerate() {
                    *flow_of.entry(lane.edge_id).or_insert(0.0) +=
                        st.t[w] * self.phi[w][slot];
                }
            }
            for w in 0..w_cnt {
                for (slot, lane) in spec.lanes[w].iter().enumerate() {
                    let f = flow_of[&lane.edge_id];
                    st.dprime[w][slot] = lane.cost.derivative(f, lane.capacity);
                }
            }
        }

        if !st.flows_done {
            return;
        }

        // 3. marginal broadcast: session done when every downstream marginal
        //    arrived
        for w in 0..w_cnt {
            if st.sent_marginal[w] {
                continue;
            }
            let got = st.r_down[w].iter().filter(|r| r.is_some()).count()
                - (spec.lanes[w].len() - spec.expected_marginals(w));
            if got < spec.expected_marginals(w) {
                continue;
            }
            // r_i(w) = Σ φ (D' + r_down)   (eq. 21), skipping zero lanes
            // exactly like the engine's reverse sweep
            let mut r_i = 0.0;
            for (slot, _) in spec.lanes[w].iter().enumerate() {
                let f = self.phi[w][slot];
                if f > 0.0 {
                    r_i += f * (st.dprime[w][slot] + st.r_down[w][slot].unwrap());
                }
            }
            st.sent_marginal[w] = true;
            for up in &spec.in_peers[w] {
                match up.peer {
                    Peer::Actor(a) => fabric.send(
                        a,
                        Msg::Marginal { w, from: spec.node_id, value: r_i },
                    ),
                    Peer::Leader => fabric.send_leader(Msg::Marginal {
                        w,
                        from: spec.node_id,
                        value: r_i,
                    }),
                    Peer::Destination => {}
                }
            }
        }

        // 4. when every session's marginals are settled, apply the mirror
        //    update (Algorithm 2 lines 4–5) and report
        if !st.reported && (0..w_cnt).all(|w| st.sent_marginal[w]) {
            st.reported = true;
            for w in 0..w_cnt {
                // paper: only nodes with t_i(w) > 0 and a real choice update
                if st.t[w] > 0.0 && spec.lanes[w].len() >= 2 {
                    let delta: Vec<f64> = spec.lanes[w]
                        .iter()
                        .enumerate()
                        .map(|(slot, _)| st.dprime[w][slot] + st.r_down[w][slot].unwrap())
                        .collect();
                    OmdRouter::update_row(&mut self.phi[w], &delta, st.eta);
                }
            }
            let mut rows: Vec<(usize, usize, f64)> = Vec::new();
            for w in 0..w_cnt {
                for (slot, lane) in spec.lanes[w].iter().enumerate() {
                    rows.push((w, lane.edge_id, self.phi[w][slot]));
                }
            }
            fabric.send_leader(Msg::RowsReport { from: spec.node_id, rows });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_expected_counts() {
        let spec = NodeSpec {
            actor: 0,
            node_id: 1,
            n_sessions: 2,
            lanes: vec![
                vec![
                    OutLane {
                        edge_id: 0,
                        dst: Peer::Actor(1),
                        capacity: 10.0,
                        cost: CostKind::Exp,
                    },
                    OutLane {
                        edge_id: 1,
                        dst: Peer::Destination,
                        capacity: 5.0,
                        cost: CostKind::Exp,
                    },
                ],
                vec![OutLane {
                    edge_id: 2,
                    dst: Peer::Actor(2),
                    capacity: 10.0,
                    cost: CostKind::Exp,
                }],
            ],
            in_peers: vec![
                vec![Upstream { node: 0, peer: Peer::Leader }],
                vec![
                    Upstream { node: 0, peer: Peer::Leader },
                    Upstream { node: 4, peer: Peer::Actor(3) },
                ],
            ],
            phi0: vec![vec![0.5, 0.5], vec![1.0]],
        };
        assert_eq!(spec.expected_ingress(0), 1);
        assert_eq!(spec.expected_ingress(1), 2);
        assert_eq!(spec.expected_marginals(0), 1);
        assert_eq!(spec.expected_marginals(1), 1);
    }

    #[test]
    fn ingress_sum_is_arrival_order_agnostic() {
        // the same contributions delivered in opposite orders must produce
        // bit-identical sums (slot-order summation, not arrival-order)
        let spec = NodeSpec {
            actor: 0,
            node_id: 1,
            n_sessions: 1,
            lanes: vec![vec![OutLane {
                edge_id: 0,
                dst: Peer::Destination,
                capacity: 5.0,
                cost: CostKind::Exp,
            }]],
            in_peers: vec![vec![
                Upstream { node: 0, peer: Peer::Leader },
                Upstream { node: 2, peer: Peer::Actor(1) },
                Upstream { node: 3, peer: Peer::Actor(2) },
            ]],
            phi0: vec![vec![1.0]],
        };
        // three values whose sum depends on association order
        let rates = [(0usize, 0.1f64), (2, 1.0e16), (3, -1.0e16)];
        let sum_for = |order: &[usize]| {
            let mut actor = NodeActor::new(spec.clone());
            let mut st = RoundState::new(&actor.spec, 0.5);
            let (fabric, _rxs, _lrx) = Fabric::new(3);
            for &k in order {
                let (from, rate) = rates[k];
                actor.handle(&mut st, Msg::Ingress { w: 0, from, rate }, &fabric);
            }
            actor.progress(&mut st, &fabric);
            assert!(st.sent_ingress[0]);
            st.t[0]
        };
        let a = sum_for(&[0, 1, 2]);
        let b = sum_for(&[2, 1, 0]);
        let c = sum_for(&[1, 2, 0]);
        assert_eq!(a.to_bits(), b.to_bits());
        assert_eq!(a.to_bits(), c.to_bits());
    }
}
