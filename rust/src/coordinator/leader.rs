//! The leader (the controller at virtual source `S`): spawns one actor per
//! edge device, drives barriered OMD-RT rounds over the message fabric, and
//! owns S's routing rows. Metrics (cost trajectories, message counts) are
//! collected leader-side; the *algorithm* only uses local node state plus
//! the broadcast protocol, exactly as the paper prescribes.

use std::collections::HashMap;
use std::sync::mpsc::Receiver;

use super::messages::Msg;
use super::net::Fabric;
use super::node::{NodeActor, NodeSpec, OutLane, Peer};
use crate::engine::FlowEngine;
use crate::graph::augmented::AugmentedNet;
use crate::model::flow::Phi;
use crate::model::Problem;
use crate::routing::omd::OmdRouter;
use crate::routing::RoutingState;

/// Communication accounting for one distributed run.
#[derive(Clone, Copy, Debug, Default)]
pub struct CommStats {
    pub messages: u64,
    pub bytes: u64,
    pub rounds: usize,
}

/// Distributed OMD-RT: thread-per-device actors + leader orchestration.
pub struct DistributedOmd {
    pub eta: f64,
}

impl DistributedOmd {
    pub fn new(eta: f64) -> Self {
        DistributedOmd { eta }
    }

    /// Build every actor's local view from the global topology (this is the
    /// deployment step — at runtime each node only ever touches its spec).
    pub fn build_specs(net: &AugmentedNet, phi: &Phi) -> Vec<NodeSpec> {
        let classify = |node: usize| -> Peer {
            if node == AugmentedNet::SOURCE {
                Peer::Leader
            } else if node > net.n_real {
                Peer::Destination
            } else {
                Peer::Actor(node - 1)
            }
        };
        (1..=net.n_real)
            .map(|node| {
                let w_cnt = net.n_versions();
                let mut lanes = Vec::with_capacity(w_cnt);
                let mut in_peers = Vec::with_capacity(w_cnt);
                let mut phi0 = Vec::with_capacity(w_cnt);
                for w in 0..w_cnt {
                    let mut ls = Vec::new();
                    let mut p0 = Vec::new();
                    for e in net.session_out(w, node) {
                        let edge = net.graph.edge(e);
                        ls.push(OutLane {
                            edge_id: e,
                            dst: classify(edge.dst),
                            capacity: edge.capacity,
                        });
                        p0.push(phi.frac[w][e]);
                    }
                    let ins = net
                        .graph
                        .in_edges(node)
                        .iter()
                        .filter(|&&e| net.session_edges[w][e])
                        .map(|&e| classify(net.graph.edge(e).src))
                        .collect();
                    lanes.push(ls);
                    in_peers.push(ins);
                    phi0.push(p0);
                }
                NodeSpec {
                    actor: node - 1,
                    node_id: node,
                    n_sessions: net.n_versions(),
                    cost: crate::model::cost::CostKind::Exp, // overwritten below
                    lanes,
                    in_peers,
                    phi0,
                }
            })
            .collect()
    }

    /// Run `rounds` barriered routing iterations; returns the final routing
    /// state (trajectory measured leader-side) plus communication stats.
    pub fn solve(
        &self,
        problem: &Problem,
        lam: &[f64],
        rounds: usize,
    ) -> (RoutingState, CommStats) {
        let t0 = std::time::Instant::now();
        let net = &problem.net;
        let w_cnt = net.n_versions();
        let mut phi = Phi::uniform(net);

        let mut specs = Self::build_specs(net, &phi);
        for s in &mut specs {
            s.cost = problem.cost;
        }
        let (fabric, receivers, leader_rx) = Fabric::new(net.n_real);
        let mut handles = Vec::new();
        for (spec, rx) in specs.into_iter().zip(receivers) {
            let f = fabric.clone();
            handles.push(std::thread::spawn(move || NodeActor::new(spec).run(rx, f)));
        }

        // leader-owned source rows: (session -> [(edge, dst_node)])
        let s_lanes: Vec<Vec<(usize, usize)>> = (0..w_cnt)
            .map(|w| {
                net.session_out(w, AugmentedNet::SOURCE)
                    .map(|e| (e, net.graph.edge(e).dst))
                    .collect()
            })
            .collect();

        let mut trajectory = Vec::with_capacity(rounds + 1);
        let mut eta_cur = self.eta;
        let mut last_cost = None;
        // leader-side cost telemetry via the fused engine sweep (the
        // distributed algorithm itself stays message-passing only)
        let mut engine = FlowEngine::new();
        for round in 0..rounds {
            let cost = engine.evaluate_cost(problem, &phi, lam);
            trajectory.push(cost);
            // same backtracking rule as the centralized router: the leader
            // aggregates the total cost along the broadcast tree
            eta_cur = OmdRouter::adapt_eta(eta_cur, self.eta, last_cost, cost);
            last_cost = Some(cost);
            self.run_round(
                problem, lam, &mut phi, &s_lanes, &fabric, &leader_rx, round as u64, eta_cur,
            );
        }
        let final_cost = engine.evaluate_cost(problem, &phi, lam);
        trajectory.push(final_cost);

        fabric.broadcast(Msg::Shutdown);
        for h in handles {
            let _ = h.join();
        }
        let (messages, bytes) = fabric.counters.snapshot();
        (
            RoutingState {
                phi,
                cost: final_cost,
                trajectory,
                iterations: rounds,
                elapsed_s: t0.elapsed().as_secs_f64(),
            },
            CommStats { messages, bytes, rounds },
        )
    }

    /// One barriered round: kick off, admit λ, collect reports, update S.
    fn run_round(
        &self,
        problem: &Problem,
        lam: &[f64],
        phi: &mut Phi,
        s_lanes: &[Vec<(usize, usize)>],
        fabric: &Fabric,
        leader_rx: &Receiver<Msg>,
        round: u64,
        eta: f64,
    ) {
        let net = &problem.net;
        let w_cnt = net.n_versions();
        fabric.broadcast(Msg::BeginRound { round, eta });
        // admit: S forwards λ_w over its rows
        for (w, lanes) in s_lanes.iter().enumerate() {
            for &(e, dst) in lanes {
                fabric.send(dst - 1, Msg::Ingress { w, rate: lam[w] * phi.frac[w][e] });
            }
        }
        // collect all node reports (+ S's downstream marginals)
        let mut reports: HashMap<usize, Vec<(usize, usize, f64)>> = HashMap::new();
        let mut r_of: Vec<HashMap<usize, f64>> = vec![HashMap::new(); w_cnt];
        while reports.len() < net.n_real {
            match leader_rx.recv().expect("leader inbox closed mid-round") {
                Msg::Marginal { w, from, value } => {
                    r_of[w].insert(from, value);
                }
                Msg::RowsReport { from, rows } => {
                    reports.insert(from, rows);
                }
                other => panic!("unexpected message at leader: {other:?}"),
            }
        }
        // S's own mirror update (it is a router like any other)
        for (w, lanes) in s_lanes.iter().enumerate() {
            if lam[w] <= 0.0 || lanes.len() < 2 {
                continue;
            }
            // F on S-links is S-local; downstream r comes from the broadcast
            let mut row: Vec<f64> = lanes.iter().map(|&(e, _)| phi.frac[w][e]).collect();
            // (eta from the adaptive schedule, same value broadcast to nodes)
            let delta: Vec<f64> = lanes
                .iter()
                .map(|&(e, dst)| {
                    let edge = net.graph.edge(e);
                    let f: f64 = (0..w_cnt).map(|v| lam[v] * phi.frac[v][e]).sum();
                    problem.cost.derivative(f, edge.capacity)
                        + r_of[w].get(&dst).copied().unwrap_or(0.0)
                })
                .collect();
            OmdRouter::update_row(&mut row, &delta, eta);
            for (&(e, _), &v) in lanes.iter().zip(&row) {
                phi.frac[w][e] = v;
            }
        }
        // merge node reports into the global snapshot (metrics/state only)
        for (_from, rows) in reports {
            for (w, e, v) in rows {
                phi.frac[w][e] = v;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topologies;
    use crate::model::cost::CostKind;
    use crate::routing::Router;
    use crate::util::rng::Rng;

    fn problem(seed: u64, n: usize) -> Problem {
        let mut rng = Rng::seed_from(seed);
        let net = topologies::connected_er(n, 0.35, 3, &mut rng);
        Problem::new(net, 60.0, CostKind::Exp)
    }

    #[test]
    fn distributed_matches_centralized() {
        // the distributed actors must reproduce the centralized OMD-RT
        // trajectory (same math, message-passing evaluation)
        let p = problem(1, 8);
        let lam = p.uniform_allocation();
        let dist = DistributedOmd::new(0.3);
        let (dsol, comm) = dist.solve(&p, &lam, 12);
        let csol = OmdRouter::new(0.3).solve(&p, &lam, 12);
        assert!(comm.messages > 0);
        for (i, (a, b)) in dsol.trajectory.iter().zip(&csol.trajectory).enumerate() {
            assert!(
                (a - b).abs() < 1e-6 * b.abs().max(1.0),
                "iter {i}: distributed {a} vs centralized {b}"
            );
        }
    }

    #[test]
    fn message_count_scales_with_rounds() {
        let p = problem(2, 6);
        let lam = p.uniform_allocation();
        let dist = DistributedOmd::new(0.3);
        let (_s1, c1) = dist.solve(&p, &lam, 5);
        let (_s2, c2) = dist.solve(&p, &lam, 10);
        assert!(c2.messages > c1.messages);
        assert!(c2.bytes > c1.bytes);
    }

    #[test]
    fn distributed_descends() {
        // monotone descent needs the small-step regime (Theorem 4); with a
        // larger η the invariant is trajectory-equality with the
        // centralized solver, covered above
        let p = problem(3, 10);
        let lam = p.uniform_allocation();
        let (sol, _) = DistributedOmd::new(0.05).solve(&p, &lam, 20);
        for w in sol.trajectory.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "cost increased {} -> {}", w[0], w[1]);
        }
        sol.phi.is_feasible(&p.net, 1e-9).unwrap();
        // and the same η must match the centralized trajectory exactly
        let c = OmdRouter::new(0.05).solve(&p, &lam, 20);
        for (a, b) in sol.trajectory.iter().zip(&c.trajectory) {
            assert!((a - b).abs() < 1e-6 * b.abs().max(1.0), "{a} vs {b}");
        }
    }
}
