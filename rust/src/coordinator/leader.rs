//! The leader (the controller at virtual source `S`): spawns one actor per
//! edge device, drives barriered OMD-RT rounds over the message fabric, and
//! owns S's routing rows.
//!
//! [`DistributedOmd`] implements [`Router`], so the distributed algorithm
//! is a first-class registry solver (`"distributed-omd"`) and streams
//! through the same `session::RunCore` protocol as every centralized
//! router: `session.distributed_run(rounds)` (or
//! `session.routing_run("distributed-omd", rounds)`) yields a
//! [`crate::session::DistributedRun`] with stop rules, observers, and a
//! unified [`crate::session::RunReport`] whose `comm` field carries the
//! [`CommStats`] telemetry. One [`Router::step`] is one barriered round;
//! actors are deployed lazily on the first step (warm-starting from
//! whatever φ the run carries) and shut down on drop or redeploy.
//!
//! The *algorithm* only uses local node state plus the broadcast protocol,
//! exactly as the paper prescribes; the leader-side engine evaluation is
//! cost telemetry (the same aggregate the broadcast tree delivers) used
//! for the adaptive step-size rule shared with the centralized router.
//! With the deterministic per-slot ingress summation in
//! [`super::node`], a distributed round is bit-identical to the
//! centralized [`OmdRouter`] iteration — at any engine worker count.

use std::collections::BTreeMap;
use std::sync::mpsc::Receiver;
use std::thread::JoinHandle;

use super::messages::Msg;
use super::net::Fabric;
use super::node::{NodeActor, NodeSpec, OutLane, Peer, Upstream};
use crate::engine::FlowEngine;
use crate::graph::augmented::AugmentedNet;
use crate::model::flow::Phi;
use crate::model::Problem;
use crate::routing::omd::OmdRouter;
use crate::routing::Router;

pub use super::net::CommStats;

/// A live actor deployment: fabric, threads, and S's own lane table.
struct Deployment {
    fabric: Fabric,
    leader_rx: Receiver<Msg>,
    handles: Vec<JoinHandle<()>>,
    /// Leader-owned source rows: per session, `(edge, dst_node)` pairs.
    s_lanes: Vec<Vec<(usize, usize)>>,
    /// Digest of the problem the actors were built for (topology wiring,
    /// capacities, cost family).
    digest: u64,
    /// The routing state the actors currently hold (kept in sync after
    /// every round); a caller handing in a different φ forces a redeploy.
    phi: Phi,
}

/// Distributed OMD-RT: thread-per-device actors + leader orchestration,
/// behind the standard [`Router`] step protocol.
pub struct DistributedOmd {
    /// Base mirror-descent step size η (paper: constant `η_k ≤ c/L_D`).
    pub eta: f64,
    /// Backtracking adaptation (default on) — the same rule as
    /// [`OmdRouter`], driven by the leader-aggregated total cost.
    pub adaptive: bool,
    eta_cur: f64,
    last_cost: Option<f64>,
    /// Leader-side cost telemetry via the fused engine sweep (the
    /// distributed algorithm itself stays message-passing only).
    engine: FlowEngine,
    deployment: Option<Deployment>,
    rounds: usize,
    /// Counters carried over from shut-down deployments.
    comm_base: (u64, u64),
}

impl DistributedOmd {
    pub fn new(eta: f64) -> Self {
        DistributedOmd {
            eta,
            adaptive: true,
            eta_cur: eta,
            last_cost: None,
            engine: FlowEngine::new(),
            deployment: None,
            rounds: 0,
            comm_base: (0, 0),
        }
    }

    /// Fixed-step variant (theory experiments; requires η ≤ c/L_D).
    /// (No struct-update shorthand here: `DistributedOmd` implements
    /// `Drop`, which rules out functional record updates.)
    pub fn fixed(eta: f64) -> Self {
        let mut router = Self::new(eta);
        router.adaptive = false;
        router
    }

    /// Worker threads for the leader-side engine telemetry (`0` = auto).
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.engine.set_workers(workers);
        self
    }

    /// Build every actor's local view from the global topology (this is the
    /// deployment step — at runtime each node only ever touches its spec).
    /// Upstream lists are sorted in each session's forward topological
    /// order so the actors' deferred ingress sums reproduce the engine's
    /// accumulation order bit for bit. Each out-lane carries its own link
    /// cost family (heterogeneous per-edge costs deploy transparently).
    pub fn build_specs(problem: &Problem, phi: &Phi) -> Vec<NodeSpec> {
        let net = &problem.net;
        let classify = |node: usize| -> Peer {
            if node == AugmentedNet::SOURCE {
                Peer::Leader
            } else if node > net.n_real {
                Peer::Destination
            } else {
                Peer::Actor(node - 1)
            }
        };
        // per-session topo rank of every DAG node (S is topo-first)
        let rank: Vec<BTreeMap<usize, usize>> = (0..net.n_sessions())
            .map(|w| {
                net.session_topo(w).iter().enumerate().map(|(k, &i)| (i, k)).collect()
            })
            .collect();
        (1..=net.n_real)
            .map(|node| {
                let w_cnt = net.n_sessions();
                let mut lanes = Vec::with_capacity(w_cnt);
                let mut in_peers = Vec::with_capacity(w_cnt);
                let mut phi0 = Vec::with_capacity(w_cnt);
                for w in 0..w_cnt {
                    let mut ls = Vec::new();
                    let mut p0 = Vec::new();
                    for e in net.session_out(w, node) {
                        let edge = net.graph.edge(e);
                        ls.push(OutLane {
                            edge_id: e,
                            dst: classify(edge.dst),
                            capacity: edge.capacity,
                            cost: problem.edge_kind(e),
                        });
                        p0.push(phi.frac[w][e]);
                    }
                    let mut ins: Vec<Upstream> = net
                        .graph
                        .in_edges(node)
                        .iter()
                        .filter(|&&e| net.session_edges[w][e])
                        .map(|&e| {
                            let src = net.graph.edge(e).src;
                            Upstream { node: src, peer: classify(src) }
                        })
                        .collect();
                    ins.sort_unstable_by_key(|u| rank[w][&u.node]);
                    lanes.push(ls);
                    in_peers.push(ins);
                    phi0.push(p0);
                }
                NodeSpec {
                    actor: node - 1,
                    node_id: node,
                    n_sessions: net.n_sessions(),
                    lanes,
                    in_peers,
                    phi0,
                }
            })
            .collect()
    }

    /// FNV-1a digest of everything the actor specs are built from:
    /// node/edge/session counts, the per-session lane wiring, link
    /// capacities, and the cost family. Two problems with the same digest
    /// deploy identical specs, so a matching digest (plus a matching φ)
    /// is what makes fleet reuse across steps sound. Shared with the
    /// sharded plane ([`super::shard::ShardedOmd`]), which uses the same
    /// redeploy contract.
    pub(crate) fn fleet_digest(problem: &Problem) -> u64 {
        let mut h = crate::util::hash::Fnv64::new();
        let net = &problem.net;
        h.mix(net.n_nodes() as u64);
        h.mix(net.graph.n_edges() as u64);
        h.mix(net.n_sessions() as u64);
        for (&e, &d) in net.csr.lane_edge.iter().zip(&net.csr.lane_dst) {
            h.mix(e as u64);
            h.mix(d as u64);
        }
        // bind lanes to their owning (session, node) rows: the flat lane
        // sequence alone cannot distinguish two problems that partition
        // the same lanes differently across nodes or sessions
        for row in &net.csr.rows {
            h.mix(row.node as u64);
            h.mix(row.start as u64);
            h.mix(row.end as u64);
        }
        for &(a, b) in &net.csr.session_rows {
            h.mix(a as u64);
            h.mix(b as u64);
        }
        for (e, edge) in net.graph.edges().iter().enumerate() {
            h.mix(edge.src as u64);
            h.mix(edge.dst as u64);
            h.mix(edge.capacity.to_bits());
            h.mix(problem.edge_kind(e) as u64);
        }
        h.mix(problem.cost as u64);
        h.finish()
    }

    /// Spawn the actor threads for `problem`, warm-starting every node's
    /// rows from `phi`.
    fn deploy(problem: &Problem, phi: &Phi) -> Deployment {
        let net = &problem.net;
        let specs = Self::build_specs(problem, phi);
        let (fabric, receivers, leader_rx) = Fabric::new(net.n_real);
        let mut handles = Vec::with_capacity(specs.len());
        for (spec, rx) in specs.into_iter().zip(receivers) {
            let f = fabric.clone();
            let name = format!("jowr-node-{}", spec.node_id);
            handles.push(
                std::thread::Builder::new()
                    .name(name)
                    .spawn(move || NodeActor::new(spec).run(rx, f))
                    .expect("spawn node actor"),
            );
        }
        let s_lanes: Vec<Vec<(usize, usize)>> = (0..net.n_sessions())
            .map(|w| {
                net.session_out(w, AugmentedNet::SOURCE)
                    .map(|e| (e, net.graph.edge(e).dst))
                    .collect()
            })
            .collect();
        Deployment {
            fabric,
            leader_rx,
            handles,
            s_lanes,
            digest: Self::fleet_digest(problem),
            phi: phi.clone(),
        }
    }

    /// Deploy the actor fleet, or redeploy when the running fleet no
    /// longer matches what the caller hands in: a changed problem
    /// (topology, capacities, cost family) *or* a φ that differs from the
    /// actors' current rows (e.g. a fresh run resetting to the uniform
    /// initializer while the old fleet had converged state). Exact-equality
    /// on φ keeps steady-state reuse free while making reuse always sound.
    fn ensure_deployed(&mut self, problem: &Problem, phi: &Phi) {
        let digest = Self::fleet_digest(problem);
        let in_sync = self
            .deployment
            .as_ref()
            .is_some_and(|d| d.digest == digest && d.phi == *phi);
        if !in_sync {
            self.shutdown();
            // a redeploy is a fresh run: the backtracking schedule restarts
            // too, exactly like a newly constructed router (otherwise a
            // stale last_cost from the previous run would halve η on the
            // first round of the new one)
            self.eta_cur = self.eta;
            self.last_cost = None;
            self.deployment = Some(Self::deploy(problem, phi));
        }
    }

    /// Orderly shutdown: stop the actors, fold their traffic counters into
    /// the carried-over base.
    fn shutdown(&mut self) {
        if let Some(dep) = self.deployment.take() {
            dep.fabric.broadcast(Msg::Shutdown);
            for h in dep.handles {
                let _ = h.join();
            }
            let (messages, bytes) = dep.fabric.counters.snapshot();
            self.comm_base.0 += messages;
            self.comm_base.1 += bytes;
        }
    }

    /// One barriered round: kick off, admit λ, collect reports, update S.
    fn run_round(
        dep: &Deployment,
        problem: &Problem,
        lam: &[f64],
        phi: &mut Phi,
        round: u64,
        eta: f64,
    ) {
        let net = &problem.net;
        let w_cnt = net.n_sessions();
        dep.fabric.broadcast(Msg::BeginRound { round, eta });
        // admit: S forwards λ_w over its rows
        for (w, lanes) in dep.s_lanes.iter().enumerate() {
            for &(e, dst) in lanes {
                dep.fabric.send(
                    dst - 1,
                    Msg::Ingress {
                        w,
                        from: AugmentedNet::SOURCE,
                        rate: lam[w] * phi.frac[w][e],
                    },
                );
            }
        }
        // collect all node reports (+ S's downstream marginals)
        let mut reports: BTreeMap<usize, Vec<(usize, usize, f64)>> = BTreeMap::new();
        let mut r_of: Vec<BTreeMap<usize, f64>> = vec![BTreeMap::new(); w_cnt];
        while reports.len() < net.n_real {
            match dep.leader_rx.recv().expect("leader inbox closed mid-round") {
                Msg::Marginal { w, from, value } => {
                    r_of[w].insert(from, value);
                }
                Msg::RowsReport { from, rows } => {
                    reports.insert(from, rows);
                }
                other => panic!("unexpected message at leader: {other:?}"),
            }
        }
        // S's own mirror update (it is a router like any other)
        for (w, lanes) in dep.s_lanes.iter().enumerate() {
            if lam[w] <= 0.0 || lanes.len() < 2 {
                continue;
            }
            // F on S-links is S-local; downstream r comes from the broadcast
            let mut row: Vec<f64> = lanes.iter().map(|&(e, _)| phi.frac[w][e]).collect();
            // (eta from the adaptive schedule, same value broadcast to nodes)
            let delta: Vec<f64> = lanes
                .iter()
                .map(|&(e, dst)| {
                    let edge = net.graph.edge(e);
                    let f: f64 = (0..w_cnt).map(|v| lam[v] * phi.frac[v][e]).sum();
                    problem.edge_kind(e).derivative(f, edge.capacity)
                        + r_of[w].get(&dst).copied().unwrap_or(0.0)
                })
                .collect();
            OmdRouter::update_row(&mut row, &delta, eta);
            for (&(e, _), &v) in lanes.iter().zip(&row) {
                phi.frac[w][e] = v;
            }
        }
        // merge node reports into the global snapshot in ascending node
        // order (BTreeMap iteration; the writes are disjoint — each node
        // reports its own out-edges — so the order is cosmetic, but audit
        // rule r1 wants it deterministic by construction, not by argument)
        for (_from, rows) in reports {
            for (w, e, v) in rows {
                phi.frac[w][e] = v;
            }
        }
    }
}

impl Router for DistributedOmd {
    fn name(&self) -> &'static str {
        "distributed-omd"
    }

    /// One barriered distributed round. Actors are deployed on the first
    /// call (warm-starting from `phi`) and persist across steps; the
    /// returned value is the total cost *before* the round's update, as
    /// with every router.
    fn step(&mut self, problem: &Problem, lam: &[f64], phi: &mut Phi) -> f64 {
        self.ensure_deployed(problem, phi);
        let cost_before = self.engine.evaluate_cost(problem, phi, lam);
        if self.adaptive {
            self.eta_cur =
                OmdRouter::adapt_eta(self.eta_cur, self.eta, self.last_cost, cost_before);
        }
        self.last_cost = Some(cost_before);
        let dep = self.deployment.as_mut().expect("deployed above");
        Self::run_round(dep, problem, lam, phi, self.rounds as u64, self.eta_cur);
        // remember the state the actors now hold, so the next step can
        // detect an externally reset/replaced φ and redeploy
        dep.phi.clone_from(phi);
        self.rounds += 1;
        cost_before
    }

    fn set_workers(&mut self, workers: usize) {
        self.engine.set_workers(workers);
    }

    fn set_batch_mode(&mut self, mode: crate::engine::BatchMode) {
        self.engine.set_batch_mode(mode);
    }

    fn comm_stats(&self) -> Option<CommStats> {
        let (m, b) = self
            .deployment
            .as_ref()
            .map(|d| d.fabric.counters.snapshot())
            .unwrap_or((0, 0));
        Some(CommStats {
            messages: self.comm_base.0 + m,
            bytes: self.comm_base.1 + b,
            rounds: self.rounds,
            // single-leader fabric: no per-shard breakdown
            shards: Vec::new(),
        })
    }
}

impl Drop for DistributedOmd {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topologies;
    use crate::model::cost::CostKind;
    use crate::session::{RoutingRun, Trajectory};
    use crate::util::rng::Rng;

    fn problem(seed: u64, n: usize) -> Problem {
        let mut rng = Rng::seed_from(seed);
        let net = topologies::connected_er(n, 0.35, 3, &mut rng);
        Problem::new(net, 60.0, CostKind::Exp)
    }

    fn run_distributed(
        p: &Problem,
        eta: f64,
        rounds: usize,
    ) -> (Trajectory, crate::session::RunReport) {
        let mut traj = Trajectory::default();
        let report = RoutingRun::new(
            p,
            Box::new(DistributedOmd::new(eta)),
            p.uniform_allocation(),
            rounds,
        )
        .observe(&mut traj)
        .finish();
        (traj, report)
    }

    #[test]
    fn distributed_matches_centralized() {
        // the distributed actors must reproduce the centralized OMD-RT
        // trajectory (same math, message-passing evaluation; with the
        // slot-ordered ingress sums the match is to rounding noise)
        let p = problem(1, 8);
        let (dtraj, dreport) = run_distributed(&p, 0.3, 12);
        let mut ctraj = Trajectory::default();
        let creport = RoutingRun::new(
            &p,
            Box::new(OmdRouter::new(0.3)),
            p.uniform_allocation(),
            12,
        )
        .observe(&mut ctraj)
        .finish();
        let comm = dreport.comm.expect("distributed runs report comm stats");
        assert!(comm.messages > 0);
        assert_eq!(comm.rounds, dreport.iterations);
        for (i, (a, b)) in dtraj.values.iter().zip(&ctraj.values).enumerate() {
            assert!(
                (a - b).abs() <= 1e-9 * b.abs().max(1.0),
                "iter {i}: distributed {a} vs centralized {b}"
            );
        }
        assert!(
            (dreport.objective - creport.objective).abs()
                <= 1e-9 * creport.objective.abs().max(1.0),
            "final cost: {} vs {}",
            dreport.objective,
            creport.objective
        );
    }

    #[test]
    fn message_count_scales_with_rounds() {
        let p = problem(2, 6);
        let (_t1, r1) = run_distributed(&p, 0.3, 5);
        let (_t2, r2) = run_distributed(&p, 0.3, 10);
        let (c1, c2) = (r1.comm.unwrap(), r2.comm.unwrap());
        assert!(c2.messages > c1.messages);
        assert!(c2.bytes > c1.bytes);
        assert_eq!(c1.rounds, 5);
        assert_eq!(c2.rounds, 10);
    }

    #[test]
    fn distributed_descends() {
        // monotone descent needs the small-step regime (Theorem 4); with a
        // larger η the invariant is trajectory-equality with the
        // centralized solver, covered above
        let p = problem(3, 10);
        let (traj, report) = run_distributed(&p, 0.05, 20);
        for w in traj.values.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "cost increased {} -> {}", w[0], w[1]);
        }
        report.phi.expect("routing runs expose phi").is_feasible(&p.net, 1e-9).unwrap();
    }

    #[test]
    fn reused_router_redeploys_when_phi_is_reset() {
        // driving the same instance through two fresh runs must behave
        // like two fresh routers: the second run hands in the uniform
        // initializer again, so the converged fleet is torn down and
        // redeployed — and the adaptive η schedule restarts — instead of
        // silently desyncing from the leader's φ (adaptive default on, so
        // a stale last_cost would show up as a diverging trajectory here)
        let p = problem(6, 8);
        let lam = p.uniform_allocation();
        let mut reused = DistributedOmd::new(0.2);
        let mut traj_a = Vec::new();
        let mut traj_b = Vec::new();
        for traj in [&mut traj_a, &mut traj_b] {
            let mut phi = Phi::uniform(&p.net);
            for _ in 0..6 {
                traj.push(reused.step(&p, &lam, &mut phi));
            }
        }
        for (a, b) in traj_a.iter().zip(&traj_b) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
        }
        // comm accounting survives the redeploy (counters carry over)
        let comm = reused.comm_stats().unwrap();
        assert_eq!(comm.rounds, 12);
        assert!(comm.messages > 0);
    }

    #[test]
    fn redeploys_after_topology_change_and_keeps_counters() {
        let p1 = problem(4, 6);
        let p2 = problem(5, 9);
        let mut router = DistributedOmd::new(0.3);
        let lam1 = p1.uniform_allocation();
        let mut phi1 = Phi::uniform(&p1.net);
        router.step(&p1, &lam1, &mut phi1);
        let after_first = router.comm_stats().unwrap();
        assert!(after_first.messages > 0);
        // new topology: the old fleet is shut down, counters carry over
        let lam2 = p2.uniform_allocation();
        let mut phi2 = Phi::uniform(&p2.net);
        router.step(&p2, &lam2, &mut phi2);
        let after_second = router.comm_stats().unwrap();
        assert!(after_second.messages > after_first.messages);
        assert_eq!(after_second.rounds, 2);
    }
}
