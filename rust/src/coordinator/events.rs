//! Topology-change events (the Fig. 11 perturbation and the
//! `examples/topology_change.rs` scenario).

use crate::config::ExperimentConfig;
use crate::model::Problem;
use crate::session::SessionError;
use crate::util::rng::Rng;

/// A scheduled network change at a given outer iteration.
#[derive(Clone, Debug)]
pub enum NetworkEvent {
    /// Regenerate the ER topology with a fresh seed (the paper's Fig. 11
    /// "change the network topology at the 50-th allocation iteration").
    Rewire { seed: u64 },
    /// Scale every link capacity by `factor` (congestion shock).
    CapacityScale { factor: f64 },
    /// Set task class `class`'s admitted rate to `rate` — the breakpoints
    /// of a [`crate::session::spec::RateSpec::Trace`] compile to these
    /// (see [`crate::session::spec::ScenarioSpec::events`]).
    ClassRate { class: usize, rate: f64 },
}

/// An ordered schedule of events keyed by outer iteration.
#[derive(Clone, Debug, Default)]
pub struct EventSchedule {
    events: Vec<(usize, NetworkEvent)>,
}

impl EventSchedule {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn at(mut self, iter: usize, ev: NetworkEvent) -> Self {
        self.events.push((iter, ev));
        self.events.sort_by_key(|(i, _)| *i);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Events firing exactly at `iter`.
    pub fn fire(&self, iter: usize) -> impl Iterator<Item = &NetworkEvent> {
        self.events.iter().filter(move |(i, _)| *i == iter).map(|(_, e)| e)
    }

    /// Apply one event to a problem, producing the new problem instance.
    /// Fails only when a rewire's config has become invalid (e.g. an
    /// unknown topology name).
    pub fn apply(
        cfg: &ExperimentConfig,
        problem: &Problem,
        ev: &NetworkEvent,
    ) -> Result<Problem, SessionError> {
        match ev {
            NetworkEvent::Rewire { seed } => {
                let mut rng = Rng::seed_from(*seed);
                let fresh = cfg.build_problem(&mut rng)?;
                // a rewire regenerates the *topology*; the live workload
                // (class structure + any rates already updated by trace
                // events) must survive it. The scalar config can only
                // regenerate single-class-shaped problems, so a workload
                // whose session count no longer matches is a clean error,
                // not a silent desync (lam-length panics downstream).
                if problem.workload.n_sessions() != fresh.n_sessions() {
                    return Err(SessionError::InvalidScenario {
                        what: format!(
                            "Rewire regenerates {} sessions from the scalar config, but \
                             the live workload has {} (multi-class scenarios cannot be \
                             rewired through ExperimentConfig)",
                            fresh.n_sessions(),
                            problem.workload.n_sessions()
                        ),
                    });
                }
                Ok(Problem::with_workload(fresh.net, fresh.cost, problem.workload.clone()))
            }
            NetworkEvent::CapacityScale { factor } => {
                let mut net = problem.net.clone();
                let mut g = crate::graph::DiGraph::with_nodes(net.graph.n_nodes());
                for e in net.graph.edges() {
                    g.add_edge(e.src, e.dst, e.capacity * factor);
                }
                net.graph = g;
                net.rebuild_session_dags();
                // structure (sessions, edge ids) is unchanged: the workload
                // and any per-edge cost overrides carry over
                Ok(Problem::with_workload(net, problem.cost, problem.workload.clone())
                    .with_edge_cost(problem.edge_cost.clone()))
            }
            NetworkEvent::ClassRate { class, rate } => {
                if *class >= problem.workload.n_classes() {
                    return Err(SessionError::InvalidScenario {
                        what: format!(
                            "rate event for class {class}, but the workload has {} classes",
                            problem.workload.n_classes()
                        ),
                    });
                }
                if !(*rate > 0.0) {
                    return Err(SessionError::InvalidScenario {
                        what: format!("class {class} rate event must be > 0 (got {rate})"),
                    });
                }
                let mut workload = problem.workload.clone();
                workload.class_rates[*class] = *rate;
                Ok(Problem::with_workload(problem.net.clone(), problem.cost, workload)
                    .with_edge_cost(problem.edge_cost.clone()))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::cost::CostKind;

    #[test]
    fn schedule_fires_in_order() {
        let s = EventSchedule::new()
            .at(50, NetworkEvent::Rewire { seed: 9 })
            .at(10, NetworkEvent::CapacityScale { factor: 0.5 });
        assert_eq!(s.fire(10).count(), 1);
        assert_eq!(s.fire(50).count(), 1);
        assert_eq!(s.fire(11).count(), 0);
        assert!(!s.is_empty());
    }

    #[test]
    fn rewire_changes_topology() {
        let cfg = ExperimentConfig::paper_default();
        let mut rng = Rng::seed_from(cfg.seed);
        let p = cfg.build_problem(&mut rng).unwrap();
        let p2 = EventSchedule::apply(&cfg, &p, &NetworkEvent::Rewire { seed: 777 }).unwrap();
        assert_eq!(p2.total_rate, p.total_rate);
        // almost surely a different edge set
        assert!(
            p2.net.graph.n_edges() != p.net.graph.n_edges()
                || p2.net
                    .graph
                    .edges()
                    .iter()
                    .zip(p.net.graph.edges())
                    .any(|(a, b)| a != b)
        );
    }

    #[test]
    fn class_rate_updates_workload_and_rejects_bad_input() {
        let cfg = ExperimentConfig::paper_default();
        let mut rng = Rng::seed_from(2);
        let p = cfg.build_problem(&mut rng).unwrap();
        let p2 =
            EventSchedule::apply(&cfg, &p, &NetworkEvent::ClassRate { class: 0, rate: 45.0 })
                .unwrap();
        assert_eq!(p2.workload.class_rates, vec![45.0]);
        assert_eq!(p2.total_rate, 45.0);
        assert_eq!(p2.net.graph.n_edges(), p.net.graph.n_edges());
        // unknown class / non-positive rate are clean errors
        assert!(EventSchedule::apply(
            &cfg,
            &p,
            &NetworkEvent::ClassRate { class: 7, rate: 10.0 }
        )
        .is_err());
        assert!(EventSchedule::apply(
            &cfg,
            &p,
            &NetworkEvent::ClassRate { class: 0, rate: 0.0 }
        )
        .is_err());
    }

    #[test]
    fn rewire_preserves_trace_updated_rates_and_rejects_multi_class() {
        let cfg = ExperimentConfig::paper_default();
        let mut rng = Rng::seed_from(3);
        let p = cfg.build_problem(&mut rng).unwrap();
        // a trace breakpoint fired, then the topology rewires: the updated
        // rate must survive the rewire
        let p = EventSchedule::apply(&cfg, &p, &NetworkEvent::ClassRate { class: 0, rate: 48.0 })
            .unwrap();
        let p = EventSchedule::apply(&cfg, &p, &NetworkEvent::Rewire { seed: 555 }).unwrap();
        assert_eq!(p.workload.class_rates, vec![48.0]);
        assert_eq!(p.total_rate, 48.0);
        // a multi-class workload cannot be regenerated from the scalar
        // config: clean error, not a session-count desync
        let session = crate::session::Scenario::paper_default()
            .versions(2)
            .delta(0.2)
            .class("a", "log", 30.0, &[])
            .class("b", "sqrt", 20.0, &[])
            .build()
            .unwrap();
        assert!(EventSchedule::apply(
            &session.cfg,
            &session.problem,
            &NetworkEvent::Rewire { seed: 1 }
        )
        .is_err());
    }

    #[test]
    fn capacity_scale_preserves_structure() {
        let cfg = ExperimentConfig::paper_default();
        let mut rng = Rng::seed_from(1);
        let p = cfg.build_problem(&mut rng).unwrap();
        let p2 =
            EventSchedule::apply(&cfg, &p, &NetworkEvent::CapacityScale { factor: 2.0 }).unwrap();
        assert_eq!(p2.net.graph.n_edges(), p.net.graph.n_edges());
        assert_eq!(p2.cost, CostKind::Exp);
        for (a, b) in p2.net.graph.edges().iter().zip(p.net.graph.edges()) {
            assert!((a.capacity - 2.0 * b.capacity).abs() < 1e-12);
        }
    }
}
