//! Topology-change events (the Fig. 11 perturbation and the
//! `examples/topology_change.rs` scenario).

use crate::config::ExperimentConfig;
use crate::model::Problem;
use crate::session::SessionError;
use crate::util::rng::Rng;

/// A scheduled network change at a given outer iteration.
#[derive(Clone, Debug)]
pub enum NetworkEvent {
    /// Regenerate the ER topology with a fresh seed (the paper's Fig. 11
    /// "change the network topology at the 50-th allocation iteration").
    Rewire { seed: u64 },
    /// Scale every link capacity by `factor` (congestion shock).
    CapacityScale { factor: f64 },
}

/// An ordered schedule of events keyed by outer iteration.
#[derive(Clone, Debug, Default)]
pub struct EventSchedule {
    events: Vec<(usize, NetworkEvent)>,
}

impl EventSchedule {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn at(mut self, iter: usize, ev: NetworkEvent) -> Self {
        self.events.push((iter, ev));
        self.events.sort_by_key(|(i, _)| *i);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Events firing exactly at `iter`.
    pub fn fire(&self, iter: usize) -> impl Iterator<Item = &NetworkEvent> {
        self.events.iter().filter(move |(i, _)| *i == iter).map(|(_, e)| e)
    }

    /// Apply one event to a problem, producing the new problem instance.
    /// Fails only when a rewire's config has become invalid (e.g. an
    /// unknown topology name).
    pub fn apply(
        cfg: &ExperimentConfig,
        problem: &Problem,
        ev: &NetworkEvent,
    ) -> Result<Problem, SessionError> {
        match ev {
            NetworkEvent::Rewire { seed } => {
                let mut rng = Rng::seed_from(*seed);
                cfg.build_problem(&mut rng)
            }
            NetworkEvent::CapacityScale { factor } => {
                let mut net = problem.net.clone();
                let mut g = crate::graph::DiGraph::with_nodes(net.graph.n_nodes());
                for e in net.graph.edges() {
                    g.add_edge(e.src, e.dst, e.capacity * factor);
                }
                net.graph = g;
                net.rebuild_session_dags();
                Ok(Problem::new(net, problem.total_rate, problem.cost))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::cost::CostKind;

    #[test]
    fn schedule_fires_in_order() {
        let s = EventSchedule::new()
            .at(50, NetworkEvent::Rewire { seed: 9 })
            .at(10, NetworkEvent::CapacityScale { factor: 0.5 });
        assert_eq!(s.fire(10).count(), 1);
        assert_eq!(s.fire(50).count(), 1);
        assert_eq!(s.fire(11).count(), 0);
        assert!(!s.is_empty());
    }

    #[test]
    fn rewire_changes_topology() {
        let cfg = ExperimentConfig::paper_default();
        let mut rng = Rng::seed_from(cfg.seed);
        let p = cfg.build_problem(&mut rng).unwrap();
        let p2 = EventSchedule::apply(&cfg, &p, &NetworkEvent::Rewire { seed: 777 }).unwrap();
        assert_eq!(p2.total_rate, p.total_rate);
        // almost surely a different edge set
        assert!(
            p2.net.graph.n_edges() != p.net.graph.n_edges()
                || p2.net
                    .graph
                    .edges()
                    .iter()
                    .zip(p.net.graph.edges())
                    .any(|(a, b)| a != b)
        );
    }

    #[test]
    fn capacity_scale_preserves_structure() {
        let cfg = ExperimentConfig::paper_default();
        let mut rng = Rng::seed_from(1);
        let p = cfg.build_problem(&mut rng).unwrap();
        let p2 =
            EventSchedule::apply(&cfg, &p, &NetworkEvent::CapacityScale { factor: 2.0 }).unwrap();
        assert_eq!(p2.net.graph.n_edges(), p.net.graph.n_edges());
        assert_eq!(p2.cost, CostKind::Exp);
        for (a, b) in p2.net.graph.edges().iter().zip(p.net.graph.edges()) {
            assert!((a.capacity - 2.0 * b.capacity).abs() < 1e-12);
        }
    }
}
