//! Distributed CEC coordinator (the paper's system layer).
//!
//! * [`net`] — the message fabric: per-node inboxes over std channels, with
//!   delivered-message accounting (the communication-overhead metric).
//! * [`messages`] — the wire protocol between node actors.
//! * [`node`] — one actor per edge device: holds its own routing rows,
//!   computes local marginals, participates in the broadcast protocol.
//! * [`leader`] — the controller at the virtual source: drives allocation
//!   (GS-OMA / OMAD) rounds and topology-change events.
//! * [`serving`] — discrete-event serving simulator (Poisson arrivals,
//!   queues, real DNN execution via the PJRT runtime) producing *measured*
//!   utilities for the online learner.

pub mod events;
pub mod leader;
pub mod messages;
pub mod net;
pub mod node;
pub mod serving;
