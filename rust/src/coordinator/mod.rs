//! Distributed CEC coordinator (the paper's system layer).
//!
//! * [`net`] — the message fabric: per-node inboxes over std channels, with
//!   delivered-message accounting ([`net::CommStats`], the
//!   communication-overhead metric).
//! * [`messages`] — the wire protocol between node actors.
//! * [`node`] — one actor per edge device: holds its own routing rows,
//!   computes local marginals, participates in the broadcast protocol.
//! * [`leader`] — the controller at the virtual source:
//!   [`leader::DistributedOmd`] implements the standard
//!   [`crate::routing::Router`] step protocol (one step = one barriered
//!   round over live actors), so distributed runs stream through the
//!   session stack like every other solver — `"distributed-omd"` in the
//!   registry, [`crate::session::Session::distributed_run`] as the typed
//!   entry point, `CommStats` on the final `RunReport`.
//! * [`serving`] — discrete-event serving simulator (Poisson arrivals,
//!   queues, real DNN execution via the PJRT runtime) producing *measured*
//!   utilities for the online learner; its oracle rides the shared
//!   [`crate::engine::FlowEngine`] with the `--workers` knob.
//! * [`transport`] — the shard-to-shard message fabric abstraction
//!   ([`transport::Transport`]: loopback now, sockets later) plus the
//!   transport-agnostic [`transport::CommStats`] accounting with its
//!   per-shard breakdown.
//! * [`shard`] — the sharded coordination plane:
//!   [`shard::ShardedOmd`] (`"sharded-omd"` in the registry) partitions
//!   sessions across K leader shards running staleness-bounded rounds
//!   with λ-sync delta gossip; K = 1 degenerates to
//!   [`leader::DistributedOmd`].

pub mod events;
pub mod leader;
pub mod messages;
pub mod net;
pub mod node;
pub mod serving;
pub mod shard;
pub mod transport;
