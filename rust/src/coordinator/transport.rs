//! Transport abstraction for the sharded coordination plane.
//!
//! The sharded solver ([`super::shard::ShardedOmd`]) never talks to a
//! channel, socket, or queue directly — every inter-shard message goes
//! through the [`Transport`] trait, so a future socket (or RDMA, or
//! simulated-latency) transport slots in without touching solver code.
//! Two implementations ship today:
//!
//! * [`Loopback`] — bounded in-process channels, one mailbox per shard.
//!   The production default for the in-process plane and the reference
//!   for every equivalence test.
//! * [`Blackhole`] — counts sends and drops them; every receive times
//!   out. Used by the staleness-violation tests: a partitioned peer must
//!   surface as a typed [`crate::session::SessionError::StalenessExceeded`],
//!   never as a hang.
//!
//! Communication accounting is transport-agnostic: every transport owns a
//! [`ShardCounters`] and snapshots it into the unified [`CommStats`] —
//! totals plus a per-shard breakdown (`msgs`, `bytes`, `stale_rounds`) —
//! which [`crate::routing::Router::comm_stats`] surfaces on
//! [`crate::session::RunReport::comm`] and the suite CSV/JSON dumps.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::Mutex;
use std::time::Duration;

use super::messages::Msg;

/// Per-shard communication breakdown (messages *sent by* the shard, their
/// approximate wire bytes, and the rounds it completed on peer aggregates
/// older than its own round — the staleness the bound S admitted).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ShardComm {
    pub msgs: u64,
    pub bytes: u64,
    pub stale_rounds: u64,
}

/// Communication accounting for a distributed run (the paper's
/// communication-overhead metric). Totals are fabric-wide; `shards` is the
/// per-shard breakdown when the run used the sharded plane (empty for the
/// single-leader [`crate::coordinator::leader::DistributedOmd`] fabric).
/// Exposed on [`crate::session::RunReport::comm`] via
/// [`crate::routing::Router::comm_stats`].
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct CommStats {
    /// Messages delivered over the fabric (control + data plane).
    pub messages: u64,
    /// Approximate wire bytes (see [`super::messages::Msg::wire_bytes`]).
    pub bytes: u64,
    /// Rounds driven by the leader / shard plane.
    pub rounds: usize,
    /// Per-shard breakdown (empty when the plane is not sharded).
    pub shards: Vec<ShardComm>,
}

impl CommStats {
    /// Total stale rounds across every shard.
    pub fn stale_rounds(&self) -> u64 {
        self.shards.iter().map(|s| s.stale_rounds).sum()
    }

    /// Fold another snapshot into this one (per-shard entries merge by
    /// index) — used to carry counters across plane redeploys.
    pub fn absorb(&mut self, other: &CommStats) {
        self.messages += other.messages;
        self.bytes += other.bytes;
        if self.shards.len() < other.shards.len() {
            self.shards.resize(other.shards.len(), ShardComm::default());
        }
        for (a, b) in self.shards.iter_mut().zip(&other.shards) {
            a.msgs += b.msgs;
            a.bytes += b.bytes;
            a.stale_rounds += b.stale_rounds;
        }
    }
}

/// Shard-to-shard message fabric. `send`/`recv` address shards by index
/// (`0..shards()`); implementations must be callable from any thread.
pub trait Transport: Send + Sync {
    /// Number of shard endpoints this transport connects.
    fn shards(&self) -> usize;

    /// Deliver `msg` from shard `from` into shard `to`'s mailbox. Returns
    /// `false` when the recipient is unreachable (counted either way).
    fn send(&self, from: usize, to: usize, msg: Msg) -> bool;

    /// Blocking receive on shard `to`'s mailbox; `None` on timeout.
    fn recv(&self, to: usize, timeout: Duration) -> Option<Msg>;

    /// Telemetry hook: shard `shard` completed a round using peer
    /// aggregates older than its own round (within the staleness bound).
    fn note_stale_round(&self, shard: usize);

    /// Snapshot the traffic counters (the solver fills in `rounds`).
    fn comm(&self) -> CommStats;
}

/// Shared per-shard atomic counters — the accounting backend every
/// transport implementation reuses.
#[derive(Debug)]
pub struct ShardCounters {
    msgs: Vec<AtomicU64>,
    bytes: Vec<AtomicU64>,
    stale: Vec<AtomicU64>,
}

impl ShardCounters {
    pub fn new(shards: usize) -> Self {
        let zeros = |n: usize| (0..n).map(|_| AtomicU64::new(0)).collect();
        ShardCounters { msgs: zeros(shards), bytes: zeros(shards), stale: zeros(shards) }
    }

    /// Count one message of `bytes` wire bytes sent by `from`.
    pub fn count_send(&self, from: usize, bytes: u64) {
        self.msgs[from].fetch_add(1, Ordering::Relaxed);
        self.bytes[from].fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn note_stale(&self, shard: usize) {
        self.stale[shard].fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> CommStats {
        let shards: Vec<ShardComm> = self
            .msgs
            .iter()
            .zip(&self.bytes)
            .zip(&self.stale)
            .map(|((m, b), s)| ShardComm {
                msgs: m.load(Ordering::Relaxed),
                bytes: b.load(Ordering::Relaxed),
                stale_rounds: s.load(Ordering::Relaxed),
            })
            .collect();
        CommStats {
            messages: shards.iter().map(|s| s.msgs).sum(),
            bytes: shards.iter().map(|s| s.bytes).sum(),
            rounds: 0,
            shards,
        }
    }
}

/// In-process transport: one bounded channel per shard mailbox. The
/// capacity holds several rounds of gossip, so lockstep rounds never
/// block a sender; per-sender FIFO order is preserved by the channel.
pub struct Loopback {
    senders: Vec<SyncSender<Msg>>,
    receivers: Vec<Mutex<Receiver<Msg>>>,
    counters: ShardCounters,
}

impl Loopback {
    pub fn new(shards: usize) -> Self {
        let cap = shards.max(1) * 4 + 16;
        let mut senders = Vec::with_capacity(shards);
        let mut receivers = Vec::with_capacity(shards);
        for _ in 0..shards {
            let (tx, rx) = sync_channel(cap);
            senders.push(tx);
            receivers.push(Mutex::new(rx));
        }
        Loopback { senders, receivers, counters: ShardCounters::new(shards) }
    }
}

impl Transport for Loopback {
    fn shards(&self) -> usize {
        self.senders.len()
    }

    fn send(&self, from: usize, to: usize, msg: Msg) -> bool {
        self.counters.count_send(from, msg.wire_bytes() as u64);
        self.senders[to].send(msg).is_ok()
    }

    fn recv(&self, to: usize, timeout: Duration) -> Option<Msg> {
        let rx = self.receivers[to].lock().expect("loopback mailbox poisoned");
        match rx.recv_timeout(timeout) {
            Ok(msg) => Some(msg),
            Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => None,
        }
    }

    fn note_stale_round(&self, shard: usize) {
        self.counters.note_stale(shard);
    }

    fn comm(&self) -> CommStats {
        self.counters.snapshot()
    }
}

/// A transport that counts sends and drops them; every receive fails
/// immediately. Models a fully partitioned peer set: the staleness bound
/// can never be satisfied, so a round must surface
/// [`crate::session::SessionError::StalenessExceeded`] instead of hanging.
pub struct Blackhole {
    shards: usize,
    counters: ShardCounters,
}

impl Blackhole {
    pub fn new(shards: usize) -> Self {
        Blackhole { shards, counters: ShardCounters::new(shards) }
    }
}

impl Transport for Blackhole {
    fn shards(&self) -> usize {
        self.shards
    }

    fn send(&self, from: usize, _to: usize, msg: Msg) -> bool {
        self.counters.count_send(from, msg.wire_bytes() as u64);
        false
    }

    fn recv(&self, _to: usize, _timeout: Duration) -> Option<Msg> {
        // dropping everything means the wait can never be satisfied; fail
        // fast instead of sleeping out the timeout
        None
    }

    fn note_stale_round(&self, shard: usize) {
        self.counters.note_stale(shard);
    }

    fn comm(&self) -> CommStats {
        self.counters.snapshot()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loopback_delivers_and_counts_per_shard() {
        let t = Loopback::new(3);
        assert!(t.send(0, 1, Msg::FlowDelta { shard: 0, round: 0, edges: vec![(2, 0.5)] }));
        assert!(t.send(2, 1, Msg::FlowDelta { shard: 2, round: 0, edges: vec![] }));
        let got = t.recv(1, Duration::from_millis(100)).unwrap();
        assert!(matches!(got, Msg::FlowDelta { shard: 0, .. }));
        let comm = t.comm();
        assert_eq!(comm.messages, 2);
        assert_eq!(comm.shards.len(), 3);
        assert_eq!(comm.shards[0].msgs, 1);
        assert_eq!(comm.shards[1].msgs, 0);
        assert_eq!(comm.shards[2].msgs, 1);
        assert!(comm.shards[0].bytes > comm.shards[2].bytes);
    }

    #[test]
    fn loopback_recv_times_out_empty() {
        let t = Loopback::new(1);
        assert!(t.recv(0, Duration::from_millis(10)).is_none());
    }

    #[test]
    fn blackhole_drops_but_counts() {
        let t = Blackhole::new(2);
        assert!(!t.send(0, 1, Msg::Shutdown));
        assert!(t.recv(1, Duration::from_secs(3600)).is_none()); // returns at once
        assert_eq!(t.comm().messages, 1);
    }

    #[test]
    fn stale_rounds_aggregate_and_absorb() {
        let t = Loopback::new(2);
        t.note_stale_round(1);
        t.note_stale_round(1);
        let comm = t.comm();
        assert_eq!(comm.stale_rounds(), 2);
        assert_eq!(comm.shards[1].stale_rounds, 2);
        let mut base = CommStats::default();
        base.absorb(&comm);
        base.absorb(&comm);
        assert_eq!(base.stale_rounds(), 4);
        assert_eq!(base.shards.len(), 2);
    }
}
