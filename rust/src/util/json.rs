//! Minimal JSON parser + writer (substitute for the unavailable `serde_json`).
//!
//! Supports the full JSON grammar minus surrogate-pair escapes; numbers are
//! parsed as `f64`. Used for the artifact manifest, experiment configs, and
//! metrics dumps. Not performance-critical.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` for deterministic serialization.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    /// Lossless u64 accessor: accepts integral non-negative numbers
    /// *strictly below* 2^53 (where every integer is exactly representable
    /// as `f64` — at 2^53 itself, 2^53+1 already collapses onto the same
    /// double, so the boundary cannot be trusted) and decimal strings (the
    /// serialization of larger values, see [`Json::from_u64`]).
    pub fn as_u64(&self) -> Option<u64> {
        const MAX_EXACT: f64 = 9_007_199_254_740_992.0; // 2^53
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x < MAX_EXACT => Some(*x as u64),
            Json::Str(s) => s.parse().ok(),
            _ => None,
        }
    }

    /// Lossless u64 constructor: a JSON number when strictly below 2^53, a
    /// decimal string from 2^53 up (JSON numbers are doubles; larger
    /// integers would be silently corrupted).
    pub fn from_u64(x: u64) -> Json {
        if x < (1u64 << 53) {
            Json::Num(x as f64)
        } else {
            Json::Str(x.to_string())
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["a"]["b"]`-style access; returns Null for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Self {
        Json::Str(x.to_string())
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Self {
        Json::Bool(x)
    }
}
impl From<Vec<f64>> for Json {
    fn from(xs: Vec<f64>) -> Self {
        Json::Arr(xs.into_iter().map(Json::Num).collect())
    }
}

#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub at: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.at, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), at: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                _ => {
                    // collect the full UTF-8 sequence starting at c
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    if self.i > self.b.len() {
                        return Err(self.err("truncated utf8"));
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-2e3").unwrap(), Json::Num(-2000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str().unwrap(), "x\ny");
    }

    #[test]
    fn parse_unicode_escape() {
        let v = Json::parse(r#""é""#).unwrap();
        assert_eq!(v, Json::Str("é".into()));
    }

    #[test]
    fn parse_utf8_passthrough() {
        let v = Json::parse("\"héllo→\"").unwrap();
        assert_eq!(v, Json::Str("héllo→".into()));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,true,null,"s"],"nested":{"k":"v"}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nulll").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn get_on_non_object_is_null() {
        assert_eq!(Json::Num(1.0).get("x"), &Json::Null);
    }

    #[test]
    fn escaped_output() {
        let v = Json::Str("a\"b\\c\nd".into());
        assert_eq!(v.to_string(), r#""a\"b\\c\nd""#);
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn u64_roundtrip_beyond_f64_precision() {
        for x in [0u64, 42, 1 << 53, (1 << 53) + 1, u64::MAX] {
            let j = Json::from_u64(x);
            let text = j.to_string();
            let back = Json::parse(&text).unwrap();
            assert_eq!(back.as_u64(), Some(x), "via {text}");
        }
    }

    #[test]
    fn as_u64_rejects_lossy_values() {
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Num(1.5).as_u64(), None);
        assert_eq!(Json::Num(1e18).as_u64(), None, "beyond exact f64 range");
        // 2^53 itself is rejected: a hand-written 2^53+1 parses to the
        // same double, so the boundary value is ambiguous
        assert_eq!(Json::Num(9_007_199_254_740_992.0).as_u64(), None);
        assert_eq!(Json::Str("not a number".into()).as_u64(), None);
        assert_eq!(Json::Null.as_u64(), None);
    }

    #[test]
    fn manifest_style_access() {
        let v = Json::parse(r#"{"entries":{"m":{"rows":128,"file":"m.hlo.txt"}}}"#).unwrap();
        let m = v.get("entries").get("m");
        assert_eq!(m.get("rows").as_usize().unwrap(), 128);
        assert_eq!(m.get("file").as_str().unwrap(), "m.hlo.txt");
    }
}
