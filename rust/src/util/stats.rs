//! Summary statistics helpers shared by the bench harness and metrics.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile by linear interpolation on a *sorted copy* (q in [0, 100]).
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = (q / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Median absolute deviation (robust spread).
pub fn mad(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = median(xs);
    let devs: Vec<f64> = xs.iter().map(|x| (x - m).abs()).collect();
    median(&devs)
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((stddev(&xs) - 1.1180).abs() < 1e-3);
    }

    #[test]
    fn percentiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(median(&xs), 2.5);
    }

    #[test]
    fn mad_robust() {
        let xs = [1.0, 1.0, 1.0, 100.0];
        assert_eq!(mad(&xs), 0.0);
    }

    #[test]
    fn empty_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(median(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
    }
}
