//! Tiny CLI argument parser (substitute for the unavailable `clap`).
//!
//! Grammar: `jowr <subcommand> [--flag] [--key value] ...`.
//! Values are parsed on demand; unknown keys are reported at the end so
//! typos fail loudly instead of being silently ignored.

use std::collections::BTreeMap;

/// Parsed arguments for one subcommand invocation.
#[derive(Debug, Default, Clone)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse `argv` (without the program / subcommand names).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if key.is_empty() {
                    return Err("empty option name '--'".into());
                }
                if let Some((k, v)) = key.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.opts.insert(key.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.consumed.borrow_mut().push(name.to_string());
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.consumed.borrow_mut().push(name.to_string());
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: bad integer '{v}'")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: bad integer '{v}'")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: bad float '{v}'")),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Error if any provided option/flag was never consumed (catches typos).
    pub fn finish(&self) -> Result<(), String> {
        let seen = self.consumed.borrow();
        let unknown: Vec<&String> = self
            .opts
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !seen.contains(k))
            .collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(format!(
                "unknown option(s): {}",
                unknown.iter().map(|s| format!("--{s}")).collect::<Vec<_>>().join(", ")
            ))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn options_and_flags() {
        // note: a bare token right after `--flag` is taken as its value, so
        // flags go last (or use `--key=value` style)
        let a = parse("--n 25 --p=0.2 pos1 --verbose");
        assert_eq!(a.usize_or("n", 0).unwrap(), 25);
        assert_eq!(a.f64_or("p", 0.0).unwrap(), 0.2);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["pos1".to_string()]);
        a.finish().unwrap();
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.usize_or("iters", 50).unwrap(), 50);
        assert_eq!(a.get_or("name", "abilene"), "abilene");
    }

    #[test]
    fn negative_number_as_value() {
        let a = parse("--offset -3.5");
        assert_eq!(a.f64_or("offset", 0.0).unwrap(), -3.5);
    }

    #[test]
    fn unknown_options_detected() {
        let a = parse("--typo 1 --n 2");
        let _ = a.usize_or("n", 0);
        assert!(a.finish().is_err());
    }

    #[test]
    fn bad_value_errors() {
        let a = parse("--n abc");
        assert!(a.usize_or("n", 0).is_err());
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("--quiet --fast");
        assert!(a.flag("quiet") && a.flag("fast"));
        a.finish().unwrap();
    }
}
