//! Micro/macro benchmark harness (substitute for the unavailable `criterion`).
//!
//! Warms up, then runs timed samples until a wall-clock budget or sample cap
//! is hit, and reports median / MAD / min. Used by every `rust/benches/*`
//! target (all built with `harness = false`).

use std::time::{Duration, Instant};

use crate::util::stats;

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub samples: Vec<f64>, // seconds per iteration
}

impl Measurement {
    pub fn median_s(&self) -> f64 {
        stats::median(&self.samples)
    }
    pub fn mad_s(&self) -> f64 {
        stats::mad(&self.samples)
    }
    pub fn min_s(&self) -> f64 {
        stats::min(&self.samples)
    }

    pub fn report(&self) -> String {
        format!(
            "{:<40} median {:>12}  mad {:>10}  min {:>12}  (n={})",
            self.name,
            fmt_time(self.median_s()),
            fmt_time(self.mad_s()),
            fmt_time(self.min_s()),
            self.samples.len()
        )
    }
}

/// Human time formatting (ns/µs/ms/s).
pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

/// Benchmark runner with a per-case time budget.
pub struct Bencher {
    pub warmup: Duration,
    pub budget: Duration,
    pub max_samples: usize,
    pub results: Vec<Measurement>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(2),
            max_samples: 200,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(20),
            budget: Duration::from_millis(300),
            max_samples: 50,
            results: Vec::new(),
        }
    }

    /// Time `f`, which performs one logical iteration and returns a value
    /// that is black-boxed to prevent dead-code elimination.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &Measurement {
        // Warmup.
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            black_box(f());
        }
        // Timed samples.
        let mut samples = Vec::new();
        let b0 = Instant::now();
        while b0.elapsed() < self.budget && samples.len() < self.max_samples {
            let t = Instant::now();
            black_box(f());
            samples.push(t.elapsed().as_secs_f64());
        }
        self.results.push(Measurement { name: name.to_string(), samples });
        let m = self.results.last().unwrap();
        println!("{}", m.report());
        m
    }

    /// Wall-clock a one-shot closure (for end-to-end figure harnesses).
    pub fn once<T, F: FnOnce() -> T>(name: &str, f: F) -> (T, f64) {
        let t = Instant::now();
        let v = f();
        let dt = t.elapsed().as_secs_f64();
        println!("{:<40} {:>12}", name, fmt_time(dt));
        (v, dt)
    }
}

/// Opaque value sink (stable `std::hint::black_box`).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bencher {
            warmup: Duration::from_millis(1),
            budget: Duration::from_millis(20),
            max_samples: 10,
            results: vec![],
        };
        let m = b.bench("noop-sum", || (0..100u64).sum::<u64>());
        assert!(!m.samples.is_empty());
        assert!(m.median_s() >= 0.0);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_time(5e-9).ends_with("ns"));
        assert!(fmt_time(5e-6).ends_with("µs"));
        assert!(fmt_time(5e-3).ends_with("ms"));
        assert!(fmt_time(5.0).ends_with('s'));
    }
}
